"""Minimal stand-in for ``hypothesis`` on hermetic machines.

The real hypothesis is a declared dev dependency (see pyproject.toml) and is
always preferred: ``tests/conftest.py`` installs this module into
``sys.modules`` *only when* ``import hypothesis`` would fail, so air-gapped
containers can still collect and run the property tests instead of erroring
at import time.

This implements just the surface the test-suite uses -- ``@given`` /
``@settings`` with ``st.integers``, ``st.floats``, ``st.lists`` and
``st.data()`` -- as plain seeded random sampling.  No shrinking, no example
database, no health checks; a failing example is reported with its arguments
in the assertion traceback.  Draws are deterministic per test (seeded from
the test name) so failures reproduce.
"""
from __future__ import annotations

import random
import types
import zlib
from typing import Any, Callable, List, Optional

__version__ = "0.0-fallback"

_DEFAULT_MAX_EXAMPLES = 100


class _Strategy:
    """A sampleable value source; ``example(rng)`` draws one value."""

    def __init__(self, sample: Callable[[random.Random], Any], name: str):
        self._sample = sample
        self._name = name

    def example(self, rng: random.Random) -> Any:
        return self._sample(rng)

    def __repr__(self) -> str:
        return self._name


class _DataStrategy(_Strategy):
    """Marker for ``st.data()``: the test receives a draw handle."""

    def __init__(self):
        super().__init__(lambda rng: DataObject(rng), "data()")


class DataObject:
    def __init__(self, rng: random.Random):
        self._rng = rng

    def draw(self, strategy: _Strategy, label: Optional[str] = None) -> Any:
        return strategy.example(self._rng)

    def __repr__(self) -> str:
        return "data(...)"


def _integers(min_value: Optional[int] = None, max_value: Optional[int] = None
              ) -> _Strategy:
    lo = -(2 ** 63) if min_value is None else int(min_value)
    hi = 2 ** 63 - 1 if max_value is None else int(max_value)

    def sample(rng: random.Random) -> int:
        # bias toward boundaries, where off-by-ones live
        r = rng.random()
        if r < 0.05:
            return lo
        if r < 0.10:
            return hi
        return rng.randint(lo, hi)

    return _Strategy(sample, f"integers({lo}, {hi})")


def _floats(min_value: Optional[float] = None, max_value: Optional[float] = None,
            allow_nan: bool = True, allow_infinity: bool = True,
            width: int = 64) -> _Strategy:
    lo = -1e308 if min_value is None else float(min_value)
    hi = 1e308 if max_value is None else float(max_value)

    def sample(rng: random.Random) -> float:
        r = rng.random()
        if r < 0.05:
            return lo
        if r < 0.10:
            return hi
        if r < 0.20 and lo <= 0.0 <= hi:
            return 0.0
        return rng.uniform(lo, hi)

    return _Strategy(sample, f"floats({lo}, {hi})")


def _lists(elements: _Strategy, min_size: int = 0,
           max_size: Optional[int] = None) -> _Strategy:
    hi = min_size + 20 if max_size is None else int(max_size)

    def sample(rng: random.Random) -> List[Any]:
        size = rng.randint(min_size, hi)
        return [elements.example(rng) for _ in range(size)]

    return _Strategy(sample, f"lists({elements}, {min_size}, {hi})")


strategies = types.ModuleType("hypothesis.strategies")
strategies.integers = _integers
strategies.floats = _floats
strategies.lists = _lists
strategies.data = _DataStrategy
strategies.__all__ = ["integers", "floats", "lists", "data"]


def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, deadline: Any = None,
             **kwargs) -> Callable:
    """Decorator recording run parameters for :func:`given` to pick up."""

    def apply(fn: Callable) -> Callable:
        fn._fallback_max_examples = int(max_examples)
        return fn

    return apply


def given(*strategy_args: _Strategy, **strategy_kwargs: _Strategy) -> Callable:
    """Run the wrapped test over ``max_examples`` sampled argument tuples."""

    def wrap(fn: Callable) -> Callable:
        max_examples = getattr(fn, "_fallback_max_examples",
                               _DEFAULT_MAX_EXAMPLES)

        def runner():
            # deterministic per test: failures reproduce run to run
            seed = zlib.crc32(fn.__qualname__.encode())
            rng = random.Random(seed)
            for example_idx in range(max_examples):
                args = tuple(s.example(rng) for s in strategy_args)
                kwargs = {k: s.example(rng)
                          for k, s in strategy_kwargs.items()}
                try:
                    fn(*args, **kwargs)
                except _UnsatisfiedAssumption:
                    continue
                except Exception:
                    print(f"[hypothesis-fallback] falsifying example "
                          f"#{example_idx}: args={args!r} kwargs={kwargs!r}")
                    raise

        # pytest must see a zero-argument test function; deliberately no
        # __wrapped__ (inspect.signature would follow it to the original)
        runner.__name__ = fn.__name__
        runner.__qualname__ = fn.__qualname__
        runner.__doc__ = fn.__doc__
        runner.__module__ = fn.__module__
        runner.hypothesis_fallback_inner = fn
        return runner

    return wrap


class HealthCheck:
    """Accepted and ignored (API compatibility)."""
    all = staticmethod(lambda: [])
    too_slow = data_too_large = filter_too_much = None


def assume(condition: bool) -> bool:
    if not condition:
        raise _UnsatisfiedAssumption()
    return True


class _UnsatisfiedAssumption(Exception):
    pass
