"""Test-support utilities (hermetic-environment fallbacks)."""
