"""Training runtime: step factory, telemetry, trainer loop."""
from .step import make_eval_step, make_train_step

__all__ = ["make_train_step", "make_eval_step"]
