"""Training step factory: microbatched gradient accumulation, remat, AdamW.

The step is a single pjit-compiled function over globally-sharded arrays:
  * batch arrives pre-reshaped [microbatches, global_batch/microbatches, ...]
    (explicit, so the per-microbatch data-parallel sharding is visible),
  * gradients accumulate in f32 across a ``lax.scan`` over microbatches --
    each microbatch's backward ends in reduce-scatter/all-reduce collectives
    that XLA's latency-hiding scheduler overlaps with the next microbatch's
    compute (the standard accumulation-overlap trick),
  * AdamW with fp32 master params and bf16 moments (see repro.optim.adamw).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.optim import adamw
from repro.distributed.sharding import ShardingCtx


def make_train_step(model, opt_cfg: adamw.AdamWConfig,
                    ctx: Optional[ShardingCtx] = None,
                    q_chunk: int = 1024, k_chunk: int = 1024,
                    aux_weight: float = 0.01,
                    param_logical=None,
                    accum_dtype=jnp.float32):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state, metrics).

    ``batch`` leaves are [M, B/M, ...] (M = microbatches; M=1 supported).

    ``param_logical``: optional logical-axes pytree matching ``params``.
    When given (and ctx is set), each microbatch's gradients are constrained
    to the parameters' sharding BEFORE accumulation -- without it GSPMD
    all-reduces full-size f32 gradient tensors across the data axis every
    microbatch (measured 1.1 TB/device/step on Mixtral train_4k); with it
    the reduction becomes a reduce-scatter into the FSDP shards.
    ``accum_dtype``: gradient accumulator dtype (bf16 halves its traffic).
    """

    def micro_loss(params, mb):
        loss, parts = model.loss(params, mb, ctx, q_chunk=q_chunk,
                                 k_chunk=k_chunk, aux_weight=aux_weight)
        return loss

    def constrain_grads(grads):
        if ctx is None or param_logical is None:
            return grads
        return jax.tree.map(
            lambda g, l: ctx.c(g, l), grads, param_logical,
            is_leaf=lambda x: isinstance(x, tuple) and all(
                isinstance(e, (str, type(None))) for e in x))

    def train_step(params, opt_state, batch):
        M = jax.tree.leaves(batch)[0].shape[0]

        def one_micro(params, mb):
            loss, grads = jax.value_and_grad(micro_loss)(params, mb)
            return loss, constrain_grads(grads)

        if M == 1:
            mb = jax.tree.map(lambda x: x[0], batch)
            loss, grads = one_micro(params, mb)
        else:
            def body(carry, mb):
                g_acc, l_acc = carry
                loss, grads = one_micro(params, mb)
                g_acc = jax.tree.map(
                    lambda a, g: a + g.astype(accum_dtype), g_acc, grads)
                g_acc = constrain_grads(g_acc)
                return (g_acc, l_acc + loss), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, accum_dtype), params)
            g0 = constrain_grads(g0)
            (g_sum, l_sum), _ = jax.lax.scan(body, (g0, jnp.zeros(())), batch)
            grads = jax.tree.map(lambda g: g / M, g_sum)
            loss = l_sum / M

        grads, gnorm = adamw.clip_by_global_norm(grads, opt_cfg.clip_norm)
        new_params, new_opt = adamw.apply_updates(params, grads, opt_state, opt_cfg)
        metrics = {"loss": loss, "grad_norm": gnorm,
                   "lr": adamw.schedule(opt_cfg, new_opt["step"]),
                   "step": new_opt["step"]}
        return new_params, new_opt, metrics

    return train_step


def make_eval_step(model, ctx=None, q_chunk: int = 1024, k_chunk: int = 1024):
    def eval_step(params, batch):
        loss, parts = model.loss(params, batch, ctx,
                                 q_chunk=q_chunk, k_chunk=k_chunk)
        return {"loss": loss, **parts}
    return eval_step
