"""Sketch-based training telemetry: gradient agreement without moving gradients.

Estimating the pairwise cosine similarity of per-replica gradients normally
costs a full gradient gather (GBs).  With the paper's inner-product sketches
it costs ``O(m)`` per replica: each replica sketches its flattened gradient,
an all-gather moves only the m-sized sketches, and any monitor (host or
device) estimates all R^2 pairwise inner products from them.

Gradients of embedding / MoE-expert rows are *sparse with low overlap across
data shards* (each shard touches its own tokens' rows) -- precisely the
regime where Theorem 2 beats linear sketching, so the default sketcher here
is the device ICWS (weighted MinHash) path; a JL option is provided for
dense gradients.

Used for divergence detection (a replica whose gradient stops correlating
with the fleet signals data corruption or hardware fault -- see repro.ft)
and for diagnosing straggler-induced staleness in async settings.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops


@dataclasses.dataclass(frozen=True)
class TelemetryConfig:
    m: int = 256                  # sketch size (per replica)
    seed: int = 23
    method: str = "icws"          # icws (weighted minhash) | jl


def sketch_gradient(flat_grad: jnp.ndarray, cfg: TelemetryConfig):
    """[T] gradient -> sketch pytree (device path, batched-kernel friendly)."""
    if cfg.method == "jl":
        # hash-based +-1 projection, m rows (the JL sign stream of the
        # kernel registry, so these projections interoperate with
        # device-JL-sketched vectors)
        from repro.kernels.common import JL_SIGN_STREAM, hash_u32, salt_for
        t = jnp.arange(cfg.m, dtype=jnp.int32)
        idx = jnp.arange(flat_grad.shape[0], dtype=jnp.uint32)
        sign = jnp.where(
            (hash_u32(idx[None, :], salt_for(cfg.seed, JL_SIGN_STREAM, t)[:, None])
             & jnp.uint32(1)) == 0, 1.0, -1.0)
        proj = (sign @ flat_grad) / jnp.sqrt(cfg.m)
        return {"proj": proj}
    norm = jnp.linalg.norm(flat_grad)
    safe = jnp.maximum(norm, 1e-30)
    zn = flat_grad / safe
    w = (zn * zn)[None, :]
    keys = jnp.arange(flat_grad.shape[0], dtype=jnp.int32)[None, :]
    fp, val, _, _ = kops.icws_sketch(w, keys, zn[None, :], m=cfg.m,
                                     seed=cfg.seed)
    return {"fp": fp[0], "val": val[0], "norm": norm}


def estimate_pairwise(sketches, cfg: TelemetryConfig) -> jnp.ndarray:
    """Stacked sketches (leaves with leading replica dim R) -> [R, R] inner
    product estimates."""
    if cfg.method == "jl":
        proj = sketches["proj"]                       # [R, m]
        return proj @ proj.T
    fp, val, norm = sketches["fp"], sketches["val"], sketches["norm"]
    R = fp.shape[0]
    fa = jnp.repeat(fp, R, axis=0)
    va = jnp.repeat(val, R, axis=0)
    na = jnp.repeat(norm, R)
    fb = jnp.tile(fp, (R, 1))
    vb = jnp.tile(val, (R, 1))
    nb = jnp.tile(norm, R)
    est = kops.icws_estimate(fa, va, na, fb, vb, nb)
    return est.reshape(R, R)


def gradient_agreement(flat_grad: jnp.ndarray, axis_name: str,
                       cfg: TelemetryConfig) -> jnp.ndarray:
    """Inside shard_map over the data axis: [R, R] cosine-similarity estimate.

    Only m-sized sketches cross the network (all_gather), never gradients.
    """
    sk = sketch_gradient(flat_grad, cfg)
    gathered = jax.tree.map(
        lambda x: jax.lax.all_gather(x, axis_name), sk)
    est = estimate_pairwise(gathered, cfg)
    if cfg.method == "jl":
        return est
    norms = gathered["norm"]
    denom = jnp.outer(norms, norms)
    return est / jnp.maximum(denom, 1e-30)
