"""Production training loop: pipeline + pjit step + checkpoint + FT hooks.

Composes every substrate layer: deterministic resumable data, microbatched
train step, async atomic checkpoints, preemption handling, heartbeat/
straggler monitors, and sketch-based gradient telemetry.  Runs identically
on the CPU host mesh (tests, examples) and the production mesh (dry-run).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, Optional

import jax
import numpy as np

from repro.checkpoint import AsyncCheckpointer, latest_step, restore
from repro.configs.base import ModelConfig
from repro.data.pipeline import TokenPipeline
from repro.distributed.sharding import ShardingCtx, make_rules
from repro.ft import HeartbeatRegistry, PreemptionHandler, StragglerDetector
from repro.models import Model
from repro.optim import adamw
from repro.train.step import make_train_step


@dataclasses.dataclass
class TrainerConfig:
    steps: int = 100
    global_batch: int = 8
    seq: int = 128
    microbatches: int = 1
    seed: int = 0
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    log_every: int = 10
    opt: adamw.AdamWConfig = dataclasses.field(default_factory=adamw.AdamWConfig)


class Trainer:
    def __init__(self, model_cfg: ModelConfig, tcfg: TrainerConfig,
                 mesh=None, rules=None,
                 log_fn: Callable[[str], None] = print):
        self.model_cfg = model_cfg
        self.tcfg = tcfg
        self.model = Model(model_cfg)
        self.mesh = mesh
        self.ctx = ShardingCtx(mesh, rules or make_rules()) if mesh else None
        self.log = log_fn
        self.preemption = PreemptionHandler()
        self.heartbeats = HeartbeatRegistry(num_hosts=1, timeout=600)
        self.stragglers = StragglerDetector(num_hosts=1)
        self._ckpt = (AsyncCheckpointer(tcfg.ckpt_dir)
                      if tcfg.ckpt_dir else None)

    # ------------------------------------------------------------------
    def init_state(self):
        params, specs = self.model.init(jax.random.PRNGKey(self.tcfg.seed))
        opt_state = adamw.init_opt_state(params, self.tcfg.opt)
        return params, opt_state

    def maybe_restore(self, params, opt_state):
        start = 0
        if self._ckpt is not None:
            step = latest_step(self.tcfg.ckpt_dir)
            if step is not None:
                (params, opt_state), extra = restore(
                    self.tcfg.ckpt_dir, step, (params, opt_state))
                start = int(extra.get("data_step", step))
                self.log(f"[trainer] restored checkpoint step={step}")
        return params, opt_state, start

    # ------------------------------------------------------------------
    def run(self) -> Dict[str, list]:
        t = self.tcfg
        params, opt_state = self.init_state()
        params, opt_state, start_step = self.maybe_restore(params, opt_state)

        step_fn = make_train_step(self.model, t.opt, self.ctx,
                                  q_chunk=min(1024, t.seq),
                                  k_chunk=min(1024, t.seq))
        step_fn = jax.jit(step_fn, donate_argnums=(0, 1))

        pipe = TokenPipeline(seed=t.seed, global_batch=t.global_batch,
                             seq=t.seq, vocab=self.model_cfg.vocab_size,
                             microbatches=t.microbatches,
                             start_step=start_step)
        history = {"loss": [], "step_time": [], "step": []}
        try:
            for i in range(start_step, t.steps):
                batch = next(pipe)
                data_step = batch.pop("step")
                if t.microbatches == 1:
                    batch = {k: v[None] for k, v in batch.items()}
                t0 = time.time()
                params, opt_state, metrics = step_fn(params, opt_state, batch)
                loss = float(metrics["loss"])
                dt = time.time() - t0
                history["loss"].append(loss)
                history["step_time"].append(dt)
                history["step"].append(i)
                self.heartbeats.post(0, i)
                self.stragglers.record(0, dt)
                if i % t.log_every == 0:
                    self.log(f"[trainer] step={i} loss={loss:.4f} "
                             f"dt={dt*1e3:.0f}ms lr={float(metrics['lr']):.2e}")
                want_ckpt = self._ckpt is not None and (
                    (i + 1) % t.ckpt_every == 0 or self.preemption.should_save()
                    or i + 1 == t.steps)
                if want_ckpt:
                    self._ckpt.save(i + 1, (params, opt_state),
                                    extra={"data_step": i + 1})
                if self.preemption.should_save():
                    self.log("[trainer] preemption requested; checkpointed and exiting")
                    break
        finally:
            pipe.close()
            if self._ckpt is not None:
                self._ckpt.wait()
        return history
