"""Mergeable corpora: shard-and-merge parallel lake builds.

A data lake is rarely sketched in one stream: ingest naturally arrives
partitioned (per machine, per day, per source).  This module makes the
corpus layer *mergeable* so those partitions can be sketched independently
and combined afterwards, for every serving family:

  * :func:`split_by_key` partitions a sparse vector by a hash of its 31-bit
    folded key -- a disjoint, deterministic split of the coordinate domain
    (every sketch in this codebase keys on the folded coordinate, so a
    folded key lands wholly in exactly one shard, which is what the
    sampling merges require).
  * :func:`merge_stores` combines two row-aligned
    :class:`repro.data.store.CorpusStore` arenas holding sketches of
    disjoint partitions of the same vectors, delegating the per-row
    semantics to the family's ``merge_rows``:

      - **cs / jl** -- exact by linearity: the tables add.
      - **icws** -- coordinated per-slot min-merge: shard winners are
        re-scored under the merged norm on the shared u32 streams and the
        smaller hash wins (approximate: a shard may have discarded the
        union argmin; empirically ~90% of slots match a build-once sketch,
        and estimates stay within sampling noise).
      - **ts / ps** -- union re-subsampling: pool the kept slots, recompute
        the scheme threshold (TS: taus add; PS: ``min(T_a, T_b, T_cand)``),
        re-decide with the coordinated hash.  PS is *exactly* build-once;
        TS is exact modulo the rare per-shard overflow truncation.

  * :func:`build_sharded` runs the whole pipeline: partition every input
    vector across ``shards`` shards, sketch each shard independently (the
    parallelizable part), then compact with a pairwise merge tree.

Tenancy survives merging: row-aligned stores must carry identical
per-tenant row-range tables, and the merged arena inherits them verbatim.
"""
from __future__ import annotations

from typing import Sequence

import jax.numpy as jnp
import numpy as np

from repro import obs as _obs
from repro.core import u32
from repro.core.sampling import SAMPLE_KEY_MASK
from repro.core.types import SparseVec

from .store import CorpusStore


def split_by_key(v: SparseVec, shards: int, shard: int) -> SparseVec:
    """The ``shard``-th of ``shards`` disjoint key-partitions of ``v``.

    A coordinate goes to shard ``mix32(key) % shards`` where ``key`` is the
    31-bit folded index -- the exact key every u32-contract sketch hashes.
    Folding *before* hashing guarantees two raw indices that alias to one
    key (and are therefore one coordinate to every sketch) land in the same
    shard, so partitions have disjoint key supports: the precondition of
    the sampling union-merges, and what makes partition inner products sum
    to the full inner product.
    """
    shards = int(shards)
    if shards < 1:
        raise ValueError("shards must be >= 1")
    if not 0 <= int(shard) < shards:
        raise ValueError(f"shard {shard} out of range for {shards} shards")
    if shards == 1:
        return v
    keys = (np.asarray(v.indices, np.int64)
            & np.int64(SAMPLE_KEY_MASK)).astype(np.uint32)
    keep = u32.mix32(keys) % np.uint32(shards) == np.uint32(shard)
    return SparseVec(indices=v.indices[keep], values=v.values[keep], n=v.n)


def partition_by_key(v: SparseVec, shards: int) -> "tuple[SparseVec, ...]":
    """All ``shards`` disjoint key-partitions of ``v`` in one hash pass.

    Identical assignment rule to :func:`split_by_key` (element ``s`` equals
    ``split_by_key(v, shards, s)``), but each key is folded and hashed
    once instead of once per shard -- the producer-side partition pass of
    a parallel build does this, not ``shards`` independent scans.
    """
    shards = int(shards)
    if shards < 1:
        raise ValueError("shards must be >= 1")
    if shards == 1:
        return (v,)
    keys = (np.asarray(v.indices, np.int64)
            & np.int64(SAMPLE_KEY_MASK)).astype(np.uint32)
    sid = u32.mix32(keys) % np.uint32(shards)
    return tuple(
        SparseVec(indices=v.indices[sid == s], values=v.values[sid == s],
                  n=v.n)
        for s in range(shards))


def merge_stores(a: CorpusStore, b: CorpusStore) -> CorpusStore:
    """Merge two row-aligned stores of disjoint-partition sketches.

    Row ``i`` of ``a`` and row ``i`` of ``b`` must sketch disjoint
    key-partitions of the same underlying vector (e.g. two
    :func:`split_by_key` shards); the result's row ``i`` sketches their
    union, with per-row semantics from the family's ``merge_rows`` (see
    the module docstring for the per-family guarantees).  Both stores must
    share the family *including its seed* -- every merge rule re-decides
    winners on the coordinated u32 hash streams, which only line up when
    both sides drew from the same seed -- and carry identical per-tenant
    row-range tables, which the merged arena inherits.

    Returns a fresh store (on ``a``'s mesh); the inputs are not consumed.
    """
    if getattr(a, "packed", False) or getattr(b, "packed", False):
        raise ValueError(
            "cannot merge packed stores: the packed wire layout is frozen "
            "(ICWS drops the argkeys re-leveling sidecar and values are "
            "bf16-truncated) -- merge unpacked stores, then pack the result")
    if a.family != b.family:
        raise ValueError(
            "cannot merge stores of different families or seeds: "
            f"{a.family!r} vs {b.family!r} -- coordinated merge semantics "
            "require identical family parameters, seed included")
    if a.fields != b.fields:
        raise ValueError(f"field count mismatch: {a.fields} vs {b.fields}")
    if len(a) != len(b):
        raise ValueError(
            f"stores must be row-aligned: {len(a)} vs {len(b)} rows")
    tenants_a = {t: a.tenant_ranges(t) for t in a.tenants()}
    tenants_b = {t: b.tenant_ranges(t) for t in b.tenants()}
    if tenants_a != tenants_b:
        raise ValueError(
            "tenant row-range tables differ; merge inputs must assign "
            f"identical rows to identical tenants ({tenants_a} vs "
            f"{tenants_b})")
    with _obs.span("merge.merge_stores", family=a.family.name,
                   rows=len(a), fields=a.fields):
        merged = a.family.merge_rows(a.field_arrays(), b.field_arrays())
        out = CorpusStore(family=a.family, fields=a.fields, mesh=a.mesh)
        out.append(*merged)
    if _obs.enabled():
        _obs.counter("merge.merges_total", family=a.family.name).inc()
    for t, ranges in tenants_a.items():
        out._tenant_ranges[t] = [tuple(r) for r in ranges]
    return out


def _field_rows(rows) -> "list[tuple]":
    """Normalize ``rows`` to a list of per-row field tuples."""
    rows = list(rows)
    if rows and isinstance(rows[0], SparseVec):
        return [(r,) for r in rows]
    return [tuple(r) for r in rows]


def build_sharded(rows: Sequence, *, family, shards: int, mesh=None,
                  bucket: int = 256) -> CorpusStore:
    """Build a corpus store from ``rows`` via ``shards`` parallel partitions.

    ``rows`` is either a sequence of :class:`SparseVec` (a single-field
    corpus) or a sequence of per-row field tuples ``(v_f1, .., v_fF)`` (a
    field-stacked corpus).  Each row is key-partitioned across the shards
    (:func:`split_by_key`), every shard is sketched independently with the
    family's batch launch -- the part a parallel lake build distributes --
    and the shard stores compact through a pairwise merge tree
    (:func:`merge_stores`).

    With ``shards=1`` this is exactly the single-stream build.  For the
    linear and sampling families the merged rows match the single-stream
    rows (bitwise / exactly, see :func:`merge_stores`); for ICWS the
    merged rows are statistically equivalent re-leveled sketches whose
    estimates agree with single-stream to within sampling noise.
    """
    shards = int(shards)
    if shards < 1:
        raise ValueError("shards must be >= 1")
    field_rows = _field_rows(rows)
    if not field_rows:
        raise ValueError("build_sharded needs at least one row")
    F = len(field_rows[0])
    n_comp = len(family.components)
    # one partition pass over the data (each key folded + hashed once),
    # then per-shard sketching -- the distributable part
    with _obs.span("merge.build_sharded", family=family.name, shards=shards,
                   rows=len(field_rows)):
        parted = [tuple(partition_by_key(v, shards) for v in fr)
                  for fr in field_rows]
        stores = []
        for s in range(shards):
            with _obs.span("merge.sketch_shard", family=family.name, shard=s):
                per_field = [family.sketch_rows([pr[f][s] for pr in parted],
                                                bucket=bucket)
                             for f in range(F)]
                stacked = tuple(
                    jnp.stack([per_field[f][i] for f in range(F)], axis=0)
                    for i in range(n_comp))
                store = CorpusStore(family=family, fields=F, mesh=mesh)
                store.append(*stacked)
            stores.append(store)
        while len(stores) > 1:
            merged = [merge_stores(stores[i], stores[i + 1])
                      for i in range(0, len(stores) - 1, 2)]
            if len(stores) % 2:
                merged.append(stores[-1])
            stores = merged
        return stores[0]
