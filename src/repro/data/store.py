"""Canonical field-stacked sketch store with amortized device-side append.

This is the single device-resident copy of a sketch corpus.  All F field
corpora of a dataset-search index (F = 3 for the §1.3 fields) live in one
set of preallocated buffers:

    fingerprints  [F, capacity, m]  int32
    values        [F, capacity, m]  float32
    norms         [F, capacity]     float32

``append`` writes new rows into the buffers with
``jax.lax.dynamic_update_slice`` under a jit whose buffer arguments are
*donated*, so on accelerators the write is in place and costs O(rows
appended), not O(corpus).  When the corpus outgrows its capacity the buffers
double (classic amortized growth: total copy work over any append sequence
is O(final size)).  This replaces the old chunk-list scheme whose first
query after an append re-concatenated every row ever ingested.

Unused capacity rows are *inert* under the estimate kernels: their
fingerprints hold the corpus pad sentinel (``-2``, the same value the
kernels pad with, which never equals a query fingerprint) and their norms
are zero (the estimate epilogue zeroes any pair with a zero norm).  Query
paths therefore run directly on the full-capacity buffers -- no exact-size
slice of the corpus is ever materialized on the hot path -- and slice the
*estimates* (cheap, ``O(capacity)`` per query row) down to the live row
count.  Per-row estimates are bitwise independent of trailing capacity, so
results are identical to running on exact-size arrays.

On CPU (no buffer donation in XLA's CPU client) the update falls back to a
buffer copy; the scheme still never restacks chunk lists and becomes truly
in-place on TPU.
"""
from __future__ import annotations

import contextlib
import functools
import warnings
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec

from repro.distributed.sharding import corpus_axis
from repro.kernels.estimate import CORPUS_PAD_FP


@contextlib.contextmanager
def _quiet_cpu_donation():
    # XLA's CPU client has no buffer donation; jax warns once per shape at
    # compile time.  The copy fallback is this module's documented CPU
    # behavior, so the warning is noise here (donation works on TPU).
    with warnings.catch_warnings():
        warnings.filterwarnings(
            "ignore", message="Some donated buffers were not usable")
        yield

# Corpus pad sentinel: the estimate kernels' own corpus padding fill
# (single definition in repro.kernels.estimate), so unused capacity rows
# never collide with any query fingerprint (queries pad with -1; live
# fingerprints are >= 0).
PAD_FP = CORPUS_PAD_FP


@functools.partial(jax.jit, donate_argnums=(0, 1, 2))
def _write_rows(fpb, vb, nb, fp, val, norm, off):
    zero = jnp.int32(0)
    return (jax.lax.dynamic_update_slice(fpb, fp, (zero, off, zero)),
            jax.lax.dynamic_update_slice(vb, val, (zero, off, zero)),
            jax.lax.dynamic_update_slice(nb, norm, (zero, off)))


@functools.partial(jax.jit, static_argnames=("cap",), donate_argnums=(0, 1, 2))
def _grow_buffers(fpb, vb, nb, *, cap: int):
    F, old, m = fpb.shape
    ext = cap - old
    return (jnp.concatenate([fpb, jnp.full((F, ext, m), PAD_FP, jnp.int32)],
                            axis=1),
            jnp.concatenate([vb, jnp.zeros((F, ext, m), jnp.float32)], axis=1),
            jnp.concatenate([nb, jnp.zeros((F, ext), jnp.float32)], axis=1))


class CorpusStore:
    """Growable field-stacked device store of ICWS sketch rows.

    ``fields=1`` is the generic single-corpus case (see
    :class:`repro.data.corpus.SketchCorpus`, a thin view over this class);
    ``fields=3`` backs :class:`repro.data.dataset_search.DatasetSearchIndex`
    with all three §1.3 field corpora in one canonical stack.
    """

    def __init__(self, m: int, fields: int = 1, min_capacity: int = 64,
                 mesh=None, row_multiple: int = 0):
        if fields < 1:
            raise ValueError("fields must be >= 1")
        if min_capacity < 1:
            raise ValueError("min_capacity must be >= 1")
        self.m = int(m)
        self.fields = int(fields)
        # a mesh with a multi-device corpus axis (see
        # repro.distributed.sharding.corpus_axis) shards the buffers over
        # their row dim at allocation, so the corpus memory -- not just the
        # query compute -- spreads across devices and no per-query
        # redistribution ever happens
        self.mesh = mesh
        self.corpus_axis = corpus_axis(mesh) if mesh is not None else None
        if self.corpus_axis is not None:
            self._buf_sharding = NamedSharding(
                mesh, PartitionSpec(None, self.corpus_axis, None))
            self._norm_sharding = NamedSharding(
                mesh, PartitionSpec(None, self.corpus_axis))
        else:
            self._buf_sharding = self._norm_sharding = None
        # round the capacity floor up to a multiple of row_multiple (the
        # corpus-axis size unless overridden): doubling preserves
        # divisibility, so every capacity this store ever allocates splits
        # evenly over the shards and the query path never re-pads rows
        if row_multiple < 1:
            row_multiple = (mesh.shape[self.corpus_axis]
                            if self.corpus_axis is not None else 1)
        self.row_multiple = int(row_multiple)
        self.min_capacity = (-(-int(min_capacity) // self.row_multiple)
                             * self.row_multiple)
        self._fp = None
        self._val = None
        self._norm = None
        self._size = 0
        self._cap = 0

    def __len__(self) -> int:
        return self._size

    @property
    def size(self) -> int:
        """Live rows per field."""
        return self._size

    @property
    def capacity(self) -> int:
        """Allocated rows per field (size <= capacity < 2 * max(size, min))."""
        return self._cap

    # -- ingestion -----------------------------------------------------------
    def append(self, fp, val, norm) -> None:
        """Append sketch rows: ``fp``/``val`` ``[F, b, m]``, ``norm [F, b]``
        (``[b, m]`` / ``[b]`` accepted when ``fields == 1``).

        All three components are validated against each other up front --
        a row-count mismatch raises here, at ingest, never at query time.
        """
        fp = jnp.asarray(fp, jnp.int32)
        val = jnp.asarray(val, jnp.float32)
        norm = jnp.asarray(norm, jnp.float32)
        if self.fields == 1 and fp.ndim == 2:
            fp, val, norm = fp[None], val[None], norm.reshape(1, -1)
        if fp.ndim != 3 or fp.shape[0] != self.fields or fp.shape[2] != self.m:
            raise ValueError(
                f"fingerprints must be [{self.fields}, b, {self.m}]; "
                f"got {tuple(fp.shape)}")
        if val.shape != fp.shape:
            raise ValueError(
                f"value rows {tuple(val.shape)} do not match fingerprint "
                f"rows {tuple(fp.shape)}")
        b = int(fp.shape[1])
        if norm.shape != (self.fields, b):
            raise ValueError(
                f"norm rows {tuple(norm.shape)} do not match fingerprint "
                f"rows ({self.fields}, {b})")
        if b == 0:
            return
        self._reserve(self._size + b)
        with _quiet_cpu_donation():
            self._fp, self._val, self._norm = _write_rows(
                self._fp, self._val, self._norm, fp, val, norm,
                jnp.int32(self._size))
        self._place()
        self._size += b

    def _reserve(self, n: int) -> None:
        if n <= self._cap:
            return
        cap = max(self._cap, self.min_capacity)
        while cap < n:
            cap *= 2
        if self._fp is None:
            F, m = self.fields, self.m
            self._fp = jnp.full((F, cap, m), PAD_FP, jnp.int32)
            self._val = jnp.zeros((F, cap, m), jnp.float32)
            self._norm = jnp.zeros((F, cap), jnp.float32)
        else:
            with _quiet_cpu_donation():
                self._fp, self._val, self._norm = _grow_buffers(
                    self._fp, self._val, self._norm, cap=cap)
        self._cap = cap
        self._place()

    def _place(self) -> None:
        """Pin the buffers to their row-sharded placement.

        ``device_put`` onto an array's existing sharding is a no-op, so
        this only moves data when an allocation / growth / update changed
        the placement; single-device stores skip it entirely."""
        if self._buf_sharding is None:
            return
        self._fp = jax.device_put(self._fp, self._buf_sharding)
        self._val = jax.device_put(self._val, self._buf_sharding)
        self._norm = jax.device_put(self._norm, self._norm_sharding)

    # -- views ---------------------------------------------------------------
    def buffers(self) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
        """The canonical full-capacity device buffers
        ``(fp [F, cap, m], val [F, cap, m], norm [F, cap])``.

        This is what query paths consume: unused capacity rows are inert
        under the estimate kernels (pad-sentinel fingerprints, zero norms),
        so estimates over the buffers match estimates over exact-size
        arrays row for row -- callers slice the *estimates* to
        ``[..., :len(store)]``, never the corpus.

        .. warning:: the next :meth:`append` DONATES these exact arrays
           back to XLA for the in-place update, which invalidates them on
           backends with donation (TPU/GPU: using a stale reference raises
           ``Array has been deleted``).  Re-fetch per query; never cache
           the returned arrays across appends.
        """
        if self._size == 0:
            raise ValueError("empty corpus")
        return self._fp, self._val, self._norm

    def arrays(self) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
        """Exact-size ``(fp [F, P, m], val [F, P, m], norm [F, P])`` slices
        (``[P, m]`` / ``[P]`` when ``fields == 1``).

        A transient copy when ``size < capacity`` -- intended for host-side
        cross-checks and tests; hot query paths use :meth:`buffers`.
        """
        if self._size == 0:
            raise ValueError("empty corpus")
        fp = self._fp[:, :self._size]
        val = self._val[:, :self._size]
        norm = self._norm[:, :self._size]
        if self.fields == 1:
            return fp[0], val[0], norm[0]
        return fp, val, norm

    def storage_doubles(self) -> float:
        """Paper accounting: 1.5 doubles per sample + 1 norm, per sketch."""
        return self._size * self.fields * (1.5 * self.m + 1.0)
