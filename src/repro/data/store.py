"""Canonical field-stacked sketch store with amortized device-side append.

This is the single device-resident copy of a sketch corpus, for ANY serving
family (:mod:`repro.data.families`).  All F field corpora of a
dataset-search index (F = 3 for the §1.3 fields) live in one set of
preallocated per-component buffers ``[F, capacity, *trailing]``:

    icws      fingerprints [F, cap, m] i32 + values [F, cap, m] f32
              + norms [F, cap] f32
    cs / jl   tables [F, cap, R, W] f32          (JL: R = 1, W = m)

``append`` writes new rows into the buffers with
``jax.lax.dynamic_update_slice`` under a jit whose buffer arguments are
*donated*, so on accelerators the write is in place and costs O(rows
appended), not O(corpus).  When the corpus outgrows its capacity the buffers
double (classic amortized growth: total copy work over any append sequence
is O(final size)).  This replaces the old chunk-list scheme whose first
query after an append re-concatenated every row ever ingested.

Unused capacity rows are *inert* under the family's estimate launch: each
component fills with its family's ``ComponentSpec.fill`` -- the ICWS corpus
pad sentinel (``-2``, which never equals a query fingerprint) with zero
norms, or plain zeros for linear tables (a zero table dots to zero).  Query
paths therefore run directly on the full-capacity buffers -- no exact-size
slice of the corpus is ever materialized on the hot path -- and slice the
*estimates* (cheap, ``O(capacity)`` per query row) down to the live row
count.  Per-row estimates are bitwise independent of trailing capacity, so
results are identical to running on exact-size arrays.

On CPU (no buffer donation in XLA's CPU client) the update falls back to a
buffer copy; the scheme still never restacks chunk lists and becomes truly
in-place on TPU.

**Multi-tenant arena.**  One store can hold many logical corpora: every
``append(..., tenant=...)`` records the written row range in a per-tenant
row-range table, so N tenants share one set of device buffers (one
allocation, one growth schedule, one jit shape family) while queries
address a single tenant's rows.  Because per-row estimates are bitwise
independent of the surrounding rows, a tenant's results off the shared
arena equal a dedicated single-tenant store bit for bit -- the serving
stack exploits this by slicing (contiguous tenants) or gathering
(fragmented tenants) at query time.  Rows appended without a tenant belong
to the arena at large and are only visible to tenant-less queries.
"""
from __future__ import annotations

import contextlib
import functools
import warnings
from typing import Dict, List, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec

from repro import obs as _obs
from repro.distributed.sharding import corpus_axis
from repro.kernels.estimate import CORPUS_PAD_FP

from .families import ICWSFamily


@contextlib.contextmanager
def _quiet_cpu_donation():
    # XLA's CPU client has no buffer donation; jax warns once per shape at
    # compile time.  The copy fallback is this module's documented CPU
    # behavior, so the warning is noise here (donation works on TPU).
    with warnings.catch_warnings():
        warnings.filterwarnings(
            "ignore", message="Some donated buffers were not usable")
        yield

# Corpus pad sentinel: the estimate kernels' own corpus padding fill
# (single definition in repro.kernels.estimate), so unused ICWS capacity
# rows never collide with any query fingerprint (queries pad with -1; live
# fingerprints are >= 0).  Linear families need no sentinel: their fill is
# plain zero.
PAD_FP = CORPUS_PAD_FP


@functools.partial(jax.jit, donate_argnums=(0,))
def _write_rows(bufs, rows, off):
    zero = jnp.int32(0)
    return tuple(
        jax.lax.dynamic_update_slice(b, r, (zero, off) + (zero,) * (b.ndim - 2))
        for b, r in zip(bufs, rows))


@functools.partial(jax.jit, static_argnames=("cap", "fills"),
                   donate_argnums=(0,))
def _grow_buffers(bufs, *, cap: int, fills):
    return tuple(
        jnp.concatenate(
            [b, jnp.full((b.shape[0], cap - b.shape[1]) + b.shape[2:],
                         fill, b.dtype)], axis=1)
        for b, fill in zip(bufs, fills))


class CorpusStore:
    """Growable field-stacked device store of sketch rows.

    The buffer layout, inert-row fills, and storage accounting come from a
    :mod:`repro.data.families` ``SketchFamily``; the default (``family=None``
    with ``m`` given) is the ICWS family, preserving the original
    ``(fingerprints, values, norms)`` three-buffer contract bit for bit.

    ``fields=1`` is the generic single-corpus case (see
    :class:`repro.data.corpus.SketchCorpus`, a thin view over this class);
    ``fields=3`` backs :class:`repro.data.dataset_search.DatasetSearchIndex`
    with all three §1.3 field corpora in one canonical stack.

    ``packed=True`` switches the resident buffers to the family's bit-packed
    wire layout (``family.packed_components``): sketch values are stored as
    bf16 halfword pairs in int32 words and decoded *inside* the estimate
    kernels, cutting resident bytes/row to ~50% (icws / linear) or ~75%
    (ts / ps, whose 31-bit exact-match keys are the information floor).
    ``append`` still takes ordinary unpacked sketch rows -- they are
    validated against the family's unpacked contract, then packed via
    ``family.pack_rows`` before the device write, so ingest call sites are
    unchanged.  Query paths consume the packed buffers directly through
    ``family.estimate_fields_packed``; rankings are bitwise identical to an
    unpacked store holding the bf16-roundtripped rows.  Packed stores are
    frozen for merging: the ICWS packed layout drops the ``argkeys``
    re-leveling sidecar, so :func:`repro.data.merge.merge_stores` refuses
    them.
    """

    def __init__(self, m: "int | None" = None, fields: int = 1,
                 min_capacity: int = 64, mesh=None, row_multiple: int = 0,
                 family=None, packed: bool = False):
        if family is None:
            if m is None:
                raise ValueError("provide a family or an ICWS sample count m")
            family = ICWSFamily(m=int(m))
        elif m is not None:
            raise ValueError(
                "m and family are mutually exclusive: the family defines its "
                "own sketch size")
        if fields < 1:
            raise ValueError("fields must be >= 1")
        if min_capacity < 1:
            raise ValueError("min_capacity must be >= 1")
        self.family = family
        self.packed = bool(packed)
        # append always validates against the unpacked row contract; the
        # resident layout is the packed one when packed=True
        self._row_specs = tuple(family.components)
        self._specs = (tuple(family.packed_components) if self.packed
                       else self._row_specs)
        self._fills = tuple(s.fill for s in self._specs)
        self.m = getattr(family, "m", None)
        self.fields = int(fields)
        # a mesh with a multi-device corpus axis (see
        # repro.distributed.sharding.corpus_axis) shards the buffers over
        # their row dim at allocation, so the corpus memory -- not just the
        # query compute -- spreads across devices and no per-query
        # redistribution ever happens
        self.mesh = mesh
        self.corpus_axis = corpus_axis(mesh) if mesh is not None else None
        if self.corpus_axis is not None:
            self._shardings = tuple(
                NamedSharding(mesh, PartitionSpec(
                    None, self.corpus_axis, *(None,) * len(s.trailing)))
                for s in self._specs)
        else:
            self._shardings = None
        # round the capacity floor up to a multiple of row_multiple (the
        # corpus-axis size unless overridden): doubling preserves
        # divisibility, so every capacity this store ever allocates splits
        # evenly over the shards and the query path never re-pads rows
        if row_multiple < 1:
            row_multiple = (mesh.shape[self.corpus_axis]
                            if self.corpus_axis is not None else 1)
        self.row_multiple = int(row_multiple)
        self.min_capacity = (-(-int(min_capacity) // self.row_multiple)
                             * self.row_multiple)
        self._bufs = None
        self._size = 0
        self._cap = 0
        # tenant id -> ordered [start, stop) row ranges (coalesced when
        # consecutive appends land back to back)
        self._tenant_ranges: Dict[str, List[Tuple[int, int]]] = {}

    def __len__(self) -> int:
        return self._size

    @property
    def size(self) -> int:
        """Live rows per field."""
        return self._size

    @property
    def capacity(self) -> int:
        """Allocated rows per field (size <= capacity < 2 * max(size, min))."""
        return self._cap

    # -- ingestion -----------------------------------------------------------
    def append(self, *rows, tenant: "str | None" = None) -> None:
        """Append sketch rows, one array per family component, each
        ``[F, b, *trailing]`` (the leading F axis may be omitted when
        ``fields == 1`` -- e.g. ICWS ``[b, m]`` / ``[b]``).

        All components are validated against each other up front -- a
        row-count mismatch raises here, at ingest, never at query time.

        ``tenant`` assigns the written rows to a logical corpus inside the
        shared arena (see the module docstring); ``None`` leaves them in
        the tenant-less pool.
        """
        if len(rows) != len(self._row_specs):
            raise ValueError(
                f"{self.family.name} rows have {len(self._row_specs)} "
                f"components ({', '.join(s.name for s in self._row_specs)}); "
                f"got {len(rows)}")
        rows = [jnp.asarray(r, s.dtype) for r, s in zip(rows, self._row_specs)]
        if self.fields == 1:
            rows = [r[None] if r.ndim == 1 + len(s.trailing) else r
                    for r, s in zip(rows, self._row_specs)]
        lead = self._row_specs[0]
        if (rows[0].ndim != 2 + len(lead.trailing)
                or rows[0].shape[0] != self.fields
                or rows[0].shape[2:] != lead.trailing):
            raise ValueError(
                f"{lead.name} rows must be [{self.fields}, b, "
                f"{', '.join(map(str, lead.trailing))}]; "
                f"got {tuple(rows[0].shape)}")
        b = int(rows[0].shape[1])
        for r, s in zip(rows[1:], self._row_specs[1:]):
            if r.shape != (self.fields, b) + s.trailing:
                raise ValueError(
                    f"{s.name} rows {tuple(r.shape)} do not match "
                    f"{lead.name} rows "
                    f"{(self.fields, b) + s.trailing}")
        if b == 0:
            return
        with _obs.span("store.append", family=self.family.name, rows=b,
                       tenant=tenant):
            if self.packed:
                rows = [jnp.asarray(r, s.dtype) for r, s in
                        zip(self.family.pack_rows(tuple(rows)), self._specs)]
            self._reserve(self._size + b)
            with _quiet_cpu_donation():
                self._bufs = _write_rows(self._bufs, tuple(rows),
                                         jnp.int32(self._size))
            self._place()
        if tenant is not None:
            ranges = self._tenant_ranges.setdefault(str(tenant), [])
            if ranges and ranges[-1][1] == self._size:
                ranges[-1] = (ranges[-1][0], self._size + b)
            else:
                ranges.append((self._size, self._size + b))
        self._size += b
        if _obs.enabled():
            fam = self.family.name
            _obs.counter("store.appends_total", family=fam).inc()
            _obs.gauge("store.rows", family=fam).set(self._size)
            _obs.gauge("store.resident_bytes", family=fam).set(
                self._cap * self.fields * self.bytes_per_row())

    # -- tenancy -------------------------------------------------------------
    def tenants(self) -> Tuple[str, ...]:
        """Tenant ids in first-append order."""
        return tuple(self._tenant_ranges)

    def tenant_ranges(self, tenant: str) -> Tuple[Tuple[int, int], ...]:
        """The tenant's ordered, coalesced ``[start, stop)`` row ranges.

        A tenant whose appends were never interleaved with other writes has
        exactly one range -- the query path then serves it by slicing the
        shared buffers instead of gathering.
        """
        try:
            return tuple(self._tenant_ranges[str(tenant)])
        except KeyError:
            raise KeyError(f"unknown tenant {tenant!r}; "
                           f"have {list(self._tenant_ranges)}") from None

    def tenant_rows(self, tenant: str) -> np.ndarray:
        """Global row indices of the tenant's rows, ascending."""
        return np.concatenate(
            [np.arange(a, b, dtype=np.int64)
             for a, b in self.tenant_ranges(tenant)] or
            [np.zeros(0, np.int64)])

    def tenant_size(self, tenant: str) -> int:
        return int(sum(b - a for a, b in self.tenant_ranges(tenant)))

    def describe_tenants(self) -> Dict[str, Dict[str, float]]:
        """Per-tenant accounting: rows, row ranges, and the tenant's share
        of the paper's storage-doubles ledger."""
        per_row = self.fields * self.family.storage_doubles_per_row()
        return {
            t: {"rows": float(self.tenant_size(t)),
                "ranges": float(len(self.tenant_ranges(t))),
                "storage_doubles": float(self.tenant_size(t) * per_row)}
            for t in self._tenant_ranges}

    def _reserve(self, n: int) -> None:
        if n <= self._cap:
            return
        cap = max(self._cap, self.min_capacity)
        while cap < n:
            cap *= 2
        if self._bufs is None:
            F = self.fields
            self._bufs = tuple(
                jnp.full((F, cap) + s.trailing, s.fill, s.dtype)
                for s in self._specs)
        else:
            with _obs.span("store.grow", family=self.family.name,
                           capacity=cap):
                with _quiet_cpu_donation():
                    self._bufs = _grow_buffers(self._bufs, cap=cap,
                                               fills=self._fills)
            if _obs.enabled():
                _obs.counter("store.grows_total",
                             family=self.family.name).inc()
        self._cap = cap
        self._place()

    def _place(self) -> None:
        """Pin the buffers to their row-sharded placement.

        ``device_put`` onto an array's existing sharding is a no-op, so
        this only moves data when an allocation / growth / update changed
        the placement; single-device stores skip it entirely."""
        if self._shardings is None:
            return
        self._bufs = tuple(jax.device_put(b, s)
                           for b, s in zip(self._bufs, self._shardings))

    # -- views ---------------------------------------------------------------
    def buffers(self) -> Tuple[jnp.ndarray, ...]:
        """The canonical full-capacity device buffers, one per family
        component: ICWS ``(fp [F, cap, m], val [F, cap, m], norm [F, cap])``,
        linear families ``(tables [F, cap, R, W],)``.

        This is what query paths consume: unused capacity rows are inert
        under the family's estimate launch (pad-sentinel fingerprints and
        zero norms, or all-zero tables), so estimates over the buffers
        match estimates over exact-size arrays row for row -- callers slice
        the *estimates* to ``[..., :len(store)]``, never the corpus.

        .. warning:: the next :meth:`append` DONATES these exact arrays
           back to XLA for the in-place update, which invalidates them on
           backends with donation (TPU/GPU: using a stale reference raises
           ``Array has been deleted``).  Re-fetch per query; never cache
           the returned arrays across appends.
        """
        if self._size == 0:
            raise ValueError("empty corpus")
        return self._bufs

    def arrays(self) -> Tuple[jnp.ndarray, ...]:
        """Exact-size ``[F, P, *trailing]`` component slices (the leading F
        axis is dropped when ``fields == 1``).

        A transient copy when ``size < capacity`` -- intended for host-side
        cross-checks and tests; hot query paths use :meth:`buffers`.
        """
        if self._size == 0:
            raise ValueError("empty corpus")
        out = tuple(b[:, :self._size] for b in self._bufs)
        if self.fields == 1:
            return tuple(o[0] for o in out)
        return out

    def field_arrays(self) -> Tuple[jnp.ndarray, ...]:
        """Exact-size component slices, ALWAYS ``[F, P, *trailing]``.

        Like :meth:`arrays` but without the ``fields == 1`` F-axis drop --
        the uniform layout the merge layer (:mod:`repro.data.merge`)
        consumes and the family ``merge_rows`` contracts are written
        against.
        """
        if self._size == 0:
            raise ValueError("empty corpus")
        return tuple(b[:, :self._size] for b in self._bufs)

    def bytes_per_row(self) -> int:
        """Resident device bytes per stored sketch row (one field), straight
        from the component specs that size the buffers: ``sum(itemsize *
        prod(trailing))``.  This is the quantity the packed layout shrinks
        (the ``perf/scale`` gate compares packed vs unpacked stores) --
        distinct from :meth:`storage_doubles`, the paper's idealized
        double-equivalents ledger."""
        return int(sum(
            np.dtype(s.dtype).itemsize
            * int(np.prod(s.trailing, dtype=np.int64))
            for s in self._specs))

    def storage_doubles(self) -> float:
        """Paper accounting, per family (icws: 1.5 doubles per sample + 1
        norm per sketch; linear: one double equivalent per table cell)."""
        return self._size * self.fields * self.family.storage_doubles_per_row()
