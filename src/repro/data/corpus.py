"""Device-resident ICWS sketch corpus: sketch once, query many times.

The paper's §1.3 dataset-search regime sketches every column of a data lake
once, then answers every query by estimating the query sketch against the
*whole corpus*.  This module keeps that corpus where the estimator runs:

  * ingestion pads sparse vectors into ``[B, N]`` batches and sketches them
    with the Pallas ICWS kernel (one kernel launch per batch, all fields);
  * fingerprints / values / norms live as pre-stacked ``[P, m]`` device
    arrays, appended in chunks (a list of per-batch arrays concatenated
    lazily, once, on first query after an append) -- queries never restack
    the corpus and never materialize a ``[P, m]`` copy of the query;
  * queries run through the one-vs-many estimate kernel
    (:func:`repro.kernels.ops.icws_estimate_corpus`), which broadcasts the
    single query sketch across the corpus grid dimension.

Host and device sketches are interchangeable here: :class:`repro.core.ICWS`
shares the kernel's RNG/fingerprint contract (see :mod:`repro.core.u32`),
so a corpus may be populated from either path.
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.types import SparseVec
from repro.kernels import ops


def pad_sparse_batch(vecs: Sequence[SparseVec], *, bucket: int = 256
                     ) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Pad sparse vectors into the kernel's ``[B, N]`` layout.

    Returns host arrays ``(w, keys, vals, norms)``: f32 normalized squared
    weights, int32 keys (mod 2^32, the kernel's key domain), f32 normalized
    signed values, and f64 norms.  ``N`` is the max nnz rounded up to a
    multiple of ``bucket`` so repeated ingests reuse the same jit cache entry.
    """
    B = len(vecs)
    max_nnz = max((v.nnz for v in vecs), default=0)
    N = max(bucket, -(-max_nnz // bucket) * bucket)
    w = np.zeros((B, N), np.float32)
    keys = np.zeros((B, N), np.int32)
    vals = np.zeros((B, N), np.float32)
    norms = np.zeros(B, np.float64)
    for i, v in enumerate(vecs):
        norm = v.norm()
        norms[i] = norm
        if v.nnz == 0 or norm == 0.0:
            continue
        z32 = (v.values / norm).astype(np.float32)
        k = v.nnz
        w[i, :k] = z32 * z32
        keys[i, :k] = (v.indices & np.int64(0xFFFFFFFF)).astype(np.uint32).astype(np.int32)
        vals[i, :k] = z32
    return w, keys, vals, norms


def sketch_batch(vecs: Sequence[SparseVec], *, m: int, seed: int = 0,
                 bucket: int = 256):
    """Device-sketch a batch of sparse vectors through the Pallas ICWS kernel.

    Returns device arrays ``(fp [B, m] int32, val [B, m] f32, norm [B] f32)``.
    """
    w, keys, vals, norms = pad_sparse_batch(vecs, bucket=bucket)
    fp, val, _ = ops.icws_sketch(jnp.asarray(w), jnp.asarray(keys),
                                 jnp.asarray(vals), m=m, seed=seed)
    return fp, val, jnp.asarray(norms, jnp.float32)


class SketchCorpus:
    """A growing corpus of ICWS sketches resident on the device.

    Append-in-chunks storage: each ``add_*`` call appends one ``[b, m]``
    device array per component; :meth:`arrays` concatenates the chunks into
    the canonical ``[P, m]`` layout exactly once per dirty state (cached
    until the next append).  The query path is a single one-vs-many kernel
    launch over those arrays.
    """

    def __init__(self, m: int, seed: int = 0, bucket: int = 256):
        self.m = int(m)
        self.seed = int(seed)
        self.bucket = int(bucket)
        self._chunks: List[Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]] = []
        self._cache: Optional[Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]] = None
        self._size = 0

    def __len__(self) -> int:
        return self._size

    # -- ingestion ----------------------------------------------------------
    def add_batch(self, vecs: Sequence[SparseVec]) -> None:
        """Sketch ``vecs`` on device (one kernel launch) and append them."""
        if not vecs:
            return
        fp, val, norm = sketch_batch(vecs, m=self.m, seed=self.seed,
                                     bucket=self.bucket)
        self.add_sketches(fp, val, norm)

    def add_sketches(self, fp, val, norm) -> None:
        """Append pre-computed sketch rows (``[b, m]``, ``[b]``).

        Accepts device or host arrays; host ICWS sketches interoperate
        because both paths share the fingerprint contract.
        """
        fp = jnp.asarray(fp, jnp.int32).reshape(-1, self.m)
        val = jnp.asarray(val, jnp.float32).reshape(-1, self.m)
        norm = jnp.asarray(norm, jnp.float32).reshape(-1)
        if fp.shape[0] != norm.shape[0]:
            raise ValueError("fingerprint/norm row count mismatch")
        self._chunks.append((fp, val, norm))
        self._cache = None
        self._size += int(fp.shape[0])

    # -- the device-resident [P, m] view ------------------------------------
    def arrays(self) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
        """The pre-stacked ``(fp [P, m], val [P, m], norm [P])`` device arrays.

        Consolidates pending chunks at most once per append; every query
        between appends reuses the same device buffers (no restacking).
        """
        if self._size == 0:
            raise ValueError("empty corpus")
        if self._cache is None:
            if len(self._chunks) == 1:
                self._cache = self._chunks[0]
            else:
                fp = jnp.concatenate([c[0] for c in self._chunks], axis=0)
                val = jnp.concatenate([c[1] for c in self._chunks], axis=0)
                norm = jnp.concatenate([c[2] for c in self._chunks], axis=0)
                self._cache = (fp, val, norm)
                self._chunks = [self._cache]
        return self._cache

    # -- queries ------------------------------------------------------------
    def sketch_query(self, v: SparseVec):
        """Sketch one query vector on device: ``(fq [1, m], vq [1, m], nq [1])``."""
        return sketch_batch([v], m=self.m, seed=self.seed, bucket=self.bucket)

    def estimate(self, fq, vq, nq) -> jnp.ndarray:
        """Inner-product estimates of one query sketch vs every corpus row.

        The query stays ``[1, m]`` end to end; the one-vs-many kernel
        broadcasts it across the corpus grid.  Returns ``[P]`` f32.
        """
        fpc, vc, nc = self.arrays()
        return ops.icws_estimate_corpus(jnp.asarray(fq, jnp.int32).reshape(1, -1),
                                        jnp.asarray(vq, jnp.float32).reshape(1, -1),
                                        jnp.asarray(nq, jnp.float32).reshape(()),
                                        fpc, vc, nc)

    def estimate_batch(self, fq, vq, nq) -> jnp.ndarray:
        """Inner-product estimates of Q query sketches vs every corpus row.

        One many-vs-many kernel launch for the whole query batch: each
        ``[bq, m]`` query block is re-read across the corpus grid dimension,
        so no ``[Q, P, m]`` intermediate ever exists.  Returns ``[Q, P]`` f32.
        """
        fpc, vc, nc = self.arrays()
        return ops.icws_estimate_many(
            jnp.asarray(fq, jnp.int32).reshape(-1, self.m),
            jnp.asarray(vq, jnp.float32).reshape(-1, self.m),
            jnp.asarray(nq, jnp.float32).reshape(-1),
            fpc, vc, nc)

    def estimate_vec(self, v: SparseVec) -> jnp.ndarray:
        """Sketch ``v`` and estimate it against the whole corpus."""
        fq, vq, nq = self.sketch_query(v)
        return self.estimate(fq, vq, nq[0])

    def estimate_vecs(self, vecs: Sequence[SparseVec]) -> jnp.ndarray:
        """Sketch a batch of queries (one launch) and estimate all of them
        against the whole corpus (one launch).  Returns ``[Q, P]`` f32."""
        fq, vq, nq = sketch_batch(vecs, m=self.m, seed=self.seed,
                                  bucket=self.bucket)
        return self.estimate_batch(fq, vq, nq)

    def storage_doubles(self) -> float:
        """Paper accounting: 1.5 doubles per sample + 1 norm, per sketch."""
        return self._size * (1.5 * self.m + 1.0)
