"""Device-resident ICWS sketch corpus: sketch once, query many times.

The paper's §1.3 dataset-search regime sketches every column of a data lake
once, then answers every query by estimating the query sketch against the
*whole corpus*.  This module keeps that corpus where the estimator runs:

  * ingestion pads sparse vectors into ``[B, N]`` batches with one flat
    numpy scatter (no per-vector Python loop) and sketches them with the
    Pallas ICWS kernel (one kernel launch per batch, all fields);
  * fingerprints / values / norms / argkeys live in a single canonical
    :class:`repro.data.store.CorpusStore` -- preallocated capacity-doubling
    device buffers, appended in place via ``jax.lax.dynamic_update_slice``
    in amortized O(rows appended), with all component shapes validated by
    the store at ingest;
  * queries run through the one-vs-many / many-vs-many estimate kernels
    directly on the store buffers (unused capacity rows are inert), and a
    mesh with a multi-device corpus axis shards the many-vs-many launch
    over corpus rows with bitwise-identical results.

Host and device sketches are interchangeable here: :class:`repro.core.ICWS`
shares the kernel's RNG/fingerprint contract (see :mod:`repro.core.u32`),
so a corpus may be populated from either path.
"""
from __future__ import annotations

from typing import Sequence, Tuple

import jax.numpy as jnp

from repro.core.types import SparseVec
from repro.kernels import ops

# canonical home of the padding/sketching helpers is repro.data.ingest;
# re-exported here for compatibility (this module was their original home)
from .ingest import pad_linear_batch, pad_sparse_batch, sketch_batch  # noqa: F401
from .store import CorpusStore


class SketchCorpus:
    """A growing corpus of ICWS sketches resident on the device.

    A thin single-field (F=1) view over :class:`repro.data.store.CorpusStore`:
    appends write into the store's preallocated buffers (amortized
    capacity-doubling growth, no chunk lists, no restacking), and queries
    launch the one-vs-many / many-vs-many estimate kernels directly on
    those buffers.  Pass a ``mesh`` whose corpus axis (see
    :func:`repro.distributed.sharding.corpus_axis`) spans 2+ devices to run
    batched estimates sharded over corpus rows -- results are bitwise
    identical to the single-device launch.
    """

    def __init__(self, m: int, seed: int = 0, bucket: int = 256, mesh=None):
        self.m = int(m)
        self.seed = int(seed)
        self.bucket = int(bucket)
        self.mesh = mesh
        # the store resolves the corpus axis, shards its buffers over it,
        # and keeps capacity divisible by the shard count
        self._store = CorpusStore(m=m, fields=1, mesh=mesh)
        self._axis = self._store.corpus_axis

    def __len__(self) -> int:
        return len(self._store)

    @property
    def capacity(self) -> int:
        return self._store.capacity

    # -- ingestion ----------------------------------------------------------
    def add_batch(self, vecs: Sequence[SparseVec]) -> None:
        """Sketch ``vecs`` on device (one kernel launch) and append them."""
        if not vecs:
            return
        fp, val, norm, argkey = sketch_batch(vecs, m=self.m, seed=self.seed,
                                             bucket=self.bucket)
        self.add_sketches(fp, val, norm, argkey)

    def add_sketches(self, fp, val, norm, argkeys) -> None:
        """Append pre-computed sketch rows (``[b, m]``, ``[b]``, ``[b, m]``).

        Accepts device or host arrays; host ICWS sketches interoperate
        because both paths share the fingerprint contract (``argkeys`` is
        :attr:`repro.core.icws.ICWSSketch.argkeys`, the merge sidecar).
        Validation -- component count, row counts, trailing shapes -- is
        the store's: everything is passed straight to
        :meth:`repro.data.store.CorpusStore.append`, which raises
        ``ValueError`` at ingest, not at query time.
        """
        self._store.append(jnp.asarray(fp, jnp.int32),
                           jnp.asarray(val, jnp.float32),
                           jnp.asarray(norm, jnp.float32),
                           jnp.asarray(argkeys, jnp.int32))

    # -- the device-resident view -------------------------------------------
    def arrays(self) -> Tuple[jnp.ndarray, ...]:
        """Exact-size ``(fp [P, m], val [P, m], norm [P], argkey [P, m])``
        device slices.

        A transient copy of the canonical store buffers when the corpus has
        spare capacity -- use for host cross-checks; query methods run on
        the buffers themselves.
        """
        return self._store.arrays()

    # -- queries ------------------------------------------------------------
    def sketch_query(self, v: SparseVec):
        """Sketch one query vector on device:
        ``(fq [1, m], vq [1, m], nq [1], kq [1, m])``."""
        return sketch_batch([v], m=self.m, seed=self.seed, bucket=self.bucket)

    def estimate(self, fq, vq, nq) -> jnp.ndarray:
        """Inner-product estimates of one query sketch vs every corpus row.

        The query stays ``[1, m]`` end to end; the one-vs-many kernel
        broadcasts it across the corpus grid.  Returns ``[P]`` f32.
        """
        fpb, vb, nb = self._store.buffers()[:3]
        est = ops.icws_estimate_corpus_stacked(
            jnp.asarray(fq, jnp.int32).reshape(1, -1),
            jnp.asarray(vq, jnp.float32).reshape(1, -1),
            jnp.asarray(nq, jnp.float32).reshape(()),
            fpb, vb, nb)
        return est[:len(self)]

    def estimate_batch(self, fq, vq, nq) -> jnp.ndarray:
        """Inner-product estimates of Q query sketches vs every corpus row.

        One many-vs-many kernel launch for the whole query batch (per mesh
        shard when the corpus is sharded): each ``[bq, m]`` query block is
        re-read across the corpus grid dimension, so no ``[Q, P, m]``
        intermediate ever exists.  Returns ``[Q, P]`` f32.
        """
        fpb, vb, nb = self._store.buffers()[:3]
        fq = jnp.asarray(fq, jnp.int32).reshape(-1, self.m)
        vq = jnp.asarray(vq, jnp.float32).reshape(-1, self.m)
        nq = jnp.asarray(nq, jnp.float32).reshape(-1)
        if self._axis is not None:
            est = ops.icws_estimate_many_sharded(fq, vq, nq, fpb, vb, nb,
                                                 mesh=self.mesh,
                                                 axis=self._axis)
        else:
            est = ops.icws_estimate_many_stacked(fq, vq, nq, fpb, vb, nb)
        return est[:, :len(self)]

    def estimate_vec(self, v: SparseVec) -> jnp.ndarray:
        """Sketch ``v`` and estimate it against the whole corpus."""
        fq, vq, nq, _ = self.sketch_query(v)
        return self.estimate(fq, vq, nq[0])

    def estimate_vecs(self, vecs: Sequence[SparseVec]) -> jnp.ndarray:
        """Sketch a batch of queries (one launch) and estimate all of them
        against the whole corpus (one launch).  Returns ``[Q, P]`` f32."""
        fq, vq, nq, _ = sketch_batch(vecs, m=self.m, seed=self.seed,
                                     bucket=self.bucket)
        return self.estimate_batch(fq, vq, nq)

    def storage_doubles(self) -> float:
        """Paper accounting: 1.5 doubles per sample + 1 norm, per sketch."""
        return self._store.storage_doubles()
