"""Batch ingest helpers: pad sparse vectors into kernel layouts, sketch them.

The sketch kernels consume ``[B, N]`` padded batches.  Two padding
conventions exist, one per sketch-family class:

  * :func:`pad_sparse_batch` -- the ICWS layout: *normalized* squared
    weights + signed values + per-vector norms (the kernel masks ``w == 0``
    lanes as padding).
  * :func:`pad_linear_batch` -- the linear (CS/JL) layout: raw signed
    values, zero-valued padding (a zero value contributes sign * 0 = 0 to a
    linear sketch, so padding is inert with no mask at all).

Both fill with one flat numpy scatter over the concatenated indices/values
of the whole batch -- no per-vector Python loop -- and round ``N`` up to a
``bucket`` multiple so repeated ingests reuse one jit cache entry.

The sampling families (TS/PS) ingest differently: :func:`pad_sample_batch`
*builds the sketch itself* on the host (weighted sampling is a per-vector
select/top-k, not a kernel-shaped reduction) and emits finished fixed-slot
sample rows ``(key [B, slots], val [B, slots], tau [B])`` that the
key-match estimate kernel consumes directly.
"""
from __future__ import annotations

from typing import Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.dmh import dmh_replication, replicate_keys
from repro.core.sampling import priority_sample, threshold_sample
from repro.core.types import SparseVec
from repro.kernels import ops
from repro.kernels.sample_estimate import SAMPLE_QUERY_PAD_KEY


def _flat_scatter(vecs: Sequence[SparseVec], active: np.ndarray,
                  nnz: np.ndarray):
    """Row/col scatter coordinates + concatenated indices/values of the
    active vectors (the shared inner loop of both padding layouts)."""
    counts = nnz[active]
    idx_cat = np.concatenate([v.indices for v, a in zip(vecs, active) if a])
    val_cat = np.concatenate([v.values for v, a in zip(vecs, active) if a])
    rows = np.repeat(np.nonzero(active)[0], counts)
    starts = np.concatenate(([0], np.cumsum(counts)[:-1]))
    cols = np.arange(idx_cat.size) - np.repeat(starts, counts)
    return rows, cols, idx_cat, val_cat, counts


def _keys_i32(idx_cat: np.ndarray) -> np.ndarray:
    """Fold int64 indices into the kernels' uint32 key domain (as int32)."""
    return (idx_cat & np.int64(0xFFFFFFFF)).astype(np.uint32).astype(np.int32)


def pad_sparse_batch(vecs: Sequence[SparseVec], *, bucket: int = 256
                     ) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Pad sparse vectors into the ICWS kernel's ``[B, N]`` layout.

    Returns host arrays ``(w, keys, vals, norms)``: f32 normalized squared
    weights, int32 keys (mod 2^32, the kernel's key domain), f32 normalized
    signed values, and f64 norms.  ``N`` is the max nnz rounded up to a
    multiple of ``bucket`` so repeated ingests reuse the same jit cache entry.

    The fill is one flat numpy scatter over the concatenated indices/values
    of the whole batch -- no per-vector Python loop.  Norms stay per-vector
    ``SparseVec.norm()`` calls so the normalized values are bitwise
    identical to the host sketcher's (``np.sum`` pairwise summation).
    """
    B = len(vecs)
    nnz = np.fromiter((v.nnz for v in vecs), np.int64, count=B)
    max_nnz = int(nnz.max()) if B else 0
    N = max(bucket, -(-max_nnz // bucket) * bucket)
    w = np.zeros((B, N), np.float32)
    keys = np.zeros((B, N), np.int32)
    vals = np.zeros((B, N), np.float32)
    norms = np.array([v.norm() for v in vecs], np.float64)
    active = (nnz > 0) & (norms > 0.0) if B else np.zeros(0, bool)
    if np.any(active):
        rows, cols, idx_cat, val_cat, counts = _flat_scatter(vecs, active, nnz)
        z32 = (val_cat / np.repeat(norms[active], counts)).astype(np.float32)
        w[rows, cols] = z32 * z32
        keys[rows, cols] = _keys_i32(idx_cat)
        vals[rows, cols] = z32
    return w, keys, vals, norms


def pad_linear_batch(vecs: Sequence[SparseVec], *, bucket: int = 256
                     ) -> Tuple[np.ndarray, np.ndarray]:
    """Pad sparse vectors into the linear kernels' ``[B, N]`` layout.

    Returns host arrays ``(keys, vals)``: int32 keys (mod 2^32) and f32 RAW
    signed values (linear sketches are applied to the un-normalized vector;
    there is no norm side-channel).  Padding lanes hold value 0, which
    contributes nothing to any linear sketch.
    """
    B = len(vecs)
    nnz = np.fromiter((v.nnz for v in vecs), np.int64, count=B)
    max_nnz = int(nnz.max()) if B else 0
    N = max(bucket, -(-max_nnz // bucket) * bucket)
    keys = np.zeros((B, N), np.int32)
    vals = np.zeros((B, N), np.float32)
    active = nnz > 0 if B else np.zeros(0, bool)
    if np.any(active):
        rows, cols, idx_cat, val_cat, _ = _flat_scatter(vecs, active, nnz)
        keys[rows, cols] = _keys_i32(idx_cat)
        vals[rows, cols] = val_cat.astype(np.float32)
    return keys, vals


def pad_sample_batch(vecs: Sequence[SparseVec], *, slots: int,
                     method: str = "ts", seed: int = 0,
                     target: "int | None" = None
                     ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Build fixed-slot sampling-sketch rows for a batch of sparse vectors.

    Returns host arrays ``(keys [B, slots] i32, vals [B, slots] f32,
    tau [B] f32)`` in the :mod:`repro.kernels.sample_estimate` layout:
    live (key, value) pairs ascending-key in the leading slots, empty slots
    filled with the query-pad sentinel (-1) and value 0 (probability 0
    under the kernel's epilogue, hence inert), and ``tau`` the per-row
    probability scale.  ``method`` picks the scheme (``"ts"`` threshold /
    ``"ps"`` priority); the row contents are byte-identical to what the
    :mod:`repro.core.sampling` host oracles store, so host-oracle estimates
    and device key-match estimates agree on the same vectors.

    Unlike the ICWS/linear pads this is not a scatter into a kernel input
    -- the sampling *is* the sketch, and it is selection-bound host work
    (per-vector hash + sort/top-k), not a device reduction.
    """
    if method == "ts":
        def select(v):
            return threshold_sample(v.indices, v.values, slots=slots,
                                    seed=seed, target=target)
    elif method == "ps":
        if target is not None:
            raise ValueError("target is a threshold-sampling knob")

        def select(v):
            return priority_sample(v.indices, v.values, slots=slots,
                                   seed=seed)
    else:
        raise ValueError(f"unknown sampling method {method!r}; "
                         "choose 'ts' or 'ps'")
    B = len(vecs)
    keys = np.full((B, slots), SAMPLE_QUERY_PAD_KEY, np.int32)
    vals = np.zeros((B, slots), np.float32)
    taus = np.zeros(B, np.float32)
    for b, v in enumerate(vecs):
        k, vv, tau = select(v)
        keys[b, :k.size] = k.astype(np.int32)
        vals[b, :k.size] = vv.astype(np.float32)
        taus[b] = tau
    return keys, vals, taus


def sketch_batch(vecs: Sequence[SparseVec], *, m: int, seed: int = 0,
                 bucket: int = 256):
    """Device-sketch a batch of sparse vectors through the Pallas ICWS kernel.

    Returns device arrays ``(fp [B, m] int32, val [B, m] f32, norm [B] f32,
    argkey [B, m] int32)`` -- the four ICWS family components; ``argkey``
    is the merge sidecar (winning index per sample).
    """
    w, keys, vals, norms = pad_sparse_batch(vecs, bucket=bucket)
    fp, val, _, argkey = ops.icws_sketch(jnp.asarray(w), jnp.asarray(keys),
                                         jnp.asarray(vals), m=m, seed=seed)
    return fp, val, jnp.asarray(norms, jnp.float32), argkey


def dmh_sketch_batch(vecs: Sequence[SparseVec], *, m: int, seed: int = 0,
                     bucket: int = 256):
    """Device-sketch a batch of sparse vectors through the Pallas DMH kernel.

    Same padded layout (:func:`pad_sparse_batch`) and the same four
    components as :func:`sketch_batch` -- only the kernel differs (one
    binning pass over the non-zeros instead of the m-way ICWS broadcast),
    so lake ingest swaps families with no layout change.

    For m > 64 each key is expanded into ``dmh_replication(m)``
    pseudo-key replicas before the launch (the host oracle
    :meth:`repro.core.dmh.DMH.sketch` expands identically through the
    shared :func:`repro.core.dmh.replicate_keys`); the kernel itself is
    replication-agnostic.  Pad lanes replicate inertly (w = 0 ranks to
    the +inf sentinel regardless of the pseudo-key).
    """
    w, keys, vals, norms = pad_sparse_batch(vecs, bucket=bucket)
    c = dmh_replication(m)
    if c > 1:
        keys = replicate_keys(keys.view(np.uint32), c).view(np.int32)
        w = np.tile(w, (1, c))
        vals = np.tile(vals, (1, c))
    fp, val, _, argkey = ops.dmh_sketch(jnp.asarray(w), jnp.asarray(keys),
                                        jnp.asarray(vals), m=m, seed=seed)
    return fp, val, jnp.asarray(norms, jnp.float32), argkey
