"""Dataset-search service: the paper's motivating application (Section 1.3).

Tables are (key column, value column) pairs.  Per table we pre-compute WMH
sketches of the four vector representations from Figure 3:

    x^{1[K]}   key multiplicities (1 per row)   -> join sizes (inner products)
    x^{V}      values summed at key index       -> post-join SUM / MEAN / corr
    x^{V^2}    squared values summed at key     -> post-join variance

Repeated join keys are aggregated (values summed, multiplicities counted), so
real-world tables with duplicate keys ingest cleanly and join sizes count
joined row *pairs*, as SQL join cardinality does.

Serving path (default, ``backend="device"``): all three field corpora live
in ONE canonical :class:`~repro.data.store.CorpusStore` -- field-stacked
``[3, capacity, m]`` device buffers with amortized in-place append (the
single device-resident copy of the corpus; there is no per-field duplicate
and no stack-for-batching duplicate).  Every device query, single or
batched, is sketched by one ``[3Q, N]`` ICWS kernel launch and answered by
ONE fused multi-field many-vs-many estimate launch
(:func:`repro.kernels.ops.icws_estimate_fields`) straight off the store
buffers; a single query is simply the Q=1 case.  Candidate ranking (top-k
by |sketch-estimated corr| among sufficiently-joinable tables) happens in
jnp before any result leaves the device; the host then refines the
correlation of just those k candidates from the matched KMV samples.

Sharded serving: construct the index with a ``mesh`` whose corpus axis (see
:func:`repro.distributed.sharding.corpus_axis`, logical axis ``"corpus"``,
by default the ``data`` mesh axis) spans 2+ devices, and the fused estimate
launch runs per shard over corpus rows under ``repro.compat.shard_map``
with queries replicated, followed by a per-shard top-k and a global merge.
Rankings are bitwise identical to the single-device path: per-row estimate
math is independent of the row count, and the top-k merge preserves
``jax.lax.top_k`` tie order (ascending index).

Oracle path (``backend="host"``): the original host-numpy WMH implementation,
kept verbatim as the cross-checked reference for the device path.  Every §1.3
statistic falls out of inner-product estimates:

    |K_A join K_B|      = <1[K_A], 1[K_B]>
    SUM(V_A after join) = <x^{V_A}, 1[K_B]>
    MEAN(V_A)           = SUM / join_size
    corr(V_A, V_B)      via the five inner products (Santos et al. 2021).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import KMV, SparseVec, WeightedMinHash, stack_wmh
from repro.core.kmv import KMVSketch
from repro.core.wmh import StackedWMH, WMHSketch
from repro import obs as _obs
from repro.kernels import ops

from .families import FAMILY_NAMES, make_family, wmh_storage
from .merge import build_sharded
from .store import CorpusStore

FIELDS = ("key_indicator", "values", "values_sq")

# Field-pair maps for the fused multi-field estimate kernel, in
# _corr_scores argument order (join, sum_a, sum_b, sum_a2, sum_b2, prod):
# estimate g pairs query field QFIELD[g] with corpus field CFIELD[g].
_IND, _VAL, _SQ = 0, 1, 2
QFIELD = (_IND, _VAL, _IND, _SQ, _IND, _VAL)
CFIELD = (_IND, _IND, _VAL, _IND, _SQ, _VAL)


@dataclasses.dataclass
class TableSketch:
    name: str
    key_indicator: Optional[WMHSketch]  # x^{1[K]} (host oracle; None if disabled)
    values: Optional[WMHSketch]         # x^{V}
    values_sq: Optional[WMHSketch]      # x^{V^2}
    sample: KMVSketch            # KMV keyed sample of (key -> value): the
                                 # correlation sketch of Santos et al. 2021
    n_rows: int


@dataclasses.dataclass
class SearchResult:
    name: str
    join_size: float
    joinability: float           # join size / query rows
    sum_b: float
    mean_b: float
    corr: float


@jax.jit
def _corr_scores(join, sum_a, sum_b, sum_a2, sum_b2, prod, min_join):
    """Ranking scores: |sketch-estimated corr| among joinable rows.

    All inputs are [Q, P] device arrays of inner-product estimates.  Rows
    failing ``join >= min_join`` score -1 so the host can drop them.  One
    jitted executable serves both the single-device and the sharded ranking
    path, so scores are bitwise identical between them.
    """
    var_a = join * sum_a2 - sum_a * sum_a
    var_b = join * sum_b2 - sum_b * sum_b
    cov = join * prod - sum_a * sum_b
    ok = (var_a > 0) & (var_b > 0)
    corr = jnp.where(ok, cov * jax.lax.rsqrt(jnp.where(ok, var_a * var_b, 1.0)),
                     0.0)
    corr = jnp.clip(corr, -1.0, 1.0)
    return jnp.where(join >= min_join, jnp.abs(corr), -1.0)


@functools.partial(jax.jit, static_argnames=("k",))
def _top_k(score, k: int):
    """Top-k scores + indices per query row; (scores [Q, k], idx [Q, k]) is
    the only data that leaves the device."""
    return jax.lax.top_k(score, k)


class DatasetSearchIndex:
    """Sketch once, query many times -- the data-lake discovery pattern."""

    def __init__(self, m: int = 256, seed: int = 0, key_space: int = 2 ** 31,
                 backend: str = "device", keep_host_oracle: bool = True,
                 mesh=None, family: str = "icws", packed: bool = False):
        if backend not in ("device", "host"):
            raise ValueError(f"unknown backend {backend!r}")
        if family not in FAMILY_NAMES:
            raise ValueError(
                f"unknown sketch family {family!r}; choose from {FAMILY_NAMES}")
        if family != "icws" and backend == "host":
            raise ValueError(
                "backend='host' is the WMH/ICWS oracle path; the other "
                "families (cs, jl, ts, ps) serve on the device path only")
        self.m = m
        self.seed = seed
        self.key_space = key_space
        self.backend = backend
        # the device serving family, sized to the storage budget an
        # m-sample WMH/ICWS sketch occupies (registry accounting), so
        # icws/cs/jl indexes built with one m are storage-matched and the
        # paper's comparison is fair by construction.  family="icws"
        # resolves to exactly m samples -- the original path, bit for bit.
        self.family = make_family(family, storage=wmh_storage(m), seed=seed)
        # host oracle sketches are required to serve backend="host" queries;
        # symmetrically, the device corpus is only built when the index
        # serves (or may serve) device queries.  Linear families can never
        # serve the (WMH) host path, so they never pay the per-table host
        # sketching cost, whatever the flag says.
        self.keep_host_oracle = ((keep_host_oracle or backend == "host")
                                 and family == "icws")
        self.keep_device_corpus = backend == "device"
        self.mesh = mesh
        self.sketcher = WeightedMinHash(m=m, seed=seed)
        self.kmv = KMV(k=m, seed=seed)
        self.tables: List[TableSketch] = []
        # tenant id -> global table positions, ascending; device stores keep
        # the same assignment as row ranges (table i IS store row i), this
        # mirror serves the host path and the per-tenant TableSketch lookup
        self._tenant_tables: Dict[str, List[int]] = {}
        # the single device-resident copy of all three field corpora: the
        # store resolves the corpus axis, shards its buffers over it, and
        # keeps capacity divisible by the shard count
        # packed=True stores the corpus in the family's bit-packed wire
        # layout and serves queries through the unpack-in-kernel estimate
        # launches; rankings equal an unpacked index over bf16-roundtripped
        # rows bit for bit (see repro.data.store.CorpusStore)
        self.packed = bool(packed)
        self.store: Optional[CorpusStore] = (
            CorpusStore(family=self.family, fields=len(FIELDS), mesh=mesh,
                        packed=self.packed)
            if self.keep_device_corpus else None)
        self._corpus_axis = (self.store.corpus_axis
                             if self.store is not None else None)

    # -- ingestion ----------------------------------------------------------
    def vectorize(self, keys: np.ndarray, values: np.ndarray
                  ) -> Tuple[SparseVec, SparseVec, SparseVec]:
        keys = np.asarray(keys, dtype=np.int64)
        values = np.asarray(values, dtype=np.float64)
        # the sketch key domain is [0, key_space): fold raw int64 keys FIRST,
        # so two distinct keys that collide mod key_space aggregate the same
        # way in all three field vectors (pre-fix, the signed-value vector
        # deduplicated raw keys and then hit from_pairs' duplicate-index
        # error when folded keys collided, while the indicator aggregated)
        keys = keys % np.int64(self.key_space)
        # zero values would vanish from the sparse vector; nudge them so the
        # key stays represented (the paper's vectors assume non-zero values)
        safe = np.where(values == 0.0, 1e-9, values)
        # aggregate repeated (post-modulus) join keys: multiplicity for the
        # indicator, summed (squared) values for the value vectors
        ind = SparseVec.from_pairs(keys, np.ones_like(safe), self.key_space,
                                   sum_duplicates=True)
        sq = SparseVec.from_pairs(keys, safe ** 2, self.key_space,
                                  sum_duplicates=True)
        # signed value sums can cancel to exactly zero, which from_pairs
        # would drop; nudge post-aggregation so the key stays represented
        uniq, inverse = np.unique(keys, return_inverse=True)
        vsum = np.zeros(uniq.size, np.float64)
        np.add.at(vsum, inverse, safe)
        val = SparseVec.from_pairs(uniq, np.where(vsum == 0.0, 1e-9, vsum),
                                   self.key_space)
        return ind, val, sq

    def add_table(self, name: str, keys: np.ndarray, values: np.ndarray,
                  tenant: Optional[str] = None):
        """Sketch one table into the corpus; ``tenant`` scopes it to a
        logical corpus inside the shared arena (see :meth:`query`)."""
        ind, val, sq = self.vectorize(keys, values)
        if self.store is not None:
            # device path: one [3, N] kernel launch sketches all three
            # fields; the rows append in place into the canonical store
            with _obs.family_context(self.family.name):
                comps = self.family.sketch_rows([ind, val, sq])
                self.store.append(*(c[:, None] for c in comps), tenant=tenant)
        self._register_table(name, keys, ind, val, sq, tenant=tenant)

    def add_tables_sharded(self, tables: Sequence[Tuple[str, np.ndarray,
                                                        np.ndarray]],
                           *, shards: int, tenant: Optional[str] = None):
        """Ingest many tables via a ``shards``-way parallel lake build.

        Every table's three field vectors are key-partitioned across the
        shards, each shard is sketched independently (the distributable
        part of a parallel build), and the shard corpora compact through
        the pairwise merge tree of :func:`repro.data.merge.build_sharded`
        before appending into this index's arena.  Per-table host-side
        metadata (the KMV correlation sample and, when kept, the host
        oracle sketches) is built single-stream -- the oracle path does
        not shard.

        Rankings off a sharded build match the single-stream build:
        bitwise for the linear families, exactly for the sampling families
        (modulo f32 tau rounding), and to within re-leveling noise for
        ICWS (top-k sets preserved on separated lakes).
        """
        if self.store is None:
            raise ValueError("sharded builds target the device corpus "
                             "(index constructed with backend='host')")
        tables = list(tables)
        if not tables:
            return
        rows, metas = [], []
        for name, keys, values in tables:
            ind, val, sq = self.vectorize(keys, values)
            rows.append((ind, val, sq))
            metas.append((name, keys, ind, val, sq))
        with _obs.family_context(self.family.name):
            merged = build_sharded(rows, family=self.family, shards=shards)
            self.store.append(*merged.field_arrays(), tenant=tenant)
        for name, keys, ind, val, sq in metas:
            self._register_table(name, keys, ind, val, sq, tenant=tenant)

    def _register_table(self, name, keys, ind, val, sq,
                        tenant: Optional[str] = None):
        host = {}
        if self.keep_host_oracle:
            host = {"key_indicator": self.sketcher.sketch(ind),
                    "values": self.sketcher.sketch(val),
                    "values_sq": self.sketcher.sketch(sq)}
        if tenant is not None:
            self._tenant_tables.setdefault(str(tenant), []).append(
                len(self.tables))
        self.tables.append(TableSketch(
            name=name,
            key_indicator=host.get("key_indicator"),
            values=host.get("values"),
            values_sq=host.get("values_sq"),
            sample=self.kmv.sketch(val),
            n_rows=len(keys)))

    # -- tenancy -------------------------------------------------------------
    def tenants(self) -> Tuple[str, ...]:
        return tuple(self._tenant_tables)

    def _tenant_table_list(self, tenant: Optional[str]) -> List[TableSketch]:
        if tenant is None:
            return self.tables
        try:
            sel = self._tenant_tables[str(tenant)]
        except KeyError:
            raise KeyError(f"unknown tenant {tenant!r}; "
                           f"have {list(self._tenant_tables)}") from None
        return [self.tables[i] for i in sel]

    # -- queries ------------------------------------------------------------
    def query(self, keys: np.ndarray, values: np.ndarray,
              top_k: int = 10, min_join: float = 1.0,
              backend: Optional[str] = None,
              tenant: Optional[str] = None) -> List[SearchResult]:
        """Rank corpus tables by |corr| among sufficiently-joinable tables.

        ``tenant`` restricts the search to one logical corpus of the shared
        arena: only that tenant's tables are ranked, and -- because per-row
        estimates are independent of the surrounding arena rows -- the
        results are bitwise what a dedicated single-tenant index over the
        same tables would return.
        """
        if not self.tables:
            return []
        backend = backend or self.backend
        if backend == "host":
            return self._query_host(keys, values, top_k, min_join,
                                    tenant=tenant)
        # the fused batch engine with Q=1: same kernels, same numerics --
        # single and batched queries are one code path by construction
        with _obs.family_context(self.family.name):
            return self._query_batch_device(
                [(np.asarray(keys), np.asarray(values))], top_k, min_join,
                tenant=tenant)[0]

    def _assemble_results(self, scores, idx, join_h, sum_b_h, q_sample,
                          n_q: int, tables: Optional[List[TableSketch]] = None
                          ) -> List[SearchResult]:
        """Host epilogue shared by all device paths: drop min_join failures,
        refine corr from the matched KMV samples, re-rank the k survivors
        by refined |corr|.  ``tables`` is the candidate list the estimate
        columns (and ``idx``) index into -- the full corpus by default, a
        tenant's subset under tenant-scoped queries."""
        if tables is None:
            tables = self.tables
        results = []
        for score, i in zip(scores, idx):
            if score < 0:                    # failed the min_join filter
                continue
            t = tables[int(i)]
            js = max(float(join_h[i]), 0.0)
            mean_b = float(sum_b_h[i]) / js if js > 0 else 0.0
            corr = self._sample_corr(q_sample, t.sample)
            results.append(SearchResult(
                name=t.name, join_size=js, joinability=js / n_q,
                sum_b=float(sum_b_h[i]), mean_b=mean_b, corr=corr))
        results.sort(key=lambda r: abs(r.corr), reverse=True)
        return results

    # -- batched queries -----------------------------------------------------
    def query_batch(self, queries: Sequence[Tuple[np.ndarray, np.ndarray]],
                    top_k: int = 10, min_join: float = 1.0,
                    backend: Optional[str] = None,
                    tenant: Optional[str] = None) -> List[List[SearchResult]]:
        """Answer Q ``(keys, values)`` queries in one shot.

        Device backend: ONE ``[3Q, N]`` ICWS sketch launch covers every field
        vector of every query, and ONE fused multi-field many-vs-many launch
        (per mesh shard when the corpus is sharded) computes all ``6 * Q * P``
        inner-product estimates.  Per-query results are identical to
        ``[self.query(k, v) for k, v in queries]``.

        Host backend: the host oracle has no kernel launches to amortize, so
        it simply loops the sequential oracle path.
        """
        queries = list(queries)
        if not self.tables or not queries:
            return [[] for _ in queries]
        backend = backend or self.backend
        if backend == "host":
            return [self._query_host(np.asarray(k), np.asarray(v),
                                     top_k, min_join, tenant=tenant)
                    for k, v in queries]
        with _obs.family_context(self.family.name):
            return self._query_batch_device(queries, top_k, min_join,
                                            tenant=tenant)

    def _estimate(self, qcomps, cbufs):
        """The fused single-device fields launch, routed to the packed
        (unpack-in-kernel) twin when the store holds the packed layout."""
        if self.packed:
            return self.family.estimate_fields_packed(
                qcomps, cbufs, qmap=QFIELD, cmap=CFIELD)
        return self.family.estimate_fields(qcomps, cbufs,
                                           qmap=QFIELD, cmap=CFIELD)

    def _estimate_sharded(self, qcomps, cbufs):
        if self.packed:
            return self.family.estimate_fields_packed_sharded(
                qcomps, cbufs, qmap=QFIELD, cmap=CFIELD, mesh=self.mesh,
                axis=self._corpus_axis)
        return self.family.estimate_fields_sharded(
            qcomps, cbufs, qmap=QFIELD, cmap=CFIELD, mesh=self.mesh,
            axis=self._corpus_axis)

    def _query_batch_device(self, queries, top_k: int, min_join: float,
                            tenant: Optional[str] = None
                            ) -> List[List[SearchResult]]:
        if self.store is None:
            raise ValueError("device corpus was not built at ingest "
                             "(index constructed with backend='host')")
        Q = len(queries)
        field_vecs: List[SparseVec] = []
        samples: List[KMVSketch] = []
        for keys, values in queries:
            ind, val, sq = self.vectorize(keys, values)
            field_vecs.extend((ind, val, sq))
            samples.append(self.kmv.sketch(val))
        # one kernel launch sketches all 3Q query field vectors; each
        # component reshapes [3Q, ...] -> [3, Q, ...] for the fields launch
        qcomps = tuple(
            jnp.swapaxes(c.reshape((Q, 3) + c.shape[1:]), 0, 1)
            for c in self.family.sketch_rows(field_vecs))

        # one fused launch (per corpus shard): all six field-pair estimates
        # for every query, straight off the canonical store buffers (unused
        # capacity rows are inert and sliced out of the estimates below)
        cbufs = self.store.buffers()
        tables = self.tables
        if tenant is not None:
            # tenant-scoped query against the shared arena.  Per-row
            # estimates are independent of the surrounding rows, so both
            # routes below are bitwise what a dedicated single-tenant store
            # would produce.
            ranges = self.store.tenant_ranges(tenant)
            tables = self._tenant_table_list(tenant)
            P = len(tables)
            if len(ranges) == 1:
                # contiguous tenant: slice the arena buffers before the
                # launch -- per-query cost scales with THIS tenant's rows,
                # not the arena (the performance-isolation fast path)
                lo, hi = ranges[0]
                est = self._estimate(qcomps,
                                     tuple(c[:, lo:hi] for c in cbufs))
            else:
                # fragmented tenant: full-arena launch, gather the tenant's
                # estimate columns (O(arena) compute, exact results)
                if self._corpus_axis is not None:
                    est = self._estimate_sharded(qcomps, cbufs)
                else:
                    est = self._estimate(qcomps, cbufs)
                est = est[:, :, jnp.asarray(self.store.tenant_rows(tenant))]
            est = est[:, :, :P]
            k = min(top_k, P)
            score = _corr_scores(est[0], est[1], est[2], est[3], est[4],
                                 est[5], jnp.float32(min_join))
            scores, idx = _top_k(score, k)
        else:
            if self._corpus_axis is not None:
                est = self._estimate_sharded(qcomps, cbufs)    # [6, Q, cap]
            else:
                est = self._estimate(qcomps, cbufs)
            P = len(self.tables)
            est = est[:, :, :P]

            k = min(top_k, P)
            score = _corr_scores(est[0], est[1], est[2], est[3], est[4],
                                 est[5], jnp.float32(min_join))
            if self._corpus_axis is not None:
                scores, idx = ops.sharded_top_k(score, k, mesh=self.mesh,
                                                axis=self._corpus_axis)
            else:
                scores, idx = _top_k(score, k)
        scores, idx = np.asarray(scores), np.asarray(idx)
        join_h, sum_b_h = np.asarray(est[0]), np.asarray(est[2])
        return [
            self._assemble_results(scores[qi], idx[qi], join_h[qi],
                                   sum_b_h[qi], samples[qi],
                                   n_q=max(len(queries[qi][0]), 1),
                                   tables=tables)
            for qi in range(Q)]

    # -- host oracle (the original numpy implementation, cross-checked) -----
    def _stack(self, field: str) -> StackedWMH:
        return stack_wmh([getattr(t, field) for t in self.tables])

    def _query_host(self, keys, values, top_k: int, min_join: float,
                    tenant: Optional[str] = None) -> List[SearchResult]:
        # guard per-query backend overrides too: a non-ICWS index must
        # never silently answer from the WMH oracle instead of its own
        # sketch method (the constructor enforces the same rule up front)
        if self.family.name != "icws":
            raise ValueError(
                "backend='host' is the WMH/ICWS oracle path; this index "
                f"serves the {self.family.name!r} family on the device path "
                "only")
        if not self.keep_host_oracle or self.tables[0].key_indicator is None:
            raise ValueError("host oracle sketches were not kept at ingest "
                             "(keep_host_oracle=False)")
        ind, val, sq = self.vectorize(keys, values)
        q_ind = self.sketcher.sketch(ind)
        q_sample = self.kmv.sketch(val)
        tables = self._tenant_table_list(tenant)
        P = len(tables)

        def est(q: WMHSketch, field: str) -> np.ndarray:
            A = stack_wmh([q] * P)
            return self.sketcher.estimate_batch(
                A, stack_wmh([getattr(t, field) for t in tables]))

        join = est(q_ind, "key_indicator")                  # <1A, 1B>
        sum_b = est(q_ind, "values")                        # <1A, VB>

        results = []
        for i, t in enumerate(tables):
            js = max(join[i], 0.0)
            if js < min_join:
                continue
            mean_b = sum_b[i] / js if js > 0 else 0.0
            corr = self._sample_corr(q_sample, t.sample)
            results.append(SearchResult(
                name=t.name, join_size=float(js),
                joinability=float(js / max(len(keys), 1)),
                sum_b=float(sum_b[i]), mean_b=float(mean_b), corr=corr))
        results.sort(key=lambda r: abs(r.corr), reverse=True)
        return results[:top_k]

    def _sample_corr(self, sa: KMVSketch, sb: KMVSketch,
                     min_pairs: int = 8) -> float:
        """Sample Pearson correlation over the join, from matched KMV samples
        (Santos et al. 2021 correlation sketches).

        Matched hashes within the k smallest of the union form a uniform
        sample of joined rows; the *sample* correlation sidesteps the
        catastrophic moment cancellation that estimated E[x^2]-E[x]^2
        suffers under sketch noise.  The device path uses the (noisier)
        five-inner-product corr only to *select* candidates on device; this
        refines the k survivors.
        """
        if sa.hashes.size == 0 or sb.hashes.size == 0:
            return 0.0
        union_h = np.union1d(sa.hashes, sb.hashes)
        kk = min(self.kmv.k, union_h.size)
        tau = union_h[kk - 1]
        common, ia, ib = np.intersect1d(sa.hashes, sb.hashes,
                                        return_indices=True)
        keep = common <= tau
        va, vb = sa.values[ia[keep]], sb.values[ib[keep]]
        if va.size < min_pairs or va.std() == 0 or vb.std() == 0:
            return 0.0
        return float(np.clip(np.corrcoef(va, vb)[0, 1], -1.0, 1.0))

    def storage_doubles(self) -> float:
        """Serving-sketch storage (three fields per table, paper accounting)."""
        if self.store is not None:
            return self.store.storage_doubles()
        # host-only index: same accounting, counted from the oracle sketches
        return (len(self.tables) * len(FIELDS)
                * self.family.storage_doubles_per_row())
