"""Dataset-search service: the paper's motivating application (Section 1.3).

Tables are (key column, value column) pairs.  Per table we pre-compute WMH
sketches of the four vector representations from Figure 3:

    x^{1[K]}   binary key-indicator        -> join sizes (inner products)
    x^{V}      values placed at key index  -> post-join SUM / MEAN / corr
    x^{V^2}    squared values              -> post-join variance

A query table is sketched once and compared against the whole corpus with
the *batched* estimator (the Pallas estimate kernel on device); every §1.3
statistic falls out of inner-product estimates:

    |K_A join K_B|      = <1[K_A], 1[K_B]>
    SUM(V_A after join) = <x^{V_A}, 1[K_B]>
    MEAN(V_A)           = SUM / join_size
    corr(V_A, V_B)      via the five inner products (Santos et al. 2021).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import KMV, SparseVec, WeightedMinHash, stack_wmh
from repro.core.kmv import KMVSketch
from repro.core.wmh import StackedWMH, WMHSketch


@dataclasses.dataclass
class TableSketch:
    name: str
    key_indicator: WMHSketch     # x^{1[K]}
    values: WMHSketch            # x^{V}
    values_sq: WMHSketch         # x^{V^2}
    sample: KMVSketch            # KMV keyed sample of (key -> value): the
                                 # correlation sketch of Santos et al. 2021
    n_rows: int


@dataclasses.dataclass
class SearchResult:
    name: str
    join_size: float
    joinability: float           # join size / query rows
    sum_b: float
    mean_b: float
    corr: float


class DatasetSearchIndex:
    """Sketch once, query many times -- the data-lake discovery pattern."""

    def __init__(self, m: int = 256, seed: int = 0, key_space: int = 2 ** 31):
        self.m = m
        self.seed = seed
        self.key_space = key_space
        self.sketcher = WeightedMinHash(m=m, seed=seed)
        self.kmv = KMV(k=m, seed=seed)
        self.tables: List[TableSketch] = []

    # -- ingestion ----------------------------------------------------------
    def vectorize(self, keys: np.ndarray, values: np.ndarray
                  ) -> Tuple[SparseVec, SparseVec, SparseVec]:
        keys = np.asarray(keys, dtype=np.int64)
        values = np.asarray(values, dtype=np.float64)
        ind = SparseVec.from_pairs(keys, np.ones_like(values), self.key_space)
        # zero values would vanish from the sparse vector; nudge them so the
        # key stays represented (the paper's vectors assume non-zero values)
        safe = np.where(values == 0.0, 1e-9, values)
        val = SparseVec.from_pairs(keys, safe, self.key_space)
        sq = SparseVec.from_pairs(keys, safe ** 2, self.key_space)
        return ind, val, sq

    def add_table(self, name: str, keys: np.ndarray, values: np.ndarray):
        ind, val, sq = self.vectorize(keys, values)
        self.tables.append(TableSketch(
            name=name,
            key_indicator=self.sketcher.sketch(ind),
            values=self.sketcher.sketch(val),
            values_sq=self.sketcher.sketch(sq),
            sample=self.kmv.sketch(val),
            n_rows=len(keys)))

    # -- queries ------------------------------------------------------------
    def _stack(self, field: str) -> StackedWMH:
        return stack_wmh([getattr(t, field) for t in self.tables])

    def query(self, keys: np.ndarray, values: np.ndarray,
              top_k: int = 10, min_join: float = 1.0) -> List[SearchResult]:
        """Rank corpus tables by |corr| among sufficiently-joinable tables."""
        if not self.tables:
            return []
        ind, val, sq = self.vectorize(keys, values)
        q_ind = self.sketcher.sketch(ind)
        q_val = self.sketcher.sketch(val)
        q_sq = self.sketcher.sketch(sq)
        q_sample = self.kmv.sketch(val)
        P = len(self.tables)

        def est(q: WMHSketch, field: str) -> np.ndarray:
            A = stack_wmh([q] * P)
            return self.sketcher.estimate_batch(A, self._stack(field))

        join = est(q_ind, "key_indicator")                  # <1A, 1B>
        sum_b = est(q_ind, "values")                        # <1A, VB>
        # (q_val x values => <VA,VB>; q_sq / values_sq => post-join variances;
        # exposed for downstream statistics, not needed for ranking)

        results = []
        for i, t in enumerate(self.tables):
            js = max(join[i], 0.0)
            if js < min_join:
                continue
            mean_b = sum_b[i] / js if js > 0 else 0.0
            corr = self._sample_corr(q_sample, t.sample)
            results.append(SearchResult(
                name=t.name, join_size=float(js),
                joinability=float(js / max(len(keys), 1)),
                sum_b=float(sum_b[i]), mean_b=float(mean_b), corr=corr))
        results.sort(key=lambda r: abs(r.corr), reverse=True)
        return results[:top_k]

    def _sample_corr(self, sa: KMVSketch, sb: KMVSketch,
                     min_pairs: int = 8) -> float:
        """Sample Pearson correlation over the join, from matched KMV samples
        (Santos et al. 2021 correlation sketches).

        Matched hashes within the k smallest of the union form a uniform
        sample of joined rows; the *sample* correlation sidesteps the
        catastrophic moment cancellation that estimated E[x^2]-E[x]^2
        suffers under sketch noise.
        """
        if sa.hashes.size == 0 or sb.hashes.size == 0:
            return 0.0
        union_h = np.union1d(sa.hashes, sb.hashes)
        kk = min(self.kmv.k, union_h.size)
        tau = union_h[kk - 1]
        common, ia, ib = np.intersect1d(sa.hashes, sb.hashes,
                                        return_indices=True)
        keep = common <= tau
        va, vb = sa.values[ia[keep]], sb.values[ib[keep]]
        if va.size < min_pairs or va.std() == 0 or vb.std() == 0:
            return 0.0
        return float(np.clip(np.corrcoef(va, vb)[0, 1], -1.0, 1.0))

    def storage_doubles(self) -> float:
        return sum(t.key_indicator.storage_doubles()
                   + t.values.storage_doubles()
                   + t.values_sq.storage_doubles() for t in self.tables)
