"""The ``SketchFamily`` contract: device-resident serving for any sketch.

The paper's headline result is a head-to-head -- weighted MinWise hashing
vs the linear sketches (CountSketch, JL) -- and this module is what lets
the *serving stack* run that comparison live instead of only in host-numpy
benchmarks.  A family bundles everything the corpus store and the query
engine need to know about one sketch method:

  * **Buffer layout** (``components``): the per-row device buffers a
    :class:`repro.data.store.CorpusStore` preallocates.  ICWS rows are
    ``(fp [m] int32, val [m] f32, norm [] f32)``; linear rows are a single
    dense ``[R, W]`` f32 table (JL is the R = 1, W = m case).
  * **Inert-spare-row rule** (``ComponentSpec.fill``): the fill value that
    makes unused capacity rows estimate to exactly zero, so query launches
    run on full-capacity buffers and stay bitwise identical to exact-size
    arrays.  ICWS fingerprints fill with the corpus pad sentinel and norms
    with zero; linear tables fill with zero (a zero table dots to zero) --
    no sentinel machinery at all.
  * **Sketch launch** (``sketch_rows``): one padded-batch Pallas launch
    turning B sparse vectors into B buffer rows.
  * **Fused estimate launch** (``estimate_fields`` and its mesh-sharded
    twin): all (query-field, corpus-field) pairs of a Q-query batch in ONE
    kernel launch -- the ICWS collision kernel, or MXU matmuls with a
    median-of-reps epilogue for the linear families.
  * **Storage accounting** (``storage_doubles_per_row``) and storage-matched
    construction (:func:`make_family`), using the same per-method sizing as
    :mod:`repro.core.registry` so cross-family comparisons are
    storage-fair by construction.
  * **Host oracle** (``host_oracle``): the numpy sketcher sharing the
    kernel RNG contract (:class:`repro.core.ICWS`,
    :class:`repro.core.linear.CountSketchU32`,
    :class:`repro.core.linear.JLU32`) that device estimates are
    cross-checked against.

``DatasetSearchIndex(family="cs")`` / ``SketchSearchService(family="jl")``
thread one of these through the whole stack; ``family="icws"`` reproduces
the original ICWS path bit for bit.  The sampling families (``"ts"`` /
``"ps"``, arXiv:2309.16157) add a third estimator geometry: fixed-slot
coordinate samples matched by *key equality* rather than slot position,
served by the key-match contraction kernel in
:mod:`repro.kernels.sample_estimate`.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import registry, u32
from repro.core.dmh import DMH
from repro.core.icws import ICWS
from repro.core.linear import REPS, CountSketchU32, JLU32
from repro.core.sampling import (SAMPLE_HASH_STREAM, PrioritySamplingU32,
                                 ThresholdSamplingU32)
from repro.core.types import SparseVec
from repro.kernels import ops
from repro.kernels.common import (DMH_BETA_STREAM, DMH_BIN_STREAM,
                                  DMH_C1_STREAM, DMH_C2_STREAM,
                                  DMH_DENSIFY_STREAM, DMH_FP_STREAM,
                                  DMH_R1_STREAM, DMH_R2_STREAM,
                                  ICWS_BETA_STREAM, ICWS_C1_STREAM,
                                  ICWS_C2_STREAM, ICWS_FP_STREAM,
                                  ICWS_R1_STREAM, ICWS_R2_STREAM,
                                  densify_probes, hash_u32, salt_for,
                                  uniform01)
from repro.kernels.estimate import CORPUS_PAD_FP
from repro.kernels.packed import pack_halfwords_f32, unpack_halfwords_f32
from repro.kernels.ref import BIG

from .ingest import (dmh_sketch_batch, pad_linear_batch, pad_sample_batch,
                     sketch_batch)


def _pad_last(x: jnp.ndarray, n: int, value=0) -> jnp.ndarray:
    """Pad the last dim by ``n`` elements of ``value`` (0 -> unchanged)."""
    if not n:
        return x
    widths = [(0, 0)] * (x.ndim - 1) + [(0, n)]
    return jnp.pad(x, widths, constant_values=value)


@dataclasses.dataclass(frozen=True)
class ComponentSpec:
    """One per-row buffer of a family's corpus layout.

    A store allocates each component as ``[fields, capacity, *trailing]``
    with every element set to ``fill`` -- the value that keeps unallocated
    rows inert under the family's estimate launch.
    """

    name: str
    trailing: Tuple[int, ...]
    dtype: jnp.dtype
    fill: float


@dataclasses.dataclass(frozen=True)
class ICWSFamily:
    """ICWS (weighted MinWise) serving family -- the paper's method.

    Rows are (fingerprints, sampled values, norm); estimation is the fused
    collision kernel.  This family IS the pre-refactor serving path: it
    calls the same jitted launches with the same arguments, so rankings
    are bitwise unchanged.
    """

    m: int
    seed: int = 0
    name: str = dataclasses.field(default="icws", init=False)

    @property
    def components(self) -> Tuple[ComponentSpec, ...]:
        # argkeys (the per-sample winning key) rides LAST so every consumer
        # of the first three components -- estimate launches, host
        # estimators, field maps -- is layout-compatible with pre-argkeys
        # code.  It is only read by the merge path; spare rows fill with 0,
        # which the estimate kernels never look at.
        return (ComponentSpec("fingerprints", (self.m,), jnp.int32,
                              CORPUS_PAD_FP),
                ComponentSpec("values", (self.m,), jnp.float32, 0.0),
                ComponentSpec("norms", (), jnp.float32, 0.0),
                ComponentSpec("argkeys", (self.m,), jnp.int32, 0.0))

    def storage_doubles_per_row(self) -> float:
        """Paper accounting: 1.5 doubles per sample + 1 norm.  The argkeys
        merge sidecar is deliberately NOT charged: the paper's storage
        x-axis prices the *estimation* state, and dropping argkeys (serving
        a frozen, unmergeable corpus) loses nothing at query time."""
        return 1.5 * self.m + 1.0

    def sketch_rows(self, vecs: Sequence[SparseVec], *, bucket: int = 256):
        """One ICWS kernel launch: B sparse vectors -> (fp, val, norm,
        argkey) rows."""
        return sketch_batch(vecs, m=self.m, seed=self.seed, bucket=bucket)

    def estimate_fields(self, q, c, *, qmap, cmap):
        fq, vq, nq = q[0], q[1], q[2]
        fpc, vc, nc = c[0], c[1], c[2]
        return ops.icws_estimate_fields(fq, vq, nq, fpc, vc, nc,
                                        qmap=qmap, cmap=cmap)

    def estimate_fields_sharded(self, q, c, *, qmap, cmap, mesh, axis):
        fq, vq, nq = q[0], q[1], q[2]
        fpc, vc, nc = c[0], c[1], c[2]
        return ops.icws_estimate_fields_sharded(fq, vq, nq, fpc, vc, nc,
                                                qmap=qmap, cmap=cmap,
                                                mesh=mesh, axis=axis)

    @property
    def packed_components(self) -> Tuple[ComponentSpec, ...]:
        """Packed wire format: fingerprints stay full i32 lanes (31-bit
        exact-match state), values pack two bf16 halfwords per i32 word
        (odd m gains one inert pad slot), and the argkeys merge sidecar is
        dropped -- packed corpora are frozen serving state, 6m + 4 bytes
        per row vs 12m + 4 unpacked (50%)."""
        me = self.m + (self.m % 2)
        return (ComponentSpec("fingerprints", (me,), jnp.int32,
                              CORPUS_PAD_FP),
                ComponentSpec("packed_values", (me // 2,), jnp.int32, 0.0),
                ComponentSpec("norms", (), jnp.float32, 0.0))

    def pack_rows(self, rows):
        """(fp, val, norm[, argkey]) -> packed components, any leading dims.
        Values are bf16-truncated (exact thereafter); argkeys are dropped."""
        fp = _pad_last(jnp.asarray(rows[0]).astype(jnp.int32), self.m % 2,
                       CORPUS_PAD_FP)
        val = _pad_last(jnp.asarray(rows[1]).astype(jnp.float32), self.m % 2)
        return (fp, pack_halfwords_f32(val),
                jnp.asarray(rows[2]).astype(jnp.float32))

    def unpack_rows(self, rows):
        """Packed components -> unpacked-layout rows, bitwise the fixpoint
        of ``pack_rows`` (pack(unpack(p)) == p).  The argkeys sidecar comes
        back zeroed: packed rows are frozen and cannot re-enter the merge
        path."""
        fp, w, norm = (jnp.asarray(x) for x in rows)
        val = unpack_halfwords_f32(w)[..., :self.m]
        return (fp[..., :self.m].astype(jnp.int32), val,
                norm.astype(jnp.float32),
                jnp.zeros(fp.shape[:-1] + (self.m,), jnp.int32))

    def estimate_fields_packed(self, q, c, *, qmap, cmap):
        fq, vq, nq = q[0], q[1], q[2]
        fpc, wc, nc = c[0], c[1], c[2]
        return ops.icws_estimate_fields_packed(fq, vq, nq, fpc, wc, nc,
                                               qmap=qmap, cmap=cmap)

    def estimate_fields_packed_sharded(self, q, c, *, qmap, cmap, mesh,
                                       axis):
        fq, vq, nq = q[0], q[1], q[2]
        fpc, wc, nc = c[0], c[1], c[2]
        return ops.icws_estimate_fields_packed_sharded(
            fq, vq, nq, fpc, wc, nc, qmap=qmap, cmap=cmap, mesh=mesh,
            axis=axis)

    def merge_rows(self, a, b):
        """Coordinated per-slot min-merge of row-aligned ICWS components.

        ``a`` and ``b`` are same-shape component tuples ``(fp [..., m], val
        [..., m], norm [...], argkey [..., m])`` sketching *disjoint
        partitions* of the same underlying vectors.  Device twin of
        :meth:`repro.core.icws.ICWS.merge`: both shard winners are
        re-scored under the merged norm (variates redrawn from (sample,
        key) -- the shared u32 streams), the smaller ICWS hash wins, and
        its fingerprint is re-derived at the re-leveled weight.  Ties break
        toward the smaller key, so the merge commutes bitwise.
        """
        fpa, va, na, ka = (jnp.asarray(x) for x in a)
        fpb, vb, nb, kb = (jnp.asarray(x) for x in b)
        t = jnp.arange(self.m, dtype=jnp.int32)
        # exact identity when one side is empty: sqrt(n^2) may round, so
        # pass the live norm through untouched
        norm_q = jnp.sqrt(na * na + nb * nb)
        norm_c = jnp.where(na == 0, nb, jnp.where(nb == 0, na, norm_q))
        safe_c = jnp.maximum(norm_c, jnp.float32(1e-37))[..., None]

        def rescore(fp, val, norm, key):
            z = val * (norm[..., None] / safe_c)
            w = z * z
            kk = key.astype(jnp.uint32)

            def u(stream):
                return uniform01(kk, salt_for(self.seed, stream, t))

            r = -jnp.log(u(ICWS_R1_STREAM) * u(ICWS_R2_STREAM))
            c = -jnp.log(u(ICWS_C1_STREAM) * u(ICWS_C2_STREAM))
            beta = u(ICWS_BETA_STREAM)
            logw = jnp.log(jnp.maximum(w, jnp.float32(1e-37)))
            lvl = jnp.floor(logw / r + beta)
            y = jnp.exp(r * (lvl - beta))
            av = c / (y * jnp.exp(r))
            av = jnp.where((fp < 0) | (w <= 0), jnp.float32(BIG), av)
            return z, av, lvl.astype(jnp.int32)

        za, aa, la = rescore(fpa, va, na, ka)
        zb, ab, lb = rescore(fpb, vb, nb, kb)
        pick_b = (ab < aa) | ((ab == aa)
                             & (kb.astype(jnp.uint32) < ka.astype(jnp.uint32)))
        key_c = jnp.where(pick_b, kb, ka)
        lvl_c = jnp.where(pick_b, lb, la)
        val_c = jnp.where(pick_b, zb, za)
        fpbits = hash_u32(
            key_c.astype(jnp.uint32)
            ^ (lvl_c.astype(jnp.uint32) * jnp.uint32(0x9E3779B9)),
            salt_for(self.seed, ICWS_FP_STREAM, t))
        fp_c = (fpbits & jnp.uint32(0x7FFFFFFF)).astype(jnp.int32)
        dead = jnp.minimum(aa, ab) >= BIG
        return (jnp.where(dead, -1, fp_c),
                jnp.where(dead, 0.0, val_c).astype(jnp.float32),
                norm_c.astype(jnp.float32),
                jnp.where(dead, 0, key_c).astype(jnp.int32))

    def host_oracle(self) -> ICWS:
        return ICWS(m=self.m, seed=self.seed)


@dataclasses.dataclass(frozen=True)
class DMHFamily(ICWSFamily):
    """DMH (densified one-permutation weighted MinHash) serving family.

    Same wire layout, storage accounting, packed format, and fused
    estimate launches as :class:`ICWSFamily` -- rows are ``(fingerprints,
    values, norm, argkeys)`` consumed by the same collision kernels -- but
    the *build* is O(c * nnz + m) per vector instead of O(nnz * m),
    with ``c = dmh_replication(m) <= 4``: one binning pass over the
    non-zeros (pseudo-key-replicated for m > 64 to debias the restricted
    collision law -- see :func:`repro.core.dmh.dmh_replication`) with an
    in-kernel densification epilogue
    (:mod:`repro.kernels.dmh_sketch`).  Only the three members
    that touch sketch construction differ: the sketch launch, the
    union-merge (which must recover bin origins and re-densify), and the
    host oracle.
    """

    name: str = dataclasses.field(default="dmh", init=False)

    def sketch_rows(self, vecs: Sequence[SparseVec], *, bucket: int = 256):
        """One DMH kernel launch: B sparse vectors -> (fp, val, norm,
        argkey) rows."""
        return dmh_sketch_batch(vecs, m=self.m, seed=self.seed,
                                bucket=bucket)

    def merge_rows(self, a, b):
        """Coordinated union-merge of row-aligned DMH components.

        Device twin of :meth:`repro.core.dmh.DMH.merge`.  DMH rows store
        no occupancy bitmap, but origins are recoverable from the layout:
        bin t holds its own minimum (not a densified copy) iff
        ``bin(argkey[t]) == t``.  Origin winners re-score under the merged
        norm (DMH streams at t = bin), strict-< picks the winner with ties
        toward the smaller key (commutative), and bins with no origin on
        either side re-densify from the merged occupancy through the same
        probe sequence the sketch kernel uses.
        """
        fpa, va, na, ka = (jnp.asarray(x) for x in a)
        fpb, vb, nb, kb = (jnp.asarray(x) for x in b)
        t = jnp.arange(self.m, dtype=jnp.int32)
        norm_q = jnp.sqrt(na * na + nb * nb)
        norm_c = jnp.where(na == 0, nb, jnp.where(nb == 0, na, norm_q))
        safe_c = jnp.maximum(norm_c, jnp.float32(1e-37))[..., None]
        bin_salt = salt_for(self.seed, DMH_BIN_STREAM, jnp.uint32(0))

        def rescore(fp, val, norm, key):
            kk = key.astype(jnp.uint32)
            bins = (hash_u32(kk, bin_salt)
                    % jnp.uint32(self.m)).astype(jnp.int32)
            origin = (fp >= 0) & (bins == t)
            z = val * (norm[..., None] / safe_c)
            w = z * z

            def u(stream):
                return uniform01(kk, salt_for(self.seed, stream, t))

            r = -jnp.log(u(DMH_R1_STREAM) * u(DMH_R2_STREAM))
            c = -jnp.log(u(DMH_C1_STREAM) * u(DMH_C2_STREAM))
            beta = u(DMH_BETA_STREAM)
            logw = jnp.log(jnp.maximum(w, jnp.float32(1e-37)))
            lvl = jnp.floor(logw / r + beta)
            y = jnp.exp(r * (lvl - beta))
            av = c / (y * jnp.exp(r))
            av = jnp.where(origin & (w > 0), av, jnp.float32(BIG))
            return z, av, lvl.astype(jnp.int32)

        za, aa, la = rescore(fpa, va, na, ka)
        zb, ab, lb = rescore(fpb, vb, nb, kb)
        pick_b = (ab < aa) | ((ab == aa)
                             & (kb.astype(jnp.uint32) < ka.astype(jnp.uint32)))
        key_c = jnp.where(pick_b, kb, ka)
        lvl_c = jnp.where(pick_b, lb, la)
        val_c = jnp.where(pick_b, zb, za)
        fpbits = hash_u32(
            key_c.astype(jnp.uint32)
            ^ (lvl_c.astype(jnp.uint32) * jnp.uint32(0x9E3779B9)),
            salt_for(self.seed, DMH_FP_STREAM, t))
        fp_c = (fpbits & jnp.uint32(0x7FFFFFFF)).astype(jnp.int32)
        occ = jnp.minimum(aa, ab) < BIG
        fp_c = jnp.where(occ, fp_c, -1)
        val_c = jnp.where(occ, val_c, 0.0).astype(jnp.float32)
        key_c = jnp.where(occ, key_c, 0).astype(jnp.int32)
        # re-densify: same reseeded probes as the sketch kernel, applied
        # to the merged origin occupancy
        J = densify_probes(self.m)
        js = jnp.arange(J, dtype=jnp.int32)
        psalt = salt_for(self.seed, DMH_DENSIFY_STREAM, js)
        src = (hash_u32(t[:, None].astype(jnp.uint32), psalt[None, :])
               % jnp.uint32(self.m)).astype(jnp.int32)      # [m, J]
        occ_p = jnp.take(occ, src, axis=-1)                 # [..., m, J]
        has = jnp.any(occ_p, axis=-1)
        firstj = jnp.argmax(occ_p, axis=-1).astype(jnp.int32)
        src_w = (hash_u32(t.astype(jnp.uint32),
                          salt_for(self.seed, DMH_DENSIFY_STREAM, firstj))
                 % jnp.uint32(self.m)).astype(jnp.int32)
        fallback = jnp.argmax(occ, axis=-1).astype(jnp.int32)[..., None]
        src_sel = jnp.where(has, src_w, fallback)
        need = (~occ) & jnp.any(occ, axis=-1)[..., None]

        def borrow(x):
            return jnp.where(need,
                             jnp.take_along_axis(x, src_sel, axis=-1), x)

        return (borrow(fp_c), borrow(val_c), norm_c.astype(jnp.float32),
                borrow(key_c))

    def host_oracle(self) -> DMH:
        return DMH(m=self.m, seed=self.seed)


class _LinearFamily:
    """Shared serving plumbing of the linear families (S(a) = Pi a).

    Rows are one dense ``[R, W]`` f32 table; estimation is per-rep MXU
    dots + a median-of-reps epilogue (R = 1 for JL, where the median is
    the dot itself).  Everything is zero-fill inert: empty sketches, spare
    capacity, and padding all estimate to exactly zero.
    """

    reps: int
    width: int
    seed: int

    @property
    def components(self) -> Tuple[ComponentSpec, ...]:
        return (ComponentSpec("tables", (self.reps, self.width),
                              jnp.float32, 0.0),)

    def storage_doubles_per_row(self) -> float:
        """Paper accounting: every table cell is one double equivalent."""
        return float(self.reps * self.width)

    def _sketch_tables(self, keys, vals):
        raise NotImplementedError

    def sketch_rows(self, vecs: Sequence[SparseVec], *, bucket: int = 256):
        """One linear-sketch kernel launch: B sparse vectors -> [B, R, W]."""
        keys, vals = pad_linear_batch(vecs, bucket=bucket)
        return (self._sketch_tables(jnp.asarray(keys), jnp.asarray(vals)),)

    def estimate_fields(self, q, c, *, qmap, cmap):
        return ops.linear_estimate_fields(q[0], c[0], qmap=qmap, cmap=cmap)

    def estimate_fields_sharded(self, q, c, *, qmap, cmap, mesh, axis):
        return ops.linear_estimate_fields_sharded(q[0], c[0], qmap=qmap,
                                                  cmap=cmap, mesh=mesh,
                                                  axis=axis)

    @property
    def packed_components(self) -> Tuple[ComponentSpec, ...]:
        """Packed wire format: every table cell bf16-truncated, two cells
        per i32 word (odd widths gain one zero column) -- half the
        unpacked ``[R, W]`` f32 bytes.  Zero-fill stays inert: the zero
        word decodes to a zero table."""
        we = self.width + (self.width % 2)
        return (ComponentSpec("packed_tables", (self.reps, we // 2),
                              jnp.int32, 0.0),)

    def pack_rows(self, rows):
        t = _pad_last(jnp.asarray(rows[0]).astype(jnp.float32),
                      self.width % 2)
        return (pack_halfwords_f32(t),)

    def unpack_rows(self, rows):
        return (unpack_halfwords_f32(jnp.asarray(rows[0]))[..., :self.width],)

    def estimate_fields_packed(self, q, c, *, qmap, cmap):
        return ops.linear_estimate_fields_packed(q[0], c[0], qmap=qmap,
                                                 cmap=cmap)

    def estimate_fields_packed_sharded(self, q, c, *, qmap, cmap, mesh,
                                       axis):
        return ops.linear_estimate_fields_packed_sharded(
            q[0], c[0], qmap=qmap, cmap=cmap, mesh=mesh, axis=axis)

    def merge_rows(self, a, b):
        """Exact merge by linearity: ``S(x + y) = S(x) + S(y)`` -- the
        row-aligned tables simply add.  Commutative and associative up to
        f32 addition order (bitwise exact on integer-valued data)."""
        return (jnp.asarray(a[0]) + jnp.asarray(b[0]),)


@dataclasses.dataclass(frozen=True)
class CSFamily(_LinearFamily):
    """CountSketch serving family (median of ``reps`` repetitions)."""

    width: int
    reps: int = REPS
    seed: int = 0
    name: str = dataclasses.field(default="cs", init=False)

    def _sketch_tables(self, keys, vals):
        return ops.countsketch_sparse(keys, vals, width=self.width,
                                      reps=self.reps, seed=self.seed)

    def host_oracle(self) -> CountSketchU32:
        return CountSketchU32(width=self.width, seed=self.seed,
                              reps=self.reps)


@dataclasses.dataclass(frozen=True)
class JLFamily(_LinearFamily):
    """JL / AMS projection serving family (a single ``[1, m]`` table row)."""

    m: int
    seed: int = 0
    name: str = dataclasses.field(default="jl", init=False)

    @property
    def reps(self) -> int:
        return 1

    @property
    def width(self) -> int:
        return self.m

    def _sketch_tables(self, keys, vals):
        return ops.jl_sketch(keys, vals, m=self.m, seed=self.seed)[:, None, :]

    def host_oracle(self) -> JLU32:
        return JLU32(m=self.m, seed=self.seed)


class _SamplingFamily:
    """Shared serving plumbing of the sampling families (TS/PS).

    Rows are fixed-slot coordinate samples ``(key [slots] i32, val [slots]
    f32, tau [] f32)`` -- see :mod:`repro.core.sampling` for the contract.
    Estimation is the unaligned key-match contraction
    (:mod:`repro.kernels.sample_estimate`): slots are matched by key
    equality, not position, and matches are reweighted by inverse inclusion
    probability.  Inert spare rows are corpus-pad-sentinel keys with zero
    values and zero tau (probability 0 on every slot), so they estimate to
    exactly zero with the same guard that excludes slot padding.

    Sketch *building* is host-side (:func:`repro.data.ingest.
    pad_sample_batch`): weighted sampling is per-vector select/top-k work,
    not a kernel-shaped reduction -- the device owns storage + estimation.
    """

    slots: int
    seed: int

    @property
    def components(self) -> Tuple[ComponentSpec, ...]:
        return (ComponentSpec("keys", (self.slots,), jnp.int32,
                              CORPUS_PAD_FP),
                ComponentSpec("values", (self.slots,), jnp.float32, 0.0),
                ComponentSpec("taus", (), jnp.float32, 0.0))

    def storage_doubles_per_row(self) -> float:
        """A key (i32) + value (f32) pair per slot is one 64-bit double
        equivalent, plus one double for the probability scale tau."""
        return float(self.slots) + 1.0

    def sketch_rows(self, vecs: Sequence[SparseVec], *, bucket: int = 256):
        """Host-build B sample rows (``bucket`` is a padded-batch knob of
        the kernel-ingest families; sampling rows are fixed-slot already)."""
        del bucket
        k, v, t = pad_sample_batch(vecs, slots=self.slots, method=self.name,
                                   seed=self.seed)
        return jnp.asarray(k), jnp.asarray(v), jnp.asarray(t)

    def estimate_fields(self, q, c, *, qmap, cmap):
        kq, vq, tq = q
        kc, vc, tc = c
        return ops.sample_estimate_fields(kq, vq, tq, kc, vc, tc,
                                          qmap=qmap, cmap=cmap)

    def estimate_fields_sharded(self, q, c, *, qmap, cmap, mesh, axis):
        kq, vq, tq = q
        kc, vc, tc = c
        return ops.sample_estimate_fields_sharded(kq, vq, tq, kc, vc, tc,
                                                  qmap=qmap, cmap=cmap,
                                                  mesh=mesh, axis=axis)

    @property
    def packed_components(self) -> Tuple[ComponentSpec, ...]:
        """Packed wire format: sample keys stay full i32 lanes (31-bit
        exact-match state -- the information floor of this layout), values
        pack two bf16 halfwords per i32 word (odd slot counts gain one
        inert pad slot), taus stay f32: 6S + 4 bytes per row vs 8S + 4
        unpacked (75%)."""
        se = self.slots + (self.slots % 2)
        return (ComponentSpec("keys", (se,), jnp.int32, CORPUS_PAD_FP),
                ComponentSpec("packed_values", (se // 2,), jnp.int32, 0.0),
                ComponentSpec("taus", (), jnp.float32, 0.0))

    def pack_rows(self, rows):
        k = _pad_last(jnp.asarray(rows[0]).astype(jnp.int32),
                      self.slots % 2, CORPUS_PAD_FP)
        v = _pad_last(jnp.asarray(rows[1]).astype(jnp.float32),
                      self.slots % 2)
        return (k, pack_halfwords_f32(v),
                jnp.asarray(rows[2]).astype(jnp.float32))

    def unpack_rows(self, rows):
        k, w, t = (jnp.asarray(x) for x in rows)
        return (k[..., :self.slots].astype(jnp.int32),
                unpack_halfwords_f32(w)[..., :self.slots],
                t.astype(jnp.float32))

    def estimate_fields_packed(self, q, c, *, qmap, cmap):
        kq, vq, tq = q
        kc, wc, tc = c
        return ops.sample_estimate_fields_packed(kq, vq, tq, kc, wc, tc,
                                                 qmap=qmap, cmap=cmap)

    def estimate_fields_packed_sharded(self, q, c, *, qmap, cmap, mesh,
                                       axis):
        kq, vq, tq = q
        kc, wc, tc = c
        return ops.sample_estimate_fields_packed_sharded(
            kq, vq, tq, kc, wc, tc, qmap=qmap, cmap=cmap, mesh=mesh,
            axis=axis)

    def _merge_keep(self, live, h, vals, ta, tb):
        raise NotImplementedError

    def merge_rows(self, a, b):
        """Union re-subsampling of row-aligned sample components.

        ``a`` and ``b`` are ``(key [..., S], val [..., S], tau [...])``
        component tuples sampling *disjoint partitions* of the same
        vectors.  The kept slot sets are pooled, the merged scheme
        threshold is recomputed (TS: ``tau_c = tau_a + tau_b``; PS:
        ``T_c = min(T_a, T_b, T_cand)``), the coordinated hash re-decides
        every pooled slot, and survivors repack in the canonical
        ascending-key layout.  Runs host-side in float64, mirroring the
        builders in :mod:`repro.core.sampling` decision for decision --
        sampling is select/sort-shaped work, and bit-agreement with the
        host oracles matters more than device residency (the builders
        themselves are host-side for the same reason).
        """
        ka, va, ta = (np.asarray(x) for x in a)
        kb, vb, tb = (np.asarray(x) for x in b)
        S = self.slots
        keys = np.concatenate([ka, kb], axis=-1).astype(np.int64)
        vals = np.concatenate([va, vb], axis=-1).astype(np.float64)
        live = keys >= 0                       # slot pads are negative
        vals = np.where(live, vals, 0.0)
        lane = np.arange(2 * S, dtype=np.int64)
        big = np.int64(1) << 33                # above any 31-bit key
        srt = np.sort(np.where(live, keys, big + lane), axis=-1)
        if np.any((srt[..., 1:] == srt[..., :-1]) & (srt[..., 1:] < big)):
            raise ValueError("union-merge requires disjoint supports "
                             "(shared keys found in both rows)")
        salt = u32.salt_for(self.seed, SAMPLE_HASH_STREAM,
                            np.zeros(1, np.uint32))
        h = u32.uniform01(keys.astype(np.uint64).astype(np.uint32),
                          salt).astype(np.float64)
        keep, tau_c = self._merge_keep(live, h, vals,
                                       ta.astype(np.float64),
                                       tb.astype(np.float64))
        order = np.argsort(np.where(keep, keys, big + lane), axis=-1,
                           kind="stable")
        k_s = np.take_along_axis(keys, order, -1)[..., :S]
        v_s = np.take_along_axis(vals, order, -1)[..., :S]
        kept = np.take_along_axis(keep, order, -1)[..., :S]
        return (jnp.asarray(np.where(kept, k_s, -1).astype(np.int32)),
                jnp.asarray(np.where(kept, v_s, 0.0).astype(np.float32)),
                jnp.asarray(tau_c.astype(np.float32)))


@dataclasses.dataclass(frozen=True)
class TSFamily(_SamplingFamily):
    """Threshold Sampling serving family (expected-size-bounded sample)."""

    slots: int
    seed: int = 0
    name: str = dataclasses.field(default="ts", init=False)

    def _merge_keep(self, live, h, vals, ta, tb):
        # tau = ||v||^2 * slots / target: disjoint-support norms add, so
        # the merged tau is the sum and p_c = min(1, S v^2 / tau_c) only
        # shrinks -- re-flipping the same coordinated coin on the pooled
        # slots reproduces the build-once sample (see ThresholdSamplingU32
        # .merge for the overflow caveat).
        S = self.slots
        tau_c = ta + tb
        denom = np.where(tau_c > 0, tau_c, 1.0)[..., None]
        p = np.where(tau_c[..., None] > 0,
                     np.minimum(1.0, S * vals * vals / denom), 1.0)
        p = np.where(live, p, 0.0)
        keep = h < p
        over = keep.sum(axis=-1) > S
        if np.any(over):
            rank = np.where(keep, h / np.where(p > 0, p, 1.0), np.inf)
            pos = np.argsort(np.argsort(rank, axis=-1, kind="stable"),
                             axis=-1)
            keep = keep & (~over[..., None] | (pos < S))
        return keep, tau_c

    def host_oracle(self) -> ThresholdSamplingU32:
        return ThresholdSamplingU32(slots=self.slots, seed=self.seed)


@dataclasses.dataclass(frozen=True)
class PSFamily(_SamplingFamily):
    """Priority Sampling serving family (exactly-full fixed-size sample)."""

    slots: int
    seed: int = 0
    name: str = dataclasses.field(default="ps", init=False)

    def _merge_keep(self, live, h, vals, ta, tb):
        # T = slots / tau is each side's threshold rank (infinite when the
        # support fit); the union threshold is min(T_a, T_b, T_cand) with
        # T_cand the (S+1)-th smallest pooled rank.  Exactly build-once:
        # see PrioritySamplingU32.merge for the argument.
        S = self.slots
        t_a = np.where(ta > 0, S / np.where(ta > 0, ta, 1.0), np.inf)
        t_b = np.where(tb > 0, S / np.where(tb > 0, tb, 1.0), np.inf)
        sq = np.where(live, vals * vals, 1.0)
        rank = np.where(live, h / sq, np.inf)
        t_cand = np.sort(rank, axis=-1)[..., S]
        t_c = np.minimum(np.minimum(t_a, t_b), t_cand)
        keep = rank < t_c[..., None]
        tau_c = np.where(np.isinf(t_c), 0.0,
                         S / np.where(np.isinf(t_c), 1.0, t_c))
        return keep, tau_c

    def host_oracle(self) -> PrioritySamplingU32:
        return PrioritySamplingU32(slots=self.slots, seed=self.seed)


FAMILY_NAMES = ("icws", "cs", "jl", "ts", "ps", "dmh")


def make_family(name: str, *, storage: float, seed: int = 0):
    """Construct a serving family sized to a total storage budget.

    ``storage`` is the paper's x-axis -- total 64-bit-double equivalents
    per sketch -- and the per-method sizing is delegated to
    :mod:`repro.core.registry` (icws/dmh: ``m = (storage - 1) / 1.5``; cs:
    ``width = storage / reps``; jl: ``m = storage``; ts/ps:
    ``slots = storage - 1``), so families built from one budget are
    storage-matched and comparisons are fair.
    """
    if name == "icws":
        return ICWSFamily(m=registry.make_icws(storage).m, seed=seed)
    if name == "dmh":
        return DMHFamily(m=registry.make_dmh(storage).m, seed=seed)
    if name == "cs":
        host = registry.make_cs(storage)
        return CSFamily(width=host.width, reps=host.reps, seed=seed)
    if name == "jl":
        return JLFamily(m=registry.make_jl(storage).m, seed=seed)
    if name == "ts":
        return TSFamily(slots=registry.make_ts(storage).slots, seed=seed)
    if name == "ps":
        return PSFamily(slots=registry.make_ps(storage).slots, seed=seed)
    raise ValueError(
        f"unknown sketch family {name!r}; choose from {FAMILY_NAMES}")


def wmh_storage(m: int) -> float:
    """The storage budget an m-sample WMH/ICWS sketch occupies -- the
    anchor :class:`repro.data.dataset_search.DatasetSearchIndex` uses to
    size every family from its ``m`` parameter.  Delegates to the family's
    own accounting so the formula lives in exactly one place."""
    return ICWSFamily(m=m).storage_doubles_per_row()
