"""Synthetic data generators matching the paper's experimental protocols.

* :func:`sparse_pair` -- Section 5.1: length-n vectors, fixed nnz, controlled
  overlap ratio, U(-1,1) values with 10% outliers in U(20,30).
* :func:`worldbank_like_pair` -- Section 5.2 proxy: heavy-tailed numeric
  "columns" with controllable overlap and kurtosis (log-normal body + Pareto
  outliers), normalized to unit norm as the paper does.
* :func:`tfidf_corpus` -- Section 5.2 (20 Newsgroups) proxy: Zipf-distributed
  term draws with TF-IDF weighting over a large vocabulary (uni+bigram-sized).
* :func:`token_stream` -- LM training tokens (Zipf unigrams), deterministic
  per (seed, step) for resumable input pipelines.
"""
from __future__ import annotations

from typing import List, Tuple

import numpy as np

from repro.core import SparseVec


def sparse_pair(rng: np.random.Generator, n: int = 10000, nnz: int = 2000,
                overlap: float = 0.1, outlier_frac: float = 0.1
                ) -> Tuple[SparseVec, SparseVec]:
    """The paper's Fig. 4 protocol."""
    n_ov = int(round(overlap * nnz))
    idx = rng.choice(n, size=2 * nnz - n_ov, replace=False)
    ia = idx[:nnz]
    ib = np.concatenate([idx[:n_ov], idx[nnz:]])

    def values(k):
        v = rng.uniform(-1.0, 1.0, size=k)
        out = rng.random(k) < outlier_frac
        v[out] = rng.uniform(20.0, 30.0, size=int(out.sum()))
        return v

    a = np.zeros(n)
    b = np.zeros(n)
    a[ia] = values(nnz)
    b[ib] = values(len(ib))
    return SparseVec.from_dense(a), SparseVec.from_dense(b)


def worldbank_like_pair(rng: np.random.Generator, n: int = 20000,
                        nnz: int = 1500, overlap: float = 0.2,
                        outlier_rate: float = 0.02, outlier_scale: float = 50.0
                        ) -> Tuple[SparseVec, SparseVec]:
    """Heavy-tailed column pairs with controllable overlap/kurtosis.

    Outlier magnitudes are *correlated across the two columns on shared
    keys*: a scale-dominating row (a country total, a capital city) is large
    in BOTH tables.  This is the regime of the paper's real-data study --
    the joined inner product concentrates on a few co-located heavy rows,
    which unweighted MinHash samples uniformly (and so usually misses)
    while WMH samples them proportionally to magnitude.
    """
    n_ov = int(round(overlap * nnz))
    idx = rng.choice(n, size=2 * nnz - n_ov, replace=False)
    shared = idx[:n_ov]
    ia, ib = idx[:nnz], np.concatenate([shared, idx[nnz:]])

    def body(k):
        return rng.lognormal(mean=0.0, sigma=1.0, size=k) * rng.choice([-1, 1], k)

    a = np.zeros(n)
    b = np.zeros(n)
    a[ia] = body(nnz)
    b[ib] = body(len(ib))
    # independent per-column outliers (non-shared keys)
    for vec, own in ((a, ia), (b, ib)):
        out = own[rng.random(len(own)) < outlier_rate]
        vec[out] *= outlier_scale * (1 + rng.pareto(2.0, size=len(out)))
    # co-located outliers on shared keys (same "row scale" in both tables)
    if n_ov:
        hot = shared[rng.random(n_ov) < outlier_rate]
        scale = outlier_scale * (1 + rng.pareto(2.0, size=len(hot)))
        a[hot] *= scale
        b[hot] *= scale
    a /= max(np.linalg.norm(a), 1e-12)   # paper normalizes columns to norm 1
    b /= max(np.linalg.norm(b), 1e-12)
    return SparseVec.from_dense(a), SparseVec.from_dense(b)


def kurtosis(v: SparseVec) -> float:
    x = v.values
    if x.size < 4:
        return 0.0
    mu, sd = x.mean(), x.std()
    if sd == 0:
        return 0.0
    return float(np.mean(((x - mu) / sd) ** 4) - 3.0)


def tfidf_corpus(rng: np.random.Generator, n_docs: int = 200,
                 vocab: int = 2 ** 18, doc_len_range=(50, 2000),
                 zipf_a: float = 1.3, topic_frac: float = 0.5) -> List[SparseVec]:
    """Zipf term draws -> TF-IDF sparse vectors (Fig. 6 proxy).

    A ``topic_frac`` fraction of each document's tokens comes from a
    document-specific vocabulary block -- the stand-in for the paper's
    bigram features, which are mostly unique per document and make the
    vectors sparse with *low overlap* (the regime where Fig. 6 shows WMH
    winning).  The rest is shared Zipf-distributed vocabulary.
    """
    lengths = rng.integers(doc_len_range[0], doc_len_range[1], size=n_docs)
    term_lists = []
    df = {}
    block = vocab // (2 * max(n_docs, 1))
    stopwords = 20          # standard preprocessing drops the Zipf head
    for d, L in enumerate(lengths):
        L = int(L)
        n_topic = int(L * topic_frac)
        shared = stopwords + ((rng.zipf(zipf_a, size=L - n_topic) - 1)
                              % (vocab // 2 - stopwords))
        topic_lo = vocab // 2 + d * block
        topic = topic_lo + ((rng.zipf(zipf_a, size=n_topic) - 1) % block)
        terms = np.concatenate([shared, topic])
        uniq, counts = np.unique(terms, return_counts=True)
        term_lists.append((uniq, counts, int(L)))
        for t in uniq:
            df[int(t)] = df.get(int(t), 0) + 1
    docs = []
    for uniq, counts, L in term_lists:
        idf = np.array([np.log(n_docs / (1 + df[int(t)])) + 1.0 for t in uniq])
        tf = 1.0 + np.log(counts)    # sublinear tf, sklearn-style
        docs.append(SparseVec.from_pairs(uniq.astype(np.int64), tf * idf, vocab))
    return docs


def token_stream(seed: int, step: int, batch: int, seq: int,
                 vocab: int) -> np.ndarray:
    """Deterministic (seed, step) -> tokens [batch, seq].  Resumable by design:
    restarting at step k regenerates exactly the same batch."""
    rng = np.random.default_rng(np.random.SeedSequence([seed, step]))
    z = rng.zipf(1.3, size=(batch, seq + 1))
    return (z - 1) % vocab
