"""Data substrate: synthetic generators (paper protocols), sharded pipeline,
and the dataset-search sketch index (the paper's §1.3 application)."""
from .dataset_search import DatasetSearchIndex, SearchResult, TableSketch
from .pipeline import TokenPipeline
from .synthetic import (kurtosis, sparse_pair, tfidf_corpus, token_stream,
                        worldbank_like_pair)

__all__ = ["DatasetSearchIndex", "SearchResult", "TableSketch",
           "TokenPipeline", "sparse_pair", "worldbank_like_pair", "kurtosis",
           "tfidf_corpus", "token_stream"]
