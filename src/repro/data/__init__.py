"""Data substrate: synthetic generators (paper protocols), sharded pipeline,
the device-resident sketch corpus, and the dataset-search sketch index (the
paper's §1.3 application)."""
from .corpus import SketchCorpus, pad_sparse_batch, sketch_batch
from .dataset_search import DatasetSearchIndex, SearchResult, TableSketch
from .families import (FAMILY_NAMES, ComponentSpec, CSFamily, ICWSFamily,
                       JLFamily, PSFamily, TSFamily, make_family,
                       wmh_storage)
from .ingest import pad_linear_batch, pad_sample_batch
from .merge import (build_sharded, merge_stores, partition_by_key,
                    split_by_key)
from .pipeline import TokenPipeline
from .store import CorpusStore
from .synthetic import (kurtosis, sparse_pair, tfidf_corpus, token_stream,
                        worldbank_like_pair)

__all__ = ["DatasetSearchIndex", "SearchResult", "TableSketch",
           "CorpusStore", "SketchCorpus", "sketch_batch", "pad_sparse_batch",
           "pad_linear_batch", "pad_sample_batch",
           "FAMILY_NAMES", "ComponentSpec", "ICWSFamily", "CSFamily",
           "JLFamily", "TSFamily", "PSFamily", "make_family", "wmh_storage",
           "build_sharded", "merge_stores", "partition_by_key",
           "split_by_key",
           "TokenPipeline", "sparse_pair", "worldbank_like_pair", "kurtosis",
           "tfidf_corpus", "token_stream"]
