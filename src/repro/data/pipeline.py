"""Sharded, prefetching, deterministically-resumable input pipeline.

Each host generates only its own shard of the global batch (indexed by
``host_id``/``num_hosts``), prefetches ahead on a worker thread, and is
exactly resumable: batch content is a pure function of (seed, step), so a
job restarted from a step-k checkpoint sees the same stream it would have --
no data-loader state in the checkpoint at all.
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Dict, Iterator, Optional

import numpy as np

from .synthetic import token_stream


class TokenPipeline:
    def __init__(self, *, seed: int, global_batch: int, seq: int, vocab: int,
                 host_id: int = 0, num_hosts: int = 1, microbatches: int = 1,
                 prefetch: int = 2, start_step: int = 0):
        assert global_batch % num_hosts == 0
        self.seed = seed
        self.global_batch = global_batch
        self.seq = seq
        self.vocab = vocab
        self.host_id = host_id
        self.num_hosts = num_hosts
        self.microbatches = microbatches
        self._step = start_step
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _make(self, step: int) -> Dict[str, np.ndarray]:
        toks = token_stream(self.seed, step, self.global_batch, self.seq,
                            self.vocab)
        per_host = self.global_batch // self.num_hosts
        lo = self.host_id * per_host
        shard = toks[lo:lo + per_host]
        tokens, labels = shard[:, :-1], shard[:, 1:]
        M = self.microbatches
        if M > 1:
            tokens = tokens.reshape(M, per_host // M, self.seq)
            labels = labels.reshape(M, per_host // M, self.seq)
        return {"tokens": tokens.astype(np.int32),
                "labels": labels.astype(np.int32), "step": step}

    def _worker(self):
        step = self._step
        while not self._stop.is_set():
            batch = self._make(step)
            while not self._stop.is_set():
                try:
                    self._q.put(batch, timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        return self._q.get()

    def close(self):
        self._stop.set()
        self._thread.join(timeout=2)
