"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

THE proof that the distribution config is coherent without real hardware:
``jax.jit(step, in_shardings, out_shardings).lower(**structs).compile()``
must succeed on the 16x16 single-pod mesh AND the 2x16x16 two-pod mesh for
every applicable cell, and the compiled artifact yields memory_analysis()
(fits HBM?) + cost_analysis() + parsed collective schedule for §Roofline.

Usage:
  python -m repro.launch.dryrun --arch tinyllama-1.1b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out experiments/dryrun]

Results are cached as JSON per cell so the sweep is resumable.
"""
# The VERY FIRST lines, before ANY other import: jax locks the device count
# on first init, and the production meshes need 512 placeholder devices.
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

import argparse
import dataclasses
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro import configs
from repro.configs.base import cell_applicable
from repro.distributed.sharding import ShardingCtx, make_rules, rules_for_cell
from repro.launch import specs as S
from repro.launch.mesh import make_production_mesh
from repro.models import Model
from repro.optim import adamw
from repro.roofline import Roofline, analyze_hlo, model_flops_for
from repro.serve.step import make_decode_step, make_prefill_step
from repro.train.step import make_train_step

DEFAULT_OUT = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def _chips(mesh) -> int:
    n = 1
    for v in mesh.shape.values():
        n *= v
    return n


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool,
               rule_overrides=None, q_chunk: int = 1024, k_chunk: int = 1024,
               microbatches: int = 0, extra_tag: str = "",
               grad_constraint: bool = True, accum_dtype: str = "float32"):
    """Lower + compile one cell; returns the result record (dict)."""
    cfg = configs.get(arch)
    shape_cfg = configs.SHAPES[shape_name]
    ok, reason = cell_applicable(cfg, shape_cfg)
    if not ok:
        return {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
                "status": "skipped", "reason": reason}

    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = rules_for_cell(cfg, shape_cfg, mesh)
    if rule_overrides:
        rules.update(rule_overrides)
    ctx = ShardingCtx(mesh=mesh, rules=rules)
    model = Model(cfg)

    t0 = time.time()
    params_shape, param_specs = S.model_shapes_and_specs(model)
    params_sh = S.tree_shardings_of(params_shape, param_specs, rules, mesh)

    if shape_cfg.kind == "train":
        M = microbatches or S.train_microbatches(shape_cfg, mesh)
        batch_struct, batch_sh = S.batch_shardings(cfg, shape_cfg, mesh, rules, M)
        opt_cfg = adamw.AdamWConfig()
        opt_shape, opt_specs = S.opt_shapes_and_specs(params_shape, param_specs,
                                                      opt_cfg)
        opt_sh = S.tree_shardings_of(opt_shape, opt_specs, rules, mesh)
        opt_sh["step"] = S.scalar_sharding(mesh)
        step = make_train_step(model, opt_cfg, ctx,
                               q_chunk=q_chunk, k_chunk=k_chunk,
                               param_logical=param_specs if grad_constraint else None,
                               accum_dtype=jnp.dtype(accum_dtype))
        metrics_sh = {k: S.scalar_sharding(mesh)
                      for k in ("loss", "grad_norm", "lr", "step")}
        with mesh:
            lowered = jax.jit(
                step,
                in_shardings=(params_sh, opt_sh, batch_sh),
                out_shardings=(params_sh, opt_sh, metrics_sh),
                donate_argnums=(0, 1),
            ).lower(params_shape, opt_shape, batch_struct)
            compiled = lowered.compile()
    elif shape_cfg.kind == "prefill":
        batch_struct, batch_sh = S.batch_shardings(cfg, shape_cfg, mesh, rules, 0)
        step = make_prefill_step(model, ctx, q_chunk=q_chunk, k_chunk=k_chunk)
        with mesh:
            lowered = jax.jit(step, in_shardings=(params_sh, batch_sh)
                              ).lower(params_shape, batch_struct)
            compiled = lowered.compile()
    else:  # decode
        B, T = shape_cfg.global_batch, shape_cfg.seq_len
        state_shape, state_specs = S.decode_state_shapes(model, B, T)
        state_sh = S.tree_shardings_of(state_shape, state_specs, rules, mesh)
        tok_struct = jax.ShapeDtypeStruct((B, 1), jnp.int32)
        tok_sh = S.sharding_from_rules((B, 1), ("batch", None), rules, mesh)
        step = make_decode_step(model, ctx)
        with mesh:
            lowered = jax.jit(step,
                              in_shardings=(params_sh, tok_sh, state_sh),
                              out_shardings=None,
                              donate_argnums=(2,),
                              ).lower(params_shape, tok_struct, state_shape)
            compiled = lowered.compile()

    compile_s = time.time() - t0
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    rc = analyze_hlo(hlo)
    chips = _chips(mesh)
    rl = Roofline(chips=chips,
                  flops=rc.flops * chips,
                  hbm_bytes=rc.hbm_bytes * chips,
                  collective_bytes=rc.collective_bytes * chips,
                  model_flops=model_flops_for(cfg, shape_cfg))

    record = {
        "arch": arch, "shape": shape_name, "multi_pod": multi_pod,
        "tag": extra_tag, "status": "ok",
        "kind": shape_cfg.kind,
        "chips": chips,
        "compile_seconds": compile_s,
        "microbatches": microbatches or (
            S.train_microbatches(shape_cfg, mesh) if shape_cfg.kind == "train" else 1),
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
        "memory": {
            "argument_bytes_per_device": mem.argument_size_in_bytes,
            "output_bytes_per_device": mem.output_size_in_bytes,
            "temp_bytes_per_device": mem.temp_size_in_bytes,
            "alias_bytes_per_device": mem.alias_size_in_bytes,
            "code_bytes": mem.generated_code_size_in_bytes,
            "peak_bytes_per_device": (mem.argument_size_in_bytes
                                      + mem.output_size_in_bytes
                                      + mem.temp_size_in_bytes
                                      - mem.alias_size_in_bytes),
            "hbm_per_device": 16 * 1024 ** 3,
        },
        "cost_analysis": {k: v for k, v in cost.items()
                          if k in ("flops", "bytes accessed")},
        "hlo_counts": {
            "flops_per_device": rc.flops,
            "hbm_bytes_per_device": rc.hbm_bytes,
            "collective_bytes_per_device": rc.collective_bytes,
            "collectives_by_kind": rc.collectives,
            "while_trip_counts": rc.while_trip_counts[:32],
        },
        "roofline": rl.as_dict(),
        "rules": {k: (list(v) if isinstance(v, tuple) else v)
                  for k, v in rules.items()},
    }
    return record


def run_cell(arch, shape_name, multi_pod, outdir: Path, force=False, **kw):
    tag = kw.get("extra_tag", "")
    name = f"{arch}_{shape_name}_{'pod2' if multi_pod else 'pod1'}"
    if tag:
        name += f"_{tag}"
    path = outdir / f"{name}.json"
    if path.exists() and not force:
        rec = json.loads(path.read_text())
        print(f"[cached] {name}: {rec['status']}")
        return rec
    try:
        rec = lower_cell(arch, shape_name, multi_pod=multi_pod, **kw)
    except Exception as e:  # noqa: BLE001 - record the failure, keep sweeping
        rec = {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
               "tag": tag, "status": "error", "error": str(e)[-2000:],
               "traceback": traceback.format_exc()[-4000:]}
    outdir.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(rec, indent=2, default=float))
    status = rec["status"]
    extra = ""
    if status == "ok":
        extra = (f" compile={rec['compile_seconds']:.0f}s"
                 f" dominant={rec['roofline']['dominant']}"
                 f" peakGB={rec['memory']['peak_bytes_per_device']/2**30:.1f}")
    print(f"[{status}] {name}{extra}", flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default=str(DEFAULT_OUT))
    args = ap.parse_args()
    outdir = Path(args.out)

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    if args.all:
        cells = [(a, s) for a in configs.ARCHS for s in configs.SHAPES]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    n_ok = n_skip = n_err = 0
    for mp in meshes:
        for arch, shape in cells:
            rec = run_cell(arch, shape, mp, outdir, force=args.force)
            n_ok += rec["status"] == "ok"
            n_skip += rec["status"] == "skipped"
            n_err += rec["status"] == "error"
    print(f"done: ok={n_ok} skipped={n_skip} errors={n_err}")
    return 1 if n_err else 0


if __name__ == "__main__":
    raise SystemExit(main())
