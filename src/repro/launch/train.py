"""Production training launcher.

On a real multi-host TPU deployment each host runs this same binary with
cluster-provided JAX distributed env; on this container it runs the reduced
config on the host mesh.  ``--dry-run`` lowers the full-size model for the
production mesh instead (see repro.launch.dryrun for the sweep driver).

Usage:
  python -m repro.launch.train --arch tinyllama-1.1b --steps 50
  python -m repro.launch.train --arch mixtral-8x22b --dry-run
"""
from __future__ import annotations

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=2)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--dry-run", action="store_true",
                    help="lower+compile the FULL config on the production mesh")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()

    if args.dry_run:
        # Re-exec through the dryrun entrypoint so XLA_FLAGS is set first.
        import os
        import subprocess
        import sys
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", args.arch, "--shape", "train_4k"]
        if args.multi_pod:
            cmd.append("--multi-pod")
        raise SystemExit(subprocess.call(cmd, env=os.environ))

    from repro import configs
    from repro.optim import AdamWConfig
    from repro.train.trainer import Trainer, TrainerConfig

    cfg = configs.reduced(args.arch)
    if cfg.family in ("encdec", "vlm"):
        raise SystemExit(f"{args.arch}: the token-stream trainer drives LM "
                         "families; use examples/ for multimodal stubs")
    tcfg = TrainerConfig(
        steps=args.steps, global_batch=args.global_batch, seq=args.seq,
        microbatches=args.microbatches, ckpt_dir=args.ckpt_dir,
        opt=AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=args.steps))
    trainer = Trainer(cfg, tcfg)
    trainer.preemption.install()
    hist = trainer.run()
    print(f"final loss {hist['loss'][-1]:.4f} "
          f"(start {hist['loss'][0]:.4f})")


if __name__ == "__main__":
    main()
