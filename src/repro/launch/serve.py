"""Serving launcher: batched decode on the reduced config (host mesh) or
full-size decode-cell lowering on the production mesh (--dry-run).

Usage:
  python -m repro.launch.serve --arch tinyllama-1.1b --requests 6
  python -m repro.launch.serve --arch mixtral-8x22b --dry-run --shape decode_32k
"""
from __future__ import annotations

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new-tokens", type=int, default=8)
    ap.add_argument("--dry-run", action="store_true")
    ap.add_argument("--shape", default="decode_32k")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()

    if args.dry_run:
        import os
        import subprocess
        import sys
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", args.arch, "--shape", args.shape]
        if args.multi_pod:
            cmd.append("--multi-pod")
        raise SystemExit(subprocess.call(cmd, env=os.environ))

    import time

    import jax

    from repro import configs
    from repro.models import Model
    from repro.serve.engine import Request, ServeEngine

    cfg = configs.reduced(args.arch)
    model = Model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(model, params, batch_slots=args.slots, max_seq=128)
    reqs = [Request(rid=i, prompt=[1 + i, 2 + i], max_new_tokens=args.max_new_tokens)
            for i in range(args.requests)]
    for r in reqs:
        engine.submit(r)
    t0 = time.time()
    ticks = 0
    while any(not r.done for r in reqs) and ticks < 10_000:
        engine.tick()
        ticks += 1
    toks = sum(len(r.output) for r in reqs)
    print(f"{args.arch}: served {len(reqs)} requests / {toks} tokens "
          f"in {time.time()-t0:.2f}s")


if __name__ == "__main__":
    main()
