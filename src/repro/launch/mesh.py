"""Production mesh definitions.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state -- the dry-run sets XLA_FLAGS before first init.
"""
from __future__ import annotations

import jax

from repro.compat import auto_axis_types, make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 two-pod (512 chips) mesh."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes, axis_types=auto_axis_types(len(axes)))


def make_corpus_mesh(data: int = 0):
    """1-D ``("data",)`` mesh for sharded corpus-query execution.

    The dataset-search store shards its corpus rows over this axis (logical
    axis ``"corpus"`` in ``distributed.sharding.DEFAULT_RULES``).  ``data=0``
    uses every visible device -- e.g. the forced host devices under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` on CPU.
    """
    n = data or len(jax.devices())
    return make_mesh((n,), ("data",), axis_types=auto_axis_types(1))


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests / examples on CPU)."""
    n = len(jax.devices())
    if data * model > n:
        data, model = n, 1
    return make_mesh((data, model), ("data", "model"),
                     axis_types=auto_axis_types(2))
