"""ShapeDtypeStruct input stand-ins + shardings for every (arch x shape) cell.

Nothing here allocates device memory: parameters/optimizer/cache shapes come
from ``jax.eval_shape`` over the real init functions, inputs are synthesized
structs, and shardings resolve through the logical-rule table.  This is the
shared machinery of the dry-run, the roofline pass, and the perf hillclimb.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.distributed.sharding import ShardingCtx, sharding_for, spec_for
from repro.models import Model
from repro.optim import adamw


def train_microbatches(shape_cfg: ShapeConfig, mesh) -> int:
    """Microbatch count: per-replica microbatch of 1 sequence at 4k train."""
    dp = 1
    for a in ("pod", "data"):
        if a in mesh.shape:
            dp *= mesh.shape[a]
    per_replica = max(shape_cfg.global_batch // dp, 1)
    return min(per_replica, 8)


def batch_structs(cfg: ModelConfig, shape_cfg: ShapeConfig,
                  microbatches: int = 1) -> Dict[str, jax.ShapeDtypeStruct]:
    """Training batch structs: always [M, B/M, ...] (M=1 included);
    prefill (microbatches=0) gets flat [B, ...]."""
    B, T = shape_cfg.global_batch, shape_cfg.seq_len
    M = microbatches
    lead = (M, B // M) if M >= 1 else (B,)

    def s(shape, dtype):
        return jax.ShapeDtypeStruct(lead + shape, dtype)

    batch = {}
    t_text = T - cfg.num_patches if cfg.family == "vlm" else T
    batch["tokens"] = s((t_text,), jnp.int32)
    batch["labels"] = s((t_text,), jnp.int32)
    if cfg.family == "vlm":
        batch["patches"] = s((cfg.num_patches, cfg.d_model), jnp.float32)
    if cfg.family == "encdec":
        batch["frames"] = s((cfg.encoder_seq, cfg.encoder_d_model), jnp.float32)
    return batch


def batch_logical(cfg: ModelConfig, microbatches: int = 1) -> Dict[str, tuple]:
    lead = (None, "batch") if microbatches >= 1 else ("batch",)
    logical = {"tokens": lead + ("seq",), "labels": lead + ("seq",)}
    if cfg.family == "vlm":
        logical["patches"] = lead + ("seq", "embed")
    if cfg.family == "encdec":
        logical["frames"] = lead + ("seq", None)
    return logical


def batch_shardings(cfg, shape_cfg, mesh, rules, microbatches=1):
    structs = batch_structs(cfg, shape_cfg, microbatches)
    logical = batch_logical(cfg, microbatches)
    return structs, {k: sharding_for(structs[k].shape, logical[k], rules, mesh)
                     for k in structs}


def model_shapes_and_specs(model: Model):
    """(param structs, logical specs).  Specs are static python data, so we
    get them from a real (tiny-key) trace of init via eval_shape on params
    only."""
    def init_params_only(key):
        p, _ = model.init(key)
        return p
    params_shape = jax.eval_shape(init_params_only, jax.random.PRNGKey(0))
    # Specs are deterministic static structures: build them cheaply by calling
    # init under eval_shape and capturing the second output via closure.
    captured = {}
    def init_capture(key):
        p, s = model.init(key)
        captured["specs"] = s
        return p
    jax.eval_shape(init_capture, jax.random.PRNGKey(0))
    return params_shape, captured["specs"]


def opt_shapes_and_specs(params_shape, param_specs, opt_cfg):
    opt_shape = jax.eval_shape(lambda: adamw.init_opt_state(
        jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), params_shape),
        opt_cfg))
    opt_specs = adamw.opt_state_specs(param_specs)
    return opt_shape, opt_specs


def decode_state_shapes(model: Model, batch: int, max_seq: int):
    captured = {}
    def init_capture():
        st, sp = model.init_decode_state(batch, max_seq)
        captured["specs"] = sp
        return st
    state_shape = jax.eval_shape(init_capture)
    return state_shape, captured["specs"]


def _is_logical_leaf(x):
    return isinstance(x, tuple) and all(isinstance(e, (str, type(None)))
                                        for e in x)


def tree_shardings_of(shapes, logical, rules, mesh):
    return jax.tree.map(
        lambda s, l: sharding_for(s.shape, l, rules, mesh),
        shapes, logical, is_leaf=lambda x: _is_logical_leaf(x))


def scalar_sharding(mesh):
    return NamedSharding(mesh, P())


def sharding_from_rules(shape, logical, rules, mesh):
    return sharding_for(shape, logical, rules, mesh)
