"""Pallas TPU kernels for the sketching hot-spots + pure-jnp oracles.

Kernels (each = pallas_call + explicit BlockSpec VMEM tiling):
  * icws_sketch  -- batched weighted-MinHash (ICWS) sketching
  * countsketch  -- MXU-formulated CountSketch (gradient compression)
  * estimate     -- fused Algorithm-5 estimator partials

``ops`` holds the jit'd wrappers; ``ref`` the oracles used for validation.
"""
from . import ops, ref
from .countsketch import countsketch_pallas
from .estimate import estimate_one_vs_many_pallas, estimate_partials_pallas
from .icws_sketch import icws_sketch_pallas

__all__ = ["ops", "ref", "icws_sketch_pallas", "countsketch_pallas",
           "estimate_partials_pallas", "estimate_one_vs_many_pallas"]
