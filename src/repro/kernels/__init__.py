"""Pallas TPU kernels for the sketching hot-spots + pure-jnp oracles.

Kernels (each = pallas_call + explicit BlockSpec VMEM tiling):
  * icws_sketch  -- batched weighted-MinHash (ICWS) sketching
  * countsketch  -- MXU-formulated CountSketch (dense gradients + padded
                    sparse batches for the CS serving family)
  * jl_sketch    -- MXU-formulated JL/AMS projection of padded sparse batches
  * estimate     -- fused Algorithm-5 estimator partials + per-rep MXU dot
                    estimation for the linear families
  * sample_estimate -- unaligned key-match contraction for the sampling
                    families (Threshold/Priority Sampling rows)

``ops`` holds the jit'd wrappers; ``ref`` the oracles used for validation.
"""
from . import ops, ref
from .countsketch import countsketch_pallas, countsketch_sparse_pallas
from .estimate import (estimate_one_vs_many_pallas, estimate_partials_pallas,
                       linear_estimate_fields_pallas)
from .icws_sketch import icws_sketch_pallas
from .jl_sketch import jl_sketch_pallas
from .sample_estimate import (sample_estimate_fields_pallas,
                              sample_inclusion_probs)

__all__ = ["ops", "ref", "icws_sketch_pallas", "countsketch_pallas",
           "countsketch_sparse_pallas", "jl_sketch_pallas",
           "estimate_partials_pallas", "estimate_one_vs_many_pallas",
           "linear_estimate_fields_pallas", "sample_estimate_fields_pallas",
           "sample_inclusion_probs"]
