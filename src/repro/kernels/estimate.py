"""Pallas TPU kernels: fused sketch-pair estimator partials (Algorithm 5, line 3).

For P sketch pairs with m samples each, computes per pair:
  * the collision count  ``sum_t 1[fp_a == fp_b]``
  * the importance sum   ``sum_t 1[...] * va*vb / min(va^2, vb^2)``

Two variants share the kernel body:

  * ``estimate_partials_pallas``          -- pairwise: A and B are both [P, m].
  * ``estimate_one_vs_many_pallas``       -- one query sketch [1, m] against a
    corpus [P, m].  The query block is *broadcast* across the P grid dimension
    via its BlockSpec index map (every grid step re-reads block (0, mi)), so
    the caller never tiles the query into a [P, m] copy -- this is the
    dataset-search serving hot loop (every query hits every corpus sketch).

Grid ``(P/BP, m/BM)`` with the m dimension innermost and accumulating into
``[BP]`` output blocks.  Pure VPU elementwise + row reduction; one pass over
the sketches, no intermediate [P, m] materialization in HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _est_kernel(fpa_ref, va_ref, fpb_ref, vb_ref, cnt_ref, sw_ref):
    m_idx = pl.program_id(1)

    fpa, fpb = fpa_ref[:, :], fpb_ref[:, :]
    va, vb = va_ref[:, :], vb_ref[:, :]
    collide = (fpa == fpb) & (fpa >= 0)
    q = jnp.minimum(va * va, vb * vb)
    safe_q = jnp.where(collide & (q > 0), q, 1.0)
    term = jnp.where(collide, va * vb / safe_q, 0.0)
    cnt = collide.astype(jnp.float32).sum(axis=1)
    sw = term.sum(axis=1)

    @pl.when(m_idx == 0)
    def _init():
        cnt_ref[:] = cnt
        sw_ref[:] = sw

    @pl.when(m_idx != 0)
    def _acc():
        cnt_ref[:] = cnt_ref[:] + cnt
        sw_ref[:] = sw_ref[:] + sw


@functools.partial(jax.jit, static_argnames=("bp", "bm", "interpret"))
def estimate_partials_pallas(fpa, va, fpb, vb, *, bp: int = 8, bm: int = 128,
                             interpret: bool = True):
    """Matches :func:`repro.kernels.ref.estimate_partials_ref`."""
    P, m = fpa.shape
    p_pad = (-P) % bp
    m_pad = (-m) % bm
    if p_pad or m_pad:
        fpa = jnp.pad(fpa, ((0, p_pad), (0, m_pad)), constant_values=-1)
        fpb = jnp.pad(fpb, ((0, p_pad), (0, m_pad)), constant_values=-2)
        va = jnp.pad(va, ((0, p_pad), (0, m_pad)))
        vb = jnp.pad(vb, ((0, p_pad), (0, m_pad)))
    Pp, mp = fpa.shape
    grid = (Pp // bp, mp // bm)
    cnt, sw = pl.pallas_call(
        _est_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((bp, bm), lambda p, mi: (p, mi))] * 4,
        out_specs=[pl.BlockSpec((bp,), lambda p, mi: (p,))] * 2,
        out_shape=[jax.ShapeDtypeStruct((Pp,), jnp.float32)] * 2,
        interpret=interpret,
    )(fpa.astype(jnp.int32), va.astype(jnp.float32),
      fpb.astype(jnp.int32), vb.astype(jnp.float32))
    return cnt[:P], sw[:P]


@functools.partial(jax.jit, static_argnames=("bp", "bm", "interpret"))
def estimate_one_vs_many_pallas(fq, vq, fpc, vc, *, bp: int = 8, bm: int = 128,
                                interpret: bool = True):
    """One query sketch against a P-row corpus; matches
    :func:`repro.kernels.ref.estimate_one_vs_many_ref`.

    Args: fq/vq [1, m] (or [m]) query fingerprints/values; fpc/vc [P, m]
    corpus.  Returns (n_collide [P], s_weight [P]).  The query block is
    broadcast by its index map -- no [P, m] tiling of the query ever exists.
    """
    fq = fq.reshape(1, -1)
    vq = vq.reshape(1, -1)
    P, m = fpc.shape
    p_pad = (-P) % bp
    m_pad = (-m) % bm
    if m_pad:
        # pad fingerprints to *different* sentinels so padding never collides
        fq = jnp.pad(fq, ((0, 0), (0, m_pad)), constant_values=-1)
        vq = jnp.pad(vq, ((0, 0), (0, m_pad)))
    if p_pad or m_pad:
        fpc = jnp.pad(fpc, ((0, p_pad), (0, m_pad)), constant_values=-2)
        vc = jnp.pad(vc, ((0, p_pad), (0, m_pad)))
    Pp, mp = fpc.shape
    grid = (Pp // bp, mp // bm)
    cnt, sw = pl.pallas_call(
        _est_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm), lambda p, mi: (0, mi)),   # query: broadcast
            pl.BlockSpec((1, bm), lambda p, mi: (0, mi)),
            pl.BlockSpec((bp, bm), lambda p, mi: (p, mi)),  # corpus: tiled
            pl.BlockSpec((bp, bm), lambda p, mi: (p, mi)),
        ],
        out_specs=[pl.BlockSpec((bp,), lambda p, mi: (p,))] * 2,
        out_shape=[jax.ShapeDtypeStruct((Pp,), jnp.float32)] * 2,
        interpret=interpret,
    )(fq.astype(jnp.int32), vq.astype(jnp.float32),
      fpc.astype(jnp.int32), vc.astype(jnp.float32))
    return cnt[:P], sw[:P]
