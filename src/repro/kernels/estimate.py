"""Pallas TPU kernel: fused sketch-pair estimator partials (Algorithm 5, line 3).

For P sketch pairs with m samples each, computes per pair:
  * the collision count  ``sum_t 1[fp_a == fp_b]``
  * the importance sum   ``sum_t 1[...] * va*vb / min(va^2, vb^2)``

Grid ``(P/BP, m/BM)`` with the m dimension innermost and accumulating into
``[BP]`` output blocks.  Pure VPU elementwise + row reduction; one pass over
the sketches, no intermediate [P, m] materialization in HBM -- this is the
hot loop of corpus-scale dataset search (every query hits every corpus
sketch).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _est_kernel(fpa_ref, va_ref, fpb_ref, vb_ref, cnt_ref, sw_ref):
    m_idx = pl.program_id(1)

    fpa, fpb = fpa_ref[:, :], fpb_ref[:, :]
    va, vb = va_ref[:, :], vb_ref[:, :]
    collide = (fpa == fpb) & (fpa >= 0)
    q = jnp.minimum(va * va, vb * vb)
    safe_q = jnp.where(collide & (q > 0), q, 1.0)
    term = jnp.where(collide, va * vb / safe_q, 0.0)
    cnt = collide.astype(jnp.float32).sum(axis=1)
    sw = term.sum(axis=1)

    @pl.when(m_idx == 0)
    def _init():
        cnt_ref[:] = cnt
        sw_ref[:] = sw

    @pl.when(m_idx != 0)
    def _acc():
        cnt_ref[:] = cnt_ref[:] + cnt
        sw_ref[:] = sw_ref[:] + sw


@functools.partial(jax.jit, static_argnames=("bp", "bm", "interpret"))
def estimate_partials_pallas(fpa, va, fpb, vb, *, bp: int = 8, bm: int = 128,
                             interpret: bool = True):
    """Matches :func:`repro.kernels.ref.estimate_partials_ref`."""
    P, m = fpa.shape
    p_pad = (-P) % bp
    m_pad = (-m) % bm
    if p_pad or m_pad:
        fpa = jnp.pad(fpa, ((0, p_pad), (0, m_pad)), constant_values=-1)
        fpb = jnp.pad(fpb, ((0, p_pad), (0, m_pad)), constant_values=-2)
        va = jnp.pad(va, ((0, p_pad), (0, m_pad)))
        vb = jnp.pad(vb, ((0, p_pad), (0, m_pad)))
    Pp, mp = fpa.shape
    grid = (Pp // bp, mp // bm)
    cnt, sw = pl.pallas_call(
        _est_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((bp, bm), lambda p, mi: (p, mi))] * 4,
        out_specs=[pl.BlockSpec((bp,), lambda p, mi: (p,))] * 2,
        out_shape=[jax.ShapeDtypeStruct((Pp,), jnp.float32)] * 2,
        interpret=interpret,
    )(fpa.astype(jnp.int32), va.astype(jnp.float32),
      fpb.astype(jnp.int32), vb.astype(jnp.float32))
    return cnt[:P], sw[:P]
