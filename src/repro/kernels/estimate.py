"""Pallas TPU kernels: fused sketch-pair estimator partials (Algorithm 5, line 3).

For P sketch pairs with m samples each, computes per pair:
  * the collision count  ``sum_t 1[fp_a == fp_b]``
  * the importance sum   ``sum_t 1[...] * va*vb / min(va^2, vb^2)``

Four variants share the kernel math:

  * ``estimate_partials_pallas``          -- pairwise: A and B are both [P, m].
  * ``estimate_one_vs_many_pallas``       -- one query sketch [1, m] against a
    corpus [P, m].  The query block is *broadcast* across the P grid dimension
    via its BlockSpec index map (every grid step re-reads block (0, mi)), so
    the caller never tiles the query into a [P, m] copy.
  * ``estimate_many_vs_many_pallas``      -- Q query sketches against a corpus
    [P, m] in ONE launch, grid ``(Q/BQ, P/BP, m/BM)``.  Each query block is
    re-read across the P grid dimension exactly the way the one-vs-many
    variant broadcasts its single row; collisions are formed blockwise as
    ``[BQ, BP, BM]`` in VMEM and reduced immediately -- no ``[Q, P, m]``
    tensor is ever materialized.
  * ``estimate_fields_pallas``            -- the fused multi-field form of the
    above: query/corpus sketches arrive stacked per *field* (``[F, Q, m]`` /
    ``[C, P, m]``) and a static list of (query-field, corpus-field) pairs is
    folded into the leading grid dimension, so e.g. all six §1.3 field-pair
    estimates of a dataset-search batch run as a single kernel launch.

Grids keep the m dimension innermost and accumulate into per-(row[, col])
output blocks.  Pure VPU elementwise + reduction; one pass over the sketches,
no intermediate [P, m] / [Q, P, m] materialization in HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .packed import unpack_halfwords_f32

# Pad sentinels -- the single definition of the padding convention every
# estimate variant (and the corpus store / sharded wrappers) relies on:
# query padding (-1, also the empty-sketch fingerprint) and corpus padding
# (-2) never equal each other or a live fingerprint (>= 0), and the kernel
# guard ``fq >= 0`` keeps both out of the estimate.
QUERY_PAD_FP = -1
CORPUS_PAD_FP = -2


def _est_kernel(fpa_ref, va_ref, fpb_ref, vb_ref, cnt_ref, sw_ref):
    m_idx = pl.program_id(1)

    fpa, fpb = fpa_ref[:, :], fpb_ref[:, :]
    va, vb = va_ref[:, :], vb_ref[:, :]
    collide = (fpa == fpb) & (fpa >= 0)
    q = jnp.minimum(va * va, vb * vb)
    safe_q = jnp.where(collide & (q > 0), q, 1.0)
    term = jnp.where(collide, va * vb / safe_q, 0.0)
    cnt = collide.astype(jnp.float32).sum(axis=1)
    sw = term.sum(axis=1)

    @pl.when(m_idx == 0)
    def _init():
        cnt_ref[:] = cnt
        sw_ref[:] = sw

    @pl.when(m_idx != 0)
    def _acc():
        cnt_ref[:] = cnt_ref[:] + cnt
        sw_ref[:] = sw_ref[:] + sw


@functools.partial(jax.jit, static_argnames=("bp", "bm", "interpret"))
def estimate_partials_pallas(fpa, va, fpb, vb, *, bp: int = 8, bm: int = 128,
                             interpret: bool = True):
    """Matches :func:`repro.kernels.ref.estimate_partials_ref`."""
    P, m = fpa.shape
    p_pad = (-P) % bp
    m_pad = (-m) % bm
    if p_pad or m_pad:
        fpa = jnp.pad(fpa, ((0, p_pad), (0, m_pad)), constant_values=QUERY_PAD_FP)
        fpb = jnp.pad(fpb, ((0, p_pad), (0, m_pad)), constant_values=CORPUS_PAD_FP)
        va = jnp.pad(va, ((0, p_pad), (0, m_pad)))
        vb = jnp.pad(vb, ((0, p_pad), (0, m_pad)))
    Pp, mp = fpa.shape
    grid = (Pp // bp, mp // bm)
    cnt, sw = pl.pallas_call(
        _est_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((bp, bm), lambda p, mi: (p, mi))] * 4,
        out_specs=[pl.BlockSpec((bp,), lambda p, mi: (p,))] * 2,
        out_shape=[jax.ShapeDtypeStruct((Pp,), jnp.float32)] * 2,
        interpret=interpret,
    )(fpa.astype(jnp.int32), va.astype(jnp.float32),
      fpb.astype(jnp.int32), vb.astype(jnp.float32))
    return cnt[:P], sw[:P]


@functools.partial(jax.jit, static_argnames=("bp", "bm", "interpret"))
def estimate_one_vs_many_pallas(fq, vq, fpc, vc, *, bp: int = 64, bm: int = 128,
                                interpret: bool = True):
    """One query sketch against a P-row corpus; matches
    :func:`repro.kernels.ref.estimate_one_vs_many_ref`.

    Args: fq/vq [1, m] (or [m]) query fingerprints/values; fpc/vc [P, m]
    corpus.  Returns (n_collide [P], s_weight [P]).  The query block is
    broadcast by its index map -- no [P, m] tiling of the query ever exists.
    """
    fq = fq.reshape(1, -1)
    vq = vq.reshape(1, -1)
    P, m = fpc.shape
    p_pad = (-P) % bp
    m_pad = (-m) % bm
    if m_pad:
        # pad fingerprints to *different* sentinels so padding never collides
        fq = jnp.pad(fq, ((0, 0), (0, m_pad)), constant_values=QUERY_PAD_FP)
        vq = jnp.pad(vq, ((0, 0), (0, m_pad)))
    if p_pad or m_pad:
        fpc = jnp.pad(fpc, ((0, p_pad), (0, m_pad)), constant_values=CORPUS_PAD_FP)
        vc = jnp.pad(vc, ((0, p_pad), (0, m_pad)))
    Pp, mp = fpc.shape
    grid = (Pp // bp, mp // bm)
    cnt, sw = pl.pallas_call(
        _est_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm), lambda p, mi: (0, mi)),   # query: broadcast
            pl.BlockSpec((1, bm), lambda p, mi: (0, mi)),
            pl.BlockSpec((bp, bm), lambda p, mi: (p, mi)),  # corpus: tiled
            pl.BlockSpec((bp, bm), lambda p, mi: (p, mi)),
        ],
        out_specs=[pl.BlockSpec((bp,), lambda p, mi: (p,))] * 2,
        out_shape=[jax.ShapeDtypeStruct((Pp,), jnp.float32)] * 2,
        interpret=interpret,
    )(fq.astype(jnp.int32), vq.astype(jnp.float32),
      fpc.astype(jnp.int32), vc.astype(jnp.float32))
    return cnt[:P], sw[:P]


def _mvm_body(fq, vq, fc, vc):
    """Blockwise many-vs-many partials: [BQ, BM] x [BP, BM] -> [BQ, BP].

    The [BQ, BP, BM] collision tensor lives only in VMEM for this block.
    """
    fqb, fcb = fq[:, None, :], fc[None, :, :]
    vqb, vcb = vq[:, None, :], vc[None, :, :]
    collide = (fqb == fcb) & (fqb >= 0)
    q = jnp.minimum(vqb * vqb, vcb * vcb)
    safe_q = jnp.where(collide & (q > 0), q, 1.0)
    term = jnp.where(collide, vqb * vcb / safe_q, 0.0)
    return collide.astype(jnp.float32).sum(axis=2), term.sum(axis=2)


def _mvm_kernel(fq_ref, vq_ref, fc_ref, vc_ref, cnt_ref, sw_ref):
    m_idx = pl.program_id(2)
    cnt, sw = _mvm_body(fq_ref[:, :], vq_ref[:, :], fc_ref[:, :], vc_ref[:, :])

    @pl.when(m_idx == 0)
    def _init():
        cnt_ref[:, :] = cnt
        sw_ref[:, :] = sw

    @pl.when(m_idx != 0)
    def _acc():
        cnt_ref[:, :] = cnt_ref[:, :] + cnt
        sw_ref[:, :] = sw_ref[:, :] + sw


@functools.partial(jax.jit,
                   static_argnames=("bq", "bp", "bm", "interpret"))
def estimate_many_vs_many_pallas(fq, vq, fpc, vc, *, bq: int = 8,
                                 bp: int = 128, bm: int = 128,
                                 interpret: bool = True):
    """Q query sketches against a P-row corpus in one launch; matches
    :func:`repro.kernels.ref.estimate_many_vs_many_ref`.

    Args: fq/vq [Q, m] query fingerprints/values; fpc/vc [P, m] corpus.
    Returns (n_collide [Q, P], s_weight [Q, P]).  Grid (Q/bq, P/bp, m/bm),
    m innermost; the query block's index map ignores the P grid index, so
    every query block is re-read (broadcast) across the corpus dimension and
    no [Q, P, m] intermediate ever exists outside a [bq, bp, bm] VMEM tile.
    """
    Q, m = fq.shape
    P, _ = fpc.shape
    q_pad = (-Q) % bq
    p_pad = (-P) % bp
    m_pad = (-m) % bm
    if q_pad or m_pad:
        # distinct pad sentinels: query padding (-1) never collides with
        # corpus padding (-2), and fq >= 0 guards both out of the estimate
        fq = jnp.pad(fq, ((0, q_pad), (0, m_pad)), constant_values=QUERY_PAD_FP)
        vq = jnp.pad(vq, ((0, q_pad), (0, m_pad)))
    if p_pad or m_pad:
        fpc = jnp.pad(fpc, ((0, p_pad), (0, m_pad)), constant_values=CORPUS_PAD_FP)
        vc = jnp.pad(vc, ((0, p_pad), (0, m_pad)))
    Qp, mp = fq.shape
    Pp = fpc.shape[0]
    grid = (Qp // bq, Pp // bp, mp // bm)
    cnt, sw = pl.pallas_call(
        _mvm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, bm), lambda q, p, mi: (q, mi)),   # re-read over p
            pl.BlockSpec((bq, bm), lambda q, p, mi: (q, mi)),
            pl.BlockSpec((bp, bm), lambda q, p, mi: (p, mi)),
            pl.BlockSpec((bp, bm), lambda q, p, mi: (p, mi)),
        ],
        out_specs=[pl.BlockSpec((bq, bp), lambda q, p, mi: (q, p))] * 2,
        out_shape=[jax.ShapeDtypeStruct((Qp, Pp), jnp.float32)] * 2,
        interpret=interpret,
    )(fq.astype(jnp.int32), vq.astype(jnp.float32),
      fpc.astype(jnp.int32), vc.astype(jnp.float32))
    return cnt[:Q, :P], sw[:Q, :P]


def _fields_kernel(fq_ref, vq_ref, fc_ref, vc_ref, cnt_ref, sw_ref):
    m_idx = pl.program_id(3)
    cnt, sw = _mvm_body(fq_ref[0, :, :], vq_ref[0, :, :],
                        fc_ref[0, :, :], vc_ref[0, :, :])

    @pl.when(m_idx == 0)
    def _init():
        cnt_ref[0, :, :] = cnt
        sw_ref[0, :, :] = sw

    @pl.when(m_idx != 0)
    def _acc():
        cnt_ref[0, :, :] = cnt_ref[0, :, :] + cnt
        sw_ref[0, :, :] = sw_ref[0, :, :] + sw


@functools.partial(jax.jit, static_argnames=("qmap", "cmap", "bq", "bp", "bm",
                                             "interpret"))
def estimate_fields_pallas(fq, vq, fpc, vc, *, qmap, cmap, bq: int = 8,
                           bp: int = 128, bm: int = 128,
                           interpret: bool = True):
    """Fused multi-field many-vs-many partials in ONE kernel launch; matches
    :func:`repro.kernels.ref.estimate_fields_ref`.

    Args:
      fq/vq: [F, Q, m] per-field query sketches.
      fpc/vc: [C, P, m] per-field corpus sketches.
      qmap/cmap: static same-length tuples of field indices; estimate ``g``
        pairs query field ``qmap[g]`` with corpus field ``cmap[g]`` (§1.3
        uses six such pairs over F = C = 3 fields).
    Returns (n_collide [G, Q, P], s_weight [G, Q, P]) with G = len(qmap).

    The pair list is folded into the leading grid dimension: the query /
    corpus BlockSpec index maps gather the right field via a static lookup
    table, so no per-pair [Q, m] / [P, m] copies are ever stacked in HBM.
    """
    qmap = tuple(int(i) for i in qmap)
    cmap = tuple(int(i) for i in cmap)
    if len(qmap) != len(cmap):
        raise ValueError("qmap/cmap length mismatch")
    if not qmap:
        raise ValueError("qmap/cmap must name at least one field pair")
    G = len(qmap)
    F, Q, m = fq.shape
    C, P, _ = fpc.shape
    if min(qmap) < 0 or max(qmap) >= F or min(cmap) < 0 or max(cmap) >= C:
        raise ValueError("field map index out of range")
    q_pad = (-Q) % bq
    p_pad = (-P) % bp
    m_pad = (-m) % bm
    if q_pad or m_pad:
        fq = jnp.pad(fq, ((0, 0), (0, q_pad), (0, m_pad)), constant_values=QUERY_PAD_FP)
        vq = jnp.pad(vq, ((0, 0), (0, q_pad), (0, m_pad)))
    if p_pad or m_pad:
        fpc = jnp.pad(fpc, ((0, 0), (0, p_pad), (0, m_pad)),
                      constant_values=CORPUS_PAD_FP)
        vc = jnp.pad(vc, ((0, 0), (0, p_pad), (0, m_pad)))
    Qp, mp = fq.shape[1:]
    Pp = fpc.shape[1]

    def _lut(table):
        # static python-int lookup expressed as select arithmetic: index maps
        # may not capture traced constants, only combine grid indices with
        # python scalars
        def sel(g):
            idx = table[0]
            for i, v in enumerate(table[1:], start=1):
                idx = jnp.where(g == i, v, idx)
            return idx
        return sel

    qsel, csel = _lut(qmap), _lut(cmap)
    grid = (G, Qp // bq, Pp // bp, mp // bm)
    cnt, sw = pl.pallas_call(
        _fields_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, bm), lambda g, q, p, mi: (qsel(g), q, mi)),
            pl.BlockSpec((1, bq, bm), lambda g, q, p, mi: (qsel(g), q, mi)),
            pl.BlockSpec((1, bp, bm), lambda g, q, p, mi: (csel(g), p, mi)),
            pl.BlockSpec((1, bp, bm), lambda g, q, p, mi: (csel(g), p, mi)),
        ],
        out_specs=[pl.BlockSpec((1, bq, bp),
                                lambda g, q, p, mi: (g, q, p))] * 2,
        out_shape=[jax.ShapeDtypeStruct((G, Qp, Pp), jnp.float32)] * 2,
        interpret=interpret,
    )(fq.astype(jnp.int32), vq.astype(jnp.float32),
      fpc.astype(jnp.int32), vc.astype(jnp.float32))
    return cnt[:, :Q, :P], sw[:, :Q, :P]


def _fields_packed_kernel(fq_ref, vq_ref, fc_ref, wc_ref, cnt_ref, sw_ref):
    m_idx = pl.program_id(3)
    # decode the corpus value tile in VMEM: [bp, bm//2] i32 -> [bp, bm] f32.
    # The decode is exact (bf16 -> f32), so the tile is bitwise equal to the
    # unpacked-roundtripped corpus tile and _mvm_body reduces identically.
    vc = unpack_halfwords_f32(wc_ref[0, :, :])
    cnt, sw = _mvm_body(fq_ref[0, :, :], vq_ref[0, :, :], fc_ref[0, :, :], vc)

    @pl.when(m_idx == 0)
    def _init():
        cnt_ref[0, :, :] = cnt
        sw_ref[0, :, :] = sw

    @pl.when(m_idx != 0)
    def _acc():
        cnt_ref[0, :, :] = cnt_ref[0, :, :] + cnt
        sw_ref[0, :, :] = sw_ref[0, :, :] + sw


@functools.partial(jax.jit, static_argnames=("qmap", "cmap", "bq", "bp", "bm",
                                             "interpret"))
def estimate_fields_packed_pallas(fq, vq, fpc, wc, *, qmap, cmap, bq: int = 8,
                                  bp: int = 128, bm: int = 128,
                                  interpret: bool = True):
    """:func:`estimate_fields_pallas` over a bit-packed corpus value plane.

    Identical contract except the corpus values arrive packed: ``wc`` is
    ``[C, P, m // 2]`` i32 bf16-halfword words (see
    :mod:`repro.kernels.packed`) instead of ``vc [C, P, m]`` f32, and the
    kernel decodes each ``[bp, bm // 2]`` tile to ``[bp, bm]`` in VMEM --
    the f32 plane never exists in HBM.  ``m`` and ``bm`` must be even
    (odd-m families pad one inert sample at pack time).  Zero words decode
    to value 0.0 and spare rows keep sentinel fingerprints, so the packed
    layout inherits the inert-spare-row invariant unchanged.
    """
    qmap = tuple(int(i) for i in qmap)
    cmap = tuple(int(i) for i in cmap)
    if len(qmap) != len(cmap):
        raise ValueError("qmap/cmap length mismatch")
    if not qmap:
        raise ValueError("qmap/cmap must name at least one field pair")
    G = len(qmap)
    F, Q, m = fq.shape
    C, P, mw = wc.shape
    if m % 2 or bm % 2:
        raise ValueError(f"packed estimate needs even m and bm; got "
                         f"m={m}, bm={bm}")
    if fpc.shape[2] != m or 2 * mw != m:
        raise ValueError(f"packed corpus {(fpc.shape[2], 2 * mw)} does not "
                         f"match query m={m}")
    if min(qmap) < 0 or max(qmap) >= F or min(cmap) < 0 or max(cmap) >= C:
        raise ValueError("field map index out of range")
    q_pad = (-Q) % bq
    p_pad = (-P) % bp
    m_pad = (-m) % bm           # even: m and bm are both even
    if q_pad or m_pad:
        fq = jnp.pad(fq, ((0, 0), (0, q_pad), (0, m_pad)),
                     constant_values=QUERY_PAD_FP)
        vq = jnp.pad(vq, ((0, 0), (0, q_pad), (0, m_pad)))
    if p_pad or m_pad:
        fpc = jnp.pad(fpc, ((0, 0), (0, p_pad), (0, m_pad)),
                      constant_values=CORPUS_PAD_FP)
        # zero words decode to value 0.0 -- the same inert fill the
        # unpacked path pads vc with
        wc = jnp.pad(wc, ((0, 0), (0, p_pad), (0, m_pad // 2)))
    Qp, mp = fq.shape[1:]
    Pp = fpc.shape[1]

    def _lut(table):
        # static lookup via select arithmetic, as estimate_fields_pallas
        def sel(g):
            idx = table[0]
            for i, v in enumerate(table[1:], start=1):
                idx = jnp.where(g == i, v, idx)
            return idx
        return sel

    qsel, csel = _lut(qmap), _lut(cmap)
    grid = (G, Qp // bq, Pp // bp, mp // bm)
    cnt, sw = pl.pallas_call(
        _fields_packed_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, bm), lambda g, q, p, mi: (qsel(g), q, mi)),
            pl.BlockSpec((1, bq, bm), lambda g, q, p, mi: (qsel(g), q, mi)),
            pl.BlockSpec((1, bp, bm), lambda g, q, p, mi: (csel(g), p, mi)),
            pl.BlockSpec((1, bp, bm // 2),
                         lambda g, q, p, mi: (csel(g), p, mi)),
        ],
        out_specs=[pl.BlockSpec((1, bq, bp),
                                lambda g, q, p, mi: (g, q, p))] * 2,
        out_shape=[jax.ShapeDtypeStruct((G, Qp, Pp), jnp.float32)] * 2,
        interpret=interpret,
    )(fq.astype(jnp.int32), vq.astype(jnp.float32),
      fpc.astype(jnp.int32), wc.astype(jnp.int32))
    return cnt[:, :Q, :P], sw[:, :Q, :P]


# ---------------------------------------------------------------------------
# Linear-family estimation: per-rep sketch dots as MXU matmuls
# ---------------------------------------------------------------------------
def _linear_fields_kernel(tq_ref, tc_ref, out_ref):
    w_idx = pl.program_id(3)
    a = tq_ref[0, :, 0, :]                                    # [BQ, BW]
    b = tc_ref[0, :, 0, :]                                    # [BP, BW]
    tile = jax.lax.dot_general(
        a, b, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)                   # [BQ, BP]

    @pl.when(w_idx == 0)
    def _init():
        out_ref[0, 0, :, :] = tile

    @pl.when(w_idx != 0)
    def _acc():
        out_ref[0, 0, :, :] = out_ref[0, 0, :, :] + tile


@functools.partial(jax.jit, static_argnames=("qmap", "cmap", "bq", "bp", "bw",
                                             "interpret"))
def linear_estimate_fields_pallas(tq, tc, *, qmap, cmap, bq: int = 8,
                                  bp: int = 128, bw: int = 128,
                                  interpret: bool = True):
    """Fused multi-field per-rep linear-sketch dots in ONE kernel launch;
    matches :func:`repro.kernels.ref.linear_estimate_fields_ref`.

    Args:
      tq: [F, Q, R, W] per-field query tables (JL: R = 1, W = m).
      tc: [C, P, R, W] per-field corpus tables.
      qmap/cmap: static same-length tuples of field indices, exactly as
        :func:`estimate_fields_pallas`.
    Returns [G, R, Q, P] f32 per-rep inner products: each ``[BQ, BW] @
    [BW, BP]`` tile is MXU work, accumulated over the (innermost) W grid
    dimension.  The (pair, rep) axes fold into the leading grid dimension
    the same way the ICWS fields kernel folds its pair list, so all G * R
    dot matrices of a dataset-search batch run as a single launch.  The
    median-of-reps (CS) / squeeze (JL) epilogue belongs to the caller.

    Zero padding is inert everywhere: padded W lanes add 0 to every dot,
    and padded Q/P rows only produce extra output rows that are sliced off
    -- per-(q, p) results are bitwise independent of Q, P, and row padding.
    """
    qmap = tuple(int(i) for i in qmap)
    cmap = tuple(int(i) for i in cmap)
    if len(qmap) != len(cmap):
        raise ValueError("qmap/cmap length mismatch")
    if not qmap:
        raise ValueError("qmap/cmap must name at least one field pair")
    G = len(qmap)
    F, Q, R, W = tq.shape
    C, P, Rc, Wc = tc.shape
    if (R, W) != (Rc, Wc):
        raise ValueError(f"query tables {(R, W)} do not match corpus "
                         f"tables {(Rc, Wc)}")
    if min(qmap) < 0 or max(qmap) >= F or min(cmap) < 0 or max(cmap) >= C:
        raise ValueError("field map index out of range")
    q_pad = (-Q) % bq
    p_pad = (-P) % bp
    w_pad = (-W) % bw
    if q_pad or w_pad:
        tq = jnp.pad(tq, ((0, 0), (0, q_pad), (0, 0), (0, w_pad)))
    if p_pad or w_pad:
        tc = jnp.pad(tc, ((0, 0), (0, p_pad), (0, 0), (0, w_pad)))
    Qp, Pp, Wp = Q + q_pad, P + p_pad, W + w_pad

    def _lut(table):
        # static lookup via select arithmetic, as estimate_fields_pallas
        def sel(g):
            idx = table[0]
            for i, v in enumerate(table[1:], start=1):
                idx = jnp.where(g == i, v, idx)
            return idx
        return sel

    qsel, csel = _lut(qmap), _lut(cmap)
    # (pair g, rep r) fold into the leading grid dim: gr = g * R + r
    grid = (G * R, Qp // bq, Pp // bp, Wp // bw)
    out = pl.pallas_call(
        _linear_fields_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, 1, bw),
                         lambda gr, q, p, wi: (qsel(gr // R), q, gr % R, wi)),
            pl.BlockSpec((1, bp, 1, bw),
                         lambda gr, q, p, wi: (csel(gr // R), p, gr % R, wi)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, bp),
                               lambda gr, q, p, wi: (gr // R, gr % R, q, p)),
        out_shape=jax.ShapeDtypeStruct((G, R, Qp, Pp), jnp.float32),
        interpret=interpret,
    )(tq.astype(jnp.float32), tc.astype(jnp.float32))
    return out[:, :, :Q, :P]


def _linear_fields_packed_kernel(tq_ref, wc_ref, out_ref):
    w_idx = pl.program_id(3)
    a = tq_ref[0, :, 0, :]                                    # [BQ, BW]
    b = unpack_halfwords_f32(wc_ref[0, :, 0, :])              # [BP, BW]
    tile = jax.lax.dot_general(
        a, b, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)                   # [BQ, BP]

    @pl.when(w_idx == 0)
    def _init():
        out_ref[0, 0, :, :] = tile

    @pl.when(w_idx != 0)
    def _acc():
        out_ref[0, 0, :, :] = out_ref[0, 0, :, :] + tile


@functools.partial(jax.jit, static_argnames=("qmap", "cmap", "bq", "bp", "bw",
                                             "interpret"))
def linear_estimate_fields_packed_pallas(tq, wc, *, qmap, cmap, bq: int = 8,
                                         bp: int = 128, bw: int = 128,
                                         interpret: bool = True):
    """:func:`linear_estimate_fields_pallas` over bf16-halfword corpus tables.

    ``wc`` is ``[C, P, R, W // 2]`` i32 packed words in place of the f32
    ``tc [C, P, R, W]``; each corpus tile decodes in VMEM before the MXU
    dot.  ``W`` and ``bw`` must be even (odd widths gain one zero column at
    pack time -- inert under the dot, exactly like zero W-padding).
    """
    qmap = tuple(int(i) for i in qmap)
    cmap = tuple(int(i) for i in cmap)
    if len(qmap) != len(cmap):
        raise ValueError("qmap/cmap length mismatch")
    if not qmap:
        raise ValueError("qmap/cmap must name at least one field pair")
    G = len(qmap)
    F, Q, R, W = tq.shape
    C, P, Rc, Ww = wc.shape
    if W % 2 or bw % 2:
        raise ValueError(f"packed linear estimate needs even W and bw; got "
                         f"W={W}, bw={bw}")
    if (R, W) != (Rc, 2 * Ww):
        raise ValueError(f"query tables {(R, W)} do not match packed corpus "
                         f"tables {(Rc, 2 * Ww)}")
    if min(qmap) < 0 or max(qmap) >= F or min(cmap) < 0 or max(cmap) >= C:
        raise ValueError("field map index out of range")
    q_pad = (-Q) % bq
    p_pad = (-P) % bp
    w_pad = (-W) % bw           # even: W and bw are both even
    if q_pad or w_pad:
        tq = jnp.pad(tq, ((0, 0), (0, q_pad), (0, 0), (0, w_pad)))
    if p_pad or w_pad:
        wc = jnp.pad(wc, ((0, 0), (0, p_pad), (0, 0), (0, w_pad // 2)))
    Qp, Pp, Wp = Q + q_pad, P + p_pad, W + w_pad

    def _lut(table):
        # static lookup via select arithmetic, as estimate_fields_pallas
        def sel(g):
            idx = table[0]
            for i, v in enumerate(table[1:], start=1):
                idx = jnp.where(g == i, v, idx)
            return idx
        return sel

    qsel, csel = _lut(qmap), _lut(cmap)
    grid = (G * R, Qp // bq, Pp // bp, Wp // bw)
    out = pl.pallas_call(
        _linear_fields_packed_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, 1, bw),
                         lambda gr, q, p, wi: (qsel(gr // R), q, gr % R, wi)),
            pl.BlockSpec((1, bp, 1, bw // 2),
                         lambda gr, q, p, wi: (csel(gr // R), p, gr % R, wi)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, bp),
                               lambda gr, q, p, wi: (gr // R, gr % R, q, p)),
        out_shape=jax.ShapeDtypeStruct((G, R, Qp, Pp), jnp.float32),
        interpret=interpret,
    )(tq.astype(jnp.float32), wc.astype(jnp.int32))
    return out[:, :, :Q, :P]
