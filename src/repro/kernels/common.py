"""Shared in-kernel utilities: 32-bit mixing RNG and uniform generation.

TPU vector units have native uint32 arithmetic (full-width low product), so
all in-kernel pseudo-randomness is built from murmur3-style finalizers over
``uint32`` lanes -- no 64-bit emulation, no host round-trips.  These run both
inside Pallas kernel bodies and in plain jnp (the ref oracles use the same
functions so kernel-vs-ref comparisons are bit-exact).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

_M1 = 0x85EBCA6B
_M2 = 0xC2B2AE35
_GOLDEN = 0x9E3779B9

# ---------------------------------------------------------------------------
# The u32 salt-stream registry: every independent hash draw any kernel makes
# gets a named ``*_STREAM`` constant HERE (device side) with an identically
# named, identically valued host twin in ``repro.core`` (u32.py for ICWS,
# linear.py for CS/JL, sampling.py for TS/PS -- those packages stay
# numpy-only and never import this module).  Stream IDs must be globally
# unique: two draws sharing an ID share their randomness, which silently
# correlates sketches that the estimators assume independent.  Uniqueness,
# host/device mirroring, and literal-free call sites are machine-checked by
# ``python -m repro.analysis`` (rules SR001-SR006); the generated STREAMS.md
# at the repo root is the human-readable registry table.
# ---------------------------------------------------------------------------

# ICWS (weighted MinHash): per-(sample, key) variates r ~ Gamma(2,1) from
# two uniforms, c ~ Gamma(2,1) from two more, beta ~ U(0,1), plus the
# (key, level) fingerprint salt.
ICWS_R1_STREAM = 1
ICWS_R2_STREAM = 2
ICWS_C1_STREAM = 3
ICWS_C2_STREAM = 4
ICWS_BETA_STREAM = 5
ICWS_FP_STREAM = 9
# linear-sketch kernels: CountSketch buckets/signs (shared between the dense
# gradient-compression kernel and the sparse corpus-ingest kernel so
# position- and key-sketched vectors interoperate) and JL signs.
CS_BUCKET_STREAM = 21
CS_SIGN_STREAM = 22
JL_SIGN_STREAM = 31
# coordinated sample hash h(key) of the TS/PS sampling sketches (one draw
# per key, shared across vectors -- repro.core.sampling mirrors this)
SAMPLE_HASH_STREAM = 41
# DMH (densified one-permutation weighted MinHash, arXiv:1602.08393 /
# 1703.04664): one bin draw per key, ICWS-style variates drawn at
# sample-index t = bin (so within-bin ranks follow the exact weighted
# MinHash law), a (key, level)-salted fingerprint per bin, and the
# 2-universal reseeded probe stream of optimal densification (one draw per
# (empty bin, attempt) pair -- repro.core.dmh mirrors all of these).
DMH_BIN_STREAM = 51
DMH_R1_STREAM = 52
DMH_R2_STREAM = 53
DMH_C1_STREAM = 54
DMH_C2_STREAM = 55
DMH_BETA_STREAM = 56
DMH_FP_STREAM = 57
DMH_DENSIFY_STREAM = 58


def densify_probes(m: int) -> int:
    """Probe budget of the DMH densification epilogue (lane-multiple).
    Mirrored bit for bit by ``repro.core.dmh.densify_probes`` -- the host
    oracle and the kernel must probe identically or borrowed fingerprints
    stop colliding across the host/device boundary."""
    return min(1024, 128 * -(-4 * int(m) // 128))


def streams() -> dict:
    """The enumerated stream registry: ``{name: id}`` for every ``*_STREAM``
    constant above (runtime view of what ``repro.analysis`` reads from the
    AST)."""
    return {k: v for k, v in sorted(globals().items())
            if k.endswith("_STREAM") and isinstance(v, int)}


def mix32(x: jnp.ndarray) -> jnp.ndarray:
    """Murmur3 fmix32: high-quality 32-bit mixer (bijective)."""
    z = x.astype(jnp.uint32)
    z = z ^ (z >> jnp.uint32(16))
    z = z * jnp.uint32(_M1)
    z = z ^ (z >> jnp.uint32(13))
    z = z * jnp.uint32(_M2)
    z = z ^ (z >> jnp.uint32(16))
    return z


def hash_u32(key: jnp.ndarray, salt: jnp.ndarray) -> jnp.ndarray:
    """Mix key with a salt (two rounds; inputs broadcast)."""
    k = key.astype(jnp.uint32)
    s = jnp.asarray(salt).astype(jnp.uint32)
    return mix32(mix32(k + s * jnp.uint32(_GOLDEN))
                 ^ (s * jnp.uint32(_M2) + jnp.uint32(0x27D4EB2F)))


def uniform01(key: jnp.ndarray, salt) -> jnp.ndarray:
    """Strictly-interior uniform (0,1) f32 from a 32-bit hash.

    Uses the top 24 bits => values in [2^-25, 1 - 2^-25]; logs are safe.
    """
    bits = hash_u32(key, salt) >> jnp.uint32(8)          # 24 random bits
    return bits.astype(jnp.float32) * jnp.float32(2 ** -24) + jnp.float32(2 ** -25)


def salt_for(seed: int, stream: int, t: jnp.ndarray) -> jnp.ndarray:
    """Combine (seed, stream, sample-index t) into a salt array."""
    base = jnp.uint32(seed & 0xFFFFFFFF) * jnp.uint32(0x9E3779B1) \
        + jnp.uint32(stream) * jnp.uint32(0x517CC1B7)
    return base + t.astype(jnp.uint32) * jnp.uint32(0x2545F491)
