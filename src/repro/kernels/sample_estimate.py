"""Pallas TPU kernel: key-match estimation for sampling sketches (TS/PS).

The sampling families (:mod:`repro.core.sampling`) store fixed-slot rows

    ``(key [m] i32, val [m] f32, tau [] f32)``

where the keys of a row are an importance *sample* of the vector's support.
Unlike ICWS rows, slots are NOT aligned: query slot t and corpus slot u
refer to the same coordinate iff their keys are equal, wherever they sit.
The estimate for a (query, corpus-row) pair is therefore a full key-equality
contraction over the ``m x m`` slot pairs,

    ``est[q, p] = sum_{t,u} 1[kq[q,t] == kc[p,u]] * vq[q,t] * vc[p,u]
                            / min(pq[q,t], pc[p,u])``

with inverse-inclusion-probability weights ``p = min(1, m * v^2 / tau)``
(``tau <= 0`` means probability 1; see the ops-layer epilogue
:func:`sample_inclusion_probs`).  This is a third estimator geometry for
the kernel layer: not slot-aligned collision counting (ICWS), not dense
MXU dots (CS/JL), but an unaligned sparse join expressed as a blockwise
``[bq*bt x bp*bu]`` equality contraction.

``sample_estimate_fields_pallas`` is the fused multi-field form, mirroring
:func:`repro.kernels.estimate.estimate_fields_pallas`: per-field stacks
``[F, Q, m]`` / ``[C, P, m]`` plus static qmap/cmap field-pair tuples folded
into the leading grid dimension, so all §1.3 field-pair estimates of a
dataset-search batch run as ONE launch.  The grid is
``(G, Q/bq, P/bp, m/bt, m/bu)`` with both *sample* axes tiled and innermost:
the double sum decomposes over (t, u) blocks, so each output block
accumulates across the two inner grid dims exactly as the ICWS kernels
accumulate over m.  VMEM per step is dominated by the ``[bq, bt, bp, bu]``
cross tensor -- 2 MiB f32 at the defaults (8, 64, 8, 128), comfortably
inside the ~16 MiB budget with its where/min temporaries.

Padding reuses the single estimate-kernel sentinel convention
(:mod:`repro.kernels.estimate`): live keys are 31-bit non-negative, query
padding is -1 (also the empty-slot fill of ingested rows), corpus padding
and inert spare store rows are -2, and the ``kq >= 0`` guard keeps all of
them out of the estimate.  Probability 0 marks empty slots (value 0), so
spare rows (zero values, zero tau) estimate to exactly 0.0.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .estimate import CORPUS_PAD_FP, QUERY_PAD_FP

# Sampling rows reuse the estimate kernels' pad convention: empty / padded
# query slots hold -1, corpus padding and spare store rows hold -2.
SAMPLE_QUERY_PAD_KEY = QUERY_PAD_FP
SAMPLE_CORPUS_PAD_KEY = CORPUS_PAD_FP


def sample_inclusion_probs(vals: jnp.ndarray, tau: jnp.ndarray) -> jnp.ndarray:
    """Per-slot inclusion probabilities from the stored sample layout.

    Args: vals ``[..., m]`` f32 sampled values (0 marks an empty slot);
    tau ``[...]`` f32 probability scales.  Returns ``[..., m]`` f32
    ``min(1, m * v^2 / tau)`` with ``tau <= 0`` meaning probability 1 and
    empty slots pinned to probability 0 (the kernel's live-slot guard).
    The f64 host twin is :func:`repro.core.sampling.sample_probs`.
    """
    m = vals.shape[-1]
    v = vals.astype(jnp.float32)
    t = tau.astype(jnp.float32)[..., None]
    num = jnp.float32(m) * v * v
    p = jnp.where(t > 0, jnp.minimum(1.0, num / jnp.where(t > 0, t, 1.0)),
                  1.0)
    return jnp.where(v != 0, p, 0.0)


def _sample_fields_kernel(kq_ref, vq_ref, aq_ref, kc_ref, vc_ref, ac_ref,
                          out_ref):
    t_idx = pl.program_id(3)
    u_idx = pl.program_id(4)

    kq = kq_ref[0][:, :, None, None]          # [bq, bt, 1, 1]
    vq = vq_ref[0][:, :, None, None]
    aq = aq_ref[0][:, :, None, None]
    kc = kc_ref[0][None, None, :, :]          # [1, 1, bp, bu]
    vc = vc_ref[0][None, None, :, :]
    ac = ac_ref[0][None, None, :, :]

    # unaligned key match: the [bq, bt, bp, bu] cross tensor lives only in
    # VMEM for this block; `kq >= 0` guards every pad sentinel and `p > 0`
    # guards empty slots (either side), so pads never divide or match
    p = jnp.minimum(aq, ac)
    live = (kq == kc) & (kq >= 0) & (p > 0)
    term = jnp.where(live, vq * vc / jnp.where(live, p, 1.0), 0.0)
    tile = term.sum(axis=(1, 3))              # [bq, bp]

    @pl.when((t_idx == 0) & (u_idx == 0))
    def _init():
        out_ref[0, :, :] = tile

    @pl.when((t_idx != 0) | (u_idx != 0))
    def _acc():
        out_ref[0, :, :] = out_ref[0, :, :] + tile


@functools.partial(jax.jit, static_argnames=("qmap", "cmap", "bq", "bp",
                                             "bt", "bu", "interpret"))
def sample_estimate_fields_pallas(kq, vq, aq, kc, vc, ac, *, qmap, cmap,
                                  bq: int = 8, bp: int = 8, bt: int = 64,
                                  bu: int = 128, interpret: bool = True):
    """Fused multi-field key-match estimates in ONE kernel launch; matches
    :func:`repro.kernels.ref.sample_estimate_fields_ref`.

    Args:
      kq/vq/aq: [F, Q, m] per-field query sample keys / values / inclusion
        probabilities (see :func:`sample_inclusion_probs`).
      kc/vc/ac: [C, P, m] per-field corpus samples.
      qmap/cmap: static same-length tuples of field indices, exactly as
        :func:`repro.kernels.estimate.estimate_fields_pallas`.
    Returns [G, Q, P] f32 inner-product estimates (no epilogue: the inverse-
    probability weighting happens inside the contraction).

    Per-(q, p) results are bitwise independent of Q/P row padding and of
    the corpus row count: each output element reduces only over its own
    rows' (t, u) slot blocks, in a fixed (bt, bu) grid order.
    """
    qmap = tuple(int(i) for i in qmap)
    cmap = tuple(int(i) for i in cmap)
    if len(qmap) != len(cmap):
        raise ValueError("qmap/cmap length mismatch")
    if not qmap:
        raise ValueError("qmap/cmap must name at least one field pair")
    G = len(qmap)
    F, Q, m = kq.shape
    C, P, mc = kc.shape
    if m != mc:
        raise ValueError(f"query slots {m} do not match corpus slots {mc}")
    if min(qmap) < 0 or max(qmap) >= F or min(cmap) < 0 or max(cmap) >= C:
        raise ValueError("field map index out of range")
    q_pad = (-Q) % bq
    p_pad = (-P) % bp
    t_pad = (-m) % bt
    u_pad = (-m) % bu
    if q_pad or t_pad:
        kq = jnp.pad(kq, ((0, 0), (0, q_pad), (0, t_pad)),
                     constant_values=SAMPLE_QUERY_PAD_KEY)
        vq = jnp.pad(vq, ((0, 0), (0, q_pad), (0, t_pad)))
        aq = jnp.pad(aq, ((0, 0), (0, q_pad), (0, t_pad)))
    if p_pad or u_pad:
        kc = jnp.pad(kc, ((0, 0), (0, p_pad), (0, u_pad)),
                     constant_values=SAMPLE_CORPUS_PAD_KEY)
        vc = jnp.pad(vc, ((0, 0), (0, p_pad), (0, u_pad)))
        ac = jnp.pad(ac, ((0, 0), (0, p_pad), (0, u_pad)))
    Qp, mt = kq.shape[1:]
    Pp, mu = kc.shape[1:]

    def _lut(table):
        # static python-int lookup via select arithmetic, exactly as
        # estimate_fields_pallas: index maps may not capture traced values
        def sel(g):
            idx = table[0]
            for i, v in enumerate(table[1:], start=1):
                idx = jnp.where(g == i, v, idx)
            return idx
        return sel

    qsel, csel = _lut(qmap), _lut(cmap)
    grid = (G, Qp // bq, Pp // bp, mt // bt, mu // bu)
    out = pl.pallas_call(
        _sample_fields_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, bt),
                         lambda g, q, p, t, u: (qsel(g), q, t)),
            pl.BlockSpec((1, bq, bt),
                         lambda g, q, p, t, u: (qsel(g), q, t)),
            pl.BlockSpec((1, bq, bt),
                         lambda g, q, p, t, u: (qsel(g), q, t)),
            pl.BlockSpec((1, bp, bu),
                         lambda g, q, p, t, u: (csel(g), p, u)),
            pl.BlockSpec((1, bp, bu),
                         lambda g, q, p, t, u: (csel(g), p, u)),
            pl.BlockSpec((1, bp, bu),
                         lambda g, q, p, t, u: (csel(g), p, u)),
        ],
        out_specs=pl.BlockSpec((1, bq, bp),
                               lambda g, q, p, t, u: (g, q, p)),
        out_shape=jax.ShapeDtypeStruct((G, Qp, Pp), jnp.float32),
        interpret=interpret,
    )(kq.astype(jnp.int32), vq.astype(jnp.float32), aq.astype(jnp.float32),
      kc.astype(jnp.int32), vc.astype(jnp.float32), ac.astype(jnp.float32))
    return out[:, :Q, :P]
