"""Pallas TPU kernel: key-match estimation for sampling sketches (TS/PS).

The sampling families (:mod:`repro.core.sampling`) store fixed-slot rows

    ``(key [m] i32, val [m] f32, tau [] f32)``

where the keys of a row are an importance *sample* of the vector's support.
Unlike ICWS rows, slots are NOT aligned: query slot t and corpus slot u
refer to the same coordinate iff their keys are equal, wherever they sit.
The estimate for a (query, corpus-row) pair is therefore a full key-equality
contraction over the ``m x m`` slot pairs,

    ``est[q, p] = sum_{t,u} 1[kq[q,t] == kc[p,u]] * vq[q,t] * vc[p,u]
                            / min(pq[q,t], pc[p,u])``

with inverse-inclusion-probability weights ``p = min(1, m * v^2 / tau)``
(``tau <= 0`` means probability 1; see the ops-layer epilogue
:func:`sample_inclusion_probs`).  This is a third estimator geometry for
the kernel layer: not slot-aligned collision counting (ICWS), not dense
MXU dots (CS/JL), but an unaligned sparse join expressed as a blockwise
``[bq*bt x bp*bu]`` equality contraction.

``sample_estimate_fields_pallas`` is the fused multi-field form, mirroring
:func:`repro.kernels.estimate.estimate_fields_pallas`: per-field stacks
``[F, Q, m]`` / ``[C, P, m]`` plus static qmap/cmap field-pair tuples folded
into the leading grid dimension, so all §1.3 field-pair estimates of a
dataset-search batch run as ONE launch.  The grid is
``(G, Q/bq, P/bp, m/bt, m/bu)`` with both *sample* axes tiled and innermost:
the double sum decomposes over (t, u) blocks, so each output block
accumulates across the two inner grid dims exactly as the ICWS kernels
accumulate over m.  VMEM per step is dominated by the ``[bq, bt, bp, bu]``
cross tensor -- 2 MiB f32 at the defaults (8, 64, 8, 128), comfortably
inside the ~16 MiB budget with its where/min temporaries.

Padding reuses the single estimate-kernel sentinel convention
(:mod:`repro.kernels.estimate`): live keys are 31-bit non-negative, query
padding is -1 (also the empty-slot fill of ingested rows), corpus padding
and inert spare store rows are -2, and the ``kq >= 0`` guard keeps all of
them out of the estimate.  Probability 0 marks empty slots (value 0), so
spare rows (zero values, zero tau) estimate to exactly 0.0.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .estimate import CORPUS_PAD_FP, QUERY_PAD_FP
from .packed import unpack_halfwords_f32

# Sampling rows reuse the estimate kernels' pad convention: empty / padded
# query slots hold -1, corpus padding and spare store rows hold -2.
SAMPLE_QUERY_PAD_KEY = QUERY_PAD_FP
SAMPLE_CORPUS_PAD_KEY = CORPUS_PAD_FP


def _inclusion_probs(v: jnp.ndarray, t: jnp.ndarray, m: int) -> jnp.ndarray:
    """Elementwise inclusion-probability core shared by the host-side
    prologue and the packed kernel's in-VMEM recompute -- a single
    definition so the two paths are bitwise identical by construction.
    ``v`` f32 values, ``t`` f32 taus broadcastable against ``v``, ``m`` the
    static slot count of the sketch scheme (NOT a padded tile width).
    """
    num = jnp.float32(m) * v * v
    p = jnp.where(t > 0, jnp.minimum(1.0, num / jnp.where(t > 0, t, 1.0)),
                  1.0)
    return jnp.where(v != 0, p, 0.0)


def sample_inclusion_probs(vals: jnp.ndarray, tau: jnp.ndarray) -> jnp.ndarray:
    """Per-slot inclusion probabilities from the stored sample layout.

    Args: vals ``[..., m]`` f32 sampled values (0 marks an empty slot);
    tau ``[...]`` f32 probability scales.  Returns ``[..., m]`` f32
    ``min(1, m * v^2 / tau)`` with ``tau <= 0`` meaning probability 1 and
    empty slots pinned to probability 0 (the kernel's live-slot guard).
    The f64 host twin is :func:`repro.core.sampling.sample_probs`.
    """
    return _inclusion_probs(vals.astype(jnp.float32),
                            tau.astype(jnp.float32)[..., None],
                            vals.shape[-1])


def _sample_fields_kernel(kq_ref, vq_ref, aq_ref, kc_ref, vc_ref, ac_ref,
                          out_ref):
    t_idx = pl.program_id(3)
    u_idx = pl.program_id(4)

    kq = kq_ref[0][:, :, None, None]          # [bq, bt, 1, 1]
    vq = vq_ref[0][:, :, None, None]
    aq = aq_ref[0][:, :, None, None]
    kc = kc_ref[0][None, None, :, :]          # [1, 1, bp, bu]
    vc = vc_ref[0][None, None, :, :]
    ac = ac_ref[0][None, None, :, :]

    # unaligned key match: the [bq, bt, bp, bu] cross tensor lives only in
    # VMEM for this block; `kq >= 0` guards every pad sentinel and `p > 0`
    # guards empty slots (either side), so pads never divide or match
    p = jnp.minimum(aq, ac)
    live = (kq == kc) & (kq >= 0) & (p > 0)
    term = jnp.where(live, vq * vc / jnp.where(live, p, 1.0), 0.0)
    tile = term.sum(axis=(1, 3))              # [bq, bp]

    @pl.when((t_idx == 0) & (u_idx == 0))
    def _init():
        out_ref[0, :, :] = tile

    @pl.when((t_idx != 0) | (u_idx != 0))
    def _acc():
        out_ref[0, :, :] = out_ref[0, :, :] + tile


@functools.partial(jax.jit, static_argnames=("qmap", "cmap", "bq", "bp",
                                             "bt", "bu", "interpret"))
def sample_estimate_fields_pallas(kq, vq, aq, kc, vc, ac, *, qmap, cmap,
                                  bq: int = 8, bp: int = 8, bt: int = 64,
                                  bu: int = 128, interpret: bool = True):
    """Fused multi-field key-match estimates in ONE kernel launch; matches
    :func:`repro.kernels.ref.sample_estimate_fields_ref`.

    Args:
      kq/vq/aq: [F, Q, m] per-field query sample keys / values / inclusion
        probabilities (see :func:`sample_inclusion_probs`).
      kc/vc/ac: [C, P, m] per-field corpus samples.
      qmap/cmap: static same-length tuples of field indices, exactly as
        :func:`repro.kernels.estimate.estimate_fields_pallas`.
    Returns [G, Q, P] f32 inner-product estimates (no epilogue: the inverse-
    probability weighting happens inside the contraction).

    Per-(q, p) results are bitwise independent of Q/P row padding and of
    the corpus row count: each output element reduces only over its own
    rows' (t, u) slot blocks, in a fixed (bt, bu) grid order.
    """
    qmap = tuple(int(i) for i in qmap)
    cmap = tuple(int(i) for i in cmap)
    if len(qmap) != len(cmap):
        raise ValueError("qmap/cmap length mismatch")
    if not qmap:
        raise ValueError("qmap/cmap must name at least one field pair")
    G = len(qmap)
    F, Q, m = kq.shape
    C, P, mc = kc.shape
    if m != mc:
        raise ValueError(f"query slots {m} do not match corpus slots {mc}")
    if min(qmap) < 0 or max(qmap) >= F or min(cmap) < 0 or max(cmap) >= C:
        raise ValueError("field map index out of range")
    q_pad = (-Q) % bq
    p_pad = (-P) % bp
    t_pad = (-m) % bt
    u_pad = (-m) % bu
    if q_pad or t_pad:
        kq = jnp.pad(kq, ((0, 0), (0, q_pad), (0, t_pad)),
                     constant_values=SAMPLE_QUERY_PAD_KEY)
        vq = jnp.pad(vq, ((0, 0), (0, q_pad), (0, t_pad)))
        aq = jnp.pad(aq, ((0, 0), (0, q_pad), (0, t_pad)))
    if p_pad or u_pad:
        kc = jnp.pad(kc, ((0, 0), (0, p_pad), (0, u_pad)),
                     constant_values=SAMPLE_CORPUS_PAD_KEY)
        vc = jnp.pad(vc, ((0, 0), (0, p_pad), (0, u_pad)))
        ac = jnp.pad(ac, ((0, 0), (0, p_pad), (0, u_pad)))
    Qp, mt = kq.shape[1:]
    Pp, mu = kc.shape[1:]

    def _lut(table):
        # static python-int lookup via select arithmetic, exactly as
        # estimate_fields_pallas: index maps may not capture traced values
        def sel(g):
            idx = table[0]
            for i, v in enumerate(table[1:], start=1):
                idx = jnp.where(g == i, v, idx)
            return idx
        return sel

    qsel, csel = _lut(qmap), _lut(cmap)
    grid = (G, Qp // bq, Pp // bp, mt // bt, mu // bu)
    out = pl.pallas_call(
        _sample_fields_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, bt),
                         lambda g, q, p, t, u: (qsel(g), q, t)),
            pl.BlockSpec((1, bq, bt),
                         lambda g, q, p, t, u: (qsel(g), q, t)),
            pl.BlockSpec((1, bq, bt),
                         lambda g, q, p, t, u: (qsel(g), q, t)),
            pl.BlockSpec((1, bp, bu),
                         lambda g, q, p, t, u: (csel(g), p, u)),
            pl.BlockSpec((1, bp, bu),
                         lambda g, q, p, t, u: (csel(g), p, u)),
            pl.BlockSpec((1, bp, bu),
                         lambda g, q, p, t, u: (csel(g), p, u)),
        ],
        out_specs=pl.BlockSpec((1, bq, bp),
                               lambda g, q, p, t, u: (g, q, p)),
        out_shape=jax.ShapeDtypeStruct((G, Qp, Pp), jnp.float32),
        interpret=interpret,
    )(kq.astype(jnp.int32), vq.astype(jnp.float32), aq.astype(jnp.float32),
      kc.astype(jnp.int32), vc.astype(jnp.float32), ac.astype(jnp.float32))
    return out[:, :Q, :P]


def _sample_fields_packed_kernel(kq_ref, vq_ref, aq_ref, kc_ref, wc_ref,
                                 tc_ref, out_ref, *, s_total):
    t_idx = pl.program_id(3)
    u_idx = pl.program_id(4)

    kq = kq_ref[0][:, :, None, None]          # [bq, bt, 1, 1]
    vq = vq_ref[0][:, :, None, None]
    aq = aq_ref[0][:, :, None, None]
    # decode the corpus value tile and recompute its inclusion probabilities
    # in VMEM from the per-row tau block -- the f32 value and probability
    # planes never exist in HBM.  _inclusion_probs with the static scheme
    # slot count s_total is the same elementwise expression the unpacked
    # path applies host-side, so the tiles match bitwise; pad slots (zero
    # words -> value 0) land on probability 0 exactly as zero-padded ac.
    vc2 = unpack_halfwords_f32(wc_ref[0])     # [bp, bu]
    ac2 = _inclusion_probs(vc2, tc_ref[0][:, None], s_total)
    kc = kc_ref[0][None, None, :, :]          # [1, 1, bp, bu]
    vc = vc2[None, None, :, :]
    ac = ac2[None, None, :, :]

    p = jnp.minimum(aq, ac)
    live = (kq == kc) & (kq >= 0) & (p > 0)
    term = jnp.where(live, vq * vc / jnp.where(live, p, 1.0), 0.0)
    tile = term.sum(axis=(1, 3))              # [bq, bp]

    @pl.when((t_idx == 0) & (u_idx == 0))
    def _init():
        out_ref[0, :, :] = tile

    @pl.when((t_idx != 0) | (u_idx != 0))
    def _acc():
        out_ref[0, :, :] = out_ref[0, :, :] + tile


@functools.partial(jax.jit, static_argnames=("s_total", "qmap", "cmap", "bq",
                                             "bp", "bt", "bu", "interpret"))
def sample_estimate_fields_packed_pallas(kq, vq, aq, kc, wc, tc, *, s_total,
                                         qmap, cmap, bq: int = 8,
                                         bp: int = 8, bt: int = 64,
                                         bu: int = 128,
                                         interpret: bool = True):
    """:func:`sample_estimate_fields_pallas` over a bit-packed corpus.

    The corpus side arrives as stored: keys ``kc [C, P, S]`` i32, values
    packed as bf16-halfword words ``wc [C, P, S // 2]`` i32 (see
    :mod:`repro.kernels.packed`), and per-row taus ``tc [C, P]`` f32.
    Corpus inclusion probabilities are recomputed inside the kernel from
    the decoded tile and ``tc`` -- no ``ac`` plane is materialized.
    ``s_total`` is the static slot count of the sketch scheme (the ``m``
    of :func:`sample_inclusion_probs`), which may differ from the stored
    slot dim ``S`` when an odd slot count gained one inert pad slot at
    pack time; ``S`` and ``bu`` must be even.  Query slots are independent
    of corpus slots, exactly as in the unpacked kernel.
    """
    qmap = tuple(int(i) for i in qmap)
    cmap = tuple(int(i) for i in cmap)
    if len(qmap) != len(cmap):
        raise ValueError("qmap/cmap length mismatch")
    if not qmap:
        raise ValueError("qmap/cmap must name at least one field pair")
    G = len(qmap)
    F, Q, m = kq.shape
    C, P, S = kc.shape
    if S % 2 or bu % 2:
        raise ValueError(f"packed sample estimate needs even corpus slots "
                         f"and bu; got S={S}, bu={bu}")
    if 2 * wc.shape[2] != S:
        raise ValueError(f"packed words {wc.shape[2]} do not match corpus "
                         f"slots {S}")
    if min(qmap) < 0 or max(qmap) >= F or min(cmap) < 0 or max(cmap) >= C:
        raise ValueError("field map index out of range")
    q_pad = (-Q) % bq
    p_pad = (-P) % bp
    t_pad = (-m) % bt
    u_pad = (-S) % bu           # even: S and bu are both even
    if q_pad or t_pad:
        kq = jnp.pad(kq, ((0, 0), (0, q_pad), (0, t_pad)),
                     constant_values=SAMPLE_QUERY_PAD_KEY)
        vq = jnp.pad(vq, ((0, 0), (0, q_pad), (0, t_pad)))
        aq = jnp.pad(aq, ((0, 0), (0, q_pad), (0, t_pad)))
    if p_pad or u_pad:
        kc = jnp.pad(kc, ((0, 0), (0, p_pad), (0, u_pad)),
                     constant_values=SAMPLE_CORPUS_PAD_KEY)
        # zero words decode to value 0 -> probability 0: inert, like ac pad
        wc = jnp.pad(wc, ((0, 0), (0, p_pad), (0, u_pad // 2)))
        tc = jnp.pad(tc, ((0, 0), (0, p_pad)))
    Qp, mt = kq.shape[1:]
    Pp, mu = kc.shape[1:]

    def _lut(table):
        # static python-int lookup via select arithmetic, exactly as
        # estimate_fields_pallas: index maps may not capture traced values
        def sel(g):
            idx = table[0]
            for i, v in enumerate(table[1:], start=1):
                idx = jnp.where(g == i, v, idx)
            return idx
        return sel

    qsel, csel = _lut(qmap), _lut(cmap)
    grid = (G, Qp // bq, Pp // bp, mt // bt, mu // bu)
    out = pl.pallas_call(
        functools.partial(_sample_fields_packed_kernel, s_total=int(s_total)),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, bt),
                         lambda g, q, p, t, u: (qsel(g), q, t)),
            pl.BlockSpec((1, bq, bt),
                         lambda g, q, p, t, u: (qsel(g), q, t)),
            pl.BlockSpec((1, bq, bt),
                         lambda g, q, p, t, u: (qsel(g), q, t)),
            pl.BlockSpec((1, bp, bu),
                         lambda g, q, p, t, u: (csel(g), p, u)),
            pl.BlockSpec((1, bp, bu // 2),
                         lambda g, q, p, t, u: (csel(g), p, u)),
            pl.BlockSpec((1, bp),
                         lambda g, q, p, t, u: (csel(g), p)),
        ],
        out_specs=pl.BlockSpec((1, bq, bp),
                               lambda g, q, p, t, u: (g, q, p)),
        out_shape=jax.ShapeDtypeStruct((G, Qp, Pp), jnp.float32),
        interpret=interpret,
    )(kq.astype(jnp.int32), vq.astype(jnp.float32), aq.astype(jnp.float32),
      kc.astype(jnp.int32), wc.astype(jnp.int32), tc.astype(jnp.float32))
    return out[:, :Q, :P]
