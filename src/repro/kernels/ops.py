"""jit'd public wrappers around the Pallas kernels.

Dispatch policy: on TPU backends the compiled kernels run natively; on CPU
(this container) they run under ``interpret=True`` -- same kernel body,
executed by the Pallas interpreter -- and every op is validated against the
pure-jnp oracles in :mod:`repro.kernels.ref`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref
from .countsketch import countsketch_pallas
from .estimate import estimate_one_vs_many_pallas, estimate_partials_pallas
from .icws_sketch import icws_sketch_pallas


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def icws_sketch(w, keys, vals, *, m: int, seed: int = 0):
    """Device ICWS sketch of padded sparse batch.  [B,N] -> (fp, val, amin) [B,m]."""
    return icws_sketch_pallas(w, keys, vals, m=m, seed=seed,
                              interpret=_interpret())


def countsketch(x, *, width: int, reps: int = 5, seed: int = 0, offset: int = 0):
    """CountSketch table [reps, width] of a dense vector."""
    return countsketch_pallas(x, width=width, reps=reps, seed=seed,
                              offset=offset, interpret=_interpret())


def countsketch_decode(table, indices, *, seed: int = 0):
    """Unbiased median-of-reps point query (pure jnp: gather-bound, no kernel)."""
    return ref.countsketch_decode_ref(table, indices, seed)


def estimate_partials(fpa, va, fpb, vb):
    """Fused Algorithm-5 partial sums for P sketch pairs."""
    return estimate_partials_pallas(fpa, va, fpb, vb, interpret=_interpret())


def estimate_partials_one_vs_many(fq, vq, fpc, vc):
    """Fused Algorithm-5 partial sums: one query sketch vs a [P, m] corpus."""
    return estimate_one_vs_many_pallas(fq, vq, fpc, vc,
                                       interpret=_interpret())


@functools.partial(jax.jit, static_argnames=())
def icws_estimate(fpa, va, na, fpb, vb, nb):
    """Full ICWS inner-product estimate for P pairs (epilogue in jnp).

    Args: fp [P, m] int32, v [P, m] f32, norms [P] f32.
    """
    m = fpa.shape[1]
    cnt, sw = estimate_partials(fpa, va, fpb, vb)
    j_hat = cnt / m
    m_tilde = 2.0 / (1.0 + j_hat)
    est = na * nb * (m_tilde / m) * sw
    return jnp.where((na == 0) | (nb == 0), 0.0, est)


@functools.partial(jax.jit, static_argnames=())
def icws_estimate_corpus(fq, vq, nq, fpc, vc, nc):
    """ICWS inner-product estimates of one query against a whole corpus.

    Args: fq/vq [1, m] (or [m]) query, nq scalar norm; fpc/vc [P, m] corpus,
    nc [P] norms.  Returns [P] f32 estimates.  The query is broadcast inside
    the kernel -- no [P, m] query tiling is ever materialized.
    """
    m = fpc.shape[1]
    cnt, sw = estimate_partials_one_vs_many(fq, vq, fpc, vc)
    j_hat = cnt / m
    m_tilde = 2.0 / (1.0 + j_hat)
    est = nq * nc * (m_tilde / m) * sw
    return jnp.where((nq == 0) | (nc == 0), 0.0, est)
