"""jit'd public wrappers around the Pallas kernels.

Dispatch policy: on TPU backends the compiled kernels run natively; on CPU
(this container) they run under ``interpret=True`` -- same kernel body,
executed by the Pallas interpreter -- and every op is validated against the
pure-jnp oracles in :mod:`repro.kernels.ref`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref
from .countsketch import countsketch_pallas
from .estimate import (estimate_fields_pallas, estimate_many_vs_many_pallas,
                       estimate_one_vs_many_pallas, estimate_partials_pallas)
from .icws_sketch import icws_sketch_pallas


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def icws_sketch(w, keys, vals, *, m: int, seed: int = 0, row_block: int = 0):
    """Device ICWS sketch of padded sparse batch.  [B,N] -> (fp, val, amin) [B,m].

    ``row_block=0`` auto-picks: large batches (serving micro-batches, lake
    ingest) sketch several rows per grid step; small/single-query launches
    keep the minimal-VMEM one-row tiling.  Results are bitwise identical
    either way.
    """
    if row_block == 0:
        row_block = 4 if w.shape[0] >= 8 else 1
    return icws_sketch_pallas(w, keys, vals, m=m, seed=seed, br=row_block,
                              interpret=_interpret())


def countsketch(x, *, width: int, reps: int = 5, seed: int = 0, offset: int = 0):
    """CountSketch table [reps, width] of a dense vector."""
    return countsketch_pallas(x, width=width, reps=reps, seed=seed,
                              offset=offset, interpret=_interpret())


def countsketch_decode(table, indices, *, seed: int = 0):
    """Unbiased median-of-reps point query (pure jnp: gather-bound, no kernel)."""
    return ref.countsketch_decode_ref(table, indices, seed)


def estimate_partials(fpa, va, fpb, vb):
    """Fused Algorithm-5 partial sums for P sketch pairs."""
    return estimate_partials_pallas(fpa, va, fpb, vb, interpret=_interpret())


def estimate_partials_one_vs_many(fq, vq, fpc, vc):
    """Fused Algorithm-5 partial sums: one query sketch vs a [P, m] corpus."""
    return estimate_one_vs_many_pallas(fq, vq, fpc, vc,
                                       interpret=_interpret())


def estimate_partials_many_vs_many(fq, vq, fpc, vc):
    """Fused Algorithm-5 partial sums: [Q, m] queries vs a [P, m] corpus."""
    return estimate_many_vs_many_pallas(fq, vq, fpc, vc,
                                        interpret=_interpret())


def estimate_partials_fields(fq, vq, fpc, vc, *, qmap, cmap):
    """Fused multi-field partial sums: one launch for all field pairs."""
    return estimate_fields_pallas(fq, vq, fpc, vc, qmap=tuple(qmap),
                                  cmap=tuple(cmap), interpret=_interpret())


@functools.partial(jax.jit, static_argnames=())
def icws_estimate(fpa, va, na, fpb, vb, nb):
    """Full ICWS inner-product estimate for P pairs (epilogue in jnp).

    Args: fp [P, m] int32, v [P, m] f32, norms [P] f32.
    """
    m = fpa.shape[1]
    cnt, sw = estimate_partials(fpa, va, fpb, vb)
    j_hat = cnt / m
    m_tilde = 2.0 / (1.0 + j_hat)
    est = na * nb * (m_tilde / m) * sw
    return jnp.where((na == 0) | (nb == 0), 0.0, est)


@functools.partial(jax.jit, static_argnames=())
def icws_estimate_corpus(fq, vq, nq, fpc, vc, nc):
    """ICWS inner-product estimates of one query against a whole corpus.

    Args: fq/vq [1, m] (or [m]) query, nq scalar norm; fpc/vc [P, m] corpus,
    nc [P] norms.  Returns [P] f32 estimates.  The query is broadcast inside
    the kernel -- no [P, m] query tiling is ever materialized.
    """
    m = fpc.shape[1]
    cnt, sw = estimate_partials_one_vs_many(fq, vq, fpc, vc)
    j_hat = cnt / m
    m_tilde = 2.0 / (1.0 + j_hat)
    est = nq * nc * (m_tilde / m) * sw
    return jnp.where((nq == 0) | (nc == 0), 0.0, est)


@functools.partial(jax.jit, static_argnames=())
def icws_estimate_many(fq, vq, nq, fpc, vc, nc):
    """ICWS inner-product estimates of Q queries against a whole corpus.

    Args: fq/vq [Q, m] queries, nq [Q] norms; fpc/vc [P, m] corpus, nc [P]
    norms.  Returns [Q, P] f32 estimates from ONE many-vs-many kernel launch.
    """
    m = fpc.shape[1]
    cnt, sw = estimate_partials_many_vs_many(fq, vq, fpc, vc)
    j_hat = cnt / m
    m_tilde = 2.0 / (1.0 + j_hat)
    est = nq[:, None] * nc[None, :] * (m_tilde / m) * sw
    return jnp.where((nq[:, None] == 0) | (nc[None, :] == 0), 0.0, est)


@functools.partial(jax.jit, static_argnames=("qmap", "cmap"))
def icws_estimate_fields(fq, vq, nq, fpc, vc, nc, *, qmap, cmap):
    """Fused multi-field ICWS estimates: all field pairs in ONE launch.

    Args: fq/vq [F, Q, m] per-field queries, nq [F, Q] norms; fpc/vc
    [C, P, m] per-field corpus, nc [C, P] norms; qmap/cmap static length-G
    field-pair maps.  Returns [G, Q, P] f32 estimates -- for §1.3 dataset
    search, the six estimate launches of the sequential path collapse into
    this single call.
    """
    m = fpc.shape[2]
    cnt, sw = estimate_partials_fields(fq, vq, fpc, vc, qmap=qmap, cmap=cmap)
    j_hat = cnt / m
    m_tilde = 2.0 / (1.0 + j_hat)
    nqg = jnp.stack([nq[qf] for qf in qmap])[:, :, None]    # [G, Q, 1]
    ncg = jnp.stack([nc[cf] for cf in cmap])[:, None, :]    # [G, 1, P]
    est = nqg * ncg * (m_tilde / m) * sw
    return jnp.where((nqg == 0) | (ncg == 0), 0.0, est)
