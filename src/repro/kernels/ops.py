"""jit'd public wrappers around the Pallas kernels.

Dispatch policy: on TPU backends the compiled kernels run natively; on CPU
(this container) they run under ``interpret=True`` -- same kernel body,
executed by the Pallas interpreter -- and every op is validated against the
pure-jnp oracles in :mod:`repro.kernels.ref`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as PSpec

from repro import compat
from repro import obs as _obs
from repro.roofline import autotune

from . import ref
from .countsketch import countsketch_pallas, countsketch_sparse_pallas
from .estimate import (CORPUS_PAD_FP, QUERY_PAD_FP, estimate_fields_pallas,
                       estimate_fields_packed_pallas,
                       estimate_many_vs_many_pallas,
                       estimate_one_vs_many_pallas, estimate_partials_pallas,
                       linear_estimate_fields_packed_pallas,
                       linear_estimate_fields_pallas)
from .dmh_sketch import dmh_sketch_pallas, dmh_sketch_scatter
from .icws_sketch import icws_sketch_pallas
from .jl_sketch import jl_sketch_pallas
from .sample_estimate import (sample_estimate_fields_packed_pallas,
                              sample_estimate_fields_pallas,
                              sample_inclusion_probs)


def _interpret() -> bool:
    interp = jax.default_backend() != "tpu"
    if _obs.enabled():
        _obs.gauge("ops.interpret_mode").set(float(interp))
    return interp


def _tuned(kernel: str, key, clamp):
    """Autotuned block kwargs for one launch ({} -> the kernel's defaults).

    Resolution happens at trace time on concrete shapes (the wrappers are
    jit'd with static field maps), so the cache file is consulted once per
    traced shape, never per call.  Row-dim blocks are clamped to the
    launch's padded row count (:func:`repro.roofline.autotune.resolve`);
    reduction-dim blocks come back exactly as tuned, keyed only by the
    sketch width, which is what keeps every bitwise ranking identity
    (batched/sequential, sharded/single-device, tenant, packed/unpacked)
    intact under tuning.
    """
    blocks = autotune.resolve(kernel, jax.default_backend(), key, clamp=clamp)
    if _obs.enabled():
        _obs.counter("ops.autotune_resolved_total", kernel=kernel,
                     source="tuned" if blocks else "default").inc()
    return blocks


@_obs.instrumented("icws_sketch")
def icws_sketch(w, keys, vals, *, m: int, seed: int = 0, row_block: int = 0,
                pack_vals: bool = False):
    """Device ICWS sketch of padded sparse batch.
    [B,N] -> (fp, val, amin, argkey) [B,m].

    ``row_block=0`` auto-picks: large batches (serving micro-batches, lake
    ingest) sketch several rows per grid step; small/single-query launches
    keep the minimal-VMEM one-row tiling; a tuned ``icws_sketch`` cache
    entry (keyed by (m, N)) overrides both when present.  Results are
    bitwise identical either way.  ``pack_vals=True`` appends the
    bf16-halfword packed value plane ``[B, (m + m % 2) // 2]`` i32 as a
    fifth output, packed in-kernel (see :func:`icws_sketch_pallas`).
    """
    if row_block == 0:
        row_block = 4 if w.shape[0] >= 8 else 1
    blocks = _tuned("icws_sketch", {"m": m, "N": w.shape[1]},
                    {"br": (w.shape[0], 1)})
    br = blocks.pop("br", row_block)
    return icws_sketch_pallas(w, keys, vals, m=m, seed=seed, br=br,
                              pack_vals=pack_vals, interpret=_interpret(),
                              **blocks)


@_obs.instrumented("dmh_sketch")
def dmh_sketch(w, keys, vals, *, m: int, seed: int = 0, row_block: int = 0,
               pack_vals: bool = False):
    """Device DMH sketch of a padded sparse batch -- same signature and
    ``(fp, val, amin, argkey)`` wire layout as :func:`icws_sketch`, but
    O(nnz + m) per row instead of O(nnz * m): each non-zero is binned once
    and only the per-bin minima are kept (see
    :mod:`repro.kernels.dmh_sketch`).

    The VMEM bin-state width ``bm`` is fixed here to the lane-rounded
    sketch width -- it is a capacity, not a tuning knob, so the autotune
    cache only carries (br, bn).  Results are bitwise identical across all
    block choices.

    Without a compiled Pallas backend the kernel's ``[br, bm, bn]``
    bin-equality cross (free across TPU VPU lanes) would be materialized
    by interpret mode, silently re-inflating DMH to the O(nnz * m) cost it
    exists to avoid -- so the interpret branch dispatches to
    :func:`repro.kernels.dmh_sketch.dmh_sketch_scatter`, the scatter-min
    lowering of the same contract (same winners, same wire layout).
    """
    if _interpret():
        return dmh_sketch_scatter(w, keys, vals, m=m, seed=seed,
                                  pack_vals=pack_vals)
    if row_block == 0:
        row_block = 4 if w.shape[0] >= 8 else 1
    blocks = _tuned("dmh_sketch", {"m": m, "N": w.shape[1]},
                    {"br": (w.shape[0], 1)})
    br = blocks.pop("br", row_block)
    blocks.pop("bm", None)
    bm = 128 * (-(-max(m, 1) // 128))
    return dmh_sketch_pallas(w, keys, vals, m=m, seed=seed, br=br, bm=bm,
                             pack_vals=pack_vals, interpret=_interpret(),
                             **blocks)


@_obs.instrumented("countsketch")
def countsketch(x, *, width: int, reps: int = 5, seed: int = 0, offset: int = 0):
    """CountSketch table [reps, width] of a dense vector."""
    return countsketch_pallas(x, width=width, reps=reps, seed=seed,
                              offset=offset, interpret=_interpret())


@_obs.instrumented("countsketch_decode")
def countsketch_decode(table, indices, *, seed: int = 0):
    """Unbiased median-of-reps point query (pure jnp: gather-bound, no kernel)."""
    return ref.countsketch_decode_ref(table, indices, seed)


@_obs.instrumented("countsketch_sparse")
def countsketch_sparse(keys, vals, *, width: int, reps: int = 5,
                       seed: int = 0):
    """Device CountSketch of a padded sparse batch.  [B, N] -> [B, reps, width]."""
    return countsketch_sparse_pallas(keys, vals, width=width, reps=reps,
                                     seed=seed, interpret=_interpret())


@_obs.instrumented("jl_sketch")
def jl_sketch(keys, vals, *, m: int, seed: int = 0):
    """Device JL projection of a padded sparse batch.  [B, N] -> [B, m]."""
    return jl_sketch_pallas(keys, vals, m=m, seed=seed,
                            interpret=_interpret())


@_obs.instrumented("estimate_partials")
def estimate_partials(fpa, va, fpb, vb):
    """Fused Algorithm-5 partial sums for P sketch pairs."""
    return estimate_partials_pallas(fpa, va, fpb, vb, interpret=_interpret())


@_obs.instrumented("estimate_partials_one_vs_many")
def estimate_partials_one_vs_many(fq, vq, fpc, vc):
    """Fused Algorithm-5 partial sums: one query sketch vs a [P, m] corpus."""
    return estimate_one_vs_many_pallas(fq, vq, fpc, vc,
                                       interpret=_interpret())


@_obs.instrumented("estimate_partials_many_vs_many")
def estimate_partials_many_vs_many(fq, vq, fpc, vc):
    """Fused Algorithm-5 partial sums: [Q, m] queries vs a [P, m] corpus."""
    return estimate_many_vs_many_pallas(fq, vq, fpc, vc,
                                        interpret=_interpret())


@_obs.instrumented("estimate_partials_fields")
def estimate_partials_fields(fq, vq, fpc, vc, *, qmap, cmap):
    """Fused multi-field partial sums: one launch for all field pairs."""
    blocks = _tuned("estimate_fields", {"m": fpc.shape[2]},
                    {"bq": (fq.shape[1], 8), "bp": (fpc.shape[1], 128)})
    return estimate_fields_pallas(fq, vq, fpc, vc, qmap=tuple(qmap),
                                  cmap=tuple(cmap), interpret=_interpret(),
                                  **blocks)


@_obs.instrumented("icws_estimate")
@functools.partial(jax.jit, static_argnames=())
def icws_estimate(fpa, va, na, fpb, vb, nb):
    """Full ICWS inner-product estimate for P pairs (epilogue in jnp).

    Args: fp [P, m] int32, v [P, m] f32, norms [P] f32.
    """
    m = fpa.shape[1]
    cnt, sw = estimate_partials(fpa, va, fpb, vb)
    j_hat = cnt / m
    m_tilde = 2.0 / (1.0 + j_hat)
    est = na * nb * (m_tilde / m) * sw
    return jnp.where((na == 0) | (nb == 0), 0.0, est)


@_obs.instrumented("icws_estimate_corpus")
@functools.partial(jax.jit, static_argnames=())
def icws_estimate_corpus(fq, vq, nq, fpc, vc, nc):
    """ICWS inner-product estimates of one query against a whole corpus.

    Args: fq/vq [1, m] (or [m]) query, nq scalar norm; fpc/vc [P, m] corpus,
    nc [P] norms.  Returns [P] f32 estimates.  The query is broadcast inside
    the kernel -- no [P, m] query tiling is ever materialized.
    """
    m = fpc.shape[1]
    cnt, sw = estimate_partials_one_vs_many(fq, vq, fpc, vc)
    j_hat = cnt / m
    m_tilde = 2.0 / (1.0 + j_hat)
    est = nq * nc * (m_tilde / m) * sw
    return jnp.where((nq == 0) | (nc == 0), 0.0, est)


@_obs.instrumented("icws_estimate_many")
@functools.partial(jax.jit, static_argnames=())
def icws_estimate_many(fq, vq, nq, fpc, vc, nc):
    """ICWS inner-product estimates of Q queries against a whole corpus.

    Args: fq/vq [Q, m] queries, nq [Q] norms; fpc/vc [P, m] corpus, nc [P]
    norms.  Returns [Q, P] f32 estimates from ONE many-vs-many kernel launch.
    """
    m = fpc.shape[1]
    cnt, sw = estimate_partials_many_vs_many(fq, vq, fpc, vc)
    j_hat = cnt / m
    m_tilde = 2.0 / (1.0 + j_hat)
    est = nq[:, None] * nc[None, :] * (m_tilde / m) * sw
    return jnp.where((nq[:, None] == 0) | (nc[None, :] == 0), 0.0, est)


@_obs.instrumented("icws_estimate_corpus_stacked")
@jax.jit
def icws_estimate_corpus_stacked(fq, vq, nq, fpb, vb, nb):
    """One query vs field 0 of stacked ``[1, cap, m]`` store buffers.

    The field slice happens inside jit, so no standalone ``[cap, m]`` copy
    of the corpus is materialized outside the launch.  Unused capacity rows
    (pad-sentinel fingerprints, zero norms) estimate to zero -- callers
    slice the result to the live row count.
    """
    return icws_estimate_corpus(fq, vq, nq, fpb[0], vb[0], nb[0])


@_obs.instrumented("icws_estimate_many_stacked")
@jax.jit
def icws_estimate_many_stacked(fq, vq, nq, fpb, vb, nb):
    """Q queries vs field 0 of stacked ``[1, cap, m]`` store buffers."""
    return icws_estimate_many(fq, vq, nq, fpb[0], vb[0], nb[0])


@_obs.instrumented("linear_estimate_fields")
@functools.partial(jax.jit, static_argnames=("qmap", "cmap"))
def linear_estimate_fields(tq, tc, *, qmap, cmap):
    """Fused multi-field linear-sketch estimates: all field pairs, ONE launch.

    Args: tq [F, Q, R, W] per-field query tables, tc [C, P, R, W] per-field
    corpus tables (JL: R = 1, W = m); qmap/cmap static length-G field-pair
    maps.  Returns [G, Q, P] f32 estimates: per-rep MXU dot products from
    :func:`linear_estimate_fields_pallas`, then the unbiasing epilogue --
    the median over repetitions (for R = 1 the median IS the single dot, so
    JL and CS share this one wrapper).  Zero rows (empty sketches, spare
    store capacity, padding) estimate to zero with no sentinel machinery.
    """
    blocks = _tuned("linear_estimate_fields", {"W": tq.shape[3]},
                    {"bq": (tq.shape[1], 8), "bp": (tc.shape[1], 128)})
    dots = linear_estimate_fields_pallas(tq, tc, qmap=qmap, cmap=cmap,
                                         interpret=_interpret(), **blocks)
    return jnp.median(dots, axis=1)


@_obs.instrumented("icws_estimate_fields")
@functools.partial(jax.jit, static_argnames=("qmap", "cmap"))
def icws_estimate_fields(fq, vq, nq, fpc, vc, nc, *, qmap, cmap):
    """Fused multi-field ICWS estimates: all field pairs in ONE launch.

    Args: fq/vq [F, Q, m] per-field queries, nq [F, Q] norms; fpc/vc
    [C, P, m] per-field corpus, nc [C, P] norms; qmap/cmap static length-G
    field-pair maps.  Returns [G, Q, P] f32 estimates -- for §1.3 dataset
    search, the six estimate launches of the sequential path collapse into
    this single call.
    """
    m = fpc.shape[2]
    cnt, sw = estimate_partials_fields(fq, vq, fpc, vc, qmap=qmap, cmap=cmap)
    j_hat = cnt / m
    m_tilde = 2.0 / (1.0 + j_hat)
    nqg = jnp.stack([nq[qf] for qf in qmap])[:, :, None]    # [G, Q, 1]
    ncg = jnp.stack([nc[cf] for cf in cmap])[:, None, :]    # [G, 1, P]
    est = nqg * ncg * (m_tilde / m) * sw
    return jnp.where((nqg == 0) | (ncg == 0), 0.0, est)


@_obs.instrumented("sample_estimate_fields")
@functools.partial(jax.jit, static_argnames=("qmap", "cmap"))
def sample_estimate_fields(kq, vq, tq, kc, vc, tc, *, qmap, cmap):
    """Fused multi-field sampling-sketch (TS/PS) estimates, ONE launch.

    Args: kq/vq [F, Q, m] per-field query sample keys/values, tq [F, Q]
    probability scales; kc/vc [C, P, m] / tc [C, P] corpus samples;
    qmap/cmap static length-G field-pair maps.  Returns [G, Q, P] f32
    inverse-inclusion-probability estimates from the key-match kernel --
    the probabilities ``min(1, m * v^2 / tau)`` are reconstructed here
    (elementwise prologue) so the stored layout stays (key, val, tau).
    """
    aq = sample_inclusion_probs(vq, tq)
    ac = sample_inclusion_probs(vc, tc)
    blocks = _tuned("sample_estimate_fields", {"S": kq.shape[2]},
                    {"bq": (kq.shape[1], 8), "bp": (kc.shape[1], 8)})
    return sample_estimate_fields_pallas(kq, vq, aq, kc, vc, ac,
                                         qmap=qmap, cmap=cmap,
                                         interpret=_interpret(), **blocks)


# ---------------------------------------------------------------------------
# packed-corpus estimation: the store's bit-packed buffers, decoded in-kernel
# ---------------------------------------------------------------------------
# Each wrapper mirrors its unpacked twin exactly -- same epilogue, same true
# sketch width in every formula -- with the corpus value plane arriving as
# bf16-halfword words (see repro.kernels.packed).  Queries are sketched
# fresh per request and stay unpacked; when the stored width gained an
# inert pad slot (odd m rounded up to even at pack time), the query is
# padded here with the standard sentinels, which the kernel guards already
# treat as dead.  Block sizes resolve from the same autotune cache entries
# as the unpacked path (widths even-normalized in the cache key), so packed
# and unpacked launches always share a reduction order -- the packed
# estimates are bitwise equal to the unpacked path run on
# family.unpack_rows(family.pack_rows(rows)).

@_obs.instrumented("icws_estimate_fields_packed")
@functools.partial(jax.jit, static_argnames=("qmap", "cmap"))
def icws_estimate_fields_packed(fq, vq, nq, fpc, wc, nc, *, qmap, cmap):
    """Packed-corpus :func:`icws_estimate_fields`: fpc ``[C, P, me]`` i32
    fingerprints, wc ``[C, P, me // 2]`` i32 packed values (me = m rounded
    up to even), nc ``[C, P]`` norms.  Returns [G, Q, P] f32."""
    m = fq.shape[2]
    me = fpc.shape[2]
    if me != m:
        fq = jnp.pad(fq, ((0, 0), (0, 0), (0, me - m)),
                     constant_values=QUERY_PAD_FP)
        vq = jnp.pad(vq, ((0, 0), (0, 0), (0, me - m)))
    blocks = _tuned("estimate_fields", {"m": me},
                    {"bq": (fq.shape[1], 8), "bp": (fpc.shape[1], 128)})
    cnt, sw = estimate_fields_packed_pallas(fq, vq, fpc, wc,
                                            qmap=tuple(qmap),
                                            cmap=tuple(cmap),
                                            interpret=_interpret(), **blocks)
    # epilogue over the TRUE sample count m, not the even-padded width:
    # the pad slot never collides, so cnt/sw match the unpacked launch
    j_hat = cnt / m
    m_tilde = 2.0 / (1.0 + j_hat)
    nqg = jnp.stack([nq[qf] for qf in qmap])[:, :, None]    # [G, Q, 1]
    ncg = jnp.stack([nc[cf] for cf in cmap])[:, None, :]    # [G, 1, P]
    est = nqg * ncg * (m_tilde / m) * sw
    return jnp.where((nqg == 0) | (ncg == 0), 0.0, est)


@_obs.instrumented("linear_estimate_fields_packed")
@functools.partial(jax.jit, static_argnames=("qmap", "cmap"))
def linear_estimate_fields_packed(tq, wc, *, qmap, cmap):
    """Packed-corpus :func:`linear_estimate_fields`: wc ``[C, P, R,
    We // 2]`` i32 packed tables (We = W rounded up to even).  The query
    gains zero columns for the pad width -- inert under the dot."""
    W = tq.shape[3]
    We = 2 * wc.shape[3]
    if We != W:
        tq = jnp.pad(tq, ((0, 0), (0, 0), (0, 0), (0, We - W)))
    blocks = _tuned("linear_estimate_fields", {"W": We},
                    {"bq": (tq.shape[1], 8), "bp": (wc.shape[1], 128)})
    dots = linear_estimate_fields_packed_pallas(tq, wc, qmap=qmap, cmap=cmap,
                                                interpret=_interpret(),
                                                **blocks)
    return jnp.median(dots, axis=1)


@_obs.instrumented("sample_estimate_fields_packed")
@functools.partial(jax.jit, static_argnames=("qmap", "cmap"))
def sample_estimate_fields_packed(kq, vq, tq, kc, wc, tc, *, qmap, cmap):
    """Packed-corpus :func:`sample_estimate_fields`: kc ``[C, P, Se]`` i32
    keys, wc ``[C, P, Se // 2]`` i32 packed values (Se = slots rounded up
    to even), tc ``[C, P]`` taus.  Corpus inclusion probabilities are
    recomputed in-kernel from the decoded tile and tau, with the TRUE slot
    count (the query's) in the formula -- the pad slot decodes to value 0
    and lands on probability 0, exactly like zero-padded ``ac``."""
    aq = sample_inclusion_probs(vq, tq)
    s_total = kq.shape[2]
    blocks = _tuned("sample_estimate_fields", {"S": s_total},
                    {"bq": (kq.shape[1], 8), "bp": (kc.shape[1], 8)})
    return sample_estimate_fields_packed_pallas(kq, vq, aq, kc, wc, tc,
                                                s_total=s_total,
                                                qmap=qmap, cmap=cmap,
                                                interpret=_interpret(),
                                                **blocks)


# ---------------------------------------------------------------------------
# sharded query execution: corpus rows spread over a mesh axis
# ---------------------------------------------------------------------------
# Each shard runs the same jitted estimate launch on its slice of the corpus
# rows with the queries replicated; because every corpus row's estimate is
# independent of every other row (the kernels reduce only over the sample
# axis m, with identical block sizes on any row count), the concatenated
# per-shard results are bitwise identical to the single-device launch.

def _pad_corpus_rows(x, pad: int, axis: int, value=0):
    if not pad:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


# The shard_map-transformed callables are built once per (mesh, axis, ...)
# and cached: rebuilding the closure per call would change the transformed
# function's identity and defeat jax's tracing cache on the serving hot
# path -- exactly the per-launch overhead the batched engine amortizes.

@functools.lru_cache(maxsize=None)
def _many_sharded_fn(mesh, axis: str):
    def body(fq, vq, nq, fpb, vb, nb):
        return icws_estimate_many(fq, vq, nq, fpb[0], vb[0], nb[0])

    return compat.shard_map(
        body, mesh=mesh,
        in_specs=(PSpec(), PSpec(), PSpec(),
                  PSpec(None, axis), PSpec(None, axis), PSpec(None, axis)),
        out_specs=PSpec(None, axis))


@_obs.instrumented("icws_estimate_many_sharded")
def icws_estimate_many_sharded(fq, vq, nq, fpb, vb, nb, *, mesh, axis="data"):
    """Sharded :func:`icws_estimate_many_stacked`: Q queries vs an F=1 store
    whose corpus rows are split over mesh axis ``axis``.

    Queries replicate; corpus buffers shard along their row dim (padded with
    inert rows to a multiple of the axis size).  Returns ``[Q, cap]`` f32,
    bitwise identical to the single-device launch.
    """
    d = mesh.shape[axis]
    cap = fpb.shape[1]
    pad = (-cap) % d
    fpb = _pad_corpus_rows(fpb, pad, 1, CORPUS_PAD_FP)
    vb = _pad_corpus_rows(vb, pad, 1)
    nb = _pad_corpus_rows(nb, pad, 1)
    f = _many_sharded_fn(mesh, axis)
    return f(fq, vq, nq, fpb, vb, nb)[:, :cap]


@functools.lru_cache(maxsize=None)
def _fields_sharded_fn(mesh, axis: str, qmap, cmap):
    def body(fq, vq, nq, fpc, vc, nc):
        return icws_estimate_fields(fq, vq, nq, fpc, vc, nc,
                                    qmap=qmap, cmap=cmap)

    return compat.shard_map(
        body, mesh=mesh,
        in_specs=(PSpec(), PSpec(), PSpec(),
                  PSpec(None, axis), PSpec(None, axis), PSpec(None, axis)),
        out_specs=PSpec(None, None, axis))


@_obs.instrumented("icws_estimate_fields_sharded")
def icws_estimate_fields_sharded(fq, vq, nq, fpc, vc, nc, *, qmap, cmap,
                                 mesh, axis="data"):
    """Sharded :func:`icws_estimate_fields`: the fused multi-field launch
    runs per shard over corpus rows split along mesh axis ``axis``.

    Args as :func:`icws_estimate_fields` (corpus ``[C, P, m]`` may be
    full-capacity store buffers).  Returns ``[G, Q, P]`` f32, bitwise
    identical to the single-device launch.
    """
    d = mesh.shape[axis]
    cap = fpc.shape[1]
    pad = (-cap) % d
    fpc = _pad_corpus_rows(fpc, pad, 1, CORPUS_PAD_FP)
    vc = _pad_corpus_rows(vc, pad, 1)
    nc = _pad_corpus_rows(nc, pad, 1)
    f = _fields_sharded_fn(mesh, axis, tuple(qmap), tuple(cmap))
    return f(fq, vq, nq, fpc, vc, nc)[:, :, :cap]


@functools.lru_cache(maxsize=None)
def _linear_fields_sharded_fn(mesh, axis: str, qmap, cmap):
    def body(tq, tc):
        return linear_estimate_fields(tq, tc, qmap=qmap, cmap=cmap)

    return compat.shard_map(
        body, mesh=mesh,
        in_specs=(PSpec(), PSpec(None, axis, None, None)),
        out_specs=PSpec(None, None, axis))


@_obs.instrumented("linear_estimate_fields_sharded")
def linear_estimate_fields_sharded(tq, tc, *, qmap, cmap, mesh, axis="data"):
    """Sharded :func:`linear_estimate_fields`: per-shard launches over
    corpus rows split along mesh axis ``axis``, queries replicated.

    Returns ``[G, Q, P]`` f32, bitwise identical to the single-device
    launch: each (q, p) dot depends only on row p's table, rows pad with
    zeros (inert for linear sketches), and the median epilogue is
    elementwise over the rep axis inside each shard.
    """
    d = mesh.shape[axis]
    cap = tc.shape[1]
    pad = (-cap) % d
    tc = _pad_corpus_rows(tc, pad, 1)
    f = _linear_fields_sharded_fn(mesh, axis, tuple(qmap), tuple(cmap))
    return f(tq, tc)[:, :, :cap]


@functools.lru_cache(maxsize=None)
def _sample_fields_sharded_fn(mesh, axis: str, qmap, cmap):
    def body(kq, vq, tq, kc, vc, tc):
        return sample_estimate_fields(kq, vq, tq, kc, vc, tc,
                                      qmap=qmap, cmap=cmap)

    return compat.shard_map(
        body, mesh=mesh,
        in_specs=(PSpec(), PSpec(), PSpec(),
                  PSpec(None, axis), PSpec(None, axis), PSpec(None, axis)),
        out_specs=PSpec(None, None, axis))


@_obs.instrumented("sample_estimate_fields_sharded")
def sample_estimate_fields_sharded(kq, vq, tq, kc, vc, tc, *, qmap, cmap,
                                   mesh, axis="data"):
    """Sharded :func:`sample_estimate_fields`: the fused key-match launch
    runs per shard over corpus rows split along mesh axis ``axis``, queries
    replicated.  Returns ``[G, Q, P]`` f32, bitwise identical to the
    single-device launch: each (q, p) estimate reduces only over row p's
    slot blocks, rows pad with corpus-pad-sentinel keys / zero values /
    zero tau (inert under the kernel's guards), and the (bt, bu) block
    reduction order is independent of the per-shard row count.
    """
    d = mesh.shape[axis]
    cap = kc.shape[1]
    pad = (-cap) % d
    kc = _pad_corpus_rows(kc, pad, 1, CORPUS_PAD_FP)
    vc = _pad_corpus_rows(vc, pad, 1)
    tc = _pad_corpus_rows(tc, pad, 1)
    f = _sample_fields_sharded_fn(mesh, axis, tuple(qmap), tuple(cmap))
    return f(kq, vq, tq, kc, vc, tc)[:, :, :cap]


# Packed sharded twins: identical row-sharding scheme to the unpacked
# wrappers above (queries replicated, corpus rows split and padded with
# inert spare-row fills -- sentinel keys/fingerprints, zero words, zero
# norms/taus).  Per-shard launches resolve the SAME autotune cache entry
# as the single-device launch (the key holds only the sketch width), so
# the reduction order matches and the concatenated results stay bitwise
# identical to the unsharded packed launch.

@functools.lru_cache(maxsize=None)
def _fields_packed_sharded_fn(mesh, axis: str, qmap, cmap):
    def body(fq, vq, nq, fpc, wc, nc):
        return icws_estimate_fields_packed(fq, vq, nq, fpc, wc, nc,
                                           qmap=qmap, cmap=cmap)

    return compat.shard_map(
        body, mesh=mesh,
        in_specs=(PSpec(), PSpec(), PSpec(),
                  PSpec(None, axis), PSpec(None, axis), PSpec(None, axis)),
        out_specs=PSpec(None, None, axis))


@_obs.instrumented("icws_estimate_fields_packed_sharded")
def icws_estimate_fields_packed_sharded(fq, vq, nq, fpc, wc, nc, *, qmap,
                                        cmap, mesh, axis="data"):
    """Sharded :func:`icws_estimate_fields_packed`; returns ``[G, Q, cap]``
    f32, bitwise identical to the single-device packed launch."""
    d = mesh.shape[axis]
    cap = fpc.shape[1]
    pad = (-cap) % d
    fpc = _pad_corpus_rows(fpc, pad, 1, CORPUS_PAD_FP)
    wc = _pad_corpus_rows(wc, pad, 1)
    nc = _pad_corpus_rows(nc, pad, 1)
    f = _fields_packed_sharded_fn(mesh, axis, tuple(qmap), tuple(cmap))
    return f(fq, vq, nq, fpc, wc, nc)[:, :, :cap]


@functools.lru_cache(maxsize=None)
def _linear_fields_packed_sharded_fn(mesh, axis: str, qmap, cmap):
    def body(tq, wc):
        return linear_estimate_fields_packed(tq, wc, qmap=qmap, cmap=cmap)

    return compat.shard_map(
        body, mesh=mesh,
        in_specs=(PSpec(), PSpec(None, axis, None, None)),
        out_specs=PSpec(None, None, axis))


@_obs.instrumented("linear_estimate_fields_packed_sharded")
def linear_estimate_fields_packed_sharded(tq, wc, *, qmap, cmap, mesh,
                                          axis="data"):
    """Sharded :func:`linear_estimate_fields_packed`; zero words decode to
    zero tables, so row padding stays inert exactly as unpacked."""
    d = mesh.shape[axis]
    cap = wc.shape[1]
    pad = (-cap) % d
    wc = _pad_corpus_rows(wc, pad, 1)
    f = _linear_fields_packed_sharded_fn(mesh, axis, tuple(qmap), tuple(cmap))
    return f(tq, wc)[:, :, :cap]


@functools.lru_cache(maxsize=None)
def _sample_fields_packed_sharded_fn(mesh, axis: str, qmap, cmap):
    def body(kq, vq, tq, kc, wc, tc):
        return sample_estimate_fields_packed(kq, vq, tq, kc, wc, tc,
                                             qmap=qmap, cmap=cmap)

    return compat.shard_map(
        body, mesh=mesh,
        in_specs=(PSpec(), PSpec(), PSpec(),
                  PSpec(None, axis), PSpec(None, axis), PSpec(None, axis)),
        out_specs=PSpec(None, None, axis))


@_obs.instrumented("sample_estimate_fields_packed_sharded")
def sample_estimate_fields_packed_sharded(kq, vq, tq, kc, wc, tc, *, qmap,
                                          cmap, mesh, axis="data"):
    """Sharded :func:`sample_estimate_fields_packed`; pad rows carry
    sentinel keys / zero words / zero tau, inert under the kernel guards."""
    d = mesh.shape[axis]
    cap = kc.shape[1]
    pad = (-cap) % d
    kc = _pad_corpus_rows(kc, pad, 1, CORPUS_PAD_FP)
    wc = _pad_corpus_rows(wc, pad, 1)
    tc = _pad_corpus_rows(tc, pad, 1)
    f = _sample_fields_packed_sharded_fn(mesh, axis, tuple(qmap), tuple(cmap))
    return f(kq, vq, tq, kc, wc, tc)[:, :, :cap]


@_obs.instrumented("sharded_top_k")
def sharded_top_k(score, k: int, *, mesh, axis="data"):
    """Per-shard top-k over the last dim of ``score``, merged globally.

    Bitwise identical -- values AND indices -- to ``jax.lax.top_k(score,
    k)``: ``top_k`` breaks score ties by ascending index, each shard's
    candidate list keeps ascending global indices within equal scores, and
    the merge concatenates shards in index order, so the global re-``top_k``
    resolves ties exactly as the unsharded call does.  Any global top-k row
    must be in its own shard's top-k (rows ranked above it locally are
    ranked above it globally), so per-shard k candidates always suffice.
    """
    d = mesh.shape[axis]
    n = score.shape[-1]
    pad = (-n) % d
    # pad below every real score (the ranking floor is -1), never selected
    score = _pad_corpus_rows(score, pad, score.ndim - 1, -jnp.inf)
    shard = score.shape[-1] // d
    kl = min(k, shard)
    f = _sharded_topk_fn(mesh, axis, kl, shard, score.ndim)
    vals, idx = f(score)
    v, pos = jax.lax.top_k(vals, k)
    return v, jnp.take_along_axis(idx, pos, axis=-1)


@functools.lru_cache(maxsize=None)
def _sharded_topk_fn(mesh, axis: str, kl: int, shard: int, ndim: int):
    def body(s):
        v, i = jax.lax.top_k(s, kl)
        return v, i + jax.lax.axis_index(axis) * shard

    spec = PSpec(*([None] * (ndim - 1) + [axis]))
    return compat.shard_map(body, mesh=mesh, in_specs=(spec,),
                            out_specs=(spec, spec))
