"""Pure-jnp oracles for every Pallas kernel in this package.

Each function computes exactly what the corresponding kernel computes
(same RNG from :mod:`repro.kernels.common`, same masking, same reduction
order semantics where it matters), with no tiling.  Tests assert
``allclose(kernel(interpret=True), ref)`` across shape/dtype sweeps.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import (CS_BUCKET_STREAM, CS_SIGN_STREAM, DMH_BETA_STREAM,
                     DMH_BIN_STREAM, DMH_C1_STREAM, DMH_C2_STREAM,
                     DMH_DENSIFY_STREAM, DMH_FP_STREAM, DMH_R1_STREAM,
                     DMH_R2_STREAM, ICWS_BETA_STREAM, ICWS_C1_STREAM,
                     ICWS_C2_STREAM, ICWS_FP_STREAM, ICWS_R1_STREAM,
                     ICWS_R2_STREAM, JL_SIGN_STREAM, densify_probes,
                     hash_u32, salt_for, uniform01)

BIG = 3.0e38  # python float: safe to close over in kernel bodies


# ---------------------------------------------------------------------------
# ICWS sketch  (Ioffe Consistent Weighted Sampling; see repro.core.icws)
# ---------------------------------------------------------------------------
def icws_sketch_ref(w, keys, vals, m: int, seed: int):
    """Reference ICWS sketch of a batch of padded sparse vectors.

    Args:
      w:    [B, N] f32 weights (normalized squared values); 0 => padding.
      keys: [B, N] int32 original vector indices (ignored where w == 0).
      vals: [B, N] f32 signed normalized values.
      m:    number of samples.
      seed: RNG seed.
    Returns:
      fp   [B, m] int32 fingerprints of (key, level, t); -1 for empty inputs,
      val  [B, m] f32 sampled signed values,
      amin [B, m] f32 the minimizing ICWS hash values,
      argkey [B, m] int32 winning original indices (0 for empty inputs) --
      the sidecar that lets the merge path re-level samples under a new norm.
    """
    B, N = w.shape
    t = jnp.arange(m, dtype=jnp.int32)                       # [m]
    kk = keys.astype(jnp.uint32)[:, None, :]                 # [B, 1, N]

    def u(stream):
        salt = salt_for(seed, stream, t)[None, :, None]      # [1, m, 1]
        return uniform01(kk, salt)                           # [B, m, N]

    r = -jnp.log(u(ICWS_R1_STREAM) * u(ICWS_R2_STREAM))
    c = -jnp.log(u(ICWS_C1_STREAM) * u(ICWS_C2_STREAM))
    beta = u(ICWS_BETA_STREAM)
    logw = jnp.log(jnp.maximum(w, 1e-37))[:, None, :]        # [B, 1, N]
    lvl = jnp.floor(logw / r + beta)
    y = jnp.exp(r * (lvl - beta))
    a = c / (y * jnp.exp(r))
    mask = (w > 0)[:, None, :]
    a = jnp.where(mask, a, BIG)

    arg = jnp.argmin(a, axis=2)                              # [B, m]
    amin = jnp.take_along_axis(a, arg[:, :, None], axis=2)[:, :, 0]
    key_sel = jnp.take_along_axis(keys, arg.astype(jnp.int32), axis=1)  # [B, m]
    lvl_sel = jnp.take_along_axis(lvl, arg[:, :, None], axis=2)[:, :, 0]
    val_sel = jnp.take_along_axis(vals, arg.astype(jnp.int32), axis=1)

    fpbits = hash_u32(
        key_sel.astype(jnp.uint32)
        ^ (lvl_sel.astype(jnp.int32).astype(jnp.uint32) * jnp.uint32(0x9E3779B9)),
        salt_for(seed, ICWS_FP_STREAM, t)[None, :])
    # 31-bit fingerprint: keeps int32 values non-negative so the estimator's
    # `fp >= 0` empty-sentinel guard never discards real collisions
    fp = (fpbits & jnp.uint32(0x7FFFFFFF)).astype(jnp.int32)
    nonempty = jnp.any(w > 0, axis=1)[:, None]
    fp = jnp.where(nonempty, fp, -1)
    val_sel = jnp.where(nonempty, val_sel, 0.0)
    key_sel = jnp.where(nonempty, key_sel, 0)
    return fp, val_sel, jnp.where(nonempty, amin, BIG), key_sel


# ---------------------------------------------------------------------------
# DMH sketch  (densified one-permutation weighted MinHash; repro.core.dmh)
# ---------------------------------------------------------------------------
def dmh_sketch_ref(w, keys, vals, m: int, seed: int):
    """Reference DMH sketch of a batch of padded sparse vectors.

    Args / returns exactly as :func:`icws_sketch_ref` (same wire layout),
    but each non-zero is binned into one sample ``t = h(key) mod m`` and
    scored by ICWS variates drawn at that single t; empty bins borrow from
    occupied ones through the reseeded densification probes.  ``amin`` of
    a borrowed bin is its source bin's minimum (< BIG marks it live).
    """
    B, N = w.shape
    kk = keys.astype(jnp.uint32)
    bin_salt = salt_for(seed, DMH_BIN_STREAM, jnp.uint32(0))
    bins = (hash_u32(kk, bin_salt) % jnp.uint32(m)).astype(jnp.int32)

    def u(stream):
        return uniform01(kk, salt_for(seed, stream, bins))    # [B, N]

    r = -jnp.log(u(DMH_R1_STREAM) * u(DMH_R2_STREAM))
    c = -jnp.log(u(DMH_C1_STREAM) * u(DMH_C2_STREAM))
    beta = u(DMH_BETA_STREAM)
    logw = jnp.log(jnp.maximum(w, 1e-37))
    lvl = jnp.floor(logw / r + beta)
    y = jnp.exp(r * (lvl - beta))
    a = c / (y * jnp.exp(r))
    a = jnp.where(w > 0, a, BIG)

    t = jnp.arange(m, dtype=jnp.int32)
    am = jnp.where(bins[:, None, :] == t[None, :, None],
                   a[:, None, :], BIG)                        # [B, m, N]
    arg = jnp.argmin(am, axis=2)                              # [B, m]
    amin = jnp.min(am, axis=2)
    key_sel = jnp.take_along_axis(keys, arg, axis=1)
    lvl_sel = jnp.take_along_axis(lvl, arg, axis=1)
    val_sel = jnp.take_along_axis(vals, arg, axis=1)

    fpbits = hash_u32(
        key_sel.astype(jnp.uint32)
        ^ (lvl_sel.astype(jnp.int32).astype(jnp.uint32)
           * jnp.uint32(0x9E3779B9)),
        salt_for(seed, DMH_FP_STREAM, t)[None, :])
    fp = (fpbits & jnp.uint32(0x7FFFFFFF)).astype(jnp.int32)

    # densification: first probe h(t; j) mod m landing on an occupied bin;
    # all-miss falls back to the first occupied bin (repro.core.dmh)
    occ = amin < BIG                                          # [B, m]
    J = densify_probes(m)
    js = jnp.arange(J, dtype=jnp.int32)
    psalt = salt_for(seed, DMH_DENSIFY_STREAM, js)
    src = (hash_u32(t[:, None].astype(jnp.uint32), psalt[None, :])
           % jnp.uint32(m)).astype(jnp.int32)                 # [m, J]
    occ_p = jnp.take(occ, src, axis=1)                        # [B, m, J]
    has = jnp.any(occ_p, axis=2)
    firstj = jnp.argmax(occ_p, axis=2).astype(jnp.int32)
    src_w = (hash_u32(t.astype(jnp.uint32),
                      salt_for(seed, DMH_DENSIFY_STREAM, firstj))
             % jnp.uint32(m)).astype(jnp.int32)               # [B, m]
    fallback = jnp.argmax(occ, axis=1).astype(jnp.int32)[:, None]
    src_sel = jnp.where(has, src_w, fallback)
    need = (~occ) & jnp.any(occ, axis=1)[:, None]

    def borrow(x):
        return jnp.where(need, jnp.take_along_axis(x, src_sel, axis=1), x)

    fp, val_sel, key_sel, amin = (borrow(fp), borrow(val_sel),
                                  borrow(key_sel), borrow(amin))
    alive = amin < BIG
    return (jnp.where(alive, fp, -1), jnp.where(alive, val_sel, 0.0),
            amin, jnp.where(alive, key_sel, 0))


# ---------------------------------------------------------------------------
# CountSketch  (linear sketch used for gradient compression)
# ---------------------------------------------------------------------------
def countsketch_ref(x, width: int, reps: int, seed: int, offset: int = 0):
    """Reference CountSketch of a dense f32 vector.

    Args:
      x:      [T] f32 values; element i has global index offset + i.
      width:  table width W.
      reps:   number of independent repetitions R.
      seed:   RNG seed.
    Returns: [R, W] f32 table.
    """
    (T,) = x.shape
    idx = (jnp.arange(T, dtype=jnp.uint32) + jnp.uint32(offset))
    r = jnp.arange(reps, dtype=jnp.int32)
    hb = hash_u32(idx[None, :], salt_for(seed, CS_BUCKET_STREAM, r)[:, None])      # [R, T]
    bucket = (hb % jnp.uint32(width)).astype(jnp.int32)
    hs = hash_u32(idx[None, :], salt_for(seed, CS_SIGN_STREAM, r)[:, None])
    sign = jnp.where((hs & jnp.uint32(1)) == 0, 1.0, -1.0).astype(x.dtype)
    contrib = sign * x[None, :]                                      # [R, T]
    onehot = jax.nn.one_hot(bucket, width, dtype=x.dtype)            # [R, T, W]
    return jnp.einsum("rt,rtw->rw", contrib, onehot).astype(jnp.float32)


def countsketch_sparse_ref(keys, vals, width: int, reps: int, seed: int):
    """Reference CountSketch of a padded sparse batch.

    Args:
      keys: [B, N] int32 vector indices (kernel key domain, mod 2^32).
      vals: [B, N] f32 signed values; 0 => padding (zero contribution, so
        padding is inert without any sentinel).
    Returns: [B, R, W] f32 tables.  Streams match :func:`countsketch_ref`,
    so sketching a densified vector by position gives the same table.
    """
    idx = keys.astype(jnp.uint32)                                    # [B, N]
    r = jnp.arange(reps, dtype=jnp.int32)
    hb = hash_u32(idx[:, None, :], salt_for(seed, CS_BUCKET_STREAM, r)[None, :, None])
    bucket = (hb % jnp.uint32(width)).astype(jnp.int32)              # [B, R, N]
    hs = hash_u32(idx[:, None, :], salt_for(seed, CS_SIGN_STREAM, r)[None, :, None])
    sign = jnp.where((hs & jnp.uint32(1)) == 0, 1.0, -1.0).astype(jnp.float32)
    contrib = sign * vals.astype(jnp.float32)[:, None, :]            # [B, R, N]
    onehot = jax.nn.one_hot(bucket, width, dtype=jnp.float32)        # [B, R, N, W]
    return jnp.einsum("brn,brnw->brw", contrib, onehot)


def jl_sketch_ref(keys, vals, m: int, seed: int):
    """Reference JL projection of a padded sparse batch.

    Args as :func:`countsketch_sparse_ref`; returns [B, m] f32 projections
    ``proj[t] = (1/sqrt(m)) * sum_i sign(t, key_i) * val_i`` with signs from
    u32 stream 31 (the :class:`repro.core.linear.JLU32` contract).
    """
    t = jnp.arange(m, dtype=jnp.int32)
    hs = hash_u32(keys.astype(jnp.uint32)[:, None, :],
                  salt_for(seed, JL_SIGN_STREAM, t)[None, :, None])              # [B, m, N]
    sign = jnp.where((hs & jnp.uint32(1)) == 0, 1.0, -1.0).astype(jnp.float32)
    proj = jnp.einsum("bmn,bn->bm", sign, vals.astype(jnp.float32))
    return proj / jnp.sqrt(jnp.float32(m))


def countsketch_decode_ref(table, indices, seed: int):
    """Median-of-reps unbiased point query (decompression)."""
    reps, width = table.shape
    r = jnp.arange(reps, dtype=jnp.int32)
    idx = indices.astype(jnp.uint32)
    hb = hash_u32(idx[None, :], salt_for(seed, CS_BUCKET_STREAM, r)[:, None])
    bucket = (hb % jnp.uint32(width)).astype(jnp.int32)
    hs = hash_u32(idx[None, :], salt_for(seed, CS_SIGN_STREAM, r)[:, None])
    sign = jnp.where((hs & jnp.uint32(1)) == 0, 1.0, -1.0)
    est = jnp.take_along_axis(table, bucket, axis=1) * sign          # [R, n]
    return jnp.median(est, axis=0)


# ---------------------------------------------------------------------------
# Fused sketch-pair estimator (Algorithm 5 inner loop over m samples)
# ---------------------------------------------------------------------------
def estimate_partials_ref(fpa, va, fpb, vb):
    """Per-pair partial sums for the WMH/ICWS estimator.

    Args:  fpa/fpb [P, m] int32 fingerprints; va/vb [P, m] f32 values.
    Returns:
      n_collide [P] f32   -- number of colliding samples,
      s_weight  [P] f32   -- sum of va*vb / min(va^2, vb^2) over collisions.
    """
    collide = (fpa == fpb) & (fpa >= 0)
    q = jnp.minimum(va * va, vb * vb)
    safe_q = jnp.where(collide & (q > 0), q, 1.0)
    term = jnp.where(collide, va * vb / safe_q, 0.0)
    return collide.astype(jnp.float32).sum(axis=1), term.sum(axis=1)


def estimate_one_vs_many_ref(fq, vq, fpc, vc):
    """One query sketch vs a P-row corpus (broadcast form of the above).

    Args:  fq/vq [1, m] or [m] query; fpc/vc [P, m] corpus.
    Returns (n_collide [P], s_weight [P]).
    """
    fq = fq.reshape(1, -1)
    vq = vq.reshape(1, -1)
    return estimate_partials_ref(fq, vq, fpc, vc)


def estimate_many_vs_many_ref(fq, vq, fpc, vc):
    """Q query sketches vs a P-row corpus.

    Args:  fq/vq [Q, m] queries; fpc/vc [P, m] corpus.
    Returns (n_collide [Q, P], s_weight [Q, P]).  The oracle may materialize
    the [Q, P, m] broadcast; the kernel must not.
    """
    fqb, fcb = fq[:, None, :], fpc[None, :, :]
    vqb, vcb = vq[:, None, :], vc[None, :, :]
    collide = (fqb == fcb) & (fqb >= 0)
    q = jnp.minimum(vqb * vqb, vcb * vcb)
    safe_q = jnp.where(collide & (q > 0), q, 1.0)
    term = jnp.where(collide, vqb * vcb / safe_q, 0.0)
    return collide.astype(jnp.float32).sum(axis=2), term.sum(axis=2)


def estimate_fields_ref(fq, vq, fpc, vc, *, qmap, cmap):
    """Fused multi-field many-vs-many partials.

    Args:  fq/vq [F, Q, m] per-field queries; fpc/vc [C, P, m] per-field
    corpus; qmap/cmap length-G field-index tuples (see the kernel).
    Returns (n_collide [G, Q, P], s_weight [G, Q, P]).
    """
    cnts, sws = [], []
    for qf, cf in zip(qmap, cmap):
        cnt, sw = estimate_many_vs_many_ref(fq[qf], vq[qf], fpc[cf], vc[cf])
        cnts.append(cnt)
        sws.append(sw)
    return jnp.stack(cnts), jnp.stack(sws)


# ---------------------------------------------------------------------------
# Sampling-family estimation: unaligned key-match contraction (TS/PS)
# ---------------------------------------------------------------------------
def sample_estimate_fields_ref(kq, vq, aq, kc, vc, ac, *, qmap, cmap):
    """Fused multi-field key-match estimates for sampling sketches.

    Args:  kq/vq/aq [F, Q, m] per-field query sample keys / values /
    inclusion probabilities (:func:`repro.kernels.sample_estimate.
    sample_inclusion_probs`); kc/vc/ac [C, P, m] per-field corpus samples;
    qmap/cmap length-G field-index tuples (as the ICWS fields kernel).
    Returns [G, Q, P] f32 estimates.  The oracle may materialize the
    [Q, P, m, m] key-equality cross; the kernel must not.
    """
    outs = []
    for qf, cf in zip(qmap, cmap):
        kqb, kcb = kq[qf][:, None, :, None], kc[cf][None, :, None, :]
        p = jnp.minimum(aq[qf][:, None, :, None], ac[cf][None, :, None, :])
        live = (kqb == kcb) & (kqb >= 0) & (p > 0)
        term = jnp.where(
            live,
            vq[qf][:, None, :, None] * vc[cf][None, :, None, :]
            / jnp.where(live, p, 1.0), 0.0)
        outs.append(term.sum(axis=(2, 3)))
    return jnp.stack(outs)


# ---------------------------------------------------------------------------
# Linear-family estimation: per-rep sketch dot products (MXU work on device)
# ---------------------------------------------------------------------------
def linear_estimate_fields_ref(tq, tc, *, qmap, cmap):
    """Fused multi-field per-rep dot products for linear sketches.

    Args:  tq [F, Q, R, W] per-field query tables; tc [C, P, R, W] per-field
    corpus tables; qmap/cmap length-G field-index tuples (as the ICWS
    fields kernel).  JL is the R = 1, W = m case.
    Returns [G, R, Q, P] f32 per-rep inner products
    ``out[g, r, q, p] = <tq[qmap[g], q, r], tc[cmap[g], p, r]>`` -- the
    median-of-reps (CS) or squeeze (JL) epilogue happens in the ops layer.
    """
    return jnp.stack([
        jnp.einsum("qrw,prw->rqp", tq[qf].astype(jnp.float32),
                   tc[cf].astype(jnp.float32))
        for qf, cf in zip(qmap, cmap)])
