"""Pallas TPU kernel: fused flash attention (forward).

Motivation (measured, see EXPERIMENTS.md §Perf): the XLA chunked-attention
path materializes each [qc, kc] f32 score tile in HBM ~6-8 times across the
softmax chain (sub/exp/max/select fusions) -- 2.6 TB/device/step on Mixtral
train_4k, the dominant memory-roofline term on every dense train/prefill
cell.  This kernel keeps the whole online-softmax recurrence in VMEM: HBM
traffic collapses to one read of q/k/v + one write of o per tile.

Layout: q [BH, T, D], kv [BKV, S, D] with GQA handled zero-copy by the
index map (q head bh reads kv head bh // group).  Grid (BH, nq); the key
loop runs inside the kernel over S/kc slices with (m, l, acc) carried in
registers/VMEM.  VMEM budget: kv block 2*S*D bf16 (32k x 128 => 8 MiB) +
qc*D accumulators -- fits v5e's ~16 MiB budget up to S=32k at D=128, with
kc-slicing keeping the working set far smaller.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, kc: int, causal: bool,
                  window: int, scale: float, q_offset: int, k_offset: int):
    qi = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32) * scale              # [qc, D]
    qc, D = q.shape
    S = k_ref.shape[1]
    nk = S // kc
    q_pos = q_offset + qi * qc + jax.lax.iota(jnp.int32, qc)

    def body(i, carry):
        m, l, acc = carry
        k_blk = k_ref[0, pl.dslice(i * kc, kc), :].astype(jnp.float32)
        v_blk = v_ref[0, pl.dslice(i * kc, kc), :].astype(jnp.float32)
        s = q @ k_blk.T                                    # [qc, kc]
        k_pos = k_offset + i * kc + jax.lax.iota(jnp.int32, kc)
        mask = jnp.ones((qc, kc), jnp.bool_)
        if causal:
            mask &= k_pos[None, :] <= q_pos[:, None]
        if window:
            mask &= k_pos[None, :] > q_pos[:, None] - window
        s = jnp.where(mask, s, NEG)
        m_new = jnp.maximum(m, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=1)
        acc_new = acc * corr[:, None] + p @ v_blk
        return m_new, l_new, acc_new

    m0 = jnp.full((qc,), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((qc,), jnp.float32)
    a0 = jnp.zeros((qc, D), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, nk, body, (m0, l0, a0))
    o_ref[0] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "group", "causal", "window", "qc", "kc", "q_offset", "k_offset",
    "interpret"))
def flash_attention_pallas(q, k, v, *, group: int = 1, causal: bool = True,
                           window: int = 0, qc: int = 512, kc: int = 512,
                           q_offset: int = 0, k_offset: int = 0,
                           interpret: bool = True):
    """q [BH, T, D]; k/v [BH//group, S, D].  Returns o [BH, T, D].

    ``group`` = GQA group size: q head i attends kv head i // group via the
    BlockSpec index map (no kv repetition in memory).
    """
    BH, T, D = q.shape
    S = k.shape[1]
    qc = min(qc, T)
    kc = min(kc, S)
    assert T % qc == 0 and S % kc == 0, (T, qc, S, kc)
    grid = (BH, T // qc)
    scale = 1.0 / (D ** 0.5)
    kernel = functools.partial(_flash_kernel, kc=kc, causal=causal,
                               window=window, scale=scale,
                               q_offset=q_offset, k_offset=k_offset)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, qc, D), lambda bh, qi: (bh, qi, 0)),
            pl.BlockSpec((1, S, D), lambda bh, qi: (bh // group, 0, 0)),
            pl.BlockSpec((1, S, D), lambda bh, qi: (bh // group, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, qc, D), lambda bh, qi: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, T, D), q.dtype),
        interpret=interpret,
    )(q, k, v)


def flash_attention(q, k, v, *, causal=True, window=0, interpret=True,
                    qc=512, kc=512):
    """Model-layout wrapper: q [B,T,H,D], k/v [B,S,K,D] -> [B,T,H,D]."""
    B, T, H, D = q.shape
    S, K = k.shape[1], k.shape[2]
    G = H // K
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, T, D)
    kf = k.transpose(0, 2, 1, 3).reshape(B * K, S, D)
    vf = v.transpose(0, 2, 1, 3).reshape(B * K, S, D)
    of = flash_attention_pallas(qf, kf, vf, group=G, causal=causal,
                                window=window, qc=qc, kc=kc,
                                interpret=interpret)
    return of.reshape(B, H, T, D).transpose(0, 2, 1, 3)
