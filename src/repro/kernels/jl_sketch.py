"""Pallas TPU kernel: batched JL (AMS) projection of padded sparse batches.

``proj[b, t] = (1/sqrt(m)) * sum_i sign(t, key_i) * val_i`` with +-1 signs
drawn per (sample t, key) from the shared u32 mixing RNG (stream 31 -- the
:class:`repro.core.linear.JLU32` host contract).  Like the CountSketch
kernel, the reduction over non-zeros is MXU-shaped: each grid step forms
the ``[BN, BM]`` sign tile from a hash of the keys block against the global
sample ids and contracts it with the values block as a ``[1, BN] @
[BN, BM]`` matmul, accumulating across the (sequential, innermost) N
dimension.  Zero-valued padding lanes contribute sign * 0 = 0, so padding
is inert with no sentinel machinery.

VMEM per step (f32): ``BN`` keys/values + ``BN x BM`` signs ~= 128 KiB at
BN=256, BM=128 -- far under budget; both block dims are lane-width
multiples.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import JL_SIGN_STREAM, hash_u32, salt_for


def _jl_kernel(key_ref, val_ref, out_ref, *, seed: int, bm: int):
    m_idx = pl.program_id(1)
    n_idx = pl.program_id(2)

    keys = key_ref[0, :].astype(jnp.uint32)                   # [BN]
    vals = val_ref[0, :]                                      # [BN]
    t = m_idx * bm + jax.lax.iota(jnp.int32, bm)              # global samples
    hs = hash_u32(keys[:, None], salt_for(seed, JL_SIGN_STREAM, t)[None, :])   # [BN, BM]
    sign = jnp.where((hs & jnp.uint32(1)) == 0, 1.0, -1.0).astype(jnp.float32)
    tile = jnp.dot(vals.astype(jnp.float32)[None, :], sign,
                   preferred_element_type=jnp.float32)[0]     # [BM]

    @pl.when(n_idx == 0)
    def _init():
        out_ref[0, :] = tile

    @pl.when(n_idx != 0)
    def _acc():
        out_ref[0, :] = out_ref[0, :] + tile


@functools.partial(jax.jit, static_argnames=("m", "seed", "bm", "bn",
                                             "interpret"))
def jl_sketch_pallas(keys, vals, *, m: int, seed: int = 0, bm: int = 128,
                     bn: int = 256, interpret: bool = True):
    """JL projections [B, m] of a padded sparse batch.

    Args: keys [B, N] int32 vector indices (mod 2^32); vals [B, N] f32
    signed values, 0 marking padding.  Matches
    :func:`repro.kernels.ref.jl_sketch_ref`.
    """
    B, N = keys.shape
    n_pad = (-N) % bn
    if n_pad:
        keys = jnp.pad(keys, ((0, 0), (0, n_pad)))
        vals = jnp.pad(vals, ((0, 0), (0, n_pad)))    # zero values: inert
    m_padded = m + ((-m) % bm)
    grid = (B, m_padded // bm, (N + n_pad) // bn)
    kernel = functools.partial(_jl_kernel, seed=seed, bm=bm)
    proj = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bn), lambda b, mi, ni: (b, ni)),
            pl.BlockSpec((1, bn), lambda b, mi, ni: (b, ni)),
        ],
        out_specs=pl.BlockSpec((1, bm), lambda b, mi, ni: (b, mi)),
        out_shape=jax.ShapeDtypeStruct((B, m_padded), jnp.float32),
        interpret=interpret,
    )(keys.astype(jnp.int32), vals.astype(jnp.float32))
    return proj[:, :m] / jnp.sqrt(jnp.float32(m))
