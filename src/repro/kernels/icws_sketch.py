"""Pallas TPU kernel: batched ICWS (weighted MinHash) sketching.

Grid: ``(B/BR, M/BM, N/BN)`` with the non-zero dimension N innermost and
*sequential* ("arbitrary"): each step computes ICWS hash values for a
``[BR, BM, BN]`` tile of (rows x samples x non-zeros) entirely in VMEM -- 5
uniform draws, two logs, one exp, one divide per lane, then a per-row argmin
-- and merges the tile winner into the running ``[BR, BM]`` output blocks
(value / fingerprint / min) with a strict ``<`` so earlier tiles win ties,
matching ``jnp.argmin`` first-hit semantics in the oracle.

``BR`` (row block, default 1) amortizes per-step costs across sketch rows:
a single query sketches 3 field rows and cannot fill a row block, but the
batched serving/ingest paths launch 3Q-row batches and sketch them with
``BR`` rows per grid step.  Results are bitwise independent of all three
block sizes (each row's winner is a global min with first-index ties).

VMEM budget per step (f32): inputs ``3 * BR*BN`` + intermediates
``~6 * BR*BM*BN``.  Defaults BR=1, BM=128, BN=256 => ~800 KiB, comfortably
under the ~16 MiB/core VMEM of TPU v5e; keep ``BR*BM*BN`` under ~128K lanes
(~3 MiB per intermediate) when raising BR.  The lane dimension (BN=256) is a
multiple of 128 as the VPU wants; there is no MXU work in this kernel -- it
is VPU/transcendental bound, which is exactly why it beats the paper's
scalar "active index" loop on TPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import (ICWS_BETA_STREAM, ICWS_C1_STREAM, ICWS_C2_STREAM,
                     ICWS_FP_STREAM, ICWS_R1_STREAM, ICWS_R2_STREAM,
                     hash_u32, salt_for, uniform01)
from .packed import pack_halfwords_f32
from .ref import BIG


def _icws_kernel(w_ref, key_ref, val_ref, fp_ref, out_val_ref, amin_ref,
                 out_key_ref, *, seed: int, bm: int, bn: int):
    m_idx = pl.program_id(1)
    n_idx = pl.program_id(2)

    w = w_ref[:, :]                                   # [BR, BN]
    keys = key_ref[:, :]                              # [BR, BN] int32
    vals = val_ref[:, :]                              # [BR, BN]

    t = m_idx * bm + jax.lax.iota(jnp.int32, bm)      # global sample ids [BM]
    kk = keys.astype(jnp.uint32)[:, None, :]          # [BR, 1, BN]

    def u(stream):
        salt = salt_for(seed, stream, t)[None, :, None]   # [1, BM, 1]
        return uniform01(kk, salt)                    # [BR, BM, BN]

    r = -jnp.log(u(ICWS_R1_STREAM) * u(ICWS_R2_STREAM))
    c = -jnp.log(u(ICWS_C1_STREAM) * u(ICWS_C2_STREAM))
    beta = u(ICWS_BETA_STREAM)
    logw = jnp.log(jnp.maximum(w, 1e-37))[:, None, :]
    lvl = jnp.floor(logw / r + beta)
    y = jnp.exp(r * (lvl - beta))
    a = c / (y * jnp.exp(r))
    a = jnp.where((w > 0)[:, None, :], a, BIG)        # mask padding

    arg = jnp.argmin(a, axis=2)                       # [BR, BM]
    cols = jax.lax.iota(jnp.int32, bn)[None, None, :]
    sel = cols == arg[:, :, None]                     # one-hot [BR, BM, BN]
    amin = jnp.min(a, axis=2)
    key_sel = jnp.sum(jnp.where(sel, keys[:, None, :], 0), axis=2)
    lvl_sel = jnp.sum(jnp.where(sel, lvl, 0.0), axis=2)
    val_sel = jnp.sum(jnp.where(sel, vals[:, None, :], 0.0), axis=2)

    fpbits = hash_u32(
        key_sel.astype(jnp.uint32)
        ^ (lvl_sel.astype(jnp.int32).astype(jnp.uint32) * jnp.uint32(0x9E3779B9)),
        salt_for(seed, ICWS_FP_STREAM, t)[None, :])
    # 31-bit fingerprint: non-negative int32 (see ref.icws_sketch_ref)
    fp = (fpbits & jnp.uint32(0x7FFFFFFF)).astype(jnp.int32)

    @pl.when(n_idx == 0)
    def _init():
        amin_ref[:, :] = amin
        fp_ref[:, :] = fp
        out_val_ref[:, :] = val_sel
        out_key_ref[:, :] = key_sel

    @pl.when(n_idx != 0)
    def _merge():
        better = amin < amin_ref[:, :]
        amin_ref[:, :] = jnp.where(better, amin, amin_ref[:, :])
        fp_ref[:, :] = jnp.where(better, fp, fp_ref[:, :])
        out_val_ref[:, :] = jnp.where(better, val_sel, out_val_ref[:, :])
        out_key_ref[:, :] = jnp.where(better, key_sel, out_key_ref[:, :])


def _icws_kernel_packed(w_ref, key_ref, val_ref, fp_ref, out_val_ref,
                        amin_ref, out_key_ref, packed_ref, *, seed: int,
                        bm: int, bn: int, m_live: int, n_steps: int):
    """The sketch kernel plus a pack-on-output epilogue: after the final
    non-zero tile has merged, the per-row value block is bf16-halfword
    packed in VMEM (see :mod:`repro.kernels.packed`) and written as a fifth
    output -- the packed plane a packed :class:`CorpusStore` appends
    directly, with no host-side re-pack of the f32 values.  Samples beyond
    ``m_live`` (bm padding / the odd-m inert slot) and empty rows are
    zeroed before packing, matching the host epilogue's empty fixup and
    ``pack_rows``' zero pad bit for bit.
    """
    _icws_kernel(w_ref, key_ref, val_ref, fp_ref, out_val_ref, amin_ref,
                 out_key_ref, seed=seed, bm=bm, bn=bn)
    m_idx = pl.program_id(1)
    n_idx = pl.program_id(2)

    @pl.when(n_idx == n_steps - 1)
    def _pack():
        t = m_idx * bm + jax.lax.iota(jnp.int32, bm)
        v = out_val_ref[:, :]
        v = jnp.where((t < m_live)[None, :], v, 0.0)
        v = jnp.where(amin_ref[:, :] >= BIG, 0.0, v)
        packed_ref[:, :] = pack_halfwords_f32(v)


@functools.partial(jax.jit, static_argnames=("m", "seed", "br", "bm", "bn",
                                             "pack_vals", "interpret"))
def icws_sketch_pallas(w, keys, vals, *, m: int, seed: int, br: int = 1,
                       bm: int = 128, bn: int = 256,
                       pack_vals: bool = False, interpret: bool = True):
    """Batched ICWS sketch via Pallas.  See :func:`repro.kernels.ref.icws_sketch_ref`.

    Args: w/keys/vals [B, N] (N padded to a multiple of ``bn`` by the caller
    or here); returns (fp [B, m] int32, val [B, m] f32, amin [B, m] f32,
    argkey [B, m] int32 -- the original vector index that won each sample,
    the sidecar the merge path re-levels from; 0 for empty inputs).
    ``br`` rows are sketched per grid step (pad rows are all-zero => empty);
    results are bitwise identical for every (br, bm, bn) choice.

    With ``pack_vals=True`` (needs even ``bm``) a fifth output is appended:
    ``[B, (m + m % 2) // 2]`` i32 bf16-halfword packed values, produced
    in-kernel at the last non-zero grid step -- bitwise equal to
    ``pack_halfwords_f32`` of the (zero-padded-to-even) ``val`` output.
    """
    B, N = w.shape
    n_pad = (-N) % bn
    b_pad = (-B) % br
    if n_pad or b_pad:
        w = jnp.pad(w, ((0, b_pad), (0, n_pad)))
        keys = jnp.pad(keys, ((0, b_pad), (0, n_pad)))
        vals = jnp.pad(vals, ((0, b_pad), (0, n_pad)))
    m_pad = (-m) % bm
    mp = m + m_pad
    Bp, Np = w.shape

    grid = (Bp // br, mp // bm, Np // bn)
    if pack_vals:
        if bm % 2:
            raise ValueError(f"pack_vals needs an even bm; got bm={bm}")
        kernel = functools.partial(_icws_kernel_packed, seed=seed, bm=bm,
                                   bn=bn, m_live=m, n_steps=Np // bn)
        fp, val, amin, key, packed = pl.pallas_call(
            kernel,
            grid=grid,
            in_specs=[
                pl.BlockSpec((br, bn), lambda b, mi, ni: (b, ni)),
                pl.BlockSpec((br, bn), lambda b, mi, ni: (b, ni)),
                pl.BlockSpec((br, bn), lambda b, mi, ni: (b, ni)),
            ],
            out_specs=[
                pl.BlockSpec((br, bm), lambda b, mi, ni: (b, mi)),
                pl.BlockSpec((br, bm), lambda b, mi, ni: (b, mi)),
                pl.BlockSpec((br, bm), lambda b, mi, ni: (b, mi)),
                pl.BlockSpec((br, bm), lambda b, mi, ni: (b, mi)),
                pl.BlockSpec((br, bm // 2), lambda b, mi, ni: (b, mi)),
            ],
            out_shape=[
                jax.ShapeDtypeStruct((Bp, mp), jnp.int32),
                jax.ShapeDtypeStruct((Bp, mp), jnp.float32),
                jax.ShapeDtypeStruct((Bp, mp), jnp.float32),
                jax.ShapeDtypeStruct((Bp, mp), jnp.int32),
                jax.ShapeDtypeStruct((Bp, mp // 2), jnp.int32),
            ],
            interpret=interpret,
        )(w.astype(jnp.float32), keys.astype(jnp.int32),
          vals.astype(jnp.float32))
    else:
        kernel = functools.partial(_icws_kernel, seed=seed, bm=bm, bn=bn)
        fp, val, amin, key = pl.pallas_call(
            kernel,
            grid=grid,
            in_specs=[
                pl.BlockSpec((br, bn), lambda b, mi, ni: (b, ni)),
                pl.BlockSpec((br, bn), lambda b, mi, ni: (b, ni)),
                pl.BlockSpec((br, bn), lambda b, mi, ni: (b, ni)),
            ],
            out_specs=[
                pl.BlockSpec((br, bm), lambda b, mi, ni: (b, mi)),
                pl.BlockSpec((br, bm), lambda b, mi, ni: (b, mi)),
                pl.BlockSpec((br, bm), lambda b, mi, ni: (b, mi)),
                pl.BlockSpec((br, bm), lambda b, mi, ni: (b, mi)),
            ],
            out_shape=[
                jax.ShapeDtypeStruct((Bp, mp), jnp.int32),
                jax.ShapeDtypeStruct((Bp, mp), jnp.float32),
                jax.ShapeDtypeStruct((Bp, mp), jnp.float32),
                jax.ShapeDtypeStruct((Bp, mp), jnp.int32),
            ],
            interpret=interpret,
        )(w.astype(jnp.float32), keys.astype(jnp.int32),
          vals.astype(jnp.float32))
        packed = None

    fp, val, amin, key = fp[:B, :m], val[:B, :m], amin[:B, :m], key[:B, :m]
    empty = amin >= BIG
    outs = (jnp.where(empty, -1, fp), jnp.where(empty, 0.0, val), amin,
            jnp.where(empty, 0, key))
    if pack_vals:
        me = m + (m % 2)
        return outs + (packed[:B, :me // 2],)
    return outs
