"""bf16-halfword codec: the packed corpus value layout (2 samples / i32 word).

The packed :class:`repro.data.store.CorpusStore` layout stores every f32
*value* component (ICWS sampled values, TS/PS sampled values, linear table
cells) as bf16 halfwords, two consecutive samples per i32 word:

    word k = bf16(x[2k]) | bf16(x[2k+1]) << 16

``bf16(x)`` here is *truncation* -- the top 16 bits of the f32 encoding
(sign, 8-bit exponent, 7 mantissa bits).  Truncation, not round-to-nearest,
is deliberate: it makes the decode exact (``unpack(pack(x)) ==
pack-domain(x)`` bit for bit) and the codec idempotent
(``pack(unpack(w)) == w`` for every word), which is what the packed-path
bitwise-identity contract is stated against.  Zero encodes to the zero
word, so zero-filled spare rows and slot padding stay inert through the
codec with no sentinel machinery.

Integer components (31-bit ICWS fingerprints, TS/PS sample keys) are NOT
narrowed: they are exact-match state -- a single flipped bit changes
collision/join semantics -- and 31 bits do not compress below one i32 lane
without changing results.  The byte savings come entirely from the value
lanes (f32 -> bf16 halves the dominant component), which the estimate
kernels decode tile-by-tile in VMEM (`unpack_halfwords_f32` is the
in-kernel decode used by ``estimate_fields_packed_pallas`` and friends);
the packed words never expand in HBM.

These helpers are shape-polymorphic over leading dims and run both as
plain jnp (host-side ``pack_rows``/``unpack_rows``) and inside Pallas
kernel bodies (interpret and compiled), where shifts/bitcasts lower to
plain VPU ops.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def packed_width(n: int) -> int:
    """i32 words needed for ``n`` bf16 halfword samples (rounds up)."""
    return (int(n) + 1) // 2


def pack_halfwords_f32(x: jnp.ndarray) -> jnp.ndarray:
    """``[..., 2k]`` f32 -> ``[..., k]`` i32, two bf16 halfwords per word.

    Each f32 is truncated to its top 16 bits (bf16); the even sample lands
    in the low halfword.  The last dim must be even -- callers pad odd
    widths with one zero sample first (zero packs to zero bits).
    """
    x = jnp.asarray(x, jnp.float32)
    if x.shape[-1] % 2:
        raise ValueError(f"pack_halfwords_f32 needs an even last dim; "
                         f"got {x.shape}")
    bits = jax.lax.bitcast_convert_type(x, jnp.uint32) >> 16
    pairs = bits.reshape(x.shape[:-1] + (x.shape[-1] // 2, 2))
    word = pairs[..., 0] | (pairs[..., 1] << 16)
    return jax.lax.bitcast_convert_type(word, jnp.int32)


def unpack_halfwords_f32(w: jnp.ndarray) -> jnp.ndarray:
    """``[..., k]`` i32 -> ``[..., 2k]`` f32, the exact codec inverse.

    Each halfword expands to the f32 whose top 16 bits it holds (low 16
    mantissa bits zero) -- bf16 -> f32 is exact, so this reproduces the
    pack-domain values bit for bit.  Used both host-side and as the
    in-kernel tile decode of the packed estimate kernels.
    """
    wu = jax.lax.bitcast_convert_type(jnp.asarray(w, jnp.int32), jnp.uint32)
    even = jax.lax.bitcast_convert_type((wu << 16).astype(jnp.uint32),
                                        jnp.float32)
    odd = jax.lax.bitcast_convert_type(wu & jnp.uint32(0xFFFF0000),
                                       jnp.float32)
    out = jnp.stack([even, odd], axis=-1)
    return out.reshape(w.shape[:-1] + (2 * w.shape[-1],))
