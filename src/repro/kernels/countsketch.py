"""Pallas TPU kernel: CountSketch of a dense vector (gradient compression).

Formulated MXU-style: instead of a scatter (which TPUs hate), each
``(rep, t_tile, w_tile)`` grid step builds the one-hot bucket-membership tile
``eq [BT, BW]`` with an iota compare and contracts it against the signed
values with a ``[1, BT] @ [BT, BW]`` matmul -- turning the scatter into dense
MXU work.  The table accumulates across the (sequential, innermost) t
dimension.

VMEM per step: ``BT`` values + ``BT x BW`` one-hot (f32) ~= 0.5 MiB at
BT=1024, BW=128.  BW=128 matches the lane width; BT=1024 keeps the matmul
MXU-shaped (the contraction dim is the 1024-long t axis).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import hash_u32, salt_for


def _cs_kernel(x_ref, out_ref, *, width: int, seed: int, bt: int, bw: int,
               offset: int):
    r_idx = pl.program_id(0)
    w_idx = pl.program_id(1)
    t_idx = pl.program_id(2)

    x = x_ref[:]                                              # [BT]
    idx = (jnp.uint32(offset) + (t_idx * bt + jax.lax.iota(jnp.int32, bt))
           .astype(jnp.uint32))
    r = r_idx * jnp.ones((), jnp.int32)
    hb = hash_u32(idx, salt_for(seed, 21, r))
    bucket = (hb % jnp.uint32(width)).astype(jnp.int32)       # [BT]
    hs = hash_u32(idx, salt_for(seed, 22, r))
    sign = jnp.where((hs & jnp.uint32(1)) == 0, 1.0, -1.0).astype(jnp.float32)

    w0 = w_idx * bw
    lanes = w0 + jax.lax.iota(jnp.int32, bw)                  # [BW]
    eq = (bucket[:, None] == lanes[None, :]).astype(jnp.float32)  # [BT, BW]
    contrib = (sign * x.astype(jnp.float32))[None, :]         # [1, BT]
    tile = jnp.dot(contrib, eq, preferred_element_type=jnp.float32)[0]  # [BW]

    @pl.when(t_idx == 0)
    def _init():
        out_ref[0, :] = tile

    @pl.when(t_idx != 0)
    def _acc():
        out_ref[0, :] = out_ref[0, :] + tile


@functools.partial(jax.jit, static_argnames=("width", "reps", "seed", "offset",
                                             "bt", "bw", "interpret"))
def countsketch_pallas(x, *, width: int, reps: int = 5, seed: int = 0,
                       offset: int = 0, bt: int = 1024, bw: int = 128,
                       interpret: bool = True):
    """CountSketch table [reps, width] of dense x [T].  Matches
    :func:`repro.kernels.ref.countsketch_ref`."""
    (T,) = x.shape
    t_pad = (-T) % bt
    if t_pad:
        x = jnp.pad(x, (0, t_pad))        # padded values are 0 => no contribution
    w_padded = width + ((-width) % bw)
    grid = (reps, w_padded // bw, (T + t_pad) // bt)
    kernel = functools.partial(_cs_kernel, width=width, seed=seed,
                               bt=bt, bw=bw, offset=offset)
    table = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((bt,), lambda r, wi, ti: (ti,))],
        out_specs=pl.BlockSpec((1, bw), lambda r, wi, ti: (r, wi)),
        out_shape=jax.ShapeDtypeStruct((reps, w_padded), jnp.float32),
        interpret=interpret,
    )(x)
    return table[:, :width]
