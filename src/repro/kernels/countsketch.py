"""Pallas TPU kernels: CountSketch of dense vectors and padded sparse batches.

Formulated MXU-style: instead of a scatter (which TPUs hate), each grid step
builds the one-hot bucket-membership tile ``eq [BT, BW]`` with an iota
compare and contracts it against the signed values with a ``[1, BT] @
[BT, BW]`` matmul -- turning the scatter into dense MXU work.  The table
accumulates across the (sequential, innermost) non-zero dimension.

Two entry points share that formulation:

  * :func:`countsketch_pallas` -- dense vector (gradient compression);
    buckets/signs are hashed from the element's *position*.
  * :func:`countsketch_sparse_pallas` -- a ``[B, N]`` padded sparse batch
    (corpus/query ingest for the CS serving family); buckets/signs are
    hashed from the element's *key*, with the same salt streams, so a
    sparse vector sketched by key equals the dense kernel's sketch of its
    densification.  Zero-valued padding lanes contribute sign * 0 = 0 --
    padding is inert with no sentinel machinery.

VMEM per step: ``BT`` values + ``BT x BW`` one-hot (f32) ~= 0.5 MiB at
BT=1024, BW=128.  BW=128 matches the lane width; BT=1024 keeps the matmul
MXU-shaped (the contraction dim is the 1024-long t axis).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import (CS_BUCKET_STREAM, CS_SIGN_STREAM, hash_u32, salt_for)


def _cs_kernel(x_ref, out_ref, *, width: int, seed: int, bt: int, bw: int,
               offset: int):
    r_idx = pl.program_id(0)
    w_idx = pl.program_id(1)
    t_idx = pl.program_id(2)

    x = x_ref[:]                                              # [BT]
    idx = (jnp.uint32(offset) + (t_idx * bt + jax.lax.iota(jnp.int32, bt))
           .astype(jnp.uint32))
    r = r_idx * jnp.ones((), jnp.int32)
    hb = hash_u32(idx, salt_for(seed, CS_BUCKET_STREAM, r))
    bucket = (hb % jnp.uint32(width)).astype(jnp.int32)       # [BT]
    hs = hash_u32(idx, salt_for(seed, CS_SIGN_STREAM, r))
    sign = jnp.where((hs & jnp.uint32(1)) == 0, 1.0, -1.0).astype(jnp.float32)

    w0 = w_idx * bw
    lanes = w0 + jax.lax.iota(jnp.int32, bw)                  # [BW]
    eq = (bucket[:, None] == lanes[None, :]).astype(jnp.float32)  # [BT, BW]
    contrib = (sign * x.astype(jnp.float32))[None, :]         # [1, BT]
    tile = jnp.dot(contrib, eq, preferred_element_type=jnp.float32)[0]  # [BW]

    @pl.when(t_idx == 0)
    def _init():
        out_ref[0, :] = tile

    @pl.when(t_idx != 0)
    def _acc():
        out_ref[0, :] = out_ref[0, :] + tile


@functools.partial(jax.jit, static_argnames=("width", "reps", "seed", "offset",
                                             "bt", "bw", "interpret"))
def countsketch_pallas(x, *, width: int, reps: int = 5, seed: int = 0,
                       offset: int = 0, bt: int = 1024, bw: int = 128,
                       interpret: bool = True):
    """CountSketch table [reps, width] of dense x [T].  Matches
    :func:`repro.kernels.ref.countsketch_ref`."""
    (T,) = x.shape
    t_pad = (-T) % bt
    if t_pad:
        x = jnp.pad(x, (0, t_pad))        # padded values are 0 => no contribution
    w_padded = width + ((-width) % bw)
    grid = (reps, w_padded // bw, (T + t_pad) // bt)
    kernel = functools.partial(_cs_kernel, width=width, seed=seed,
                               bt=bt, bw=bw, offset=offset)
    table = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((bt,), lambda r, wi, ti: (ti,))],
        out_specs=pl.BlockSpec((1, bw), lambda r, wi, ti: (r, wi)),
        out_shape=jax.ShapeDtypeStruct((reps, w_padded), jnp.float32),
        interpret=interpret,
    )(x)
    return table[:, :width]


def _cs_sparse_kernel(key_ref, val_ref, out_ref, *, width: int, seed: int,
                      bw: int):
    r_idx = pl.program_id(1)
    w_idx = pl.program_id(2)
    n_idx = pl.program_id(3)

    keys = key_ref[0, :].astype(jnp.uint32)                   # [BN]
    vals = val_ref[0, :]                                      # [BN]
    r = r_idx * jnp.ones((), jnp.int32)
    hb = hash_u32(keys, salt_for(seed, CS_BUCKET_STREAM, r))
    bucket = (hb % jnp.uint32(width)).astype(jnp.int32)       # [BN]
    hs = hash_u32(keys, salt_for(seed, CS_SIGN_STREAM, r))
    sign = jnp.where((hs & jnp.uint32(1)) == 0, 1.0, -1.0).astype(jnp.float32)

    lanes = w_idx * bw + jax.lax.iota(jnp.int32, bw)          # [BW]
    eq = (bucket[:, None] == lanes[None, :]).astype(jnp.float32)  # [BN, BW]
    contrib = (sign * vals.astype(jnp.float32))[None, :]      # [1, BN]
    tile = jnp.dot(contrib, eq, preferred_element_type=jnp.float32)[0]  # [BW]

    @pl.when(n_idx == 0)
    def _init():
        out_ref[0, 0, :] = tile

    @pl.when(n_idx != 0)
    def _acc():
        out_ref[0, 0, :] = out_ref[0, 0, :] + tile


@functools.partial(jax.jit, static_argnames=("width", "reps", "seed",
                                             "bn", "bw", "interpret"))
def countsketch_sparse_pallas(keys, vals, *, width: int, reps: int = 5,
                              seed: int = 0, bn: int = 256, bw: int = 128,
                              interpret: bool = True):
    """CountSketch tables [B, reps, width] of a padded sparse batch.

    Args: keys [B, N] int32 vector indices (mod 2^32, the kernel key
    domain); vals [B, N] f32 signed values, 0 marking padding.  Matches
    :func:`repro.kernels.ref.countsketch_sparse_ref` and the host
    :class:`repro.core.linear.CountSketchU32` contract.
    """
    B, N = keys.shape
    n_pad = (-N) % bn
    if n_pad:
        keys = jnp.pad(keys, ((0, 0), (0, n_pad)))
        vals = jnp.pad(vals, ((0, 0), (0, n_pad)))    # zero values: inert
    w_padded = width + ((-width) % bw)
    grid = (B, reps, w_padded // bw, (N + n_pad) // bn)
    kernel = functools.partial(_cs_sparse_kernel, width=width, seed=seed,
                               bw=bw)
    table = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bn), lambda b, r, wi, ni: (b, ni)),
            pl.BlockSpec((1, bn), lambda b, r, wi, ni: (b, ni)),
        ],
        out_specs=pl.BlockSpec((1, 1, bw), lambda b, r, wi, ni: (b, r, wi)),
        out_shape=jax.ShapeDtypeStruct((B, reps, w_padded), jnp.float32),
        interpret=interpret,
    )(keys.astype(jnp.int32), vals.astype(jnp.float32))
    return table[:, :, :width]
