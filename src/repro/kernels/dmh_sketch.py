"""Pallas TPU kernel: densified one-permutation weighted MinHash ingest.

The ICWS sketch kernel (:mod:`repro.kernels.icws_sketch`) does O(N * m)
hash work per vector: 5 uniform draws per (non-zero x sample) lane.  DMH
(arXiv:1602.08393 with the optimal densification of arXiv:1703.04664, see
:mod:`repro.core.dmh`) needs O(N + m): each non-zero is binned into its
sample index by ONE u32 hash, scored by ICWS variates drawn at that single
t = bin, and each of the m bins keeps its minimum; empty bins then borrow
from occupied ones through a reseeded 2-universal probe sequence (uniform
borrowing, not the biased rotation).

Grid: ``(B/BR, N/BN)`` -- deliberately NO m grid dimension.  The whole
m-bin state ``[BR, BM]`` (BM = m rounded up to a lane multiple) stays
resident in VMEM across the sequential non-zero steps; that residency is
what converts the ICWS kernel's per-(lane x sample) hashing into per-lane
hashing.  Each step draws the 5 uniforms on the ``[BR, BN]`` lane tile,
masks one ``[BR, BM, BN]`` bin-equality cross for the per-bin argmin, and
min-merges winners into the running blocks with strict ``<`` (earlier
tiles win ties -- the oracle's first-index argmin order).  Winner payloads
(key / level / value) are gathered from the lane tile, not one-hot
reduced, so the cross tensor count stays ~3 against ICWS's ~6 at 1/m-th
the draw work.

At the last non-zero step a densification epilogue runs entirely in VMEM
(probes chunked 128 wide to bound temporaries), and with ``pack_vals=True``
the bf16 pack epilogue mirrors the ICWS one.  The output wire layout is
identical to ICWS -- ``(fp, val, amin, argkey)`` -- so every estimate /
packed / sharded launch consumes DMH rows unchanged.

VMEM budget per step (f32): inputs ``3 * BR*BN`` + outputs ``4 * BR*BM`` +
~3 ``[BR, BM, BN]`` cross temporaries; the epilogue adds ``[BR, BM, 128]``
probe chunks.  Results are bitwise independent of BR and BN (global
first-min per bin); BM only pads (inert bins, sliced off) and the probe
budget is a pure function of m (:func:`repro.kernels.common.
densify_probes`), never tuned.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import (DMH_BETA_STREAM, DMH_BIN_STREAM, DMH_C1_STREAM,
                     DMH_C2_STREAM, DMH_DENSIFY_STREAM, DMH_FP_STREAM,
                     DMH_R1_STREAM, DMH_R2_STREAM, densify_probes, hash_u32,
                     salt_for, uniform01)
from .packed import pack_halfwords_f32
from .ref import BIG

_PROBE_CHUNK = 128   # probe lanes materialized at once in the epilogue


def _densify(fp_ref, out_val_ref, amin_ref, out_key_ref, *, seed: int,
             m_live: int, bm: int, jprobe: int):
    """Fill empty bins from occupied ones (optimal densification).

    Probes ``src = h(t; j) mod m`` for j = 0..jprobe-1; the first probe
    landing on an occupied bin is the borrow source.  If every probe
    misses, fall back to the first occupied bin (exact when exactly one
    bin is occupied; coordinated regardless).  Rows with no occupied bin
    at all are left untouched (the wrapper's empty fixup emits -1).
    """
    occ = amin_ref[:, :] < BIG                             # [BR, BM]
    t = jax.lax.iota(jnp.int32, bm)
    tu = t.astype(jnp.uint32)
    best_j = jnp.full(occ.shape, jprobe, jnp.int32)
    for j0 in range(0, jprobe, _PROBE_CHUNK):
        js = j0 + jax.lax.iota(jnp.int32, _PROBE_CHUNK)
        psalt = salt_for(seed, DMH_DENSIFY_STREAM, js)     # [CHUNK]
        src = (hash_u32(tu[:, None], psalt[None, :])
               % jnp.uint32(m_live)).astype(jnp.int32)     # [BM, CHUNK]
        hit = jnp.take(occ, src, axis=1)                   # [BR, BM, CHUNK]
        found = jnp.any(hit, axis=2)
        firstj = j0 + jnp.argmax(hit, axis=2).astype(jnp.int32)
        best_j = jnp.where((best_j == jprobe) & found, firstj, best_j)
    has = best_j < jprobe
    salt_w = salt_for(seed, DMH_DENSIFY_STREAM, jnp.where(has, best_j, 0))
    src_w = (hash_u32(tu[None, :], salt_w)
             % jnp.uint32(m_live)).astype(jnp.int32)       # [BR, BM]
    fallback = jnp.argmax(occ, axis=1).astype(jnp.int32)[:, None]
    src_sel = jnp.where(has, src_w, fallback)
    need = (~occ) & jnp.any(occ, axis=1)[:, None]

    for ref_ in (fp_ref, out_val_ref, out_key_ref, amin_ref):
        cur = ref_[:, :]
        ref_[:, :] = jnp.where(
            need, jnp.take_along_axis(cur, src_sel, axis=1), cur)


def _dmh_kernel(w_ref, key_ref, val_ref, fp_ref, out_val_ref, amin_ref,
                out_key_ref, *, seed: int, m_live: int, bm: int, bn: int,
                n_steps: int, jprobe: int):
    n_idx = pl.program_id(1)

    w = w_ref[:, :]                                        # [BR, BN]
    keys = key_ref[:, :]                                   # [BR, BN] int32
    vals = val_ref[:, :]                                   # [BR, BN]
    kk = keys.astype(jnp.uint32)

    bin_salt = salt_for(seed, DMH_BIN_STREAM, jnp.uint32(0))
    bins = (hash_u32(kk, bin_salt)
            % jnp.uint32(m_live)).astype(jnp.int32)        # [BR, BN]

    def u(stream):
        # variates at t = bin: one draw per LANE, not per (lane, sample)
        return uniform01(kk, salt_for(seed, stream, bins))

    r = -jnp.log(u(DMH_R1_STREAM) * u(DMH_R2_STREAM))
    c = -jnp.log(u(DMH_C1_STREAM) * u(DMH_C2_STREAM))
    beta = u(DMH_BETA_STREAM)
    logw = jnp.log(jnp.maximum(w, 1e-37))
    lvl = jnp.floor(logw / r + beta)
    y = jnp.exp(r * (lvl - beta))
    a = c / (y * jnp.exp(r))
    a = jnp.where(w > 0, a, BIG)                           # mask padding

    t = jax.lax.iota(jnp.int32, bm)
    am = jnp.where(bins[:, None, :] == t[None, :, None],
                   a[:, None, :], BIG)                     # [BR, BM, BN]
    arg = jnp.argmin(am, axis=2)                           # [BR, BM]
    amin = jnp.min(am, axis=2)
    key_sel = jnp.take_along_axis(keys, arg, axis=1)       # [BR, BM]
    lvl_sel = jnp.take_along_axis(lvl, arg, axis=1)
    val_sel = jnp.take_along_axis(vals, arg, axis=1)

    fpbits = hash_u32(
        key_sel.astype(jnp.uint32)
        ^ (lvl_sel.astype(jnp.int32).astype(jnp.uint32)
           * jnp.uint32(0x9E3779B9)),
        salt_for(seed, DMH_FP_STREAM, t)[None, :])
    # 31-bit fingerprint: non-negative int32 (see ref.dmh_sketch_ref)
    fp = (fpbits & jnp.uint32(0x7FFFFFFF)).astype(jnp.int32)

    @pl.when(n_idx == 0)
    def _init():
        amin_ref[:, :] = amin
        fp_ref[:, :] = fp
        out_val_ref[:, :] = val_sel
        out_key_ref[:, :] = key_sel

    @pl.when(n_idx != 0)
    def _merge():
        better = amin < amin_ref[:, :]
        amin_ref[:, :] = jnp.where(better, amin, amin_ref[:, :])
        fp_ref[:, :] = jnp.where(better, fp, fp_ref[:, :])
        out_val_ref[:, :] = jnp.where(better, val_sel, out_val_ref[:, :])
        out_key_ref[:, :] = jnp.where(better, key_sel, out_key_ref[:, :])

    @pl.when(n_idx == n_steps - 1)
    def _fill():
        _densify(fp_ref, out_val_ref, amin_ref, out_key_ref, seed=seed,
                 m_live=m_live, bm=bm, jprobe=jprobe)


def _dmh_kernel_packed(w_ref, key_ref, val_ref, fp_ref, out_val_ref,
                       amin_ref, out_key_ref, packed_ref, *, seed: int,
                       m_live: int, bm: int, bn: int, n_steps: int,
                       jprobe: int):
    """The DMH kernel plus the bf16 pack-on-output epilogue (the ICWS
    ``pack_vals`` epilogue, run after densification so borrowed bins pack
    their borrowed values).  Bins beyond ``m_live`` and empty rows are
    zeroed before packing, matching ``pack_rows``' zero pad bit for bit."""
    _dmh_kernel(w_ref, key_ref, val_ref, fp_ref, out_val_ref, amin_ref,
                out_key_ref, seed=seed, m_live=m_live, bm=bm, bn=bn,
                n_steps=n_steps, jprobe=jprobe)
    n_idx = pl.program_id(1)

    @pl.when(n_idx == n_steps - 1)
    def _pack():
        t = jax.lax.iota(jnp.int32, bm)
        v = out_val_ref[:, :]
        v = jnp.where((t < m_live)[None, :], v, 0.0)
        v = jnp.where(amin_ref[:, :] >= BIG, 0.0, v)
        packed_ref[:, :] = pack_halfwords_f32(v)


@functools.partial(jax.jit, static_argnames=("m", "seed", "br", "bm", "bn",
                                             "pack_vals", "interpret"))
def dmh_sketch_pallas(w, keys, vals, *, m: int, seed: int, br: int = 1,
                      bm: int = 128, bn: int = 256,
                      pack_vals: bool = False, interpret: bool = True):
    """Batched DMH sketch via Pallas.  See :func:`repro.kernels.ref.dmh_sketch_ref`.

    Args: w/keys/vals [B, N] (padded here to ``br``/``bn`` multiples);
    returns (fp [B, m] int32, val [B, m] f32, amin [B, m] f32, argkey
    [B, m] int32) -- the ICWS wire layout; borrowed (densified) bins carry
    their source bin's payload, and ``argkey`` doubles as the occupancy
    witness the merge path recovers origins from.  ``bm`` must cover m in
    one block (the bin state is VMEM-resident; there is no m grid axis);
    results are bitwise identical for every (br, bm, bn) choice.

    With ``pack_vals=True`` a fifth output is appended: ``[B, (m + m % 2)
    // 2]`` i32 bf16-halfword packed values, bitwise equal to
    ``pack_halfwords_f32`` of the zero-padded ``val`` output.
    """
    B, N = w.shape
    if bm % 128 or bm < m:
        raise ValueError(f"bm must be a lane multiple covering m; "
                         f"got bm={bm}, m={m}")
    n_pad = (-N) % bn
    b_pad = (-B) % br
    if n_pad or b_pad:
        w = jnp.pad(w, ((0, b_pad), (0, n_pad)))
        keys = jnp.pad(keys, ((0, b_pad), (0, n_pad)))
        vals = jnp.pad(vals, ((0, b_pad), (0, n_pad)))
    Bp, Np = w.shape

    grid = (Bp // br, Np // bn)
    jprobe = densify_probes(m)
    kw = dict(seed=seed, m_live=m, bm=bm, bn=bn, n_steps=Np // bn,
              jprobe=jprobe)
    if pack_vals:
        kernel = functools.partial(_dmh_kernel_packed, **kw)
        fp, val, amin, key, packed = pl.pallas_call(
            kernel,
            grid=grid,
            in_specs=[
                pl.BlockSpec((br, bn), lambda b, ni: (b, ni)),
                pl.BlockSpec((br, bn), lambda b, ni: (b, ni)),
                pl.BlockSpec((br, bn), lambda b, ni: (b, ni)),
            ],
            out_specs=[
                pl.BlockSpec((br, bm), lambda b, ni: (b, 0)),
                pl.BlockSpec((br, bm), lambda b, ni: (b, 0)),
                pl.BlockSpec((br, bm), lambda b, ni: (b, 0)),
                pl.BlockSpec((br, bm), lambda b, ni: (b, 0)),
                pl.BlockSpec((br, bm // 2), lambda b, ni: (b, 0)),
            ],
            out_shape=[
                jax.ShapeDtypeStruct((Bp, bm), jnp.int32),
                jax.ShapeDtypeStruct((Bp, bm), jnp.float32),
                jax.ShapeDtypeStruct((Bp, bm), jnp.float32),
                jax.ShapeDtypeStruct((Bp, bm), jnp.int32),
                jax.ShapeDtypeStruct((Bp, bm // 2), jnp.int32),
            ],
            interpret=interpret,
        )(w.astype(jnp.float32), keys.astype(jnp.int32),
          vals.astype(jnp.float32))
    else:
        kernel = functools.partial(_dmh_kernel, **kw)
        fp, val, amin, key = pl.pallas_call(
            kernel,
            grid=grid,
            in_specs=[
                pl.BlockSpec((br, bn), lambda b, ni: (b, ni)),
                pl.BlockSpec((br, bn), lambda b, ni: (b, ni)),
                pl.BlockSpec((br, bn), lambda b, ni: (b, ni)),
            ],
            out_specs=[
                pl.BlockSpec((br, bm), lambda b, ni: (b, 0)),
                pl.BlockSpec((br, bm), lambda b, ni: (b, 0)),
                pl.BlockSpec((br, bm), lambda b, ni: (b, 0)),
                pl.BlockSpec((br, bm), lambda b, ni: (b, 0)),
            ],
            out_shape=[
                jax.ShapeDtypeStruct((Bp, bm), jnp.int32),
                jax.ShapeDtypeStruct((Bp, bm), jnp.float32),
                jax.ShapeDtypeStruct((Bp, bm), jnp.float32),
                jax.ShapeDtypeStruct((Bp, bm), jnp.int32),
            ],
            interpret=interpret,
        )(w.astype(jnp.float32), keys.astype(jnp.int32),
          vals.astype(jnp.float32))
        packed = None

    fp, val, amin, key = fp[:B, :m], val[:B, :m], amin[:B, :m], key[:B, :m]
    empty = amin >= BIG
    outs = (jnp.where(empty, -1, fp), jnp.where(empty, 0.0, val), amin,
            jnp.where(empty, 0, key))
    if pack_vals:
        me = m + (m % 2)
        return outs + (packed[:B, :me // 2],)
    return outs


@functools.partial(jax.jit, static_argnames=("m", "seed", "pack_vals"))
def dmh_sketch_scatter(w, keys, vals, *, m: int, seed: int,
                       pack_vals: bool = False):
    """Scatter-min lowering of the DMH sketch -- same contract, O(nnz + m).

    The Pallas kernel realizes the per-bin argmin as a ``[BR, BM, BN]``
    bin-equality cross because TPU Pallas has no scatter primitive; the
    VPU evaluates that cross across its 8x128 lanes essentially for free,
    but interpret mode (and any non-TPU backend) must materialize it --
    re-introducing the O(nnz * m) work DMH exists to avoid.  This jnp
    builder is the genuinely linear form of the SAME computation: one
    XLA ``scatter-min`` per bin plane instead of the broadcast, winner =
    minimum ``a`` per bin with ties to the lowest lane index, which is
    exactly the kernel's strict-< tile order and the oracle's first-hit
    argmin.  Outputs match :func:`dmh_sketch_pallas` plane for plane
    (fingerprints / argkeys bitwise; ``val``/``amin`` to transcendental
    rounding); :mod:`repro.kernels.ops` dispatches here exactly where it
    would have forced ``interpret=True`` on the kernel.
    """
    B, N = w.shape
    w = w.astype(jnp.float32)
    vals = vals.astype(jnp.float32)
    kk = keys.astype(jnp.uint32)
    bins = (hash_u32(kk, salt_for(seed, DMH_BIN_STREAM, jnp.uint32(0)))
            % jnp.uint32(m)).astype(jnp.int32)                # [B, N]

    def u(stream):
        return uniform01(kk, salt_for(seed, stream, bins))

    r = -jnp.log(u(DMH_R1_STREAM) * u(DMH_R2_STREAM))
    c = -jnp.log(u(DMH_C1_STREAM) * u(DMH_C2_STREAM))
    beta = u(DMH_BETA_STREAM)
    logw = jnp.log(jnp.maximum(w, 1e-37))
    lvl = jnp.floor(logw / r + beta)
    y = jnp.exp(r * (lvl - beta))
    a = jnp.where(w > 0, c / (y * jnp.exp(r)), BIG).astype(jnp.float32)

    # per-bin first-min via two scatter-mins: the min itself, then the
    # lowest lane index attaining it (ties break like np.argmin)
    seg = (jnp.arange(B, dtype=jnp.int32)[:, None] * m + bins).reshape(-1)
    amin = (jnp.full(B * m, BIG, jnp.float32).at[seg].min(a.reshape(-1))
            .reshape(B, m))
    hit = a == jnp.take(amin.reshape(-1), seg).reshape(B, N)
    lane = jnp.broadcast_to(jnp.arange(N, dtype=jnp.int32), (B, N))
    arg = (jnp.full(B * m, N, jnp.int32).at[seg]
           .min(jnp.where(hit, lane, N).reshape(-1)).reshape(B, m))
    arg = jnp.minimum(arg, N - 1)     # bins no lane mapped to: inert gather

    key_sel = jnp.take_along_axis(keys.astype(jnp.int32), arg, axis=1)
    lvl_sel = jnp.take_along_axis(lvl, arg, axis=1)
    val_sel = jnp.take_along_axis(vals, arg, axis=1)
    t = jnp.arange(m, dtype=jnp.int32)
    fpbits = hash_u32(
        key_sel.astype(jnp.uint32)
        ^ (lvl_sel.astype(jnp.int32).astype(jnp.uint32)
           * jnp.uint32(0x9E3779B9)),
        salt_for(seed, DMH_FP_STREAM, t)[None, :])
    fp = (fpbits & jnp.uint32(0x7FFFFFFF)).astype(jnp.int32)

    # densification epilogue, jnp twin of the in-kernel one
    occ = amin < BIG                                          # [B, m]
    J = densify_probes(m)
    psalt = salt_for(seed, DMH_DENSIFY_STREAM, jnp.arange(J, dtype=jnp.int32))
    src = (hash_u32(t[:, None].astype(jnp.uint32), psalt[None, :])
           % jnp.uint32(m)).astype(jnp.int32)                 # [m, J]
    occ_p = jnp.take(occ, src, axis=1)                        # [B, m, J]
    has = jnp.any(occ_p, axis=2)
    firstj = jnp.argmax(occ_p, axis=2).astype(jnp.int32)
    src_w = (hash_u32(t.astype(jnp.uint32),
                      salt_for(seed, DMH_DENSIFY_STREAM, firstj))
             % jnp.uint32(m)).astype(jnp.int32)
    fallback = jnp.argmax(occ, axis=1).astype(jnp.int32)[:, None]
    src_sel = jnp.where(has, src_w, fallback)
    need = (~occ) & jnp.any(occ, axis=1)[:, None]

    def borrow(x):
        return jnp.where(need, jnp.take_along_axis(x, src_sel, axis=1), x)

    fp, val_sel, key_sel, amin = (borrow(fp), borrow(val_sel),
                                  borrow(key_sel), borrow(amin))
    empty = amin >= BIG
    outs = (jnp.where(empty, -1, fp), jnp.where(empty, 0.0, val_sel), amin,
            jnp.where(empty, 0, key_sel))
    if pack_vals:
        me = m + (m % 2)
        padded = jnp.pad(outs[1], ((0, 0), (0, me - m)))
        return outs + (pack_halfwords_f32(padded),)
    return outs
