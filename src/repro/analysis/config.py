"""Analysis configuration + the pinned-allowlist (baseline) loader.

The baseline file is TOML, but this package must run with *zero*
third-party imports on Python 3.10 (no ``tomllib`` until 3.11, and the CI
lint job installs nothing).  We therefore parse the narrow subset the
baseline actually uses -- ``[[exempt]]`` array-of-tables with quoted
string values -- with a ~40-line reader.  Anything outside that subset is
a hard config error (exit 2), never a silent pass.
"""
from __future__ import annotations

import dataclasses
import pathlib
from typing import Dict, List, Optional, Tuple

# Directories scanned for enforced source rules, repo-relative.  Tests and
# benchmarks are deliberately *not* here for CB004/SR005 (interpret=True
# and ad-hoc streams are fine in test code); family-contract sweeps name
# their files explicitly.
SRC_DIRS = ("src",)

# Stream registry geography (repo-relative).
DEVICE_REGISTRY = "src/repro/kernels/common.py"
HOST_REGISTRIES = (
    "src/repro/core/u32.py",
    "src/repro/core/linear.py",
    "src/repro/core/sampling.py",
)

# Family-contract geography.
FAMILIES_MODULE = "src/repro/data/families.py"
SWEEP_FILES = (
    "tests/test_families.py",
    "tests/test_sharded_query.py",
    "benchmarks/perf_sketch.py",
)

# compat boundary: the one module allowed to touch version-gated jax APIs.
COMPAT_MODULE = "src/repro/compat.py"


@dataclasses.dataclass
class Config:
    root: pathlib.Path
    # Per-pallas_call budget for the summed BlockSpec block I/O, bytes.
    # ~2 MiB leaves ample headroom inside the ~16 MiB/core VMEM once the
    # compiler's double-buffering and kernel intermediates are accounted.
    vmem_block_budget: int = 2 * 1024 * 1024
    rules: Tuple[str, ...] = ()        # prefix filter; empty = all
    baseline_path: Optional[pathlib.Path] = None

    def baseline_file(self) -> pathlib.Path:
        if self.baseline_path is not None:
            return self.baseline_path
        return pathlib.Path(__file__).parent / "baseline.toml"

    def wants(self, rule: str) -> bool:
        return not self.rules or any(rule.startswith(p) for p in self.rules)


@dataclasses.dataclass(frozen=True)
class BaselineEntry:
    rule: str
    path: str
    reason: str
    match: str = ""        # substring of the finding message; "" = any
    line: int = 0          # line in baseline.toml (for BL001 anchoring)

    def covers(self, rule: str, path: str, message: str) -> bool:
        return (self.rule == rule and self.path == path
                and (not self.match or self.match in message))


class BaselineError(ValueError):
    """Malformed baseline file -- a config error, not a finding."""


def _parse_value(raw: str, lineno: int) -> str:
    raw = raw.strip()
    if len(raw) >= 2 and raw[0] == raw[-1] and raw[0] in ("'", '"'):
        return raw[1:-1]
    raise BaselineError(
        f"baseline.toml:{lineno}: expected a quoted string value, got {raw!r}")


def parse_baseline(text: str) -> List[BaselineEntry]:
    entries: List[BaselineEntry] = []
    current: Optional[Dict[str, object]] = None

    def flush():
        nonlocal current
        if current is None:
            return
        missing = [k for k in ("rule", "path", "reason") if k not in current]
        if missing:
            raise BaselineError(
                f"baseline.toml:{current['_line']}: [[exempt]] entry missing "
                f"required key(s): {', '.join(missing)}")
        entries.append(BaselineEntry(
            rule=str(current["rule"]), path=str(current["path"]),
            reason=str(current["reason"]), match=str(current.get("match", "")),
            line=int(current["_line"])))
        current = None

    for lineno, line in enumerate(text.splitlines(), start=1):
        stripped = line.split("#", 1)[0].strip() if not line.lstrip().startswith("#") \
            else ""
        if not stripped:
            continue
        if stripped == "[[exempt]]":
            flush()
            current = {"_line": lineno}
            continue
        if stripped.startswith("["):
            raise BaselineError(
                f"baseline.toml:{lineno}: only [[exempt]] tables are "
                f"supported, got {stripped!r}")
        if "=" not in stripped:
            raise BaselineError(
                f"baseline.toml:{lineno}: expected `key = \"value\"`")
        if current is None:
            raise BaselineError(
                f"baseline.toml:{lineno}: key outside an [[exempt]] table")
        key, raw = stripped.split("=", 1)
        key = key.strip()
        if key not in ("rule", "path", "match", "reason"):
            raise BaselineError(
                f"baseline.toml:{lineno}: unknown key {key!r} "
                f"(allowed: rule, path, match, reason)")
        current[key] = _parse_value(raw, lineno)
    flush()
    return entries


def load_baseline(path: pathlib.Path) -> List[BaselineEntry]:
    if not path.exists():
        return []
    return parse_baseline(path.read_text())
