"""``repro.analysis``: the repo's invariants as machine-checked lint rules.

The accuracy story of this reproduction (WMH beating CountSketch/JL on
sparse vectors, arXiv:2301.05811; TS/PS beating both, arXiv:2309.16157)
rests on *implementation* invariants that no single runtime test sees
whole: host oracles must be bit-twins of the Pallas kernels, u32 hash
streams must never collide across the five sketch families, every serving
path must stay behind ``repro/compat.py``, and kernel BlockSpecs must fit
VMEM.  This package turns those standing invariants into an AST-based
static-analysis pass -- pure stdlib, **no jax import**, <2s on the whole
repo -- runnable anywhere (including a CI job with nothing installed):

    python -m repro.analysis --strict

Rule groups (see ``repro.analysis.findings.RULES`` or ``--list-rules``):

* ``SR*`` stream-registry  -- every u32 salt stream is a named ``*_STREAM``
  constant in ``kernels/common.py`` with an identically named, identically
  valued host twin in ``core/``; IDs are globally unique; call sites never
  inline literals.  Generates the ``STREAMS.md`` registry table.
* ``CB*`` compat-boundary  -- version-gated jax APIs (``jax.shard_map``,
  ``jax.sharding.AxisType``, ``jax.make_mesh``) only inside
  ``repro/compat.py``; no hardcoded ``interpret=True`` call sites in src.
* ``PB*`` pallas-budget    -- per-kernel VMEM block footprint statically
  bounded from BlockSpec shapes x dtypes against a configurable budget;
  emits the per-kernel report the block-size autotuner consumes.
* ``FC*`` family-contract  -- every name in ``FAMILY_NAMES`` has a complete
  ``SketchFamily`` implementation and appears in the parameterized
  test/bench sweeps, so a sixth family cannot be half-registered.
* ``BL*`` baseline hygiene -- stale allowlist entries are themselves
  findings, keeping ``analysis/baseline.toml`` honest and diffable.

True exceptions are pinned in ``baseline.toml`` next to this module, one
entry per finding with a written justification.
"""
from __future__ import annotations

from .config import Config, load_baseline
from .engine import AnalysisResult, run
from .findings import RULES, Finding

__all__ = ["AnalysisResult", "Config", "Finding", "RULES", "load_baseline",
           "run"]
