"""Finding record + the rule catalogue every checker registers under."""
from __future__ import annotations

import dataclasses

# One-line description per rule ID.  Checker modules own their group prefix;
# this central table is what ``--list-rules`` prints and what README's
# "Invariants as code" section documents.
RULES = {
    # stream-registry (repro.analysis.streams)
    "SR001": "u32 stream IDs must be globally unique within a registry side "
             "(two draws sharing an ID share their randomness)",
    "SR002": "host-oracle stream constant has no identically named device "
             "mirror in kernels/common.py",
    "SR003": "device stream constant has no identically named host twin in "
             "core/ (u32.py, linear.py, sampling.py)",
    "SR004": "host and device stream constants with the same name disagree "
             "on the stream ID",
    "SR005": "inline u32 stream literal at a call site; route it through a "
             "named *_STREAM constant of the registry",
    "SR006": "STREAMS.md is stale: regenerate with "
             "`python -m repro.analysis --write-streams`",
    # compat-boundary (repro.analysis.compat)
    "CB001": "direct jax shard_map use outside repro/compat.py (0.4.x spells "
             "it jax.experimental.shard_map with check_rep)",
    "CB002": "direct jax.sharding.AxisType use outside repro/compat.py "
             "(absent on jax 0.4.x)",
    "CB003": "direct jax.make_mesh use outside repro/compat.py (axis_types "
             "kwarg is version-gated)",
    "CB004": "hardcoded interpret=True call site under src/ (dispatch "
             "belongs to repro.kernels.ops._interpret)",
    # pallas-budget (repro.analysis.budget)
    "PB001": "pallas_call block working set exceeds the configured VMEM "
             "block budget",
    "PB002": "pallas_call block shape cannot be statically bounded "
             "(runtime-dependent dimension)",
    # family-contract (repro.analysis.families)
    "FC001": "family in FAMILY_NAMES lacks a complete SketchFamily "
             "implementation",
    "FC002": "family in FAMILY_NAMES is not constructible via make_family",
    "FC003": "family in FAMILY_NAMES is missing from a parameterized "
             "test/bench sweep",
    # observability coverage (repro.analysis.obs)
    "OB001": "public kernels/ops.py launch wrapper is missing the "
             "@instrumented decorator (or declares a mismatched op name)",
    "OB002": "METRICS.md is stale against the obs/registry.py SPECS table: "
             "regenerate with `python -m repro.analysis --write-metrics`",
    # baseline hygiene (repro.analysis.engine)
    "BL001": "baseline.toml entry matches no current finding; delete it",
}


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation, anchored to a repo-relative file:line."""

    rule: str
    path: str          # repo-relative, posix separators
    line: int
    message: str

    def format(self) -> str:
        return f"{self.path}:{self.line}: {self.rule} {self.message}"

    def sort_key(self):
        return (self.path, self.line, self.rule, self.message)
