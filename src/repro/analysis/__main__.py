"""CLI entry point: ``python -m repro.analysis [--strict] [...]``.

Exit codes: 0 clean (or report-only mode), 1 actionable findings under
``--strict``, 2 configuration error (bad baseline, bad root).
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

from .config import BaselineError, Config
from .engine import METRICS_MD, STREAMS_MD, run
from .findings import RULES


def _find_root(start: pathlib.Path) -> pathlib.Path:
    """Walk up from ``start`` to the checkout root (the dir holding src/)."""
    p = start.resolve()
    for cand in (p, *p.parents):
        if (cand / "src" / "repro").is_dir():
            return cand
    return p


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Static invariant checks for the repro codebase "
                    "(stream registry, compat boundary, pallas VMEM "
                    "budget, family contract). Pure stdlib; no jax.")
    ap.add_argument("--root", type=pathlib.Path, default=None,
                    help="checkout root (default: auto-detect upward from "
                         "cwd)")
    ap.add_argument("--strict", action="store_true",
                    help="exit 1 when non-baselined findings exist")
    ap.add_argument("--rules", default="",
                    help="comma-separated rule-ID prefixes to run "
                         "(e.g. SR,CB); default all")
    ap.add_argument("--baseline", type=pathlib.Path, default=None,
                    help="override the baseline.toml allowlist path")
    ap.add_argument("--vmem-budget", type=int, default=None,
                    help="per-pallas_call block I/O budget in bytes "
                         "(default 2 MiB)")
    ap.add_argument("--write-streams", action="store_true",
                    help="(re)write STREAMS.md at the root and exit")
    ap.add_argument("--write-metrics", action="store_true",
                    help="(re)write METRICS.md (the obs metric registry) "
                         "at the root")
    ap.add_argument("--budget-report", type=pathlib.Path, default=None,
                    help="write the per-kernel VMEM budget report (JSON)")
    ap.add_argument("--json", dest="json_out", type=pathlib.Path,
                    default=None, help="write findings as JSON")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule catalogue and exit")
    args = ap.parse_args(argv)

    if args.list_rules:
        for rule in sorted(RULES):
            print(f"{rule}  {RULES[rule]}")
        return 0

    root = _find_root(args.root or pathlib.Path.cwd())
    if not (root / "src" / "repro").is_dir():
        print(f"error: {root} does not look like the repo checkout "
              f"(no src/repro/)", file=sys.stderr)
        return 2

    cfg = Config(root=root, baseline_path=args.baseline)
    if args.vmem_budget is not None:
        cfg.vmem_block_budget = args.vmem_budget
    if args.rules:
        cfg.rules = tuple(p.strip() for p in args.rules.split(",") if p.strip())

    t0 = time.monotonic()
    try:
        result = run(cfg)
    except BaselineError as e:
        print(f"error: {e}", file=sys.stderr)
        return 2
    dt = time.monotonic() - t0

    if args.write_streams:
        (root / STREAMS_MD).write_text(result.streams_md)
        print(f"wrote {root / STREAMS_MD}")
        # fall through: still report findings (a fresh STREAMS.md clears
        # SR006 on the next run, not this one)

    if args.write_metrics:
        if not result.metrics_md:
            print("error: no obs metric registry found "
                  "(src/repro/obs/registry.py)", file=sys.stderr)
            return 2
        (root / METRICS_MD).write_text(result.metrics_md)
        print(f"wrote {root / METRICS_MD}")
        # fall through, same contract as --write-streams

    if args.budget_report is not None:
        args.budget_report.parent.mkdir(parents=True, exist_ok=True)
        args.budget_report.write_text(
            json.dumps(result.budget_report, indent=2) + "\n")
        print(f"wrote {args.budget_report} "
              f"({len(result.budget_report)} pallas_call sites)")

    if args.json_out is not None:
        payload = {
            "findings": [vars(f) for f in result.findings],
            "baselined": [{**vars(f), "reason": e.reason}
                          for f, e in result.baselined],
            "budget_report": result.budget_report,
        }
        args.json_out.parent.mkdir(parents=True, exist_ok=True)
        args.json_out.write_text(json.dumps(payload, indent=2) + "\n")

    for f in result.findings:
        print(f.format())
    n_base = len(result.baselined)
    n_sites = len(result.budget_report)
    status = "clean" if result.ok else f"{len(result.findings)} finding(s)"
    print(f"repro.analysis: {status}, {n_base} baselined, "
          f"{n_sites} pallas_call sites budgeted, {dt:.2f}s")
    if args.strict and not result.ok:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
