"""Shared AST plumbing for the analysis checkers.

A :class:`Repo` parses every ``.py`` file once and hands the cached
:class:`ParsedFile` objects to each checker, so the whole pass stays well
under the 2s budget.  Helpers here are deliberately conservative: when a
value cannot be resolved statically they return ``None`` and let the
checker decide whether that is a finding (pallas-budget) or a pass
(everything else).
"""
from __future__ import annotations

import ast
import dataclasses
import pathlib
from typing import Dict, Iterable, List, Optional, Tuple


@dataclasses.dataclass
class ParsedFile:
    path: pathlib.Path       # absolute
    rel: str                 # repo-relative, posix separators
    tree: ast.AST
    source: str


class Repo:
    """Parse-once cache over a file tree."""

    def __init__(self, root: pathlib.Path, scan_dirs: Iterable[str]):
        self.root = pathlib.Path(root).resolve()
        self.files: List[ParsedFile] = []
        seen = set()
        for d in scan_dirs:
            base = self.root / d
            if not base.exists():
                continue
            paths = [base] if base.is_file() else sorted(base.rglob("*.py"))
            for p in paths:
                if p.suffix != ".py" or p in seen:
                    continue
                seen.add(p)
                try:
                    source = p.read_text()
                    tree = ast.parse(source, filename=str(p))
                except (SyntaxError, UnicodeDecodeError):
                    continue    # not ours to lint (e.g. fixture snippets)
                rel = p.relative_to(self.root).as_posix()
                self.files.append(ParsedFile(p, rel, tree, source))

    def get(self, rel: str) -> Optional[ParsedFile]:
        for f in self.files:
            if f.rel == rel:
                return f
        return None

    def matching(self, suffix: str) -> List[ParsedFile]:
        return [f for f in self.files if f.rel.endswith(suffix)]


def dotted_name(node: ast.AST) -> Optional[str]:
    """``jax.sharding.AxisType`` attribute chain -> its dotted string."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def eval_int(node: ast.AST, env: Dict[str, int]) -> Optional[int]:
    """Fold an expression to an int given a name environment, else None.

    Supports literals, names, unary +/-, and the + - * // arithmetic that
    shows up in block-size expressions.  ``min``/``max`` calls fold when
    every argument folds (used for clamped block sizes -- the result is
    exact, and for budget purposes a declared default is an upper bound
    anyway).
    """
    if isinstance(node, ast.Constant) and isinstance(node.value, int) \
            and not isinstance(node.value, bool):
        return node.value
    if isinstance(node, ast.Name):
        return env.get(node.id)
    if isinstance(node, ast.UnaryOp) and isinstance(node.op, (ast.USub, ast.UAdd)):
        v = eval_int(node.operand, env)
        if v is None:
            return None
        return -v if isinstance(node.op, ast.USub) else v
    if isinstance(node, ast.BinOp):
        a, b = eval_int(node.left, env), eval_int(node.right, env)
        if a is None or b is None:
            return None
        if isinstance(node.op, ast.Add):
            return a + b
        if isinstance(node.op, ast.Sub):
            return a - b
        if isinstance(node.op, ast.Mult):
            return a * b
        if isinstance(node.op, ast.FloorDiv) and b != 0:
            return a // b
        return None
    if isinstance(node, ast.Call) and isinstance(node.func, ast.Name) \
            and node.func.id in ("min", "max") and not node.keywords:
        vals = [eval_int(a, env) for a in node.args]
        if any(v is None for v in vals) or not vals:
            return None
        return (min if node.func.id == "min" else max)(vals)
    return None


def module_int_env(tree: ast.AST) -> Dict[str, int]:
    """Top-level ``NAME = <int expr>`` constants of a module."""
    env: Dict[str, int] = {}
    for stmt in getattr(tree, "body", []):
        if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1 \
                and isinstance(stmt.targets[0], ast.Name):
            v = eval_int(stmt.value, env)
            if v is not None:
                env[stmt.targets[0].id] = v
        elif isinstance(stmt, ast.AnnAssign) and isinstance(stmt.target, ast.Name) \
                and stmt.value is not None:
            v = eval_int(stmt.value, env)
            if v is not None:
                env[stmt.target.id] = v
    return env


def function_default_env(fn: ast.FunctionDef) -> Dict[str, int]:
    """Int-valued parameter defaults of a function (``bq=8, bm=128, ...``)."""
    env: Dict[str, int] = {}
    a = fn.args
    pos = a.posonlyargs + a.args
    for arg, default in zip(pos[len(pos) - len(a.defaults):], a.defaults):
        v = eval_int(default, {})
        if v is not None:
            env[arg.arg] = v
    for arg, default in zip(a.kwonlyargs, a.kw_defaults):
        if default is not None:
            v = eval_int(default, {})
            if v is not None:
                env[arg.arg] = v
    return env


def enclosing_functions(tree: ast.AST) -> Dict[ast.AST, ast.FunctionDef]:
    """Map every AST node to its innermost enclosing function def."""
    owner: Dict[ast.AST, ast.FunctionDef] = {}

    def visit(node: ast.AST, current: Optional[ast.FunctionDef]):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            current = node
        for child in ast.iter_child_nodes(node):
            if current is not None:
                owner[child] = current
            visit(child, current)

    visit(tree, None)
    return owner


def int_assignments(tree: ast.AST, names: Tuple[str, ...] = ()) -> List[Tuple[str, int, int]]:
    """All ``NAME = <int literal>`` assignments anywhere in a module.

    Returns ``(name, value, lineno)`` triples; used by the stream-registry
    checker, which must see constants wherever they are defined.
    """
    out: List[Tuple[str, int, int]] = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name):
            name = node.targets[0].id
            if names and not any(name.endswith(s) for s in names):
                continue
            v = eval_int(node.value, {})
            if v is not None:
                out.append((name, v, node.lineno))
    return out
