"""CB* compat-boundary checker.

``repro/compat.py`` is the single module allowed to spell version-gated
jax APIs: ``shard_map`` moved from ``jax.experimental.shard_map`` (0.4.x,
``check_rep``) to ``jax.shard_map`` (0.7.x, ``check_vma``), and
``jax.sharding.AxisType`` / ``jax.make_mesh(axis_types=...)`` do not exist
on the 0.4.x floor the CI matrix pins.  A direct use anywhere else breaks
one side of the matrix silently until that job runs; these rules catch it
at lint time.  CB004 additionally pins the ``interpret=True`` dispatch
convention: kernels decide interpret-vs-TPU at runtime through
``repro.kernels.ops._interpret()``, so a hardcoded ``interpret=True`` call
site under ``src/`` would pin a production path to the emulator.
"""
from __future__ import annotations

import ast
from typing import List

from . import config as cfg_mod
from .astutil import Repo, dotted_name
from .findings import Finding

# Dotted attribute chains that must not appear outside compat.py.  Matched
# against full attribute chains and against `from X import Y` forms.
_GATED_ATTRS = {
    "jax.shard_map": "CB001",
    "jax.experimental.shard_map": "CB001",
    "jax.experimental.shard_map.shard_map": "CB001",
    "jax.sharding.AxisType": "CB002",
    "jax.make_mesh": "CB003",
}
# (module, name) pairs for ImportFrom.
_GATED_IMPORTS = {
    ("jax", "shard_map"): "CB001",
    ("jax.experimental", "shard_map"): "CB001",
    ("jax.experimental.shard_map", "shard_map"): "CB001",
    ("jax.sharding", "AxisType"): "CB002",
    ("jax", "make_mesh"): "CB003",
}


def check(repo: Repo) -> List[Finding]:
    findings: List[Finding] = []
    for pf in repo.files:
        if not pf.rel.startswith("src/") or pf.rel == cfg_mod.COMPAT_MODULE:
            continue
        for node in ast.walk(pf.tree):
            if isinstance(node, ast.Attribute):
                chain = dotted_name(node)
                rule = _GATED_ATTRS.get(chain or "")
                if rule:
                    findings.append(Finding(
                        rule, pf.rel, node.lineno,
                        f"direct {chain} use; route through "
                        f"repro.compat"))
            elif isinstance(node, ast.ImportFrom) and node.module:
                for alias in node.names:
                    rule = _GATED_IMPORTS.get((node.module, alias.name))
                    if rule:
                        findings.append(Finding(
                            rule, pf.rel, node.lineno,
                            f"direct `from {node.module} import "
                            f"{alias.name}`; route through repro.compat"))
            elif isinstance(node, ast.Import):
                for alias in node.names:
                    if alias.name.startswith("jax.experimental.shard_map"):
                        findings.append(Finding(
                            "CB001", pf.rel, node.lineno,
                            f"direct `import {alias.name}`; route through "
                            f"repro.compat"))
            elif isinstance(node, ast.Call):
                for kw in node.keywords:
                    if kw.arg == "interpret" \
                            and isinstance(kw.value, ast.Constant) \
                            and kw.value.value is True:
                        findings.append(Finding(
                            "CB004", pf.rel, kw.value.lineno,
                            "hardcoded interpret=True call site; dispatch "
                            "via repro.kernels.ops._interpret()"))
    return findings
