"""Analysis driver: parse once, run every checker, apply the baseline.

Separated from ``__main__`` so tests (and future tooling, e.g. the
block-size autotuner reading the budget report) can call :func:`run`
directly on any root -- including tiny fixture trees.
"""
from __future__ import annotations

import dataclasses
import pathlib
from typing import Dict, List, Tuple

from . import budget as budget_mod
from . import compat as compat_mod
from . import config as cfg_mod
from . import families as families_mod
from . import obs as obs_mod
from . import streams as streams_mod
from .astutil import Repo
from .config import BaselineEntry, Config, load_baseline
from .findings import Finding

# Directories a full run parses.  src/ carries the enforced rules; tests/
# and benchmarks/ are parsed only as sweep evidence for FC003 (their own
# code is exempt from SR005/CB004 by the checkers' src/ scoping).
SCAN_DIRS = ("src", "tests", "benchmarks")

STREAMS_MD = "STREAMS.md"
METRICS_MD = "METRICS.md"


@dataclasses.dataclass
class AnalysisResult:
    findings: List[Finding]              # non-baselined (actionable)
    baselined: List[Tuple[Finding, BaselineEntry]]
    streams_md: str                      # rendered registry table
    budget_report: List[Dict]            # per-pallas_call VMEM accounting
    metrics_md: str = ""                 # rendered metric registry table

    @property
    def ok(self) -> bool:
        return not self.findings


def _apply_baseline(findings: List[Finding],
                    entries: List[BaselineEntry],
                    report_stale: bool = True):
    """Split findings into (actionable, baselined); unmatched baseline
    entries become BL001 findings so the allowlist cannot rot.  Stale
    reporting is suppressed under a ``--rules`` filter, where unmatched
    entries are expected (their rules never ran)."""
    used = [False] * len(entries)
    actionable: List[Finding] = []
    baselined: List[Tuple[Finding, BaselineEntry]] = []
    for f in findings:
        hit = None
        for i, e in enumerate(entries):
            if e.covers(f.rule, f.path, f.message):
                hit = e
                used[i] = True
                break
        if hit is None:
            actionable.append(f)
        else:
            baselined.append((f, hit))
    for e, u in zip(entries, used):
        if not u and report_stale:
            actionable.append(Finding(
                "BL001", "src/repro/analysis/baseline.toml", e.line,
                f"stale baseline entry (rule={e.rule}, path={e.path}"
                + (f", match={e.match!r}" if e.match else "") + ")"))
    return actionable, baselined


def run(cfg: Config) -> AnalysisResult:
    repo = Repo(cfg.root, SCAN_DIRS)
    findings: List[Finding] = []

    sr_findings, streams_md = streams_mod.check(repo)
    findings.extend(sr_findings)

    # SR006: the committed registry table must match the regenerated one.
    committed = cfg.root / STREAMS_MD
    if not committed.exists():
        findings.append(Finding(
            "SR006", STREAMS_MD, 1,
            "STREAMS.md missing; generate with --write-streams"))
    elif committed.read_text() != streams_md:
        findings.append(Finding(
            "SR006", STREAMS_MD, 1,
            "STREAMS.md is stale; regenerate with --write-streams"))

    findings.extend(compat_mod.check(repo))
    pb_findings, budget_report = budget_mod.check(repo, cfg)
    findings.extend(pb_findings)
    findings.extend(families_mod.check(repo))

    ob_findings, metrics_md = obs_mod.check(repo)
    findings.extend(ob_findings)

    # OB002: like SR006, the committed metric registry table must match the
    # regenerated one.  Trees without obs/registry.py (fixture checkouts)
    # render no table and skip the pin.
    if metrics_md:
        committed_metrics = cfg.root / METRICS_MD
        if not committed_metrics.exists():
            findings.append(Finding(
                "OB002", METRICS_MD, 1,
                "METRICS.md missing; generate with --write-metrics"))
        elif committed_metrics.read_text() != metrics_md:
            findings.append(Finding(
                "OB002", METRICS_MD, 1,
                "METRICS.md is stale; regenerate with --write-metrics"))

    findings = [f for f in findings if cfg.wants(f.rule)]
    entries = load_baseline(cfg.baseline_file())
    actionable, baselined = _apply_baseline(findings, entries,
                                            report_stale=not cfg.rules)
    actionable.sort(key=Finding.sort_key)
    baselined.sort(key=lambda pair: pair[0].sort_key())
    return AnalysisResult(findings=actionable, baselined=baselined,
                          streams_md=streams_md,
                          budget_report=budget_report,
                          metrics_md=metrics_md)


def default_config(root) -> Config:
    return Config(root=pathlib.Path(root).resolve())


# Re-exported for convenience of `from repro.analysis.engine import ...`.
__all__ = ["AnalysisResult", "Config", "run", "default_config",
           "SCAN_DIRS", "STREAMS_MD", "METRICS_MD"]
