"""FC* family-contract checker.

``FAMILY_NAMES`` in ``repro/data/families.py`` is the single source of
truth for which serving families exist; everything downstream -- corpus
stores, the sharded query engine, the storage-matched benchmarks, the
parameterized test sweeps -- iterates it.  A sixth family added to the
tuple without a complete ``SketchFamily`` implementation (or vice versa) is
exactly the half-registered state that passes whatever tests exist and
fails in serving.  This checker proves, per name in ``FAMILY_NAMES``:

* FC001 -- a class in the module declares ``name = "<family>"`` and
  (transitively through same-module bases) implements the full contract:
  ``components``, ``storage_doubles_per_row``, ``sketch_rows``,
  ``estimate_fields``, ``estimate_fields_sharded``, ``merge_rows``,
  ``host_oracle``.
* FC002 -- ``make_family`` can construct it (the name appears as a string
  constant in its body).
* FC003 -- every parameterized sweep covers it (the sweep file iterates
  ``FAMILY_NAMES`` or quotes the name, including inside embedded
  subprocess scripts).
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from . import config as cfg_mod
from .astutil import Repo, dotted_name
from .findings import Finding

CONTRACT_MEMBERS = (
    "components",
    "storage_doubles_per_row",
    "sketch_rows",
    "estimate_fields",
    "estimate_fields_sharded",
    "merge_rows",
    "host_oracle",
)


def _family_names(tree: ast.AST) -> Optional[Tuple[List[str], int]]:
    for node in ast.walk(tree):
        if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name) \
                and node.targets[0].id == "FAMILY_NAMES" \
                and isinstance(node.value, (ast.Tuple, ast.List)):
            names = []
            for elt in node.value.elts:
                if isinstance(elt, ast.Constant) and isinstance(elt.value, str):
                    names.append(elt.value)
            return names, node.lineno
    return None


def _declared_family(cls: ast.ClassDef) -> Optional[str]:
    """The family name a class declares: ``name = "cs"`` or the dataclass
    idiom ``name: str = dataclasses.field(default="cs", init=False)``."""
    for stmt in cls.body:
        target = None
        value = None
        if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1 \
                and isinstance(stmt.targets[0], ast.Name):
            target, value = stmt.targets[0].id, stmt.value
        elif isinstance(stmt, ast.AnnAssign) and isinstance(stmt.target, ast.Name):
            target, value = stmt.target.id, stmt.value
        if target != "name" or value is None:
            continue
        if isinstance(value, ast.Constant) and isinstance(value.value, str):
            return value.value
        if isinstance(value, ast.Call):
            callee = dotted_name(value.func) or ""
            if callee.split(".")[-1] == "field":
                for kw in value.keywords:
                    if kw.arg == "default" \
                            and isinstance(kw.value, ast.Constant) \
                            and isinstance(kw.value.value, str):
                        return kw.value.value
    return None


def _own_members(cls: ast.ClassDef) -> Set[str]:
    members: Set[str] = set()
    for stmt in cls.body:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
            members.add(stmt.name)
        elif isinstance(stmt, ast.Assign):
            for t in stmt.targets:
                if isinstance(t, ast.Name):
                    members.add(t.id)
        elif isinstance(stmt, ast.AnnAssign) and isinstance(stmt.target, ast.Name):
            members.add(stmt.target.id)
    return members


def _all_members(cls: ast.ClassDef, by_name: Dict[str, ast.ClassDef],
                 seen: Optional[Set[str]] = None) -> Set[str]:
    seen = seen or set()
    if cls.name in seen:
        return set()
    seen.add(cls.name)
    members = _own_members(cls)
    for base in cls.bases:
        base_name = dotted_name(base)
        if base_name and base_name in by_name:
            members |= _all_members(by_name[base_name], by_name, seen)
    return members


def check(repo: Repo) -> List[Finding]:
    findings: List[Finding] = []
    pf = repo.get(cfg_mod.FAMILIES_MODULE)
    if pf is None:
        findings.append(Finding(
            "FC001", cfg_mod.FAMILIES_MODULE, 1,
            "families module not found; FAMILY_NAMES contract unverifiable"))
        return findings
    got = _family_names(pf.tree)
    if got is None:
        findings.append(Finding(
            "FC001", pf.rel, 1,
            "FAMILY_NAMES tuple of string literals not found"))
        return findings
    names, names_line = got

    classes = {node.name: node for node in ast.walk(pf.tree)
               if isinstance(node, ast.ClassDef)}
    by_family: Dict[str, ast.ClassDef] = {}
    for cls in classes.values():
        fam = _declared_family(cls)
        if fam is not None:
            by_family[fam] = cls

    # FC001: complete SketchFamily implementation per name.
    for fam in names:
        cls = by_family.get(fam)
        if cls is None:
            findings.append(Finding(
                "FC001", pf.rel, names_line,
                f"family {fam!r} has no class declaring name={fam!r}"))
            continue
        missing = sorted(set(CONTRACT_MEMBERS)
                         - _all_members(cls, classes))
        if missing:
            findings.append(Finding(
                "FC001", pf.rel, cls.lineno,
                f"family {fam!r} ({cls.name}) is missing contract "
                f"member(s): {', '.join(missing)}"))

    # FC002: constructible via make_family.
    make = None
    for node in ast.walk(pf.tree):
        if isinstance(node, ast.FunctionDef) and node.name == "make_family":
            make = node
            break
    if make is None:
        findings.append(Finding(
            "FC002", pf.rel, names_line, "make_family() not found"))
    else:
        literals = {n.value for n in ast.walk(make)
                    if isinstance(n, ast.Constant) and isinstance(n.value, str)}
        for fam in names:
            if fam not in literals:
                findings.append(Finding(
                    "FC002", pf.rel, make.lineno,
                    f"family {fam!r} is not constructible via "
                    f"make_family()"))

    # FC003: parameterized sweep coverage.
    for rel in cfg_mod.SWEEP_FILES:
        sweep = repo.get(rel)
        if sweep is None:
            findings.append(Finding(
                "FC003", rel, 1,
                f"sweep file missing; cannot verify coverage of "
                f"{', '.join(names)}"))
            continue
        if "FAMILY_NAMES" in sweep.source:
            continue    # iterates the registry itself: future-proof
        for fam in names:
            if f'"{fam}"' in sweep.source or f"'{fam}'" in sweep.source:
                continue
            findings.append(Finding(
                "FC003", rel, 1,
                f"family {fam!r} missing from this parameterized sweep "
                f"(iterate FAMILY_NAMES to stay future-proof)"))
    return findings
