"""SR* stream-registry checker + STREAMS.md generator.

The u32 salt-stream contract is the load-bearing piece of host<->device
bit-exactness: every independent pseudo-random draw is selected by a small
integer stream ID fed to ``salt_for(seed, stream, t)``.  Two draws sharing
an ID share their randomness -- a silent correctness bug no runtime test
catches unless it happens to compare exactly those two draws.  This
checker extracts every ``*_STREAM`` constant from the device registry
(``kernels/common.py``) and the host registries (``core/u32.py``,
``core/linear.py``, ``core/sampling.py``), proves global ID uniqueness per
side, proves the host/device mirrors agree name-by-name, forbids inline
stream literals at call sites, and renders the generated ``STREAMS.md``
registry table.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Tuple

from . import config as cfg_mod
from .astutil import ParsedFile, Repo, dotted_name, int_assignments
from .findings import Finding

STREAM_SUFFIX = ("_STREAM",)


def _registry(pf: ParsedFile) -> List[Tuple[str, int, int]]:
    return int_assignments(pf.tree, STREAM_SUFFIX)


def _stream_helpers(tree: ast.AST) -> List[str]:
    """Names of local functions that take a ``stream`` param and forward it
    to ``salt_for`` -- the ``def u(stream): ... salt_for(seed, stream, t)``
    idiom.  Calls to these are stream call sites too."""
    out = []
    for node in ast.walk(tree):
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        params = [a.arg for a in node.args.posonlyargs + node.args.args]
        if "stream" not in params:
            continue
        for inner in ast.walk(node):
            if isinstance(inner, ast.Call):
                callee = dotted_name(inner.func)
                if callee and callee.split(".")[-1] == "salt_for":
                    out.append((node.name, params.index("stream")))
                    break
    return [name for name, _ in out], dict(out)


def _literal_stream_arg(call: ast.Call, pos: int):
    """The int-literal stream argument of a call, if any (position or kw)."""
    for kw in call.keywords:
        if kw.arg == "stream" and isinstance(kw.value, ast.Constant) \
                and isinstance(kw.value.value, int) \
                and not isinstance(kw.value.value, bool):
            return kw.value.value
    if len(call.args) > pos:
        a = call.args[pos]
        if isinstance(a, ast.Constant) and isinstance(a.value, int) \
                and not isinstance(a.value, bool):
            return a.value
    return None


def check(repo: Repo) -> Tuple[List[Finding], str]:
    """Run SR001-SR005 and return (findings, rendered STREAMS.md text).

    SR006 (staleness vs the committed STREAMS.md) is applied by the engine,
    which owns file I/O policy.
    """
    findings: List[Finding] = []

    dev_pf = repo.get(cfg_mod.DEVICE_REGISTRY)
    device: Dict[str, Tuple[int, str, int]] = {}
    if dev_pf is not None:
        for name, value, line in _registry(dev_pf):
            device[name] = (value, dev_pf.rel, line)

    host: Dict[str, Tuple[int, str, int]] = {}
    for rel in cfg_mod.HOST_REGISTRIES:
        pf = repo.get(rel)
        if pf is None:
            continue
        for name, value, line in _registry(pf):
            if name in host and host[name][0] != value:
                findings.append(Finding(
                    "SR001", pf.rel, line,
                    f"host stream {name} redefined with value {value} "
                    f"(already {host[name][0]} at {host[name][1]}:"
                    f"{host[name][2]})"))
            host[name] = (value, pf.rel, line)

    # SR001: globally unique IDs within each side of the mirror.
    for side, reg in (("device", device), ("host", host)):
        by_value: Dict[int, List[str]] = {}
        for name, (value, _, _) in reg.items():
            by_value.setdefault(value, []).append(name)
        for value, names in sorted(by_value.items()):
            if len(names) > 1:
                names = sorted(names, key=lambda n: (reg[n][1], reg[n][2]))
                _, path, line = reg[names[-1]]    # latest definition anchors
                findings.append(Finding(
                    "SR001", path, line,
                    f"duplicate {side} stream ID {value} shared by "
                    f"{', '.join(names)}"))

    # SR002/SR003/SR004: name-by-name host<->device mirroring.
    for name, (value, path, line) in sorted(host.items()):
        if name not in device:
            findings.append(Finding(
                "SR002", path, line,
                f"host stream {name}={value} has no device mirror in "
                f"{cfg_mod.DEVICE_REGISTRY}"))
        elif device[name][0] != value:
            findings.append(Finding(
                "SR004", path, line,
                f"stream {name} disagrees across the mirror: host {value} "
                f"vs device {device[name][0]} "
                f"({cfg_mod.DEVICE_REGISTRY}:{device[name][2]})"))
    for name, (value, path, line) in sorted(device.items()):
        if name not in host:
            findings.append(Finding(
                "SR003", path, line,
                f"device stream {name}={value} has no host twin in "
                f"{', '.join(cfg_mod.HOST_REGISTRIES)}"))

    # SR005: no inline stream literals at call sites under src/.
    registry_files = {cfg_mod.DEVICE_REGISTRY, *cfg_mod.HOST_REGISTRIES}
    for pf in repo.files:
        if not pf.rel.startswith("src/"):
            continue
        helper_names, helper_pos = _stream_helpers(pf.tree)
        for node in ast.walk(pf.tree):
            if not isinstance(node, ast.Call):
                continue
            callee = dotted_name(node.func)
            if callee is None:
                continue
            leaf = callee.split(".")[-1]
            if leaf == "salt_for":
                lit = _literal_stream_arg(node, pos=1)
            elif leaf in helper_names:
                lit = _literal_stream_arg(node, pos=helper_pos[leaf])
            else:
                continue
            if lit is None:
                continue
            if pf.rel in registry_files:
                continue    # registries may self-document with literals
            findings.append(Finding(
                "SR005", pf.rel, node.lineno,
                f"inline stream literal {lit} passed to {leaf}(); use the "
                f"named *_STREAM constant"))

    return findings, render_streams_md(repo, device, host)


def render_streams_md(repo: Repo, device, host) -> str:
    """The generated registry table committed as STREAMS.md."""
    lines = [
        "# u32 salt-stream registry",
        "",
        "Generated by `python -m repro.analysis --write-streams` -- do not",
        "edit by hand.  Every independent pseudo-random draw in the repo is",
        "selected by one of these stream IDs via `salt_for(seed, stream, t)`;",
        "the `SR*` rules of `repro.analysis` enforce that IDs are globally",
        "unique per side and that every device constant has an identically",
        "valued host twin (the host<->device bit-exactness contract).",
        "",
        "| stream | id | device definition | host twin | used by |",
        "|---|---|---|---|---|",
    ]
    registry_files = {cfg_mod.DEVICE_REGISTRY, *cfg_mod.HOST_REGISTRIES}
    names = sorted(set(device) | set(host),
                   key=lambda n: (device.get(n, host.get(n))[0], n))
    for name in names:
        value = device.get(name, host.get(name))[0]
        dev = (f"{device[name][1]}:{device[name][2]}"
               if name in device else "(missing)")
        hst = (f"{host[name][1]}:{host[name][2]}"
               if name in host else "(missing)")
        users = sorted(
            pf.rel for pf in repo.files
            if pf.rel not in registry_files and name in pf.source)
        lines.append(f"| `{name}` | {value} | {dev} | {hst} | "
                     f"{', '.join(users) if users else '-'} |")
    lines.append("")
    return "\n".join(lines)
