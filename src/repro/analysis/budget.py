"""PB* pallas-budget checker: static VMEM block accounting per kernel.

Every ``pl.pallas_call`` site tiles its operands through BlockSpecs; the
blocks (x2 for the compiler's double buffering) must fit the ~16 MiB/core
VMEM.  This checker resolves each BlockSpec's block shape from the
enclosing function's parameter defaults (the ``bq=8, bp=64, bm=128``
convention) plus module-level int constants, charges 4 bytes/element
(f32/i32 -- every repo dtype), and compares the summed block I/O per call
site against ``Config.vmem_block_budget``.  Shapes that cannot be bounded
statically (runtime-dependent dims) are PB002 findings: either refactor to
a declared default or baseline with a written justification.

The per-kernel report this emits (``--budget-report``) is the input the
planned block-size autotuner (ROADMAP item) consumes: it already knows
every call site, its tunable block parameters, and its headroom.
"""
from __future__ import annotations

import ast
import json
from typing import Dict, List, Optional, Tuple

from .astutil import (Repo, dotted_name, enclosing_functions, eval_int,
                      function_default_env, module_int_env)
from .config import Config
from .findings import Finding

_BYTES_PER_ELEM = 4    # f32 / i32 / u32: every dtype the kernels move

# The roofline autotuner's committed block-size cache.  Tuned launches
# resolve their blocks from here at runtime (repro.roofline.autotune), so
# the static per-call-site pass below -- which only sees the declared
# defaults -- would miss a tuned configuration that blows the budget.  The
# cache check closes that hole: every entry's block_shapes are charged
# under the same 4-bytes/element accounting and gated by the same PB001.
_CACHE_REL = "src/repro/roofline/block_cache.json"


def _blockspec_calls(node: ast.AST) -> Optional[List[Tuple[ast.Call, int]]]:
    """Flatten an in_specs/out_specs expression into (BlockSpec call, count)
    pairs.  Handles single specs, lists/tuples, and ``[spec] * N``."""
    if isinstance(node, ast.Call):
        callee = dotted_name(node.func)
        if callee and callee.split(".")[-1] == "BlockSpec":
            return [(node, 1)]
        return None
    if isinstance(node, (ast.List, ast.Tuple)):
        out: List[Tuple[ast.Call, int]] = []
        for elt in node.elts:
            sub = _blockspec_calls(elt)
            if sub is None:
                return None
            out.extend(sub)
        return out
    if isinstance(node, ast.BinOp) and isinstance(node.op, ast.Mult):
        for specs, count in ((node.left, node.right), (node.right, node.left)):
            sub = _blockspec_calls(specs)
            n = eval_int(count, {})
            if sub is not None and n is not None:
                return [(call, c * n) for call, c in sub]
        return None
    return None


def _block_shape(call: ast.Call) -> Optional[ast.AST]:
    for kw in call.keywords:
        if kw.arg == "block_shape":
            return kw.value
    if call.args:
        return call.args[0]
    return None


def _check_cache(repo: Repo, cfg: Config, findings: List[Finding],
                 report: List[Dict]) -> None:
    """Charge every autotuner cache entry against the VMEM block budget.

    Each entry carries the exact per-operand block shapes its tuned launch
    will request (``block_shapes``: ``[count, [dims..]]`` pairs, written by
    ``repro.roofline.autotune.tune``).  Report rows use
    ``kernel="cache:<group>|<key>"`` at line 0 of the cache file; a
    malformed entry is PB002 (the runtime would silently fall back to
    defaults, but a cache that cannot be audited must not ship), an
    over-budget one is PB001 -- same rules, no new baseline entries.
    """
    path = repo.root / _CACHE_REL
    if not path.exists():
        return
    try:
        entries = json.loads(path.read_text())["entries"]
        if not isinstance(entries, list):
            raise TypeError("entries is not a list")
    except Exception as e:
        findings.append(Finding(
            "PB002", _CACHE_REL, 0,
            f"autotuner block cache is unreadable ({e}); the budget check "
            f"cannot audit tuned launches"))
        return
    for ei, e in enumerate(entries):
        try:
            kernel = "cache:{}|{}".format(
                e["kernel"], ",".join(f"{k}={v}"
                                      for k, v in sorted(e["key"].items())))
            shapes = [(int(c), [int(d) for d in dims])
                      for c, dims in e["block_shapes"]]
        except Exception as exc:
            findings.append(Finding(
                "PB002", _CACHE_REL, 0,
                f"autotuner cache entry [{ei}] is malformed ({exc}); tuned "
                f"block shapes must be statically auditable"))
            continue
        blocks = []
        for i, (count, dims) in enumerate(shapes):
            nbytes = _BYTES_PER_ELEM * count
            for d in dims:
                nbytes *= d
            blocks.append({"spec": f"cache[{i}]", "count": count,
                           "shape": dims, "bytes": nbytes})
        total = sum(b["bytes"] for b in blocks)
        report.append({
            "kernel": kernel, "file": _CACHE_REL, "line": 0,
            "blocks": blocks, "total_block_bytes": total,
            "budget_bytes": cfg.vmem_block_budget,
            "within_budget": total <= cfg.vmem_block_budget,
            "unresolved": [],
        })
        if total > cfg.vmem_block_budget:
            findings.append(Finding(
                "PB001", _CACHE_REL, 0,
                f"autotuned blocks for {kernel}: block I/O {total} bytes "
                f"exceeds budget {cfg.vmem_block_budget}"))


def check(repo: Repo, cfg: Config) -> Tuple[List[Finding], List[Dict]]:
    findings: List[Finding] = []
    report: List[Dict] = []
    for pf in repo.files:
        if not pf.rel.startswith("src/"):
            continue
        if "pallas_call" not in pf.source:
            continue
        owners = enclosing_functions(pf.tree)
        mod_env = module_int_env(pf.tree)
        for node in ast.walk(pf.tree):
            if not isinstance(node, ast.Call):
                continue
            callee = dotted_name(node.func)
            if not callee or callee.split(".")[-1] != "pallas_call":
                continue
            fn = owners.get(node)
            env = dict(mod_env)
            if fn is not None:
                env.update(function_default_env(fn))
            kernel = fn.name if fn is not None else "<module>"

            blocks: List[Dict] = []
            unresolved: List[str] = []
            for kw in node.keywords:
                if kw.arg not in ("in_specs", "out_specs"):
                    continue
                specs = _blockspec_calls(kw.value)
                if specs is None:
                    unresolved.append(
                        f"{kw.arg}: expression not statically recognizable")
                    continue
                for i, (spec, count) in enumerate(specs):
                    shape_node = _block_shape(spec)
                    if not isinstance(shape_node, (ast.Tuple, ast.List)):
                        unresolved.append(
                            f"{kw.arg}[{i}]: block shape is not a literal "
                            f"tuple")
                        continue
                    dims: List[int] = []
                    bad = None
                    for d in shape_node.elts:
                        v = eval_int(d, env)
                        if v is None:
                            bad = ast.unparse(d)
                            break
                        dims.append(v)
                    if bad is not None:
                        unresolved.append(
                            f"{kw.arg}[{i}]: dimension `{bad}` is not "
                            f"statically bounded")
                        continue
                    nbytes = _BYTES_PER_ELEM * count
                    for v in dims:
                        nbytes *= v
                    blocks.append({"spec": f"{kw.arg}[{i}]",
                                   "count": count, "shape": dims,
                                   "bytes": nbytes})

            total = sum(b["bytes"] for b in blocks)
            entry = {
                "kernel": kernel, "file": pf.rel, "line": node.lineno,
                "blocks": blocks, "total_block_bytes": total,
                "budget_bytes": cfg.vmem_block_budget,
                "within_budget": total <= cfg.vmem_block_budget,
                "unresolved": unresolved,
            }
            report.append(entry)
            for msg in unresolved:
                findings.append(Finding(
                    "PB002", pf.rel, node.lineno,
                    f"pallas_call in {kernel}: {msg}"))
            if total > cfg.vmem_block_budget:
                findings.append(Finding(
                    "PB001", pf.rel, node.lineno,
                    f"pallas_call in {kernel}: block I/O {total} bytes "
                    f"exceeds budget {cfg.vmem_block_budget}"))
    _check_cache(repo, cfg, findings, report)
    report.sort(key=lambda e: (e["file"], e["line"]))
    return findings, report
