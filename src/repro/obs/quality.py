"""Estimator-quality telemetry channel.

Production serving has no ground truth, but it does have slower exact
references: the host oracle kept by ``DatasetSearchIndex`` and, in
benchmarks, the true inner products.  This module turns sampled re-scores
against such a reference into a rolling per-family error gauge:

* ``quality.samples_total{family}`` counts samples;
* ``quality.ppm_error{family}`` holds an exponentially-weighted moving
  average (alpha = 0.2) of the normalized absolute error in
  parts-per-million.

Callers decide what "reference" means: benchmarks feed device-vs-host and
estimate-vs-true pairs for all six families; the serving layer audits every
Nth query against the host oracle when one is resident.  Recording is
gated on :func:`repro.obs.metrics.enabled`, so the channel is free when
observability is off.
"""
from __future__ import annotations

from repro.obs import metrics as _m

EWMA_ALPHA = 0.2

_EWMA: dict = {}


def record_sample(family: str, estimate: float, reference: float,
                  scale: float | None = None) -> float | None:
    """Record one re-scored pair; returns the updated rolling ppm or None.

    ``scale`` overrides the normalization denominator (use the norm product
    or value range when references can be near zero); it defaults to
    ``|reference|``, with a floor of 1.0 to keep tiny references from
    exploding the ratio.
    """
    if not _m.enabled():
        return None
    denom = abs(float(reference)) if scale is None else float(scale)
    denom = max(denom, 1.0) if scale is None else max(denom, 1e-30)
    ppm = abs(float(estimate) - float(reference)) / denom * 1e6
    prev = _EWMA.get(family)
    cur = ppm if prev is None else EWMA_ALPHA * ppm + (1.0 - EWMA_ALPHA) * prev
    _EWMA[family] = cur
    _m.counter("quality.samples_total", family=family).inc()
    _m.gauge("quality.ppm_error", family=family).set(cur)
    return cur


def rolling_ppm(family: str) -> float | None:
    """Current EWMA ppm error for ``family``, or None if never sampled."""
    return _EWMA.get(family)


def reset_quality() -> None:
    _EWMA.clear()
