"""Instrumentation decorator for public kernel launch wrappers.

``@instrumented("icws_sketch")`` wraps a public ``repro.kernels.ops``
launch.  With observability disabled the wrapper is a strict pass-through
(one module-level bool read, then tail-call the launch), so jit'd paths and
all bitwise identities are untouched.  When enabled, each call records:

* ``ops.launches_total{op, family}`` -- launch count, attributed to the
  ambient :func:`repro.obs.metrics.family_context` if one is active;
* ``ops.first_call_seconds{op}`` -- the first observed call per op (jit
  trace + compile + execute), split from steady state;
* ``ops.launch_seconds{op, family}`` -- every subsequent call;
* one complete trace event ``ops.<op>`` in the span ring.

Wall times measure host-side dispatch on async backends; under the CPU
Pallas interpreter (the default everywhere but TPU) dispatch is effectively
synchronous, so they are end-to-end latencies there.

The decorator lives in :mod:`repro.obs`, not in ``ops.py`` itself, so the
OB001 analysis rule can require every public def in ``ops.py`` to carry it
without exempting helper definitions.
"""
from __future__ import annotations

import functools
import time

from repro.obs import metrics as _m
from repro.obs import trace as _t


def instrumented(op: str):
    """Decorate a public launch wrapper with telemetry under name ``op``."""

    def deco(fn):
        state = {"first_seen": False}

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            if not _m.enabled():
                return fn(*args, **kwargs)
            family = _m.current_family()
            _m.counter("ops.launches_total", op=op, family=family).inc()
            t0 = time.perf_counter()
            out = fn(*args, **kwargs)
            t1 = time.perf_counter()
            dt = t1 - t0
            if state["first_seen"]:
                _m.histogram("ops.launch_seconds", op=op, family=family).record(dt)
            else:
                state["first_seen"] = True
                _m.histogram("ops.first_call_seconds", op=op).record(dt)
            _t.add_complete_event("ops." + op, t0, t1, {"family": family})
            return out

        wrapper.obs_op = op
        wrapper.__wrapped__ = fn
        return wrapper

    return deco
