"""Structured spans with an in-memory ring buffer (stdlib only).

``span("ops.icws_estimate_fields", family="icws", backend="cpu")`` times a
block and, when observability is enabled, appends one *complete* event to a
bounded ring buffer.  The ring exports two ways:

* :func:`chrome_trace` / :func:`save_chrome_trace` -- Chrome trace-event
  JSON (``chrome://tracing`` / Perfetto ``X`` phase events, microsecond
  timestamps relative to process start);
* :func:`save_jsonl` -- one flat JSON object per line for ad-hoc grepping.

When observability is disabled, :func:`span` returns a shared null context:
no allocation, no clock reads, no ring append -- the instrumented block
runs exactly as before.
"""
from __future__ import annotations

import json
import os
import threading
import time
from collections import deque

from repro.obs import metrics as _m

RING_CAPACITY = int(os.environ.get("REPRO_OBS_RING", "4096"))

_EPOCH = time.perf_counter()
_RING: deque = deque(maxlen=RING_CAPACITY)
_PID = os.getpid()


class _NullSpan:
    """Shared no-op span for the disabled path."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, key, value):
        pass


_NULL = _NullSpan()


class Span:
    __slots__ = ("name", "args", "_t0")

    def __init__(self, name: str, args: dict):
        self.name = name
        self.args = args
        self._t0 = 0.0

    def set(self, key: str, value) -> None:
        """Attach an attribute discovered mid-span (e.g. a result size)."""
        self.args[key] = value

    def __enter__(self):
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb):
        t1 = time.perf_counter()
        event = {
            "name": self.name,
            "ph": "X",
            "cat": self.name.split(".", 1)[0],
            "ts": (self._t0 - _EPOCH) * 1e6,
            "dur": (t1 - self._t0) * 1e6,
            "pid": _PID,
            "tid": threading.get_ident() % 1_000_000,
            "args": {k: _jsonable(v) for k, v in self.args.items()},
        }
        if exc_type is not None:
            event["args"]["error"] = exc_type.__name__
        _RING.append(event)
        return False


def _jsonable(v):
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    return str(v)


def span(name: str, **attrs):
    """Time a block as a structured span; a strict no-op when disabled."""
    if not _m.enabled():
        return _NULL
    return Span(name, attrs)


def add_complete_event(name: str, t0: float, t1: float, args: dict) -> None:
    """Append a complete event from already-taken perf_counter readings.

    Used by the ops instrumentation decorator, which times the launch once
    and feeds both the latency histogram and the trace ring from the same
    clock pair.
    """
    _RING.append({
        "name": name,
        "ph": "X",
        "cat": name.split(".", 1)[0],
        "ts": (t0 - _EPOCH) * 1e6,
        "dur": (t1 - t0) * 1e6,
        "pid": _PID,
        "tid": threading.get_ident() % 1_000_000,
        "args": {k: _jsonable(v) for k, v in args.items()},
    })


def events() -> list:
    """Current ring contents, oldest first."""
    return list(_RING)


def reset_trace() -> None:
    _RING.clear()


def chrome_trace() -> dict:
    return {"traceEvents": events(), "displayTimeUnit": "ms"}


def save_chrome_trace(path: str) -> None:
    with open(path, "w") as fh:
        json.dump(chrome_trace(), fh)
        fh.write("\n")


def save_jsonl(path: str) -> None:
    with open(path, "w") as fh:
        for event in _RING:
            fh.write(json.dumps(event, sort_keys=True))
            fh.write("\n")
