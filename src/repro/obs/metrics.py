"""Counters, gauges, and log-scale latency histograms (stdlib only).

Design constraints, in order:

1. **Strict no-op when disabled.**  Instrumented hot paths guard on
   :func:`enabled` before touching any metric, so with ``REPRO_OBS`` unset
   the per-call cost is one module-level bool read and the jit'd numerics
   are untouched (the decorators are pure pass-throughs).
2. **O(1) record, no locks.**  Every ``record``/``inc``/``set`` is a
   handful of arithmetic ops on Python ints/floats; under the GIL that is
   race-tolerant enough for telemetry and never blocks the hot path.
3. **Mergeable.**  Histograms use a fixed global bucket layout
   (log10, exponents [-7, 3), 4 buckets per decade) so shard- or
   tenant-level histograms merge by bucketwise addition; min/max/sum/count
   merge exactly.
4. **Declared namespace.**  Registered metrics must appear in
   :data:`repro.obs.registry.SPECS` with the exact kind and label keys;
   anything else raises at the call site.  Standalone (private, unregistered)
   ``Histogram`` instances are also supported for always-on service stats.

Quantiles: each histogram keeps a bounded window of recent raw values
(``RECENT_WINDOW`` = 128).  While the window still covers *every* recorded
observation, quantiles are exact order statistics; beyond that they fall
back to bucket interpolation (geometric bucket midpoints, clamped to the
exact [min, max]).  Small-sample benchmark medians are therefore exact.
"""
from __future__ import annotations

import json
import math
import os
import threading
from collections import deque

from repro.obs.registry import SPECS

# --------------------------------------------------------------------------
# enable/disable
# --------------------------------------------------------------------------

_ENABLED = os.environ.get("REPRO_OBS", "") not in ("", "0", "false", "no")


def enabled() -> bool:
    """True when the opt-in observability layer is recording."""
    return _ENABLED


def enable() -> None:
    global _ENABLED
    _ENABLED = True


def disable() -> None:
    global _ENABLED
    _ENABLED = False


# --------------------------------------------------------------------------
# ambient label context (family attribution for ops-layer launches)
# --------------------------------------------------------------------------

_TLS = threading.local()


def current_family() -> str:
    stack = getattr(_TLS, "family", None)
    return stack[-1] if stack else "-"


class family_context:
    """Push an ambient ``family`` label for the duration of a block.

    The ops-layer decorator reads :func:`current_family` so that launches
    issued on behalf of a sketch family (via ``data/families.py``) are
    attributed to it without threading a label through every call site.
    Reentrant and thread-local; usable as decorator sugar is deliberately
    omitted -- call sites are explicit ``with`` blocks.
    """

    __slots__ = ("name",)

    def __init__(self, name: str):
        self.name = str(name)

    def __enter__(self):
        stack = getattr(_TLS, "family", None)
        if stack is None:
            stack = []
            _TLS.family = stack
        stack.append(self.name)
        return self

    def __exit__(self, *exc):
        _TLS.family.pop()
        return False


# --------------------------------------------------------------------------
# histogram bucket layout (fixed, global, so all histograms merge)
# --------------------------------------------------------------------------

BUCKET_LO_EXP = -7          # first finite bucket starts at 1e-7
BUCKET_HI_EXP = 3           # last finite bucket ends at 1e3
BUCKETS_PER_DECADE = 4
N_FINITE = (BUCKET_HI_EXP - BUCKET_LO_EXP) * BUCKETS_PER_DECADE
LAYOUT = "log10[%d,%d)x%d" % (BUCKET_LO_EXP, BUCKET_HI_EXP, BUCKETS_PER_DECADE)

RECENT_WINDOW = 128

_LOG_SCALE = BUCKETS_PER_DECADE
_LOG_SHIFT = -BUCKET_LO_EXP * BUCKETS_PER_DECADE


def bucket_index(value: float) -> int:
    """Map a value to [0, N_FINITE+1]: 0 = underflow, N_FINITE+1 = overflow."""
    if value < 1e-7:            # includes 0 and negatives: underflow
        return 0
    i = math.floor(math.log10(value) * _LOG_SCALE) + _LOG_SHIFT
    if i < 0:
        return 0
    if i >= N_FINITE:
        return N_FINITE + 1
    return i + 1


def bucket_bounds(i: int) -> tuple[float, float]:
    """(lo, hi) of finite bucket slot ``i`` in [1, N_FINITE]."""
    e = (i - 1 - _LOG_SHIFT) / _LOG_SCALE
    return 10.0 ** e, 10.0 ** (e + 1.0 / _LOG_SCALE)


# --------------------------------------------------------------------------
# metric kinds
# --------------------------------------------------------------------------

class Counter:
    __slots__ = ("name", "labels", "value")

    def __init__(self, name: str, labels: dict | None = None):
        self.name = name
        self.labels = dict(labels or {})
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n

    def as_dict(self) -> dict:
        return {"labels": self.labels, "value": self.value}

    def merge(self, other: "Counter") -> None:
        self.value += other.value


class Gauge:
    __slots__ = ("name", "labels", "value")

    def __init__(self, name: str, labels: dict | None = None):
        self.name = name
        self.labels = dict(labels or {})
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def as_dict(self) -> dict:
        return {"labels": self.labels, "value": self.value}


class Histogram:
    """Fixed-bucket log-scale histogram with exact min/max/sum and a bounded
    exact-quantile window.  Construct directly for a private (unregistered)
    histogram, or via :func:`histogram` for a registered series."""

    __slots__ = ("name", "labels", "count", "sum", "min", "max", "last",
                 "buckets", "recent")

    def __init__(self, name: str = "", labels: dict | None = None):
        self.name = name
        self.labels = dict(labels or {})
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf
        self.last = 0.0
        self.buckets = [0] * (N_FINITE + 2)
        self.recent = deque(maxlen=RECENT_WINDOW)

    def record(self, value: float) -> None:
        v = float(value)
        self.count += 1
        self.sum += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v
        self.last = v
        self.buckets[bucket_index(v)] += 1
        self.recent.append(v)

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def quantile(self, q: float) -> float:
        if self.count == 0:
            return 0.0
        if len(self.recent) == self.count:
            # window covers every observation: exact order statistic
            xs = sorted(self.recent)
            k = min(len(xs) - 1, max(0, int(math.ceil(q * len(xs))) - 1))
            return xs[k]
        # bucket interpolation: geometric midpoint, clamped to exact extremes
        target = q * self.count
        cum = 0
        for i, n in enumerate(self.buckets):
            cum += n
            if cum >= target and n:
                if i == 0:
                    return self.min
                if i == N_FINITE + 1:
                    return self.max
                lo, hi = bucket_bounds(i)
                mid = math.sqrt(lo * hi)
                return min(max(mid, self.min), self.max)
        return self.max

    def merge(self, other: "Histogram") -> None:
        """Bucketwise in-place merge; exact for count/sum/min/max, and the
        recent windows concatenate (still exact while the union fits)."""
        if len(other.buckets) != len(self.buckets):
            raise ValueError("histogram bucket layouts differ; cannot merge")
        self.count += other.count
        self.sum += other.sum
        if other.count:
            self.min = min(self.min, other.min)
            self.max = max(self.max, other.max)
            self.last = other.last
        for i, n in enumerate(other.buckets):
            self.buckets[i] += n
        self.recent.extend(other.recent)

    def as_dict(self) -> dict:
        d = {
            "labels": self.labels,
            "count": self.count,
            "sum": self.sum,
            "min": self.min if self.count else 0.0,
            "max": self.max if self.count else 0.0,
            "last": self.last,
            "p50": self.quantile(0.50),
            "p90": self.quantile(0.90),
            "p99": self.quantile(0.99),
            "layout": LAYOUT,
            "buckets": list(self.buckets),
        }
        return d


# --------------------------------------------------------------------------
# registry of live series
# --------------------------------------------------------------------------

_SPEC_BY_NAME = {s["name"]: s for s in SPECS}
_KIND_CLS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}
_SERIES: dict = {}


def _series(kind: str, name: str, labels: dict):
    spec = _SPEC_BY_NAME.get(name)
    if spec is None:
        raise KeyError("undeclared metric %r; add it to repro.obs.registry.SPECS" % name)
    if spec["type"] != kind:
        raise TypeError("metric %r is declared as %s, not %s" % (name, spec["type"], kind))
    if set(labels) != set(spec["labels"]):
        raise ValueError("metric %r requires labels %r, got %r"
                         % (name, spec["labels"], tuple(sorted(labels))))
    ordered = {k: str(labels[k]) for k in spec["labels"]}
    key = (name, tuple(ordered.values()))
    obj = _SERIES.get(key)
    if obj is None:
        obj = _KIND_CLS[kind](name, ordered)
        _SERIES[key] = obj
    return obj


def counter(name: str, **labels) -> Counter:
    return _series("counter", name, labels)


def gauge(name: str, **labels) -> Gauge:
    return _series("gauge", name, labels)


def histogram(name: str, **labels) -> Histogram:
    return _series("histogram", name, labels)


def reset() -> None:
    """Drop every registered series (trace ring is separate; see obs.trace)."""
    _SERIES.clear()


# --------------------------------------------------------------------------
# exporters
# --------------------------------------------------------------------------

def describe_metrics() -> dict:
    """Snapshot of every live series, grouped by declared metric."""
    metrics: dict = {}
    for (name, _), obj in sorted(_SERIES.items(), key=lambda kv: kv[0]):
        spec = _SPEC_BY_NAME[name]
        entry = metrics.setdefault(name, {
            "type": spec["type"], "unit": spec["unit"], "help": spec["help"],
            "series": [],
        })
        entry["series"].append(obj.as_dict())
    return {"version": 1, "enabled": enabled(), "metrics": metrics}


def save_metrics(path: str) -> None:
    with open(path, "w") as fh:
        json.dump(describe_metrics(), fh, indent=1, sort_keys=True)
        fh.write("\n")


def _prom_name(name: str) -> str:
    return "repro_" + name.replace(".", "_")


def _prom_labels(labels: dict, extra: dict | None = None) -> str:
    items = dict(labels)
    if extra:
        items.update(extra)
    if not items:
        return ""
    body = ",".join('%s="%s"' % (k, str(v).replace('"', '\\"'))
                    for k, v in items.items())
    return "{" + body + "}"


def prometheus_text() -> str:
    """Prometheus exposition format (text/plain; version 0.0.4)."""
    out: list[str] = []
    snap = describe_metrics()["metrics"]
    for spec in SPECS:
        name = spec["name"]
        entry = snap.get(name)
        if entry is None:
            continue
        pname = _prom_name(name)
        out.append("# HELP %s %s" % (pname, spec["help"]))
        out.append("# TYPE %s %s" % (pname, spec["type"]))
        for s in entry["series"]:
            labels = s["labels"]
            if spec["type"] in ("counter", "gauge"):
                out.append("%s%s %s" % (pname, _prom_labels(labels), s["value"]))
                continue
            cum = 0
            for i, n in enumerate(s["buckets"]):
                cum += n
                if i == 0:
                    le = "%g" % (10.0 ** BUCKET_LO_EXP)
                elif i <= N_FINITE:
                    le = "%g" % bucket_bounds(i)[1]
                else:
                    le = "+Inf"
                out.append("%s_bucket%s %d"
                           % (pname, _prom_labels(labels, {"le": le}), cum))
            out.append("%s_sum%s %g" % (pname, _prom_labels(labels), s["sum"]))
            out.append("%s_count%s %d" % (pname, _prom_labels(labels), s["count"]))
    return "\n".join(out) + "\n"
