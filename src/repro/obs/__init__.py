"""Opt-in, zero-dependency observability for the sketch serving stack.

Enable with ``REPRO_OBS=1`` in the environment (or :func:`enable` at
runtime).  While disabled -- the default -- every instrumented path is a
strict no-op: one bool read per call, no metric writes, no spans, and the
jit'd numerics are bitwise untouched.

Pieces:

* :mod:`repro.obs.metrics` -- counters, gauges, mergeable log-bucket
  latency histograms; ``describe_metrics()`` / Prometheus exporters.
* :mod:`repro.obs.trace` -- structured spans, Chrome-trace / JSONL export.
* :mod:`repro.obs.quality` -- sampled estimator re-scores, rolling
  ppm-error gauge per family.
* :mod:`repro.obs.instrument` -- the ``@instrumented`` decorator applied
  to every public launch in ``repro.kernels.ops`` (enforced by analysis
  rule OB001).
* ``python -m repro.obs`` -- pretty-print a metrics dump or diff two.

Every metric name is declared in :mod:`repro.obs.registry`; the generated
``METRICS.md`` is pinned against that registry by analysis rule OB002.

This package is pure stdlib (no jax import) so the static-analysis pass
and the CLI stay usable on machines without the accelerator stack.
"""
from __future__ import annotations

import os

from repro.obs.instrument import instrumented
from repro.obs.metrics import (
    Counter,
    Gauge,
    Histogram,
    counter,
    current_family,
    describe_metrics,
    disable,
    enable,
    enabled,
    family_context,
    gauge,
    histogram,
    prometheus_text,
    reset,
    save_metrics,
)
from repro.obs.quality import record_sample, reset_quality, rolling_ppm
from repro.obs.registry import SPECS
from repro.obs.trace import (
    chrome_trace,
    events,
    reset_trace,
    save_chrome_trace,
    save_jsonl,
    span,
)

__all__ = [
    "Counter", "Gauge", "Histogram", "SPECS",
    "chrome_trace", "counter", "current_family", "describe_metrics",
    "disable", "enable", "enabled", "events", "export_snapshot",
    "family_context", "gauge", "histogram", "instrumented",
    "prometheus_text", "record_sample", "reset", "reset_all",
    "reset_quality", "reset_trace", "rolling_ppm", "save_chrome_trace",
    "save_jsonl", "save_metrics", "span",
]


def reset_all() -> None:
    """Clear metrics, the trace ring, and the quality EWMA state."""
    reset()
    reset_trace()
    reset_quality()


def export_snapshot(directory: str | None = None) -> dict:
    """Write metrics.json + trace.json (Chrome) + trace.jsonl to a directory.

    ``directory`` defaults to ``$REPRO_OBS_DIR`` or ``obs_snapshot``.
    Returns the written paths keyed by artifact name.
    """
    directory = directory or os.environ.get("REPRO_OBS_DIR") or "obs_snapshot"
    os.makedirs(directory, exist_ok=True)
    paths = {
        "metrics": os.path.join(directory, "metrics.json"),
        "chrome_trace": os.path.join(directory, "trace.json"),
        "jsonl": os.path.join(directory, "trace.jsonl"),
    }
    save_metrics(paths["metrics"])
    save_chrome_trace(paths["chrome_trace"])
    save_jsonl(paths["jsonl"])
    return paths
