"""CLI for observability dumps: pretty-print a snapshot or diff two.

Usage:
    python -m repro.obs show  obs_snapshot/metrics.json
    python -m repro.obs diff  before.json after.json

``show`` renders one line per series (counters/gauges: value; histograms:
count, p50/p90/p99, max).  ``diff`` prints only series that changed, with
counter deltas and histogram p50 movement -- handy for comparing a metrics
dump from before and after a perf run or a config change.
"""
from __future__ import annotations

import argparse
import json
import sys


def _load(path: str) -> dict:
    with open(path) as fh:
        snap = json.load(fh)
    if "metrics" not in snap:
        raise SystemExit("%s: not a metrics snapshot (no 'metrics' key)" % path)
    return snap["metrics"]


def _fmt_val(v: float) -> str:
    if isinstance(v, int):
        return str(v)
    if v == 0:
        return "0"
    if abs(v) >= 1e-3:
        return "%.4g" % v
    return "%.3e" % v


def _series_key(s: dict) -> str:
    labels = s.get("labels") or {}
    if not labels:
        return ""
    return "{" + ",".join("%s=%s" % kv for kv in labels.items()) + "}"


def _show(path: str) -> int:
    metrics = _load(path)
    rows = []
    for name in sorted(metrics):
        entry = metrics[name]
        for s in entry["series"]:
            label = name + _series_key(s)
            if entry["type"] == "histogram":
                detail = ("count=%d p50=%s p90=%s p99=%s max=%s" % (
                    s["count"], _fmt_val(s["p50"]), _fmt_val(s["p90"]),
                    _fmt_val(s["p99"]), _fmt_val(s["max"])))
            else:
                detail = _fmt_val(s["value"])
            rows.append((label, entry["type"], entry["unit"], detail))
    if not rows:
        print("(no series recorded)")
        return 0
    width = max(len(r[0]) for r in rows)
    for label, kind, unit, detail in rows:
        print("%-*s  %-9s %-8s %s" % (width, label, kind, unit, detail))
    return 0


def _index(metrics: dict) -> dict:
    out = {}
    for name, entry in metrics.items():
        for s in entry["series"]:
            out[name + _series_key(s)] = (entry["type"], s)
    return out


def _diff(path_a: str, path_b: str) -> int:
    a, b = _index(_load(path_a)), _index(_load(path_b))
    keys = sorted(set(a) | set(b))
    changed = []
    for key in keys:
        kind_a, sa = a.get(key, (None, None))
        kind_b, sb = b.get(key, (None, None))
        kind = kind_b or kind_a
        if kind == "histogram":
            ca = sa["count"] if sa else 0
            cb = sb["count"] if sb else 0
            if ca == cb and sa and sb and sa["sum"] == sb["sum"]:
                continue
            p50a = _fmt_val(sa["p50"]) if sa else "-"
            p50b = _fmt_val(sb["p50"]) if sb else "-"
            changed.append((key, "count %+d (%d -> %d), p50 %s -> %s"
                            % (cb - ca, ca, cb, p50a, p50b)))
        else:
            va = sa["value"] if sa else 0
            vb = sb["value"] if sb else 0
            if va == vb:
                continue
            if kind == "counter":
                changed.append((key, "%+d (%d -> %d)" % (vb - va, va, vb)))
            else:
                changed.append((key, "%s -> %s" % (_fmt_val(va), _fmt_val(vb))))
    if not changed:
        print("(no differences)")
        return 0
    width = max(len(k) for k, _ in changed)
    for key, detail in changed:
        print("%-*s  %s" % (width, key, detail))
    return 0


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(prog="python -m repro.obs",
                                     description=__doc__.split("\n", 1)[0])
    sub = parser.add_subparsers(dest="cmd", required=True)
    p_show = sub.add_parser("show", help="pretty-print a metrics snapshot")
    p_show.add_argument("path")
    p_diff = sub.add_parser("diff", help="diff two metrics snapshots")
    p_diff.add_argument("path_a")
    p_diff.add_argument("path_b")
    args = parser.parse_args(argv)
    if args.cmd == "show":
        return _show(args.path)
    return _diff(args.path_a, args.path_b)


if __name__ == "__main__":
    sys.exit(main())
