"""Declared metric registry: every metric name the codebase may emit.

``SPECS`` is deliberately a **pure literal** tuple of dicts: the static
analysis pass (:mod:`repro.analysis.obs`, rule OB002) extracts it with
``ast.literal_eval`` -- no import, no jax -- renders the generated
``METRICS.md`` table from it, and pins the committed file against drift the
same way ``STREAMS.md`` pins the salt-stream registry.  Keep every entry a
plain dict of strings/tuples; no computed values, no comprehensions.

The runtime side (:mod:`repro.obs.metrics`) validates every
``counter()`` / ``gauge()`` / ``histogram()`` call against this table:
an undeclared metric name, a wrong kind, or a wrong label set raises at
the call site instead of silently forking the telemetry namespace.

Fields per spec:

    name    dotted metric name (``subsystem.metric``); counters end in
            ``_total`` by convention
    type    "counter" | "gauge" | "histogram"
    labels  tuple of label keys every series of this metric must carry
    unit    unit of the recorded value ("s", "B", "ppm", ...)
    help    one-line meaning, rendered into METRICS.md and the Prometheus
            HELP line
"""
from __future__ import annotations

SPECS = (
    # -- kernels / ops layer -------------------------------------------------
    {"name": "ops.launches_total", "type": "counter",
     "labels": ("op", "family"), "unit": "launches",
     "help": "Calls through a public repro.kernels.ops launch wrapper, by "
             "op and ambient serving family ('-' outside a family "
             "context)."},
    {"name": "ops.launch_seconds", "type": "histogram",
     "labels": ("op", "family"), "unit": "s",
     "help": "Steady-state wall time per public ops launch (dispatch time "
             "on async backends; end-to-end under the CPU interpreter). "
             "The first observed call per op lands in "
             "ops.first_call_seconds instead."},
    {"name": "ops.first_call_seconds", "type": "histogram",
     "labels": ("op",), "unit": "s",
     "help": "Wall time of the first observed call per op -- jit trace + "
             "compile + execute -- split out so compile cost never "
             "pollutes the steady-state latency histogram."},
    {"name": "ops.autotune_resolved_total", "type": "counter",
     "labels": ("kernel", "source"), "unit": "resolutions",
     "help": "Autotune block-size resolutions at trace time: "
             "source='tuned' when the roofline cache supplied blocks, "
             "'default' when the kernel's declared defaults ran."},
    {"name": "ops.interpret_mode", "type": "gauge",
     "labels": (), "unit": "bool",
     "help": "1 when Pallas launches run under the interpreter (non-TPU "
             "backend), 0 for compiled TPU launches."},
    # -- data / store layer --------------------------------------------------
    {"name": "store.resident_bytes", "type": "gauge",
     "labels": ("family",), "unit": "B",
     "help": "Allocated device bytes (capacity x fields x bytes/row) of "
             "the most recently touched CorpusStore of each family."},
    {"name": "store.rows", "type": "gauge",
     "labels": ("family",), "unit": "rows",
     "help": "Live rows (per field) of the most recently touched "
             "CorpusStore of each family."},
    {"name": "store.appends_total", "type": "counter",
     "labels": ("family",), "unit": "appends",
     "help": "CorpusStore.append batches written, by family."},
    {"name": "store.grows_total", "type": "counter",
     "labels": ("family",), "unit": "growths",
     "help": "Capacity-doubling buffer growths, by family."},
    {"name": "merge.merges_total", "type": "counter",
     "labels": ("family",), "unit": "merges",
     "help": "merge_stores calls (pairwise shard-merge steps), by family."},
    # -- serving layer -------------------------------------------------------
    {"name": "serve.request_seconds", "type": "histogram",
     "labels": ("endpoint",), "unit": "s",
     "help": "Per-request latency by endpoint: 'search' times one query, "
             "'search_batch' times one micro-batch."},
    {"name": "serve.batched_query_seconds", "type": "histogram",
     "labels": (), "unit": "s",
     "help": "Per-query latency through the batched endpoint: micro-batch "
             "wall time / batch size, one observation per micro-batch."},
    {"name": "serve.tenant_request_seconds", "type": "histogram",
     "labels": ("tenant",), "unit": "s",
     "help": "Per-request latency of tenant-scoped queries, by tenant."},
    {"name": "serve.queries_total", "type": "counter",
     "labels": (), "unit": "queries",
     "help": "Single-query search requests served."},
    {"name": "serve.batches_total", "type": "counter",
     "labels": (), "unit": "batches",
     "help": "Micro-batches served through search_batch."},
    {"name": "serve.batch_queries_total", "type": "counter",
     "labels": (), "unit": "queries",
     "help": "Individual queries served through search_batch."},
    {"name": "serve.tables_ingested_total", "type": "counter",
     "labels": (), "unit": "tables",
     "help": "Tables ingested into the serving index."},
    {"name": "serve.rows_ingested_total", "type": "counter",
     "labels": (), "unit": "rows",
     "help": "Raw table rows ingested into the serving index."},
    # -- estimator quality ---------------------------------------------------
    {"name": "quality.ppm_error", "type": "gauge",
     "labels": ("family",), "unit": "ppm",
     "help": "Rolling (EWMA, alpha=0.2) normalized estimator error in "
             "parts-per-million, from sampled query pairs re-scored "
             "against the host oracle or ground truth, by family."},
    {"name": "quality.samples_total", "type": "counter",
     "labels": ("family",), "unit": "samples",
     "help": "Quality-channel re-score samples recorded, by family."},
)
