"""jax version compatibility shims.

The distributed substrate targets two jax API generations:

  * jax >= 0.5-ish: ``jax.shard_map`` (kwarg ``check_vma``) and
    ``jax.make_mesh(..., axis_types=(jax.sharding.AxisType.Auto, ...))``.
  * jax 0.4.x (this container ships 0.4.37): ``shard_map`` lives at
    ``jax.experimental.shard_map.shard_map`` (kwarg ``check_rep``),
    ``jax.make_mesh`` exists but takes no ``axis_types``, and
    ``jax.sharding.AxisType`` does not exist at all.

Everything in-repo (``launch/mesh.py``, examples, the subprocess scripts in
``tests/test_substrate.py``) goes through these wrappers instead of touching
the version-specific spellings directly.
"""
from __future__ import annotations

import inspect
from typing import Sequence

import jax

try:  # jax >= 0.5-ish
    from jax.sharding import AxisType  # type: ignore[attr-defined]
except ImportError:  # jax 0.4.x
    AxisType = None

HAS_AXIS_TYPES = AxisType is not None


def auto_axis_types(n: int):
    """``(AxisType.Auto,) * n`` where supported, else None (0.4.x default)."""
    if HAS_AXIS_TYPES:
        return (AxisType.Auto,) * n
    return None


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str], *,
              axis_types=None, devices=None):
    """``jax.make_mesh`` that drops ``axis_types`` where unsupported."""
    kwargs = {}
    if devices is not None:
        kwargs["devices"] = devices
    if axis_types is not None and HAS_AXIS_TYPES and (
            "axis_types" in inspect.signature(jax.make_mesh).parameters):
        kwargs["axis_types"] = axis_types
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names), **kwargs)


if hasattr(jax, "shard_map"):  # jax >= 0.5-ish
    _shard_map_impl = jax.shard_map
else:  # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map_impl

# the replication-check kwarg was renamed check_rep -> check_vma around the
# time shard_map was promoted to the top level, but not atomically with it --
# probe the signature instead of keying off the import location
_CHECK_KWARG = ("check_vma"
                if "check_vma" in inspect.signature(_shard_map_impl).parameters
                else "check_rep")


def shard_map(f, *, mesh, in_specs, out_specs, check: bool = False):
    """Uniform ``shard_map``; ``check`` maps to check_vma / check_rep."""
    return _shard_map_impl(f, mesh=mesh, in_specs=in_specs,
                           out_specs=out_specs, **{_CHECK_KWARG: check})
