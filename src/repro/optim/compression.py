"""Sketch-based gradient compression for the data-parallel all-reduce.

The paper's linear-sketch baselines (CountSketch) become a distributed-
optimization feature: linear sketches are *mergeable* (S(sum g_i) = sum
S(g_i)), so replicas exchange ``reps x width`` tables instead of full
gradients -- ``jax.lax.psum`` over the data axis runs in sketch space.
Decompression is the unbiased median-of-reps point query; the residual is
carried as **error feedback** so compression noise becomes a delayed, not a
lost, signal (standard EF-SGD; converges at the uncompressed rate).

Weighted MinHash is deliberately NOT usable here: it is not linear, hence
not mergeable under addition.  That asymmetry -- WMH wins accuracy for
sparse low-overlap *estimation*, linear sketches win *mergeability* -- is
exactly the paper's linear-vs-nonlinear dichotomy, surfaced as an
engineering trade-off.  (WMH powers the telemetry path instead:
:mod:`repro.train.telemetry`.)

Runs inside ``jax.shard_map`` over the data axis; see
``examples/gradient_compression.py`` and tests.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.kernels import ref as kref


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    width: int = 4096            # table width per repetition
    reps: int = 5
    seed: int = 17
    use_kernel: bool = False     # Pallas kernel path (True on TPU)
    residual_decay: float = 0.9  # EF memory decay: bounds stale-flush energy
                                 # (beta=1 provably oscillates on dense inputs;
                                 # see tests) at the cost of slight signal loss


def compress(flat_grad: jnp.ndarray, cfg: CompressionConfig) -> jnp.ndarray:
    """[T] -> [reps, width] CountSketch table.

    The table follows the u32 kernel contract
    (:class:`repro.core.linear.CountSketchU32` is the host oracle, sharing
    the bucket/sign streams), so a compressed gradient is the same sketch a
    served CountSketch corpus row carries and can be estimated against one
    directly.  ``use_kernel=True`` routes through :func:`repro.kernels.ops.
    countsketch` -- compiled Pallas on TPU, interpreter elsewhere; the
    backend dispatch lives in the ops layer, not a hardcoded flag here --
    while ``False`` keeps the pure-jnp reference path.
    """
    if cfg.use_kernel:
        return ops.countsketch(flat_grad, width=cfg.width, reps=cfg.reps,
                               seed=cfg.seed)
    return kref.countsketch_ref(flat_grad, width=cfg.width, reps=cfg.reps,
                                seed=cfg.seed)


def decompress(table: jnp.ndarray, n: int, cfg: CompressionConfig) -> jnp.ndarray:
    """[reps, width] -> [n] median-of-reps estimates.

    ``use_kernel`` picks the ops-layer decode (today a gather-bound jnp
    path on every backend -- there is no decode kernel to dispatch to)
    versus the reference decode, mirroring :func:`compress`.
    """
    if cfg.use_kernel:
        return ops.countsketch_decode(table, jnp.arange(n), seed=cfg.seed)
    return kref.countsketch_decode_ref(table, jnp.arange(n), cfg.seed)


def ef_decode(table: jnp.ndarray, n: int, cfg: CompressionConfig,
              norm_bound: jnp.ndarray, noise_mult: float = 2.0) -> jnp.ndarray:
    """FetchSGD-style noise-thresholded decode for error feedback.

    The raw median-of-reps decode is unbiased but NOT a contraction: on a
    vector with no heavy hitters, subtracting the decoded noise *adds*
    energy, and naive EF spirals (see the divergence tests).  The repair is
    to extract only coordinates that stand above the sketch's noise floor,
    ``tau = noise_mult * ||p|| / sqrt(width)`` (per-bucket rms): heavy
    hitters are flushed, everything else stays in the residual where true
    signal grows linearly per round while collision noise grows as sqrt --
    so every coordinate eventually emerges and is applied.  (This is the
    FetchSGD extraction rule.)  A final norm clip bounds the pathological
    case where the median estimate still overshoots.
    """
    est = decompress(table, n, cfg)
    tau = noise_mult * norm_bound / jnp.sqrt(jnp.float32(cfg.width))
    est = jnp.where(jnp.abs(est) >= tau, est, 0.0)
    norm = jnp.linalg.norm(est)
    scale = jnp.minimum(1.0, norm_bound / jnp.maximum(norm, 1e-30))
    return est * scale


def compressed_update(flat_grad: jnp.ndarray, residual: jnp.ndarray,
                      axis_name: Optional[str], cfg: CompressionConfig,
                      lr: float) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Error-feedback compressed update (classical EF-SGD form).

    The residual stores the *unapplied update* (lr INSIDE the memory):
        p_t     = residual_t + lr * grad_t
        Delta_t = extract(pmean(sketch(p_t)))      <- only sketches cross links
        res_t+1 = p_t - Delta_t
        x_t+1   = x_t - Delta_t
    Applying lr after extraction instead double-counts the error through the
    next gradient and diverges -- see tests/test_substrate.py.

    Returns (Delta [T] to subtract from params, new residual [T]).
    """
    p = residual + lr * flat_grad
    table = compress(p, cfg)
    if axis_name is not None:
        table = jax.lax.pmean(table, axis_name)     # all-reduce in sketch space

    # Identify heavy hitters from the sketch; exchange their EXACT values in
    # a second (k-sized) collective.  Subtracting noisy *estimated* values
    # injects ~noise-floor energy per round and stalls/diverges EF (verified
    # in tests); identification-only decoding keeps the sketch's compression
    # for the heavy O(n) exchange while making extraction exact.  The dense
    # masked psum below is the simulation of a sparse k-value all-reduce --
    # the real wire cost is reps*width + k floats (see compression_ratio).
    est = decompress(table, p.shape[0], cfg)
    tau = 2.0 * jnp.linalg.norm(p) / jnp.sqrt(jnp.float32(cfg.width))
    k = max(1, cfg.width // 2)
    kth = jax.lax.top_k(jnp.abs(est), k)[0][-1]
    # threshold picks well-identified heavy hitters; the top-k fallback
    # guarantees progress even with no heavy hitters (exact values make any
    # mask a strict contraction, so extra coordinates are free progress)
    mask = (jnp.abs(est) >= tau) | (jnp.abs(est) >= kth)
    masked = jnp.where(mask, p, 0.0)
    if axis_name is not None:
        delta = jax.lax.pmean(masked, axis_name)    # k exact values on the wire
        delta = jnp.where(mask, delta, 0.0)
    else:
        delta = masked
    # Per-coordinate trust-region clip: a coordinate extracted after s silent
    # rounds carries ~s*lr*g_i of accumulated signal; flushing it unclipped
    # overshoots any curvature with s*lr > 2 (verified divergence on a
    # quadratic -- and a *global* norm clip does not help, because flushes
    # concentrate on few coordinates).  Cap each coordinate's step at a few
    # fresh-gradient scales; the clipped remainder stays in the residual, so
    # no signal is lost, only deferred.
    g_scale = jnp.abs(flat_grad) + jnp.linalg.norm(flat_grad) / jnp.sqrt(
        jnp.float32(flat_grad.shape[0]))
    cap = 3.0 * lr * g_scale
    delta = jnp.clip(delta, -cap, cap)
    new_residual = cfg.residual_decay * (p - delta)
    return delta, new_residual


# Back-compat alias used by earlier drafts of the examples.
compressed_psum = compressed_update


def compression_ratio(n_params: int, cfg: CompressionConfig) -> float:
    return n_params / float(cfg.width * cfg.reps)
