"""Optimizer substrate: memory-efficient AdamW + sketch-based compression."""
from . import adamw
from .adamw import AdamWConfig

__all__ = ["adamw", "AdamWConfig"]
