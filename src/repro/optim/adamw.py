"""Memory-efficient AdamW with warmup-cosine schedule and global-norm clipping.

Production memory layout for 100B+ models: a single fp32 master copy of the
parameters (no separate bf16 weight copy; compute casts at use) plus moments
in a configurable dtype (bf16 moments halve optimizer HBM -- the knob that
lets 398B Jamba train on 512 v5e chips; see EXPERIMENTS.md §Dry-run).
Optimizer state inherits the parameters' logical sharding (FSDP/ZeRO comes
for free from the same rule table).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1
    moment_dtype: str = "bfloat16"   # bf16 moments: 4 bytes/param saved


def schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def init_opt_state(params, cfg: AdamWConfig):
    dt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def opt_state_specs(param_specs):
    """Moments share the parameters' logical axes; step is replicated."""
    return {"mu": param_specs, "nu": param_specs, "step": ()}


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def apply_updates(params, grads, state, cfg: AdamWConfig):
    """One AdamW step.  params fp32 master; grads any float dtype."""
    step = state["step"] + 1
    lr = schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)
    mdt = jnp.dtype(cfg.moment_dtype)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32)
        mu_f = b1 * mu.astype(jnp.float32) + (1 - b1) * g
        nu_f = b2 * nu.astype(jnp.float32) + (1 - b2) * g * g
        mu_hat = mu_f / bc1
        nu_hat = nu_f / bc2
        delta = mu_hat / (jnp.sqrt(nu_hat) + cfg.eps) + cfg.weight_decay * \
            p.astype(jnp.float32)
        return ((p.astype(jnp.float32) - lr * delta).astype(p.dtype),
                mu_f.astype(mdt), nu_f.astype(mdt))

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(state["mu"])
    flat_nu = treedef.flatten_up_to(state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_mu = treedef.unflatten([o[1] for o in out])
    new_nu = treedef.unflatten([o[2] for o in out])
    return new_p, {"mu": new_mu, "nu": new_nu, "step": step}
