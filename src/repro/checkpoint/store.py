"""Sharded, atomic, mesh-elastic checkpointing.

Layout (tensor-store style, one file per leaf per host shard):

    <dir>/step_<k>.tmp/          written first
        manifest.json            tree structure, shapes, dtypes, mesh shape
        <leaf-path>.npy          host-local shard (or full array on 1 host)
    <dir>/step_<k>/              atomic rename when complete

Fault-tolerance properties:
  * atomicity -- a crash mid-write leaves only a .tmp dir, never a corrupt
    checkpoint; restore always picks the newest *complete* step;
  * elasticity -- arrays are saved with their *global* shapes + layout
    metadata; restore reshards onto whatever mesh the job restarts with
    (different device count included), verified in tests;
  * async -- ``save_async`` snapshots to host memory synchronously (cheap)
    and writes to disk on a background thread so training continues.
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil
import threading
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out.append((key, leaf))
    return out, treedef


def save(ckpt_dir: str, step: int, tree: Any, extra: Optional[Dict] = None):
    """Synchronous atomic checkpoint of a pytree of (device or host) arrays."""
    ckpt_dir = Path(ckpt_dir)
    tmp = ckpt_dir / f"step_{step}.tmp"
    final = ckpt_dir / f"step_{step}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    leaves, _ = _flatten_with_paths(tree)
    manifest = {"step": step, "leaves": [], "extra": extra or {}}
    for key, leaf in leaves:
        arr = np.asarray(jax.device_get(leaf))
        orig_dtype = str(arr.dtype)
        if arr.dtype.kind == "V" or orig_dtype in ("bfloat16",):
            # numpy .npy cannot round-trip ml_dtypes (bf16 etc.): store wide,
            # record the true dtype, cast back on restore.
            arr = arr.astype(np.float32)
        fname = key.replace("/", "__") + ".npy"
        np.save(tmp / fname, arr)
        manifest["leaves"].append({
            "key": key, "file": fname, "shape": list(arr.shape),
            "dtype": orig_dtype, "stored_dtype": str(arr.dtype)})
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


class AsyncCheckpointer:
    """Snapshot-to-host synchronously, write-to-disk asynchronously."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = Path(ckpt_dir)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    def save(self, step: int, tree: Any, extra: Optional[Dict] = None):
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        self.wait()
        self._thread = threading.Thread(
            target=self._write, args=(step, host_tree, extra), daemon=True)
        self._thread.start()

    def _write(self, step, host_tree, extra):
        save(self.ckpt_dir, step, host_tree, extra)
        self._gc()

    def _gc(self):
        steps = sorted(all_steps(self.ckpt_dir))
        for s in steps[:-self.keep]:
            shutil.rmtree(self.ckpt_dir / f"step_{s}", ignore_errors=True)

    def wait(self):
        if self._thread is not None and self._thread.is_alive():
            self._thread.join()


def all_steps(ckpt_dir) -> list:
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return []
    steps = []
    for p in ckpt_dir.iterdir():
        if p.is_dir() and p.name.startswith("step_") and \
                not p.name.endswith(".tmp") and (p / "manifest.json").exists():
            steps.append(int(p.name.split("_")[1]))
    return sorted(steps)


def latest_step(ckpt_dir) -> Optional[int]:
    steps = all_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore(ckpt_dir: str, step: int, target_tree: Any,
            shardings: Any = None) -> Tuple[Any, Dict]:
    """Restore into the structure of ``target_tree`` (shapes validated).

    ``shardings``: optional pytree of NamedSharding -- arrays are placed with
    jax.device_put per-shard, which is what makes restore *elastic*: the
    saved global array reshards onto the current mesh regardless of the mesh
    it was saved from.
    """
    final = Path(ckpt_dir) / f"step_{step}"
    manifest = json.loads((final / "manifest.json").read_text())
    by_key = {l["key"]: l for l in manifest["leaves"]}
    leaves, treedef = _flatten_with_paths(target_tree)
    out = []
    for key, leaf in leaves:
        meta = by_key[key]
        arr = np.load(final / meta["file"])
        if meta.get("stored_dtype", meta["dtype"]) != meta["dtype"]:
            import ml_dtypes  # ships with jax
            arr = arr.astype(np.dtype(getattr(ml_dtypes, meta["dtype"])))
        expect = tuple(np.shape(leaf)) if hasattr(leaf, "shape") else None
        if expect is not None and tuple(arr.shape) != expect:
            raise ValueError(f"shape mismatch for {key}: {arr.shape} vs {expect}")
        out.append(arr)
    restored = jax.tree_util.tree_unflatten(treedef, out)
    if shardings is not None:
        restored = jax.tree.map(
            lambda a, s: jax.device_put(a, s), restored, shardings)
    return restored, manifest["extra"]
