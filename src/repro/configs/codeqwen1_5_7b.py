"""codeqwen1.5-7b [dense] — 32L d4096 32H (MHA kv=32) d_ff=13440 vocab=92416.
qwen1.5 architecture.  [hf:Qwen/CodeQwen1.5-7B]"""
import dataclasses

from .base import ModelConfig

CONFIG = ModelConfig(
    name="codeqwen1.5-7b", family="dense",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=32, head_dim=128,
    d_ff=13440, vocab_size=92416,
    rope_theta=1e6, mlp_variant="swiglu",
)

REDUCED = dataclasses.replace(
    CONFIG, num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=256)
