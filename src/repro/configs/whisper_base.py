"""whisper-base [audio] — 6L d512 8H d_ff=2048 vocab=51865, encoder-decoder.
The conv/mel frontend is a STUB: input_specs() supplies precomputed frame
embeddings [B, 1500, 512].  [arXiv:2212.04356]"""
import dataclasses

from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base", family="encdec",
    num_layers=6, d_model=512, num_heads=8, num_kv_heads=8, head_dim=64,
    d_ff=2048, vocab_size=51865,
    encoder_layers=6, encoder_seq=1500, encoder_d_model=512,
    rope_theta=1e4, mlp_variant="gelu",
)

REDUCED = dataclasses.replace(
    CONFIG, num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=256, encoder_layers=2, encoder_seq=30,
    encoder_d_model=64)
