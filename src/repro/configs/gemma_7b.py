"""gemma-7b [dense] — 28L d3072 16H (MHA kv=16) d_ff=24576 vocab=256000,
GeGLU MLP, head_dim=256 (attention width 4096 != d_model).
[arXiv:2403.08295; hf]"""
import dataclasses

from .base import ModelConfig

CONFIG = ModelConfig(
    name="gemma-7b", family="dense",
    num_layers=28, d_model=3072, num_heads=16, num_kv_heads=16, head_dim=256,
    d_ff=24576, vocab_size=256000,
    rope_theta=1e4, mlp_variant="geglu", tie_embeddings=True,
)

REDUCED = dataclasses.replace(
    CONFIG, num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=32,
    d_ff=128, vocab_size=512)
