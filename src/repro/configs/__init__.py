"""Architecture registry: ``--arch <id>`` resolves here."""
from __future__ import annotations

from typing import Dict

from .base import SHAPES, ModelConfig, ShapeConfig, cell_applicable
from . import (codeqwen1_5_7b, gemma_7b, internvl2_1b, jamba_1_5_large_398b,
               mistral_nemo_12b, mixtral_8x22b, qwen3_moe_30b_a3b,
               rwkv6_1_6b, tinyllama_1_1b, whisper_base)

_MODULES = {
    "mixtral-8x22b": mixtral_8x22b,
    "qwen3-moe-30b-a3b": qwen3_moe_30b_a3b,
    "codeqwen1.5-7b": codeqwen1_5_7b,
    "tinyllama-1.1b": tinyllama_1_1b,
    "mistral-nemo-12b": mistral_nemo_12b,
    "gemma-7b": gemma_7b,
    "whisper-base": whisper_base,
    "internvl2-1b": internvl2_1b,
    "rwkv6-1.6b": rwkv6_1_6b,
    "jamba-1.5-large-398b": jamba_1_5_large_398b,
}

ARCHS = tuple(_MODULES.keys())


def get(name: str) -> ModelConfig:
    return _MODULES[name].CONFIG


def reduced(name: str) -> ModelConfig:
    """Small same-family config for CPU smoke tests."""
    return _MODULES[name].REDUCED


def all_configs() -> Dict[str, ModelConfig]:
    return {k: m.CONFIG for k, m in _MODULES.items()}


__all__ = ["ARCHS", "SHAPES", "ModelConfig", "ShapeConfig", "cell_applicable",
           "get", "reduced", "all_configs"]
