"""rwkv6-1.6b [ssm] — 24L d2048 (attention-free) d_ff=7168 vocab=65536.
"Finch": data-dependent per-channel decay; head size 64 => 32 heads.
[arXiv:2404.05892]"""
import dataclasses

from .base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b", family="ssm",
    num_layers=24, d_model=2048, num_heads=32, num_kv_heads=32, head_dim=64,
    d_ff=7168, vocab_size=65536,
    mlp_variant="gelu",  # rwkv channel-mix uses squared relu; see models.ssm
)

REDUCED = dataclasses.replace(
    CONFIG, num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=256)
