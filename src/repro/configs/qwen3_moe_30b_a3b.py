"""qwen3-moe-30b-a3b [moe] — 48L d2048 32H (GQA kv=4) per-expert d_ff=768
vocab=151936, MoE 128 experts top-8.  [hf:Qwen/Qwen3-30B-A3B]
head_dim=128 per the HF config (decoupled from d_model/num_heads)."""
import dataclasses

from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b", family="moe",
    num_layers=48, d_model=2048, num_heads=32, num_kv_heads=4, head_dim=128,
    d_ff=768, vocab_size=151936,
    num_experts=128, num_experts_per_tok=8,
    rope_theta=1e6, mlp_variant="swiglu",
)

REDUCED = dataclasses.replace(
    CONFIG, num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=32, vocab_size=256, num_experts=8, num_experts_per_tok=2)
