"""internvl2-1b [vlm] — 24L d896 14H (GQA kv=2) d_ff=4864 vocab=151655.
InternLM2 text backbone; the InternViT frontend is a STUB: input_specs()
supplies precomputed patch embeddings [B, 256, 896].  [arXiv:2404.16821; hf]"""
import dataclasses

from .base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b", family="vlm",
    num_layers=24, d_model=896, num_heads=14, num_kv_heads=2, head_dim=64,
    d_ff=4864, vocab_size=151655,
    num_patches=256,
    rope_theta=1e6, mlp_variant="swiglu",
)

REDUCED = dataclasses.replace(
    CONFIG, num_layers=2, d_model=56, num_heads=2, num_kv_heads=1, head_dim=28,
    d_ff=128, vocab_size=256, num_patches=8)
