"""Model configuration schema + shape suite shared by every architecture."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # MoE
    num_experts: int = 0
    num_experts_per_tok: int = 0
    moe_every: int = 1           # MoE FFN at layers where (layer % moe_every == moe_offset)
    moe_offset: int = 0
    capacity_factor: float = 1.25

    # attention
    sliding_window: int = 0      # 0 => full attention
    rope_theta: float = 1e4
    mlp_variant: str = "swiglu"  # swiglu | geglu | gelu

    # ssm (rwkv6 / mamba)
    ssm_state: int = 16          # mamba d_state
    ssm_expand: int = 2          # mamba d_inner = expand * d_model
    ssm_conv: int = 4            # mamba causal-conv width
    ssm_dt_rank: int = 0         # 0 => d_model // 16

    # hybrid (jamba): layers per group and the attention position inside it
    hybrid_group: int = 8        # 1 attention layer per `hybrid_group` layers
    hybrid_attn_index: int = 0

    # encoder-decoder (whisper): encoder depth + stub frontend sequence
    encoder_layers: int = 0
    encoder_seq: int = 0
    encoder_d_model: int = 0

    # vlm: stub patch-embedding count
    num_patches: int = 0

    norm_eps: float = 1e-6
    tie_embeddings: bool = False

    @property
    def dt_rank(self) -> int:
        return self.ssm_dt_rank or max(1, self.d_model // 16)

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // max(self.num_kv_heads, 1)

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def subquadratic(self) -> bool:
        """Can this arch decode at 500k context with bounded state?"""
        if self.family in ("ssm",):
            return True
        if self.family == "hybrid":
            return True               # attention is 1:hybrid_group and KV is small
        return self.sliding_window > 0  # SWA bounds the KV cache

    def param_count(self) -> int:
        """Analytic parameter count (used for roofline MODEL_FLOPS)."""
        from repro.models.counting import count_params
        return count_params(self)

    def active_param_count(self) -> int:
        from repro.models.counting import count_active_params
        return count_active_params(self)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


# The assigned LM shape suite (identical for all 10 archs).
SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def cell_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Is (arch x shape) a runnable dry-run cell?  Returns (ok, reason)."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, "pure full-attention arch: 500k decode needs sub-quadratic attention"
    return True, ""
