"""jamba-1.5-large-398b [hybrid] — 72L d8192 64H (GQA kv=8) d_ff=24576
vocab=65536, MoE 16 experts top-2, Mamba:attention 7:1 interleave
(1 attention layer per 8-layer group), MoE every other layer.
[arXiv:2403.19887; hf]"""
import dataclasses

from .base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b", family="hybrid",
    num_layers=72, d_model=8192, num_heads=64, num_kv_heads=8, head_dim=128,
    d_ff=24576, vocab_size=65536,
    num_experts=16, num_experts_per_tok=2, moe_every=2, moe_offset=1,
    hybrid_group=8, hybrid_attn_index=4,
    ssm_state=16, ssm_expand=2, ssm_conv=4,
    rope_theta=1e4, mlp_variant="swiglu",
)

REDUCED = dataclasses.replace(
    CONFIG, num_layers=8, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256, num_experts=4, hybrid_group=4,
    hybrid_attn_index=2, ssm_state=4)
