"""Linear sketches: JL/AMS projection and CountSketch (median-of-5).

These are the paper's linear baselines (Fact 1).  Both are *linear* maps
S(a) = Pi a, hence mergeable under addition -- the property
:mod:`repro.optim.compression` exploits to all-reduce gradients in sketch
space.  Signs/buckets come from 4-wise independent polynomial hashes so the
classic AMS/CountSketch variance analysis applies.

Two hash contracts live here:

  * :class:`JL` / :class:`CountSketch` -- the paper-faithful baselines,
    4-wise independent polynomial hashes over Z_p (host only).
  * :class:`JLU32` / :class:`CountSketchU32` -- the *device-contract*
    variants: signs and buckets drawn from the uint32 mixing RNG the Pallas
    kernels use (:mod:`repro.core.u32` mirrors ``repro.kernels.common``),
    exactly as :class:`repro.core.icws.ICWS` mirrors the ICWS kernel.  A
    host-U32-sketched vector and a device-sketched vector carry the same
    table up to f32 summation order, so these are the cross-checked host
    oracles for the device CS/JL serving path.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from . import u32
from .hashing import MERSENNE_P, _mix_to_zp, _rng
from .types import SparseVec

# u32 salt streams shared with the kernels: host twins of the identically
# named constants in repro.kernels.common (kept in sync the same way
# repro.core.u32 twins the mixers; this package stays numpy-only, so it
# never imports the kernels).  CountSketch buckets/signs reuse the dense
# gradient-compression kernel's streams so a sparse vector sketched by key
# and a dense vector sketched by position interoperate when keys ==
# positions; JL signs get their own stream.
CS_BUCKET_STREAM = 21
CS_SIGN_STREAM = 22
JL_SIGN_STREAM = 31


def _keys_u32(indices: np.ndarray) -> np.ndarray:
    """Fold int64 indices into the kernels' uint32 key domain."""
    return (np.asarray(indices, np.int64) & np.int64(0xFFFFFFFF)).astype(np.uint32)


def _poly_hash(coeffs: np.ndarray, x: np.ndarray) -> np.ndarray:
    """4-wise independent polynomial hash over Z_p. coeffs [k, deg], x [nnz]."""
    x = _mix_to_zp(np.asarray(x, dtype=np.int64))
    acc = np.zeros((coeffs.shape[0], x.shape[0]), dtype=np.int64)
    for d in range(coeffs.shape[1]):  # Horner, mod p every step: products < 2^62
        acc = (acc * x[None, :] + coeffs[:, d][:, None]) % MERSENNE_P
    return acc


def _make_coeffs(k: int, deg: int, seed: int) -> np.ndarray:
    g = _rng(seed)
    return g.integers(0, MERSENNE_P, size=(k, deg), dtype=np.int64)


# ---------------------------------------------------------------------------
# JL / AMS sketch
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class JLSketch:
    proj: np.ndarray  # float64 [m]

    def storage_doubles(self) -> float:
        return float(self.proj.shape[0])


class JL:
    """S(a)[t] = (1/sqrt(m)) * sum_i sigma_t(i) a_i, sigma 4-wise +-1."""

    name = "jl"

    def __init__(self, m: int, seed: int = 0):
        self.m = int(m)
        self.seed = int(seed)
        self._coeffs = _make_coeffs(self.m, 4, seed ^ 0x11)

    def sketch(self, v: SparseVec) -> JLSketch:
        if v.nnz == 0:
            return JLSketch(proj=np.zeros(self.m))
        h = _poly_hash(self._coeffs, v.indices)          # [m, nnz]
        signs = 1.0 - 2.0 * (h & 1).astype(np.float64)
        return JLSketch(proj=(signs @ v.values) / np.sqrt(self.m))

    def sketch_dense(self, a: np.ndarray) -> JLSketch:
        return self.sketch(SparseVec.from_dense(a))

    def estimate(self, sa: JLSketch, sb: JLSketch) -> float:
        return float(np.dot(sa.proj, sb.proj))

    def merge(self, sa: JLSketch, sb: JLSketch) -> JLSketch:
        """Linearity: S(a + b) = S(a) + S(b)."""
        return JLSketch(proj=sa.proj + sb.proj)


# ---------------------------------------------------------------------------
# CountSketch, median of 5 repetitions [Charikar et al.; Larsen et al. 2021]
# ---------------------------------------------------------------------------
REPS = 5


@dataclasses.dataclass
class CSSketch:
    table: np.ndarray  # float64 [REPS, width]

    def storage_doubles(self) -> float:
        return float(self.table.size)


class CountSketch:
    name = "cs"

    def __init__(self, width: int, seed: int = 0, reps: int = REPS):
        self.width = int(width)
        self.reps = int(reps)
        self.seed = int(seed)
        self._bucket_coeffs = _make_coeffs(self.reps, 4, seed ^ 0x22)
        self._sign_coeffs = _make_coeffs(self.reps, 4, seed ^ 0x33)

    def sketch(self, v: SparseVec) -> CSSketch:
        table = np.zeros((self.reps, self.width), dtype=np.float64)
        if v.nnz == 0:
            return CSSketch(table=table)
        buckets = _poly_hash(self._bucket_coeffs, v.indices) % self.width
        signs = 1.0 - 2.0 * (_poly_hash(self._sign_coeffs, v.indices) & 1)
        for r in range(self.reps):
            np.add.at(table[r], buckets[r], signs[r] * v.values)
        return CSSketch(table=table)

    def sketch_dense(self, a: np.ndarray) -> CSSketch:
        return self.sketch(SparseVec.from_dense(a))

    def estimate(self, sa: CSSketch, sb: CSSketch) -> float:
        per_rep = np.sum(sa.table * sb.table, axis=1)
        return float(np.median(per_rep))

    def merge(self, sa: CSSketch, sb: CSSketch) -> CSSketch:
        return CSSketch(table=sa.table + sb.table)

    # decompress: unbiased point query (used by gradient compression)
    def decode(self, s: CSSketch, indices: np.ndarray) -> np.ndarray:
        buckets = _poly_hash(self._bucket_coeffs, indices) % self.width
        signs = 1.0 - 2.0 * (_poly_hash(self._sign_coeffs, indices) & 1)
        est = np.stack([s.table[r, buckets[r]] * signs[r] for r in range(self.reps)])
        return np.median(est, axis=0)


# ---------------------------------------------------------------------------
# Device-contract variants: u32 mixing RNG shared with the Pallas kernels
# ---------------------------------------------------------------------------
class JLU32:
    """JL projection drawing signs from the kernel u32 RNG.

    Host oracle for the device JL family: ``sigma_t(i)`` is the parity of
    ``hash_u32(key_i, salt(seed, JL_SIGN_STREAM, t))`` -- the same variates
    the Pallas JL sketch kernel draws, so host and device sketches of one
    vector agree up to f32 vs f64 summation order.
    """

    name = "jl_u32"

    def __init__(self, m: int, seed: int = 0):
        self.m = int(m)
        self.seed = int(seed)

    def sketch(self, v: SparseVec) -> JLSketch:
        if v.nnz == 0:
            return JLSketch(proj=np.zeros(self.m))
        salt = u32.salt_for(self.seed, JL_SIGN_STREAM, np.arange(self.m))
        h = u32.hash_u32(_keys_u32(v.indices)[None, :], salt[:, None])  # [m, nnz]
        signs = 1.0 - 2.0 * (h & np.uint32(1)).astype(np.float64)
        return JLSketch(proj=(signs @ v.values) / np.sqrt(self.m))

    def sketch_dense(self, a: np.ndarray) -> JLSketch:
        return self.sketch(SparseVec.from_dense(a))

    def estimate(self, sa: JLSketch, sb: JLSketch) -> float:
        return float(np.dot(sa.proj, sb.proj))

    def merge(self, sa: JLSketch, sb: JLSketch) -> JLSketch:
        return JLSketch(proj=sa.proj + sb.proj)


class CountSketchU32:
    """CountSketch drawing buckets/signs from the kernel u32 RNG.

    Host oracle for the device CS family.  Streams match the dense
    gradient-compression kernel (:mod:`repro.kernels.countsketch`), so a
    sparse vector sketched by key here equals the dense kernel's sketch of
    the densified vector (keys == positions), up to f32 summation order.
    """

    name = "cs_u32"

    def __init__(self, width: int, seed: int = 0, reps: int = REPS):
        self.width = int(width)
        self.reps = int(reps)
        self.seed = int(seed)

    def _hashes(self, indices: np.ndarray):
        r = np.arange(self.reps)
        keys = _keys_u32(indices)[None, :]
        hb = u32.hash_u32(keys, u32.salt_for(self.seed, CS_BUCKET_STREAM, r)[:, None])
        buckets = (hb % np.uint32(self.width)).astype(np.int64)       # [R, nnz]
        hs = u32.hash_u32(keys, u32.salt_for(self.seed, CS_SIGN_STREAM, r)[:, None])
        signs = 1.0 - 2.0 * (hs & np.uint32(1)).astype(np.float64)
        return buckets, signs

    def sketch(self, v: SparseVec) -> CSSketch:
        table = np.zeros((self.reps, self.width), dtype=np.float64)
        if v.nnz == 0:
            return CSSketch(table=table)
        buckets, signs = self._hashes(v.indices)
        for r in range(self.reps):
            np.add.at(table[r], buckets[r], signs[r] * v.values)
        return CSSketch(table=table)

    def sketch_dense(self, a: np.ndarray) -> CSSketch:
        return self.sketch(SparseVec.from_dense(a))

    def estimate(self, sa: CSSketch, sb: CSSketch) -> float:
        per_rep = np.sum(sa.table * sb.table, axis=1)
        return float(np.median(per_rep))

    def merge(self, sa: CSSketch, sb: CSSketch) -> CSSketch:
        return CSSketch(table=sa.table + sb.table)

    def decode(self, s: CSSketch, indices: np.ndarray) -> np.ndarray:
        buckets, signs = self._hashes(indices)
        est = np.stack([s.table[r, buckets[r]] * signs[r]
                        for r in range(self.reps)])
        return np.median(est, axis=0)
