"""Linear sketches: JL/AMS projection and CountSketch (median-of-5).

These are the paper's linear baselines (Fact 1).  Both are *linear* maps
S(a) = Pi a, hence mergeable under addition -- the property
:mod:`repro.optim.compression` exploits to all-reduce gradients in sketch
space.  Signs/buckets come from 4-wise independent polynomial hashes so the
classic AMS/CountSketch variance analysis applies.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .hashing import MERSENNE_P, _mix_to_zp, _rng
from .types import SparseVec


def _poly_hash(coeffs: np.ndarray, x: np.ndarray) -> np.ndarray:
    """4-wise independent polynomial hash over Z_p. coeffs [k, deg], x [nnz]."""
    x = _mix_to_zp(np.asarray(x, dtype=np.int64))
    acc = np.zeros((coeffs.shape[0], x.shape[0]), dtype=np.int64)
    for d in range(coeffs.shape[1]):  # Horner, mod p every step: products < 2^62
        acc = (acc * x[None, :] + coeffs[:, d][:, None]) % MERSENNE_P
    return acc


def _make_coeffs(k: int, deg: int, seed: int) -> np.ndarray:
    g = _rng(seed)
    return g.integers(0, MERSENNE_P, size=(k, deg), dtype=np.int64)


# ---------------------------------------------------------------------------
# JL / AMS sketch
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class JLSketch:
    proj: np.ndarray  # float64 [m]

    def storage_doubles(self) -> float:
        return float(self.proj.shape[0])


class JL:
    """S(a)[t] = (1/sqrt(m)) * sum_i sigma_t(i) a_i, sigma 4-wise +-1."""

    name = "jl"

    def __init__(self, m: int, seed: int = 0):
        self.m = int(m)
        self.seed = int(seed)
        self._coeffs = _make_coeffs(self.m, 4, seed ^ 0x11)

    def sketch(self, v: SparseVec) -> JLSketch:
        if v.nnz == 0:
            return JLSketch(proj=np.zeros(self.m))
        h = _poly_hash(self._coeffs, v.indices)          # [m, nnz]
        signs = 1.0 - 2.0 * (h & 1).astype(np.float64)
        return JLSketch(proj=(signs @ v.values) / np.sqrt(self.m))

    def sketch_dense(self, a: np.ndarray) -> JLSketch:
        return self.sketch(SparseVec.from_dense(a))

    def estimate(self, sa: JLSketch, sb: JLSketch) -> float:
        return float(np.dot(sa.proj, sb.proj))

    def merge(self, sa: JLSketch, sb: JLSketch) -> JLSketch:
        """Linearity: S(a + b) = S(a) + S(b)."""
        return JLSketch(proj=sa.proj + sb.proj)


# ---------------------------------------------------------------------------
# CountSketch, median of 5 repetitions [Charikar et al.; Larsen et al. 2021]
# ---------------------------------------------------------------------------
REPS = 5


@dataclasses.dataclass
class CSSketch:
    table: np.ndarray  # float64 [REPS, width]

    def storage_doubles(self) -> float:
        return float(self.table.size)


class CountSketch:
    name = "cs"

    def __init__(self, width: int, seed: int = 0, reps: int = REPS):
        self.width = int(width)
        self.reps = int(reps)
        self.seed = int(seed)
        self._bucket_coeffs = _make_coeffs(self.reps, 4, seed ^ 0x22)
        self._sign_coeffs = _make_coeffs(self.reps, 4, seed ^ 0x33)

    def sketch(self, v: SparseVec) -> CSSketch:
        table = np.zeros((self.reps, self.width), dtype=np.float64)
        if v.nnz == 0:
            return CSSketch(table=table)
        buckets = _poly_hash(self._bucket_coeffs, v.indices) % self.width
        signs = 1.0 - 2.0 * (_poly_hash(self._sign_coeffs, v.indices) & 1)
        for r in range(self.reps):
            np.add.at(table[r], buckets[r], signs[r] * v.values)
        return CSSketch(table=table)

    def sketch_dense(self, a: np.ndarray) -> CSSketch:
        return self.sketch(SparseVec.from_dense(a))

    def estimate(self, sa: CSSketch, sb: CSSketch) -> float:
        per_rep = np.sum(sa.table * sb.table, axis=1)
        return float(np.median(per_rep))

    def merge(self, sa: CSSketch, sb: CSSketch) -> CSSketch:
        return CSSketch(table=sa.table + sb.table)

    # decompress: unbiased point query (used by gradient compression)
    def decode(self, s: CSSketch, indices: np.ndarray) -> np.ndarray:
        buckets = _poly_hash(self._bucket_coeffs, indices) % self.width
        signs = 1.0 - 2.0 * (_poly_hash(self._sign_coeffs, indices) & 1)
        est = np.stack([s.table[r, buckets[r]] * signs[r] for r in range(self.reps)])
        return np.median(est, axis=0)
