"""Numpy twins of the in-kernel uint32 RNG (:mod:`repro.kernels.common`).

The Pallas kernels build all pseudo-randomness from murmur3-style uint32
mixing so the TPU vector units never touch 64-bit integers.  The host ICWS
sketcher must draw the *same* variates and fingerprints, otherwise a
host-sketched corpus and a device-sketched query silently report zero
collisions (every fingerprint differs).  These functions mirror
``repro.kernels.common`` operation for operation: the integer parts are
bit-exact (uint32 wrap-around arithmetic), and the float parts perform the
same IEEE f32 operations, so host/device sketches agree except where libm
and XLA transcendentals differ in the last ulp *and* that ulp flips a floor
or an argmin (empirically <<1% of samples; the contract test in
``tests/test_icws_contract.py`` pins this).

All functions take and return numpy arrays; integer overflow wraps mod 2^32
by construction (uint32 array arithmetic).
"""
from __future__ import annotations

import numpy as np

_M1 = np.uint32(0x85EBCA6B)
_M2 = np.uint32(0xC2B2AE35)
_GOLDEN = np.uint32(0x9E3779B9)

# Host twins of the ICWS salt streams in ``repro.kernels.common`` -- same
# names, same values, checked by ``repro.analysis`` rule SR004 (the CS/JL
# twins live in repro.core.linear, the TS/PS twin in repro.core.sampling;
# this module mirrors the mixers, so it also mirrors the ICWS streams its
# callers draw from).
ICWS_R1_STREAM = 1
ICWS_R2_STREAM = 2
ICWS_C1_STREAM = 3
ICWS_C2_STREAM = 4
ICWS_BETA_STREAM = 5
ICWS_FP_STREAM = 9
# Host twins of the DMH (densified one-permutation weighted MinHash) salt
# streams: bin assignment, ICWS-style variates drawn at t = bin, the
# per-bin fingerprint salt, and the reseeded densification probes
# (``repro.core.dmh`` draws from these).
DMH_BIN_STREAM = 51
DMH_R1_STREAM = 52
DMH_R2_STREAM = 53
DMH_C1_STREAM = 54
DMH_C2_STREAM = 55
DMH_BETA_STREAM = 56
DMH_FP_STREAM = 57
DMH_DENSIFY_STREAM = 58


def mix32(x: np.ndarray) -> np.ndarray:
    """Murmur3 fmix32 over uint32 lanes; twin of ``kernels.common.mix32``."""
    z = np.asarray(x).astype(np.uint32)
    z = z ^ (z >> np.uint32(16))
    z = z * _M1
    z = z ^ (z >> np.uint32(13))
    z = z * _M2
    z = z ^ (z >> np.uint32(16))
    return z


def hash_u32(key: np.ndarray, salt: np.ndarray) -> np.ndarray:
    """Twin of ``kernels.common.hash_u32`` (two mixing rounds, broadcast)."""
    k = np.asarray(key).astype(np.uint32)
    s = np.asarray(salt).astype(np.uint32)
    return mix32(mix32(k + s * _GOLDEN)
                 ^ (s * _M2 + np.uint32(0x27D4EB2F)))


def uniform01(key: np.ndarray, salt: np.ndarray) -> np.ndarray:
    """Strictly-interior uniform (0,1) f32; twin of ``kernels.common.uniform01``.

    The uint32 hash and the 24-bit -> f32 conversion are exact, so these
    match the kernel bit for bit.
    """
    bits = hash_u32(key, salt) >> np.uint32(8)
    return (bits.astype(np.float32) * np.float32(2 ** -24)
            + np.float32(2 ** -25))


def salt_for(seed: int, stream: int, t: np.ndarray) -> np.ndarray:
    """Twin of ``kernels.common.salt_for``: (seed, stream, sample) -> salt."""
    base = ((int(seed) & 0xFFFFFFFF) * 0x9E3779B1
            + int(stream) * 0x517CC1B7) & 0xFFFFFFFF
    return (np.uint32(base)
            + np.asarray(t).astype(np.uint32) * np.uint32(0x2545F491))
