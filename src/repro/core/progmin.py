"""Exact minimum of an arithmetic progression mod p in O(log p) — vectorized.

Why this exists
---------------
Algorithm 3 of the paper conceptually hashes every one of the ``k_i`` active
slots of block ``i`` of the extended vector (``k_i`` up to ``L = 10^7``) and
keeps the minimum.  The paper's fast path (the "active index" trick of
Gollapudi & Panigrahy) skips ahead with geometric jumps -- an inherently
*sequential, data-dependent* loop that does not map to TPU vector units.

Our TPU-native replacement exploits the hash structure instead: with the
multilinear pair hash ``h(i, j) = (a*i + b*j + c) mod p``, the slot hashes of
block ``i`` form the arithmetic progression ``start_i + j*b (mod p)``,
``j = 0..k_i-1``.  The minimum of such a progression is computable *exactly*
in O(log p) by a Euclidean descent (each step at least halves the modulus), as
a fixed-trip-count, branch-free loop over the whole ``(m, nnz)`` grid -- the
same answer as hashing all ``k_i`` slots, bit for bit.

Recurrence (all quantities integers):

``f(a, b, m, n) = min_{i=0..n-1} (a*i + b) mod m``,  with ``0 <= a, b < m``.

* ``a == 0`` or ``n == 1``          ->  ``b``.
* ``a <= m/2`` (increasing steps): segment minima are the start ``b`` and the
  post-wrap values ``v_t = (b - t*m) mod a`` for ``t = 1..T``,
  ``T = (a*(n-1) + b) // m``.  If ``T == 0`` the answer is ``b``; otherwise
  ``min(b, f((-m) mod a, (b - m) mod a, a, T))``  (modulus drops to ``a``).
* ``a >  m/2`` (decreasing by ``d = m - a``): if the sequence never wraps
  (``d*(n-1) <= b``) the answer is ``b - d*(n-1)``.  Otherwise the candidates
  are the pre-wrap values ``(b + k*m) mod d`` of the ``K`` completed segments,
  ``K = (d*n - 1 - b) // m + 1``, plus the final value
  ``(b - d*(n-1)) mod m``; so ``min(v_last, f(m mod d, b mod d, d, K))``
  (modulus drops to ``d < m/2``).

Both branches at least halve the modulus, so 40 iterations cover any
``m < 2^31``.  int64 products stay below ~2^56 for ``n <= 2^24``.
"""
from __future__ import annotations

import numpy as np

_MAX_ITERS = 48  # modulus halves each iteration; 2^31 modulus needs <= 32.


def progression_min(a, b, m, n) -> np.ndarray:
    """Elementwise min_{i=0..n-1} (a*i + b) mod m over int64 arrays.

    Arguments broadcast against each other.  Requires 0 <= a < m, 0 <= b < m,
    n >= 1 elementwise (validated cheaply).  Returns int64 array.
    """
    a, b, m, n = np.broadcast_arrays(
        np.asarray(a, dtype=np.int64), np.asarray(b, dtype=np.int64),
        np.asarray(m, dtype=np.int64), np.asarray(n, dtype=np.int64))
    a, b, m, n = (np.ascontiguousarray(x).copy() for x in (a, b, m, n))
    if a.size == 0:
        return np.zeros_like(a)
    if np.any(n < 1) or np.any(a < 0) or np.any(b < 0) or np.any(a >= m) or np.any(b >= m):
        raise ValueError("progression_min requires 0<=a<m, 0<=b<m, n>=1")

    best = m - 1  # values are < m, so m-1 is a safe "infinity" within range
    best = best.copy()
    active = np.ones(a.shape, dtype=bool)

    for _ in range(_MAX_ITERS):
        if not active.any():
            break
        # --- terminal cases -------------------------------------------------
        term = active & ((a == 0) | (n == 1))
        best[term] = np.minimum(best[term], b[term])
        active &= ~term

        half = m >> 1
        inc = active & (a <= half)
        dec = active & (a > half)

        # --- increasing branch ----------------------------------------------
        if inc.any():
            ai, bi, mi, ni = a[inc], b[inc], m[inc], n[inc]
            T = (ai * (ni - 1) + bi) // mi
            best[inc] = np.minimum(best[inc], bi)  # b is always a candidate
            done = T == 0
            # recursion: modulus -> a
            na = (-mi) % ai
            nb = (bi - mi) % ai
            sub = np.zeros(a.shape, dtype=bool)
            sub[inc] = ~done
            fin = np.zeros(a.shape, dtype=bool)
            fin[inc] = done
            active &= ~fin
            a[sub], b[sub], mval, nval = na[~done], nb[~done], ai[~done], T[~done]
            m[sub], n[sub] = mval, nval

        # --- decreasing branch ----------------------------------------------
        if dec.any():
            ad, bd, md, nd = a[dec], b[dec], m[dec], n[dec]
            d = md - ad
            nowrap = d * (nd - 1) <= bd
            # no-wrap: min is the final value b - d*(n-1)
            vals_nowrap = bd - d * (nd - 1)
            # wrap: candidates = completed-segment pre-wrap mins + final value
            v_last = (bd - d * (nd - 1)) % md
            K = np.where(nowrap, 1, (d * nd - 1 - bd) // md + 1)
            upd = np.where(nowrap, vals_nowrap, v_last)
            best[dec] = np.minimum(best[dec], upd)
            fin = np.zeros(a.shape, dtype=bool)
            fin[dec] = nowrap
            active &= ~fin
            sub = np.zeros(a.shape, dtype=bool)
            sub[dec] = ~nowrap
            a[sub] = (md % d)[~nowrap]
            b[sub] = (bd % d)[~nowrap]
            m[sub] = d[~nowrap]
            n[sub] = K[~nowrap]

    if active.any():  # pragma: no cover - mathematically unreachable
        raise RuntimeError("progression_min failed to converge")
    return best


def progression_min_bruteforce(a: int, b: int, m: int, n: int) -> int:
    """O(n) oracle used by tests.  Keep n small."""
    i = np.arange(int(n), dtype=np.int64)
    return int(np.min((np.int64(a) * i + np.int64(b)) % np.int64(m)))
