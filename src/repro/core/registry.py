"""Uniform sketcher registry with the paper's storage accounting.

Every method exposes: ``make(storage_doubles, seed) -> sketcher`` whose
``sketch`` / ``estimate`` follow that method's class, sized so that the
*total* storage (in 64-bit-double equivalents, the paper's x-axis) matches
``storage_doubles``:

  jl    : m rows of doubles                      -> m = storage
  cs    : 5 reps x width doubles                 -> width = storage / 5
  mh    : 1.5 per sample (32b hash + 64b value)  -> m = storage / 1.5
  kmv   : 1.5 per sample                         -> k = storage / 1.5
  wmh   : 1.5 per sample + 1 (norm)              -> m = (storage - 1) / 1.5
  icws  : 1.5 per sample + 1 (norm)              -> m = (storage - 1) / 1.5
  dmh   : 1.5 per sample + 1 (norm)              -> m = (storage - 1) / 1.5
  ts/ps : 1 per slot (i32 key + f32 val) + 1 (tau) -> slots = storage - 1
"""
from __future__ import annotations

from typing import Callable, Dict

from .dmh import DMH
from .icws import ICWS
from .kmv import KMV
from .linear import REPS, CountSketch, JL
from .minhash import MinHash
from .sampling import PrioritySamplingU32, ThresholdSamplingU32
from .wmh import DEFAULT_L, WeightedMinHash


def make_jl(storage: float, seed: int = 0):
    return JL(m=max(1, int(storage)), seed=seed)


def make_cs(storage: float, seed: int = 0):
    return CountSketch(width=max(1, int(storage // REPS)), seed=seed)


def make_mh(storage: float, seed: int = 0):
    return MinHash(m=max(1, int(storage / 1.5)), seed=seed)


def make_kmv(storage: float, seed: int = 0):
    return KMV(k=max(1, int(storage / 1.5)), seed=seed)


def make_wmh(storage: float, seed: int = 0, L: int = DEFAULT_L):
    return WeightedMinHash(m=max(1, int((storage - 1) / 1.5)), seed=seed, L=L)


def make_icws(storage: float, seed: int = 0):
    return ICWS(m=max(1, int((storage - 1) / 1.5)), seed=seed)


def make_dmh(storage: float, seed: int = 0):
    # identical wire layout and accounting to ICWS -- only ingest differs
    return DMH(m=max(1, int((storage - 1) / 1.5)), seed=seed)


def make_ts(storage: float, seed: int = 0):
    return ThresholdSamplingU32(slots=max(1, int(storage - 1)), seed=seed)


def make_ps(storage: float, seed: int = 0):
    return PrioritySamplingU32(slots=max(1, int(storage - 1)), seed=seed)


FACTORIES: Dict[str, Callable] = {
    "jl": make_jl,
    "cs": make_cs,
    "mh": make_mh,
    "kmv": make_kmv,
    "wmh": make_wmh,
    "icws": make_icws,
    "dmh": make_dmh,
    "ts": make_ts,
    "ps": make_ps,
}

PAPER_METHODS = ("jl", "cs", "mh", "kmv", "wmh")  # the five in the paper's plots


def make(method: str, storage: float, seed: int = 0):
    return FACTORIES[method](storage, seed=seed)
