"""ICWS (Ioffe Consistent Weighted Sampling) — the TPU-native WMH variant.

The paper's WMH family includes Consistent Weighted Sampling and its
descendants, "essentially equivalent, but computationally cheaper to apply"
(Section 2, citing Ioffe 2010).  ICWS achieves the exact weighted-Jaccard
collision law

    P[sample_a == sample_b] = sum_i min(wa_i, wb_i) / sum_i max(wa_i, wb_i)

with O(1) *pure f32 elementwise* work per (non-zero x hash): log/exp/floor and
an argmin -- ideal VPU shape, no big-integer arithmetic, and it removes the
discretization parameter L (and the n^6/eps^2 rounding analysis) entirely.
This module is the host (numpy) reference; the Pallas kernel in
``repro.kernels.icws_sketch`` computes the same quantities on-device.

Per (index i, sample t), keyed pseudo-randomness:
    r ~ Gamma(2,1)   (= -log(u1*u2)),   c ~ Gamma(2,1),   beta ~ U[0,1]
    t_i  = floor(log(w_i) / r + beta)
    y_i  = exp(r * (t_i - beta))
    a_i  = c / (y_i * exp(r))
Sample = argmin_i a_i; two sketches collide at sample t iff the argmin *index*
and its *level* t_i agree.  We store a 32-bit fingerprint of (index, level)
for collision detection (paper-style 1.5m+1 doubles storage), plus the signed
normalized value at the argmin and ||a||.

Estimator (Algorithm 5 adapted): with unit-norm weights w = (a/||a||)^2 we
have  sum_i min + sum_i max = ||a~||^2 + ||b~||^2 = 2,  so the weighted union
size is  M = 2 / (1 + J)  with J the weighted Jaccard -- estimated by the
collision rate J^ = mean(collide) with the same O(1/sqrt(m)) concentration as
the paper's Lemma 1.  The rest of Algorithm 5 is unchanged.
"""
from __future__ import annotations

import dataclasses
from typing import List

import numpy as np

from .hashing import uniforms_from_key
from .types import SparseVec


@dataclasses.dataclass
class ICWSSketch:
    fingerprints: np.ndarray  # int64 [m]: 32-bit fp of (argmin index, level); -1 empty
    values: np.ndarray        # float64 [m]: normalized signed value at argmin
    norm: float

    def storage_doubles(self) -> float:
        return 1.5 * self.fingerprints.shape[0] + 1.0


def _fingerprint(keys: np.ndarray, levels: np.ndarray, t: np.ndarray) -> np.ndarray:
    """32-bit mix of (vector index, ICWS level, sample id)."""
    z = (keys.astype(np.uint64) * np.uint64(0x9E3779B97F4A7C15)
         ^ (levels.astype(np.int64).astype(np.uint64) + np.uint64(0x2545F4914F6CDD1D))
         ^ (t.astype(np.uint64) << np.uint64(32)))
    z = (z ^ (z >> np.uint64(33))) * np.uint64(0xFF51AFD7ED558CCD)
    z = z ^ (z >> np.uint64(33))
    return (z & np.uint64(0xFFFFFFFF)).astype(np.int64)


class ICWS:
    name = "icws"

    def __init__(self, m: int, seed: int = 0):
        self.m = int(m)
        self.seed = int(seed)

    def _variates(self, keys: np.ndarray):
        u1 = uniforms_from_key(self.seed, 1, keys, self.m)
        u2 = uniforms_from_key(self.seed, 2, keys, self.m)
        u3 = uniforms_from_key(self.seed, 3, keys, self.m)
        u4 = uniforms_from_key(self.seed, 4, keys, self.m)
        beta = uniforms_from_key(self.seed, 5, keys, self.m)
        r = -np.log(u1 * u2)      # Gamma(2,1)
        c = -np.log(u3 * u4)      # Gamma(2,1)
        return r, c, beta         # each [m, nnz]

    def sketch(self, v: SparseVec) -> ICWSSketch:
        norm = v.norm()
        if v.nnz == 0 or norm == 0.0:
            return ICWSSketch(fingerprints=np.full(self.m, -1, np.int64),
                              values=np.zeros(self.m), norm=0.0)
        z = v.values / norm
        w = z * z                                   # weights, sum == 1
        r, c, beta = self._variates(v.indices)      # [m, nnz]
        logw = np.log(w)[None, :]
        lvl = np.floor(logw / r + beta)             # t_i
        y = np.exp(r * (lvl - beta))
        a = c / (y * np.exp(r))
        arg = np.argmin(a, axis=1)                  # [m]
        rows = np.arange(self.m)
        fp = _fingerprint(v.indices[arg], lvl[rows, arg], rows)
        return ICWSSketch(fingerprints=fp, values=z[arg], norm=norm)

    def sketch_dense(self, a: np.ndarray) -> ICWSSketch:
        return self.sketch(SparseVec.from_dense(a))

    def estimate(self, sa: ICWSSketch, sb: ICWSSketch) -> float:
        return float(self.estimate_batch(_stack([sa]), _stack([sb]))[0])

    def estimate_batch(self, A: "StackedICWS", B: "StackedICWS") -> np.ndarray:
        collide = (A.fingerprints == B.fingerprints) & (A.fingerprints >= 0)
        va, vb = A.values, B.values
        q = np.minimum(va * va, vb * vb)
        q = np.where(collide & (q > 0), q, 1.0)
        j_hat = np.mean(collide, axis=1)
        m_tilde = 2.0 / (1.0 + j_hat)               # M = 2/(1+J) for unit norms
        s = np.sum(np.where(collide, va * vb / q, 0.0), axis=1)
        out = A.norm * B.norm * (m_tilde / collide.shape[1]) * s
        return np.where((A.norm == 0) | (B.norm == 0), 0.0, out)


@dataclasses.dataclass
class StackedICWS:
    fingerprints: np.ndarray
    values: np.ndarray
    norm: np.ndarray


def _stack(sketches: List[ICWSSketch]) -> StackedICWS:
    return StackedICWS(
        fingerprints=np.stack([s.fingerprints for s in sketches]),
        values=np.stack([s.values for s in sketches]),
        norm=np.array([s.norm for s in sketches], dtype=np.float64))


stack_icws = _stack
