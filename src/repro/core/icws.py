"""ICWS (Ioffe Consistent Weighted Sampling) — the TPU-native WMH variant.

The paper's WMH family includes Consistent Weighted Sampling and its
descendants, "essentially equivalent, but computationally cheaper to apply"
(Section 2, citing Ioffe 2010).  ICWS achieves the exact weighted-Jaccard
collision law

    P[sample_a == sample_b] = sum_i min(wa_i, wb_i) / sum_i max(wa_i, wb_i)

with O(1) *pure f32 elementwise* work per (non-zero x hash): log/exp/floor and
an argmin -- ideal VPU shape, no big-integer arithmetic, and it removes the
discretization parameter L (and the n^6/eps^2 rounding analysis) entirely.

This module is the host (numpy) reference; the Pallas kernel in
``repro.kernels.icws_sketch`` computes the same quantities on-device.  The
two paths share one pseudo-randomness contract: the uint32 mixing RNG of
``repro.kernels.common``, mirrored on host by :mod:`repro.core.u32`.  A
host-sketched vector and a device-sketched vector therefore carry
*interoperable fingerprints* -- mixed corpora estimate correctly instead of
silently reporting zero collisions.  (Keys are taken mod 2^32, matching the
kernel's int32 key lanes.)

Per (index i, sample t), keyed pseudo-randomness:
    r ~ Gamma(2,1)   (= -log(u1*u2)),   c ~ Gamma(2,1),   beta ~ U[0,1]
    t_i  = floor(log(w_i) / r + beta)
    y_i  = exp(r * (t_i - beta))
    a_i  = c / (y_i * exp(r))
Sample = argmin_i a_i; two sketches collide at sample t iff the argmin *index*
and its *level* t_i agree.  We store a 31-bit fingerprint of (index, level)
(non-negative int32; -1 is the empty sentinel, exactly as the kernel emits),
plus the signed normalized value at the argmin and ||a||.

Estimator (Algorithm 5 adapted): with unit-norm weights w = (a/||a||)^2 we
have  sum_i min + sum_i max = ||a~||^2 + ||b~||^2 = 2,  so the weighted union
size is  M = 2 / (1 + J)  with J the weighted Jaccard -- estimated by the
collision rate J^ = mean(collide) with the same O(1/sqrt(m)) concentration as
the paper's Lemma 1.  The rest of Algorithm 5 is unchanged.
"""
from __future__ import annotations

import dataclasses
from typing import List

import numpy as np

from . import u32
from .types import SparseVec

_BIG = np.float32(3.0e38)  # empty-lane sentinel, matches kernels.ref.BIG


@dataclasses.dataclass
class ICWSSketch:
    fingerprints: np.ndarray  # int32 [m]: 31-bit fp of (argmin index, level); -1 empty
    values: np.ndarray        # float64 [m]: normalized signed value at argmin
    norm: float
    # int32 [m] winning key (index mod 2^32) per sample; 0 for empty samples.
    # Sidecar for union-merge: levels must be recomputed under the merged
    # norm, which needs the raw key, not the hashed (key, level) fingerprint.
    argkeys: np.ndarray = None

    def storage_doubles(self) -> float:
        return 1.5 * self.fingerprints.shape[0] + 1.0


class ICWS:
    name = "icws"

    def __init__(self, m: int, seed: int = 0):
        self.m = int(m)
        self.seed = int(seed)

    def _variates(self, keys_u32: np.ndarray):
        """Per-(sample t, key) variates, bit-compatible with the kernel RNG."""
        t = np.arange(self.m, dtype=np.int64)

        def u(stream: int) -> np.ndarray:
            salt = u32.salt_for(self.seed, stream, t)[:, None]   # [m, 1]
            return u32.uniform01(keys_u32[None, :], salt)        # [m, nnz] f32

        r = -np.log(u(u32.ICWS_R1_STREAM) * u(u32.ICWS_R2_STREAM))  # Gamma(2,1)
        c = -np.log(u(u32.ICWS_C1_STREAM) * u(u32.ICWS_C2_STREAM))  # Gamma(2,1)
        beta = u(u32.ICWS_BETA_STREAM)
        return r, c, beta

    def sketch(self, v: SparseVec) -> ICWSSketch:
        norm = v.norm()
        if v.nnz == 0 or norm == 0.0:
            return ICWSSketch(fingerprints=np.full(self.m, -1, np.int32),
                              values=np.zeros(self.m), norm=0.0,
                              argkeys=np.zeros(self.m, np.int32))
        keys_u32 = (v.indices.astype(np.int64)
                    & np.int64(0xFFFFFFFF)).astype(np.uint32)
        z = v.values / norm
        z32 = z.astype(np.float32)
        w = z32 * z32                               # f32 weights, sum ~ 1
        r, c, beta = self._variates(keys_u32)       # [m, nnz] f32
        logw = np.log(np.maximum(w, np.float32(1e-37)))[None, :]
        lvl = np.floor(logw / r + beta)             # t_i
        y = np.exp(r * (lvl - beta))
        a = c / (y * np.exp(r))
        # f32 squaring can underflow a tiny-but-nonzero entry to w == 0; the
        # kernel masks those lanes as padding, so the host must too.
        a = np.where((w > 0)[None, :], a, _BIG)
        arg = np.argmin(a, axis=1)                  # [m]
        rows = np.arange(self.m)
        lvl_sel = lvl[rows, arg].astype(np.int32)
        fpbits = u32.hash_u32(
            keys_u32[arg] ^ (lvl_sel.astype(np.uint32) * np.uint32(0x9E3779B9)),
            u32.salt_for(self.seed, u32.ICWS_FP_STREAM, rows))
        fp = (fpbits & np.uint32(0x7FFFFFFF)).astype(np.int32)
        return ICWSSketch(fingerprints=fp, values=z[arg], norm=norm,
                          argkeys=keys_u32[arg].view(np.int32))

    def sketch_dense(self, a: np.ndarray) -> ICWSSketch:
        return self.sketch(SparseVec.from_dense(a))

    def merge(self, sa: ICWSSketch, sb: ICWSSketch) -> ICWSSketch:
        """Union-merge oracle: sketch of ``a + b`` from the two sketches.

        Requires disjoint supports (the shard-and-merge partitioning
        contract) and the ``argkeys`` sidecar.  Per sample, the two
        per-shard winners are re-scored under the merged normalization
        ``norm_c = sqrt(||a||^2 + ||b||^2)``: variates (r, c, beta) are
        redrawn from (sample, key) -- bit-identical streams on both sides
        of the merge -- levels re-derived from the rescaled weights, and
        the smaller ICWS hash value wins (ties broken toward the smaller
        key, making the merge commutative).  The result is *approximate*
        relative to sketching the union from scratch: a shard's argmin
        under its local normalization is usually, not always, the union
        argmin restricted to that shard.  Collision-law error stays at the
        O(1/sqrt(m)) sketch noise scale; see the merge-algebra tests.
        """
        if sa.norm == 0.0:
            return dataclasses.replace(sb)
        if sb.norm == 0.0:
            return dataclasses.replace(sa)
        if sa.argkeys is None or sb.argkeys is None:
            raise ValueError("ICWS merge needs argkeys sidecars "
                             "(pre-argkeys sketches cannot be merged)")
        norm_c = float(np.sqrt(sa.norm ** 2 + sb.norm ** 2))
        t = np.arange(self.m, dtype=np.int64)

        def rescore(s: ICWSSketch):
            keys = np.asarray(s.argkeys).view(np.uint32)
            z = np.asarray(s.values, np.float64) * (s.norm / norm_c)
            z32 = z.astype(np.float32)
            w = z32 * z32

            def u(stream: int) -> np.ndarray:
                return u32.uniform01(keys, u32.salt_for(self.seed, stream, t))

            r = -np.log(u(u32.ICWS_R1_STREAM) * u(u32.ICWS_R2_STREAM))
            c = -np.log(u(u32.ICWS_C1_STREAM) * u(u32.ICWS_C2_STREAM))
            beta = u(u32.ICWS_BETA_STREAM)
            logw = np.log(np.maximum(w, np.float32(1e-37)))
            lvl = np.floor(logw / r + beta)
            y = np.exp(r * (lvl - beta))
            a = c / (y * np.exp(r))
            a = np.where((s.fingerprints < 0) | (w <= 0), _BIG, a)
            return keys, z, a.astype(np.float32), lvl.astype(np.int32)

        ka, za, aa, la = rescore(sa)
        kb, zb, ab, lb = rescore(sb)
        pick_b = (ab < aa) | ((ab == aa) & (kb < ka))
        key_c = np.where(pick_b, kb, ka)
        lvl_c = np.where(pick_b, lb, la)
        val_c = np.where(pick_b, zb, za)
        fpbits = u32.hash_u32(
            key_c ^ (lvl_c.astype(np.uint32) * np.uint32(0x9E3779B9)),
            u32.salt_for(self.seed, u32.ICWS_FP_STREAM, t))
        fp = (fpbits & np.uint32(0x7FFFFFFF)).astype(np.int32)
        dead = np.minimum(aa, ab) >= _BIG
        return ICWSSketch(
            fingerprints=np.where(dead, -1, fp).astype(np.int32),
            values=np.where(dead, 0.0, val_c),
            norm=norm_c,
            argkeys=np.where(dead, 0, key_c.view(np.int32)).astype(np.int32))

    def estimate(self, sa: ICWSSketch, sb: ICWSSketch) -> float:
        return float(self.estimate_batch(_stack([sa]), _stack([sb]))[0])

    def estimate_batch(self, A: "StackedICWS", B: "StackedICWS") -> np.ndarray:
        collide = (A.fingerprints == B.fingerprints) & (A.fingerprints >= 0)
        va, vb = A.values, B.values
        q = np.minimum(va * va, vb * vb)
        q = np.where(collide & (q > 0), q, 1.0)
        j_hat = np.mean(collide, axis=1)
        m_tilde = 2.0 / (1.0 + j_hat)               # M = 2/(1+J) for unit norms
        s = np.sum(np.where(collide, va * vb / q, 0.0), axis=1)
        out = A.norm * B.norm * (m_tilde / collide.shape[1]) * s
        return np.where((A.norm == 0) | (B.norm == 0), 0.0, out)


@dataclasses.dataclass
class StackedICWS:
    fingerprints: np.ndarray
    values: np.ndarray
    norm: np.ndarray


def _stack(sketches: List[ICWSSketch]) -> StackedICWS:
    return StackedICWS(
        fingerprints=np.stack([s.fingerprints for s in sketches]),
        values=np.stack([s.values for s in sketches]),
        norm=np.array([s.norm for s in sketches], dtype=np.float64))


stack_icws = _stack
