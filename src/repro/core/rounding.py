"""Algorithm 4: vector rounding for Weighted MinHash.

Given a unit vector ``z``, produce ``z~`` with every squared entry an *exact*
integer multiple of ``1/L``, still exactly unit norm: round every squared
entry down, then add the (non-negative) deficit to the largest-magnitude
entry.  The paper's footnote 3 explains why this round-down/round-up-max
scheme yields *relative* error instead of additive 1/L error.

We work in exact integer arithmetic on the repetition counts
``k_i = floor(z_i^2 * L)`` -- the counts are what Algorithm 3 actually uses
(block ``i`` of the extended vector has ``k_i`` active slots), and integer
bookkeeping guarantees ``sum(k) == L`` exactly.
"""
from __future__ import annotations

import numpy as np


def round_counts(z: np.ndarray, L: int) -> np.ndarray:
    """Repetition counts k[i] = L * z~[i]^2 of Algorithm 4, as exact int64.

    ``z`` must be (numerically) unit norm.  Guarantees sum(k) == L and
    k[i] >= 0, with the deficit added at argmax |z| (line 2-3 of Algorithm 4).
    """
    z = np.asarray(z, dtype=np.float64)
    L = int(L)
    sq = z * z
    k = np.floor(sq * L).astype(np.int64)
    deficit = L - int(k.sum())
    if deficit < 0:
        # Only possible via float round-off in the unit normalization; shave
        # the excess off the largest count (keeps every k_i >= 0).
        i = int(np.argmax(k))
        k[i] += deficit
        if k[i] < 0:  # pragma: no cover - requires pathological inputs
            raise ValueError("rounding deficit exceeded the largest count")
        return k
    i_star = int(np.argmax(np.abs(z)))
    k[i_star] += deficit
    return k


def rounded_values(z: np.ndarray, k: np.ndarray, L: int) -> np.ndarray:
    """z~[i] = sign(z[i]) * sqrt(k[i] / L): the exactly-unit rounded vector."""
    z = np.asarray(z, dtype=np.float64)
    return np.sign(z) * np.sqrt(k.astype(np.float64) / float(L))


def round_unit(z: np.ndarray, L: int) -> np.ndarray:
    """Full Algorithm 4: unit vector in, rounded unit vector out."""
    k = round_counts(z, L)
    return rounded_values(z, k, L)
