"""Core inner-product sketching library (the paper's contribution).

Paper-faithful path: :class:`WeightedMinHash` (Algorithms 3-5) with exact
extended-domain semantics via progression minima.  Baselines: :class:`MinHash`
(Algorithms 1-2), :class:`KMV`, :class:`JL`, :class:`CountSketch`.  TPU fast
path: :class:`ICWS` (+ Pallas kernel in :mod:`repro.kernels`).
"""
from .types import (SparseVec, fact1_bound, inner, inner_fast,
                    intersection_norms, theorem2_bound)
from .hashing import MERSENNE_P, AffineHashFamily, PairHashFamily
from .rounding import round_counts, round_unit, rounded_values
from .progmin import progression_min, progression_min_bruteforce
from .wmh import (DEFAULT_L, WeightedMinHash, WMHSketch, compensated_sum,
                  sketch_bruteforce, stack_wmh)
from .minhash import MinHash, MHSketch, stack_mh
from .kmv import KMV, KMVSketch
from .linear import (CountSketch, CountSketchU32, CSSketch, JL, JLSketch,
                     JLU32)
from .sampling import (PrioritySamplingU32, SampleSketch,
                       ThresholdSamplingU32)
from .icws import ICWS, ICWSSketch, stack_icws
from .registry import FACTORIES, PAPER_METHODS, make

__all__ = [
    "SparseVec", "inner", "inner_fast", "intersection_norms",
    "theorem2_bound", "fact1_bound",
    "MERSENNE_P", "AffineHashFamily", "PairHashFamily",
    "round_counts", "round_unit", "rounded_values",
    "progression_min", "progression_min_bruteforce",
    "DEFAULT_L", "WeightedMinHash", "WMHSketch", "compensated_sum",
    "sketch_bruteforce",
    "stack_wmh", "MinHash", "MHSketch", "stack_mh", "KMV", "KMVSketch",
    "CountSketch", "CountSketchU32", "CSSketch", "JL", "JLSketch", "JLU32",
    "ThresholdSamplingU32", "PrioritySamplingU32", "SampleSketch",
    "ICWS", "ICWSSketch",
    "stack_icws", "FACTORIES", "PAPER_METHODS", "make",
]
