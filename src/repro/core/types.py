"""Common types for the sketching core.

The sketching core operates on *sparse vectors*: (indices, values) pairs over a
conceptually huge domain ``n`` (the paper notes ``n`` may be 2^32 or 2^64 -- only
non-zeros are ever touched).  The host-side reference implementations use numpy
(float64/int64) for numerical fidelity to the paper; the device path (ICWS +
linear sketches) lives in :mod:`repro.core.icws`, :mod:`repro.core.linear` and
:mod:`repro.kernels`.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class SparseVec:
    """A sparse real vector: ``v[indices[k]] = values[k]``, dimension ``n``.

    Indices must be unique and values non-zero (zeros are dropped by the
    constructors below, so downstream code can rely on ``nnz == len(indices)``).
    """

    indices: np.ndarray  # int64 [nnz], unique
    values: np.ndarray   # float64 [nnz], non-zero
    n: int               # ambient dimension (only used for densify/checks)

    @property
    def nnz(self) -> int:
        return int(self.indices.shape[0])

    def norm(self) -> float:
        return float(np.sqrt(np.sum(self.values ** 2)))

    def densify(self) -> np.ndarray:
        out = np.zeros(self.n, dtype=np.float64)
        out[self.indices] = self.values
        return out

    @staticmethod
    def from_dense(a: np.ndarray) -> "SparseVec":
        a = np.asarray(a, dtype=np.float64)
        idx = np.nonzero(a)[0].astype(np.int64)
        return SparseVec(indices=idx, values=a[idx], n=int(a.shape[0]))

    @staticmethod
    def from_pairs(indices, values, n: int,
                   sum_duplicates: bool = False) -> "SparseVec":
        idx = np.asarray(indices, dtype=np.int64)
        val = np.asarray(values, dtype=np.float64)
        if sum_duplicates and idx.size:
            uniq, inverse = np.unique(idx, return_inverse=True)
            acc = np.zeros(uniq.size, np.float64)
            np.add.at(acc, inverse, val)
            idx, val = uniq, acc
        keep = val != 0.0
        idx, val = idx[keep], val[keep]
        order = np.argsort(idx, kind="stable")
        idx, val = idx[order], val[order]
        if idx.size and np.any(idx[1:] == idx[:-1]):
            raise ValueError("duplicate indices in SparseVec")
        return SparseVec(indices=idx, values=val, n=n)


def inner(a: SparseVec, b: SparseVec) -> float:
    """Exact inner product of two sparse vectors (test/benchmark ground truth)."""
    ia = {int(i): float(v) for i, v in zip(a.indices, a.values)}
    acc = 0.0
    for i, v in zip(b.indices, b.values):
        acc += ia.get(int(i), 0.0) * float(v)
    return acc


def inner_fast(a: SparseVec, b: SparseVec) -> float:
    """Vectorized exact inner product via sorted-index intersection."""
    common, ia, ib = np.intersect1d(a.indices, b.indices, return_indices=True)
    if common.size == 0:
        return 0.0
    return float(np.sum(a.values[ia] * b.values[ib]))


def intersection_norms(a: SparseVec, b: SparseVec):
    """Return (|I|, ||a_I||, ||b_I||) with I = supp(a) & supp(b) (Theorem 2 terms)."""
    common, ia, ib = np.intersect1d(a.indices, b.indices, return_indices=True)
    a_i = float(np.sqrt(np.sum(a.values[ia] ** 2)))
    b_i = float(np.sqrt(np.sum(b.values[ib] ** 2)))
    return int(common.size), a_i, b_i


def theorem2_bound(a: SparseVec, b: SparseVec, eps: float = 1.0) -> float:
    """The RHS of Theorem 2: eps * max(||a_I|| ||b||, ||a|| ||b_I||)."""
    _, a_i, b_i = intersection_norms(a, b)
    return eps * max(a_i * b.norm(), a.norm() * b_i)


def fact1_bound(a: SparseVec, b: SparseVec, eps: float = 1.0) -> float:
    """The RHS of Fact 1 (linear sketching): eps * ||a|| ||b||."""
    return eps * a.norm() * b.norm()
