"""Sampling sketches for inner products: Threshold and Priority Sampling.

The strongest known competitor to weighted MinWise hashing on sparse data
(Daliri, Freire, Musco, Santos -- *Sampling Methods for Inner Product
Sketching*, arXiv:2309.16157): instead of hashing colliding samples, keep an
explicit weighted sample of the vector's coordinates and reweight matches by
inverse inclusion probability.  Both schemes share one *coordinated* uniform
hash ``h(key) in (0, 1)`` (u32 stream ``SAMPLE_HASH_STREAM``, the same
mixing RNG as the Pallas kernels -- :mod:`repro.core.u32` twins
:mod:`repro.kernels.common`), so two independently built sketches sample the
same coordinates consistently and the intersection of their key sets is a
valid importance sample of the joint support:

  * **Threshold Sampling** keeps every coordinate with
    ``h(i) < p_i = min(1, target * v_i^2 / ||v||^2)`` -- expected sample
    size ``<= target``, exactly unbiased estimates.
  * **Priority Sampling** ranks coordinates by ``R_i = h(i) / v_i^2`` and
    keeps the ``slots`` smallest -- a *fixed*-size sample, with the
    (slots+1)-st rank acting as the data-dependent threshold.

Both serialize to the same fixed-slot device layout (the contract of
:mod:`repro.kernels.sample_estimate`):

    ``(key [slots] i32, val [slots] f32, tau [] f32)``

with inclusion probabilities reconstructed as ``p = min(1, slots * v^2 /
tau)`` (``tau <= 0`` means "kept with probability 1").  The stored ``tau``
absorbs each scheme's parameters -- TS stores ``||v||^2 * slots / target``,
PS stores ``slots / R_(slots+1)`` -- so the estimate engine never needs to
know which scheme built a row, and TS and PS corpora are served by one
kernel.  Keys live in the 31-bit non-negative domain (raw indices folded by
``& 0x7FFFFFFF``, duplicates aggregated), exactly as ICWS fingerprints keep
31 bits, so the kernels' negative pad sentinels never collide with a live
key.

The estimator, for sketches of ``a`` and ``b`` with shared hash:

    ``est = sum_{i in S_a ^ S_b} a_i * b_i / min(1, p_a(i), p_b(i))``

which is unbiased because ``i`` lands in *both* samples iff
``h(i) < min(p_a(i), p_b(i))``.

Fixed-slot footnote: threshold samples have random size (mean <= target,
std ~ sqrt(target)), so :func:`ts_target` backs the target off the slot
count by two standard deviations; in the rare overflow the builder keeps
the ``slots`` smallest ``h/p`` ranks (the entries whose inclusion was most
forced), a truncation whose bias is O(overflow probability * dropped
fraction) -- far below the estimator's sampling noise.  Priority samples
never overflow by construction.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

from . import u32
from .types import SparseVec

# u32 salt stream for the coordinated sample hash h(key); host twin of the
# identically named constant in repro.kernels.common (kept in sync the same
# way the CS/JL streams are -- this package stays numpy-only).
SAMPLE_HASH_STREAM = 41

# Live keys occupy the 31-bit non-negative domain; the estimate kernel's
# negative pad sentinels (query -1, corpus/spare -2) can never collide.
SAMPLE_KEY_MASK = 0x7FFFFFFF


def ts_target(slots: int) -> int:
    """Default Threshold-Sampling target for a ``slots``-slot layout.

    Sample size concentrates around the target with std <= sqrt(target);
    two standard deviations of slack make overflow (and its truncation
    fallback) a ~2% tail event with only the least-forced entries dropped.
    """
    return max(1, int(slots) - int(np.ceil(2.0 * np.sqrt(max(slots, 1)))))


def _fold_aggregate(indices: np.ndarray, values: np.ndarray
                    ) -> Tuple[np.ndarray, np.ndarray]:
    """Fold raw int64 indices into the 31-bit key domain and aggregate
    duplicates (two indices that fold together ARE the same coordinate to
    every u32-contract sketch).  Returns (sorted unique keys, summed values)
    with exact zeros dropped -- a zero coordinate is absent by definition."""
    k = np.asarray(indices, np.int64) & np.int64(SAMPLE_KEY_MASK)
    v = np.asarray(values, np.float64)
    uniq, inverse = np.unique(k, return_inverse=True)
    agg = np.zeros(uniq.size, np.float64)
    np.add.at(agg, inverse, v)
    live = agg != 0.0
    return uniq[live], agg[live]


def _sample_hash(keys: np.ndarray, seed: int) -> np.ndarray:
    """The coordinated uniform hash h(key) in (0, 1), as float64.

    One draw per key (no per-slot stream): coordination across vectors is
    the whole point -- matched keys were accepted/rejected by the SAME coin.
    """
    # length-1 salt array, not a 0-d scalar: numpy warns on (wrapping)
    # scalar uint32 overflow inside the mixer, but not on array lanes
    salt = u32.salt_for(seed, SAMPLE_HASH_STREAM, np.zeros(1, np.uint32))
    return u32.uniform01(keys.astype(np.uint64).astype(np.uint32),
                         salt).astype(np.float64)


def threshold_sample(indices: np.ndarray, values: np.ndarray, *, slots: int,
                     seed: int, target: "int | None" = None
                     ) -> Tuple[np.ndarray, np.ndarray, float]:
    """Threshold-sample one sparse vector into the fixed-slot contract.

    Returns ``(keys, vals, tau)`` with ``keys`` sorted ascending (at most
    ``slots`` of them) and ``tau`` such that ``p_i = min(1, slots * v_i^2 /
    tau)`` reproduces the builder's inclusion probabilities.  ``target``
    defaults to :func:`ts_target` (two-sigma overflow slack).
    """
    if target is None:
        target = ts_target(slots)
    keys, vals = _fold_aggregate(indices, values)
    if keys.size == 0:
        return keys.astype(np.int64), vals, 0.0
    sq = vals * vals
    norm2 = float(sq.sum())
    p = np.minimum(1.0, float(target) * sq / norm2)
    h = _sample_hash(keys, seed)
    keep = h < p
    if int(keep.sum()) > slots:
        # rare by the target's slack: keep the `slots` most-forced entries
        # (smallest h/p rank); ties broken by the sorted key order
        rank = np.where(keep, h / p, np.inf)
        keep = np.zeros_like(keep)
        keep[np.argsort(rank, kind="stable")[:slots]] = True
    tau = norm2 * float(slots) / float(target)
    return keys[keep], vals[keep], tau


def priority_sample(indices: np.ndarray, values: np.ndarray, *, slots: int,
                    seed: int) -> Tuple[np.ndarray, np.ndarray, float]:
    """Priority-sample one sparse vector into the fixed-slot contract.

    Keeps the ``slots`` smallest ranks ``R_i = h(i) / v_i^2``; ``tau =
    slots / R_(slots+1)`` makes ``p_i = min(1, slots * v_i^2 / tau) =
    min(1, v_i^2 * R_(slots+1))`` the conditional inclusion probability.
    ``tau = 0`` (probability 1) when the whole support fits.
    """
    keys, vals = _fold_aggregate(indices, values)
    if keys.size <= slots:
        return keys, vals, 0.0
    h = _sample_hash(keys, seed)
    rank = h / (vals * vals)
    order = np.argsort(rank, kind="stable")
    tau = float(slots) / float(rank[order[slots]])
    keep = np.sort(order[:slots])        # canonical ascending-key layout
    return keys[keep], vals[keep], tau


def sample_probs(vals: np.ndarray, tau: float, slots: int) -> np.ndarray:
    """Inclusion probabilities from the stored layout (host/f64 form of the
    kernel epilogue): ``min(1, slots * v^2 / tau)``, probability 1 when
    ``tau <= 0``, probability 0 for empty (``v == 0``) slots."""
    v = np.asarray(vals, np.float64)
    if tau > 0:
        p = np.minimum(1.0, float(slots) * v * v / float(tau))
    else:
        p = np.ones_like(v)
    return np.where(v != 0.0, p, 0.0)


@dataclasses.dataclass
class SampleSketch:
    """A weighted coordinate sample: up to ``slots`` (key, value) pairs plus
    the probability scale ``tau`` (see module docstring for the contract)."""

    keys: np.ndarray      # int64 ascending, 31-bit domain
    values: np.ndarray    # float64 raw values
    tau: float            # p = min(1, slots * v^2 / tau); tau <= 0 => 1
    slots: int            # the fixed layout size the probabilities scale to

    def storage_doubles(self) -> float:
        """Fixed-layout accounting: a key (i32) + value (f32) pair per slot
        is one 64-bit double equivalent, plus one double for tau."""
        return float(self.slots) + 1.0


class _SamplingU32:
    """Shared host plumbing of the two sampling sketchers."""

    def __init__(self, slots: int, seed: int = 0):
        if slots < 1:
            raise ValueError("slots must be >= 1")
        self.slots = int(slots)
        self.seed = int(seed)

    def _select(self, indices, values):
        raise NotImplementedError

    def sketch(self, v: SparseVec) -> SampleSketch:
        keys, vals, tau = self._select(v.indices, v.values)
        return SampleSketch(keys=keys, values=vals, tau=tau, slots=self.slots)

    def sketch_dense(self, a: np.ndarray) -> SampleSketch:
        return self.sketch(SparseVec.from_dense(a))

    def estimate(self, sa: SampleSketch, sb: SampleSketch) -> float:
        """Inverse-inclusion-probability estimate of <a, b> from the matched
        keys: ``sum va * vb / min(pa, pb)`` -- the coordinated hash makes
        ``min(pa, pb)`` the exact probability a key lands in both samples."""
        common, ia, ib = np.intersect1d(sa.keys, sb.keys, return_indices=True)
        if common.size == 0:
            return 0.0
        va, vb = sa.values[ia], sb.values[ib]
        pa = sample_probs(va, sa.tau, self.slots)
        pb = sample_probs(vb, sb.tau, self.slots)
        p = np.minimum(pa, pb)
        return float(np.sum(va * vb / np.where(p > 0, p, 1.0) * (p > 0)))

    def _merge_candidates(self, sa: SampleSketch, sb: SampleSketch):
        """Validate a union-merge and return the pooled candidate slots."""
        for s in (sa, sb):
            if s.slots != self.slots:
                raise ValueError(f"slot mismatch: sketch has {s.slots}, "
                                 f"sketcher has {self.slots}")
        if np.intersect1d(sa.keys, sb.keys).size:
            raise ValueError("union-merge requires disjoint supports "
                             "(shared keys found in both samples)")
        keys = np.concatenate([sa.keys, sb.keys])
        vals = np.concatenate([sa.values, sb.values])
        return keys, vals

    @staticmethod
    def _packed(keys, vals, keep, tau, slots) -> SampleSketch:
        order = np.argsort(keys[keep], kind="stable")
        return SampleSketch(keys=keys[keep][order], values=vals[keep][order],
                            tau=float(tau), slots=slots)


class ThresholdSamplingU32(_SamplingU32):
    """Threshold Sampling host oracle (u32 kernel hash contract).

    Variable-size-in-expectation sampling bounded to the fixed ``slots``
    layout via the two-sigma target slack (see :func:`ts_target`); pass
    ``target`` to override.
    """

    name = "ts"

    def __init__(self, slots: int, seed: int = 0,
                 target: "int | None" = None):
        super().__init__(slots, seed)
        self.target = ts_target(self.slots) if target is None else int(target)

    def _select(self, indices, values):
        return threshold_sample(indices, values, slots=self.slots,
                                seed=self.seed, target=self.target)

    def merge(self, sa: SampleSketch, sb: SampleSketch) -> SampleSketch:
        """Union-merge oracle: re-subsample the pooled slots under the merged
        threshold.  ``tau`` is ``||v||^2 * slots / target``, so for disjoint
        supports ``tau_c = tau_a + tau_b`` IS the union's tau; inclusion
        probabilities only shrink (``p_c <= p_a``), so filtering the pooled
        kept slots by the same coordinated coin ``h(key) < p_c`` reproduces
        the build-once sample exactly (modulo the rare per-shard overflow
        truncation, which drops low-force entries a build-once pass may
        keep)."""
        keys, vals = self._merge_candidates(sa, sb)
        tau = float(sa.tau) + float(sb.tau)
        if keys.size == 0:
            return SampleSketch(keys=keys, values=vals, tau=tau,
                                slots=self.slots)
        p = sample_probs(vals, tau, self.slots)
        h = _sample_hash(keys, self.seed)
        keep = h < p
        if int(keep.sum()) > self.slots:
            rank = np.where(keep, h / p, np.inf)
            keep = np.zeros_like(keep)
            keep[np.argsort(rank, kind="stable")[:self.slots]] = True
        return self._packed(keys, vals, keep, tau, self.slots)


class PrioritySamplingU32(_SamplingU32):
    """Priority Sampling host oracle (u32 kernel hash contract): exactly
    ``min(nnz, slots)`` samples, threshold rank folded into ``tau``."""

    name = "ps"

    def _select(self, indices, values):
        return priority_sample(indices, values, slots=self.slots,
                               seed=self.seed)

    def merge(self, sa: SampleSketch, sb: SampleSketch) -> SampleSketch:
        """Union-merge oracle: *exactly* the build-once priority sample.

        Each side's threshold rank is recovered as ``T = slots / tau``
        (infinite for ``tau <= 0``); the union threshold is ``T_c =
        min(T_a, T_b, T_cand)`` with ``T_cand`` the (slots+1)-th smallest
        rank among the pooled kept slots.  Every union coordinate with rank
        below ``T_c`` is in the pool (a side only discarded ranks >= its own
        T >= T_c), so keeping pooled ranks < T_c and storing ``tau_c =
        slots / T_c`` reproduces priority-sampling the union from scratch,
        coordinate for coordinate."""
        keys, vals = self._merge_candidates(sa, sb)
        t_a = np.inf if sa.tau <= 0 else float(self.slots) / float(sa.tau)
        t_b = np.inf if sb.tau <= 0 else float(self.slots) / float(sb.tau)
        if keys.size == 0:
            return SampleSketch(keys=keys, values=vals, tau=0.0,
                                slots=self.slots)
        rank = _sample_hash(keys, self.seed) / (vals * vals)
        t_cand = (np.sort(rank)[self.slots] if keys.size > self.slots
                  else np.inf)
        t_c = min(t_a, t_b, t_cand)
        if np.isinf(t_c):
            keep = np.ones(keys.size, bool)
            tau = 0.0
        else:
            keep = rank < t_c
            tau = float(self.slots) / t_c
        return self._packed(keys, vals, keep, tau, self.slots)
