"""k-Minimum-Values (KMV) sampling sketch [Beyer et al. 2007; Santos et al. 2021].

Samples the support *without replacement*: a single hash function, keep the k
smallest (hash, index, value) triples.  Union size from the k-th smallest hash
of the merged sketch; inner product from the matched samples.  This is the
paper's "KMV" baseline.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .hashing import MERSENNE_P, AffineHashFamily
from .types import SparseVec


@dataclasses.dataclass
class KMVSketch:
    hashes: np.ndarray   # int64 [<=k], sorted ascending
    values: np.ndarray   # float64 [<=k], vector values aligned with hashes
    k: int
    seed: int

    def storage_doubles(self) -> float:
        return 1.5 * self.k  # 32-bit hash + 64-bit value per kept sample


class KMV:
    name = "kmv"

    def __init__(self, k: int, seed: int = 0):
        self.k = int(k)
        self.seed = int(seed)
        self._hash = AffineHashFamily.create(1, self.seed ^ 0x7F4A7C15)

    def sketch(self, v: SparseVec) -> KMVSketch:
        if v.nnz == 0:
            return KMVSketch(hashes=np.zeros(0, np.int64),
                             values=np.zeros(0), k=self.k, seed=self.seed)
        h = self._hash.hash_ints(v.indices)[0]          # [nnz]
        order = np.argsort(h, kind="stable")[: self.k]
        return KMVSketch(hashes=h[order], values=v.values[order],
                         k=self.k, seed=self.seed)

    def sketch_dense(self, a: np.ndarray) -> KMVSketch:
        return self.sketch(SparseVec.from_dense(a))

    def merge_union(self, sa: KMVSketch, sb: KMVSketch) -> KMVSketch:
        """Exact KMV sketch of the union of two disjoint-support vectors:
        keep the k smallest hashes of the combined samples (sharded
        ingestion; exact, order-independent)."""
        h = np.concatenate([sa.hashes, sb.hashes])
        v = np.concatenate([sa.values, sb.values])
        order = np.argsort(h, kind="stable")[: self.k]
        return KMVSketch(hashes=h[order], values=v[order], k=self.k,
                         seed=self.seed)

    def estimate(self, sa: KMVSketch, sb: KMVSketch) -> float:
        if sa.hashes.size == 0 or sb.hashes.size == 0:
            return 0.0
        # k smallest distinct hashes of the union of the two samples.
        union_h = np.union1d(sa.hashes, sb.hashes)      # sorted unique
        kk = min(self.k, union_h.size)
        x = union_h[:kk]
        tau = float(x[-1]) / float(MERSENNE_P)          # k-th smallest, in (0,1)
        if tau <= 0.0:
            return 0.0
        u_hat = (kk - 1) / tau if kk > 1 else 1.0 / tau  # union-size estimator
        # Matched samples: hashes present in BOTH sketches and within the k
        # smallest of the union (a hash among the k smallest of the union is
        # automatically among the k smallest of each containing sketch).
        common, ia, ib = np.intersect1d(sa.hashes, sb.hashes, return_indices=True)
        keep = common <= x[-1]
        prod = np.sum(sa.values[ia[keep]] * sb.values[ib[keep]])
        return float(u_hat / kk * prod)

    def estimate_pairs(self, As, Bs) -> np.ndarray:
        return np.array([self.estimate(a, b) for a, b in zip(As, Bs)])
