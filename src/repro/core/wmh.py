"""Weighted MinHash inner-product sketching (Algorithms 3-5) — paper-faithful.

Sketch (Algorithm 3): normalize to unit norm, round squared entries to exact
multiples of 1/L (Algorithm 4 via :mod:`repro.core.rounding`), conceptually
expand entry i into ``k_i = L * z~_i^2`` active slots in block i of a length
``n*L`` domain, and take m independent MinHashes over the active slots.

The expansion is never materialized: per (hash t, block i) the slot hashes
form an arithmetic progression mod p (see :mod:`repro.core.hashing`), whose
minimum :func:`repro.core.progmin.progression_min` computes exactly in
O(log p).  Total sketch cost is O(nnz * m * log p) -- matching the paper's
"active index" complexity, but branch-free and vectorized.

Estimate (Algorithm 5): collision-indicator importance sum with weights
``1/q_i``, scaled by the Flajolet-Martin-style weighted-union-size estimate
``M~`` and by ``||a|| * ||b||``.

Sketch contents exactly follow the paper's storage accounting: m hash values
(31-bit ints), m sampled values (doubles), one norm (double) => 1.5*m + 1
"double equivalents".
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

from .hashing import MERSENNE_P, PairHashFamily
from .progmin import progression_min
from .rounding import round_counts
from .types import SparseVec

DEFAULT_L = 10 ** 7  # the paper fixes L = 1e7 in all experiments (Section 5)


def compensated_sum(x: np.ndarray, axis: int = -1) -> np.ndarray:
    """Kahan-Neumaier compensated summation along ``axis`` (float64).

    The Algorithm-5 denominator ``sum_t min(ha_t, hb_t)`` is a sum of m
    same-sign terms of size ~1/m that *scales the whole estimate*; with
    L = 1e7 the union-size factor amplifies any rounding drift by ~L, so the
    denominator is accumulated with a running compensation term instead of a
    plain ``np.sum``.
    """
    x = np.moveaxis(np.asarray(x, np.float64), axis, 0)
    total = np.zeros(x.shape[1:], np.float64)
    comp = np.zeros_like(total)
    for row in x:
        t = total + row
        comp = comp + np.where(np.abs(total) >= np.abs(row),
                               (total - t) + row, (row - t) + total)
        total = t
    return total + comp


@dataclasses.dataclass
class WMHSketch:
    hash_mins: np.ndarray  # int64 [m], in [0, p); p is the empty-input sentinel
    values: np.ndarray     # float64 [m], rounded *normalized* values at argmin
    norm: float            # ||a||
    m: int
    L: int
    seed: int

    def storage_doubles(self) -> float:
        """Paper's accounting: 32-bit hash + 64-bit value per sample + norm."""
        return 1.5 * self.m + 1.0


class WeightedMinHash:
    """Coordinated sketcher: every vector sketched with the same (m, seed, L)
    uses the same hash functions, as Algorithms 3/5 require."""

    name = "wmh"

    def __init__(self, m: int, seed: int = 0, L: int = DEFAULT_L):
        if m < 1:
            raise ValueError("m must be >= 1")
        self.m = int(m)
        self.L = int(L)
        self.seed = int(seed)
        self._hash = PairHashFamily.create(self.m, self.seed)

    # -- sketching ----------------------------------------------------------
    def sketch(self, v: SparseVec) -> WMHSketch:
        norm = v.norm()
        if v.nnz == 0 or norm == 0.0:
            return WMHSketch(
                hash_mins=np.full(self.m, MERSENNE_P, dtype=np.int64),
                values=np.zeros(self.m, dtype=np.float64),
                norm=0.0, m=self.m, L=self.L, seed=self.seed)
        z = v.values / norm
        k = round_counts(z, self.L)                    # int64 [nnz], sum == L
        keep = k > 0
        blocks = v.indices[keep]                       # extended-domain blocks
        counts = k[keep]
        vals = np.sign(z[keep]) * np.sqrt(counts.astype(np.float64) / self.L)

        starts = self._hash.block_starts(blocks)       # [m, nnz]
        steps = (self._hash.b[:, None] % MERSENNE_P) * np.ones_like(starts)
        n_rep = counts[None, :] * np.ones_like(starts)
        block_mins = progression_min(steps, starts, MERSENNE_P, n_rep)  # [m,nnz]

        arg = np.argmin(block_mins, axis=1)            # [m]
        hash_mins = block_mins[np.arange(self.m), arg]
        values = vals[arg]
        return WMHSketch(hash_mins=hash_mins, values=values, norm=norm,
                         m=self.m, L=self.L, seed=self.seed)

    def sketch_dense(self, a: np.ndarray) -> WMHSketch:
        return self.sketch(SparseVec.from_dense(a))

    # -- estimation (Algorithm 5) --------------------------------------------
    def estimate(self, sa: WMHSketch, sb: WMHSketch) -> float:
        return float(self.estimate_batch(_stack([sa]), _stack([sb]))[0])

    def estimate_batch(self, A: "StackedWMH", B: "StackedWMH") -> np.ndarray:
        """Vectorized Algorithm 5 over P sketch pairs."""
        p = float(MERSENNE_P)
        ha = A.hash_mins.astype(np.float64) / p        # [P, m] in [0, 1]
        hb = B.hash_mins.astype(np.float64) / p
        collide = A.hash_mins == B.hash_mins           # [P, m] exact int equality
        va, vb = A.values, B.values
        q = np.minimum(va * va, vb * vb)               # line 1
        q = np.where(collide & (q > 0), q, 1.0)        # guarded; masked anyway
        denom = compensated_sum(np.minimum(ha, hb), axis=1)  # line 2 denominator
        denom = np.maximum(denom, 1e-300)
        m_tilde = (self.m / denom - 1.0) / float(self.L)
        summand = np.where(collide, va * vb / q, 0.0)  # line 3
        est_unit = m_tilde / self.m * np.sum(summand, axis=1)
        out = A.norm * B.norm * est_unit               # line 4
        return np.where((A.norm == 0) | (B.norm == 0), 0.0, out)


@dataclasses.dataclass
class StackedWMH:
    hash_mins: np.ndarray  # int64 [P, m]
    values: np.ndarray     # float64 [P, m]
    norm: np.ndarray       # float64 [P]


def _stack(sketches: List[WMHSketch]) -> StackedWMH:
    return StackedWMH(
        hash_mins=np.stack([s.hash_mins for s in sketches]),
        values=np.stack([s.values for s in sketches]),
        norm=np.array([s.norm for s in sketches], dtype=np.float64))


stack_wmh = _stack


# ---------------------------------------------------------------------------
# Brute-force oracle: literally materialize the extended vector and hash all
# nL slots with the same pair hash.  Used by tests for bit-exact validation of
# the progression-min fast path (small n, L only).
# ---------------------------------------------------------------------------
def sketch_bruteforce(sketcher: WeightedMinHash, v: SparseVec) -> WMHSketch:
    norm = v.norm()
    if v.nnz == 0 or norm == 0.0:
        return sketcher.sketch(v)
    z = v.values / norm
    k = round_counts(z, sketcher.L)
    keep = k > 0
    blocks = v.indices[keep]
    counts = k[keep]
    vals = np.sign(z[keep]) * np.sqrt(counts.astype(np.float64) / sketcher.L)

    m = sketcher.m
    best = np.full(m, MERSENNE_P, dtype=np.int64)
    best_val = np.zeros(m, dtype=np.float64)
    for bi, ki, vi in zip(blocks, counts, vals):
        h = sketcher._hash.hash_pairs_bruteforce(int(bi), np.arange(int(ki)))
        hmin = h.min(axis=1)
        upd = hmin < best
        best = np.where(upd, hmin, best)
        best_val = np.where(upd, vi, best_val)
    return WMHSketch(hash_mins=best, values=best_val, norm=norm,
                     m=m, L=sketcher.L, seed=sketcher.seed)
