"""DMH (densified one-permutation weighted MinHash) -- constant-time ingest.

ICWS (:mod:`repro.core.icws`) does O(nnz * m) work per vector: every
non-zero is scored against every one of the m samples.  DMH gets the same
*coordinated* weighted-MinHash samples with O(nnz + m) work, the remedy
PAPERS.md names for lake-scale ingest (Shrivastava, arXiv:1602.08393, with
the optimal densification of arXiv:1703.04664):

  0. **Replicate** (m > 64 only): each key is expanded into
     ``c = clamp(m // 64, 1, 4)`` pseudo-keys ``key ^ r * REPLICA_SALT``
     sharing its weight.  Binning restricts each comparison to the few
     union keys that share a bin, and the restricted weighted-Jaccard
     ratio ``E[sum min / sum max]`` carries an O(1/k) Jensen bias for
     k union keys per bin; replication multiplies k by c, shrinking the
     bias c-fold for O(c * nnz) extra work (see :func:`dmh_replication`).
  1. **Bin**: each (key, weight) is assigned a single bin
     ``t = h(key) mod m`` by one u32 hash draw (``DMH_BIN_STREAM``) -- the
     one-permutation step.
  2. **Rank**: the key is scored by the ICWS variates (r, c, beta) drawn at
     sample index ``t = bin`` (streams ``DMH_R1..DMH_BETA``), so
     *within a bin* the minimum follows the exact weighted-MinHash law of
     Ioffe sampling -- conditioned on the binning, colliding bins collide
     with the restricted weighted-Jaccard probability.
  3. **Densify**: empty bins borrow from occupied ones through a reseeded
     2-universal probe sequence ``src = h(t; j) mod m`` (stream
     ``DMH_DENSIFY_STREAM``, j = 0, 1, ...) -- the *uniform* optimal
     densification, not the biased rotation of the 2014 scheme.  The
     probes are coordinated (they depend only on (seed, t, j) and the
     occupancy pattern), which is what makes borrowed bins collide
     correctly across sketches.

The output is an :class:`repro.core.icws.ICWSSketch` -- same fingerprints /
values / norm / argkeys wire layout -- so the ICWS estimator
(``estimate_batch``), the fused device estimate kernels, packed storage,
and top-k ranking all consume DMH rows unchanged.  This class is the host
(numpy) oracle; the Pallas kernel in :mod:`repro.kernels.dmh_sketch` is
its bit-twin on the shared u32 contract (:mod:`repro.core.u32` /
:mod:`repro.kernels.common`).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from . import u32
from .icws import _BIG, ICWS, ICWSSketch
from .types import SparseVec


def densify_probes(m: int) -> int:
    """Probe budget of the densification pass: enough reseeded attempts
    that the uniform-borrowing fallback (first occupied bin, taken when
    every probe misses) is vanishingly rare for any non-degenerate
    occupancy, rounded to a lane multiple for the kernel.  Mirrored bit for
    bit by ``repro.kernels.common.densify_probes`` -- host and device MUST
    agree or borrowed fingerprints stop colliding."""
    return min(1024, 128 * -(-4 * int(m) // 128))


REPLICA_SALT = 0x85EBCA6B


def dmh_replication(m: int) -> int:
    """Pseudo-key replication factor ``c = clamp(m // 64, 1, 4)``.

    Binning restricts each weighted-Jaccard comparison to the
    ``k ~ |union| / m`` union keys that share a bin, and the per-bin
    collision probability ``E[sum min / sum max]`` over that random
    subset carries an O(1/k) ratio-of-sums (Jensen) bias relative to the
    full J_w -- it is exact only for constant weights.  Replicating every
    key into c pseudo-keys (:func:`replicate_keys`) multiplies k by c at
    O(c * nnz) extra ingest work, and c grows with m precisely because
    the bias does: a larger m spreads the same union over more bins.

    c MUST be a function of m alone (never of the data or nnz) so
    sketches of different vectors stay coordinated.  It is capped at 4
    because pseudo-keys of *different* keys can alias
    (``k1 ^ r1*SALT == k2 ^ r2*SALT``) and a spurious fingerprint match
    carries unbounded ``va*vb / min(va^2, vb^2)`` estimator weight; the
    alias probability per key pair grows ~c^2, and c >= 6 was measured to
    produce exactly such blow-ups on realistic sparse lakes.
    """
    return max(1, min(4, int(m) // 64))


def replica_salts(c: int) -> np.ndarray:
    """u32 XOR salts of a key's c pseudo-keys (``r * REPLICA_SALT``,
    wrapping in u32; r = 0 is the identity, so c = 1 is plain DMH)."""
    return (np.arange(c, dtype=np.uint64)
            * np.uint64(REPLICA_SALT)).astype(np.uint32)


def replicate_keys(keys_u32: np.ndarray, c: int) -> np.ndarray:
    """Expand ``[..., n]`` u32 keys into ``[..., c * n]`` pseudo-keys,
    replica-major on the last axis.  Shared by the host oracle and the
    device ingest pad (``data/ingest.dmh_sketch_batch``) -- the two
    layers MUST expand through this one function or host and device
    fingerprints stop colliding."""
    salts = replica_salts(c)
    out = keys_u32[..., None, :] ^ salts[:, None]
    return out.reshape(*keys_u32.shape[:-1], c * keys_u32.shape[-1])


class DMH(ICWS):
    """Densified one-permutation weighted MinHash host sketcher.

    Subclasses :class:`ICWS`: the estimator, stacking, and storage
    accounting are inherited unchanged (same sketch layout, same collision
    law); only how samples are *produced* differs -- one pass over the
    non-zeros instead of an m-way broadcast.
    """

    name = "dmh"

    # -- shared sub-steps (used by both sketch and merge) -----------------
    def _bins(self, keys_u32: np.ndarray) -> np.ndarray:
        """One u32 draw per key: its bin / sample index in [0, m)."""
        salt = u32.salt_for(self.seed, u32.DMH_BIN_STREAM,
                            np.zeros(1, np.uint32))
        return u32.hash_u32(keys_u32, salt) % np.uint32(self.m)

    def _rank(self, keys_u32: np.ndarray, w: np.ndarray,
              bins: np.ndarray):
        """ICWS hash value and level per key, variates drawn at t = bin."""
        def u(stream: int) -> np.ndarray:
            return u32.uniform01(keys_u32,
                                 u32.salt_for(self.seed, stream, bins))

        r = -np.log(u(u32.DMH_R1_STREAM) * u(u32.DMH_R2_STREAM))
        c = -np.log(u(u32.DMH_C1_STREAM) * u(u32.DMH_C2_STREAM))
        beta = u(u32.DMH_BETA_STREAM)
        logw = np.log(np.maximum(w, np.float32(1e-37)))
        lvl = np.floor(logw / r + beta)
        y = np.exp(r * (lvl - beta))
        a = c / (y * np.exp(r))
        return np.where(w > 0, a, _BIG).astype(np.float32), lvl

    def _fingerprint(self, keys_u32: np.ndarray, lvl: np.ndarray,
                     t: np.ndarray) -> np.ndarray:
        fpbits = u32.hash_u32(
            keys_u32 ^ (lvl.astype(np.int32).astype(np.uint32)
                        * np.uint32(0x9E3779B9)),
            u32.salt_for(self.seed, u32.DMH_FP_STREAM, t))
        return (fpbits & np.uint32(0x7FFFFFFF)).astype(np.int32)

    def _densify_sources(self, occupied: np.ndarray):
        """(empty bin indices, source bin per empty bin).

        Reseeded 2-universal probing: empty bin t borrows from the first
        probe ``h(t; j) mod m`` that lands on an occupied bin.  If every
        probe misses (probability ``(1 - occupancy)^J``), fall back to the
        first occupied bin -- exact when exactly one bin is occupied, and
        coordinated either way (deterministic in (seed, occupancy)).
        """
        occ = np.asarray(occupied, bool)
        t = np.arange(self.m, dtype=np.int64)
        empty = t[~occ]
        J = densify_probes(self.m)
        salts = u32.salt_for(self.seed, u32.DMH_DENSIFY_STREAM,
                             np.arange(J, dtype=np.int64))
        src = (u32.hash_u32(empty[:, None].astype(np.uint32),
                            salts[None, :])
               % np.uint32(self.m)).astype(np.int64)        # [E, J]
        hit = occ[src]
        has = hit.any(axis=1)
        first = np.argmax(hit, axis=1)
        fallback = int(np.argmax(occ))
        picked = np.where(has, src[np.arange(empty.size), first], fallback)
        return empty, picked

    # -- the sketch -------------------------------------------------------
    def sketch(self, v: SparseVec) -> ICWSSketch:
        norm = v.norm()
        if v.nnz == 0 or norm == 0.0:
            return ICWSSketch(fingerprints=np.full(self.m, -1, np.int32),
                              values=np.zeros(self.m), norm=0.0,
                              argkeys=np.zeros(self.m, np.int32))
        keys_u32 = (v.indices.astype(np.int64)
                    & np.int64(0xFFFFFFFF)).astype(np.uint32)
        z = v.values / norm
        c = dmh_replication(self.m)
        if c > 1:
            # debias the restricted-Jaccard collision law by comparing
            # more union keys per bin (see dmh_replication)
            keys_u32 = replicate_keys(keys_u32, c)
            z = np.tile(z, c)
        z32 = z.astype(np.float32)
        w = z32 * z32
        bins = self._bins(keys_u32)
        a, lvl = self._rank(keys_u32, w, bins)
        t = np.arange(self.m, dtype=np.int64)
        # per-bin first-min argmin (np.argmin first-hit ties, matching the
        # kernel's strict-< tile merge)
        a_mat = np.where(bins[None, :] == t[:, None], a[None, :], _BIG)
        arg = np.argmin(a_mat, axis=1)
        amin = a_mat[t, arg].astype(np.float32)
        key_sel = keys_u32[arg]
        val_sel = z[arg]
        fp = self._fingerprint(key_sel, lvl[arg], t)
        occ = amin < _BIG
        if not occ.any():
            # every weight underflowed f32 squaring: empty sketch (norm
            # kept -- the device path reports the true norm too; all-(-1)
            # fingerprints estimate to zero regardless)
            return ICWSSketch(fingerprints=np.full(self.m, -1, np.int32),
                              values=np.zeros(self.m), norm=norm,
                              argkeys=np.zeros(self.m, np.int32))
        if not occ.all():
            empty, src = self._densify_sources(occ)
            fp[empty] = fp[src]
            val_sel[empty] = val_sel[src]
            key_sel[empty] = key_sel[src]
        return ICWSSketch(fingerprints=fp, values=val_sel, norm=norm,
                          argkeys=key_sel.view(np.int32))

    # -- union-merge oracle ----------------------------------------------
    def merge(self, sa: ICWSSketch, sb: ICWSSketch) -> ICWSSketch:
        """Union-merge of two disjoint-support DMH sketches.

        DMH stores no occupancy bitmap, but origins are recoverable from
        the wire layout itself: bin t holds its *own* minimum (not a
        densified copy) iff ``bin(argkey[t]) == t`` -- a borrowed bin
        carries its source bin's winning key, whose bin hash points back
        at the source.  Per origin bin the two shard winners are re-scored
        under the merged norm (same redraw as :meth:`ICWS.merge`, DMH
        streams at t = bin), strict-< picks the winner with ties toward
        the smaller key (commutative), and bins with no origin on either
        side are re-densified from the merged occupancy through the same
        probe sequence.

        Replication is invisible here: stored argkeys *are* pseudo-keys,
        and the bin hash, re-scoring variates, and fingerprints are all
        keyed on them directly -- no expansion or un-expansion needed.
        """
        if sa.norm == 0.0:
            return dataclasses.replace(sb)
        if sb.norm == 0.0:
            return dataclasses.replace(sa)
        if sa.argkeys is None or sb.argkeys is None:
            raise ValueError("DMH merge needs argkeys sidecars "
                             "(pre-argkeys sketches cannot be merged)")
        norm_c = float(np.sqrt(sa.norm ** 2 + sb.norm ** 2))
        t = np.arange(self.m, dtype=np.int64)

        def rescore(s: ICWSSketch):
            keys = np.asarray(s.argkeys).view(np.uint32)
            origin = (np.asarray(s.fingerprints) >= 0) & (self._bins(keys)
                                                          == t)
            z = np.asarray(s.values, np.float64) * (s.norm / norm_c)
            z32 = z.astype(np.float32)
            a, lvl = self._rank(keys, z32 * z32, t)
            a = np.where(origin, a, _BIG).astype(np.float32)
            return keys, z, a, lvl

        ka, za, aa, la = rescore(sa)
        kb, zb, ab, lb = rescore(sb)
        pick_b = (ab < aa) | ((ab == aa) & (kb < ka))
        key_c = np.where(pick_b, kb, ka)
        lvl_c = np.where(pick_b, lb, la)
        val_c = np.where(pick_b, zb, za)
        fp = self._fingerprint(key_c, lvl_c, t)
        occ = np.minimum(aa, ab) < _BIG
        fp = np.where(occ, fp, -1).astype(np.int32)
        val_c = np.where(occ, val_c, 0.0)
        key_c = np.where(occ, key_c, np.uint32(0))
        if occ.any() and not occ.all():
            empty, src = self._densify_sources(occ)
            fp[empty] = fp[src]
            val_c[empty] = val_c[src]
            key_c[empty] = key_c[src]
        return ICWSSketch(fingerprints=fp, values=val_c, norm=norm_c,
                          argkeys=key_c.astype(np.uint32).view(np.int32))
