"""Unweighted (augmented) MinHash sketch — Algorithm 1 + Algorithm 2.

Stores, per hash function, the minimum hash over the support of the vector and
the vector value at the argmin.  The estimator is the collision-indicator sum
scaled by the Flajolet-Martin union-size estimate U~ (Algorithm 2 / Lemma 1).
This is the paper's "MH" baseline and the technical warm-up of Section 3.
"""
from __future__ import annotations

import dataclasses
from typing import List

import numpy as np

from .hashing import MERSENNE_P, AffineHashFamily
from .types import SparseVec


@dataclasses.dataclass
class MHSketch:
    hash_mins: np.ndarray  # int64 [m]; p is the empty-input sentinel
    values: np.ndarray     # float64 [m]; raw vector values a[j*]
    m: int
    seed: int

    def storage_doubles(self) -> float:
        return 1.5 * self.m  # 32-bit hash + 64-bit value per sample


class MinHash:
    name = "mh"

    def __init__(self, m: int, seed: int = 0):
        self.m = int(m)
        self.seed = int(seed)
        self._hash = AffineHashFamily.create(self.m, self.seed)

    def sketch(self, v: SparseVec) -> MHSketch:
        if v.nnz == 0:
            return MHSketch(hash_mins=np.full(self.m, MERSENNE_P, np.int64),
                            values=np.zeros(self.m), m=self.m, seed=self.seed)
        h = self._hash.hash_ints(v.indices)            # [m, nnz]
        arg = np.argmin(h, axis=1)
        return MHSketch(hash_mins=h[np.arange(self.m), arg],
                        values=v.values[arg], m=self.m, seed=self.seed)

    def sketch_dense(self, a: np.ndarray) -> MHSketch:
        return self.sketch(SparseVec.from_dense(a))

    def merge_union(self, sa: MHSketch, sb: MHSketch) -> MHSketch:
        """Exact sketch of the union of two disjoint-support vectors.

        MinHash is union-mergeable: min over the union = elementwise min of
        the per-part minima (value carried from the winning side).  This is
        the sharded-ingestion primitive -- every host sketches its shard of
        a column, merges are exact, order-independent, and O(m).
        """
        take_a = sa.hash_mins <= sb.hash_mins
        return MHSketch(hash_mins=np.where(take_a, sa.hash_mins, sb.hash_mins),
                        values=np.where(take_a, sa.values, sb.values),
                        m=self.m, seed=self.seed)

    def estimate(self, sa: MHSketch, sb: MHSketch) -> float:
        return float(self.estimate_batch(_stack([sa]), _stack([sb]))[0])

    def estimate_batch(self, A: "StackedMH", B: "StackedMH") -> np.ndarray:
        p = float(MERSENNE_P)
        ha = A.hash_mins.astype(np.float64) / p
        hb = B.hash_mins.astype(np.float64) / p
        denom = np.maximum(np.sum(np.minimum(ha, hb), axis=1), 1e-300)
        u_tilde = self.m / denom - 1.0                  # line 1
        collide = A.hash_mins == B.hash_mins
        s = np.sum(np.where(collide, A.values * B.values, 0.0), axis=1)
        return u_tilde / self.m * s                     # line 2


@dataclasses.dataclass
class StackedMH:
    hash_mins: np.ndarray
    values: np.ndarray


def _stack(sketches: List[MHSketch]) -> StackedMH:
    return StackedMH(hash_mins=np.stack([s.hash_mins for s in sketches]),
                     values=np.stack([s.values for s in sketches]))


stack_mh = _stack
