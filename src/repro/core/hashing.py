"""2-universal hash families over Z_p (p = 2^31 - 1), numpy host path.

The paper (Section 5, "Choice of Hash Function") uses a standard 2-wise
independent affine hash ``h(x) = (c1 x + c2) mod p`` for a 31-bit prime ``p``,
storing ``h(x)/p in [0, 1)`` as the hash value in 32 bits.

For the Weighted MinHash *extended domain* of conceptual size ``n * L`` (which
can exceed ``p``), we hash the (block, slot) **pair** with the multilinear
2-universal family ``h(i, j) = (c1 * i + c2 * j + c3) mod p``.  Within a block
``i`` this is an arithmetic progression in ``j`` with step ``c2`` -- the
structure exploited by :mod:`repro.core.progmin` to take block minima in
O(log p) instead of O(L).  (Hashing the flat index ``i*L + j mod p`` would
alias indices that differ by ``p``; the pair hash avoids that entirely.)

All arithmetic is int64; products stay below 2^62 because operands are < p.
"""
from __future__ import annotations

import dataclasses

import numpy as np

# Mersenne prime 2^31 - 1: hash values fit in 32-bit ints as the paper stores them.
MERSENNE_P = np.int64((1 << 31) - 1)


def _rng(seed: int) -> np.random.Generator:
    return np.random.default_rng(np.random.SeedSequence([0x5EED, int(seed)]))


def mix64(x: np.ndarray) -> np.ndarray:
    """Splitmix64 finalizer: a fixed bijection of the key space.

    Applied to *keys* before 2-universal hashing.  Relabeling the domain with
    a bijection leaves every distributional guarantee intact, but destroys
    adversarial key structure (e.g. consecutive integers, for which a bare
    affine hash is min-wise-biased).  Standard strengthening practice.
    """
    z = np.asarray(x).astype(np.uint64) + np.uint64(0x9E3779B97F4A7C15)
    z = (z ^ (z >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
    z = (z ^ (z >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
    z = z ^ (z >> np.uint64(31))
    return z


def _mix_to_zp(x: np.ndarray) -> np.ndarray:
    """mix64 then reduce into [0, p) as int64."""
    return (mix64(x) % np.uint64(MERSENNE_P)).astype(np.int64)


@dataclasses.dataclass(frozen=True)
class AffineHashFamily:
    """m independent hashes h_t(x) = (c1[t]*x + c2[t]) mod p, x in [0, p)."""

    c1: np.ndarray  # int64 [m], in [1, p)
    c2: np.ndarray  # int64 [m], in [0, p)

    @staticmethod
    def create(m: int, seed: int) -> "AffineHashFamily":
        g = _rng(seed)
        c1 = g.integers(1, MERSENNE_P, size=m, dtype=np.int64)
        c2 = g.integers(0, MERSENNE_P, size=m, dtype=np.int64)
        return AffineHashFamily(c1=c1, c2=c2)

    @property
    def m(self) -> int:
        return int(self.c1.shape[0])

    def hash_ints(self, x: np.ndarray) -> np.ndarray:
        """Hash int64 inputs x[...] -> int64 [m, ...] in [0, p)."""
        x = _mix_to_zp(x)
        shape = (self.m,) + (1,) * x.ndim
        c1 = self.c1.reshape(shape)
        c2 = self.c2.reshape(shape)
        return (c1 * x + c2) % MERSENNE_P

    def hash_unit(self, x: np.ndarray) -> np.ndarray:
        """Hash to floats in [0, 1) as the paper's algorithms are written."""
        return self.hash_ints(x).astype(np.float64) / float(MERSENNE_P)


@dataclasses.dataclass(frozen=True)
class PairHashFamily:
    """m independent multilinear hashes h_t(i, j) = (a[t]*i + b[t]*j + c[t]) mod p.

    2-universal over pairs (i, j) with 0 <= i, j < p.  For fixed i the map
    j -> h(i, j) is the progression  start_t(i) + j * b[t]  (mod p).
    """

    a: np.ndarray  # int64 [m], in [1, p)
    b: np.ndarray  # int64 [m], in [1, p)  (step must be non-zero for progmin)
    c: np.ndarray  # int64 [m], in [0, p)

    @staticmethod
    def create(m: int, seed: int) -> "PairHashFamily":
        g = _rng(seed ^ 0x9E3779B9)
        a = g.integers(1, MERSENNE_P, size=m, dtype=np.int64)
        b = g.integers(1, MERSENNE_P, size=m, dtype=np.int64)
        c = g.integers(0, MERSENNE_P, size=m, dtype=np.int64)
        return PairHashFamily(a=a, b=b, c=c)

    @property
    def m(self) -> int:
        return int(self.a.shape[0])

    def block_starts(self, blocks: np.ndarray) -> np.ndarray:
        """h_t(i, 0) for each block i: int64 [m, nnz] in [0, p).

        The block index is mix64-relabeled (bijection) before hashing; the
        slot index j is NOT -- the progression structure in j is what
        :mod:`repro.core.progmin` exploits.
        """
        blocks = _mix_to_zp(np.asarray(blocks, dtype=np.int64))
        return (self.a[:, None] * blocks[None, :] + self.c[:, None]) % MERSENNE_P

    def hash_pairs_bruteforce(self, i: int, js: np.ndarray) -> np.ndarray:
        """Oracle: hash (i, j) for each j.  int64 [m, len(js)].  Test-only."""
        js = np.asarray(js, dtype=np.int64) % MERSENNE_P
        i = np.int64(_mix_to_zp(np.array([int(i)]))[0])
        return (self.a[:, None] * i + self.b[:, None] * js[None, :]
                + self.c[:, None]) % MERSENNE_P


def uniforms_from_key(seed: int, stream: int, keys: np.ndarray, m: int) -> np.ndarray:
    """Derive pseudo-uniform (0,1) floats keyed by (key, t) for t in [0, m).

    Used by the ICWS host reference to generate the per-(index, sample) Gamma /
    uniform variates.  Each ``stream`` gives an independent family.  Values are
    strictly inside (0, 1) so logs are safe.
    """
    fam = AffineHashFamily.create(m, seed ^ (0xA5A5A5 + 7919 * stream))
    h = fam.hash_ints(keys)  # [m, nnz], in [0, p)
    # Mix once more (single affine hash is too structured for variate generation:
    # consecutive keys give arithmetic progressions).  Splitmix-style finalizer.
    z = h.astype(np.uint64) + np.uint64(0x9E3779B97F4A7C15)
    z = (z ^ (z >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
    z = (z ^ (z >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
    z = z ^ (z >> np.uint64(31))
    u = (z >> np.uint64(11)).astype(np.float64) / float(1 << 53)
    return np.clip(u, 1e-12, 1.0 - 1e-12)
