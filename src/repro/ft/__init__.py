"""Fault tolerance: heartbeats, stragglers, preemption, elastic recovery."""
from .monitor import (HeartbeatRegistry, PreemptionHandler, RecoveryAction,
                      StragglerDetector, elastic_plan, plan_recovery)

__all__ = ["HeartbeatRegistry", "PreemptionHandler", "RecoveryAction",
           "StragglerDetector", "elastic_plan", "plan_recovery"]
