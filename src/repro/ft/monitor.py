"""Fault tolerance: heartbeats, straggler detection, preemption, elasticity.

Designed for the multi-controller JAX deployment model (one process per
host, thousands of hosts):

  * :class:`HeartbeatRegistry` -- hosts post (host_id, step, timestamp);
    a monitor flags hosts silent for > ``timeout`` as suspected-dead.
    On real clusters the transport is the cluster KV store; here it is an
    in-process dict with the same API so the logic is testable.
  * :class:`StragglerDetector` -- robust per-step-time statistics (median +
    MAD); a host whose step time exceeds median + k*MAD for ``patience``
    consecutive steps is flagged.  The mitigation hook is pluggable
    (re-shard away, checkpoint-and-evict, or just alert).
  * :class:`PreemptionHandler` -- SIGTERM handler that requests a final
    synchronous checkpoint before the allocator kills the job.
  * :func:`elastic_plan` -- given a dead-host set, computes the largest
    rectangular (data, model) mesh over surviving hosts and the restore
    plan (which checkpoint step, which new mesh) -- paired with the elastic
    restore in :mod:`repro.checkpoint.store`.
  * Gradient-divergence detection plugs in via repro.train.telemetry: a
    replica whose sketch-estimated gradient cosine vs the fleet median
    drops below threshold is treated like a failed health check (silent
    data/hardware corruption).
"""
from __future__ import annotations

import dataclasses
import signal
import threading
import time
from typing import Callable, Dict, List, Optional, Set, Tuple

import numpy as np


@dataclasses.dataclass
class Heartbeat:
    host_id: int
    step: int
    wall_time: float


class HeartbeatRegistry:
    def __init__(self, num_hosts: int, timeout: float = 60.0):
        self.num_hosts = num_hosts
        self.timeout = timeout
        self._beats: Dict[int, Heartbeat] = {}
        self._lock = threading.Lock()

    def post(self, host_id: int, step: int, now: Optional[float] = None):
        with self._lock:
            self._beats[host_id] = Heartbeat(host_id, step,
                                             now if now is not None else time.time())

    def dead_hosts(self, now: Optional[float] = None) -> Set[int]:
        now = now if now is not None else time.time()
        with self._lock:
            dead = set()
            for h in range(self.num_hosts):
                hb = self._beats.get(h)
                if hb is None or now - hb.wall_time > self.timeout:
                    dead.add(h)
            return dead

    def healthy(self, now: Optional[float] = None) -> bool:
        return not self.dead_hosts(now)


class StragglerDetector:
    def __init__(self, num_hosts: int, k_mad: float = 6.0, patience: int = 3,
                 window: int = 50):
        self.num_hosts = num_hosts
        self.k_mad = k_mad
        self.patience = patience
        self.window = window
        self._times: Dict[int, List[float]] = {h: [] for h in range(num_hosts)}
        self._strikes: Dict[int, int] = {h: 0 for h in range(num_hosts)}

    def record(self, host_id: int, step_time: float):
        buf = self._times[host_id]
        buf.append(step_time)
        if len(buf) > self.window:
            buf.pop(0)

    def stragglers(self) -> Set[int]:
        latest = {h: t[-1] for h, t in self._times.items() if t}
        if len(latest) < max(2, self.num_hosts // 2):
            return set()
        vals = np.array(list(latest.values()))
        med = np.median(vals)
        mad = np.median(np.abs(vals - med)) + 1e-9
        out = set()
        for h, t in latest.items():
            if t > med + self.k_mad * mad:
                self._strikes[h] += 1
            else:
                self._strikes[h] = 0
            if self._strikes[h] >= self.patience:
                out.add(h)
        return out


class PreemptionHandler:
    """SIGTERM -> request checkpoint; the train loop polls should_save()."""

    def __init__(self):
        self._flag = threading.Event()

    def install(self):
        signal.signal(signal.SIGTERM, self._on_signal)
        return self

    def _on_signal(self, signum, frame):
        self._flag.set()

    def should_save(self) -> bool:
        return self._flag.is_set()

    def trigger_for_test(self):
        self._flag.set()


def elastic_plan(num_hosts: int, devices_per_host: int, dead: Set[int],
                 model_parallel: int) -> Tuple[int, int]:
    """Largest (data, model) mesh over survivors.

    Keeps model-parallel size fixed (param layout unchanged within a shard
    group) and shrinks data-parallel width to the largest multiple that
    survivors support -- restore then reshards via the elastic checkpoint.
    """
    alive = num_hosts - len(dead)
    total = alive * devices_per_host
    if total < model_parallel:
        raise RuntimeError("not enough survivors for one model replica")
    data = total // model_parallel
    return data, model_parallel


@dataclasses.dataclass
class RecoveryAction:
    kind: str          # 'none' | 'evict_and_rescale' | 'alert_straggler'
    dead_hosts: Set[int]
    stragglers: Set[int]
    new_mesh: Optional[Tuple[int, int]] = None


def plan_recovery(hb: HeartbeatRegistry, sd: StragglerDetector,
                  devices_per_host: int, model_parallel: int,
                  now: Optional[float] = None) -> RecoveryAction:
    dead = hb.dead_hosts(now)
    stragglers = sd.stragglers() - dead
    if dead:
        mesh = elastic_plan(hb.num_hosts, devices_per_host, dead, model_parallel)
        return RecoveryAction("evict_and_rescale", dead, stragglers, mesh)
    if stragglers:
        return RecoveryAction("alert_straggler", dead, stragglers, None)
    return RecoveryAction("none", dead, stragglers, None)
