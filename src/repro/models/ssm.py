"""Attention-free sequence mixers: RWKV6 ("Finch") and Mamba (for Jamba).

Both are implemented with an O(1)-state recurrence:
  * training/prefill: ``lax.scan`` over time (single-trace compile; the
    roofline module multiplies body costs by the trip count),
  * decode: a single-step update -- which is what makes the ``long_500k``
    cell feasible for these families.

RWKV6 per head h with state S [hd, hd]:
    out_t = r_t . (S + u (x) (k_t v_t^T))      (read with bonus u)
    S    <- diag(w_t) S + k_t (x) v_t          (data-dependent decay w_t)
with w_t = exp(-exp(w0 + lora(x_t))) in (0, 1) per channel -- the "Finch"
data-dependent decay.

Mamba: in_proj -> (x, z); causal conv; dt = softplus(lora(x));
    h <- exp(dt*A) h + (dt*x) (x) B_t ;  y = h . C_t + D*x ;  out(silu(z)*y).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .layers import _init_normal


# ---------------------------------------------------------------------------
# RWKV6
# ---------------------------------------------------------------------------
def init_rwkv_time_mix(key, cfg):
    d, H, hd = cfg.d_model, cfg.num_heads, cfg.head_dim
    lora = 64
    ks = jax.random.split(key, 10)
    s = 1.0 / np.sqrt(d)
    params = {
        "mu": _init_normal(ks[0], (5, d), 0.02),            # shift mixes r,k,v,g,w
        "wr": _init_normal(ks[1], (d, H, hd), s),
        "wk": _init_normal(ks[2], (d, H, hd), s),
        "wv": _init_normal(ks[3], (d, H, hd), s),
        "wg": _init_normal(ks[4], (d, H, hd), s),
        "w0": _init_normal(ks[5], (H, hd), 0.5),
        "w_lora_a": _init_normal(ks[6], (d, lora), s),
        "w_lora_b": _init_normal(ks[7], (lora, H, hd), 1.0 / np.sqrt(lora)),
        "u": _init_normal(ks[8], (H, hd), 0.5),
        "wo": _init_normal(ks[9], (H, hd, d), 1.0 / np.sqrt(H * hd)),
        "ln_g": jnp.zeros((H, hd), jnp.float32),
    }
    specs = {
        "mu": (None, "embed"),
        "wr": ("fsdp", "heads", "head_dim"),
        "wk": ("fsdp", "heads", "head_dim"),
        "wv": ("fsdp", "heads", "head_dim"),
        "wg": ("fsdp", "heads", "head_dim"),
        "w0": ("heads", "head_dim"),
        "w_lora_a": ("fsdp", None),
        "w_lora_b": (None, "heads", "head_dim"),
        "u": ("heads", "head_dim"),
        "wo": ("heads", "head_dim", "fsdp"),
        "ln_g": ("heads", "head_dim"),
    }
    return params, specs


def _rwkv_inputs(params, x, x_prev):
    """Token-shift mixing; x [B,T,d]; x_prev [B,1,d] (last token of prev chunk)."""
    dt = x.dtype
    shifted = jnp.concatenate([x_prev, x[:, :-1]], axis=1)
    mu = params["mu"].astype(dt)                            # [5, d]
    mix = x[:, :, None, :] + mu[None, None] * (shifted - x)[:, :, None, :]
    xr, xk, xv, xg, xw = [mix[:, :, i] for i in range(5)]
    r = jnp.einsum("btd,dhk->bthk", xr, params["wr"].astype(dt))
    k = jnp.einsum("btd,dhk->bthk", xk, params["wk"].astype(dt))
    v = jnp.einsum("btd,dhk->bthk", xv, params["wv"].astype(dt))
    g = jnp.einsum("btd,dhk->bthk", xg, params["wg"].astype(dt))
    wlog = params["w0"].astype(jnp.float32)[None, None] + jnp.einsum(
        "btd,dl,lhk->bthk", xw.astype(jnp.float32),
        params["w_lora_a"].astype(jnp.float32),
        params["w_lora_b"].astype(jnp.float32))
    w = jnp.exp(-jnp.exp(wlog))                              # decay in (0,1) f32
    return r, k, v, g, w


def _rwkv_read(params, r, kk, vv, g, state, u):
    """out_t given state (pre-update).  r/k/v/g [B,H,hd] f32."""
    rd = r
    bonus = u[None] * kk                                     # [B,H,hd]
    out = jnp.einsum("bhi,bhij->bhj", rd, state) \
        + jnp.einsum("bhi,bhi,bhj->bhj", rd, bonus, vv)
    # group norm over head dim
    mu = out.mean(-1, keepdims=True)
    var = out.var(-1, keepdims=True)
    out = (out - mu) * jax.lax.rsqrt(var + 1e-5) * (1.0 + params["ln_g"][None])
    return out * jax.nn.silu(g)


def rwkv_time_mix(params, x, x_prev, state):
    """x [B,T,d]; state [B,H,hd,hd] f32.  Returns (out [B,T,d], x_last, state)."""
    B, T, d = x.shape
    H, hd = params["u"].shape
    dt = x.dtype
    r, k, v, g, w = _rwkv_inputs(params, x, x_prev)
    u = params["u"].astype(jnp.float32)

    def step(S, inputs):
        rt, kt, vt, gt, wt = inputs                          # [B,H,hd] each
        out = _rwkv_read(params, rt, kt, vt, gt, S, u)
        S = wt[..., None] * S + jnp.einsum("bhi,bhj->bhij", kt, vt)
        return S, out

    xs = (r.transpose(1, 0, 2, 3).astype(jnp.float32),
          k.transpose(1, 0, 2, 3).astype(jnp.float32),
          v.transpose(1, 0, 2, 3).astype(jnp.float32),
          g.transpose(1, 0, 2, 3).astype(jnp.float32),
          w.transpose(1, 0, 2, 3))
    state, outs = jax.lax.scan(step, state, xs)              # outs [T,B,H,hd]
    out = outs.transpose(1, 0, 2, 3).astype(dt)
    out = jnp.einsum("bthk,hkd->btd", out, params["wo"].astype(dt))
    return out, x[:, -1:], state


def init_rwkv_channel_mix(key, cfg):
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 4)
    params = {
        "mu": _init_normal(ks[0], (2, d), 0.02),
        "wk": _init_normal(ks[1], (d, f), 1.0 / np.sqrt(d)),
        "wv": _init_normal(ks[2], (f, d), 1.0 / np.sqrt(f)),
        "wr": _init_normal(ks[3], (d, d), 1.0 / np.sqrt(d)),
    }
    specs = {"mu": (None, "embed"), "wk": ("fsdp", "mlp"),
             "wv": ("mlp", "fsdp"), "wr": ("fsdp", "embed")}
    return params, specs


def rwkv_channel_mix(params, x, x_prev):
    dt = x.dtype
    shifted = jnp.concatenate([x_prev, x[:, :-1]], axis=1)
    mu = params["mu"].astype(dt)
    xk = x + mu[0][None, None] * (shifted - x)
    xr = x + mu[1][None, None] * (shifted - x)
    kk = jnp.square(jax.nn.relu(xk @ params["wk"].astype(dt)))
    out = jax.nn.sigmoid(xr @ params["wr"].astype(dt)) * (kk @ params["wv"].astype(dt))
    return out, x[:, -1:]


# ---------------------------------------------------------------------------
# Mamba (selective SSM)
# ---------------------------------------------------------------------------
def init_mamba(key, cfg):
    d = cfg.d_model
    di = cfg.ssm_expand * d
    ds, dtr, cw = cfg.ssm_state, cfg.dt_rank, cfg.ssm_conv
    ks = jax.random.split(key, 8)
    s = 1.0 / np.sqrt(d)
    params = {
        "in_proj": _init_normal(ks[0], (d, 2 * di), s),
        "conv_w": _init_normal(ks[1], (cw, di), 0.5),
        "x_dt_a": _init_normal(ks[2], (di, dtr), 1.0 / np.sqrt(di)),
        "x_dt_b": _init_normal(ks[3], (dtr, di), 1.0 / np.sqrt(dtr)),
        "x_bc": _init_normal(ks[4], (di, 2 * ds), 1.0 / np.sqrt(di)),
        "a_log": jnp.log(jnp.tile(jnp.arange(1, ds + 1, dtype=jnp.float32)[None],
                                  (di, 1))),
        "d_skip": jnp.ones((di,), jnp.float32),
        "out_proj": _init_normal(ks[5], (di, d), 1.0 / np.sqrt(di)),
    }
    specs = {
        "in_proj": ("fsdp", "ssm_inner"),
        "conv_w": ("conv", "ssm_inner"),
        "x_dt_a": ("ssm_inner", "dt_rank"),
        "x_dt_b": ("dt_rank", "ssm_inner"),
        "x_bc": ("ssm_inner", None),
        "a_log": ("ssm_inner", "ssm_state"),
        "d_skip": ("ssm_inner",),
        "out_proj": ("ssm_inner", "fsdp"),
    }
    return params, specs


def _mamba_core(params, xz, conv_tail):
    """Shared projections; xz [B,T,2di]; conv_tail [B, cw-1, di]."""
    dt_ = xz.dtype
    di = params["out_proj"].shape[0]
    x, z = xz[..., :di], xz[..., di:]
    # causal conv over time with carried tail
    xin = jnp.concatenate([conv_tail.astype(dt_), x], axis=1)   # [B, T+cw-1, di]
    cw = params["conv_w"].shape[0]
    conv = sum(xin[:, i:i + x.shape[1]] * params["conv_w"][i].astype(dt_)
               for i in range(cw))
    xc = jax.nn.silu(conv)
    dt_lora = (xc @ params["x_dt_a"].astype(dt_)) @ params["x_dt_b"].astype(dt_)
    dt_v = jax.nn.softplus(dt_lora.astype(jnp.float32) - 4.0)      # [B,T,di]
    bc = xc @ params["x_bc"].astype(dt_)
    ds = params["a_log"].shape[1]
    B_t, C_t = bc[..., :ds].astype(jnp.float32), bc[..., ds:].astype(jnp.float32)
    new_tail = xin[:, -(cw - 1):] if cw > 1 else xin[:, :0]
    return x, z, xc, dt_v, B_t, C_t, new_tail


def mamba_block(params, x_seq, conv_tail, state):
    """x_seq [B,T,d]; conv_tail [B,cw-1,di]; state [B,di,ds] f32."""
    dt_ = x_seq.dtype
    xz = x_seq @ params["in_proj"].astype(dt_)
    x, z, xc, dt_v, B_t, C_t, new_tail = _mamba_core(params, xz, conv_tail)
    A = -jnp.exp(params["a_log"].astype(jnp.float32))              # [di, ds]

    def step(h, inputs):
        xt, dtt, Bt, Ct = inputs                                   # [B,di],[B,di],[B,ds],[B,ds]
        decay = jnp.exp(dtt[..., None] * A[None])                  # [B,di,ds]
        h = decay * h + (dtt * xt)[..., None] * Bt[:, None, :]
        y = jnp.einsum("bis,bs->bi", h, Ct)
        return h, y

    xs = (xc.transpose(1, 0, 2).astype(jnp.float32),
          dt_v.transpose(1, 0, 2), B_t.transpose(1, 0, 2), C_t.transpose(1, 0, 2))
    state, ys = jax.lax.scan(step, state, xs)                      # ys [T,B,di]
    y = ys.transpose(1, 0, 2).astype(dt_) + params["d_skip"].astype(dt_) * xc
    out = (jax.nn.silu(z) * y) @ params["out_proj"].astype(dt_)
    return out, new_tail, state


def init_mamba_state(cfg, batch: int):
    di = cfg.ssm_expand * cfg.d_model
    state = jnp.zeros((batch, di, cfg.ssm_state), jnp.float32)
    tail = jnp.zeros((batch, cfg.ssm_conv - 1, di), jnp.float32)
    return state, tail


def init_rwkv_state(cfg, batch: int):
    H, hd = cfg.num_heads, cfg.head_dim
    wkv = jnp.zeros((batch, H, hd, hd), jnp.float32)
    x_tm = jnp.zeros((batch, 1, cfg.d_model), jnp.float32)
    x_cm = jnp.zeros((batch, 1, cfg.d_model), jnp.float32)
    return wkv, x_tm, x_cm
