"""Model substrate: every assigned architecture family in pure JAX."""
from .transformer import Model
from .counting import count_active_params, count_params

__all__ = ["Model", "count_params", "count_active_params"]
