"""Mixture-of-Experts FFN: top-k router + sort-based capacity dispatch.

Dispatch is O(T*k*d) gather/scatter (argsort + rank-in-group), NOT the
O(T^2) GShard one-hot einsum: tokens are ranked within their expert by a
sorted segment-offset computation and scattered into a [E, capacity, d]
buffer (capacity overflow drops, GShard-style position priority).  Expert
weights are annotated ("experts", ...) so the expert dim shards over the
model axis (EP) when divisible, with the per-expert FFN dim available as a
TP fallback ("expert_mlp") for small expert counts (e.g. Mixtral's 8 < 16).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .layers import _init_normal


def init_moe(key, cfg):
    d, f, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    k0, k1, k2, k3 = jax.random.split(key, 4)
    s = 1.0 / np.sqrt(d)
    params = {
        "w_router": _init_normal(k0, (d, E), s),
        "w_gate": _init_normal(k1, (E, d, f), s),
        "w_up": _init_normal(k2, (E, d, f), s),
        "w_down": _init_normal(k3, (E, f, d), 1.0 / np.sqrt(f)),
    }
    specs = {
        "w_router": ("embed", None),
        "w_gate": ("experts", "fsdp", "expert_mlp"),
        "w_up": ("experts", "fsdp", "expert_mlp"),
        "w_down": ("experts", "expert_mlp", "fsdp"),
    }
    return params, specs


def _positions_in_expert(slot_expert: jnp.ndarray, num_experts: int):
    """Rank of each slot within its expert group (sort-based, O(N log N))."""
    n = slot_expert.shape[0]
    order = jnp.argsort(slot_expert)                    # stable in jax
    sorted_e = slot_expert[order]
    starts = jnp.searchsorted(sorted_e, jnp.arange(num_experts))   # [E]
    ranks_sorted = jnp.arange(n) - starts[sorted_e]
    pos = jnp.zeros(n, jnp.int32).at[order].set(ranks_sorted.astype(jnp.int32))
    return pos


def _dispatch_block(x, top_p, top_e, E: int, k: int, capacity: int):
    """Shard-local dispatch for ONE token block: returns (buf, gather plan)."""
    N, d = x.shape
    dt = x.dtype
    slot_expert = top_e.reshape(N * k)
    slot_weight = top_p.reshape(N * k).astype(dt)
    slot_token = jnp.repeat(jnp.arange(N, dtype=jnp.int32), k)
    pos = _positions_in_expert(slot_expert, E)
    keep = pos < capacity
    pos_c = jnp.minimum(pos, capacity - 1)
    payload = jnp.where(keep[:, None], x[slot_token], 0).astype(dt)
    buf = jnp.zeros((E, capacity, d), dt).at[slot_expert, pos_c].add(
        payload, mode="drop")
    return buf, (slot_expert, slot_weight, slot_token, keep, pos_c)


def _combine_block(out_buf, plan, N: int, d: int):
    slot_expert, slot_weight, slot_token, keep, pos_c = plan
    slot_out = out_buf[slot_expert, pos_c] * slot_weight[:, None]
    slot_out = jnp.where(keep[:, None], slot_out, 0)
    return jnp.zeros((N, d), out_buf.dtype).at[slot_token].add(slot_out)


def moe_ffn(params, x, cfg, ctx=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x [N, d] flat tokens -> (y [N, d], aux load-balance loss scalar).

    Dispatch is **block-local**: tokens are viewed as [G, N/G] blocks with G
    = the data-parallel shard count, and ranking/scatter/gather are vmapped
    over blocks.  Every dispatch op then carries the sharded block dim, so
    GSPMD keeps the whole dispatch data-parallel -- the naive *global* sort
    based dispatch forces XLA to materialize and all-reduce the full
    [E, C, d] buffer on every shard (measured 18 TB/device/step on Mixtral
    train_4k; see EXPERIMENTS.md §Perf iteration 1).  Capacity is per-block
    (the GShard/MaxText per-group semantic).
    """
    N, d = x.shape
    E, k = cfg.num_experts, cfg.num_experts_per_tok
    dt = x.dtype
    if ctx is not None:
        x = ctx.c(x, ("tokens", "embed"))

    logits = (x @ params["w_router"].astype(dt)).astype(jnp.float32)   # [N, E]
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)                             # [N, k]
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # Switch-transformer load-balance auxiliary loss.
    density = jnp.mean(jax.nn.one_hot(top_e[:, 0], E, dtype=jnp.float32), axis=0)
    mean_prob = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(density * mean_prob)

    G = _dispatch_groups(N, ctx)
    Nl = N // G
    capacity = max(int(np.ceil(Nl * k / E * cfg.capacity_factor)), 4)

    xb = x.reshape(G, Nl, d)
    pb = top_p.reshape(G, Nl, k)
    eb = top_e.reshape(G, Nl, k)
    if ctx is not None:
        xb = ctx.c(xb, ("tokens", None, "embed"))

    buf, plan = jax.vmap(
        lambda xg, pg, eg: _dispatch_block(xg, pg, eg, E, k, capacity)
    )(xb, pb, eb)                                           # buf [G, E, C, d]
    if ctx is not None:
        buf = ctx.c(buf, ("tokens", "experts", "capacity", "embed"))

    # expert FFN (swiglu), batched over blocks
    g = jnp.einsum("gecd,edf->gecf", buf, params["w_gate"].astype(dt))
    u = jnp.einsum("gecd,edf->gecf", buf, params["w_up"].astype(dt))
    if ctx is not None:
        g = ctx.c(g, ("tokens", "experts", "capacity", "expert_mlp"))
        u = ctx.c(u, ("tokens", "experts", "capacity", "expert_mlp"))
    h = jax.nn.silu(g) * u
    out_buf = jnp.einsum("gecf,efd->gecd", h, params["w_down"].astype(dt))
    if ctx is not None:
        out_buf = ctx.c(out_buf, ("tokens", "experts", "capacity", "embed"))

    y = jax.vmap(lambda ob, pl: _combine_block(ob, pl, Nl, d))(out_buf, plan)
    y = y.reshape(N, d)
    if ctx is not None:
        y = ctx.c(y, ("tokens", "embed"))
    return y, aux


def _dispatch_groups(N: int, ctx) -> int:
    """Token blocks = data-parallel shard count (1 without a mesh)."""
    if ctx is None:
        return 1
    g = 1
    for a in ("pod", "data"):
        g *= ctx.mesh.shape.get(a, 1)
    while g > 1 and N % g != 0:
        g //= 2
    return max(g, 1)
