"""Analytic parameter counts (for roofline MODEL_FLOPS = 6*N*D cross-checks)."""
from __future__ import annotations


def _attn_params(cfg) -> int:
    d, H, K, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    return d * H * hd + 2 * d * K * hd + H * hd * d


def _mlp_params(cfg) -> int:
    mult = 3 if cfg.mlp_variant in ("swiglu", "geglu") else 2
    return mult * cfg.d_model * cfg.d_ff


def _moe_params(cfg) -> int:
    return cfg.d_model * cfg.num_experts + cfg.num_experts * 3 * cfg.d_model * cfg.d_ff


def _moe_active(cfg) -> int:
    return cfg.d_model * cfg.num_experts \
        + cfg.num_experts_per_tok * 3 * cfg.d_model * cfg.d_ff


def _rwkv_layer(cfg) -> int:
    d, H, hd = cfg.d_model, cfg.num_heads, cfg.head_dim
    tm = 5 * d + 4 * d * H * hd + 2 * H * hd + d * 64 + 64 * H * hd + H * hd * d
    cm = 2 * d + 2 * cfg.d_model * cfg.d_ff + d * d
    return tm + cm


def _mamba_layer(cfg) -> int:
    d = cfg.d_model
    di = cfg.ssm_expand * d
    ds, dtr, cw = cfg.ssm_state, cfg.dt_rank, cfg.ssm_conv
    return (d * 2 * di + cw * di + di * dtr + dtr * di + di * 2 * ds
            + di * ds + di + di * d)


def _ffn_at(cfg, layer_idx: int) -> int:
    if cfg.num_experts and layer_idx % cfg.moe_every == cfg.moe_offset:
        return _moe_params(cfg)
    return _mlp_params(cfg)


def _ffn_active_at(cfg, layer_idx: int) -> int:
    if cfg.num_experts and layer_idx % cfg.moe_every == cfg.moe_offset:
        return _moe_active(cfg)
    return _mlp_params(cfg)


def count_params(cfg) -> int:
    return _count(cfg, active=False)


def count_active_params(cfg) -> int:
    return _count(cfg, active=True)


def _count(cfg, active: bool) -> int:
    ffn = _ffn_active_at if active else _ffn_at
    emb = cfg.vocab_size * cfg.d_model * (1 if cfg.tie_embeddings else 2)
    total = emb + cfg.d_model  # final norm
    if cfg.family in ("dense", "moe", "vlm"):
        for l in range(cfg.num_layers):
            total += _attn_params(cfg) + ffn(cfg, l) + 2 * cfg.d_model
        if cfg.family == "vlm":
            total += cfg.d_model * cfg.d_model  # patch projection stub
    elif cfg.family == "ssm":
        total += cfg.num_layers * _rwkv_layer(cfg)
    elif cfg.family == "hybrid":
        for l in range(cfg.num_layers):
            in_group = l % cfg.hybrid_group
            mixer = _attn_params(cfg) if in_group == cfg.hybrid_attn_index \
                else _mamba_layer(cfg)
            total += mixer + ffn(cfg, l) + 2 * cfg.d_model
    elif cfg.family == "encdec":
        enc_layer = _attn_params(cfg) + 2 * cfg.d_model * cfg.d_ff + 2 * cfg.d_model
        dec_layer = 2 * _attn_params(cfg) + 2 * cfg.d_model * cfg.d_ff + 3 * cfg.d_model
        total += cfg.encoder_layers * enc_layer + cfg.num_layers * dec_layer
        total += cfg.encoder_d_model * cfg.d_model  # frame projection stub
    return total
