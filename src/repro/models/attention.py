"""Attention: GQA projections, chunked (flash-style) training/prefill path,
and single-token decode with full or circular (sliding-window) KV caches.

The chunked path never materializes the [T, S] score matrix: an online
softmax accumulates over key chunks inside a scan over query chunks, exactly
the FlashAttention recurrence, in pure JAX (compiles to bounded-memory while
loops; a natural Pallas port if attention ever dominates the roofline --
here the paper's contribution is sketching, so we keep attention XLA-native).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .layers import COMPUTE_DTYPE, _init_normal, apply_rope

NEG = -1e30


def _pick_chunk(n: int, target: int) -> int:
    """Largest divisor of n that is <= target (falls back to n)."""
    if n <= target:
        return n
    for c in range(target, 0, -1):
        if n % c == 0:
            return c
    return n


def init_attention(key, cfg):
    d, H, K, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = 1.0 / np.sqrt(d)
    params = {
        "wq": _init_normal(k1, (d, H, hd), s),
        "wk": _init_normal(k2, (d, K, hd), s),
        "wv": _init_normal(k3, (d, K, hd), s),
        "wo": _init_normal(k4, (H, hd, d), 1.0 / np.sqrt(H * hd)),
    }
    specs = {
        "wq": ("fsdp", "heads", "head_dim"),
        "wk": ("fsdp", "kv_heads", "head_dim"),
        "wv": ("fsdp", "kv_heads", "head_dim"),
        "wo": ("heads", "head_dim", "fsdp"),
    }
    return params, specs


def _project_qkv(params, x, cfg, positions, rope: bool = True):
    dt = x.dtype
    q = jnp.einsum("btd,dhk->bthk", x, params["wq"].astype(dt))
    k = jnp.einsum("btd,dhk->bthk", x, params["wk"].astype(dt))
    v = jnp.einsum("btd,dhk->bthk", x, params["wv"].astype(dt))
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _merge_heads(params, o, dt):
    return jnp.einsum("bthk,hkd->btd", o, params["wo"].astype(dt))


# ---------------------------------------------------------------------------
# Chunked flash attention (training / prefill)
# ---------------------------------------------------------------------------
def chunked_attention(q, k, v, *, causal: bool, window: int = 0,
                      q_offset: int = 0, k_offset: int = 0,
                      q_chunk: int = 1024, k_chunk: int = 1024):
    """q [B,Tq,H,D], k/v [B,S,K,D] (GQA: H = K*G).  Returns [B,Tq,H,D].

    Online-softmax over key chunks inside a scan over query chunks; scores
    accumulate in f32.  ``window > 0`` masks keys older than ``window``.
    """
    B, Tq, H, D = q.shape
    S, K = k.shape[1], k.shape[2]
    G = H // K
    qc = _pick_chunk(Tq, q_chunk)
    kc = _pick_chunk(S, k_chunk)
    nq, nk = Tq // qc, S // kc

    scale = 1.0 / np.sqrt(D)
    q_r = q.reshape(B, nq, qc, K, G, D).transpose(1, 0, 3, 4, 2, 5)  # [nq,B,K,G,qc,D]
    k_r = k.reshape(B, nk, kc, K, D).transpose(1, 0, 3, 2, 4)        # [nk,B,K,kc,D]
    v_r = v.reshape(B, nk, kc, K, D).transpose(1, 0, 3, 2, 4)

    def q_body(_, qi_and_chunk):
        qi, q_blk = qi_and_chunk                                      # [B,K,G,qc,D]
        q_pos = q_offset + qi * qc + jnp.arange(qc)

        # Rematerialize per-chunk probabilities in the backward pass instead
        # of letting the scan stack [*, qc, kc] score matrices as residuals
        # (which would defeat flash attention's O(T) memory in training).
        @functools.partial(jax.checkpoint, prevent_cse=False)
        def k_body(carry, ki_and_kv):
            m, l, acc = carry
            ki, k_blk, v_blk = ki_and_kv
            k_pos = k_offset + ki * kc + jnp.arange(kc)
            s = jnp.einsum("bkgqd,bkcd->bkgqc", q_blk, k_blk,
                           preferred_element_type=jnp.float32) * scale
            mask = jnp.ones((qc, kc), bool)
            if causal:
                mask &= k_pos[None, :] <= q_pos[:, None]
            if window:
                mask &= k_pos[None, :] > q_pos[:, None] - window
            s = jnp.where(mask[None, None, None], s, NEG)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            pv = jnp.einsum("bkgqc,bkcd->bkgqd", p.astype(v_blk.dtype), v_blk,
                            preferred_element_type=jnp.float32)
            acc_new = acc * corr[..., None] + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, K, G, qc), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, K, G, qc), jnp.float32)
        a0 = jnp.zeros((B, K, G, qc, D), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            k_body, (m0, l0, a0), (jnp.arange(nk), k_r, v_r))
        out = acc / jnp.maximum(l, 1e-30)[..., None]                  # [B,K,G,qc,D]
        return None, out.astype(q.dtype)

    _, outs = jax.lax.scan(q_body, None, (jnp.arange(nq), q_r))       # [nq,B,K,G,qc,D]
    out = outs.transpose(1, 0, 4, 2, 3, 5).reshape(B, Tq, H, D)
    return out


def attention_block(params, x, cfg, ctx=None, *, positions=None,
                    q_chunk: int = 1024, k_chunk: int = 1024):
    """Full training/prefill self-attention sublayer (pre-norm done by caller)."""
    B, T, _ = x.shape
    if positions is None:
        positions = jnp.arange(T)[None, :]
    q, k, v = _project_qkv(params, x, cfg, positions)
    if ctx is not None:
        q = ctx.c(q, ("batch", "seq", "heads", "head_dim"))
        k = ctx.c(k, ("batch", "seq", "kv_heads", "head_dim"))
        v = ctx.c(v, ("batch", "seq", "kv_heads", "head_dim"))
    o = chunked_attention(q, k, v, causal=True, window=cfg.sliding_window,
                          q_chunk=q_chunk, k_chunk=k_chunk)
    if ctx is not None:
        o = ctx.c(o, ("batch", "seq", "heads", "head_dim"))
    return _merge_heads(params, o, x.dtype)


# ---------------------------------------------------------------------------
# Decode (single token) with full or circular KV cache
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class CacheLayout:
    size: int          # slots (max_seq for full, window for SWA)
    windowed: bool


def cache_layout(cfg, max_seq: int) -> CacheLayout:
    if cfg.sliding_window and cfg.sliding_window < max_seq:
        return CacheLayout(size=cfg.sliding_window, windowed=True)
    return CacheLayout(size=max_seq, windowed=False)


def init_kv_cache(cfg, layers: int, batch: int, layout: CacheLayout):
    K, hd = cfg.num_kv_heads, cfg.head_dim
    cache = {
        "k": jnp.zeros((layers, batch, layout.size, K, hd), COMPUTE_DTYPE),
        "v": jnp.zeros((layers, batch, layout.size, K, hd), COMPUTE_DTYPE),
    }
    specs = {
        "k": ("layers", "batch", "cache_seq", "kv_heads", "head_dim"),
        "v": ("layers", "batch", "cache_seq", "kv_heads", "head_dim"),
    }
    return cache, specs


def decode_attention(params, x, cfg, layer_k, layer_v, slot_pos, pos,
                     layout: CacheLayout, ctx=None):
    """One-token attention.  x [B,1,d]; layer_k/v [B,S,K,hd]; pos scalar.

    Returns (out [B,1,d], new_k, new_v).  ``slot_pos [S]`` holds the global
    position stored in each slot (-1 = empty) and is maintained by the caller
    (shared across layers).
    """
    B = x.shape[0]
    dt = x.dtype
    positions = jnp.full((B, 1), pos, jnp.int32)
    q, k_new, v_new = _project_qkv(params, x, cfg, positions)

    slot = pos % layout.size if layout.windowed else pos
    layer_k = jax.lax.dynamic_update_slice(layer_k, k_new, (0, slot, 0, 0))
    layer_v = jax.lax.dynamic_update_slice(layer_v, v_new, (0, slot, 0, 0))
    if ctx is not None:
        layer_k = ctx.c(layer_k, ("batch", "cache_seq", "kv_heads", "head_dim"))
        layer_v = ctx.c(layer_v, ("batch", "cache_seq", "kv_heads", "head_dim"))

    K, hd = cfg.num_kv_heads, cfg.head_dim
    G = cfg.num_heads // K
    qr = q.reshape(B, K, G, hd)
    s = jnp.einsum("bkgd,bskd->bkgs", qr, layer_k,
                   preferred_element_type=jnp.float32) / np.sqrt(hd)
    valid = (slot_pos >= 0) & (slot_pos <= pos)
    if layout.windowed:
        valid &= slot_pos > pos - layout.size
    s = jnp.where(valid[None, None, None, :], s, NEG)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", p.astype(dt), layer_v,
                   preferred_element_type=jnp.float32)
    o = o.reshape(B, 1, K * G, hd)
    out = _merge_heads(params, o.astype(dt), dt)
    return out, layer_k, layer_v


# ---------------------------------------------------------------------------
# Cross attention (whisper decoder)
# ---------------------------------------------------------------------------
def init_cross_attention(key, cfg):
    return init_attention(key, cfg)


def cross_attention(params, x, enc_k, enc_v, cfg, ctx=None):
    """x [B,T,d] attends over precomputed encoder K/V [B,S,K,hd] (no mask)."""
    dt = x.dtype
    B, T, _ = x.shape
    q = jnp.einsum("btd,dhk->bthk", x, params["wq"].astype(dt))
    o = chunked_attention(q, enc_k, enc_v, causal=False,
                          q_chunk=min(1024, T), k_chunk=min(1024, enc_k.shape[1]))
    return _merge_heads(params, o, dt)


def encode_kv(params, enc_out, cfg):
    """Precompute cross-attention K/V from encoder output (no RoPE)."""
    dt = enc_out.dtype
    k = jnp.einsum("bsd,dhk->bshk", enc_out, params["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", enc_out, params["wv"].astype(dt))
    return k, v
