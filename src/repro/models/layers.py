"""Shared model building blocks (pure JAX, explicit param pytrees).

Convention: every ``init_*`` returns ``(params, specs)`` where ``specs``
mirrors ``params`` with tuples of logical axis names per array dimension.
The distributed layer (:mod:`repro.distributed.sharding`) resolves those
against a mesh.  Compute dtype is bf16; params are stored f32 (single master
copy) and cast at use.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

COMPUTE_DTYPE = jnp.bfloat16
PARAM_DTYPE = jnp.float32


def _init_normal(key, shape, scale):
    return (jax.random.normal(key, shape, PARAM_DTYPE) * scale).astype(PARAM_DTYPE)


def dense_init(key, d_in: int, d_out: int, axes: Tuple[str, ...],
               scale: Optional[float] = None):
    scale = scale if scale is not None else 1.0 / np.sqrt(d_in)
    return _init_normal(key, (d_in, d_out), scale), axes


def rms_norm(x, gamma, eps: float):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * (1.0 + gamma.astype(jnp.float32))
    return out.astype(x.dtype)


def layer_norm(x, gamma, beta, eps: float):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps) * gamma + beta
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float):
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))


def apply_rope(x, positions, theta: float):
    """x [..., T, H, D]; positions [..., T] int32 (broadcastable)."""
    half = x.shape[-1] // 2
    freqs = rope_freqs(x.shape[-1], theta)                    # [half]
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # [..., T, half]
    cos = jnp.cos(angles)[..., :, None, :]                    # [..., T, 1, half]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP variants
# ---------------------------------------------------------------------------
def init_mlp(key, d_model: int, d_ff: int, variant: str):
    k1, k2, k3 = jax.random.split(key, 3)
    if variant in ("swiglu", "geglu"):
        params = {
            "w_gate": _init_normal(k1, (d_model, d_ff), 1.0 / np.sqrt(d_model)),
            "w_up": _init_normal(k2, (d_model, d_ff), 1.0 / np.sqrt(d_model)),
            "w_down": _init_normal(k3, (d_ff, d_model), 1.0 / np.sqrt(d_ff)),
        }
        specs = {
            "w_gate": ("fsdp", "mlp"),
            "w_up": ("fsdp", "mlp"),
            "w_down": ("mlp", "fsdp"),
        }
    else:  # plain gelu
        params = {
            "w_up": _init_normal(k1, (d_model, d_ff), 1.0 / np.sqrt(d_model)),
            "w_down": _init_normal(k2, (d_ff, d_model), 1.0 / np.sqrt(d_ff)),
        }
        specs = {"w_up": ("fsdp", "mlp"), "w_down": ("mlp", "fsdp")}
    return params, specs


def apply_mlp(params, x, variant: str, ctx=None):
    dt = x.dtype
    if variant in ("swiglu", "geglu"):
        g = x @ params["w_gate"].astype(dt)
        u = x @ params["w_up"].astype(dt)
        if ctx is not None:
            g = ctx.c(g, ("batch", "seq", "mlp"))
            u = ctx.c(u, ("batch", "seq", "mlp"))
        act = jax.nn.silu(g) if variant == "swiglu" else jax.nn.gelu(g)
        h = act * u
        out = h @ params["w_down"].astype(dt)
    else:
        h = jax.nn.gelu(x @ params["w_up"].astype(dt))
        if ctx is not None:
            h = ctx.c(h, ("batch", "seq", "mlp"))
        out = h @ params["w_down"].astype(dt)
    if ctx is not None:
        out = ctx.c(out, ("batch", "seq", "embed"))
    return out


# ---------------------------------------------------------------------------
# Embedding + LM head + loss
# ---------------------------------------------------------------------------
def init_embedding(key, vocab: int, d_model: int):
    return _init_normal(key, (vocab, d_model), 1.0), ("vocab", "embed")


def embed(table, tokens, ctx=None):
    out = jnp.take(table.astype(COMPUTE_DTYPE), tokens, axis=0)
    if ctx is not None:
        out = ctx.c(out, ("batch", "seq", "embed"))
    return out


def lm_logits(x, table_or_head, ctx=None):
    """x [B,T,d] @ head [d,V] (or embedding.T when tied)."""
    w = table_or_head.astype(x.dtype)
    if w.shape[0] != x.shape[-1]:       # tied embedding [V, d] -> transpose
        w = w.T
    out = x @ w
    if ctx is not None:
        out = ctx.c(out, ("batch", "seq", "vocab"))
    return out


def cross_entropy(logits, labels, mask=None, z_loss: float = 1e-4):
    """Vocab-sharding-friendly CE: no one-hot materialization.

    logits [B,T,V] (any float dtype), labels [B,T] int32, mask [B,T] or None.
    The correct-class logit is extracted with an iota-compare-select-reduce,
    which XLA fuses into the logsumexp traversal (works with V sharded).
    """
    lg = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lg, axis=-1)                       # [B,T]
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, lg.shape, len(lg.shape) - 1)
    correct = jnp.sum(jnp.where(vocab_iota == labels[..., None], lg, 0.0), axis=-1)
    nll = lse - correct
    if z_loss:
        nll = nll + z_loss * lse ** 2                          # stabilizer
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
