"""Unified model: one class covering all 10 assigned architecture families.

API (everything returns/consumes explicit pytrees; no framework magic):
  * ``init(rng) -> (params, specs)``           specs = logical-axes pytrees
  * ``forward(params, batch, ctx) -> logits``  training / prefill pass
  * ``loss(params, batch, ctx) -> (scalar, aux)``
  * ``init_decode_state(batch, max_seq) -> (state, specs)``
  * ``decode_step(params, tokens, state, ctx) -> (logits, state)``

Layer stacks are homogeneous ``lax.scan``s over stacked parameters (single
layer trace => 398B Jamba lowers/compiles on 512 devices in minutes, not
hours) with full-block ``jax.checkpoint`` remat.  The hybrid (Jamba) stack
scans over 8-layer *groups* (7 mamba + 1 attention, MoE every other FFN);
encoder-decoder runs two stacks; VLM prepends stub patch embeddings.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from . import attention as attn
from . import moe as moe_mod
from . import ssm
from .layers import (COMPUTE_DTYPE, _init_normal, apply_mlp, cross_entropy,
                     embed, init_embedding, init_mlp, lm_logits, rms_norm)


def _stacked_init(init_fn, key, n: int):
    """vmap an init over n layer seeds -> stacked params + (shared) specs."""
    keys = jax.random.split(key, n)
    params = jax.vmap(lambda k: init_fn(k)[0])(keys)
    _, specs = init_fn(key)
    specs = jax.tree.map(lambda ax: ("layers",) + tuple(ax), specs,
                         is_leaf=lambda x: isinstance(x, tuple) and all(
                             isinstance(e, (str, type(None))) for e in x))
    return params, specs


def _norm_init():
    return None  # placeholder; norms are created inline


class Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # ------------------------------------------------------------------ init
    def init(self, rng) -> Tuple[Dict, Dict]:
        cfg = self.cfg
        keys = jax.random.split(rng, 8)
        params: Dict[str, Any] = {}
        specs: Dict[str, Any] = {}

        params["embed"], specs["embed"] = init_embedding(
            keys[0], cfg.vocab_size, cfg.d_model)
        if not cfg.tie_embeddings:
            params["lm_head"] = _init_normal(
                keys[1], (cfg.d_model, cfg.vocab_size), 1.0 / np.sqrt(cfg.d_model))
            specs["lm_head"] = ("embed", "vocab")
        params["final_norm"] = jnp.zeros((cfg.d_model,), jnp.float32)
        specs["final_norm"] = ("embed",)

        if cfg.family in ("dense", "moe", "vlm"):
            params["layers"], specs["layers"] = _stacked_init(
                lambda k: self._init_block(k), keys[2], cfg.num_layers)
            if cfg.family == "vlm":
                params["patch_proj"] = _init_normal(
                    keys[3], (cfg.d_model, cfg.d_model), 1.0 / np.sqrt(cfg.d_model))
                specs["patch_proj"] = ("embed", "embed")
        elif cfg.family == "ssm":
            params["layers"], specs["layers"] = _stacked_init(
                lambda k: self._init_rwkv_block(k), keys[2], cfg.num_layers)
        elif cfg.family == "hybrid":
            n_groups = cfg.num_layers // cfg.hybrid_group
            params["groups"], specs["groups"] = _stacked_init(
                lambda k: self._init_hybrid_group(k), keys[2], n_groups)
        elif cfg.family == "encdec":
            params["frame_proj"] = _init_normal(
                keys[3], (cfg.encoder_d_model, cfg.d_model),
                1.0 / np.sqrt(cfg.encoder_d_model))
            specs["frame_proj"] = (None, "embed")
            params["enc_layers"], specs["enc_layers"] = _stacked_init(
                lambda k: self._init_enc_block(k), keys[4], cfg.encoder_layers)
            params["enc_norm"] = jnp.zeros((cfg.d_model,), jnp.float32)
            specs["enc_norm"] = ("embed",)
            params["layers"], specs["layers"] = _stacked_init(
                lambda k: self._init_dec_block(k), keys[5], cfg.num_layers)
        else:
            raise ValueError(cfg.family)
        return params, specs

    # block initializers ------------------------------------------------
    def _init_block(self, key):
        cfg = self.cfg
        k1, k2 = jax.random.split(key)
        a_params, a_specs = attn.init_attention(k1, cfg)
        p = {"attn": a_params,
             "norm1": jnp.zeros((cfg.d_model,), jnp.float32),
             "norm2": jnp.zeros((cfg.d_model,), jnp.float32)}
        s = {"attn": a_specs, "norm1": ("embed",), "norm2": ("embed",)}
        # Homogeneous scan stacks => MoE-every-layer for the moe family
        # (interleaved MoE lives in the hybrid group path).
        if cfg.num_experts and cfg.moe_every == 1:
            p["moe"], s["moe"] = moe_mod.init_moe(k2, cfg)
        else:
            p["mlp"], s["mlp"] = init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.mlp_variant)
        return p, s

    def _init_rwkv_block(self, key):
        k1, k2 = jax.random.split(key)
        tm, tm_s = ssm.init_rwkv_time_mix(k1, self.cfg)
        cm, cm_s = ssm.init_rwkv_channel_mix(k2, self.cfg)
        d = self.cfg.d_model
        p = {"tm": tm, "cm": cm,
             "norm1": jnp.zeros((d,), jnp.float32),
             "norm2": jnp.zeros((d,), jnp.float32)}
        s = {"tm": tm_s, "cm": cm_s, "norm1": ("embed",), "norm2": ("embed",)}
        return p, s

    def _init_hybrid_group(self, key):
        cfg = self.cfg
        p, s = {}, {}
        keys = jax.random.split(key, 2 * cfg.hybrid_group)
        for i in range(cfg.hybrid_group):
            if i == cfg.hybrid_attn_index:
                p[f"mixer_{i}"], s[f"mixer_{i}"] = attn.init_attention(keys[2 * i], cfg)
            else:
                p[f"mixer_{i}"], s[f"mixer_{i}"] = ssm.init_mamba(keys[2 * i], cfg)
            if cfg.num_experts and i % cfg.moe_every == cfg.moe_offset:
                p[f"ffn_{i}"], s[f"ffn_{i}"] = moe_mod.init_moe(keys[2 * i + 1], cfg)
            else:
                p[f"ffn_{i}"], s[f"ffn_{i}"] = init_mlp(
                    keys[2 * i + 1], cfg.d_model, cfg.d_ff, cfg.mlp_variant)
            p[f"norm_a_{i}"] = jnp.zeros((cfg.d_model,), jnp.float32)
            p[f"norm_b_{i}"] = jnp.zeros((cfg.d_model,), jnp.float32)
            s[f"norm_a_{i}"] = ("embed",)
            s[f"norm_b_{i}"] = ("embed",)
        return p, s

    def _init_enc_block(self, key):
        cfg = self.cfg
        k1, k2 = jax.random.split(key)
        a, a_s = attn.init_attention(k1, cfg)
        m, m_s = init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.mlp_variant)
        p = {"attn": a, "mlp": m,
             "norm1": jnp.zeros((cfg.d_model,), jnp.float32),
             "norm2": jnp.zeros((cfg.d_model,), jnp.float32)}
        s = {"attn": a_s, "mlp": m_s, "norm1": ("embed",), "norm2": ("embed",)}
        return p, s

    def _init_dec_block(self, key):
        cfg = self.cfg
        k1, k2, k3 = jax.random.split(key, 3)
        a, a_s = attn.init_attention(k1, cfg)
        x, x_s = attn.init_cross_attention(k2, cfg)
        m, m_s = init_mlp(k3, cfg.d_model, cfg.d_ff, cfg.mlp_variant)
        p = {"attn": a, "cross": x, "mlp": m,
             "norm1": jnp.zeros((cfg.d_model,), jnp.float32),
             "norm2": jnp.zeros((cfg.d_model,), jnp.float32),
             "norm3": jnp.zeros((cfg.d_model,), jnp.float32)}
        s = {"attn": a_s, "cross": x_s, "mlp": m_s,
             "norm1": ("embed",), "norm2": ("embed",), "norm3": ("embed",)}
        return p, s

    # --------------------------------------------------------------- forward
    def forward(self, params, batch, ctx=None, q_chunk=1024, k_chunk=1024):
        cfg = self.cfg
        tokens = batch["tokens"]
        x = embed(params["embed"], tokens, ctx)
        if cfg.family == "vlm":
            patches = batch["patches"].astype(x.dtype) @ \
                params["patch_proj"].astype(x.dtype)
            x = jnp.concatenate([patches, x], axis=1)
            if ctx is not None:
                x = ctx.c(x, ("batch", "seq", "embed"))

        aux_total = jnp.zeros((), jnp.float32)
        if cfg.family in ("dense", "moe", "vlm"):
            x, aux_total = self._stack_forward(params["layers"], x, ctx,
                                               q_chunk, k_chunk)
        elif cfg.family == "ssm":
            x = self._rwkv_forward(params["layers"], x, ctx)
        elif cfg.family == "hybrid":
            x, aux_total = self._hybrid_forward(params["groups"], x, ctx,
                                                q_chunk, k_chunk)
        elif cfg.family == "encdec":
            enc = self._encode(params, batch["frames"], ctx)
            x = self._decode_stack(params["layers"], x, enc, ctx, q_chunk, k_chunk)

        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
        logits = lm_logits(x, head, ctx)
        return logits, aux_total

    def _stack_forward(self, layers, x, ctx, q_chunk, k_chunk):
        cfg = self.cfg

        def block(x, layer):
            h = rms_norm(x, layer["norm1"], cfg.norm_eps)
            x = x + attn.attention_block(layer["attn"], h, cfg, ctx,
                                         q_chunk=q_chunk, k_chunk=k_chunk)
            h = rms_norm(x, layer["norm2"], cfg.norm_eps)
            if "moe" in layer:
                B, T, d = h.shape
                y, aux = moe_mod.moe_ffn(layer["moe"], h.reshape(B * T, d), cfg, ctx)
                y = y.reshape(B, T, d)
            else:
                y, aux = apply_mlp(layer["mlp"], h, cfg.mlp_variant, ctx), 0.0
            return x + y, jnp.asarray(aux, jnp.float32)

        block = jax.checkpoint(block, prevent_cse=False)

        def scan_body(x, layer):
            return block(x, layer)

        x, auxs = jax.lax.scan(scan_body, x, layers)
        return x, jnp.sum(auxs)

    def _rwkv_forward(self, layers, x, ctx):
        cfg = self.cfg
        B = x.shape[0]

        def block(x, layer):
            wkv0, xtm0, xcm0 = ssm.init_rwkv_state(cfg, B)
            h = rms_norm(x, layer["norm1"], cfg.norm_eps)
            o, _, _ = ssm.rwkv_time_mix(layer["tm"], h, xtm0.astype(h.dtype), wkv0)
            x = x + o
            h = rms_norm(x, layer["norm2"], cfg.norm_eps)
            o, _ = ssm.rwkv_channel_mix(layer["cm"], h, xcm0.astype(h.dtype))
            return x + o, None

        block = jax.checkpoint(block, prevent_cse=False)
        x, _ = jax.lax.scan(block, x, layers)
        return x

    def _hybrid_forward(self, groups, x, ctx, q_chunk, k_chunk):
        cfg = self.cfg
        B = x.shape[0]

        def group_block(x, g):
            aux_sum = jnp.zeros((), jnp.float32)
            for i in range(cfg.hybrid_group):
                h = rms_norm(x, g[f"norm_a_{i}"], cfg.norm_eps)
                if i == cfg.hybrid_attn_index:
                    o = attn.attention_block(g[f"mixer_{i}"], h, cfg, ctx,
                                             q_chunk=q_chunk, k_chunk=k_chunk)
                else:
                    st, tail = ssm.init_mamba_state(cfg, B)
                    o, _, _ = ssm.mamba_block(g[f"mixer_{i}"], h, tail, st)
                x = x + o
                h = rms_norm(x, g[f"norm_b_{i}"], cfg.norm_eps)
                if cfg.num_experts and i % cfg.moe_every == cfg.moe_offset:
                    Bx, T, d = h.shape
                    y, aux = moe_mod.moe_ffn(g[f"ffn_{i}"], h.reshape(Bx * T, d),
                                             cfg, ctx)
                    y = y.reshape(Bx, T, d)
                    aux_sum = aux_sum + aux
                else:
                    y = apply_mlp(g[f"ffn_{i}"], h, cfg.mlp_variant, ctx)
                x = x + y
            return x, aux_sum

        group_block = jax.checkpoint(group_block, prevent_cse=False)
        x, auxs = jax.lax.scan(group_block, x, groups)
        return x, jnp.sum(auxs)

    def _encode(self, params, frames, ctx):
        cfg = self.cfg
        x = frames.astype(COMPUTE_DTYPE) @ params["frame_proj"].astype(COMPUTE_DTYPE)
        S = x.shape[1]

        def block(x, layer):
            h = rms_norm(x, layer["norm1"], cfg.norm_eps)
            pos = jnp.arange(S)[None, :]
            q, k, v = attn._project_qkv(layer["attn"], h, cfg, pos)
            o = attn.chunked_attention(q, k, v, causal=False,
                                       q_chunk=min(1024, S), k_chunk=min(1024, S))
            x = x + attn._merge_heads(layer["attn"], o, h.dtype)
            h = rms_norm(x, layer["norm2"], cfg.norm_eps)
            return x + apply_mlp(layer["mlp"], h, cfg.mlp_variant, ctx), None

        block = jax.checkpoint(block, prevent_cse=False)
        x, _ = jax.lax.scan(block, x, params["enc_layers"])
        return rms_norm(x, params["enc_norm"], cfg.norm_eps)

    def _decode_stack(self, layers, x, enc, ctx, q_chunk, k_chunk):
        cfg = self.cfg

        def block(x, layer):
            h = rms_norm(x, layer["norm1"], cfg.norm_eps)
            x = x + attn.attention_block(layer["attn"], h, cfg, ctx,
                                         q_chunk=q_chunk, k_chunk=k_chunk)
            h = rms_norm(x, layer["norm2"], cfg.norm_eps)
            ek, ev = attn.encode_kv(layer["cross"], enc, cfg)
            x = x + attn.cross_attention(layer["cross"], h, ek, ev, cfg, ctx)
            h = rms_norm(x, layer["norm3"], cfg.norm_eps)
            return x + apply_mlp(layer["mlp"], h, cfg.mlp_variant, ctx), None

        block = jax.checkpoint(block, prevent_cse=False)
        x, _ = jax.lax.scan(block, x, layers)
        return x

    # ----------------------------------------------------------------- loss
    def loss(self, params, batch, ctx=None, q_chunk=1024, k_chunk=1024,
             aux_weight: float = 0.01):
        logits, aux = self.forward(params, batch, ctx, q_chunk, k_chunk)
        labels = batch["labels"]
        if self.cfg.family == "vlm":            # loss only on text positions
            logits = logits[:, self.cfg.num_patches:]
        ce = cross_entropy(logits, labels, batch.get("mask"))
        return ce + aux_weight * aux, {"ce": ce, "aux": aux}

    # --------------------------------------------------------------- decode
    def init_decode_state(self, batch: int, max_seq: int):
        cfg = self.cfg
        state: Dict[str, Any] = {"pos": jnp.zeros((), jnp.int32)}
        specs: Dict[str, Any] = {"pos": ()}
        layout = attn.cache_layout(cfg, max_seq)
        self._layout = layout
        if cfg.family in ("dense", "moe", "vlm"):
            kv, kv_specs = attn.init_kv_cache(cfg, cfg.num_layers, batch, layout)
            state["kv"], specs["kv"] = kv, kv_specs
            state["slot_pos"] = jnp.full((layout.size,), -1, jnp.int32)
            specs["slot_pos"] = ("cache_seq",)
        elif cfg.family == "ssm":
            L, B = cfg.num_layers, batch
            H, hd, d = cfg.num_heads, cfg.head_dim, cfg.d_model
            state["wkv"] = jnp.zeros((L, B, H, hd, hd), jnp.float32)
            state["x_tm"] = jnp.zeros((L, B, 1, d), jnp.float32)
            state["x_cm"] = jnp.zeros((L, B, 1, d), jnp.float32)
            specs["wkv"] = ("layers", "batch", "heads", "head_dim", None)
            specs["x_tm"] = ("layers", "batch", None, "embed")
            specs["x_cm"] = ("layers", "batch", None, "embed")
        elif cfg.family == "hybrid":
            G = cfg.num_layers // cfg.hybrid_group
            M = cfg.hybrid_group - 1                  # mamba layers per group
            di = cfg.ssm_expand * cfg.d_model
            kv, kv_specs = attn.init_kv_cache(cfg, G, batch, layout)
            state["kv"], specs["kv"] = kv, kv_specs
            state["slot_pos"] = jnp.full((layout.size,), -1, jnp.int32)
            specs["slot_pos"] = ("cache_seq",)
            state["mamba_h"] = jnp.zeros((G, M, batch, di, cfg.ssm_state),
                                         jnp.float32)
            state["conv_tail"] = jnp.zeros((G, M, batch, cfg.ssm_conv - 1, di),
                                           jnp.float32)
            specs["mamba_h"] = ("groups", None, "batch", "ssm_inner", "ssm_state")
            specs["conv_tail"] = ("groups", None, "batch", "conv", "ssm_inner")
        elif cfg.family == "encdec":
            kv, kv_specs = attn.init_kv_cache(cfg, cfg.num_layers, batch, layout)
            state["kv"], specs["kv"] = kv, kv_specs
            state["slot_pos"] = jnp.full((layout.size,), -1, jnp.int32)
            specs["slot_pos"] = ("cache_seq",)
            K, hd = cfg.num_kv_heads, cfg.head_dim
            state["cross_k"] = jnp.zeros(
                (cfg.num_layers, batch, cfg.encoder_seq, K, hd), COMPUTE_DTYPE)
            state["cross_v"] = jnp.zeros_like(state["cross_k"])
            specs["cross_k"] = ("layers", "batch", "seq", "kv_heads", "head_dim")
            specs["cross_v"] = specs["cross_k"]
        return state, specs

    def decode_step(self, params, tokens, state, ctx=None, max_seq: int = 0):
        cfg = self.cfg
        pos = state["pos"]
        x = embed(params["embed"], tokens, None)
        if ctx is not None:
            x = ctx.c(x, ("batch", None, "embed"))
        layout = getattr(self, "_layout", None)
        if layout is None:
            if "slot_pos" in state:
                size = int(state["slot_pos"].shape[0])
                layout = attn.CacheLayout(
                    size=size,
                    windowed=bool(cfg.sliding_window) and size == cfg.sliding_window)
            else:
                layout = attn.cache_layout(cfg, max_seq)
        new_state = dict(state)

        if cfg.family in ("dense", "moe", "vlm", "encdec"):
            slot = pos % layout.size if layout.windowed else pos
            slot_pos = state["slot_pos"].at[slot].set(pos)
            new_state["slot_pos"] = slot_pos

            def block(x, inputs):
                if cfg.family == "encdec":
                    layer, kc, vc, ck, cv = inputs
                else:
                    layer, kc, vc = inputs
                h = rms_norm(x, layer["norm1"], cfg.norm_eps)
                o, kc, vc = attn.decode_attention(
                    layer["attn"], h, cfg, kc, vc, slot_pos, pos, layout, ctx)
                x = x + o
                if cfg.family == "encdec":
                    h = rms_norm(x, layer["norm2"], cfg.norm_eps)
                    x = x + attn.cross_attention(layer["cross"], h, ck, cv, cfg)
                    h = rms_norm(x, layer["norm3"], cfg.norm_eps)
                    x = x + apply_mlp(layer["mlp"], h, cfg.mlp_variant, None)
                else:
                    h = rms_norm(x, layer["norm2"], cfg.norm_eps)
                    if "moe" in layer:
                        B = h.shape[0]
                        y, _ = moe_mod.moe_ffn(layer["moe"],
                                               h.reshape(B, cfg.d_model), cfg, ctx)
                        x = x + y.reshape(B, 1, cfg.d_model)
                    else:
                        x = x + apply_mlp(layer["mlp"], h, cfg.mlp_variant, None)
                return x, (kc, vc)

            if cfg.family == "encdec":
                xs = (params["layers"], state["kv"]["k"], state["kv"]["v"],
                      state["cross_k"], state["cross_v"])
            else:
                xs = (params["layers"], state["kv"]["k"], state["kv"]["v"])
            x, (k_new, v_new) = jax.lax.scan(block, x, xs)
            new_state["kv"] = {"k": k_new, "v": v_new}

        elif cfg.family == "ssm":
            def block(x, inputs):
                layer, wkv, xtm, xcm = inputs
                h = rms_norm(x, layer["norm1"], cfg.norm_eps)
                o, xtm_new, wkv = ssm.rwkv_time_mix(
                    layer["tm"], h, xtm.astype(h.dtype), wkv)
                x = x + o
                h = rms_norm(x, layer["norm2"], cfg.norm_eps)
                o, xcm_new = ssm.rwkv_channel_mix(layer["cm"], h, xcm.astype(h.dtype))
                x = x + o
                return x, (wkv, xtm_new.astype(jnp.float32),
                           xcm_new.astype(jnp.float32))

            x, (wkv, xtm, xcm) = jax.lax.scan(
                block, x, (params["layers"], state["wkv"], state["x_tm"],
                           state["x_cm"]))
            new_state.update({"wkv": wkv, "x_tm": xtm, "x_cm": xcm})

        elif cfg.family == "hybrid":
            slot = pos % layout.size if layout.windowed else pos
            slot_pos = state["slot_pos"].at[slot].set(pos)
            new_state["slot_pos"] = slot_pos

            def group_block(x, inputs):
                g, kc, vc, mh, tails = inputs
                mi = 0
                new_mh, new_tails = [], []
                for i in range(cfg.hybrid_group):
                    h = rms_norm(x, g[f"norm_a_{i}"], cfg.norm_eps)
                    if i == cfg.hybrid_attn_index:
                        o, kc, vc = attn.decode_attention(
                            g[f"mixer_{i}"], h, cfg, kc, vc, slot_pos, pos,
                            layout, ctx)
                    else:
                        o, tail, hst = ssm.mamba_block(
                            g[f"mixer_{i}"], h, tails[mi], mh[mi])
                        new_mh.append(hst)
                        new_tails.append(tail.astype(jnp.float32))
                        mi += 1
                    x = x + o
                    h = rms_norm(x, g[f"norm_b_{i}"], cfg.norm_eps)
                    if cfg.num_experts and i % cfg.moe_every == cfg.moe_offset:
                        B = h.shape[0]
                        y, _ = moe_mod.moe_ffn(g[f"ffn_{i}"],
                                               h.reshape(B, cfg.d_model), cfg, ctx)
                        x = x + y.reshape(B, 1, cfg.d_model)
                    else:
                        x = x + apply_mlp(g[f"ffn_{i}"], h, cfg.mlp_variant, None)
                return x, (kc, vc, jnp.stack(new_mh), jnp.stack(new_tails))

            x, (k_new, v_new, mh, tails) = jax.lax.scan(
                group_block, x,
                (params["groups"], state["kv"]["k"], state["kv"]["v"],
                 state["mamba_h"], state["conv_tail"]))
            new_state["kv"] = {"k": k_new, "v": v_new}
            new_state["mamba_h"] = mh
            new_state["conv_tail"] = tails

        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
        logits = lm_logits(x, head, None)
        new_state["pos"] = pos + 1
        return logits, new_state
