"""Distributed runtime: logical-axis sharding + mesh helpers."""
from .sharding import (DEFAULT_RULES, ShardingCtx, constrain, corpus_axis,
                       make_rules, rules_for_cell, sharding_for, spec_for,
                       tree_shardings)

__all__ = ["DEFAULT_RULES", "ShardingCtx", "constrain", "corpus_axis",
           "make_rules", "rules_for_cell", "sharding_for", "spec_for",
           "tree_shardings"]
