"""Logical-axis sharding: the single place where parallelism layout lives.

Every parameter / activation / cache leaf in the model stack is annotated
with a tuple of *logical* axis names ("embed", "heads", "vocab", ...).  A
rule table maps logical names to mesh axes; :func:`spec_for` resolves a
leaf's logical axes against a concrete mesh, **dropping any mapping whose
dimension is not divisible by the mesh-axis size** (e.g. 8 KV heads cannot
shard over a 16-way model axis => replicate).  This mirrors the MaxText
mechanism: re-sharding experiments are pure rule edits, which is exactly the
knob the §Perf hillclimb turns.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MeshAxes = Union[None, str, Tuple[str, ...]]

# Default production rules for the (pod, data, model) mesh.
DEFAULT_RULES: Dict[str, MeshAxes] = {
    "batch": ("pod", "data"),     # data parallel over pod x data
    "seq": None,                  # sequence replicated (overridden for long ctx)
    "embed": None,
    "fsdp": ("pod", "data"),      # parameter dim sharded FSDP-style
    "vocab": "model",
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "mlp": "model",               # FFN hidden dim tensor-parallel
    "experts": "model",           # expert parallel
    "expert_mlp": "model",        # TP fallback: when E < model size the expert
                                  # dim drops and the per-expert FFN dim takes
                                  # the model axis instead (Mixtral: 8e < 16)
    "tokens": ("pod", "data"),    # flattened token dim in MoE dispatch
    "capacity": ("pod", "data"),  # expert capacity dim (token-derived)
    "layers": None,
    "groups": None,
    "cache_seq": None,            # KV-cache sequence dim (decode override)
    "ssm_inner": "model",         # mamba d_inner / rwkv heads
    "ssm_state": None,
    "conv": None,
    "dt_rank": None,
    "stats": None,
    "corpus": "data",             # sketch-store corpus rows (dataset search):
                                  # queries replicate, corpus rows shard
}


def corpus_axis(mesh: Optional[Mesh], rules: Optional[Dict[str, MeshAxes]] = None
                ) -> Optional[str]:
    """The mesh axis carrying the logical ``"corpus"`` (sketch-store row)
    dim, or ``None`` when unmapped / absent / size 1 (single-device path).

    Sharded corpus-query execution (``repro.kernels.ops.*_sharded``) keys
    off this: a ``None`` means run the plain single-launch path.
    """
    if mesh is None:
        return None
    mapped = (rules or DEFAULT_RULES).get("corpus")
    if mapped is None:
        return None
    axes = (mapped,) if isinstance(mapped, str) else tuple(mapped)
    for a in axes:
        if mesh.shape.get(a, 1) > 1:
            return a
    return None


def axis_size(mesh: Mesh, axes: MeshAxes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    size = 1
    for a in axes:
        size *= mesh.shape.get(a, 1)
    return size


def spec_for(shape: Sequence[int], logical: Sequence[Optional[str]],
             rules: Dict[str, MeshAxes], mesh: Mesh) -> P:
    """Resolve logical axes -> PartitionSpec with divisibility fallback."""
    assert len(shape) == len(logical), (shape, logical)
    parts = []
    used: set = set()
    for dim, name in zip(shape, logical):
        mapped = rules.get(name) if name else None
        if mapped is None:
            parts.append(None)
            continue
        axes = (mapped,) if isinstance(mapped, str) else tuple(mapped)
        # drop axes missing from this mesh (e.g. "pod" on the single-pod mesh)
        # or already used by an earlier dim, then check divisibility
        axes = tuple(a for a in axes if a in mesh.shape and a not in used)
        if not axes or dim % axis_size(mesh, axes) != 0:
            parts.append(None)
            continue
        used.update(axes)
        parts.append(axes[0] if len(axes) == 1 else axes)
    return P(*parts)


def sharding_for(shape, logical, rules, mesh) -> NamedSharding:
    return NamedSharding(mesh, spec_for(shape, logical, rules, mesh))


def tree_shardings(tree_shapes, tree_logical, rules, mesh):
    """Map (shapes pytree, logical-axes pytree) -> NamedSharding pytree."""
    return jax.tree.map(
        lambda s, l: sharding_for(s.shape, l, rules, mesh),
        tree_shapes, tree_logical,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))


def constrain(x, logical, rules, mesh):
    """with_sharding_constraint by logical axes (no-op outside a mesh ctx)."""
    return jax.lax.with_sharding_constraint(
        x, sharding_for(x.shape, logical, rules, mesh))


@dataclasses.dataclass(frozen=True)
class ShardingCtx:
    """Threaded through model code so every constraint is rule-driven."""
    mesh: Mesh
    rules: Dict[str, MeshAxes]

    def c(self, x, logical):
        return constrain(x, logical, self.rules, self.mesh)

    def spec(self, shape, logical) -> P:
        return spec_for(shape, logical, self.rules, self.mesh)

    def sharding(self, shape, logical) -> NamedSharding:
        return sharding_for(shape, logical, self.rules, self.mesh)


def make_rules(**overrides) -> Dict[str, MeshAxes]:
    rules = dict(DEFAULT_RULES)
    rules.update(overrides)
    return rules


def rules_for_cell(cfg, shape_cfg, mesh, base: Optional[Dict[str, MeshAxes]] = None
                   ) -> Dict[str, MeshAxes]:
    """Per-(arch x shape) rule adaptation.

    * decode with KV heads not divisible by the model axis: shard the cache
      over its sequence dim instead (keeps 32k/500k caches inside HBM).
    * batch smaller than pod*data (e.g. long_500k batch=1): spec_for's
      divisibility fallback already replicates; shard seq over data instead
      so prefill/long-context work still spreads.
    """
    rules = dict(base or DEFAULT_RULES)
    model_size = mesh.shape.get("model", 1)
    if shape_cfg.kind == "decode":
        if cfg.num_kv_heads % model_size != 0:
            rules["cache_seq"] = "model"
    if shape_cfg.kind in ("prefill", "decode"):
        dp = axis_size(mesh, rules.get("batch"))
        if shape_cfg.global_batch % max(dp, 1) != 0 or shape_cfg.global_batch < dp:
            rules["seq"] = "data"
            rules["cache_seq"] = ("data", "model") if cfg.num_kv_heads % model_size else "data"
    return rules
