"""Batched serving engine: continuous batching over a decode step.

Requests (prompt token lists) are admitted into a fixed-size slot batch;
every engine tick runs one decode step for all active slots; finished
slots (EOS or max_tokens) retire and free capacity for queued requests.
Prefill is performed by stepping the prompt tokens through the decode path
(exactly correct w.r.t. the KV cache; a chunked-prefill fast path is the
documented production upgrade).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import Model


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new_tokens: int = 16
    eos: Optional[int] = None
    output: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, model: Model, params, batch_slots: int = 4,
                 max_seq: int = 256):
        self.model = model
        self.params = params
        self.slots = batch_slots
        self.max_seq = max_seq
        self.state, _ = model.init_decode_state(batch_slots, max_seq)
        self._queue: deque = deque()
        self._active: Dict[int, Request] = {}       # slot -> request
        self._slot_pos = np.zeros(batch_slots, np.int64)  # per-slot progress
        self._pending_prompt: Dict[int, deque] = {}
        self._step = jax.jit(lambda p, t, s: self.model.decode_step(p, t, s))

    def submit(self, req: Request):
        self._queue.append(req)

    def _admit(self):
        for slot in range(self.slots):
            if slot not in self._active and self._queue:
                req = self._queue.popleft()
                self._active[slot] = req
                self._pending_prompt[slot] = deque(req.prompt)

    def tick(self) -> int:
        """One decode step for the whole batch.  Returns #active slots.

        NOTE: the shared-pos decode step advances one global position per
        tick; slots therefore progress in lockstep, with idle slots fed a
        pad token and their outputs discarded (standard static-batch decode;
        per-slot position tracking is the continuous-batching upgrade).
        """
        self._admit()
        if not self._active:
            return 0
        toks = np.zeros((self.slots, 1), np.int32)
        for slot, req in self._active.items():
            pend = self._pending_prompt.get(slot)
            if pend:
                toks[slot, 0] = pend.popleft()
            elif req.output:
                toks[slot, 0] = req.output[-1]
            elif req.prompt:
                toks[slot, 0] = req.prompt[-1]
        logits, self.state = self._step(self.params, jnp.asarray(toks),
                                        self.state)
        nxt = np.asarray(jnp.argmax(logits[:, -1], axis=-1))
        for slot, req in list(self._active.items()):
            if self._pending_prompt.get(slot):
                continue                       # still prefilling this slot
            req.output.append(int(nxt[slot]))
            hit_eos = req.eos is not None and int(nxt[slot]) == req.eos
            if hit_eos or len(req.output) >= req.max_new_tokens:
                req.done = True
                del self._active[slot]
                self._pending_prompt.pop(slot, None)
        return len(self._active)

    def run_until_drained(self, max_ticks: int = 10_000) -> List[Request]:
        finished: List[Request] = []
        seen = set()
        for _ in range(max_ticks):
            self.tick()
            if not self._active and not self._queue:
                break
        return finished
