"""Serving steps: prefill (parallel forward) and single-token decode."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.distributed.sharding import ShardingCtx


def make_prefill_step(model, ctx: Optional[ShardingCtx] = None,
                      q_chunk: int = 1024, k_chunk: int = 1024):
    """prefill(params, batch) -> logits [B, T, V].

    Prefill lowers the full-sequence forward (chunked attention => bounded
    memory at 32k).  Cache population for subsequent decode reuses the same
    kernels; the serving driver (repro.serve.engine) wires the two together.
    """
    def prefill(params, batch):
        logits, _ = model.forward(params, batch, ctx,
                                  q_chunk=q_chunk, k_chunk=k_chunk)
        return logits
    return prefill


def make_decode_step(model, ctx: Optional[ShardingCtx] = None):
    """decode(params, tokens [B,1], state) -> (logits [B,1,V], state)."""
    def decode(params, tokens, state):
        return model.decode_step(params, tokens, state, ctx)
    return decode


def greedy_sample(logits):
    return jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
