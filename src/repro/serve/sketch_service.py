"""Serving front-end for corpus-scale dataset search.

Wraps :class:`repro.data.DatasetSearchIndex` in the shape a query service
needs: named-table ingestion, a ``search`` endpoint, and request accounting.
The hot loop is the device path -- the corpus lives as pre-stacked device
arrays and every query is one ICWS sketch launch plus six one-vs-many
estimate launches, independent of how the corpus was ingested.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.data import DatasetSearchIndex, SearchResult


@dataclasses.dataclass
class ServiceStats:
    tables_ingested: int = 0
    rows_ingested: int = 0
    queries_served: int = 0
    total_query_ms: float = 0.0
    last_query_ms: float = 0.0

    @property
    def mean_query_ms(self) -> float:
        return self.total_query_ms / max(self.queries_served, 1)


class SketchSearchService:
    """Sketch-index serving: ingest tables once, answer joinability/corr
    queries against the whole corpus from sketches alone."""

    def __init__(self, m: int = 256, seed: int = 0,
                 backend: str = "device", keep_host_oracle: bool = True):
        self.index = DatasetSearchIndex(m=m, seed=seed, backend=backend,
                                        keep_host_oracle=keep_host_oracle)
        self.stats = ServiceStats()

    # -- ingestion ----------------------------------------------------------
    def ingest(self, name: str, keys: np.ndarray, values: np.ndarray) -> None:
        if any(t.name == name for t in self.index.tables):
            raise ValueError(f"table {name!r} already ingested")
        self.index.add_table(name, keys, values)
        self.stats.tables_ingested += 1
        self.stats.rows_ingested += len(keys)

    def ingest_many(self, tables: Sequence[Tuple[str, np.ndarray, np.ndarray]]
                    ) -> None:
        for name, keys, values in tables:
            self.ingest(name, keys, values)

    # -- queries ------------------------------------------------------------
    def search(self, keys: np.ndarray, values: np.ndarray, *,
               top_k: int = 10, min_join: float = 1.0,
               backend: Optional[str] = None) -> List[SearchResult]:
        t0 = time.perf_counter()
        results = self.index.query(keys, values, top_k=top_k,
                                   min_join=min_join, backend=backend)
        ms = (time.perf_counter() - t0) * 1e3
        self.stats.queries_served += 1
        self.stats.last_query_ms = ms
        self.stats.total_query_ms += ms
        return results

    def describe(self) -> Dict[str, float]:
        return {
            "tables": float(len(self.index.tables)),
            "storage_doubles": self.index.storage_doubles(),
            "queries_served": float(self.stats.queries_served),
            "mean_query_ms": self.stats.mean_query_ms,
        }
