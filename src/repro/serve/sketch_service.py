"""Serving front-end for corpus-scale dataset search.

Wraps :class:`repro.data.DatasetSearchIndex` in the shape a query service
needs: named-table ingestion, ``search`` / ``search_batch`` endpoints, and
request accounting.  The hot loop is the device path -- the corpus lives in
the index's canonical field-stacked :class:`repro.data.CorpusStore` (one
device-resident copy, amortized in-place append), and every query, single
or batched, is one ``[3Q, N]`` ICWS sketch launch plus ONE fused
multi-field many-vs-many estimate launch off those buffers (``search`` is
the Q=1 case; ``search_batch`` amortizes launches across a micro-batch,
which is why batched serving is the high-traffic endpoint).  Pass a
``mesh`` with a multi-device corpus axis to serve the estimate launch
sharded over corpus rows -- rankings are bitwise identical to the
single-device path.  All of it is independent of how the corpus was
ingested.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.data import DatasetSearchIndex, SearchResult


@dataclasses.dataclass
class ServiceStats:
    tables_ingested: int = 0
    rows_ingested: int = 0
    queries_served: int = 0
    total_query_ms: float = 0.0
    last_query_ms: float = 0.0
    # batched endpoint accounting (micro-batches, not individual queries)
    batches_served: int = 0
    batch_queries_served: int = 0
    total_batch_ms: float = 0.0
    last_batch_ms: float = 0.0

    @property
    def mean_query_ms(self) -> float:
        return self.total_query_ms / max(self.queries_served, 1)

    @property
    def mean_batch_ms(self) -> float:
        return self.total_batch_ms / max(self.batches_served, 1)

    @property
    def mean_batched_query_ms(self) -> float:
        """Per-query latency through the batched endpoint."""
        return self.total_batch_ms / max(self.batch_queries_served, 1)


class SketchSearchService:
    """Sketch-index serving: ingest tables once, answer joinability/corr
    queries against the whole corpus from sketches alone."""

    def __init__(self, m: int = 256, seed: int = 0,
                 backend: str = "device", keep_host_oracle: bool = True,
                 mesh=None, family: str = "icws", packed: bool = False):
        # family picks the device serving sketch (any repro.data
        # .FAMILY_NAMES entry -- icws/dmh/cs/jl/ts/ps today), sized
        # storage-matched from m (see repro.data.families) -- the same
        # corpus can be served under any family for an apples-to-apples
        # error/throughput comparison.  packed=True keeps the corpus in the
        # family's bit-packed wire layout (roughly half the resident bytes
        # per row) and serves through the unpack-in-kernel estimate twins.
        self.index = DatasetSearchIndex(m=m, seed=seed, backend=backend,
                                        keep_host_oracle=keep_host_oracle,
                                        mesh=mesh, family=family,
                                        packed=packed)
        self.stats = ServiceStats()

    # -- ingestion ----------------------------------------------------------
    def ingest(self, name: str, keys: np.ndarray, values: np.ndarray, *,
               tenant: Optional[str] = None) -> None:
        """Ingest one named table; ``tenant`` scopes it to a logical corpus
        inside the shared arena (see :meth:`search`).  Duplicate-name
        checks are scoped per tenant -- tenants are logical corpora, so two
        tenants may each own a table called "sales"."""
        if any(t.name == name
               for t in self._tenant_tables_or_empty(tenant)):
            raise ValueError(f"table {name!r} already ingested"
                             + (f" for tenant {tenant!r}"
                                if tenant is not None else ""))
        self.index.add_table(name, keys, values, tenant=tenant)
        self.stats.tables_ingested += 1
        self.stats.rows_ingested += len(keys)

    def _tenant_tables_or_empty(self, tenant: Optional[str]):
        """The tenant's tables for the duplicate-name check -- empty for a
        tenant that has not ingested yet (a KeyError here would make the
        FIRST ingest of every tenant fail)."""
        if tenant is not None and str(tenant) not in self.index.tenants():
            return []
        return self.index._tenant_table_list(tenant)

    def ingest_many(self, tables: Sequence[Tuple[str, np.ndarray, np.ndarray]],
                    *, tenant: Optional[str] = None) -> None:
        for name, keys, values in tables:
            self.ingest(name, keys, values, tenant=tenant)

    def ingest_many_sharded(self,
                            tables: Sequence[Tuple[str, np.ndarray,
                                                   np.ndarray]],
                            *, shards: int,
                            tenant: Optional[str] = None) -> None:
        """Ingest a batch of tables via a ``shards``-way parallel lake build
        (:meth:`repro.data.DatasetSearchIndex.add_tables_sharded`)."""
        tables = list(tables)
        seen = {t.name for t in self._tenant_tables_or_empty(tenant)}
        for name, _, _ in tables:
            if name in seen:
                raise ValueError(f"table {name!r} already ingested"
                                 + (f" for tenant {tenant!r}"
                                    if tenant is not None else ""))
            seen.add(name)
        self.index.add_tables_sharded(tables, shards=shards, tenant=tenant)
        self.stats.tables_ingested += len(tables)
        self.stats.rows_ingested += sum(len(k) for _, k, _ in tables)

    # -- queries ------------------------------------------------------------
    def search(self, keys: np.ndarray, values: np.ndarray, *,
               top_k: int = 10, min_join: float = 1.0,
               backend: Optional[str] = None,
               tenant: Optional[str] = None) -> List[SearchResult]:
        """Rank tables by |corr|; ``tenant`` searches one logical corpus of
        the shared arena, bitwise equal to a dedicated single-tenant index
        over the same tables."""
        t0 = time.perf_counter()
        results = self.index.query(keys, values, top_k=top_k,
                                   min_join=min_join, backend=backend,
                                   tenant=tenant)
        ms = (time.perf_counter() - t0) * 1e3
        self.stats.queries_served += 1
        self.stats.last_query_ms = ms
        self.stats.total_query_ms += ms
        return results

    _EMPTY_QUERY = (np.zeros(0, np.int64), np.zeros(0, np.float64))

    def search_batch(self, queries: Sequence[Tuple[np.ndarray, np.ndarray]],
                     *, top_k: int = 10, min_join: float = 1.0,
                     backend: Optional[str] = None, micro_batch: int = 16,
                     tenant: Optional[str] = None
                     ) -> List[List[SearchResult]]:
        """Batched search: Q ``(keys, values)`` queries, Q result lists.

        Queries run through :meth:`DatasetSearchIndex.query_batch` in
        micro-batches of ``micro_batch``; on the device backend the tail
        micro-batch is padded with empty queries so every launch sees the
        same ``[micro_batch]`` batch shape and reuses one jit/kernel cache
        entry (empty padding sketches to the ``fp == -1`` sentinel, estimates
        to zero, and is dropped before results are returned).  Results are
        identical to a loop of :meth:`search`; per-batch latency lands in
        ``stats.last_batch_ms`` / ``stats.mean_batched_query_ms``.
        """
        if micro_batch < 1:
            raise ValueError("micro_batch must be >= 1")
        queries = list(queries)
        resolved = backend or self.index.backend
        results: List[List[SearchResult]] = []
        for lo in range(0, len(queries), micro_batch):
            chunk = queries[lo:lo + micro_batch]
            t0 = time.perf_counter()
            if resolved == "device" and len(chunk) < micro_batch:
                padded = chunk + [self._EMPTY_QUERY] * (micro_batch - len(chunk))
            else:
                padded = chunk
            out = self.index.query_batch(padded, top_k=top_k,
                                         min_join=min_join, backend=backend,
                                         tenant=tenant)
            results.extend(out[:len(chunk)])
            ms = (time.perf_counter() - t0) * 1e3
            self.stats.batches_served += 1
            self.stats.batch_queries_served += len(chunk)
            self.stats.last_batch_ms = ms
            self.stats.total_batch_ms += ms
        return results

    def describe(self, tenant: Optional[str] = None) -> Dict[str, object]:
        """Service accounting.  With ``tenant``, the report scopes to that
        logical corpus: its table count, rows, row ranges in the arena, and
        its share of the storage-doubles ledger."""
        store = self.index.store
        if tenant is not None:
            tables = self.index._tenant_table_list(tenant)
            if store is not None:
                acct = store.describe_tenants()[str(tenant)]
                rows, ranges = acct["rows"], acct["ranges"]
                storage = acct["storage_doubles"]
            else:
                rows, ranges = float(len(tables)), 1.0
                storage = float(len(tables) * 3
                                * self.index.family.storage_doubles_per_row())
            return {
                "tenant": tenant,
                "family": self.index.family.name,
                "backend": self.index.backend,
                "tables": float(len(tables)),
                "corpus_rows": rows,
                "row_ranges": ranges,
                "storage_doubles": storage,
            }
        # a host-only index (backend="host") has no device store, but its
        # corpus is just as real -- one row per ingested table per field.
        # Report the table-derived row count rather than a misleading 0;
        # host corpora are exact-size, so capacity == rows there.
        rows = float(store.size if store is not None
                     else len(self.index.tables))
        cap = float(store.capacity if store is not None
                    else len(self.index.tables))
        return {
            "family": self.index.family.name,
            "backend": self.index.backend,
            "packed": bool(store.packed) if store is not None else False,
            "bytes_per_row": float(store.bytes_per_row()
                                   if store is not None else 0),
            "tables": float(len(self.index.tables)),
            "tenants": float(len(self.index.tenants())),
            "storage_doubles": self.index.storage_doubles(),
            "corpus_rows": rows,
            "corpus_capacity": cap,
            "queries_served": float(self.stats.queries_served),
            "mean_query_ms": self.stats.mean_query_ms,
            "batches_served": float(self.stats.batches_served),
            "batch_queries_served": float(self.stats.batch_queries_served),
            "mean_batch_ms": self.stats.mean_batch_ms,
            "mean_batched_query_ms": self.stats.mean_batched_query_ms,
        }
