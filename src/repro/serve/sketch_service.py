"""Serving front-end for corpus-scale dataset search.

Wraps :class:`repro.data.DatasetSearchIndex` in the shape a query service
needs: named-table ingestion, ``search`` / ``search_batch`` endpoints, and
request accounting.  The hot loop is the device path -- the corpus lives in
the index's canonical field-stacked :class:`repro.data.CorpusStore` (one
device-resident copy, amortized in-place append), and every query, single
or batched, is one ``[3Q, N]`` ICWS sketch launch plus ONE fused
multi-field many-vs-many estimate launch off those buffers (``search`` is
the Q=1 case; ``search_batch`` amortizes launches across a micro-batch,
which is why batched serving is the high-traffic endpoint).  Pass a
``mesh`` with a multi-device corpus axis to serve the estimate launch
sharded over corpus rows -- rankings are bitwise identical to the
single-device path.  All of it is independent of how the corpus was
ingested.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro import obs as _obs
from repro.data import DatasetSearchIndex, SearchResult
from repro.obs.metrics import Histogram


class ServiceStats:
    """Request accounting: a thin compatibility view over latency histograms.

    Historically this was a dataclass of running sums; the fields the old
    mean-only API exposed (``queries_served``, ``total_query_ms``,
    ``last_query_ms``, ...) are now properties derived from three private
    :class:`repro.obs.metrics.Histogram` instances, which additionally give
    the service exact-window p50/p95/p99 for :meth:`SketchSearchService.
    describe`.  The histograms are owned by this object (not the global
    obs registry), so they always record -- two services in one process
    never share latency state -- and they work with observability disabled.
    """

    def __init__(self) -> None:
        self.tables_ingested = 0
        self.rows_ingested = 0
        # batched-endpoint query count (micro-batches land in batch_hist)
        self.batch_queries_served = 0
        self.query_hist = Histogram("serve.query_seconds")
        self.batch_hist = Histogram("serve.batch_seconds")
        # per-query latency through the batched endpoint: one observation
        # per micro-batch (batch wall time / batch size)
        self.batched_query_hist = Histogram("serve.batched_query_seconds")

    # -- compatibility view (the pre-histogram field set) -------------------
    @property
    def queries_served(self) -> int:
        return self.query_hist.count

    @property
    def total_query_ms(self) -> float:
        return self.query_hist.sum * 1e3

    @property
    def last_query_ms(self) -> float:
        return self.query_hist.last * 1e3

    @property
    def batches_served(self) -> int:
        return self.batch_hist.count

    @property
    def total_batch_ms(self) -> float:
        return self.batch_hist.sum * 1e3

    @property
    def last_batch_ms(self) -> float:
        return self.batch_hist.last * 1e3

    @property
    def mean_query_ms(self) -> float:
        return self.total_query_ms / max(self.queries_served, 1)

    @property
    def mean_batch_ms(self) -> float:
        return self.total_batch_ms / max(self.batches_served, 1)

    @property
    def mean_batched_query_ms(self) -> float:
        """Per-query latency through the batched endpoint."""
        return self.total_batch_ms / max(self.batch_queries_served, 1)


class SketchSearchService:
    """Sketch-index serving: ingest tables once, answer joinability/corr
    queries against the whole corpus from sketches alone."""

    def __init__(self, m: int = 256, seed: int = 0,
                 backend: str = "device", keep_host_oracle: bool = True,
                 mesh=None, family: str = "icws", packed: bool = False,
                 audit_every: int = 0):
        # family picks the device serving sketch (any repro.data
        # .FAMILY_NAMES entry -- icws/dmh/cs/jl/ts/ps today), sized
        # storage-matched from m (see repro.data.families) -- the same
        # corpus can be served under any family for an apples-to-apples
        # error/throughput comparison.  packed=True keeps the corpus in the
        # family's bit-packed wire layout (roughly half the resident bytes
        # per row) and serves through the unpack-in-kernel estimate twins.
        self.index = DatasetSearchIndex(m=m, seed=seed, backend=backend,
                                        keep_host_oracle=keep_host_oracle,
                                        mesh=mesh, family=family,
                                        packed=packed)
        self.stats = ServiceStats()
        # per-tenant latency histograms (private, always recording)
        self._tenant_hists: Dict[str, Histogram] = {}
        # estimator-quality audit: with observability enabled and
        # audit_every=N > 0, every Nth single search re-scores its top hit
        # against the host oracle and feeds quality.ppm_error (ICWS device
        # indexes that kept the oracle only; a no-op otherwise)
        self.audit_every = int(audit_every)

    # -- ingestion ----------------------------------------------------------
    def ingest(self, name: str, keys: np.ndarray, values: np.ndarray, *,
               tenant: Optional[str] = None) -> None:
        """Ingest one named table; ``tenant`` scopes it to a logical corpus
        inside the shared arena (see :meth:`search`).  Duplicate-name
        checks are scoped per tenant -- tenants are logical corpora, so two
        tenants may each own a table called "sales"."""
        if any(t.name == name
               for t in self._tenant_tables_or_empty(tenant)):
            raise ValueError(f"table {name!r} already ingested"
                             + (f" for tenant {tenant!r}"
                                if tenant is not None else ""))
        with _obs.span("serve.ingest", table=name, tenant=tenant):
            self.index.add_table(name, keys, values, tenant=tenant)
        self.stats.tables_ingested += 1
        self.stats.rows_ingested += len(keys)
        if _obs.enabled():
            _obs.counter("serve.tables_ingested_total").inc()
            _obs.counter("serve.rows_ingested_total").inc(len(keys))

    def _tenant_tables_or_empty(self, tenant: Optional[str]):
        """The tenant's tables for the duplicate-name check -- empty for a
        tenant that has not ingested yet (a KeyError here would make the
        FIRST ingest of every tenant fail)."""
        if tenant is not None and str(tenant) not in self.index.tenants():
            return []
        return self.index._tenant_table_list(tenant)

    def ingest_many(self, tables: Sequence[Tuple[str, np.ndarray, np.ndarray]],
                    *, tenant: Optional[str] = None) -> None:
        for name, keys, values in tables:
            self.ingest(name, keys, values, tenant=tenant)

    def ingest_many_sharded(self,
                            tables: Sequence[Tuple[str, np.ndarray,
                                                   np.ndarray]],
                            *, shards: int,
                            tenant: Optional[str] = None) -> None:
        """Ingest a batch of tables via a ``shards``-way parallel lake build
        (:meth:`repro.data.DatasetSearchIndex.add_tables_sharded`)."""
        tables = list(tables)
        seen = {t.name for t in self._tenant_tables_or_empty(tenant)}
        for name, _, _ in tables:
            if name in seen:
                raise ValueError(f"table {name!r} already ingested"
                                 + (f" for tenant {tenant!r}"
                                    if tenant is not None else ""))
            seen.add(name)
        with _obs.span("serve.ingest_sharded", shards=shards, tenant=tenant,
                       tables=len(tables)):
            self.index.add_tables_sharded(tables, shards=shards,
                                          tenant=tenant)
        self.stats.tables_ingested += len(tables)
        rows = sum(len(k) for _, k, _ in tables)
        self.stats.rows_ingested += rows
        if _obs.enabled():
            _obs.counter("serve.tables_ingested_total").inc(len(tables))
            _obs.counter("serve.rows_ingested_total").inc(rows)

    # -- queries ------------------------------------------------------------
    def search(self, keys: np.ndarray, values: np.ndarray, *,
               top_k: int = 10, min_join: float = 1.0,
               backend: Optional[str] = None,
               tenant: Optional[str] = None) -> List[SearchResult]:
        """Rank tables by |corr|; ``tenant`` searches one logical corpus of
        the shared arena, bitwise equal to a dedicated single-tenant index
        over the same tables."""
        t0 = time.perf_counter()
        with _obs.span("serve.search", tenant=tenant,
                       family=self.index.family.name,
                       backend=backend or self.index.backend):
            results = self.index.query(keys, values, top_k=top_k,
                                       min_join=min_join, backend=backend,
                                       tenant=tenant)
        dt = time.perf_counter() - t0
        self.stats.query_hist.record(dt)
        self._record_request("search", dt, tenant)
        if self.audit_every:
            self._maybe_audit(keys, values, results, top_k, min_join,
                              backend, tenant)
        return results

    # -- telemetry helpers --------------------------------------------------
    def _record_request(self, endpoint: str, dt: float,
                        tenant: Optional[str]) -> None:
        if tenant is not None:
            hist = self._tenant_hists.get(str(tenant))
            if hist is None:
                hist = Histogram("serve.tenant_seconds",
                                 {"tenant": str(tenant)})
                self._tenant_hists[str(tenant)] = hist
            hist.record(dt)
        if not _obs.enabled():
            return
        _obs.histogram("serve.request_seconds", endpoint=endpoint).record(dt)
        if endpoint == "search":
            _obs.counter("serve.queries_total").inc()
        if tenant is not None:
            _obs.histogram("serve.tenant_request_seconds",
                           tenant=str(tenant)).record(dt)

    def _maybe_audit(self, keys, values, results, top_k, min_join,
                     backend, tenant) -> None:
        """Every ``audit_every``-th search, re-score against the host oracle
        and feed the rolling quality.ppm_error gauge (see repro.obs.quality).

        Only meaningful for ICWS device indexes that kept the oracle at
        ingest; anything else (other families, host backend, empty results)
        silently skips -- auditability is a property of the index, and the
        quality channel must never change what the endpoint returns.
        """
        if not _obs.enabled() or not results:
            return
        if (backend or self.index.backend) != "device":
            return
        if self.index.family.name != "icws" or not self.index.keep_host_oracle:
            return
        if self.stats.queries_served % self.audit_every != 0:
            return
        ref = self.index.query(keys, values, top_k=top_k, min_join=min_join,
                               backend="host", tenant=tenant)
        ref_by_name = {r.name: r for r in ref}
        for r in results:
            mate = ref_by_name.get(r.name)
            if mate is None or mate.join_size == 0:
                continue
            _obs.record_sample(self.index.family.name, r.join_size,
                               mate.join_size)

    _EMPTY_QUERY = (np.zeros(0, np.int64), np.zeros(0, np.float64))

    def search_batch(self, queries: Sequence[Tuple[np.ndarray, np.ndarray]],
                     *, top_k: int = 10, min_join: float = 1.0,
                     backend: Optional[str] = None, micro_batch: int = 16,
                     tenant: Optional[str] = None
                     ) -> List[List[SearchResult]]:
        """Batched search: Q ``(keys, values)`` queries, Q result lists.

        Queries run through :meth:`DatasetSearchIndex.query_batch` in
        micro-batches of ``micro_batch``; on the device backend the tail
        micro-batch is padded with empty queries so every launch sees the
        same ``[micro_batch]`` batch shape and reuses one jit/kernel cache
        entry (empty padding sketches to the ``fp == -1`` sentinel, estimates
        to zero, and is dropped before results are returned).  Results are
        identical to a loop of :meth:`search`; per-batch latency lands in
        ``stats.last_batch_ms`` / ``stats.mean_batched_query_ms``.
        """
        if micro_batch < 1:
            raise ValueError("micro_batch must be >= 1")
        queries = list(queries)
        resolved = backend or self.index.backend
        results: List[List[SearchResult]] = []
        for lo in range(0, len(queries), micro_batch):
            chunk = queries[lo:lo + micro_batch]
            t0 = time.perf_counter()
            if resolved == "device" and len(chunk) < micro_batch:
                padded = chunk + [self._EMPTY_QUERY] * (micro_batch - len(chunk))
            else:
                padded = chunk
            with _obs.span("serve.search_batch", tenant=tenant,
                           family=self.index.family.name,
                           batch=len(chunk)):
                out = self.index.query_batch(padded, top_k=top_k,
                                             min_join=min_join,
                                             backend=backend, tenant=tenant)
            results.extend(out[:len(chunk)])
            dt = time.perf_counter() - t0
            self.stats.batch_hist.record(dt)
            self.stats.batched_query_hist.record(dt / len(chunk))
            self.stats.batch_queries_served += len(chunk)
            self._record_request("search_batch", dt, tenant)
            if _obs.enabled():
                _obs.counter("serve.batches_total").inc()
                _obs.counter("serve.batch_queries_total").inc(len(chunk))
                _obs.histogram("serve.batched_query_seconds").record(
                    dt / len(chunk))
        return results

    def describe(self, tenant: Optional[str] = None) -> Dict[str, object]:
        """Service accounting.  With ``tenant``, the report scopes to that
        logical corpus: its table count, rows, row ranges in the arena, and
        its share of the storage-doubles ledger."""
        store = self.index.store
        if tenant is not None:
            tables = self.index._tenant_table_list(tenant)
            if store is not None:
                acct = store.describe_tenants()[str(tenant)]
                rows, ranges = acct["rows"], acct["ranges"]
                storage = acct["storage_doubles"]
            else:
                rows, ranges = float(len(tables)), 1.0
                storage = float(len(tables) * 3
                                * self.index.family.storage_doubles_per_row())
            report = {
                "tenant": tenant,
                "family": self.index.family.name,
                "backend": self.index.backend,
                "tables": len(tables),
                "corpus_rows": rows,
                "row_ranges": ranges,
                "storage_doubles": storage,
            }
            hist = self._tenant_hists.get(str(tenant))
            if hist is not None and hist.count:
                report.update(_latency_fields("request_ms", hist))
            return report
        # a host-only index (backend="host") has no device store, but its
        # corpus is just as real -- one row per ingested table per field.
        # Report the table-derived row count rather than a misleading 0;
        # host corpora are exact-size, so capacity == rows there.
        rows = int(store.size if store is not None
                   else len(self.index.tables))
        cap = int(store.capacity if store is not None
                  else len(self.index.tables))
        report = {
            "family": self.index.family.name,
            "backend": self.index.backend,
            "packed": bool(store.packed) if store is not None else False,
            "bytes_per_row": float(store.bytes_per_row()
                                   if store is not None else 0),
            "tables": len(self.index.tables),
            "tenants": len(self.index.tenants()),
            "storage_doubles": self.index.storage_doubles(),
            "corpus_rows": rows,
            "corpus_capacity": cap,
            "queries_served": self.stats.queries_served,
            "mean_query_ms": self.stats.mean_query_ms,
            "batches_served": self.stats.batches_served,
            "batch_queries_served": self.stats.batch_queries_served,
            "mean_batch_ms": self.stats.mean_batch_ms,
            "mean_batched_query_ms": self.stats.mean_batched_query_ms,
        }
        report.update(_latency_fields("query_ms", self.stats.query_hist))
        report.update(_latency_fields("batch_ms", self.stats.batch_hist))
        report.update(_latency_fields("batched_query_ms",
                                      self.stats.batched_query_hist))
        return report


def _latency_fields(prefix: str, hist: Histogram) -> Dict[str, float]:
    """p50/p95/p99 (ms) of one latency histogram, keyed ``<prefix>_p50``..."""
    return {
        prefix + "_p50": hist.quantile(0.50) * 1e3,
        prefix + "_p95": hist.quantile(0.95) * 1e3,
        prefix + "_p99": hist.quantile(0.99) * 1e3,
    }
