"""Serving runtime: prefill/decode steps + batched engine."""
from .step import greedy_sample, make_decode_step, make_prefill_step

__all__ = ["make_prefill_step", "make_decode_step", "greedy_sample"]
