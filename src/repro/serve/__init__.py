"""Serving runtime: prefill/decode steps + batched engine, and the
sketch-corpus search service (the §1.3 dataset-search endpoint)."""
from .sketch_service import ServiceStats, SketchSearchService
from .step import greedy_sample, make_decode_step, make_prefill_step

__all__ = ["make_prefill_step", "make_decode_step", "greedy_sample",
           "SketchSearchService", "ServiceStats"]
