"""Traffic/FLOP breakdown of a lowered cell: the §Perf profiling tool.

Since the container has no TPU to trace, the "profile" is the compiled HLO:
this module attributes corrected HBM traffic, FLOPs, and collective bytes to
individual instructions (x while-loop multipliers) and prints the top
contributors -- the napkin-math input for every hillclimb hypothesis.
"""
from __future__ import annotations

import re
from typing import List, Optional, Tuple

from . import hlo as H


def instruction_breakdown(hlo_text: str, top: int = 15):
    comps = H.parse_computations(hlo_text)
    entry = H.find_entry(hlo_text, comps)
    mult = H.computation_multipliers(comps, entry)
    sym = {}
    for c in comps.values():
        for ins in c.instrs:
            sym[ins.name] = H.shape_bytes(ins.shape)
    fusion_bodies = set()
    for c in comps.values():
        for ins in c.instrs:
            if ins.op == "fusion":
                mc = re.search(r"calls=\{?%?([\w\.\-]+)", ins.line)
                if mc:
                    fusion_bodies.add(mc.group(1))

    traffic_items: List[Tuple[float, int, str, str, str]] = []
    coll_items: List[Tuple[float, int, str, str]] = []
    flop_items: List[Tuple[float, int, str, str]] = []

    for c in comps.values():
        m = mult.get(c.name, 0)
        if m == 0:
            continue
        in_fusion = c.name in fusion_bodies
        for ins in c.instrs:
            if ins.op in H._SKIP_OPS:
                continue
            operand_names = H._OPERAND_RE.findall(ins.args)
            op_bytes = sum(sym.get(o, 0) for o in operand_names)
            out_bytes = H.shape_bytes(ins.shape)

            if ins.op == "dot":
                out_elems, _ = H.shape_elems_and_dims(ins.shape)
                md = H._DOT_DIMS_RE.search(ins.line)
                kdim = 1
                if md and operand_names:
                    lhs_shape = next((i.shape for cc in comps.values()
                                      for i in cc.instrs
                                      if i.name == operand_names[0]), "")
                    _, dims = H.shape_elems_and_dims(lhs_shape)
                    for ci in md.group(1).split(","):
                        if ci and int(ci) < len(dims):
                            kdim *= dims[int(ci)]
                flop_items.append((m * 2.0 * out_elems * max(kdim, 1), m,
                                   c.name, ins.line.strip()[:110]))
            if in_fusion:
                continue
            if ins.op in H._SLICE_READS:
                traffic = 2 * out_bytes
            elif ins.op in H._SLICE_WRITES:
                traffic = 2 * (sym.get(operand_names[1], 0)
                               if len(operand_names) > 1 else 0)
            elif ins.op == "fusion":
                mc = re.search(r"calls=\{?%?([\w\.\-]+)", ins.line)
                fb = H._fusion_operand_bytes(comps, mc.group(1), operand_names,
                                             sym) if mc else None
                traffic = (fb if fb is not None else op_bytes) + out_bytes
            else:
                traffic = op_bytes + out_bytes
            traffic_items.append((m * traffic, m, ins.op, c.name,
                                  ins.line.strip()[:110]))
            base = ins.op[:-6] if ins.op.endswith("-start") else ins.op
            if base in H.COLLECTIVE_OPS and not ins.op.endswith("-done"):
                coll_items.append((m * op_bytes, m, base,
                                   ins.line.strip()[:130]))

    traffic_items.sort(reverse=True)
    coll_items.sort(reverse=True)
    flop_items.sort(reverse=True)
    return {"traffic": traffic_items[:top], "collectives": coll_items[:top],
            "flops": flop_items[:top],
            "traffic_total": sum(t[0] for t in traffic_items),
            "coll_total": sum(t[0] for t in coll_items),
            "flop_total": sum(t[0] for t in flop_items)}


def print_breakdown(hlo_text: str, top: int = 12):
    b = instruction_breakdown(hlo_text, top)
    gb = 2.0 ** 30
    print(f"== HBM traffic total {b['traffic_total']/gb:.0f} GB/dev ==")
    for t, m, op, cn, line in b["traffic"]:
        print(f"  {t/gb:8.1f}GB x{m:<5} {op:<12} {line[:90]}")
    print(f"== collectives total {b['coll_total']/gb:.1f} GB/dev ==")
    for t, m, kind, line in b["collectives"]:
        print(f"  {t/gb:8.1f}GB x{m:<5} {kind:<18} {line[:90]}")
    print(f"== dot FLOPs total {b['flop_total']:.2e}/dev ==")
    for t, m, cn, line in b["flops"]:
        print(f"  {t:10.2e} x{m:<5} {line[:90]}")
