"""Roofline analysis from compiled dry-run artifacts (TPU v5e constants)."""
from .hlo import RooflineCounts, analyze_hlo
from .terms import HBM_BW, ICI_BW, PEAK_FLOPS, Roofline, model_flops_for

__all__ = ["RooflineCounts", "analyze_hlo", "Roofline", "model_flops_for",
           "PEAK_FLOPS", "HBM_BW", "ICI_BW"]
