"""Roofline analysis from compiled dry-run artifacts (TPU v5e constants),
plus the block-size autotuner that feeds the Pallas launch layer."""
from .autotune import (VMEM_BLOCK_BUDGET, cache_path, load_cache,
                       model_time_s, resolve, save_cache, tune)
from .hlo import RooflineCounts, analyze_hlo
from .terms import HBM_BW, ICI_BW, PEAK_FLOPS, Roofline, model_flops_for

__all__ = ["RooflineCounts", "analyze_hlo", "Roofline", "model_flops_for",
           "PEAK_FLOPS", "HBM_BW", "ICI_BW", "VMEM_BLOCK_BUDGET",
           "cache_path", "load_cache", "model_time_s", "resolve",
           "save_cache", "tune"]
