"""Roofline terms for TPU v5e (target hardware; this container is CPU-only).

    compute term    = FLOPs / (chips * 197 TFLOP/s bf16)
    memory term     = HBM bytes / (chips * 819 GB/s)
    collective term = collective bytes / (chips * 50 GB/s/link)

All three in seconds; the max identifies the bottleneck.  MODEL_FLOPS is the
analytic useful compute (6*N*D for training, 2*N*D for inference, N = active
params), whose ratio against the HLO dot FLOPs flags remat/dispatch waste.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link


@dataclasses.dataclass
class Roofline:
    chips: int
    flops: float
    hbm_bytes: float
    collective_bytes: float
    model_flops: float

    @property
    def compute_s(self) -> float:
        return self.flops / (self.chips * PEAK_FLOPS)

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes / (self.chips * HBM_BW)

    @property
    def collective_s(self) -> float:
        return self.collective_bytes / (self.chips * ICI_BW)

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Optimistic (full-overlap) bound: max of the three terms."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_ratio(self) -> float:
        return self.model_flops / self.flops if self.flops else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the compute roofline achieved at the step-time bound:
        (useful FLOPs / step_time) / peak."""
        st = self.step_time_s
        if st <= 0:
            return 0.0
        return self.model_flops / st / (self.chips * PEAK_FLOPS)

    def as_dict(self) -> Dict:
        return {
            "chips": self.chips,
            "flops": self.flops,
            "hbm_bytes": self.hbm_bytes,
            "collective_bytes": self.collective_bytes,
            "model_flops": self.model_flops,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "useful_ratio": self.useful_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def model_flops_for(cfg, shape_cfg) -> float:
    """Analytic useful FLOPs per step for the cell.

    train:   6 * N_active * tokens  (+ attention 12*L_attn*T^2*H*hd per seq)
    prefill: 2 * N_active * tokens  (+ attention term /3)
    decode:  2 * N_active * batch   (+ attention reads of the live context)
    """
    n_act = cfg.active_param_count()
    L_attn = _attention_layers(cfg)
    H, hd = cfg.num_heads, cfg.head_dim
    T, B = shape_cfg.seq_len, shape_cfg.global_batch
    if shape_cfg.kind == "train":
        tokens = T * B
        att = _attn_flops_per_seq(cfg, T) * B * 3          # fwd + bwd(2x)
        return 6.0 * n_act * tokens + att
    if shape_cfg.kind == "prefill":
        tokens = T * B
        return 2.0 * n_act * tokens + _attn_flops_per_seq(cfg, T) * B
    # decode: one token; attention reads ctx of length min(T, window)
    ctx_len = T if not cfg.sliding_window else min(T, cfg.sliding_window)
    att = 4.0 * L_attn * H * hd * ctx_len * B
    return 2.0 * n_act * B + att


def _attention_layers(cfg) -> int:
    if cfg.family == "ssm":
        return 0
    if cfg.family == "hybrid":
        return cfg.num_layers // cfg.hybrid_group
    if cfg.family == "encdec":
        return cfg.num_layers + cfg.encoder_layers
    return cfg.num_layers


def _attn_flops_per_seq(cfg, T: int) -> float:
    L = _attention_layers(cfg)
    H, hd = cfg.num_heads, cfg.head_dim
    w = cfg.sliding_window
    eff = T if not w else min(T, w)
    # causal: half the full T x eff score/AV work; qk + av => factor 4
    return 4.0 * L * H * hd * T * eff * 0.5
