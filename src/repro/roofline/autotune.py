"""Roofline-driven block-size autotuner for the estimate/sketch kernels.

The serving kernels (`repro.kernels.estimate` / `sample_estimate` /
`icws_sketch`) launch with hand-picked ``bq/bp/bm/bt/bu/br`` defaults.
This module searches that space analytically -- no device timing loop --
using the same two inputs the repo already maintains:

  * the per-kernel BlockSpec block-I/O accounting behind the PB001/PB002
    static budget rule (``python -m repro.analysis --budget-report``, the
    ``vmem-budget-report`` CI artifact): the tuner reproduces that
    accounting per candidate and rejects anything over the 2 MiB budget,
    and the CLI cross-checks tuned entries against a report file when one
    is passed via ``--report``;
  * the roofline cost terms (:mod:`repro.roofline.terms`): per candidate,
    ``time = max(hbm_bytes / HBM_BW, flops / PEAK_FLOPS) + grid_steps *
    step_overhead(backend)``.  On real TPUs the bandwidth term dominates;
    under the Pallas interpreter (cpu backend -- CI and every dev box)
    each grid step re-enters python, so the per-step overhead term does,
    and fewer/larger blocks win whenever they fit the budget.

Tuned entries persist in a JSON cache (default ``block_cache.json`` next
to this file, override via ``$REPRO_BLOCK_CACHE``) keyed by kernel group,
backend, and the kernel's *reduction* dims.  That keying is a correctness
decision, not a convenience: the repo pins bitwise ranking identities
(batched == sequential, sharded == single-device, tenant == dedicated,
packed == unpacked-roundtripped), and those hold only if every launch
that is compared bitwise reduces in the same block order.  Reduction dims
(``bm``/``bt``/``bu``/``bw``) therefore depend only on the sketch width
-- identical across batch sizes, shards, and tenants, and shared between
a kernel and its packed twin (widths normalized to even).  Row-tile dims
(``bq``/``bp``/``br``) never affect per-element results (padding is
sliced off), so :func:`resolve` clamps them down for small launches
without breaking anything.

Set ``REPRO_AUTOTUNE_DISABLE=1`` to force the hand-picked defaults.
Regenerate the committed cache with::

    PYTHONPATH=src python -m repro.analysis --budget-report report.json
    PYTHONPATH=src python -m repro.roofline.autotune --backend cpu \
        --report report.json

This module stays stdlib-only (like the rest of ``repro.roofline`` and
``repro.analysis``) so tooling can import it without jax.
"""
from __future__ import annotations

import argparse
import functools
import itertools
import json
import os
import pathlib
from typing import Dict, Iterable, Mapping, Optional, Sequence, Tuple

from .terms import HBM_BW, PEAK_FLOPS

# Mirrors repro.analysis.config.AnalysisConfig.vmem_block_budget (PB001).
VMEM_BLOCK_BUDGET = 2 * 1024 * 1024
# Cap on kernel-internal temporaries the BlockSpec accounting cannot see
# (the sample kernel's [bq, bt, bp, bu] cross tensor, the sketch kernel's
# ~6 per-lane intermediates) so tuning never trades grid steps for an
# interpreter-hostile VMEM blowup.
INTERMEDIATE_BUDGET = 3 * 1024 * 1024
_BYTES_PER_ELEM = 4

CACHE_ENV = "REPRO_BLOCK_CACHE"
DISABLE_ENV = "REPRO_AUTOTUNE_DISABLE"
DEFAULT_CACHE = pathlib.Path(__file__).with_name("block_cache.json")

# Per-grid-step launch overhead (s).  TPU: sequential-grid bookkeeping.
# Everything else runs the Pallas interpreter, where each step is a python
# round-trip -- large enough that minimizing grid steps is the whole game.
_STEP_OVERHEAD = {"tpu": 2e-6}
_DEFAULT_STEP_OVERHEAD = 5e-4


def _ceil_div(n: int, d: int) -> int:
    return -(-int(n) // int(d))


def _ceil_to(n: int, base: int) -> int:
    return base * _ceil_div(max(int(n), 1), base)


def _even(n: int) -> int:
    return int(n) + (int(n) % 2)


# ---------------------------------------------------------------------------
# Kernel models: one entry per kernel *group*.  A group covers a kernel and
# its packed twin (same grid geometry, the packed corpus block is strictly
# smaller, so the unpacked accounting below is the shared upper bound).
# ``key_dims`` are the reduction dims that form the cache key; ``dims`` is
# the full tuning shape.  ``report_kernel`` names the group's unpacked
# pallas_call in the --budget-report artifact.
# ---------------------------------------------------------------------------
KERNELS: Dict[str, Dict] = {
    "estimate_fields": {
        "report_kernel": "estimate_fields_pallas",
        "dims": ("G", "Q", "P", "m"),
        "key_dims": ("m",),
        "defaults": {"bq": 8, "bp": 128, "bm": 128},
        "candidates": {"bq": (8, 16, 32, 64), "bp": (128, 256, 512, 1024),
                       "bm": (128, 256, 512)},
        # resolve-time clamping of row dims: block -> (shape dim, tile base)
        "row_dims": {"bq": ("Q", 8), "bp": ("P", 128)},
        "flops_per_lane": 8.0,
    },
    "linear_estimate_fields": {
        "report_kernel": "linear_estimate_fields_pallas",
        "dims": ("G", "R", "Q", "P", "W"),
        "key_dims": ("W",),
        "defaults": {"bq": 8, "bp": 128, "bw": 128},
        "candidates": {"bq": (8, 16, 32, 64), "bp": (128, 256, 512, 1024),
                       "bw": (128, 256, 512)},
        "row_dims": {"bq": ("Q", 8), "bp": ("P", 128)},
        "flops_per_lane": 2.0,
    },
    "sample_estimate_fields": {
        "report_kernel": "sample_estimate_fields_pallas",
        "dims": ("G", "Q", "P", "S"),
        "key_dims": ("S",),
        "defaults": {"bq": 8, "bp": 8, "bt": 64, "bu": 128},
        "candidates": {"bq": (8, 16), "bp": (8, 16, 32),
                       "bt": (32, 64, 128), "bu": (128, 256)},
        "row_dims": {"bq": ("Q", 8), "bp": ("P", 8)},
        "flops_per_lane": 6.0,
    },
    "icws_sketch": {
        "report_kernel": "icws_sketch_pallas",
        "dims": ("B", "m", "N"),
        "key_dims": ("m", "N"),
        "defaults": {"br": 1, "bm": 128, "bn": 256},
        "candidates": {"br": (1, 2, 4, 8), "bm": (128, 256),
                       "bn": (256, 512)},
        "row_dims": {"br": ("B", 1)},
        "flops_per_lane": 30.0,
    },
    "dmh_sketch": {
        # the bin-state width bm is NOT tuned: it is the lane-rounded
        # sketch width (a capacity the ops wrapper derives from m), so the
        # accounting below bounds it by DMH_BM_CAP, the largest serving m
        # rounded to lanes.  Only (br, bn) are free.
        "report_kernel": "dmh_sketch_pallas",
        "dims": ("B", "m", "N"),
        "key_dims": ("m", "N"),
        "defaults": {"br": 1, "bn": 256},
        "candidates": {"br": (1, 2, 4, 8), "bn": (256, 512, 1024)},
        "row_dims": {"br": ("B", 1)},
        "flops_per_lane": 6.0,
    },
}

# Upper bound on the DMH kernel's VMEM-resident bin-state width: the
# largest sketch width any serving path launches (storage budget 400 ->
# m = 266) rounded up to a lane multiple.  Used for the PB001/PB002-style
# block accounting of ``dmh_sketch`` entries, where the real bm <= this.
DMH_BM_CAP = 384


def _block_shapes(kernel: str, b: Mapping[str, int]) -> list:
    """(count, block shape) per BlockSpec, mirroring the pallas_call specs
    the PB001 rule sums (4 bytes/elem).  Packed twins reuse the group's
    accounting as an upper bound."""
    if kernel == "estimate_fields":
        return [(2, (1, b["bq"], b["bm"])), (2, (1, b["bp"], b["bm"])),
                (2, (1, b["bq"], b["bp"]))]
    if kernel == "linear_estimate_fields":
        return [(1, (1, b["bq"], 1, b["bw"])), (1, (1, b["bp"], 1, b["bw"])),
                (1, (1, 1, b["bq"], b["bp"]))]
    if kernel == "sample_estimate_fields":
        return [(3, (1, b["bq"], b["bt"])), (3, (1, b["bp"], b["bu"])),
                (1, (1, b["bq"], b["bp"]))]
    if kernel == "icws_sketch":
        # 3 inputs [br, bn]; 4 outputs + the pack_vals variant's 5th [br, bm]
        return [(3, (b["br"], b["bn"])), (5, (b["br"], b["bm"]))]
    if kernel == "dmh_sketch":
        # 3 inputs [br, bn]; 4 outputs + pack_vals' 5th at the bm cap
        return [(3, (b["br"], b["bn"])), (5, (b["br"], DMH_BM_CAP))]
    raise KeyError(f"unknown kernel group {kernel!r}")


def block_bytes(kernel: str, blocks: Mapping[str, int]) -> int:
    total = 0
    for count, shape in _block_shapes(kernel, blocks):
        n = 1
        for d in shape:
            n *= int(d)
        total += count * n * _BYTES_PER_ELEM
    return total


def _intermediate_bytes(kernel: str, b: Mapping[str, int]) -> int:
    if kernel == "sample_estimate_fields":
        # the [bq, bt, bp, bu] cross tensor (plus same-shape where/min temps)
        return 2 * _BYTES_PER_ELEM * b["bq"] * b["bt"] * b["bp"] * b["bu"]
    if kernel == "icws_sketch":
        # ~6 f32 [br, bm, bn] temporaries (5 uniform draws + hash math)
        return 6 * _BYTES_PER_ELEM * b["br"] * b["bm"] * b["bn"]
    if kernel == "dmh_sketch":
        # gather-based payload selection keeps the [br, bm, bn] cross
        # tensors down to ~2 (the bin-match mask and its argmin companion);
        # the per-lane variates are [br, bn] and the probe epilogue chunks
        # at [br, bm, 128] -- both dominated by the cross terms at any bn
        return 2 * _BYTES_PER_ELEM * b["br"] * DMH_BM_CAP * b["bn"]
    return 0


def _grid_steps(kernel: str, s: Mapping[str, int], b: Mapping[str, int]) -> int:
    if kernel == "estimate_fields":
        return (s["G"] * _ceil_div(s["Q"], b["bq"]) *
                _ceil_div(s["P"], b["bp"]) * _ceil_div(s["m"], b["bm"]))
    if kernel == "linear_estimate_fields":
        return (s["G"] * s["R"] * _ceil_div(s["Q"], b["bq"]) *
                _ceil_div(s["P"], b["bp"]) * _ceil_div(s["W"], b["bw"]))
    if kernel == "sample_estimate_fields":
        return (s["G"] * _ceil_div(s["Q"], b["bq"]) *
                _ceil_div(s["P"], b["bp"]) * _ceil_div(s["S"], b["bt"]) *
                _ceil_div(s["S"], b["bu"]))
    if kernel == "icws_sketch":
        return (_ceil_div(s["B"], b["br"]) * _ceil_div(s["m"], b["bm"]) *
                _ceil_div(s["N"], b["bn"]))
    if kernel == "dmh_sketch":
        # no m grid axis: the whole bin state stays VMEM-resident
        return _ceil_div(s["B"], b["br"]) * _ceil_div(s["N"], b["bn"])
    raise KeyError(f"unknown kernel group {kernel!r}")


def _lanes(kernel: str, s: Mapping[str, int], b: Mapping[str, int]) -> int:
    """Padded elementwise lanes actually computed -- charges block choices
    for the padding waste of oversized tiles."""
    if kernel == "estimate_fields":
        return (s["G"] * _ceil_to(s["Q"], b["bq"]) *
                _ceil_to(s["P"], b["bp"]) * _ceil_to(s["m"], b["bm"]))
    if kernel == "linear_estimate_fields":
        return (s["G"] * s["R"] * _ceil_to(s["Q"], b["bq"]) *
                _ceil_to(s["P"], b["bp"]) * _ceil_to(s["W"], b["bw"]))
    if kernel == "sample_estimate_fields":
        return (s["G"] * _ceil_to(s["Q"], b["bq"]) *
                _ceil_to(s["P"], b["bp"]) * _ceil_to(s["S"], b["bt"]) *
                _ceil_to(s["S"], b["bu"]))
    if kernel == "icws_sketch":
        return (_ceil_to(s["B"], b["br"]) * _ceil_to(s["m"], b["bm"]) *
                _ceil_to(s["N"], b["bn"]))
    if kernel == "dmh_sketch":
        return _ceil_to(s["B"], b["br"]) * _ceil_to(s["N"], b["bn"])
    raise KeyError(f"unknown kernel group {kernel!r}")


def model_time_s(kernel: str, shape: Mapping[str, int],
                 blocks: Mapping[str, int], backend: str) -> float:
    """Roofline estimate for one launch: bandwidth/compute max plus the
    per-grid-step overhead of the backend."""
    steps = _grid_steps(kernel, shape, blocks)
    hbm = float(steps * block_bytes(kernel, blocks))
    flops = float(_lanes(kernel, shape, blocks)) * \
        KERNELS[kernel]["flops_per_lane"]
    compute = max(hbm / HBM_BW, flops / PEAK_FLOPS)
    return compute + steps * _STEP_OVERHEAD.get(backend,
                                                _DEFAULT_STEP_OVERHEAD)


def cache_key(kernel: str, backend: str, key: Mapping[str, int]) -> str:
    dims = KERNELS[kernel]["key_dims"]
    missing = [d for d in dims if d not in key]
    if missing:
        raise KeyError(f"{kernel} cache key needs dims {dims}; "
                       f"missing {missing}")
    # even-normalized so a kernel and its packed twin (width rounded up to
    # even at pack time) resolve the same entry -> same reduction blocks
    parts = ",".join(f"{d}={_even(key[d])}" for d in dims)
    return f"{kernel}|{backend}|{parts}"


def tune(kernel: str, shape: Mapping[str, int], backend: str, *,
         budget: int = VMEM_BLOCK_BUDGET,
         intermediate_budget: int = INTERMEDIATE_BUDGET) -> Dict:
    """Exhaustively score the candidate grid for one (kernel, shape,
    backend) and return a cache entry for the best block choice."""
    spec = KERNELS[kernel]
    missing = [d for d in spec["dims"] if d not in shape]
    if missing:
        raise KeyError(f"{kernel} tuning shape needs dims {spec['dims']}; "
                       f"missing {missing}")
    shape = {d: int(shape[d]) for d in spec["dims"]}
    names = tuple(spec["candidates"])
    best = None
    for combo in itertools.product(*(spec["candidates"][n] for n in names)):
        blocks = dict(zip(names, combo))
        bb = block_bytes(kernel, blocks)
        if bb > budget:
            continue
        if _intermediate_bytes(kernel, blocks) > intermediate_budget:
            continue
        t = model_time_s(kernel, shape, blocks, backend)
        steps = _grid_steps(kernel, shape, blocks)
        rank = (t, steps, bb, tuple(blocks[n] for n in names))
        if best is None or rank < best[0]:
            best = (rank, blocks, bb, steps, t)
    if best is None:
        raise ValueError(f"no {kernel} candidate fits the {budget}-byte "
                         f"block budget")
    _, blocks, bb, steps, t = best
    defaults = dict(spec["defaults"])
    default_t = model_time_s(kernel, shape, defaults, backend)
    if t > default_t:
        # Every feasible candidate models slower than the defaults (this
        # happens when the defaults themselves sit outside the candidate
        # budgets, e.g. the sample kernel's [bq, bt, bp, bu] cross over
        # INTERMEDIATE_BUDGET).  The defaults are what an uncached launch
        # runs anyway, so cache *them*: the entry stays self-consistent
        # (model.time_s == model.default_time_s) instead of pinning a
        # strictly worse-modeled block set.
        blocks = defaults
        bb = block_bytes(kernel, blocks)
        steps, t = _grid_steps(kernel, shape, blocks), default_t
    return {
        "kernel": kernel,
        "backend": backend,
        "key": {d: _even(shape[d]) for d in spec["key_dims"]},
        "blocks": blocks,
        "block_shapes": [[c, list(s)] for c, s in
                         _block_shapes(kernel, blocks)],
        "block_bytes": bb,
        "budget_bytes": budget,
        "shape": shape,
        "model": {
            "grid_steps": steps,
            "time_s": t,
            "default_grid_steps": _grid_steps(kernel, shape, defaults),
            "default_time_s": default_t,
        },
    }


# ---------------------------------------------------------------------------
# Cache I/O + launch-time resolution
# ---------------------------------------------------------------------------
def cache_path(path: Optional[os.PathLike] = None) -> pathlib.Path:
    if path is not None:
        return pathlib.Path(path)
    env = os.environ.get(CACHE_ENV)
    return pathlib.Path(env) if env else DEFAULT_CACHE


@functools.lru_cache(maxsize=8)
def _load_cache_cached(path_str: str, mtime_ns: int) -> Dict[str, Dict]:
    with open(path_str, "r", encoding="utf-8") as f:
        data = json.load(f)
    out = {}
    for entry in data.get("entries", []):
        out[cache_key(entry["kernel"], entry["backend"], entry["key"])] = entry
    return out


def load_cache(path: Optional[os.PathLike] = None) -> Dict[str, Dict]:
    """Cache entries keyed by :func:`cache_key`; {} when no cache file."""
    p = cache_path(path)
    try:
        stat = p.stat()
    except OSError:
        return {}
    return _load_cache_cached(str(p), stat.st_mtime_ns)


def save_cache(entries: Iterable[Dict],
               path: Optional[os.PathLike] = None) -> pathlib.Path:
    """Merge entries into the cache file (same key replaces) and rewrite it
    deterministically (sorted keys) so the committed artifact diffs clean."""
    p = cache_path(path)
    merged = dict(load_cache(p))
    for entry in entries:
        merged[cache_key(entry["kernel"], entry["backend"],
                         entry["key"])] = entry
    payload = {"version": 1,
               "entries": [merged[k] for k in sorted(merged)]}
    p.write_text(json.dumps(payload, indent=1, sort_keys=True) + "\n",
                 encoding="utf-8")
    _load_cache_cached.cache_clear()
    return p


def resolve(kernel: str, backend: str, key: Mapping[str, int], *,
            clamp: Optional[Mapping[str, Tuple[int, int]]] = None,
            path: Optional[os.PathLike] = None) -> Dict[str, int]:
    """Block kwargs for one launch, or {} to mean "use the defaults".

    ``key`` holds the kernel's reduction dims (see ``KERNELS[...]
    ["key_dims"]``).  ``clamp`` maps row-dim block names to ``(dim_size,
    tile_base)``: a tuned row block is cut down to the launch's padded row
    count so cache entries tuned at corpus scale never slow small test
    launches -- row dims are sliced-off padding, so this cannot change any
    per-element result.  Reduction dims are returned exactly as tuned.
    """
    if os.environ.get(DISABLE_ENV):
        return {}
    entry = load_cache(path).get(cache_key(kernel, backend, key))
    if not entry:
        return {}
    blocks = {k: int(v) for k, v in entry["blocks"].items()}
    for name, (dim, base) in (clamp or {}).items():
        if name in blocks:
            blocks[name] = min(blocks[name], _ceil_to(dim, base))
    return blocks


def clear_resolve_cache() -> None:
    """Test hook: drop the mtime-keyed cache-file memoization."""
    _load_cache_cached.cache_clear()


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------
def _parse_shape(text: str) -> Dict[str, int]:
    out = {}
    for part in text.split(","):
        name, _, val = part.partition("=")
        if not val:
            raise argparse.ArgumentTypeError(
                f"shape must be dim=int[,dim=int...]; got {text!r}")
        out[name.strip()] = int(val)
    return out


# Default tuning shapes: the perf_sketch.py serving geometries at the
# sketch widths the repo actually launches (dataset-search m, bench m).
_DEFAULT_SHAPES = {
    "estimate_fields": ({"G": 6, "Q": 16, "P": 4096, "m": 64},
                        {"G": 6, "Q": 16, "P": 4096, "m": 128},
                        {"G": 6, "Q": 16, "P": 4096, "m": 256}),
    "linear_estimate_fields": ({"G": 6, "R": 5, "Q": 16, "P": 4096,
                                "W": 128},),
    "sample_estimate_fields": ({"G": 6, "Q": 16, "P": 4096, "S": 100},
                               {"G": 6, "Q": 16, "P": 4096, "S": 400}),
    "icws_sketch": ({"B": 48, "m": 128, "N": 256},
                    {"B": 48, "m": 256, "N": 256},
                    {"B": 48, "m": 64, "N": 4096}),
    "dmh_sketch": ({"B": 48, "m": 64, "N": 4096},
                   {"B": 48, "m": 128, "N": 256},
                   {"B": 48, "m": 256, "N": 256},
                   {"B": 16, "m": 66, "N": 1024},
                   {"B": 16, "m": 266, "N": 1024}),
}


def _check_report(entries: Sequence[Dict], report_path: str) -> list:
    """Cross-check tuned entries against a --budget-report artifact: the
    report must know the group's kernel, and the tuned block bytes must fit
    the report's budget.  Returns human-readable problem strings."""
    with open(report_path, "r", encoding="utf-8") as f:
        report = json.load(f)
    rows = report if isinstance(report, list) else report.get("report", [])
    by_kernel = {r.get("kernel"): r for r in rows}
    problems = []
    for entry in entries:
        rk = KERNELS[entry["kernel"]]["report_kernel"]
        row = by_kernel.get(rk)
        if row is None:
            problems.append(f"{entry['kernel']}: kernel {rk!r} not in "
                            f"budget report {report_path}")
            continue
        budget = int(row.get("budget_bytes", VMEM_BLOCK_BUDGET))
        if entry["block_bytes"] > budget:
            problems.append(
                f"{entry['kernel']}: tuned blocks {entry['block_bytes']}B "
                f"exceed report budget {budget}B")
    return problems


def main(argv: Optional[Sequence[str]] = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.roofline.autotune",
        description="Tune Pallas block sizes from the roofline model and "
                    "persist them to the block cache.")
    parser.add_argument("--kernel", action="append", choices=sorted(KERNELS),
                        help="kernel group to tune (repeatable; default all)")
    parser.add_argument("--shape", action="append", type=_parse_shape,
                        help="tuning shape as dim=int,... (repeatable; "
                             "requires exactly one --kernel)")
    parser.add_argument("--backend", default="cpu",
                        help="jax backend the entries are for (default cpu)")
    parser.add_argument("--report",
                        help="vmem-budget-report JSON to cross-check against "
                             "(from python -m repro.analysis --budget-report)")
    parser.add_argument("--out", help="cache file to update "
                                      f"(default {DEFAULT_CACHE})")
    parser.add_argument("--dry-run", action="store_true",
                        help="print entries without writing the cache")
    args = parser.parse_args(argv)

    kernels = args.kernel or sorted(KERNELS)
    if args.shape and len(kernels) != 1:
        parser.error("--shape requires exactly one --kernel")
    entries = []
    for kernel in kernels:
        shapes = args.shape or _DEFAULT_SHAPES[kernel]
        for shape in shapes:
            entries.append(tune(kernel, shape, args.backend))
    if args.report:
        problems = _check_report(entries, args.report)
        if problems:
            for p in problems:
                print(f"autotune: {p}")
            return 1
    for entry in entries:
        model = entry["model"]
        print(f"{cache_key(entry['kernel'], entry['backend'], entry['key'])}"
              f": {entry['blocks']} "
              f"steps {model['default_grid_steps']} -> {model['grid_steps']}"
              f" ({entry['block_bytes']}B of {entry['budget_bytes']}B)")
    if not args.dry_run:
        path = save_cache(entries, args.out)
        print(f"wrote {len(entries)} entries -> {path}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
