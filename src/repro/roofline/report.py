"""Generate the EXPERIMENTS.md dry-run + roofline tables from cell JSONs."""
from __future__ import annotations

import json
from pathlib import Path
from typing import List, Optional


def load_records(dirpath) -> List[dict]:
    recs = []
    for f in sorted(Path(dirpath).glob("*.json")):
        recs.append(json.loads(f.read_text()))
    return recs


def _fmt_bytes(b):
    return f"{b / 2**30:.1f}"


def roofline_table(recs: List[dict], multi_pod: Optional[bool] = None) -> str:
    lines = [
        "| arch | shape | mesh | compute_s | memory_s | collective_s | "
        "dominant | useful | roofline_frac | peak GB/dev | fits 16GB |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r["status"] != "ok":
            continue
        if multi_pod is not None and r["multi_pod"] != multi_pod:
            continue
        rl, mem = r["roofline"], r["memory"]
        peak = mem["peak_bytes_per_device"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | "
            f"{'2x16x16' if r['multi_pod'] else '16x16'} | "
            f"{rl['compute_s']:.3f} | {rl['memory_s']:.3f} | "
            f"{rl['collective_s']:.3f} | {rl['dominant']} | "
            f"{rl['useful_ratio']:.2f} | {rl['roofline_fraction']:.4f} | "
            f"{_fmt_bytes(peak)} | {'yes' if peak < 16 * 2**30 else 'NO'} |")
    return "\n".join(lines)


def skip_table(recs: List[dict]) -> str:
    lines = ["| arch | shape | reason |", "|---|---|---|"]
    seen = set()
    for r in recs:
        if r["status"] == "skipped" and (r["arch"], r["shape"]) not in seen:
            seen.add((r["arch"], r["shape"]))
            lines.append(f"| {r['arch']} | {r['shape']} | {r['reason']} |")
    return "\n".join(lines)


def dryrun_summary(recs: List[dict]) -> str:
    ok = sum(1 for r in recs if r["status"] == "ok")
    skipped = sum(1 for r in recs if r["status"] == "skipped")
    err = sum(1 for r in recs if r["status"] == "error")
    comp = [r["compile_seconds"] for r in recs if r["status"] == "ok"]
    return (f"{ok} cells lower+compile OK, {skipped} skipped "
            f"(per-spec inapplicable), {err} errors; compile time "
            f"min/median/max = {min(comp):.0f}/{sorted(comp)[len(comp)//2]:.0f}/"
            f"{max(comp):.0f}s per cell on one CPU core with 512 host devices.")


def collective_detail(recs: List[dict], arch: str, shape: str,
                      multi_pod=False) -> str:
    for r in recs:
        if (r["arch"], r["shape"], r["multi_pod"]) == (arch, shape, multi_pod) \
                and r["status"] == "ok":
            kinds = r["hlo_counts"]["collectives_by_kind"]
            return ", ".join(f"{k}: {v/2**30:.1f} GB/dev"
                             for k, v in sorted(kinds.items(),
                                                key=lambda kv: -kv[1]))
    return "n/a"


if __name__ == "__main__":
    import sys
    d = sys.argv[1] if len(sys.argv) > 1 else "experiments/dryrun"
    recs = load_records(d)
    print(dryrun_summary(recs))
    print()
    print(roofline_table(recs, multi_pod=False))
