"""HLO-text roofline extraction with while-loop trip-count correction.

``compiled.cost_analysis()`` counts each ``while`` body ONCE (verified: a
10-iteration scanned matmul reports 1x the body FLOPs), so any scan-based
stack (layers, microbatches, flash-attention chunks) is undercounted.  This
module parses ``compiled.as_text()`` instead:

  1. split the module into computations; build a symbol table
     (instruction name -> byte size of its shape),
  2. find every ``while`` op, extract the trip count from the loop-condition
     computation (jax scans lower to ``counter < constant``), and propagate
     multipliers down the call graph (nested scans multiply),
  3. FLOPs: every ``dot`` contributes 2 * result_elements * contracted_dim
     (x multiplier) -- matmuls dominate; elementwise is roofline noise.
     Remat recompute IS visible here (the recomputed dots exist in the HLO),
     which is exactly what the MODEL_FLOPS/HLO_FLOPS usefulness ratio needs,
  4. HBM bytes: per top-level instruction, operands + result bytes
     (x multiplier) -- the post-fusion HLO reads each fusion input once and
     writes its output once, so this is a faithful traffic model,
  5. collective bytes: same accounting restricted to all-gather / all-reduce /
     reduce-scatter / all-to-all / collective-permute (+ their async -start
     forms; -done twins are skipped to avoid double counting).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s+\(.*\)\s*->")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_CONST_RE = re.compile(r"=\s*[su]\d+\[\]\s+constant\((\d+)\)")
_WHILE_RE = re.compile(r"condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_DOT_DIMS_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")


def shape_bytes(shape_str: str) -> int:
    """Bytes of an HLO shape string (tuples summed)."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def shape_elems_and_dims(shape_str: str) -> Tuple[int, List[int]]:
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return 0, []
    dims = [int(d) for d in m.group(2).split(",") if d] if m.group(2) else []
    n = 1
    for d in dims:
        n *= d
    return n, dims


@dataclasses.dataclass
class Instr:
    name: str
    shape: str
    op: str
    line: str
    args: str = ""


def _balanced(s: str, start: int) -> int:
    """Index just past the paren group opening at s[start] ('(')."""
    depth = 0
    for i in range(start, len(s)):
        if s[i] == "(":
            depth += 1
        elif s[i] == ")":
            depth -= 1
            if depth == 0:
                return i + 1
    return len(s)


def parse_instr_line(line: str) -> Optional[Instr]:
    """Parse `%name = SHAPE op(args), attrs...`.

    Tuple shapes may contain `/*index=N*/` comments (hence '='), so this
    walks balanced parens instead of regexing.
    """
    s = line.strip()
    if s.startswith("ROOT "):
        s = s[5:]
    if not s.startswith("%"):
        return None
    eq = s.find(" = ")
    if eq < 0:
        return None
    name = s[1:eq].strip()
    rest = s[eq + 3:]
    if rest.startswith("("):
        end = _balanced(rest, 0)
        shape = rest[:end]
        rest2 = rest[end:].lstrip()
    else:
        sp = rest.find(" ")
        if sp < 0:
            return None
        shape = rest[:sp]
        rest2 = rest[sp + 1:].lstrip()
    par = rest2.find("(")
    if par < 0:
        return None
    op = rest2[:par].strip()
    if not op or any(c in op for c in "={}%"):
        return None
    args_end = _balanced(rest2, par)
    args = rest2[par + 1:args_end - 1]
    return Instr(name=name, shape=shape, op=op, line=line, args=args)


@dataclasses.dataclass
class Computation:
    name: str
    instrs: List[Instr]


def parse_computations(hlo: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    entry_name = ""
    current: Optional[Computation] = None
    for line in hlo.splitlines():
        if line and not line[0].isspace() and "->" in line and line.rstrip().endswith("{"):
            mc = _COMP_RE.match(line)
            if mc:
                current = Computation(name=mc.group(2), instrs=[])
                comps[current.name] = current
                if mc.group(1):
                    entry_name = current.name
                continue
        if current is None:
            continue
        ins = parse_instr_line(line)
        if ins is not None:
            current.instrs.append(ins)
    return comps


def _trip_count_for_while(line: str, comps: Dict[str, Computation]) -> int:
    """Prefer the compiler's known_trip_count; fall back to the condition
    computation's `lt(counter, constant(N))` bound."""
    mt = _TRIP_RE.search(line)
    if mt:
        return int(mt.group(1))
    mw = _WHILE_RE.search(line)
    if mw and mw.group(1) in comps:
        consts = []
        for ins in comps[mw.group(1)].instrs:
            m = _CONST_RE.search(ins.line)
            if m:
                consts.append(int(m.group(1)))
        if consts:
            return max(consts)
    return 1


def computation_multipliers(comps: Dict[str, Computation],
                            entry: str) -> Dict[str, int]:
    """Effective execution count per computation (nested whiles multiply)."""
    mult: Dict[str, int] = {}

    def visit(name: str, m: int):
        if name not in comps or not isinstance(comps[name], Computation):
            return
        mult[name] = mult.get(name, 0) + m
        for ins in comps[name].instrs:
            if ins.op == "while":
                mw = _WHILE_RE.search(ins.line)
                if not mw:
                    continue
                tc = _trip_count_for_while(ins.line, comps)
                visit(mw.group(2), m * max(tc, 1))
            elif ins.op in ("fusion", "call", "conditional", "custom-call"):
                for sub in re.findall(r"(?:calls|to_apply|called_computations)="
                                      r"\{?%?([\w\.\-]+)", ins.line):
                    if sub in comps and sub != name:
                        visit(sub, m)

    visit(entry, 1)
    return mult


def find_entry(hlo: str, comps: Dict[str, Computation]) -> str:
    m = re.search(r"^ENTRY\s+%?([\w\.\-]+)", hlo, re.MULTILINE)
    if m and m.group(1) in comps:
        return m.group(1)
    # fallback: the computation not referenced by any other
    referenced = set()
    for c in comps.values():
        if not isinstance(c, Computation):
            continue
        for ins in c.instrs:
            referenced.update(_OPERAND_RE.findall(ins.line.split("=", 1)[-1]))
    for name, c in comps.items():
        if isinstance(c, Computation) and name not in referenced \
                and not name.startswith("__"):
            return name
    return next(iter(comps))


_SKIP_OPS = {"parameter", "constant", "tuple", "get-tuple-element", "bitcast",
             "copy-start", "copy-done", "after-all", "partition-id",
             "replica-id", "iota", "while", "conditional", "call"}

# Ops that read only a slice of their (possibly huge) first operand: count
# the moved bytes, not the buffer size.  Critical for scan-over-layers, where
# every iteration dynamic-slices one layer out of the stacked parameters.
_SLICE_READS = {"dynamic-slice", "slice", "gather"}
_SLICE_WRITES = {"dynamic-update-slice", "scatter"}


def _fusion_operand_bytes(comps, called: str, operand_names, sym) -> Optional[int]:
    """Slice-aware operand traffic for a fusion: if parameter(i) of the called
    computation is consumed ONLY by slice-type ops, charge the slice sizes."""
    if called not in comps:
        return None
    c = comps[called]
    params: Dict[int, str] = {}
    for ins in c.instrs:
        if ins.op == "parameter":
            m = re.match(r"(\d+)", ins.args.strip())
            if m:
                params[int(m.group(1))] = ins.name
    total = 0
    for i, oname in enumerate(operand_names):
        full = sym.get(oname, 0)
        pname = params.get(i)
        if pname is None:
            total += full
            continue
        uses = [ins for ins in c.instrs
                if re.search(r"%" + re.escape(pname) + r"\b", ins.args)]
        if uses and all(u.op in _SLICE_READS | _SLICE_WRITES for u in uses):
            sliced = 0
            for u in uses:
                if u.op in _SLICE_READS:
                    sliced += shape_bytes(u.shape)
                else:  # dus: charge the update operand
                    ops_in = _OPERAND_RE.findall(u.args)
                    if len(ops_in) > 1:
                        upd = next((ii.shape for ii in c.instrs
                                    if ii.name == ops_in[1]), "")
                        sliced += shape_bytes(upd)
            total += min(sliced, full) if full else sliced
        else:
            total += full
    return total


@dataclasses.dataclass
class RooflineCounts:
    flops: float                 # corrected dot FLOPs
    hbm_bytes: float             # corrected operand+result traffic
    collective_bytes: float      # corrected collective operand bytes
    collectives: Dict[str, float]  # per-op-kind bytes
    while_trip_counts: List[int]


def analyze_hlo(hlo: str) -> RooflineCounts:
    comps = parse_computations(hlo)
    entry = find_entry(hlo, comps)
    mult = computation_multipliers(comps, entry)

    # global symbol table name -> byte size (names unique within module dumps)
    sym: Dict[str, int] = {}
    for c in comps.values():
        for ins in c.instrs:
            sym[ins.name] = shape_bytes(ins.shape)

    # computations inlined into a fusion: no HBM traffic of their own
    fusion_bodies = set()
    for c in comps.values():
        for ins in c.instrs:
            if ins.op == "fusion":
                mcalled = re.search(r"calls=\{?%?([\w\.\-]+)", ins.line)
                if mcalled:
                    fusion_bodies.add(mcalled.group(1))

    flops = 0.0
    hbm = 0.0
    coll = 0.0
    coll_by: Dict[str, float] = {}
    trips: List[int] = []

    for c in comps.values():
        m = mult.get(c.name, 0)
        if m == 0:
            continue
        in_fusion = c.name in fusion_bodies
        for ins in c.instrs:
            if ins.op == "while":
                trips.append(_trip_count_for_while(ins.line, comps))
                continue
            if ins.op in _SKIP_OPS:
                continue
            operand_names = _OPERAND_RE.findall(ins.args)
            op_bytes = sum(sym.get(o, 0) for o in operand_names)
            out_bytes = shape_bytes(ins.shape)

            if ins.op == "dot":
                out_elems, _ = shape_elems_and_dims(ins.shape)
                md = _DOT_DIMS_RE.search(ins.line)
                kdim = 1
                if md and operand_names:
                    lhs = operand_names[0]
                    lhs_shape = next((i.shape for cc in comps.values()
                                      for i in cc.instrs if i.name == lhs), "")
                    _, dims = shape_elems_and_dims(lhs_shape)
                    for ci in md.group(1).split(","):
                        if ci and int(ci) < len(dims):
                            kdim *= dims[int(ci)]
                flops += m * 2.0 * out_elems * max(kdim, 1)

            if in_fusion:
                continue  # traffic accounted by the enclosing fusion op

            if ins.op in _SLICE_READS:
                traffic = 2 * out_bytes                  # read slice + write it
            elif ins.op in _SLICE_WRITES:
                upd = sym.get(operand_names[1], 0) if len(operand_names) > 1 else 0
                traffic = 2 * upd                        # read update + write slot
            elif ins.op == "fusion":
                called = re.search(r"calls=\{?%?([\w\.\-]+)", ins.line)
                fb = _fusion_operand_bytes(comps, called.group(1),
                                           operand_names, sym) if called else None
                out_charge = out_bytes
                if called and called.group(1) in comps:
                    # in-place update fusions write the slice, not the buffer
                    croot = comps[called.group(1)].instrs
                    dus = [ii for ii in croot if ii.op in _SLICE_WRITES]
                    if dus:
                        upd_bytes = 0
                        for u in dus:
                            rops = _OPERAND_RE.findall(u.args)
                            if len(rops) > 1:
                                upd = next((ii.shape for ii in croot
                                            if ii.name == rops[1]), "")
                                upd_bytes += shape_bytes(upd)
                        if upd_bytes:
                            out_charge = min(out_bytes, upd_bytes)
                traffic = (fb if fb is not None else op_bytes) + out_charge
            else:
                traffic = op_bytes + out_bytes
            hbm += m * traffic

            base = ins.op[:-6] if ins.op.endswith("-start") else ins.op
            if base in COLLECTIVE_OPS and not ins.op.endswith("-done"):
                coll += m * op_bytes
                coll_by[base] = coll_by.get(base, 0.0) + m * op_bytes

    return RooflineCounts(flops=flops, hbm_bytes=hbm, collective_bytes=coll,
                          collectives=coll_by, while_trip_counts=sorted(trips))
