"""Dataset search end to end -- the paper's Section 1.3 scenario.

An analyst holds a (date -> taxi rides) table and wants to discover, from
sketches alone, which tables in a data lake are joinable AND meaningfully
correlated.  We build a sketch index over a lake of synthetic tables
(weather, festivals, unrelated junk with disjoint keys), then answer the
query without materializing a single join.

The serving path is device-resident: every table is sketched through the
Pallas ICWS kernel into pre-stacked [P, m] corpus arrays, and the query is
estimated against the whole corpus with the one-vs-many estimate kernel
(the query sketch is broadcast on device -- never tiled into a [P, m]
copy).  The original host-numpy WMH implementation is kept as an oracle;
we cross-check against it at the end.

Run:  PYTHONPATH=src python examples/dataset_search.py
"""
import numpy as np

from repro.data import DatasetSearchIndex


def main():
    rng = np.random.default_rng(0)
    days = np.arange(0, 730)                     # two years of dates
    # latent weather drives ridership down on rainy days
    rain = np.clip(rng.gamma(2.0, 2.0, size=730) - 2, 0, None)
    ridership = 120_000 - 6_000 * rain + rng.normal(0, 4_000, 730)

    index = DatasetSearchIndex(m=384, seed=7)    # backend="device" by default
    # lake tables -----------------------------------------------------------
    index.add_table("weather_precipitation", days, rain)              # joinable + correlated
    index.add_table("festivals_2022", days[365:],                     # partial join
                    (rng.random(365) < 0.05).astype(float))
    index.add_table("stock_prices", np.arange(10_000, 10_730),        # disjoint keys
                    rng.normal(100, 5, 730))
    index.add_table("random_noise", days, rng.normal(0, 1, 730))      # joinable, uncorrelated
    # taxi logs keyed by day, multiple trips per day: duplicate join keys
    trip_days = rng.integers(0, 730, size=2000)
    index.add_table("taxi_trip_fares", trip_days, rng.uniform(5, 60, 2000))
    print(f"lake indexed: {len(index.tables)} tables, "
          f"{index.storage_doubles():.0f} doubles of sketch storage total\n")

    # the analyst's query (served from the device-resident corpus) ----------
    results = index.query(days, ridership, top_k=5, min_join=30)
    print(f"{'table':<26}{'join_size':>10}{'joinability':>12}{'corr':>8}")
    for r in results:
        print(f"{r.name:<26}{r.join_size:>10.0f}{r.joinability:>12.2f}{r.corr:>8.3f}")

    true_corr = np.corrcoef(rain, ridership)[0, 1]
    est = next(r for r in results if r.name == "weather_precipitation")
    print(f"\nweather vs ridership: true corr = {true_corr:.3f}, "
          f"sketch-estimated = {est.corr:.3f}")
    print("(estimated from sketches alone -- no join was ever materialized)")

    # cross-check the device serving path against the host oracle -----------
    oracle = index.query(days, ridership, top_k=5, min_join=30, backend="host")
    print("\ndevice vs host-oracle ranking:",
          [r.name for r in results] == [r.name for r in oracle] and "MATCH"
          or f"device={[r.name for r in results]} host={[r.name for r in oracle]}")


if __name__ == "__main__":
    main()
