"""Dataset search end to end -- the paper's Section 1.3 scenario.

An analyst holds a (date -> taxi rides) table and wants to discover, from
sketches alone, which tables in a data lake are joinable AND meaningfully
correlated.  We build a WMH sketch index over a lake of synthetic tables
(weather, festivals, unrelated junk with disjoint keys), then answer the
query without materializing a single join.

Run:  PYTHONPATH=src python examples/dataset_search.py
"""
import numpy as np

from repro.data import DatasetSearchIndex


def main():
    rng = np.random.default_rng(0)
    days = np.arange(0, 730)                     # two years of dates
    # latent weather drives ridership down on rainy days
    rain = np.clip(rng.gamma(2.0, 2.0, size=730) - 2, 0, None)
    ridership = 120_000 - 6_000 * rain + rng.normal(0, 4_000, 730)

    index = DatasetSearchIndex(m=384, seed=7)
    # lake tables -----------------------------------------------------------
    index.add_table("weather_precipitation", days, rain)              # joinable + correlated
    index.add_table("festivals_2022", days[365:],                     # partial join
                    (rng.random(365) < 0.05).astype(float))
    index.add_table("stock_prices", np.arange(10_000, 10_730),        # disjoint keys
                    rng.normal(100, 5, 730))
    index.add_table("random_noise", days, rng.normal(0, 1, 730))      # joinable, uncorrelated
    print(f"lake indexed: {len(index.tables)} tables, "
          f"{index.storage_doubles():.0f} doubles of sketch storage total\n")

    # the analyst's query ----------------------------------------------------
    results = index.query(days, ridership, top_k=5, min_join=30)
    print(f"{'table':<26}{'join_size':>10}{'joinability':>12}{'corr':>8}")
    for r in results:
        print(f"{r.name:<26}{r.join_size:>10.0f}{r.joinability:>12.2f}{r.corr:>8.3f}")

    true_corr = np.corrcoef(rain, ridership)[0, 1]
    est = next(r for r in results if r.name == "weather_precipitation")
    print(f"\nweather vs ridership: true corr = {true_corr:.3f}, "
          f"sketch-estimated = {est.corr:.3f}")
    print("(estimated from sketches alone -- no join was ever materialized)")


if __name__ == "__main__":
    main()
