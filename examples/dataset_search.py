"""Dataset search end to end -- the paper's Section 1.3 scenario.

An analyst holds a (date -> taxi rides) table and wants to discover, from
sketches alone, which tables in a data lake are joinable AND meaningfully
correlated.  We build a sketch index over a lake of synthetic tables
(weather, festivals, unrelated junk with disjoint keys), then answer the
query without materializing a single join.

The serving path is device-resident: every table is sketched through the
Pallas ICWS kernel into ONE canonical field-stacked corpus store
(``[3, capacity, m]`` buffers, amortized in-place append -- the single
device copy of all three field corpora), and each query is answered by one
fused multi-field estimate launch straight off those buffers.  The original
host-numpy WMH implementation is kept as an oracle; we cross-check against
it, and then re-serve the same query *sharded*: corpus rows split over a
2-device ``data`` mesh axis (forced host devices below), per-shard top-k +
global merge, rankings bitwise identical to the single-device path.

``--family`` picks the serving sketch family (any registered
``repro.data.FAMILY_NAMES`` entry): the same lake is sketched into a
CountSketch / JL corpus (dense device tables, MXU estimate matmuls), a
Threshold / Priority Sampling corpus (fixed-slot coordinate samples,
key-match estimate kernel), or a DMH corpus (constant-time densified
weighted MinHash ingest, same wire layout as ICWS), all storage-matched
to the ICWS budget; ``all`` serves the identical query under every family
side by side -- the paper's comparison plus its strongest competitors,
live on the serving path.

``--shards N`` rebuilds the lake via the shard-and-merge parallel build
path (``repro.data.merge``): every table is key-partitioned into N
disjoint shards, each shard is sketched independently -- the part a
parallel build distributes across hosts -- and the shard corpora compact
through a pairwise merge tree before serving.  The demo re-answers the
query off the sharded build and compares the ranking to the single-stream
index.

Run:  PYTHONPATH=src python examples/dataset_search.py [--family all]
                                                       [--shards 4]
"""
import argparse
import os

# force 2 CPU "devices" so the sharded serving path is demonstrable on a
# laptop; must be set before jax first initializes, and appended (not
# setdefault) so a user's own XLA_FLAGS don't silently disable the demo
if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=2"
                               ).strip()

import numpy as np

from repro.data import FAMILY_NAMES, DatasetSearchIndex
from repro.launch.mesh import make_corpus_mesh


def lake_tables(rng, days, rain):
    # taxi logs keyed by day, multiple trips per day: duplicate join keys
    trip_days = rng.integers(0, 730, size=2000)
    return [
        ("weather_precipitation", days, rain),            # joinable + correlated
        ("festivals_2022", days[365:],                    # partial join
         (rng.random(365) < 0.05).astype(float)),
        ("stock_prices", np.arange(10_000, 10_730),       # disjoint keys
         rng.normal(100, 5, 730)),
        ("random_noise", days, rng.normal(0, 1, 730)),    # joinable, uncorrelated
        ("taxi_trip_fares", trip_days, rng.uniform(5, 60, 2000)),
    ]


def build_index(tables, mesh=None, family="icws"):
    index = DatasetSearchIndex(m=384, seed=7, mesh=mesh, family=family,
                               keep_host_oracle=(family == "icws"))
    for name, keys, values in tables:
        index.add_table(name, keys, values)
    return index


def print_results(results):
    print(f"{'table':<26}{'join_size':>10}{'joinability':>12}{'corr':>8}")
    for r in results:
        print(f"{r.name:<26}{r.join_size:>10.0f}"
              f"{r.joinability:>12.2f}{r.corr:>8.3f}")


def family_comparison(tables, days, ridership, families):
    """Serve the identical lake + query under several sketch families.

    Every index is storage-matched (one ICWS budget sizes the CS width /
    JL dimension via the registry accounting), so differences in the
    rankings and join-size estimates are the sketches' doing -- the
    paper's §1.3 comparison, answered by the device corpora."""
    for family in families:
        index = build_index(tables, family=family)
        print(f"\n--- family={family} "
              f"({index.storage_doubles():.0f} doubles of sketch storage) ---")
        print_results(index.query(days, ridership, top_k=5, min_join=30))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--family", default="icws",
                    choices=(*FAMILY_NAMES, "all"),
                    help="serving sketch family; 'all' serves the same "
                         "corpus under every family side by side")
    ap.add_argument("--shards", type=int, default=0, metavar="N",
                    help="also build the lake via an N-way shard-and-merge "
                         "parallel build and compare its ranking to the "
                         "single-stream index")
    args = ap.parse_args()
    rng = np.random.default_rng(0)
    days = np.arange(0, 730)                     # two years of dates
    # latent weather drives ridership down on rainy days
    rain = np.clip(rng.gamma(2.0, 2.0, size=730) - 2, 0, None)
    ridership = 120_000 - 6_000 * rain + rng.normal(0, 4_000, 730)

    tables = lake_tables(rng, days, rain)
    if args.family != "icws":
        # the same corpus served under other sketch families (or all of
        # them): the paper's comparison live on the device serving path,
        # enumerated from the family registry so new families show up here
        # without touching the demo
        families = (FAMILY_NAMES if args.family == "all"
                    else (args.family,))
        family_comparison(tables, days, ridership, families)
        return

    index = build_index(tables)                  # backend="device" by default
    store = index.store
    print(f"lake indexed: {len(index.tables)} tables in one canonical "
          f"[3, {store.capacity}, {index.m}] store "
          f"({index.storage_doubles():.0f} doubles of sketch storage)\n")

    # the analyst's query (served from the device-resident corpus) ----------
    results = index.query(days, ridership, top_k=5, min_join=30)
    print_results(results)

    true_corr = np.corrcoef(rain, ridership)[0, 1]
    est = next(r for r in results if r.name == "weather_precipitation")
    print(f"\nweather vs ridership: true corr = {true_corr:.3f}, "
          f"sketch-estimated = {est.corr:.3f}")
    print("(estimated from sketches alone -- no join was ever materialized)")

    # cross-check the device serving path against the host oracle -----------
    oracle = index.query(days, ridership, top_k=5, min_join=30, backend="host")
    print("\ndevice vs host-oracle ranking:",
          [r.name for r in results] == [r.name for r in oracle] and "MATCH"
          or f"device={[r.name for r in results]} host={[r.name for r in oracle]}")

    # shard-and-merge parallel lake build (repro.data.merge) ----------------
    if args.shards >= 2:
        shd = DatasetSearchIndex(m=384, seed=7, keep_host_oracle=False)
        shd.add_tables_sharded(tables, shards=args.shards)
        res_shd = shd.query(days, ridership, top_k=5, min_join=30)
        same = [r.name for r in res_shd] == [r.name for r in results]
        print(f"\n{args.shards}-way shard-and-merge build vs single-stream "
              f"ranking:", same and "MATCH"
              or f"sharded={[r.name for r in res_shd]}")

    # sharded serving: corpus rows split over a 2-device data axis ----------
    mesh = make_corpus_mesh()
    if mesh.shape["data"] < 2:
        print("sharded serving skipped: only 1 device visible "
              "(a pre-set device count override?)")
        return
    sharded = build_index(tables, mesh=mesh)
    res_sh = sharded.query(days, ridership, top_k=5, min_join=30)
    print(f"sharded ({mesh.shape['data']}-way) vs single-device serving:",
          res_sh == results and "IDENTICAL (bitwise)" or "DIVERGED")


if __name__ == "__main__":
    main()
