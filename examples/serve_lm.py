"""Batched serving example: the ServeEngine admits queued requests into a
fixed slot batch and decodes them together (static batching with slot
retirement -- the vLLM-style pattern at demo scale).

Run:  PYTHONPATH=src python examples/serve_lm.py
"""
import time

import jax

from repro import configs
from repro.models import Model
from repro.serve.engine import Request, ServeEngine


def main():
    cfg = configs.reduced("tinyllama-1.1b")
    model = Model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(model, params, batch_slots=4, max_seq=64)

    reqs = [Request(rid=i, prompt=[1 + i, 2 + i, 3 + i], max_new_tokens=8)
            for i in range(6)]                      # 6 requests > 4 slots
    for r in reqs:
        engine.submit(r)

    t0 = time.time()
    ticks = 0
    while any(not r.done for r in reqs):
        engine.tick()
        ticks += 1
        if ticks > 200:
            raise RuntimeError("engine did not drain")
    dt = time.time() - t0

    total_tokens = sum(len(r.output) for r in reqs)
    print(f"served {len(reqs)} requests / {total_tokens} tokens "
          f"in {ticks} ticks ({dt:.2f}s, {total_tokens/dt:.1f} tok/s on CPU)")
    for r in reqs:
        print(f"  req {r.rid}: prompt={r.prompt} -> output={r.output}")


if __name__ == "__main__":
    main()
