"""Quickstart: sketch two sparse vectors, estimate their inner product.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (PAPER_METHODS, SparseVec, fact1_bound, inner_fast,
                        make, theorem2_bound)
from repro.data.synthetic import sparse_pair


def main():
    rng = np.random.default_rng(0)
    # two sparse vectors with 5% overlapping support -- the paper's regime
    a, b = sparse_pair(rng, n=10_000, nnz=2_000, overlap=0.05)
    true = inner_fast(a, b)
    storage = 400  # total 64-bit words per sketch, the paper's Fig 5 setting

    print(f"true <a,b> = {true:.4f}")
    print(f"Fact 1 scale  eps*||a||*||b||                = {fact1_bound(a, b):.2f}")
    print(f"Theorem 2 scale eps*max(||a_I||||b||, ...)   = {theorem2_bound(a, b):.2f}")
    print(f"(the gap is the paper's advantage: sqrt(gamma) with gamma = overlap)\n")

    scale = a.norm() * b.norm()
    print(f"{'method':<8}{'estimate':>12}{'err/(|a||b|)':>14}  note")
    for method in PAPER_METHODS + ("icws",):
        sk = make(method, storage, seed=1)
        est = sk.estimate(sk.sketch(a), sk.sketch(b))
        note = {"wmh": "the paper's method",
                "icws": "TPU-native WMH variant (ours)"}.get(method, "baseline")
        print(f"{method:<8}{est:>12.1f}{abs(est - true) / scale:>14.5f}  {note}")
    print("\n(err/(|a||b|) is the paper's Section-5 error metric; smaller is "
          "better.\n The sampling sketches' wins grow as overlap shrinks.)")


if __name__ == "__main__":
    main()
