"""Sketch-compressed data-parallel training (the paper's linear sketches as a
distributed-optimization feature) + WMH gradient telemetry.

Four simulated DP replicas train an embedding-style model (each batch touches
a few rows of a big table => sparse, low-overlap gradients -- the paper's
favorable regime, and what vocab/expert-row gradients look like).  The
gradient exchange runs in CountSketch space (tables + identified heavy-
hitter values on the wire) with error feedback.  The claim demonstrated is
the EF guarantee: **compressed training tracks uncompressed training**, at a
fraction of the exchanged bytes.

The same shard_map also computes the WMH-sketch pairwise gradient-agreement
matrix -- the divergence detector that repro.ft consumes.

Run:  PYTHONPATH=src python examples/gradient_compression.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.compat import make_mesh, shard_map
from repro.optim.compression import (CompressionConfig, compressed_update,
                                     compression_ratio)
from repro.train.telemetry import TelemetryConfig, gradient_agreement


def main():
    n, replicas, steps, lr = 2048, 4, 200, 8.0
    ccfg = CompressionConfig(width=256, reps=5, seed=11)
    tcfg = TelemetryConfig(m=256, seed=3)
    mesh = make_mesh((replicas,), ("data",))

    rng = np.random.default_rng(0)
    w_true = rng.normal(size=n).astype(np.float32)
    rows = rng.integers(0, n, size=(replicas, 128, 8))         # batch lookups
    X = np.zeros((replicas, 128, n), np.float32)
    for r in range(replicas):
        for b in range(128):
            X[r, b, rows[r, b]] = rng.normal(size=8)
    y = np.einsum("rbn,n->rb", X, w_true).astype(np.float32)
    covered = np.zeros(n, bool)
    covered[rows.reshape(-1)] = True                           # learnable rows

    def local_grad(w, Xr, yr):
        return Xr.T @ (Xr @ w - yr) / Xr.shape[0]

    def worker(w, r, Xr, yr):
        g = local_grad(w[0], Xr[0], yr[0])
        delta, new_r = compressed_update(g, r[0], "data", ccfg, lr=lr)
        return (w[0] - delta)[None], new_r[None]

    step = jax.jit(shard_map(
        worker, mesh=mesh,
        in_specs=(P("data", None), P("data", None), P("data", None, None),
                  P("data", None)),
        out_specs=(P("data", None), P("data", None)), check=False))

    def err_of(w):
        w = np.asarray(w)
        return float(np.linalg.norm(w[covered] - w_true[covered])
                     / np.linalg.norm(w_true[covered]))

    # uncompressed DP baseline (full gradients on the wire)
    Xa, ya = X.reshape(-1, n), y.reshape(-1)
    w_base = np.zeros(n, np.float32)
    base_curve = []
    for i in range(steps):
        g = Xa.T @ (Xa @ w_base - ya) / X.shape[1] / replicas
        w_base -= lr * g
        base_curve.append(err_of(w_base))

    # compressed DP
    w = jnp.zeros((replicas, n), jnp.float32)
    res = jnp.zeros((replicas, n), jnp.float32)
    Xj, yj = jnp.asarray(X), jnp.asarray(y)
    print(f"{'step':>5} {'uncompressed':>14} {'compressed':>12}")
    for i in range(steps):
        w, res = step(w, res, Xj, yj)
        if i % 40 == 0 or i == steps - 1:
            print(f"{i:>5} {base_curve[i]:>14.4f} {err_of(w[0]):>12.4f}")

    wire = ccfg.width * ccfg.reps
    print(f"\ncompressed tracks uncompressed with ~{compression_ratio(n, ccfg):.1f}x "
          f"fewer bytes on the wire\n({wire} sketch floats + heavy-hitter values "
          f"vs {n} gradient floats per replica per step)")

    # telemetry at step 0 (informative gradients): estimated pairwise cosines
    def telem(Xr, yr):
        g = local_grad(jnp.zeros(n), Xr[0], yr[0])
        return gradient_agreement(g, "data", tcfg)[None]

    sim = shard_map(telem, mesh=mesh,
                    in_specs=(P("data", None, None), P("data", None)),
                    out_specs=P("data", None, None),
                    check=False)(Xj, yj)
    print("\nsketch-estimated gradient agreement at step 0 (m=256 floats per "
          "replica on the wire,\n instead of full gradients; diagonal = self = 1):")
    print(np.array_str(np.asarray(sim)[0], precision=2, suppress_small=True))


if __name__ == "__main__":
    main()
