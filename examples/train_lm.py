"""End-to-end training driver: a ~100M-param llama-family model trained for a
few hundred steps on the deterministic synthetic token stream, with async
atomic checkpointing, preemption handling, and resumability.

Defaults are sized for this CPU container (a ~10M model, 60 steps); pass
``--full`` for the ~100M / 300-step configuration used on real hardware.

Run:  PYTHONPATH=src python examples/train_lm.py [--full] [--resume]
"""
import argparse
import dataclasses

from repro import configs
from repro.optim import AdamWConfig
from repro.train.trainer import Trainer, TrainerConfig


def model_for(full: bool):
    base = configs.reduced("tinyllama-1.1b")
    if full:
        # ~100M params: 12L x d768 (llama-family)
        return dataclasses.replace(
            base, num_layers=12, d_model=768, num_heads=12, num_kv_heads=4,
            head_dim=64, d_ff=2048, vocab_size=32000)
    # ~10M params for the CPU demo
    return dataclasses.replace(
        base, num_layers=4, d_model=256, num_heads=4, num_kv_heads=2,
        head_dim=64, d_ff=688, vocab_size=4096)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = model_for(args.full)
    steps = args.steps or (300 if args.full else 60)
    tcfg = TrainerConfig(
        steps=steps,
        global_batch=8 if not args.full else 32,
        seq=128 if not args.full else 512,
        microbatches=2,
        ckpt_dir=args.ckpt_dir,
        ckpt_every=max(steps // 3, 10),
        log_every=max(steps // 12, 1),
        opt=AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=steps),
    )
    print(f"model: {cfg.num_layers}L d{cfg.d_model} vocab {cfg.vocab_size} "
          f"(~{configs.get('tinyllama-1.1b').param_count()/1e9:.1f}B full-size arch, "
          f"reduced for this run)")
    trainer = Trainer(cfg, tcfg)
    trainer.preemption.install()
    hist = trainer.run()
    first, last = hist["loss"][0], hist["loss"][-1]
    print(f"\nloss: {first:.3f} -> {last:.3f} over {len(hist['loss'])} steps "
          f"({'improved' if last < first else 'NO IMPROVEMENT'})")
    print(f"checkpoints in {args.ckpt_dir} (restart me to resume from there)")


if __name__ == "__main__":
    main()
