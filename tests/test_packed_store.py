"""Bit-packed sketch storage: codec exactness, per-family wire layouts,
and the packed serving path's bitwise contracts.

The packed :class:`repro.data.store.CorpusStore` keeps each family's
bf16-halfword wire format (two truncated f32 values per int32 word,
decoded *inside* the estimate kernels) and must satisfy, per family:

  * pack -> unpack roundtrips every component (keys/fingerprints exactly,
    values to their bf16 truncation, idempotent from the first re-pack);
  * packed-path estimates == the unpacked path run on the bf16-roundtripped
    rows, BITWISE -- the layout saves bytes, it does not fork the math;
  * spare capacity rows of a packed store stay bitwise inert;
  * batched == sequential and tenant-scoped == dedicated on the packed
    serving path, same as the unpacked contracts;
  * packed bytes/row <= 60% of unpacked for ICWS (the tentpole gate) and
    <= 80% for the sampling families (31-bit keys are the information
    floor);
  * packed stores refuse to merge (the ICWS packed layout drops the
    argkeys re-leveling sidecar).
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import FAMILY_NAMES, make_family, wmh_storage
from repro.data.merge import merge_stores
from repro.data.store import CorpusStore
from repro.data.synthetic import sparse_pair
from repro.kernels.packed import (pack_halfwords_f32, packed_width,
                                  unpack_halfwords_f32)
from repro.serve import SketchSearchService

QMAP = (0, 1, 0, 2, 0, 1)
CMAP = (0, 0, 1, 0, 2, 1)
STORAGE = wmh_storage(64)


def _bf16_trunc(x):
    """The codec's value map: f32 with the low 16 mantissa bits dropped."""
    return np.asarray(x, np.float32).view(np.uint32) \
        .__and__(np.uint32(0xFFFF0000)).view(np.float32)


def _field_rows(fam, rng, P, F=3):
    vecs = [sparse_pair(rng, n=400, nnz=80, overlap=0.3)[0]
            for _ in range(F * P)]
    comps = fam.sketch_rows(vecs)
    return tuple(jnp.swapaxes(c.reshape((P, F) + c.shape[1:]), 0, 1)
                 for c in comps)


# ---------------------------------------------------------------------------
# halfword codec
# ---------------------------------------------------------------------------
def test_codec_roundtrip_is_bf16_truncation():
    rng = np.random.default_rng(0)
    x = np.concatenate([rng.normal(size=500).astype(np.float32),
                        np.array([0.0, -0.0, 1e-37, -1e37], np.float32)])
    w = pack_halfwords_f32(jnp.asarray(x.reshape(2, 252)))
    assert w.shape == (2, 126) and w.dtype == jnp.int32
    back = np.asarray(unpack_halfwords_f32(w))
    np.testing.assert_array_equal(back, _bf16_trunc(x).reshape(2, 252))
    # idempotent from the first re-pack: packing the decode is the identity
    np.testing.assert_array_equal(
        np.asarray(pack_halfwords_f32(unpack_halfwords_f32(w))),
        np.asarray(w))
    # zero words decode to exact zeros (what keeps pad rows inert)
    assert np.all(np.asarray(
        unpack_halfwords_f32(jnp.zeros((3, 4), jnp.int32))) == 0.0)


def test_codec_rejects_odd_width():
    assert packed_width(5) == 3 and packed_width(6) == 3
    with pytest.raises(ValueError):
        pack_halfwords_f32(jnp.zeros((2, 5), jnp.float32))


# ---------------------------------------------------------------------------
# per-family wire layout
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", FAMILY_NAMES)
def test_pack_unpack_roundtrip_per_family(name):
    fam = make_family(name, storage=STORAGE, seed=5)
    rng = np.random.default_rng(21)
    rows = _field_rows(fam, rng, 4)
    packed = fam.pack_rows(rows)
    specs = tuple(fam.packed_components)
    assert len(packed) == len(specs)
    for comp, spec in zip(packed, specs):
        assert comp.dtype == spec.dtype, spec.name
        assert comp.shape[2:] == spec.trailing, spec.name
    rt = fam.unpack_rows(packed)
    assert len(rt) == len(rows)
    # integer planes (fingerprints / sample keys) survive exactly; value
    # planes come back bf16-truncated; re-packing the roundtrip is the
    # identity (the wire format is a fixed point).  The icws-layout
    # argkeys sidecar (icws and its dmh sibling) is dropped by the packed
    # format -- packed rows are frozen -- and comes back zeroed.
    for a, b in zip(rows, rt):
        a, b = np.asarray(a), np.asarray(b)
        if a.dtype == np.int32 and not (name in ("icws", "dmh")
                                        and b.shape == a.shape
                                        and np.all(b == 0)):
            assert np.array_equal(a, b) or np.array_equal(_bf16_trunc(a), b)
    for p1, p2 in zip(packed, fam.pack_rows(rt)):
        np.testing.assert_array_equal(np.asarray(p1), np.asarray(p2))


@pytest.mark.parametrize("name", FAMILY_NAMES)
def test_packed_estimates_bitwise_equal_unpacked_on_roundtrip(name):
    """THE packed-path contract: estimates off the packed layout equal the
    ordinary unpacked launch run on the bf16-roundtripped rows, bitwise."""
    fam = make_family(name, storage=STORAGE, seed=5)
    rng = np.random.default_rng(31)
    crows = _field_rows(fam, rng, 6)
    qrows = _field_rows(fam, np.random.default_rng(32), 2)
    packed = fam.pack_rows(crows)
    est_p = np.asarray(fam.estimate_fields_packed(qrows, packed,
                                                  qmap=QMAP, cmap=CMAP))
    est_u = np.asarray(fam.estimate_fields(qrows, fam.unpack_rows(packed),
                                           qmap=QMAP, cmap=CMAP))
    assert est_p.shape == est_u.shape == (6, 2, 6)
    np.testing.assert_array_equal(est_p, est_u)


# ---------------------------------------------------------------------------
# packed store: layout accounting, inert spares, append contract, merging
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", FAMILY_NAMES)
def test_packed_store_bytes_per_row_reduction(name):
    fam = make_family(name, storage=STORAGE, seed=5)
    unpacked = CorpusStore(family=fam, fields=3)
    packed = CorpusStore(family=fam, fields=3, packed=True)
    ratio = packed.bytes_per_row() / unpacked.bytes_per_row()
    gate = 0.80 if name in ("ts", "ps") else 0.60
    assert ratio <= gate, (name, ratio)


@pytest.mark.parametrize("name", FAMILY_NAMES)
@pytest.mark.parametrize("fill", [3, 11, 16])
def test_packed_spare_capacity_bitwise_inert(name, fill):
    """Spare capacity rows of a PACKED store estimate to exact zero and
    never perturb live rows -- the same invariant the unpacked store holds,
    now over sentinel fingerprints/keys plus all-zero packed value words."""
    fam = make_family(name, storage=STORAGE, seed=5)
    rng = np.random.default_rng(200 + fill)
    rows = _field_rows(fam, rng, fill)

    store = CorpusStore(family=fam, fields=3, min_capacity=16, packed=True)
    store.append(*rows)
    assert store.capacity == 16 and len(store) == fill
    exact = CorpusStore(family=fam, fields=3, min_capacity=fill, packed=True)
    exact.append(*rows)
    assert exact.capacity == fill

    qcomps = _field_rows(fam, np.random.default_rng(7), 2)
    est_full = np.asarray(fam.estimate_fields_packed(
        qcomps, store.buffers(), qmap=QMAP, cmap=CMAP))
    est_exact = np.asarray(fam.estimate_fields_packed(
        qcomps, exact.buffers(), qmap=QMAP, cmap=CMAP))
    assert est_full.shape == (6, 2, 16)
    assert np.all(est_full[:, :, fill:] == 0.0)
    np.testing.assert_array_equal(est_full[:, :, :fill], est_exact)


def test_packed_store_append_validates_unpacked_rows():
    """Ingest call sites hand the store ordinary unpacked sketch rows; the
    store packs internally.  Shape checks fire against the UNPACKED
    contract, so a mismatch is reported in the caller's terms."""
    fam = make_family("icws", storage=STORAGE, seed=5)
    store = CorpusStore(family=fam, fields=3, packed=True)
    rows = _field_rows(fam, np.random.default_rng(41), 2)
    store.append(*rows)
    assert len(store) == 2
    # stored buffers match the packed component specs, not the row specs
    for buf, spec in zip(store.buffers(), fam.packed_components):
        assert buf.dtype == spec.dtype and buf.shape[2:] == spec.trailing
    with pytest.raises(ValueError):
        store.append(*rows[:-1])                     # missing a component
    bad = tuple(np.asarray(r)[:, :, :3] if np.asarray(r).ndim == 3 else r
                for r in rows)
    with pytest.raises(ValueError):
        store.append(*bad)                           # wrong trailing shape


def test_packed_stores_refuse_to_merge():
    fam = make_family("ts", storage=STORAGE, seed=5)
    rows = _field_rows(fam, np.random.default_rng(43), 2)
    plain = CorpusStore(family=fam, fields=3)
    plain.append(*rows)
    packed = CorpusStore(family=fam, fields=3, packed=True)
    packed.append(*rows)
    with pytest.raises(ValueError, match="packed"):
        merge_stores(packed, packed)
    with pytest.raises(ValueError, match="packed"):
        merge_stores(plain, packed)


# ---------------------------------------------------------------------------
# packed serving path: batched == sequential, tenant == dedicated
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("family", FAMILY_NAMES)
def test_packed_service_batched_equals_sequential(family):
    rng = np.random.default_rng(17)
    svc = SketchSearchService(m=64, seed=2, family=family,
                              keep_host_oracle=False, packed=True)
    keys = np.arange(300)
    signal = rng.normal(size=300)
    svc.ingest("a_corr", keys, signal + 0.1 * rng.normal(size=300))
    svc.ingest("b_noise", keys, rng.normal(size=300))
    svc.ingest("c_disjoint", np.arange(9000, 9300), rng.normal(size=300))
    queries = [(keys, signal + 0.05 * rng.normal(size=300))
               for _ in range(3)] + [(np.arange(30), rng.normal(size=30))]
    batch = svc.search_batch(queries, top_k=3, min_join=10, micro_batch=2)
    seq = [svc.search(k, v, top_k=3, min_join=10) for k, v in queries]
    assert batch == seq
    assert svc.describe()["packed"] is True
    assert batch[0] and batch[0][0].name == "a_corr"


def test_packed_tenant_scoped_equals_dedicated():
    rng = np.random.default_rng(19)
    keys = np.arange(200)
    sig = rng.normal(size=200)
    tabs = {t: [(f"{t}{i}", keys,
                 sig + (0.1 + 0.2 * i) * rng.normal(size=200))
                for i in range(4)]
            for t in ("a", "b")}
    shared = SketchSearchService(m=64, seed=3, keep_host_oracle=False,
                                 packed=True)
    for t, rows in tabs.items():
        shared.ingest_many(rows, tenant=t)
    dedicated = SketchSearchService(m=64, seed=3, keep_host_oracle=False,
                                    packed=True)
    dedicated.ingest_many(tabs["a"])
    queries = [(keys, sig + 0.1 * rng.normal(size=200)) for _ in range(3)]
    assert (shared.search_batch(queries, top_k=3, min_join=10, tenant="a")
            == dedicated.search_batch(queries, top_k=3, min_join=10))


# ---------------------------------------------------------------------------
# pack-on-output sketch kernel
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("m", [8, 9])
def test_icws_sketch_pack_vals_matches_host_pack(m):
    """The in-kernel pack epilogue == host pack_halfwords_f32 of the val
    output (odd m zero-pads the inert trailing slot), including rows that
    sketched empty."""
    from repro.kernels.icws_sketch import icws_sketch_pallas
    rng = np.random.default_rng(51)
    B, N = 5, 64
    w = rng.random((B, N)).astype(np.float32)
    w[2] = 0.0                                       # an empty row
    keys = jnp.asarray(rng.integers(0, 2 ** 31 - 1, (B, N)), jnp.int32)
    vals = jnp.asarray(np.sqrt(w))
    w = jnp.asarray(w)
    fp, val, amin, argk, packed = icws_sketch_pallas(
        w, keys, vals, m=m, seed=3, br=2, bm=4, bn=16, pack_vals=True,
        interpret=True)
    ref4 = icws_sketch_pallas(w, keys, vals, m=m, seed=3, interpret=True)
    for a, b in zip((fp, val, amin, argk), ref4):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    me = m + (m % 2)
    host_val = np.zeros((B, me), np.float32)
    host_val[:, :m] = np.asarray(val)
    np.testing.assert_array_equal(
        np.asarray(packed),
        np.asarray(pack_halfwords_f32(jnp.asarray(host_val))))
    with pytest.raises(ValueError):
        icws_sketch_pallas(w, keys, vals, m=m, seed=3, bm=3,
                           pack_vals=True, interpret=True)
