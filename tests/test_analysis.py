"""``repro.analysis`` rule tests: seeded-bad fixtures + repo self-check.

Tier-1 and jax-free: the analysis package is pure stdlib, so every test
here runs in milliseconds with nothing installed.  Each fixture test
builds a minimal synthetic checkout under ``tmp_path``, seeds exactly one
violation, and asserts the expected rule fires at the expected file:line
-- and that the rule's group raises nothing else, so fixtures prove
precision, not just recall.  The self-check runs the full pass over this
actual repo and requires it clean (the same gate CI's lint-invariants job
enforces with ``--strict``).
"""
import os
import pathlib
import sys
import textwrap
import time

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(__file__)),
                                "src"))

from repro.analysis import Config, load_baseline, run  # noqa: E402
from repro.analysis.config import BaselineError, parse_baseline  # noqa: E402
from repro.analysis.engine import METRICS_MD, STREAMS_MD  # noqa: E402

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent

# Minimal mirrored registries used as the clean base of fixture checkouts.
DEVICE_COMMON = """
ICWS_R1_STREAM = 1
CS_SIGN_STREAM = 22


def salt_for(seed, stream, t):
    return seed ^ stream ^ t
"""
HOST_U32 = """
ICWS_R1_STREAM = 1
CS_SIGN_STREAM = 22
"""


def build_repo(tmp_path, files):
    """Write ``{repo-relative path: source}`` and return a checkout root."""
    for rel, text in files.items():
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(textwrap.dedent(text))
    return tmp_path


def run_rules(root, prefixes, baseline=None):
    cfg = Config(root=root, rules=tuple(prefixes),
                 baseline_path=baseline if baseline is not None
                 else root / "nonexistent-baseline.toml")
    return run(cfg)


def one_finding(result, rule):
    assert [f.rule for f in result.findings] == [rule], result.findings
    return result.findings[0]


def test_sr001_duplicate_stream_id(tmp_path):
    root = build_repo(tmp_path, {
        "src/repro/kernels/common.py": """
            ICWS_R1_STREAM = 1
            CS_SIGN_STREAM = 1
        """,
        "src/repro/core/u32.py": """
            ICWS_R1_STREAM = 1
            CS_SIGN_STREAM = 1
        """,
    })
    result = run_rules(root, ["SR001"])
    assert len(result.findings) == 2          # one per registry side
    for f in result.findings:
        assert f.rule == "SR001"
        assert "duplicate" in f.message and "1" in f.message
    dev = [f for f in result.findings if "device" in f.message]
    assert dev and dev[0].path == "src/repro/kernels/common.py"
    assert dev[0].line == 3                   # second definition anchors it


def test_sr002_host_stream_without_device_mirror(tmp_path):
    root = build_repo(tmp_path, {
        "src/repro/kernels/common.py": DEVICE_COMMON,
        "src/repro/core/u32.py": HOST_U32 + "ORPHAN_STREAM = 7\n",
    })
    f = one_finding(run_rules(root, ["SR002"]), "SR002")
    assert f.path == "src/repro/core/u32.py"
    assert f.line == 4
    assert "ORPHAN_STREAM" in f.message and "no device mirror" in f.message


def test_sr003_device_stream_without_host_twin(tmp_path):
    root = build_repo(tmp_path, {
        "src/repro/kernels/common.py": DEVICE_COMMON + "LONELY_STREAM = 8\n",
        "src/repro/core/u32.py": HOST_U32,
    })
    f = one_finding(run_rules(root, ["SR003"]), "SR003")
    assert f.path == "src/repro/kernels/common.py"
    assert "LONELY_STREAM" in f.message and "no host twin" in f.message


def test_sr004_mirror_value_disagreement(tmp_path):
    root = build_repo(tmp_path, {
        "src/repro/kernels/common.py": DEVICE_COMMON,
        "src/repro/core/u32.py": "ICWS_R1_STREAM = 1\nCS_SIGN_STREAM = 23\n",
    })
    f = one_finding(run_rules(root, ["SR004"]), "SR004")
    assert f.path == "src/repro/core/u32.py" and f.line == 2
    assert "CS_SIGN_STREAM" in f.message
    assert "host 23" in f.message and "device 22" in f.message


def test_sr005_inline_stream_literal(tmp_path):
    root = build_repo(tmp_path, {
        "src/repro/kernels/common.py": DEVICE_COMMON,
        "src/repro/core/u32.py": HOST_U32,
        "src/repro/kernels/bad_kernel.py": """
            from .common import salt_for


            def sketch(seed, t):
                good = salt_for(seed, 0x15 - 20, t)    # folded expr: fine
                return salt_for(seed, 22, t)
        """,
    })
    f = one_finding(run_rules(root, ["SR005"]), "SR005")
    assert f.path == "src/repro/kernels/bad_kernel.py" and f.line == 7
    assert "inline stream literal 22" in f.message


def test_sr005_literal_through_local_stream_helper(tmp_path):
    root = build_repo(tmp_path, {
        "src/repro/kernels/common.py": DEVICE_COMMON,
        "src/repro/core/u32.py": HOST_U32,
        "src/repro/core/bad_host.py": """
            from . import u32
            from repro.kernels.common import salt_for


            def variates(seed, t):
                def u(stream):
                    return salt_for(seed, stream, t)

                return u(u32.ICWS_R1_STREAM) * u(2)
        """,
    })
    f = one_finding(run_rules(root, ["SR005"]), "SR005")
    assert f.path == "src/repro/core/bad_host.py" and f.line == 10
    assert "literal 2" in f.message and "u()" in f.message


def test_sr006_streams_md_missing_and_stale(tmp_path):
    root = build_repo(tmp_path, {
        "src/repro/kernels/common.py": DEVICE_COMMON,
        "src/repro/core/u32.py": HOST_U32,
    })
    f = one_finding(run_rules(root, ["SR006"]), "SR006")
    assert f.path == STREAMS_MD and "missing" in f.message

    result = run_rules(root, ["SR"])
    assert [x.rule for x in result.findings] == ["SR006"]
    (root / STREAMS_MD).write_text(result.streams_md)
    assert run_rules(root, ["SR"]).ok          # regenerated => clean sweep
    (root / STREAMS_MD).write_text("# stale\n")
    f = one_finding(run_rules(root, ["SR006"]), "SR006")
    assert "stale" in f.message


def test_cb001_direct_shard_map(tmp_path):
    root = build_repo(tmp_path, {
        "src/repro/launch/bad_mesh.py": """
            import jax


            def launch(fn, mesh, specs):
                return jax.shard_map(fn, mesh=mesh, in_specs=specs,
                                     out_specs=specs[0])
        """,
        "src/repro/compat.py": """
            import jax

            shard_map = jax.shard_map        # the one licensed spelling
        """,
    })
    f = one_finding(run_rules(root, ["CB001"]), "CB001")
    assert f.path == "src/repro/launch/bad_mesh.py" and f.line == 6
    assert "jax.shard_map" in f.message and "repro.compat" in f.message


def test_cb001_gated_import_forms(tmp_path):
    root = build_repo(tmp_path, {
        "src/repro/a.py": "from jax.experimental.shard_map import shard_map\n",
        "src/repro/b.py": "import jax.experimental.shard_map as shmap\n",
        "src/repro/c.py": "from jax.sharding import AxisType\n",
        "src/repro/d.py": "import jax\nmesh = jax.make_mesh((2,), ('x',))\n",
    })
    result = run_rules(root, ["CB"])
    got = {(f.path, f.rule) for f in result.findings}
    assert got == {("src/repro/a.py", "CB001"), ("src/repro/b.py", "CB001"),
                   ("src/repro/c.py", "CB002"), ("src/repro/d.py", "CB003")}


def test_cb004_hardcoded_interpret_true(tmp_path):
    root = build_repo(tmp_path, {
        "src/repro/kernels/bad_call.py": """
            from jax.experimental import pallas as pl


            def f(kernel, x, interpret=True):      # signature default: fine
                return pl.pallas_call(kernel, out_shape=x,
                                      interpret=True)(x)
        """,
        # test/bench code is out of scope for CB004 by design
        "tests/helper.py": "def g(call, x):\n    return call(x, interpret=True)\n",
    })
    f = one_finding(run_rules(root, ["CB004"]), "CB004")
    assert f.path == "src/repro/kernels/bad_call.py" and f.line == 7
    assert "ops._interpret()" in f.message


def test_pb001_oversized_blockspec(tmp_path):
    root = build_repo(tmp_path, {
        "src/repro/kernels/bad_budget.py": """
            from jax.experimental import pallas as pl

            LANES = 128


            def huge_pallas(x, bq=8, bp=4096):
                return pl.pallas_call(
                    lambda q_ref, o_ref: None,
                    grid=(4,),
                    in_specs=[pl.BlockSpec((bq, bp, LANES),
                                           lambda i: (i, 0, 0))] * 2,
                    out_specs=pl.BlockSpec((bq, bp), lambda i: (i, 0)),
                    out_shape=x,
                )(x)
        """,
    })
    result = run_rules(root, ["PB"])
    f = one_finding(result, "PB001")
    assert f.path == "src/repro/kernels/bad_budget.py" and f.line == 8
    # 2 * (8*4096*128) * 4B + (8*4096) * 4B = 33685504 > 2 MiB
    assert "33685504 bytes" in f.message and "huge_pallas" in f.message
    (entry,) = result.budget_report
    assert entry["kernel"] == "huge_pallas"
    assert entry["total_block_bytes"] == 33685504
    assert not entry["within_budget"] and not entry["unresolved"]


def test_pb002_runtime_dependent_block_shape(tmp_path):
    root = build_repo(tmp_path, {
        "src/repro/kernels/bad_shape.py": """
            from jax.experimental import pallas as pl


            def dyn_pallas(x):
                S = x.shape[0]
                return pl.pallas_call(
                    lambda q_ref, o_ref: None,
                    in_specs=[pl.BlockSpec((1, S), lambda i: (i, 0))],
                    out_specs=pl.BlockSpec((1, 8), lambda i: (i, 0)),
                    out_shape=x,
                )(x)
        """,
    })
    f = one_finding(run_rules(root, ["PB"]), "PB002")
    assert f.path == "src/repro/kernels/bad_shape.py" and f.line == 7
    assert "dimension `S` is not statically bounded" in f.message


FAMILY_BASE = """
FAMILY_NAMES = ("icws", "toy")


class ICWSFamily:
    name = "icws"
    components = ()

    def storage_doubles_per_row(self):
        return 1.0

    def sketch_rows(self, vecs):
        return ()

    def estimate_fields(self, q, c):
        return None

    def estimate_fields_sharded(self, q, c):
        return None

    def merge_rows(self, a, b):
        return a

    def host_oracle(self):
        return None


class ToyFamily(ICWSFamily):
    name = "toy"
{toy_body}

def make_family(name, *, storage, seed=0):
    if name == "icws":
        return ICWSFamily()
{make_toy}    raise ValueError(name)
"""


def family_fixture(toy_body="", make_toy='    if name == "toy":\n'
                                         '        return ToyFamily()\n',
                   sweeps=True):
    files = {
        "src/repro/data/families.py":
            FAMILY_BASE.format(toy_body=toy_body, make_toy=make_toy),
    }
    if sweeps:
        for rel in ("tests/test_families.py", "tests/test_sharded_query.py",
                    "benchmarks/perf_sketch.py"):
            files[rel] = "from repro.data.families import FAMILY_NAMES\n"
    return files


def test_fc001_family_missing_merge_rows(tmp_path):
    # ToyFamily overrides the contract away: merge_rows deleted by
    # shadowing the base with a non-contract class.
    bad = FAMILY_BASE.format(toy_body="", make_toy='    if name == "toy":\n'
                                                   '        return ToyFamily()\n')
    bad = bad.replace("class ToyFamily(ICWSFamily):\n    name = \"toy\"\n",
                      "class ToyFamily:\n    name = \"toy\"\n"
                      "    components = ()\n"
                      "    def storage_doubles_per_row(self):\n"
                      "        return 1.0\n"
                      "    def sketch_rows(self, vecs):\n"
                      "        return ()\n"
                      "    def estimate_fields(self, q, c):\n"
                      "        return None\n"
                      "    def estimate_fields_sharded(self, q, c):\n"
                      "        return None\n"
                      "    def host_oracle(self):\n"
                      "        return None\n")
    files = family_fixture()
    files["src/repro/data/families.py"] = bad
    root = build_repo(tmp_path, files)
    f = one_finding(run_rules(root, ["FC"]), "FC001")
    assert f.path == "src/repro/data/families.py"
    assert "'toy'" in f.message and "merge_rows" in f.message


def test_fc001_family_with_no_class_at_all(tmp_path):
    files = family_fixture()
    files["src/repro/data/families.py"] = files[
        "src/repro/data/families.py"].replace('name = "toy"', 'label = "toy"')
    root = build_repo(tmp_path, files)
    f = one_finding(run_rules(root, ["FC"]), "FC001")
    assert "no class declaring name='toy'" in f.message


def test_fc002_family_not_constructible(tmp_path):
    files = family_fixture(make_toy="")
    root = build_repo(tmp_path, files)
    f = one_finding(run_rules(root, ["FC"]), "FC002")
    assert "'toy'" in f.message and "make_family" in f.message


def test_fc003_family_missing_from_sweep(tmp_path):
    files = family_fixture()
    files["tests/test_families.py"] = 'for fam in ("icws",):\n    pass\n'
    root = build_repo(tmp_path, files)
    f = one_finding(run_rules(root, ["FC"]), "FC003")
    assert f.path == "tests/test_families.py"
    assert "'toy'" in f.message


def test_fc_contract_dataclass_field_and_bases_resolve(tmp_path):
    # the real-repo idiom: dataclasses.field(default=...) names + same-module
    # base classes supplying contract members
    files = family_fixture()
    files["src/repro/data/families.py"] = """
import dataclasses

FAMILY_NAMES = ("toy",)


class _Base:
    components = ()

    def storage_doubles_per_row(self):
        return 1.0

    def sketch_rows(self, vecs):
        return ()

    def estimate_fields(self, q, c):
        return None

    def estimate_fields_sharded(self, q, c):
        return None

    def merge_rows(self, a, b):
        return a


@dataclasses.dataclass(frozen=True)
class ToyFamily(_Base):
    name: str = dataclasses.field(default="toy", init=False)

    def host_oracle(self):
        return None


def make_family(name, *, storage, seed=0):
    if name == "toy":
        return ToyFamily()
    raise ValueError(name)
"""
    root = build_repo(tmp_path, files)
    assert run_rules(root, ["FC"]).ok


def test_ob001_unwrapped_and_mislabeled_launches(tmp_path):
    root = build_repo(tmp_path, {
        "src/repro/kernels/ops.py": """
            from repro import obs as _obs


            @_obs.instrumented("icws_sketch")
            def icws_sketch(x):
                return x


            def icws_estimate(x):
                return x


            @_obs.instrumented("jl_sketch")
            def cs_sketch(x):
                return x


            def _interpret():                  # private helpers: out of scope
                return True
        """,
    })
    result = run_rules(root, ["OB001"])
    assert [f.rule for f in result.findings] == ["OB001", "OB001"]
    by_msg = sorted(f.message for f in result.findings)
    assert "'icws_estimate'" in by_msg[1] and "not wrapped" in by_msg[1]
    assert "'jl_sketch'" in by_msg[0] and "'cs_sketch'" in by_msg[0]
    # alternate decorator spellings all count as coverage
    root2 = build_repo(tmp_path / "ok", {
        "src/repro/kernels/ops.py": """
            from repro.obs import instrumented
            from repro import obs


            @instrumented("icws_sketch")
            def icws_sketch(x):
                return x


            @obs.instrumented("jl_sketch")
            def jl_sketch(x):
                return x
        """,
    })
    assert run_rules(root2, ["OB001"]).ok


def test_ob_rules_noop_on_fixture_trees(tmp_path):
    root = build_repo(tmp_path, {
        "src/repro/kernels/common.py": DEVICE_COMMON,
        "src/repro/core/u32.py": HOST_U32,
    })
    assert run_rules(root, ["OB"]).ok


def test_ob002_metrics_md_missing_stale_and_regenerated(tmp_path):
    root = build_repo(tmp_path, {
        "src/repro/obs/registry.py": """
            SPECS = (
                {"name": "ops.launches_total", "type": "counter",
                 "labels": ("op", "family"), "unit": "calls",
                 "help": "kernel launches"},
                {"name": "store.rows", "type": "gauge", "labels": ("family",),
                 "unit": "rows", "help": "resident rows"},
            )
        """,
    })
    f = one_finding(run_rules(root, ["OB002"]), "OB002")
    assert f.path == METRICS_MD and "missing" in f.message

    result = run_rules(root, ["OB"])
    assert "`ops.launches_total`" in result.metrics_md
    assert "op, family" in result.metrics_md
    (root / METRICS_MD).write_text(result.metrics_md)
    assert run_rules(root, ["OB"]).ok          # regenerated => clean sweep
    (root / METRICS_MD).write_text("# stale\n")
    f = one_finding(run_rules(root, ["OB002"]), "OB002")
    assert "stale" in f.message


def test_ob002_rejects_non_literal_specs(tmp_path):
    root = build_repo(tmp_path, {
        "src/repro/obs/registry.py": """
            def _spec(n):
                return {"name": n}


            SPECS = tuple(_spec(n) for n in ("a.b",))
        """,
    })
    f = one_finding(run_rules(root, ["OB002"]), "OB002")
    assert f.path == "src/repro/obs/registry.py"
    assert "pure-literal" in f.message


def test_baseline_covers_and_bl001_stale(tmp_path):
    root = build_repo(tmp_path, {
        "src/repro/kernels/common.py": DEVICE_COMMON + "LONELY_STREAM = 8\n",
        "src/repro/core/u32.py": HOST_U32,
    })
    baseline = tmp_path / "bl.toml"
    baseline.write_text(textwrap.dedent("""
        [[exempt]]
        rule = "SR003"
        path = "src/repro/kernels/common.py"
        match = "LONELY_STREAM"
        reason = "fixture exception"

        [[exempt]]
        rule = "SR003"
        path = "src/repro/core/nowhere.py"
        reason = "stale on purpose"
    """))
    cfg = Config(root=root, rules=("SR003",), baseline_path=baseline)
    result = run(cfg)
    # rules filter active: the live entry absorbs its finding, the stale
    # entry stays quiet (its rule may simply not have run)
    assert result.ok
    assert [e.rule for _, e in result.baselined] == ["SR003"]

    cfg_all = Config(root=root, baseline_path=baseline)
    rules_fired = {f.rule for f in run(cfg_all).findings}
    assert "BL001" in rules_fired and "SR003" not in rules_fired


def test_baseline_parser_rejects_malformed():
    with pytest.raises(BaselineError):
        parse_baseline('[[exempt]]\nrule = "SR001"\npath = "x.py"\n')  # no reason
    with pytest.raises(BaselineError):
        parse_baseline('[exempt]\nrule = "SR001"\n')
    with pytest.raises(BaselineError):
        parse_baseline('rule = "SR001"\n')
    with pytest.raises(BaselineError):
        parse_baseline('[[exempt]]\nrule = SR001\n')
    assert parse_baseline("# only comments\n") == []


def test_analysis_imports_no_jax():
    """The whole point: the pass must run where jax cannot."""
    banned = [m for m in sys.modules
              if m == "jax" or m.startswith("jax.")]
    import repro.analysis  # noqa: F401
    import repro.analysis.engine  # noqa: F401
    newly = [m for m in sys.modules
             if (m == "jax" or m.startswith("jax.")) and m not in banned]
    assert not newly, f"repro.analysis pulled in jax modules: {newly}"


def test_repo_self_check_is_clean_and_fast():
    """This repo passes its own invariants -- the CI lint gate, in-process.

    Every violation is either fixed or pinned in baseline.toml with a
    written reason; STREAMS.md is current; every pallas_call fits the
    VMEM block budget.
    """
    t0 = time.monotonic()
    result = run(Config(root=REPO_ROOT))
    dt = time.monotonic() - t0
    assert result.ok, "\n".join(f.format() for f in result.findings)
    assert dt < 2.0, f"analysis took {dt:.2f}s, budget is 2s"
    # the baseline is live (flash-attention PB002s) and fully consumed
    assert result.baselined, "expected pinned PB002 exceptions"
    for f, e in result.baselined:
        assert e.reason.strip(), f"baseline entry without reason: {e}"
    # the stream registry proved non-trivial: all five families present
    assert "ICWS_R1_STREAM" in result.streams_md
    assert "SAMPLE_HASH_STREAM" in result.streams_md
    # the metric registry renders and covers the core namespaces
    for needle in ("ops.launches_total", "serve.request_seconds",
                   "quality.ppm_error"):
        assert needle in result.metrics_md, needle
    # budget report covers every kernel family's pallas_call sites
    kernels = {e["kernel"] for e in result.budget_report}
    assert {"icws_sketch_pallas", "estimate_fields_pallas",
            "countsketch_pallas", "jl_sketch_pallas",
            "sample_estimate_fields_pallas"} <= kernels
    assert all(e["within_budget"] for e in result.budget_report)


def test_repo_baseline_loads():
    entries = load_baseline(
        REPO_ROOT / "src" / "repro" / "analysis" / "baseline.toml")
    assert entries and all(e.reason for e in entries)
