"""Roofline machinery tests: HLO parser exactness, terms, cell configs,
and the block-size autotuner that feeds the Pallas launch layer."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.configs.base import SHAPES, cell_applicable
from repro.roofline import Roofline, analyze_hlo, model_flops_for
from repro.roofline import autotune
from repro.roofline.hlo import parse_instr_line, shape_bytes


def test_parse_instr_handles_index_comments():
    line = ('  %while.346 = (s32[], pred[4,2,1,2,8,8]{5,4,3,2,1,0}, '
            '/*index=5*/f32[2,8]{1,0}) while(%tuple.1), condition=%c, body=%b')
    ins = parse_instr_line(line)
    assert ins is not None and ins.op == "while"
    assert "index=5" in ins.shape


def test_parse_instr_basic_dot():
    line = ('  %dot.1 = f32[128,64]{1,0} dot(%a, %b), lhs_contracting_dims={1},'
            ' rhs_contracting_dims={0}')
    ins = parse_instr_line(line)
    assert ins.op == "dot" and ins.args == "%a, %b"


def test_shape_bytes():
    assert shape_bytes("f32[128,64]{1,0}") == 128 * 64 * 4
    assert shape_bytes("bf16[10]") == 20
    assert shape_bytes("(s32[], f32[4,4]{1,0})") == 4 + 64
    assert shape_bytes("pred[8]") == 8


def test_scan_flops_exact():
    def body(c, _):
        return c @ c, None

    def f(x):
        y, _ = jax.lax.scan(body, x, None, length=10)
        return y

    co = jax.jit(f).lower(jax.ShapeDtypeStruct((64, 64), jnp.float32)).compile()
    rc = analyze_hlo(co.as_text())
    assert rc.flops == 10 * 2 * 64 ** 3
    assert rc.while_trip_counts == [10]


def test_nested_scan_flops_exact():
    def f(x):
        def outer(c, _):
            def inner(ci, _):
                return ci @ ci, None
            y, _ = jax.lax.scan(inner, c, None, length=3)
            return y, None
        y, _ = jax.lax.scan(outer, x, None, length=5)
        return y

    co = jax.jit(f).lower(jax.ShapeDtypeStruct((32, 32), jnp.float32)).compile()
    rc = analyze_hlo(co.as_text())
    assert rc.flops == 15 * 2 * 32 ** 3


def test_roofline_terms_and_dominance():
    rl = Roofline(chips=256, flops=197e12 * 256, hbm_bytes=819e9 * 256 * 2,
                  collective_bytes=50e9 * 256 * 0.5, model_flops=197e12 * 128)
    assert rl.compute_s == pytest.approx(1.0)
    assert rl.memory_s == pytest.approx(2.0)
    assert rl.collective_s == pytest.approx(0.5)
    assert rl.dominant == "memory"
    assert rl.useful_ratio == pytest.approx(0.5)
    # fraction: useful flops over step-time bound, vs peak
    assert rl.roofline_fraction == pytest.approx(197e12 * 128 / 2.0
                                                 / (256 * 197e12))


def test_model_flops_scaling():
    cfg = configs.get("tinyllama-1.1b")
    train = model_flops_for(cfg, SHAPES["train_4k"])
    prefill = model_flops_for(cfg, SHAPES["prefill_32k"])
    decode = model_flops_for(cfg, SHAPES["decode_32k"])
    # train ~ 6ND vs prefill ~ 2ND on the same token count, but prefill_32k's
    # quadratic attention term (T=32k vs 4k) eats most of the 3x headroom
    assert 1.2 < train / prefill < 4.0
    # decode processes ~1 token per sequence
    assert decode < prefill / 1000


def test_cell_applicability_matrix():
    runnable = {(a, s): cell_applicable(configs.get(a), SHAPES[s])[0]
                for a in configs.ARCHS for s in SHAPES}
    # per spec: long_500k runs only for sub-quadratic archs
    assert runnable[("mixtral-8x22b", "long_500k")]       # SWA bounds the KV
    assert runnable[("rwkv6-1.6b", "long_500k")]
    assert runnable[("jamba-1.5-large-398b", "long_500k")]
    for dense in ("codeqwen1.5-7b", "tinyllama-1.1b", "mistral-nemo-12b",
                  "gemma-7b", "qwen3-moe-30b-a3b", "whisper-base",
                  "internvl2-1b"):
        assert not runnable[(dense, "long_500k")], dense
    # everything else runs
    for a in configs.ARCHS:
        for s in ("train_4k", "prefill_32k", "decode_32k"):
            assert runnable[(a, s)], (a, s)
    n_cells = sum(runnable.values())
    assert n_cells == 33  # 40 - 7 sanctioned skips


def test_autotune_cache_key_even_normalizes():
    """A kernel and its packed twin (width rounded up to even at pack time)
    must resolve the SAME cache entry, so all bitwise-compared paths share
    one set of reduction blocks."""
    assert (autotune.cache_key("estimate_fields", "cpu", {"m": 63})
            == autotune.cache_key("estimate_fields", "cpu", {"m": 64}))
    assert (autotune.cache_key("sample_estimate_fields", "cpu", {"S": 99})
            == autotune.cache_key("sample_estimate_fields", "cpu",
                                  {"S": 100}))
    with pytest.raises(KeyError):
        autotune.cache_key("estimate_fields", "cpu", {})


def test_autotune_entry_fits_budget_and_beats_defaults():
    shape = {"G": 6, "Q": 16, "P": 4096, "m": 128}
    entry = autotune.tune("estimate_fields", shape, "cpu")
    assert entry["block_bytes"] <= autotune.VMEM_BLOCK_BUDGET
    # the whole point: the modeled tuned launch is never slower than the
    # modeled default launch (defaults are themselves a candidate)
    assert entry["model"]["time_s"] <= entry["model"]["default_time_s"]
    assert entry["model"]["grid_steps"] \
        <= entry["model"]["default_grid_steps"]
    # block_shapes must recompute to block_bytes (the budget-rule contract)
    total = sum(4 * c * int(np.prod(dims))
                for c, dims in entry["block_shapes"])
    assert total == entry["block_bytes"]


def test_autotune_resolve_roundtrip_clamp_and_disable(tmp_path, monkeypatch):
    path = tmp_path / "cache.json"
    entry = autotune.tune("estimate_fields",
                          {"G": 6, "Q": 16, "P": 4096, "m": 64}, "cpu")
    autotune.save_cache([entry], path)
    blocks = autotune.resolve("estimate_fields", "cpu", {"m": 64}, path=path)
    assert blocks == entry["blocks"]
    # odd widths even-normalize onto the same entry (packed-twin contract)
    assert autotune.resolve("estimate_fields", "cpu", {"m": 63},
                            path=path) == blocks
    # row-dim clamping: a corpus-scale bp never slows a tiny test launch
    # (reduction dims come back exactly as tuned)
    clamped = autotune.resolve("estimate_fields", "cpu", {"m": 64},
                               clamp={"bp": (64, 128)}, path=path)
    assert clamped["bp"] == min(blocks["bp"], 128)
    assert clamped["bm"] == blocks["bm"]
    # unknown key / backend -> {} (caller falls back to declared defaults)
    assert autotune.resolve("estimate_fields", "cpu", {"m": 2048},
                            path=path) == {}
    assert autotune.resolve("estimate_fields", "tpu", {"m": 64},
                            path=path) == {}
    # the kill switch forces defaults everywhere
    monkeypatch.setenv(autotune.DISABLE_ENV, "1")
    assert autotune.resolve("estimate_fields", "cpu", {"m": 64},
                            path=path) == {}


def test_committed_block_cache_resolves_and_fits_budget():
    """The committed cache must actually serve the launches ops resolves at
    query time, and every entry must restate a within-budget block set."""
    cache = autotune.load_cache()
    assert cache, "src/repro/roofline/block_cache.json missing or empty"
    for entry in cache.values():
        assert entry["block_bytes"] <= autotune.VMEM_BLOCK_BUDGET
        assert entry["model"]["time_s"] <= entry["model"]["default_time_s"]
    blocks = autotune.resolve("estimate_fields", "cpu", {"m": 128})
    assert blocks, "committed cache must cover estimate_fields cpu m=128"


def test_dryrun_records_complete():
    """The committed dry-run sweep must cover every applicable cell x mesh."""
    import json
    from pathlib import Path
    d = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"
    if not d.exists():
        pytest.skip("dry-run sweep not present")
    recs = [json.loads(f.read_text()) for f in d.glob("*.json")]
    ok = {(r["arch"], r["shape"], r["multi_pod"]) for r in recs
          if r["status"] == "ok"}
    assert len(ok) == 66  # 33 applicable cells x 2 meshes
    assert not [r for r in recs if r["status"] == "error"]
