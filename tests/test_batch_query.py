"""Batched many-vs-many query engine.

Covers: the many-vs-many Pallas kernel and the fused multi-field kernel vs
their jnp oracles (property-tested via hypothesis, or the vendored fallback
on hermetic machines); consistency of the batched kernels with the
one-vs-many serving kernel; ``SketchCorpus.estimate_batch``; and end-to-end
identity of ``DatasetSearchIndex.query_batch`` / ``SketchSearchService.
search_batch`` with a loop of single queries on both backends.
"""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.data import DatasetSearchIndex, SketchCorpus
from repro.data.synthetic import sparse_pair
from repro.kernels import ops, ref
from repro.kernels.estimate import (estimate_fields_pallas,
                                    estimate_many_vs_many_pallas,
                                    estimate_one_vs_many_pallas)
from repro.serve import SketchSearchService


def _sketch_pair_batch(rng, Q, P, m, lo=0, hi=40):
    """Random fingerprint/value batches with plenty of collisions."""
    fq = rng.integers(lo, hi, size=(Q, m)).astype(np.int32)
    fc = rng.integers(lo, hi, size=(P, m)).astype(np.int32)
    vq = rng.normal(size=(Q, m)).astype(np.float32)
    vc = rng.normal(size=(P, m)).astype(np.float32)
    return (jnp.asarray(fq), jnp.asarray(vq), jnp.asarray(fc), jnp.asarray(vc))


# ---------------------------------------------------------------------------
# many-vs-many kernel vs ref oracle (property-tested)
# ---------------------------------------------------------------------------
@pytest.mark.slow
@settings(max_examples=10, deadline=None)
@given(q=st.integers(1, 12), p=st.integers(1, 18),
       m=st.integers(1, 280), seed=st.integers(0, 2 ** 31 - 1))
def test_many_vs_many_kernel_matches_ref(q, p, m, seed):
    rng = np.random.default_rng(seed)
    fq, vq, fc, vc = _sketch_pair_batch(rng, q, p, m)
    cnt_k, sw_k = estimate_many_vs_many_pallas(fq, vq, fc, vc, interpret=True)
    cnt_r, sw_r = ref.estimate_many_vs_many_ref(fq, vq, fc, vc)
    assert cnt_k.shape == (q, p)
    np.testing.assert_array_equal(np.asarray(cnt_k), np.asarray(cnt_r))
    # adversarial random values make the collision terms span many orders of
    # magnitude, so normalize by the result scale (the kernel reduces m in
    # bm-sized blocks; the oracle reduces the whole axis at once)
    sw_r = np.asarray(sw_r)
    scale = max(1.0, float(np.max(np.abs(sw_r))))
    np.testing.assert_allclose(np.asarray(sw_k), sw_r, rtol=1e-4,
                               atol=1e-4 * scale)


@pytest.mark.slow
@settings(max_examples=8, deadline=None)
@given(data=st.data())
def test_fields_kernel_matches_ref(data):
    seed = data.draw(st.integers(0, 2 ** 31 - 1))
    rng = np.random.default_rng(seed)
    F = data.draw(st.integers(1, 3))
    C = data.draw(st.integers(1, 3))
    G = data.draw(st.integers(1, 7))
    qmap = tuple(data.draw(st.integers(0, F - 1)) for _ in range(G))
    cmap = tuple(data.draw(st.integers(0, C - 1)) for _ in range(G))
    Q, P, m = (data.draw(st.integers(1, 10)), data.draw(st.integers(1, 14)),
               data.draw(st.integers(1, 200)))
    fq = jnp.asarray(rng.integers(0, 30, size=(F, Q, m)).astype(np.int32))
    vq = jnp.asarray(rng.normal(size=(F, Q, m)).astype(np.float32))
    fc = jnp.asarray(rng.integers(0, 30, size=(C, P, m)).astype(np.int32))
    vc = jnp.asarray(rng.normal(size=(C, P, m)).astype(np.float32))
    cnt_k, sw_k = estimate_fields_pallas(fq, vq, fc, vc, qmap=qmap, cmap=cmap,
                                         interpret=True)
    cnt_r, sw_r = ref.estimate_fields_ref(fq, vq, fc, vc, qmap=qmap, cmap=cmap)
    assert cnt_k.shape == (G, Q, P)
    np.testing.assert_array_equal(np.asarray(cnt_k), np.asarray(cnt_r))
    sw_r = np.asarray(sw_r)
    scale = max(1.0, float(np.max(np.abs(sw_r))))
    np.testing.assert_allclose(np.asarray(sw_k), sw_r, rtol=1e-4,
                               atol=1e-4 * scale)


def test_many_vs_many_rows_equal_one_vs_many():
    """Each row of the batched kernel == the one-vs-many serving kernel."""
    rng = np.random.default_rng(11)
    Q, P, m = 6, 13, 260
    fq, vq, fc, vc = _sketch_pair_batch(rng, Q, P, m)
    cnt_b, sw_b = estimate_many_vs_many_pallas(fq, vq, fc, vc, interpret=True)
    for i in range(Q):
        cnt_1, sw_1 = estimate_one_vs_many_pallas(fq[i:i + 1], vq[i:i + 1],
                                                  fc, vc, interpret=True)
        np.testing.assert_array_equal(np.asarray(cnt_1), np.asarray(cnt_b)[i])
        np.testing.assert_array_equal(np.asarray(sw_1), np.asarray(sw_b)[i])


def test_many_vs_many_empty_query_guard():
    """All-empty query rows (fp == -1) collide with nothing; padding rows of
    a ragged batch behave like empty queries."""
    Q, P, m = 3, 5, 128
    fq = jnp.full((Q, m), -1, jnp.int32)
    vq = jnp.zeros((Q, m))
    fc = jnp.full((P, m), -1, jnp.int32)
    vc = jnp.zeros((P, m))
    cnt, sw = estimate_many_vs_many_pallas(fq, vq, fc, vc, interpret=True)
    assert np.all(np.asarray(cnt) == 0.0) and np.all(np.asarray(sw) == 0.0)


def test_many_vs_many_matches_ref_on_real_sketches():
    """On actual ICWS sketch values (the serving regime), kernel and oracle
    agree to 1e-5 relative -- the acceptance bar."""
    rng = np.random.default_rng(29)
    vecs = [sparse_pair(rng, n=500, nnz=120, overlap=0.3)[0] for _ in range(9)]
    queries = [sparse_pair(rng, n=500, nnz=120, overlap=0.3)[0]
               for _ in range(5)]
    corpus = SketchCorpus(m=256, seed=4)
    corpus.add_batch(vecs)
    from repro.data.corpus import sketch_batch
    fq, vq, _, _ = sketch_batch(queries, m=256, seed=4)
    fc, vc, _, _ = corpus.arrays()
    cnt_k, sw_k = estimate_many_vs_many_pallas(fq, vq, fc, vc, interpret=True)
    cnt_r, sw_r = ref.estimate_many_vs_many_ref(fq, vq, fc, vc)
    np.testing.assert_array_equal(np.asarray(cnt_k), np.asarray(cnt_r))
    sw_k, sw_r = np.asarray(sw_k, np.float64), np.asarray(sw_r, np.float64)
    scale = np.maximum(np.maximum(np.abs(sw_k), np.abs(sw_r)), 1e-12)
    assert float(np.max(np.abs(sw_k - sw_r) / scale)) < 1e-5


# ---------------------------------------------------------------------------
# SketchCorpus batched estimation
# ---------------------------------------------------------------------------
def test_corpus_estimate_batch_matches_sequential():
    rng = np.random.default_rng(19)
    vecs = [sparse_pair(rng, n=500, nnz=120, overlap=0.3)[0] for _ in range(9)]
    queries = [sparse_pair(rng, n=500, nnz=120, overlap=0.3)[0]
               for _ in range(5)]
    corpus = SketchCorpus(m=128, seed=3)
    corpus.add_batch(vecs)
    batched = np.asarray(corpus.estimate_vecs(queries))
    assert batched.shape == (5, 9)
    for qi, q in enumerate(queries):
        seq = np.asarray(corpus.estimate_vec(q))
        np.testing.assert_array_equal(batched[qi], seq)


# ---------------------------------------------------------------------------
# end-to-end: query_batch == loop of query on both backends
# ---------------------------------------------------------------------------
def _build_index(rng, m=512):
    idx = DatasetSearchIndex(m=m, seed=1)
    keys = np.arange(600)
    signal = rng.normal(size=600)
    idx.add_table("corr", keys, signal + 0.2 * rng.normal(size=600))
    idx.add_table("noise", keys, rng.normal(size=600))
    idx.add_table("disjoint", np.arange(9000, 9600), rng.normal(size=600))
    idx.add_table("half", np.arange(300, 900), rng.normal(size=600))
    queries = [(keys, signal + 0.1 * rng.normal(size=600)),
               (np.arange(100, 700), rng.normal(size=600)),
               (np.arange(50), rng.normal(size=50))]
    return idx, queries


@pytest.mark.parametrize("backend", ["device", "host"])
def test_query_batch_identical_to_query_loop(backend):
    rng = np.random.default_rng(5)
    idx, queries = _build_index(rng)
    batch = idx.query_batch(queries, top_k=4, min_join=20, backend=backend)
    seq = [idx.query(k, v, top_k=4, min_join=20, backend=backend)
           for k, v in queries]
    assert batch == seq          # SearchResult dataclass equality: all stats


def test_query_batch_empty_inputs():
    idx = DatasetSearchIndex(m=64, seed=0)
    assert idx.query_batch([]) == []
    assert idx.query_batch([(np.arange(3), np.ones(3))]) == [[]]  # no tables


def test_search_batch_identical_to_search_loop_and_stats():
    rng = np.random.default_rng(7)
    svc = SketchSearchService(m=256, seed=2)
    keys = np.arange(400)
    signal = rng.normal(size=400)
    svc.ingest("a_corr", keys, signal + 0.1 * rng.normal(size=400))
    svc.ingest("b_noise", keys, rng.normal(size=400))
    queries = [(keys, signal + 0.05 * rng.normal(size=400)) for _ in range(5)]
    # micro_batch=4 forces a padded tail batch (5 = 4 + 1 padded to 4)
    batch = svc.search_batch(queries, top_k=2, min_join=10, micro_batch=4)
    seq = [svc.search(k, v, top_k=2, min_join=10) for k, v in queries]
    assert batch == seq
    assert svc.stats.batches_served == 2
    assert svc.stats.batch_queries_served == 5
    assert svc.stats.last_batch_ms > 0
    d = svc.describe()
    assert d["batch_queries_served"] == 5.0
    assert d["mean_batched_query_ms"] > 0
    with pytest.raises(ValueError):
        svc.search_batch(queries, micro_batch=0)


def test_search_batch_host_backend_matches_loop():
    rng = np.random.default_rng(13)
    svc = SketchSearchService(m=256, seed=2)
    keys = np.arange(300)
    signal = rng.normal(size=300)
    svc.ingest("t0", keys, signal)
    svc.ingest("t1", keys, rng.normal(size=300))
    queries = [(keys, signal), (np.arange(100, 400), rng.normal(size=300))]
    batch = svc.search_batch(queries, top_k=2, min_join=5, backend="host",
                             micro_batch=8)
    seq = [svc.search(k, v, top_k=2, min_join=5, backend="host")
           for k, v in queries]
    assert batch == seq
