"""Canonical field-stacked CorpusStore: amortized append semantics.

Covers: interleaved appends across capacity-doubling boundaries produce
``arrays()`` bitwise identical to a single build-once ingest (property-
tested, for F=1 and F=3 stores, fed from host-numpy and device-jnp arrays);
capacity-doubling growth accounting; up-front validation of all three
sketch components; and inertness of unused capacity rows under the
estimate kernels (buffers-vs-exact-arrays estimates bitwise equal).
"""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.data.store import PAD_FP, CorpusStore
from repro.kernels import ops


def _rows(rng, fields, b, m):
    fp = rng.integers(0, 100, size=(fields, b, m)).astype(np.int32)
    val = rng.normal(size=(fields, b, m)).astype(np.float32)
    norm = (rng.random((fields, b)) + 0.1).astype(np.float32)
    key = rng.integers(0, 2 ** 31 - 1, size=(fields, b, m)).astype(np.int32)
    return fp, val, norm, key


# ---------------------------------------------------------------------------
# interleaved appends == one-shot ingest (bitwise), across doubling boundaries
# ---------------------------------------------------------------------------
@settings(max_examples=40, deadline=None)
@given(fields=st.integers(1, 3), device=st.integers(0, 1),
       sizes=st.lists(st.integers(1, 7), min_size=1, max_size=6),
       seed=st.integers(0, 2 ** 31 - 1))
def test_interleaved_appends_match_one_shot(fields, device, sizes, seed):
    """F=1 and F=3 stores, host-numpy and device-jnp sources, interleaved
    appends crossing capacity-doubling boundaries == build-once ingest."""
    rng = np.random.default_rng(seed)
    m, total = 16, sum(sizes)
    rows = _rows(rng, fields, total, m)

    one = CorpusStore(m=m, fields=fields, min_capacity=2)
    one.append(*rows)

    # min_capacity=2 forces several capacity doublings mid-sequence
    inc = CorpusStore(m=m, fields=fields, min_capacity=2)
    off = 0
    for b in sizes:
        chunk = tuple(r[:, off:off + b] for r in rows)
        if device:
            chunk = tuple(jnp.asarray(c) for c in chunk)
        inc.append(*chunk)
        off += b
    assert len(inc) == len(one) == total
    for a, b_ in zip(one.arrays(), inc.arrays()):
        assert np.array_equal(np.asarray(a), np.asarray(b_))


def test_capacity_doubles_amortized():
    store = CorpusStore(m=8, fields=1, min_capacity=4)
    caps = []
    rng = np.random.default_rng(0)
    for _ in range(20):
        store.append(*_rows(rng, 1, 1, 8))
        caps.append(store.capacity)
    assert len(store) == 20 and store.capacity == 32
    # growth is doubling: capacities are powers of two of the floor, and
    # the number of distinct capacities is logarithmic in the final size
    assert sorted(set(caps)) == [4, 8, 16, 32]


def test_store_row_multiple_keeps_capacity_divisible():
    """Sharded stores round the capacity floor to the mesh axis size, and
    doubling preserves it -- the sharded query path never re-pads rows."""
    store = CorpusStore(m=8, fields=1, min_capacity=5, row_multiple=3)
    assert store.min_capacity == 6
    rng = np.random.default_rng(4)
    for _ in range(15):
        store.append(*_rows(rng, 1, 1, 8))
        assert store.capacity % 3 == 0
    assert store.capacity == 24


def test_store_single_field_accepts_2d_rows():
    rng = np.random.default_rng(1)
    rows = _rows(rng, 1, 5, 8)
    flat = CorpusStore(m=8, fields=1)
    flat.append(*(r[0] for r in rows))             # [b, m] / [b]
    stacked = CorpusStore(m=8, fields=1)
    stacked.append(*rows)                          # [1, b, m] / [1, b]
    for a, b in zip(flat.arrays(), stacked.arrays()):
        assert np.array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# validation: all three components checked against each other at ingest
# ---------------------------------------------------------------------------
def test_store_append_validates_all_components():
    rng = np.random.default_rng(2)
    fp, val, norm, key = _rows(rng, 3, 4, 8)
    store = CorpusStore(m=8, fields=3)
    with pytest.raises(ValueError):
        store.append(fp, val[:, :3], norm, key)    # mismatched val rows
    with pytest.raises(ValueError):
        store.append(fp, val, norm[:, :3], key)    # mismatched norm rows
    with pytest.raises(ValueError):
        store.append(fp, val, norm, key[:, :3])    # mismatched argkey rows
    with pytest.raises(ValueError):
        store.append(fp, val, norm)                # missing a component
    with pytest.raises(ValueError):
        store.append(fp[:2], val[:2], norm[:2], key[:2])  # wrong field count
    with pytest.raises(ValueError):
        store.append(fp[:, :, :4], val[:, :, :4], norm, key)   # wrong m
    assert len(store) == 0
    store.append(fp, val, norm, key)
    assert len(store) == 4


def test_store_empty_raises_and_zero_rows_noop():
    store = CorpusStore(m=8, fields=1)
    with pytest.raises(ValueError):
        store.arrays()
    with pytest.raises(ValueError):
        store.buffers()
    store.append(np.zeros((1, 0, 8), np.int32), np.zeros((1, 0, 8)),
                 np.zeros((1, 0)), np.zeros((1, 0, 8), np.int32))
    assert len(store) == 0


# ---------------------------------------------------------------------------
# unused capacity rows are inert under the estimate kernels
# ---------------------------------------------------------------------------
def test_spare_capacity_is_inert_in_estimates():
    """Estimates off the full-capacity buffers == estimates off exact-size
    arrays, row for row and bitwise -- the invariant that lets query paths
    skip materializing an exact-size corpus copy."""
    rng = np.random.default_rng(7)
    m, P = 32, 5
    rows = _rows(rng, 1, P, m)
    store = CorpusStore(m=m, fields=1, min_capacity=16)   # capacity 16 > P=5
    store.append(*rows)
    assert store.capacity > len(store)
    fpb, vb, nb, _ = store.buffers()
    assert np.all(np.asarray(fpb)[0, P:] == PAD_FP)

    fq = jnp.asarray(rng.integers(0, 100, size=(2, m)).astype(np.int32))
    vq = jnp.asarray(rng.normal(size=(2, m)).astype(np.float32))
    nq = jnp.ones((2,), jnp.float32)

    exact = ops.icws_estimate_many(fq, vq, nq, *store.arrays()[:3])
    padded = ops.icws_estimate_many_stacked(fq, vq, nq, fpb, vb, nb)
    assert padded.shape == (2, store.capacity)
    assert np.all(np.asarray(padded)[:, P:] == 0.0)       # spare rows: zero
    assert np.array_equal(np.asarray(padded)[:, :P], np.asarray(exact))

    one = ops.icws_estimate_corpus(fq[:1], vq[:1], nq[0],
                                   *store.arrays()[:3])
    one_p = ops.icws_estimate_corpus_stacked(fq[:1], vq[:1], nq[0],
                                             fpb, vb, nb)
    assert np.array_equal(np.asarray(one_p)[:P], np.asarray(one))
