"""DMH (densified one-permutation weighted MinHash) contract tests.

The constant-time ingest family must honour four contracts at once:

  * the Pallas kernel is a bit-twin of the jnp reference and of the numpy
    host oracle (:class:`repro.core.dmh.DMH`) on the shared u32 streams --
    mixed host/device corpora keep colliding;
  * densification fills every empty bin deterministically, including the
    adversarial 1-nonzero vector where m - 1 of m bins start empty;
  * collision probability stays an unbiased weighted-Jaccard estimate --
    binning plus uniform reseeded borrowing must not re-introduce the bias
    of the rotation-densified 2014 scheme (pinned against the exact ICWS
    oracle over many seeds);
  * union-merge of disjoint-support shards commutes bitwise and matches
    the host oracle, and packed (bf16-halfword) storage round-trips with
    inert spare rows -- DMH rows ride the ICWS wire layout unchanged.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import SparseVec
from repro.core import dmh as host_dmh
from repro.core import u32
from repro.core.dmh import DMH
from repro.core.icws import ICWS
from repro.data import make_family, wmh_storage
from repro.kernels import common as kcommon
from repro.kernels import ops
from repro.kernels.dmh_sketch import dmh_sketch_pallas, dmh_sketch_scatter
from repro.kernels.packed import pack_halfwords_f32, unpack_halfwords_f32
from repro.kernels.ref import dmh_sketch_ref


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------
def _rand_batch(rng, B, N, density=1.0):
    """Padded [B, N] (w, keys, vals) device arrays + per-row SparseVecs."""
    keys = np.zeros((B, N), np.int32)
    vals = np.zeros((B, N), np.float32)
    w = np.zeros((B, N), np.float32)
    vecs = []
    for b in range(B):
        nnz = max(1, int(N * density))
        idx = rng.choice(2**31 - 1, size=nnz, replace=False).astype(np.int64)
        x = rng.normal(size=nnz)
        v = SparseVec.from_pairs(idx, x, 2**31)
        vecs.append(v)
        z = (v.values / v.norm()).astype(np.float32)
        keys[b, :nnz] = v.indices.astype(np.int32)
        vals[b, :nnz] = z
        w[b, :nnz] = z * z
    return jnp.asarray(w), jnp.asarray(keys), jnp.asarray(vals), vecs


def _f1(comps):
    """Stack F=1: [B, ...] components -> [1, B, ...] (estimate_fields)."""
    return tuple(jnp.asarray(c)[None] for c in comps)


def _assert_sketches_match(got, want, amin_rtol=1e-5):
    """fp/argkey bit-exact; val to f32 rounding; amin looser (eager jnp vs
    jitted interpret transcendentals differ in the last ulp or two)."""
    fp_g, val_g, amin_g, key_g = (np.asarray(x) for x in got[:4])
    fp_w, val_w, amin_w, key_w = (np.asarray(x) for x in want[:4])
    assert np.array_equal(fp_g, fp_w)
    assert np.array_equal(key_g, key_w)
    np.testing.assert_allclose(val_g, val_w, rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(amin_g, amin_w, rtol=amin_rtol)


# ---------------------------------------------------------------------------
# probe budget: host and device MUST agree or borrowed bins stop colliding
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("m", [1, 2, 31, 32, 64, 66, 128, 200, 266, 1024,
                               4096])
def test_densify_probe_budget_twins(m):
    assert host_dmh.densify_probes(m) == kcommon.densify_probes(m)
    assert kcommon.densify_probes(m) % 128 == 0
    assert kcommon.densify_probes(m) <= 1024


# ---------------------------------------------------------------------------
# kernel vs jnp reference
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("B,N,m,seed", [(3, 64, 64, 0),
                                        (5, 300, 200, 7),     # padded odd-ish m
                                        (2, 1024, 266, 3),    # bench sizes
                                        (8, 100, 64, 11)])
def test_kernel_matches_ref(B, N, m, seed):
    rng = np.random.default_rng(B * 1000 + N + m + seed)
    w, keys, vals, _ = _rand_batch(rng, B, N)
    ref = dmh_sketch_ref(w, keys, vals, m=m, seed=seed)
    bm = 128 * (-(-m // 128))
    got = dmh_sketch_pallas(w, keys, vals, m=m, seed=seed, bm=bm)
    _assert_sketches_match(got, ref)


@pytest.mark.parametrize("B,N,m,seed", [(3, 64, 64, 0),
                                        (5, 300, 200, 7),
                                        (8, 100, 64, 11)])
def test_scatter_lowering_matches_kernel(B, N, m, seed):
    """The O(nnz + m) scatter builder ops dispatches to off-TPU is the
    same computation as the Pallas kernel: fingerprints / argkeys bitwise,
    values / minima to transcendental rounding."""
    rng = np.random.default_rng(B * 77 + N + m + seed)
    w, keys, vals, _ = _rand_batch(rng, B, N)
    kernel = dmh_sketch_pallas(w, keys, vals, m=m, seed=seed,
                               bm=128 * (-(-m // 128)))
    scatter = dmh_sketch_scatter(w, keys, vals, m=m, seed=seed)
    _assert_sketches_match(scatter, kernel)
    # and ops.dmh_sketch resolves to one of the two (interpret dispatch)
    via_ops = ops.dmh_sketch(w, keys, vals, m=m, seed=seed)
    _assert_sketches_match(via_ops, kernel)


@pytest.mark.slow
def test_kernel_block_shape_invariant():
    """fp/val/key planes are bitwise identical for every (br, bm, bn)."""
    rng = np.random.default_rng(21)
    B, N, m, seed = 6, 700, 64, 5
    w, keys, vals, _ = _rand_batch(rng, B, N)
    base = dmh_sketch_pallas(w, keys, vals, m=m, seed=seed, br=1, bm=128,
                             bn=256)
    for br, bm, bn in [(2, 128, 256), (3, 256, 512), (6, 128, 1024),
                      (1, 384, 128)]:
        got = dmh_sketch_pallas(w, keys, vals, m=m, seed=seed, br=br, bm=bm,
                                bn=bn)
        for g, b in zip(got, base):
            assert np.array_equal(np.asarray(g), np.asarray(b)), \
                f"block shape ({br},{bm},{bn}) changed the sketch"


# ---------------------------------------------------------------------------
# host oracle vs device kernel: interoperable fingerprints
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("nnz,m,seed", [(64, 64, 0), (300, 128, 7),
                                        (1000, 266, 3)])
def test_host_device_fingerprints_compatible(nnz, m, seed):
    rng = np.random.default_rng(nnz + m + seed)
    idx = rng.choice(2**31 - 1, size=nnz, replace=False).astype(np.int64)
    v = SparseVec.from_pairs(idx, rng.normal(size=nnz), 2**31)
    host = DMH(m=m, seed=seed).sketch(v)

    # the device pad expands keys into pseudo-key replicas exactly like
    # the host oracle (m = 128 -> c = 2, m = 266 -> c = 4); raw kernel
    # inputs must go through the same shared expansion
    z32 = (v.values / v.norm()).astype(np.float32)
    c = host_dmh.dmh_replication(m)
    kk = host_dmh.replicate_keys(
        v.indices.astype(np.int64).astype(np.uint32), c)
    z_r = np.tile(z32, c)
    w = jnp.asarray((z_r * z_r)[None, :])
    keys = jnp.asarray(kk.view(np.int32)[None, :])
    vals = jnp.asarray(z_r[None, :])
    fp, val, _, key = ops.dmh_sketch(w, keys, vals, m=m, seed=seed)
    fp_dev, val_dev = np.asarray(fp)[0], np.asarray(val)[0]

    agree = np.mean(host.fingerprints == fp_dev)
    assert agree > 0.99, f"fingerprint agreement {agree:.4f}"
    same = host.fingerprints == fp_dev
    np.testing.assert_allclose(host.values[same], val_dev[same],
                               rtol=1e-5, atol=1e-6)
    assert host.fingerprints.dtype == np.int32
    assert (host.fingerprints >= -1).all()          # 31-bit fp or empty
    # argkeys witness origins identically where fingerprints agree
    assert np.array_equal(np.asarray(host.argkeys)[same],
                          np.asarray(key)[0][same])


# ---------------------------------------------------------------------------
# pseudo-key replication (m > 64): formula, expansion, ingest consistency
# ---------------------------------------------------------------------------
def test_replication_formula_and_salts():
    """c = clamp(m // 64, 1, 4): identity below m = 128, capped at 4
    (pseudo-keys of different keys can alias, k1 ^ r1*SALT == k2 ^
    r2*SALT, and the alias odds grow ~c^2 -- see dmh_replication)."""
    got = {m: host_dmh.dmh_replication(m)
           for m in (1, 64, 66, 127, 128, 191, 266, 512)}
    assert got == {1: 1, 64: 1, 66: 1, 127: 1, 128: 2, 191: 2, 266: 4,
                   512: 4}
    s = host_dmh.replica_salts(4)
    assert s.dtype == np.uint32
    assert s[0] == 0                        # replica 0 is the identity
    assert np.unique(s).size == 4
    kk = np.arange(5, dtype=np.uint32) + 7
    rep = host_dmh.replicate_keys(kk, 3)
    assert rep.shape == (15,)
    assert np.array_equal(rep[:5], kk)      # replica-major, r = 0 first
    # batched expansion == per-row expansion (the ingest pad uses [B, N])
    kb = (np.arange(10, dtype=np.uint32)
          * np.uint32(2654435761)).reshape(2, 5)
    repb = host_dmh.replicate_keys(kb, 3)
    assert repb.shape == (2, 15)
    for b in range(2):
        assert np.array_equal(repb[b], host_dmh.replicate_keys(kb[b], 3))


def test_replicated_ingest_matches_host_oracle():
    """m = 160 (c = 2): the family ingest pad and the host oracle expand
    through the shared replicate_keys, so fingerprints still collide and
    stored argkeys (pseudo-keys) witness identical origins."""
    rng = np.random.default_rng(17)
    m = 160
    fam = make_family("dmh", storage=int(1.5 * m + 1), seed=13)
    assert fam.m == m
    vecs = []
    for _ in range(4):
        idx = rng.choice(2**31 - 1, size=300, replace=False)
        vecs.append(SparseVec.from_pairs(np.sort(idx),
                                         rng.normal(size=300), 2**31))
    fp_d, _, _, key_d = (np.asarray(x) for x in fam.sketch_rows(vecs))
    host = DMH(m=m, seed=13)
    for b, v in enumerate(vecs):
        s = host.sketch(v)
        agree = s.fingerprints == fp_d[b]
        assert agree.mean() > 0.99
        assert np.array_equal(np.asarray(s.argkeys)[agree], key_d[b][agree])


def test_single_nonzero_densifies_every_bin():
    """Adversarial emptiness: 1 nonzero at m=64 leaves 63 empty bins; the
    densification epilogue must copy the lone winner everywhere, host and
    device alike."""
    m, seed = 64, 9
    v = SparseVec.from_pairs(np.array([123456789]), np.array([2.5]), 2**31)
    host = DMH(m=m, seed=seed).sketch(v)
    assert (host.fingerprints >= 0).all()
    assert np.unique(host.fingerprints).size == 1
    assert (np.asarray(host.argkeys).view(np.uint32) == 123456789).all()
    np.testing.assert_allclose(host.values, 1.0, rtol=1e-6)  # z = v / |v|

    w = jnp.asarray([[1.0]], jnp.float32)
    keys = jnp.asarray([[123456789]], jnp.int32)
    vals = jnp.asarray([[1.0]], jnp.float32)
    fp, val, amin, key = ops.dmh_sketch(w, keys, vals, m=m, seed=seed)
    assert np.array_equal(np.asarray(fp)[0], host.fingerprints)
    assert (np.asarray(key)[0] == 123456789).all()
    assert (np.asarray(amin)[0] < np.float32(host_dmh._BIG
            if hasattr(host_dmh, "_BIG") else 1e30)).all()


def test_empty_row_kernel_sentinels():
    """All-zero rows produce the ICWS empty wire sentinels the estimate
    kernels treat as zero-overlap: fp = -1, val = 0, argkey = 0."""
    m = 64
    fp, val, _, key = ops.dmh_sketch(jnp.zeros((2, 32)), jnp.zeros(
        (2, 32), jnp.int32), jnp.zeros((2, 32)), m=m, seed=0)
    assert (np.asarray(fp) == -1).all()
    assert (np.asarray(val) == 0).all()
    assert (np.asarray(key) == 0).all()

    host = DMH(m=m, seed=0).sketch(SparseVec.from_pairs(
        np.zeros(0, np.int64), np.zeros(0), 2**31))
    assert (host.fingerprints == -1).all()
    assert (host.values == 0).all()


# ---------------------------------------------------------------------------
# statistical contract: collision probability is unbiased weighted Jaccard
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_collision_probability_unbiased_vs_icws():
    """Over 400 seeds, the mean DMH collision rate on a known-Jaccard pair
    must match the exact-ICWS collision rate within 4 combined standard
    errors.  Constant-value vectors with 30 of 60 keys shared give
    weighted Jaccard exactly 1/3; a biased densification (the 2014
    rotation scheme) fails this by many sigma at m = 64."""
    m, seeds = 64, 400
    rng = np.random.default_rng(1234)
    keys = rng.choice(2**31 - 1, size=90, replace=False).astype(np.int64)
    va = SparseVec.from_pairs(np.sort(keys[:60]), np.ones(60), 2**31)
    vb = SparseVec.from_pairs(np.sort(keys[30:]), np.ones(60), 2**31)
    jac = 30.0 / 90.0

    rates = {"dmh": np.empty(seeds), "icws": np.empty(seeds)}
    for cls, name in ((DMH, "dmh"), (ICWS, "icws")):
        for s in range(seeds):
            sk = cls(m=m, seed=s)
            sa, sb = sk.sketch(va), sk.sketch(vb)
            rates[name][s] = np.mean(sa.fingerprints == sb.fingerprints)

    sem = np.sqrt(rates["dmh"].var() / seeds + rates["icws"].var() / seeds)
    diff = abs(rates["dmh"].mean() - rates["icws"].mean())
    assert diff <= 4 * sem, (
        f"dmh {rates['dmh'].mean():.4f} vs icws {rates['icws'].mean():.4f} "
        f"(J = {jac:.4f}): |diff| = {diff:.4f} > 4 SEM = {4 * sem:.4f}")
    # and both track the analytic Jaccard
    icws_sem = rates["icws"].std() / np.sqrt(seeds)
    assert abs(rates["icws"].mean() - jac) <= 5 * icws_sem


# ---------------------------------------------------------------------------
# packed storage: bf16-halfword epilogue + roundtrip + inert spare rows
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("m", [64, 65])        # even and odd (padded) widths
def test_pack_vals_epilogue_bitwise(m):
    rng = np.random.default_rng(m)
    w, keys, vals, _ = _rand_batch(rng, 3, 200)
    fp, val, amin, key, packed = dmh_sketch_pallas(w, keys, vals, m=m,
                                                   seed=2, pack_vals=True)
    me = m + m % 2
    padded = jnp.pad(jnp.asarray(val), ((0, 0), (0, me - m)))
    want = pack_halfwords_f32(padded)
    assert np.array_equal(np.asarray(packed), np.asarray(want))
    # roundtrip: unpack reproduces the bf16 truncation of val exactly
    back = np.asarray(unpack_halfwords_f32(packed))[:, :m]
    np.testing.assert_allclose(back, np.asarray(val), rtol=1 / 128.0,
                               atol=1e-6)
    assert np.array_equal(np.asarray(pack_halfwords_f32(
        jnp.asarray(back if m % 2 == 0 else np.pad(
            back, ((0, 0), (0, 1)))))), np.asarray(packed))
    # the scatter lowering's packed plane packs ITS val plane identically
    outs_s = dmh_sketch_scatter(w, keys, vals, m=m, seed=2, pack_vals=True)
    want_s = pack_halfwords_f32(jnp.pad(jnp.asarray(outs_s[1]),
                                        ((0, 0), (0, me - m))))
    assert np.array_equal(np.asarray(outs_s[4]), np.asarray(want_s))


def test_packed_store_spare_rows_inert_for_dmh():
    """A DMH corpus in packed storage estimates identically before and
    after growing spare capacity -- spare rows stay bitwise inert."""
    fam = make_family("dmh", storage=wmh_storage(64), seed=5)
    rng = np.random.default_rng(55)
    _, _, _, vecs = _rand_batch(rng, 6, 120)
    qf = fam.sketch_rows(vecs[:2])
    cf = tuple(jnp.asarray(x) for x in fam.sketch_rows(vecs))

    base = np.asarray(fam.estimate_fields(_f1(qf), _f1(cf),
                                          qmap=(0,), cmap=(0,))[0])
    # spare rows: zero-extended components (the packed store's pad layout)
    pad = 4
    cf_pad = tuple(jnp.pad(x, ((0, pad),) + ((0, 0),) * (x.ndim - 1))
                   for x in cf)
    grown = np.asarray(fam.estimate_fields(_f1(qf), _f1(cf_pad), qmap=(0,),
                                           cmap=(0,))[0])
    assert np.array_equal(grown[:, :base.shape[1]], base)
    assert (grown[:, base.shape[1]:] == 0).all()


# ---------------------------------------------------------------------------
# union-merge: disjoint shards, bitwise commutative, host-oracle-exact
# ---------------------------------------------------------------------------
def _disjoint_split(rng, nnz=160, m_ambient=2**31):
    """One vector split into a disjoint-support partition (the merge
    contract's precondition)."""
    idx = rng.choice(m_ambient - 1, size=nnz, replace=False).astype(np.int64)
    x = rng.normal(size=nnz)
    mask = rng.random(nnz) < 0.5
    full = SparseVec.from_pairs(idx, x, m_ambient)
    left = SparseVec.from_pairs(idx[mask], x[mask], m_ambient)
    right = SparseVec.from_pairs(idx[~mask], x[~mask], m_ambient)
    return full, left, right


def _family_fields(fam, vecs):
    return tuple(jnp.asarray(x) for x in fam.sketch_rows(vecs))


@pytest.mark.parametrize("seed,base_m", [(0, 64), (7, 64), (3, 256)])
def test_merge_rows_matches_host_oracle(seed, base_m):
    # base_m = 256 exercises c = 4 pseudo-key replication end to end:
    # merge operates on stored pseudo-key argkeys and needs no expansion
    fam = make_family("dmh", storage=wmh_storage(base_m), seed=seed)
    oracle = fam.host_oracle()
    rng = np.random.default_rng(60 + seed)
    full, left, right = _disjoint_split(rng)

    fa = _family_fields(fam, [left])
    fb = _family_fields(fam, [right])
    merged = fam.merge_rows(fa, fb)
    fp_m, val_m, norm_m, key_m = (np.asarray(x) for x in merged)

    host = oracle.merge(oracle.sketch(left), oracle.sketch(right))
    assert np.array_equal(fp_m[0], host.fingerprints)
    assert np.array_equal(key_m[0], np.asarray(host.argkeys))
    np.testing.assert_allclose(val_m[0], host.values, rtol=1e-5, atol=1e-7)
    np.testing.assert_allclose(norm_m[0], host.norm, rtol=1e-6)


def test_merge_rows_commutes_bitwise():
    fam = make_family("dmh", storage=wmh_storage(64), seed=3)
    rng = np.random.default_rng(61)
    _, left, right = _disjoint_split(rng)
    fa = _family_fields(fam, [left])
    fb = _family_fields(fam, [right])
    ab = fam.merge_rows(fa, fb)
    ba = fam.merge_rows(fb, fa)
    for x, y in zip(ab, ba):
        assert np.array_equal(np.asarray(x), np.asarray(y))


def test_merge_one_side_empty_is_exact():
    fam = make_family("dmh", storage=wmh_storage(64), seed=4)
    rng = np.random.default_rng(62)
    _, left, _ = _disjoint_split(rng)
    fa = _family_fields(fam, [left])
    empty = SparseVec.from_pairs(np.zeros(0, np.int64), np.zeros(0), 2**31)
    fe = _family_fields(fam, [empty])
    merged = fam.merge_rows(fa, fe)
    for got, want in zip(merged, fa):
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-6, atol=0)


def test_merged_estimates_track_full_sketch():
    """Inner-product estimates from shard-merged sketches agree with the
    full-vector sketch estimate to sketch noise (not bitwise -- rescored
    merges redraw winners -- but the estimator contract must hold)."""
    fam = make_family("dmh", storage=wmh_storage(256), seed=8)
    rng = np.random.default_rng(63)
    full, left, right = _disjoint_split(rng, nnz=400)
    probe_v = SparseVec.from_pairs(
        np.asarray(full.indices[:200]), np.asarray(full.values[:200]) * 0.7,
        2**31)

    merged = fam.merge_rows(_family_fields(fam, [left]),
                            _family_fields(fam, [right]))
    whole = _family_fields(fam, [full])
    probe = _family_fields(fam, [probe_v])

    est_m = float(np.asarray(fam.estimate_fields(
        _f1(probe), _f1(merged), qmap=(0,), cmap=(0,))[0])[0, 0])
    est_w = float(np.asarray(fam.estimate_fields(
        _f1(probe), _f1(whole), qmap=(0,), cmap=(0,))[0])[0, 0])
    true = float(0.7 * np.sum(np.asarray(full.values[:200]) ** 2))
    scale = abs(true)
    assert abs(est_m - true) <= 0.35 * scale
    assert abs(est_m - est_w) <= 0.5 * scale


# ---------------------------------------------------------------------------
# stream registry: host twins mirror the kernel constants
# ---------------------------------------------------------------------------
def test_dmh_stream_constants_in_sync():
    pairs = [("DMH_BIN_STREAM",), ("DMH_R1_STREAM",), ("DMH_R2_STREAM",),
             ("DMH_C1_STREAM",), ("DMH_C2_STREAM",), ("DMH_BETA_STREAM",),
             ("DMH_FP_STREAM",), ("DMH_DENSIFY_STREAM",)]
    for (name,) in pairs:
        assert getattr(u32, name) == getattr(kcommon, name), name
    # DMH streams collide with no other registered stream
    ids = [getattr(kcommon, n) for (n,) in pairs]
    assert len(set(ids)) == len(ids)
    all_streams = kcommon.streams()
    for i in ids:
        assert i in set(all_streams.values())
