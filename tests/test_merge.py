"""Merge algebra, shard-and-merge lake builds, and the multi-tenant arena.

Pins the contracts of :mod:`repro.data.merge` and the per-family
``merge_rows`` semantics (:mod:`repro.data.families`):

  * ``split_by_key`` partitions are disjoint, complete, and alias-safe
    (raw indices folding to one 31-bit key land in one shard).
  * ``merge_rows`` commutes bitwise for every family; the linear and
    sampling merges are associative (bitwise tables on integer data;
    bitwise keys/values with float-ulp taus).  ICWS is deliberately NOT
    associative -- re-leveling composes approximately -- so no such claim
    is tested.
  * Sharded builds match single-stream builds: bitwise tables for cs/jl
    on integer-valued data, bitwise keys/values (tau to f32 ulp) for
    ts/ps, and -- for ICWS, whose merge is approximate -- bit-identity
    between the device ``merge_rows`` and the host ``ICWS.merge`` union
    oracle, plus preserved top-k rankings on a separated lake.
  * ``merge_stores`` refuses cross-seed / cross-family / row-misaligned /
    tenant-misaligned inputs, and merged stores keep their spare capacity
    rows inert.
  * The multi-tenant arena serves every tenant bitwise identically to a
    dedicated single-tenant index -- contiguous (buffer-slice fast path)
    and fragmented (gather path) tenants alike, on both backends -- and
    the service front-end scopes duplicate-name checks and ``describe``
    per tenant.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import SparseVec
from repro.core.icws import ICWS, ICWSSketch
from repro.core.types import inner
from repro.data import DatasetSearchIndex
from repro.data.families import (CSFamily, ICWSFamily, JLFamily, PSFamily,
                                 TSFamily)
from repro.data.merge import (build_sharded, merge_stores, partition_by_key,
                              split_by_key)
from repro.data.store import CorpusStore
from repro.serve import SketchSearchService

SEED = 3


def _families():
    # jl m is a power of 4 on purpose: its 1/sqrt(m) post-scale is then a
    # power of two, so integer-valued shard tables stay exactly
    # representable and the linearity merge is bitwise (any other m leaves
    # the scale inexact and shard addition exact only to the final-rounding
    # ulp -- see the sharded-ingest ranking tests)
    return {"icws": ICWSFamily(m=64, seed=SEED),
            "cs": CSFamily(width=16, seed=SEED),
            "jl": JLFamily(m=64, seed=SEED),
            "ts": TSFamily(slots=32, seed=SEED),
            "ps": PSFamily(slots=32, seed=SEED)}


def _vec(rng, n=4000, nnz=200, integer=False):
    idx = np.sort(rng.choice(n, size=nnz, replace=False)).astype(np.int64)
    if integer:
        vals = (rng.integers(1, 6, size=nnz)
                * rng.choice([-1.0, 1.0], size=nnz))
    else:
        vals = rng.normal(size=nnz)
        vals[vals == 0.0] = 1.0
    return SparseVec.from_pairs(idx, vals, n)


def _shard_comps(family, vecs, shards):
    """Per-shard family components with the [F=1] axis merge_rows expects."""
    out = []
    for s in range(shards):
        parts = [split_by_key(v, shards, s) for v in vecs]
        comps = family.sketch_rows(parts)
        out.append(tuple(jnp.asarray(c)[None] for c in comps))
    return out


def _np(comps):
    return tuple(np.asarray(c) for c in comps)


# ---------------------------------------------------------------------------
# split_by_key: disjoint, complete, alias-safe partitions
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("shards", [2, 3, 5])
def test_split_by_key_partitions_disjoint_and_complete(shards):
    rng = np.random.default_rng(11)
    v = _vec(rng)
    parts = [split_by_key(v, shards, s) for s in range(shards)]
    got_idx = np.concatenate([p.indices for p in parts])
    got_val = np.concatenate([p.values for p in parts])
    order = np.argsort(got_idx)
    assert np.array_equal(got_idx[order], v.indices)          # complete
    assert np.unique(got_idx).size == got_idx.size            # disjoint
    np.testing.assert_array_equal(got_val[order], v.values)
    # partition inner products sum to the full inner product (disjointness)
    w = _vec(rng)
    wp = [split_by_key(w, shards, s) for s in range(shards)]
    total = sum(inner(p, q) for p, q in zip(parts, wp))
    np.testing.assert_allclose(total, inner(v, w), rtol=1e-12)


def test_split_by_key_shard1_and_validation():
    rng = np.random.default_rng(1)
    v = _vec(rng)
    assert split_by_key(v, 1, 0) is v
    with pytest.raises(ValueError):
        split_by_key(v, 0, 0)
    with pytest.raises(ValueError):
        split_by_key(v, 2, 2)
    with pytest.raises(ValueError):
        split_by_key(v, 2, -1)


def test_partition_by_key_matches_split_by_key():
    """The one-pass k-way partition (a producer's routing pass) must equal
    the per-shard scans element for element, plus shard-1 identity and
    validation."""
    rng = np.random.default_rng(17)
    v = _vec(rng)
    for shards in (2, 3, 5):
        parts = partition_by_key(v, shards)
        assert len(parts) == shards
        for s, p in enumerate(parts):
            q = split_by_key(v, shards, s)
            assert np.array_equal(p.indices, q.indices), (shards, s)
            np.testing.assert_array_equal(p.values, q.values)
            assert p.n == q.n
    assert partition_by_key(v, 1) == (v,)
    with pytest.raises(ValueError):
        partition_by_key(v, 0)


def test_split_by_key_folds_before_hashing():
    """Raw indices that alias to one 31-bit folded key (one coordinate to
    every u32-contract sketch) must land in the same shard."""
    lo = 12345
    v = SparseVec.from_pairs(np.array([lo, lo + 2 ** 31], np.int64),
                             np.array([1.0, 2.0]), 2 ** 32)
    for shards in (2, 3, 7):
        sizes = [split_by_key(v, shards, s).nnz for s in range(shards)]
        assert sorted(sizes) == [0] * (shards - 1) + [2], (shards, sizes)


# ---------------------------------------------------------------------------
# merge_rows algebra
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", ["icws", "cs", "jl", "ts", "ps"])
def test_merge_rows_commutes_bitwise(name):
    family = _families()[name]
    rng = np.random.default_rng(21)
    vecs = [_vec(rng) for _ in range(6)]
    a, b = _shard_comps(family, vecs, 2)
    ab, ba = _np(family.merge_rows(a, b)), _np(family.merge_rows(b, a))
    for x, y, spec in zip(ab, ba, family.components):
        assert np.array_equal(x, y), (name, spec.name)


@pytest.mark.parametrize("name", ["cs", "jl"])
def test_linear_merge_associative_bitwise_on_integer_data(name):
    family = _families()[name]
    rng = np.random.default_rng(22)
    vecs = [_vec(rng, integer=True) for _ in range(5)]
    a, b, c = _shard_comps(family, vecs, 3)
    left = family.merge_rows(family.merge_rows(a, b), c)
    right = family.merge_rows(a, family.merge_rows(b, c))
    assert np.array_equal(np.asarray(left[0]), np.asarray(right[0]))


@pytest.mark.parametrize("name", ["ts", "ps"])
def test_sampling_merge_associative(name):
    """Keys and values associate exactly; taus only to f32 rounding (the
    intermediate merge stores its tau in f32)."""
    family = _families()[name]
    rng = np.random.default_rng(23)
    vecs = [_vec(rng) for _ in range(5)]
    a, b, c = _shard_comps(family, vecs, 3)
    kl, vl, tl = _np(family.merge_rows(family.merge_rows(a, b), c))
    kr, vr, tr = _np(family.merge_rows(a, family.merge_rows(b, c)))
    assert np.array_equal(kl, kr)
    assert np.array_equal(vl, vr)
    np.testing.assert_allclose(tl, tr, rtol=1e-5)


def test_sampling_merge_rejects_shared_keys():
    """Union-merge preconditions disjoint supports -- merging a shard with
    itself (every kept key on both sides) must refuse, not mis-estimate."""
    family = _families()["ts"]
    rng = np.random.default_rng(24)
    (a,) = _shard_comps(family, [_vec(rng)], 1)
    with pytest.raises(ValueError, match="disjoint"):
        family.merge_rows(a, a)


# ---------------------------------------------------------------------------
# sharded builds vs single-stream builds, per family
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name,shards", [("cs", 2), ("cs", 3),
                                         ("jl", 2), ("jl", 3)])
def test_linear_build_sharded_bitwise_on_integer_data(name, shards):
    family = _families()[name]
    rng = np.random.default_rng(31)
    vecs = [_vec(rng, integer=True) for _ in range(7)]
    single = np.asarray(family.sketch_rows(vecs)[0])
    store = build_sharded(vecs, family=family, shards=shards)
    assert len(store) == len(vecs)
    merged = np.asarray(store.field_arrays()[0])[0]
    assert np.array_equal(merged, single)


@pytest.mark.parametrize("name,shards", [("ts", 2), ("ts", 3),
                                         ("ps", 2), ("ps", 3)])
def test_sampling_build_sharded_matches_single_stream(name, shards):
    """Union re-subsampling reproduces the build-once sample: keys and
    values bitwise; taus recompute from f32-stored inputs, so they agree
    to f32 rounding only."""
    family = _families()[name]
    rng = np.random.default_rng(32)
    vecs = [_vec(rng) for _ in range(7)]
    k1, v1, t1 = _np(family.sketch_rows(vecs))
    store = build_sharded(vecs, family=family, shards=shards)
    k2, v2, t2 = (np.asarray(c)[0] for c in store.field_arrays())
    assert np.array_equal(k1, k2)
    assert np.array_equal(v1, v2)
    np.testing.assert_allclose(t1, t2, rtol=1e-5)


def test_icws_device_merge_matches_host_union_oracle():
    """The device ``ICWSFamily.merge_rows`` and the host ``ICWS.merge``
    union oracle are bit-twins on identical inputs: same fingerprints and
    argkeys, values to f32 rounding.  (The merge itself is approximate
    relative to a build-once sketch; THIS identity is the correctness
    contract.)"""
    family = _families()["icws"]
    oracle = ICWS(m=family.m, seed=SEED)
    rng = np.random.default_rng(33)
    vecs = [_vec(rng) for _ in range(5)]
    a, b = _shard_comps(family, vecs, 2)
    fp_m, val_m, norm_m, key_m = _np(family.merge_rows(a, b))
    (fpa, va, na, ka), (fpb, vb, nb, kb) = _np(a), _np(b)
    for i in range(len(vecs)):
        sa = ICWSSketch(fingerprints=fpa[0, i],
                        values=va[0, i].astype(np.float64),
                        norm=float(na[0, i]), argkeys=ka[0, i])
        sb = ICWSSketch(fingerprints=fpb[0, i],
                        values=vb[0, i].astype(np.float64),
                        norm=float(nb[0, i]), argkeys=kb[0, i])
        host = oracle.merge(sa, sb)
        assert np.array_equal(host.fingerprints, fp_m[0, i]), i
        assert np.array_equal(host.argkeys, key_m[0, i]), i
        np.testing.assert_allclose(host.values, val_m[0, i],
                                   rtol=1e-5, atol=1e-7)
        np.testing.assert_allclose(host.norm, norm_m[0, i], rtol=1e-6)


# ---------------------------------------------------------------------------
# merge_stores validation + merged-store invariants
# ---------------------------------------------------------------------------
def _ts_store(family, vecs):
    store = CorpusStore(family=family, fields=1)
    store.append(*family.sketch_rows(vecs))
    return store


def test_merge_stores_rejects_cross_seed():
    """Satellite regression: per-family seeds plumb through to the merge,
    and a cross-seed merge -- whose coordinated hash streams do NOT line
    up -- must refuse loudly."""
    rng = np.random.default_rng(41)
    vecs = [_vec(rng) for _ in range(3)]
    a = _ts_store(TSFamily(slots=32, seed=1), vecs)
    b = _ts_store(TSFamily(slots=32, seed=2), vecs)
    with pytest.raises(ValueError, match="seed"):
        merge_stores(a, b)


def test_merge_stores_rejects_misaligned_inputs():
    rng = np.random.default_rng(42)
    fam = TSFamily(slots=32, seed=SEED)
    vecs = [_vec(rng) for _ in range(4)]
    a = _ts_store(fam, vecs)
    with pytest.raises(ValueError, match="row-aligned"):
        merge_stores(a, _ts_store(fam, vecs[:2]))
    with pytest.raises(ValueError, match="famil"):
        merge_stores(a, _ts_store(TSFamily(slots=16, seed=SEED), vecs))
    # tenant tables must agree row for row (disjoint shard partitions, so
    # only the tenant check can fire)
    lo = [split_by_key(v, 2, 0) for v in vecs]
    hi = [split_by_key(v, 2, 1) for v in vecs]
    c = CorpusStore(family=fam, fields=1)
    c.append(*fam.sketch_rows(lo), tenant="acme")
    with pytest.raises(ValueError, match="tenant"):
        merge_stores(a, c)
    # and identical tenant tables survive the merge verbatim
    d = CorpusStore(family=fam, fields=1)
    d.append(*fam.sketch_rows(hi), tenant="acme")
    m = merge_stores(c, d)
    assert m.tenants() == ("acme",)
    assert m.tenant_ranges("acme") == ((0, len(vecs)),)


@pytest.mark.parametrize("name", ["icws", "cs", "ts"])
def test_merged_store_spare_rows_stay_inert(name):
    """A merged store is a first-class store: spare capacity keeps the
    family fills (so query launches over full buffers stay exact) and
    further appends work."""
    family = _families()[name]
    rng = np.random.default_rng(43)
    vecs = [_vec(rng) for _ in range(5)]
    store = build_sharded(vecs, family=family, shards=2)
    assert store.capacity > len(store)
    for buf, spec in zip(store.buffers(), family.components):
        spare = np.asarray(buf[:, len(store):])
        assert np.all(spare == spec.fill), (name, spec.name)
    store.append(*family.sketch_rows([_vec(rng)]))
    assert len(store) == 6


# ---------------------------------------------------------------------------
# end-to-end: sharded lake builds preserve rankings
# ---------------------------------------------------------------------------
def _separated_lake(rng, integer=False):
    """A lake the ranking cannot confuse: near-duplicates of the query
    signal vs disjoint-support noise tables (the ICWS merge is approximate,
    so ranking invariance is only promised on separated lakes)."""
    keys = np.arange(500)
    if integer:
        # strictly integer, strictly non-zero values everywhere (zeros get
        # nudged to 1e-9 by vectorize, which would de-integerize the lake):
        # shard tables then sum exactly in f32
        signal = (rng.integers(1, 9, size=500)
                  * rng.choice([-1.0, 1.0], size=500))
        jitter = lambda: signal + rng.integers(10, 13, size=500)  # noqa: E731
        noise = lambda: (rng.integers(1, 9, size=500)             # noqa: E731
                         * rng.choice([-1.0, 1.0], size=500))
    else:
        signal = rng.normal(size=500)
        jitter = lambda: signal + 0.01 * rng.normal(size=500)  # noqa: E731
        noise = lambda: rng.normal(size=500)                   # noqa: E731
    tables = [(f"dup{i}", keys, jitter()) for i in range(3)]
    tables += [(f"far{i}", np.arange(9000 + 600 * i, 9500 + 600 * i),
                noise()) for i in range(4)]
    return tables, [(keys, signal),
                    (np.arange(250, 750), rng.normal(size=500))]


def _linear_lake_indexes(name):
    rng = np.random.default_rng(51)
    tables, queries = _separated_lake(rng, integer=True)

    def build(sharded):
        idx = DatasetSearchIndex(m=128, seed=1, keep_host_oracle=False,
                                 family=name)
        if sharded:
            idx.add_tables_sharded(tables, shards=3)
        else:
            for nm, k, v in tables:
                idx.add_table(nm, k, v)
        return idx

    return build(False), build(True), queries


def test_sharded_ingest_rankings_bitwise_cs():
    single, sharded, queries = _linear_lake_indexes("cs")
    # integer-valued lake => shard tables sum exactly (CountSketch buckets
    # are unscaled signed sums) => bitwise estimates => identical
    # SearchResults, every statistic included
    assert (single.query_batch(queries, top_k=4, min_join=20)
            == sharded.query_batch(queries, top_k=4, min_join=20))


def test_sharded_ingest_rankings_jl_exact_to_scale_ulp():
    """JL tables carry a 1/sqrt(m) post-scale; with the storage-matched m
    (193 here) the scale is not a binary fraction, so shard addition is
    exact only to the final-rounding ulp of each cell.  Rankings and every
    statistic must still agree to f32 relative tolerance (the bitwise
    linearity itself is pinned at power-of-4 m in
    test_linear_build_sharded_bitwise_on_integer_data)."""
    single, sharded, queries = _linear_lake_indexes("jl")
    for res_s, res_h in zip(single.query_batch(queries, top_k=4, min_join=20),
                            sharded.query_batch(queries, top_k=4, min_join=20)):
        assert [r.name for r in res_s] == [r.name for r in res_h]
        for a, b in zip(res_s, res_h):
            np.testing.assert_allclose(
                [a.join_size, a.sum_b, a.mean_b, a.corr],
                [b.join_size, b.sum_b, b.mean_b, b.corr],
                rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("name", ["icws", "ts", "ps"])
def test_sharded_ingest_rankings_topk_set(name):
    rng = np.random.default_rng(52)
    tables, queries = _separated_lake(rng)

    def build(sharded):
        idx = DatasetSearchIndex(m=256, seed=1, keep_host_oracle=False,
                                 family=name)
        if sharded:
            idx.add_tables_sharded(tables, shards=3)
        else:
            for nm, k, v in tables:
                idx.add_table(nm, k, v)
        return idx

    single, sharded = build(False), build(True)
    for res_s, res_h in zip(single.query_batch(queries, top_k=3, min_join=20),
                            sharded.query_batch(queries, top_k=3, min_join=20)):
        assert {r.name for r in res_s} == {r.name for r in res_h}, name
    # the signal query must surface the near-duplicates in both builds
    top = sharded.query(*queries[0], top_k=3, min_join=20)
    assert {r.name for r in top} == {"dup0", "dup1", "dup2"}, name


def test_add_tables_sharded_requires_device_corpus():
    idx = DatasetSearchIndex(m=64, seed=0, backend="host")
    with pytest.raises(ValueError, match="device corpus"):
        idx.add_tables_sharded([("t", np.arange(8), np.ones(8))], shards=2)


# ---------------------------------------------------------------------------
# multi-tenant arena == dedicated single-tenant stores, bitwise
# ---------------------------------------------------------------------------
def _tenant_tables(rng, prefix, count=3):
    keys = np.arange(400)
    return [(f"{prefix}{i}", keys,
             rng.normal(size=400) + (0.5 * i) * np.sin(keys / 7.0))
            for i in range(count)]


def test_tenant_queries_bitwise_equal_dedicated_index():
    rng = np.random.default_rng(61)
    acme = _tenant_tables(rng, "acme")
    globex = _tenant_tables(rng, "globex")
    initech = _tenant_tables(rng, "initech")

    shared = DatasetSearchIndex(m=128, seed=2)
    # interleave acme/globex appends -> both tenants fragment across the
    # arena (gather path); initech appends back-to-back -> one contiguous
    # range (buffer-slice fast path)
    for (na, ka, va), (ng, kg, vg) in zip(acme, globex):
        shared.add_table(na, ka, va, tenant="acme")
        shared.add_table(ng, kg, vg, tenant="globex")
    for nm, k, v in initech:
        shared.add_table(nm, k, v, tenant="initech")

    assert len(shared.store.tenant_ranges("acme")) > 1
    assert len(shared.store.tenant_ranges("globex")) > 1
    assert shared.store.tenant_ranges("initech") == ((6, 9),)
    assert shared.store.tenant_size("acme") == 3
    assert set(shared.tenants()) == {"acme", "globex", "initech"}

    queries = [(np.arange(400), rng.normal(size=400)),
               (np.arange(100, 500), rng.normal(size=400))]
    for tenant, tabs in (("acme", acme), ("globex", globex),
                         ("initech", initech)):
        dedicated = DatasetSearchIndex(m=128, seed=2)
        for nm, k, v in tabs:
            dedicated.add_table(nm, k, v)
        for k, v in queries:
            # device path (gather or slice, depending on the tenant)
            assert (shared.query(k, v, top_k=3, min_join=5, tenant=tenant)
                    == dedicated.query(k, v, top_k=3, min_join=5)), tenant
            # host oracle path scopes to the same tenant tables
            assert (shared.query(k, v, top_k=3, min_join=5, tenant=tenant,
                                 backend="host")
                    == dedicated.query(k, v, top_k=3, min_join=5,
                                       backend="host")), tenant
        assert (shared.query_batch(queries, top_k=3, min_join=5,
                                   tenant=tenant)
                == dedicated.query_batch(queries, top_k=3, min_join=5))

    with pytest.raises(KeyError, match="unknown tenant"):
        shared.query(np.arange(10), np.ones(10), tenant="nope")
    with pytest.raises(KeyError, match="unknown tenant"):
        shared.store.tenant_ranges("nope")


def test_store_tenant_accounting():
    fam = TSFamily(slots=32, seed=SEED)
    rng = np.random.default_rng(62)
    store = CorpusStore(family=fam, fields=1)
    store.append(*fam.sketch_rows([_vec(rng) for _ in range(3)]), tenant="a")
    store.append(*fam.sketch_rows([_vec(rng)]))              # tenant-less
    store.append(*fam.sketch_rows([_vec(rng) for _ in range(2)]), tenant="a")
    store.append(*fam.sketch_rows([_vec(rng)]), tenant="b")
    assert store.tenants() == ("a", "b")
    assert store.tenant_ranges("a") == ((0, 3), (4, 6))
    assert np.array_equal(store.tenant_rows("a"), [0, 1, 2, 4, 5])
    assert store.tenant_size("b") == 1
    acct = store.describe_tenants()
    assert acct["a"]["rows"] == 5.0 and acct["a"]["ranges"] == 2.0
    assert acct["a"]["storage_doubles"] == pytest.approx(
        5 * fam.storage_doubles_per_row())
    # back-to-back same-tenant appends coalesce into one range
    store.append(*fam.sketch_rows([_vec(rng)]), tenant="b")
    assert store.tenant_ranges("b") == ((6, 8),)


def test_service_tenancy_end_to_end():
    rng = np.random.default_rng(63)
    svc = SketchSearchService(m=128, seed=2, keep_host_oracle=False)
    keys = np.arange(300)
    svc.ingest("sales", keys, rng.normal(size=300), tenant="acme")
    # two tenants may each own a table called "sales"...
    svc.ingest("sales", keys, rng.normal(size=300), tenant="globex")
    svc.ingest("костs", keys, rng.normal(size=300), tenant="acme")
    # ...but within one tenant the name is taken
    with pytest.raises(ValueError, match="acme"):
        svc.ingest("sales", keys, rng.normal(size=300), tenant="acme")
    # sharded ingest shares the per-tenant duplicate check (and catches
    # within-batch duplicates)
    with pytest.raises(ValueError, match="sales"):
        svc.ingest_many_sharded([("sales", keys, rng.normal(size=300))],
                                shards=2, tenant="globex")
    with pytest.raises(ValueError, match="fresh"):
        svc.ingest_many_sharded(
            [("fresh", keys, rng.normal(size=300)),
             ("fresh", keys, rng.normal(size=300))], shards=2, tenant="acme")
    svc.ingest_many_sharded([("lake0", keys, rng.normal(size=300)),
                             ("lake1", keys, rng.normal(size=300))],
                            shards=2, tenant="globex")

    q = (keys, rng.normal(size=300))
    names = {r.name for r in svc.search(*q, top_k=10, min_join=5,
                                        tenant="globex")}
    assert names <= {"sales", "lake0", "lake1"}
    assert [r.name for batch in
            svc.search_batch([q], top_k=10, min_join=5, tenant="acme")
            for r in batch if r.name == "sales"]

    d = svc.describe(tenant="globex")
    assert d["tenant"] == "globex" and d["tables"] == 3.0
    assert d["corpus_rows"] == 3.0 and d["row_ranges"] >= 1.0
    assert d["storage_doubles"] > 0
    d_all = svc.describe()
    assert d_all["tenants"] == 2.0 and d_all["tables"] == 5.0
    with pytest.raises(KeyError, match="unknown tenant"):
        svc.describe(tenant="nope")


def test_sharded_ingest_into_tenant_is_contiguous():
    """add_tables_sharded appends the whole merged batch in one write, so
    the tenant stays single-range and serves off the slice fast path."""
    rng = np.random.default_rng(64)
    idx = DatasetSearchIndex(m=128, seed=2, keep_host_oracle=False)
    idx.add_tables_sharded(_tenant_tables(rng, "t"), shards=2,
                           tenant="acme")
    assert idx.store.tenant_ranges("acme") == ((0, 3),)
    res = idx.query(np.arange(400), rng.normal(size=400), top_k=3,
                    min_join=5, tenant="acme")
    assert {r.name for r in res} <= {"t0", "t1", "t2"}
