"""Integration tests: training loop (convergence, resume, preemption) and the
batched serving engine."""
import dataclasses

import jax
import numpy as np
import pytest

from repro import configs
from repro.models import Model
from repro.optim import AdamWConfig
from repro.serve.engine import Request, ServeEngine
from repro.train.trainer import Trainer, TrainerConfig


def _tiny_cfg():
    return dataclasses.replace(
        configs.reduced("tinyllama-1.1b"),
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256)


def _tcfg(tmp=None, steps=24, total_steps=None, **kw):
    return TrainerConfig(
        steps=steps, global_batch=4, seq=32, microbatches=2,
        ckpt_dir=str(tmp) if tmp else None, ckpt_every=8, log_every=100,
        opt=AdamWConfig(lr=2e-3, warmup_steps=4,
                        total_steps=total_steps or steps), **kw)


def test_trainer_loss_decreases():
    hist = Trainer(_tiny_cfg(), _tcfg(steps=30), log_fn=lambda s: None).run()
    first = np.mean(hist["loss"][:5])
    last = np.mean(hist["loss"][-5:])
    assert last < first - 0.1, (first, last)


def test_trainer_checkpoint_resume_bitexact(tmp_path):
    """Crash/restart: resuming from a checkpoint must replay the identical
    data stream and produce the identical final state (full determinism)."""
    cfg = _tiny_cfg()
    # uninterrupted run
    hist_a = Trainer(cfg, _tcfg(tmp_path / "a", steps=16),
                     log_fn=lambda s: None).run()
    # interrupted at 8 (ckpt_every=8), then resumed to 16 -- the interrupted
    # phase must run the SAME lr schedule (total_steps=16) as the full job
    t1 = Trainer(cfg, _tcfg(tmp_path / "b", steps=8, total_steps=16),
                 log_fn=lambda s: None)
    t1.run()
    t2 = Trainer(cfg, _tcfg(tmp_path / "b", steps=16), log_fn=lambda s: None)
    hist_b = t2.run()
    assert hist_b["step"][0] == 8  # resumed, not restarted
    np.testing.assert_allclose(hist_a["loss"][8:], hist_b["loss"],
                               rtol=2e-4, atol=2e-4)


def test_trainer_preemption_checkpoints_and_stops(tmp_path):
    cfg = _tiny_cfg()
    trainer = Trainer(cfg, _tcfg(tmp_path, steps=1000), log_fn=lambda s: None)
    trainer.preemption.trigger_for_test()
    hist = trainer.run()
    assert len(hist["loss"]) <= 2          # stopped immediately
    from repro.checkpoint import latest_step
    assert latest_step(tmp_path) is not None  # but saved first


def test_serve_engine_drains_and_batches():
    cfg = _tiny_cfg()
    model = Model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(model, params, batch_slots=3, max_seq=64)
    reqs = [Request(rid=i, prompt=[i + 1, i + 2], max_new_tokens=5)
            for i in range(5)]                       # more requests than slots
    for r in reqs:
        engine.submit(r)
    for _ in range(200):
        if all(r.done for r in reqs):
            break
        engine.tick()
    assert all(r.done for r in reqs)
    assert all(len(r.output) == 5 for r in reqs)
    assert all(0 <= t < cfg.vocab_size for r in reqs for t in r.output)


def test_serve_engine_eos_stops_early():
    cfg = _tiny_cfg()
    model = Model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(model, params, batch_slots=2, max_seq=64)
    # find what the model emits first, then use it as EOS for a second request
    probe = Request(rid=0, prompt=[5], max_new_tokens=1)
    engine.submit(probe)
    while not probe.done:
        engine.tick()
    eos = probe.output[0]
    req = Request(rid=1, prompt=[5], max_new_tokens=10, eos=eos)
    engine.submit(req)
    for _ in range(100):
        if req.done:
            break
        engine.tick()
    assert req.done and len(req.output) == 1  # stopped at EOS immediately
