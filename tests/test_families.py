"""Family-generic serving stack: CS/JL device corpora beside ICWS.

Covers: the new linear sketch/estimate kernels vs their jnp oracles
(property-tested sweeps are ``slow``; fixed-shape smokes run in the fast
lane); storage-matched family construction; the store's inert-spare-row
invariant head-on, for every family layout at several fill fractions;
device CS/JL corpus estimates vs the ``core/linear.py`` u32 host oracles
(<= 1e-5 rel on real sketches); and end-to-end batched-vs-sequential
ranking bitwise identity for every family.
"""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import ICWS, stack_icws
from repro.core.linear import CountSketchU32, JLU32
from repro.data import (FAMILY_NAMES, DatasetSearchIndex, make_family,
                        wmh_storage)
from repro.data.store import CorpusStore
from repro.data.synthetic import sparse_pair
from repro.kernels import ref
from repro.kernels.countsketch import countsketch_pallas, countsketch_sparse_pallas
from repro.kernels.estimate import linear_estimate_fields_pallas
from repro.kernels.jl_sketch import jl_sketch_pallas
from repro.serve import SketchSearchService

STORAGE = wmh_storage(256)


def _families(seed=0):
    return [make_family(name, storage=STORAGE, seed=seed)
            for name in FAMILY_NAMES]


def _padded_batch(rng, B, N, pad_from=None):
    keys = rng.integers(0, 2 ** 31 - 1, (B, N)).astype(np.int32)
    vals = rng.normal(size=(B, N)).astype(np.float32)
    if pad_from is not None:
        vals[:, pad_from:] = 0.0
    return jnp.asarray(keys), jnp.asarray(vals)


# ---------------------------------------------------------------------------
# new kernels vs ref oracles
# ---------------------------------------------------------------------------
def test_cs_sparse_kernel_matches_ref_smoke():
    rng = np.random.default_rng(0)
    keys, vals = _padded_batch(rng, 3, 300, pad_from=250)
    tk = countsketch_sparse_pallas(keys, vals, width=77, reps=5, seed=3,
                                   interpret=True)
    tr = ref.countsketch_sparse_ref(keys, vals, 77, 5, 3)
    assert tk.shape == (3, 5, 77)
    np.testing.assert_allclose(np.asarray(tk), np.asarray(tr),
                               rtol=1e-5, atol=1e-5)


def test_cs_sparse_kernel_matches_dense_kernel_on_positions():
    """Sparse-by-key == dense-by-position when keys are the positions --
    the contract that lets gradient tables and corpus tables interoperate."""
    rng = np.random.default_rng(1)
    x = rng.normal(size=130).astype(np.float32)
    dense = countsketch_pallas(jnp.asarray(x), width=33, reps=5, seed=1,
                               interpret=True)
    keys = jnp.asarray(np.arange(130, dtype=np.int32)[None, :])
    sparse = countsketch_sparse_pallas(keys, jnp.asarray(x[None, :]),
                                       width=33, reps=5, seed=1,
                                       interpret=True)
    np.testing.assert_array_equal(np.asarray(dense), np.asarray(sparse)[0])


def test_jl_kernel_matches_ref_smoke():
    rng = np.random.default_rng(2)
    keys, vals = _padded_batch(rng, 3, 300, pad_from=250)
    pk = jl_sketch_pallas(keys, vals, m=200, seed=7, interpret=True)
    pr = ref.jl_sketch_ref(keys, vals, 200, 7)
    assert pk.shape == (3, 200)
    np.testing.assert_allclose(np.asarray(pk), np.asarray(pr),
                               rtol=1e-4, atol=1e-5)


def test_linear_estimate_kernel_matches_ref_smoke():
    rng = np.random.default_rng(3)
    F, C, Q, P, R, W = 3, 3, 5, 9, 5, 77
    tq = jnp.asarray(rng.normal(size=(F, Q, R, W)).astype(np.float32))
    tc = jnp.asarray(rng.normal(size=(C, P, R, W)).astype(np.float32))
    qmap, cmap = (0, 1, 0, 2, 0, 1), (0, 0, 1, 0, 2, 1)
    ek = linear_estimate_fields_pallas(tq, tc, qmap=qmap, cmap=cmap,
                                       interpret=True)
    er = ref.linear_estimate_fields_ref(tq, tc, qmap=qmap, cmap=cmap)
    assert ek.shape == (6, 5, 5, 9)
    er = np.asarray(er)
    scale = max(1.0, float(np.max(np.abs(er))))
    np.testing.assert_allclose(np.asarray(ek), er, rtol=1e-4,
                               atol=1e-4 * scale)


@pytest.mark.slow
@settings(max_examples=10, deadline=None)
@given(b=st.integers(1, 6), n=st.integers(1, 600), width=st.integers(1, 200),
       reps=st.integers(1, 6), seed=st.integers(0, 2 ** 31 - 1))
def test_cs_sparse_kernel_matches_ref(b, n, width, reps, seed):
    rng = np.random.default_rng(seed)
    keys, vals = _padded_batch(rng, b, n)
    tk = countsketch_sparse_pallas(keys, vals, width=width, reps=reps,
                                   seed=seed, interpret=True)
    tr = np.asarray(ref.countsketch_sparse_ref(keys, vals, width, reps, seed))
    scale = max(1.0, float(np.max(np.abs(tr))))
    np.testing.assert_allclose(np.asarray(tk), tr, rtol=1e-4,
                               atol=1e-4 * scale)


@pytest.mark.slow
@settings(max_examples=10, deadline=None)
@given(b=st.integers(1, 6), n=st.integers(1, 600), m=st.integers(1, 300),
       seed=st.integers(0, 2 ** 31 - 1))
def test_jl_kernel_matches_ref(b, n, m, seed):
    rng = np.random.default_rng(seed)
    keys, vals = _padded_batch(rng, b, n)
    pk = jl_sketch_pallas(keys, vals, m=m, seed=seed, interpret=True)
    pr = np.asarray(ref.jl_sketch_ref(keys, vals, m, seed))
    scale = max(1.0, float(np.max(np.abs(pr))))
    np.testing.assert_allclose(np.asarray(pk), pr, rtol=1e-4,
                               atol=1e-4 * scale)


@pytest.mark.slow
@settings(max_examples=8, deadline=None)
@given(data=st.data())
def test_linear_estimate_kernel_matches_ref(data):
    seed = data.draw(st.integers(0, 2 ** 31 - 1))
    rng = np.random.default_rng(seed)
    F = data.draw(st.integers(1, 3))
    C = data.draw(st.integers(1, 3))
    G = data.draw(st.integers(1, 7))
    qmap = tuple(data.draw(st.integers(0, F - 1)) for _ in range(G))
    cmap = tuple(data.draw(st.integers(0, C - 1)) for _ in range(G))
    Q, P = data.draw(st.integers(1, 10)), data.draw(st.integers(1, 14))
    R, W = data.draw(st.integers(1, 6)), data.draw(st.integers(1, 160))
    tq = jnp.asarray(rng.normal(size=(F, Q, R, W)).astype(np.float32))
    tc = jnp.asarray(rng.normal(size=(C, P, R, W)).astype(np.float32))
    ek = linear_estimate_fields_pallas(tq, tc, qmap=qmap, cmap=cmap,
                                       interpret=True)
    er = np.asarray(ref.linear_estimate_fields_ref(tq, tc, qmap=qmap,
                                                   cmap=cmap))
    assert ek.shape == (G, R, Q, P)
    scale = max(1.0, float(np.max(np.abs(er))))
    np.testing.assert_allclose(np.asarray(ek), er, rtol=1e-4,
                               atol=1e-4 * scale)


# ---------------------------------------------------------------------------
# storage-matched family construction
# ---------------------------------------------------------------------------
def test_host_kernel_stream_constants_in_sync():
    """The host u32 twins must name the same salt streams as the kernels --
    drifting either side silently breaks the CS/JL/TS/PS interop contract."""
    from repro.core import linear as host
    from repro.core import sampling as samp
    from repro.kernels import common as dev
    assert (host.CS_BUCKET_STREAM, host.CS_SIGN_STREAM, host.JL_SIGN_STREAM) \
        == (dev.CS_BUCKET_STREAM, dev.CS_SIGN_STREAM, dev.JL_SIGN_STREAM)
    assert samp.SAMPLE_HASH_STREAM == dev.SAMPLE_HASH_STREAM


def test_make_family_is_storage_matched():
    for fam in _families():
        # each family sizes itself within one row-granule of the budget
        # (registry integer sizing), never above it
        per_row = fam.storage_doubles_per_row()
        assert per_row <= STORAGE
        assert per_row > 0.5 * STORAGE, (fam.name, per_row, STORAGE)
    # the icws anchor round-trips exactly: index m == family m
    assert make_family("icws", storage=wmh_storage(256)).m == 256
    assert make_family("icws", storage=wmh_storage(123)).m == 123
    with pytest.raises(ValueError):
        make_family("bogus", storage=STORAGE)


def test_index_rejects_bad_family_combinations():
    with pytest.raises(ValueError):
        DatasetSearchIndex(m=64, family="bogus")
    with pytest.raises(ValueError):
        DatasetSearchIndex(m=64, family="cs", backend="host")
    # the per-query backend override is guarded too: a linear-family index
    # must never silently answer from the WMH host oracle
    idx = DatasetSearchIndex(m=64, family="jl")
    idx.add_table("t", np.arange(20), np.ones(20))
    with pytest.raises(ValueError):
        idx.query(np.arange(20), np.ones(20), backend="host")
    with pytest.raises(ValueError):
        idx.query_batch([(np.arange(20), np.ones(20))], backend="host")
    # linear families never build (or pay for) host oracle sketches
    assert not idx.keep_host_oracle
    assert idx.tables[0].key_indicator is None


# ---------------------------------------------------------------------------
# inert-spare-row invariant, head-on, for every family layout
# ---------------------------------------------------------------------------
QMAP = (0, 1, 0, 2, 0, 1)
CMAP = (0, 0, 1, 0, 2, 1)


def _field_rows(fam, rng, P, F=3):
    vecs = [sparse_pair(rng, n=400, nnz=80, overlap=0.3)[0]
            for _ in range(F * P)]
    comps = fam.sketch_rows(vecs)
    return tuple(jnp.swapaxes(c.reshape((P, F) + c.shape[1:]), 0, 1)
                 for c in comps)


@pytest.mark.parametrize("name", FAMILY_NAMES)
@pytest.mark.parametrize("fill", [3, 8, 13, 16])
def test_spare_capacity_bitwise_inert_per_family(name, fill):
    """Estimates off full-capacity buffers == estimates off exact-size
    buffers, bitwise, at several fill fractions (3/16 .. 16/16) -- the
    invariant that lets every family's query path skip materializing an
    exact-size corpus copy.  Spare rows must estimate to exactly zero."""
    fam = make_family(name, storage=wmh_storage(64), seed=5)
    rng = np.random.default_rng(100 + fill)
    rows = _field_rows(fam, rng, fill)

    store = CorpusStore(family=fam, fields=3, min_capacity=16)
    store.append(*rows)
    assert store.capacity == 16 and len(store) == fill

    # an exact-size store: min_capacity == fill, so capacity == rows
    exact = CorpusStore(family=fam, fields=3, min_capacity=fill)
    exact.append(*rows)
    assert exact.capacity == fill

    qrng = np.random.default_rng(7)
    qcomps = _field_rows(fam, qrng, 2)

    est_full = np.asarray(fam.estimate_fields(qcomps, store.buffers(),
                                              qmap=QMAP, cmap=CMAP))
    est_exact = np.asarray(fam.estimate_fields(qcomps, exact.buffers(),
                                               qmap=QMAP, cmap=CMAP))
    assert est_full.shape == (6, 2, 16)
    assert np.all(est_full[:, :, fill:] == 0.0)         # spare rows: zero
    np.testing.assert_array_equal(est_full[:, :, :fill], est_exact)


# ---------------------------------------------------------------------------
# device estimates vs the host u32 oracles (real sketches, <= 1e-5 rel)
# ---------------------------------------------------------------------------
def _f1(comps):
    """Stack F=1: [B, ...] components -> [1, B, ...]."""
    return tuple(c[None] for c in comps)


@pytest.mark.parametrize("name", ["cs", "jl"])
def test_linear_device_estimates_match_host_oracle(name):
    """Device CS/JL corpus estimates == core.linear u32 host-oracle
    estimates to 1e-5 relative, with sketches computed independently on
    each side (host f64 numpy vs device f32 Pallas)."""
    fam = make_family(name, storage=wmh_storage(256), seed=9)
    oracle = fam.host_oracle()
    rng = np.random.default_rng(11)
    corpus = [sparse_pair(rng, n=2000, nnz=300, overlap=0.2)[0]
              for _ in range(7)]
    queries = [sparse_pair(rng, n=2000, nnz=300, overlap=0.2)[0]
               for _ in range(4)]

    dev = np.asarray(fam.estimate_fields(
        _f1(fam.sketch_rows(queries)), _f1(fam.sketch_rows(corpus)),
        qmap=(0,), cmap=(0,))[0], np.float64)           # [Q, P]
    host = np.array([[oracle.estimate(oracle.sketch(q), oracle.sketch(c))
                      for c in corpus] for q in queries])
    scale = float(np.max(np.abs(host)))
    assert scale > 0
    np.testing.assert_allclose(dev, host, rtol=1e-5, atol=1e-5 * scale)


def test_icws_device_estimates_match_host_oracle():
    """The ICWS family keeps its host-oracle contract: the host estimator
    over device-produced sketches equals the device launch to 1e-5 rel
    (sketch-level host/device interop is pinned by test_icws_contract)."""
    fam = make_family("icws", storage=wmh_storage(256), seed=9)
    oracle = fam.host_oracle()
    rng = np.random.default_rng(13)
    corpus = [sparse_pair(rng, n=2000, nnz=300, overlap=0.2)[0]
              for _ in range(6)]
    queries = [sparse_pair(rng, n=2000, nnz=300, overlap=0.2)[0]
               for _ in range(3)]
    qc = fam.sketch_rows(queries)
    cc = fam.sketch_rows(corpus)
    dev = np.asarray(fam.estimate_fields(_f1(qc), _f1(cc),
                                         qmap=(0,), cmap=(0,))[0], np.float64)

    from repro.core.icws import StackedICWS
    fq, vq, nq = (np.asarray(a) for a in qc[:3])
    fc, vc, nc = (np.asarray(a) for a in cc[:3])
    host = np.stack([
        oracle.estimate_batch(
            StackedICWS(np.repeat(fq[i:i + 1], len(corpus), axis=0),
                        np.repeat(vq[i:i + 1].astype(np.float64), len(corpus),
                                  axis=0),
                        np.full(len(corpus), float(nq[i]))),
            StackedICWS(fc, vc.astype(np.float64), nc.astype(np.float64)))
        for i in range(len(queries))])
    scale = float(np.max(np.abs(host)))
    np.testing.assert_allclose(dev, host, rtol=1e-5, atol=1e-5 * scale)


# ---------------------------------------------------------------------------
# end-to-end: every family serves batched == sequential, bitwise
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("packed", [False, True])
@pytest.mark.parametrize("family", FAMILY_NAMES)
def test_service_batched_equals_sequential_per_family(family, packed):
    rng = np.random.default_rng(17)
    svc = SketchSearchService(m=256, seed=2, family=family,
                              keep_host_oracle=False, packed=packed)
    keys = np.arange(400)
    signal = rng.normal(size=400)
    svc.ingest("a_corr", keys, signal + 0.1 * rng.normal(size=400))
    svc.ingest("b_noise", keys, rng.normal(size=400))
    svc.ingest("c_disjoint", np.arange(9000, 9400), rng.normal(size=400))
    svc.ingest("d_half", np.arange(200, 600), rng.normal(size=400))
    queries = [(keys, signal + 0.05 * rng.normal(size=400))
               for _ in range(5)] + [(np.arange(30), rng.normal(size=30))]
    # micro_batch=4 forces a padded tail batch (6 = 4 + 2 padded to 4)
    batch = svc.search_batch(queries, top_k=3, min_join=10, micro_batch=4)
    seq = [svc.search(k, v, top_k=3, min_join=10) for k, v in queries]
    assert batch == seq          # SearchResult dataclass equality: all stats
    assert svc.describe()["family"] == family
    # the winning table must be found by every family on this easy corpus
    assert batch[0] and batch[0][0].name == "a_corr"