"""Per-architecture smoke tests (reduced configs) + component correctness."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import Model, count_params
from repro.models.attention import chunked_attention
from repro.models.moe import moe_ffn, init_moe


def _batch_for(cfg, B=2, T=16, seed=0):
    k = jax.random.PRNGKey(seed)
    batch = {"tokens": jax.random.randint(k, (B, T), 0, cfg.vocab_size),
             "labels": jax.random.randint(k, (B, T), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        Tt = T - cfg.num_patches
        batch["tokens"] = batch["tokens"][:, :Tt]
        batch["labels"] = batch["labels"][:, :Tt]
        batch["patches"] = jax.random.normal(k, (B, cfg.num_patches, cfg.d_model))
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            k, (B, cfg.encoder_seq, cfg.encoder_d_model))
    return batch


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_arch_smoke_forward_and_train_step(arch):
    """One forward + one SGD step on the reduced config: shapes + no NaNs."""
    cfg = configs.reduced(arch)
    model = Model(cfg)
    params, specs = model.init(jax.random.PRNGKey(0))
    # specs mirror params structure
    assert set(specs.keys()) == set(params.keys())
    batch = _batch_for(cfg)

    @jax.jit
    def step(p, b):
        def loss_fn(p):
            l, parts = model.loss(p, b)
            return l
        loss, grads = jax.value_and_grad(loss_fn)(p)
        new_p = jax.tree.map(lambda w, g: w - 1e-2 * g.astype(w.dtype), p, grads)
        return loss, new_p

    loss0, params1 = step(params, batch)
    loss1, _ = step(params1, batch)
    assert np.isfinite(float(loss0)) and np.isfinite(float(loss1))
    assert float(loss1) < float(loss0) + 0.5  # not diverging after one step


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_arch_smoke_decode(arch):
    cfg = configs.reduced(arch)
    model = Model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    B = 2
    state, _ = model.init_decode_state(B, 32)
    step = jax.jit(lambda p, t, s: model.decode_step(p, t, s))
    tok = jnp.zeros((B, 1), jnp.int32)
    for _ in range(3):
        logits, state = step(params, tok, state)
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    assert int(state["pos"]) == 3


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "mixtral-8x22b",
                                  "rwkv6-1.6b", "jamba-1.5-large-398b",
                                  "gemma-7b", "whisper-base"])
def test_decode_matches_parallel_forward(arch):
    """Incremental decode == parallel forward (KV cache / state correctness)."""
    cfg = dataclasses.replace(configs.reduced(arch), capacity_factor=8.0)
    model = Model(cfg)
    params, _ = model.init(jax.random.PRNGKey(1))
    B, T = 1, 12
    batch = _batch_for(cfg, B=B, T=T, seed=2)
    toks = batch["tokens"]
    logits_par, _ = jax.jit(lambda p, b: model.forward(p, b))(params, batch)
    state, _ = model.init_decode_state(B, 32)
    if cfg.family == "encdec":
        import repro.models.attention as attn_mod
        enc = model._encode(params, batch["frames"], None)
        cks, cvs = [], []
        for l in range(cfg.num_layers):
            layer = jax.tree.map(lambda x: x[l], params["layers"])
            ck, cv = attn_mod.encode_kv(layer["cross"], enc, cfg)
            cks.append(ck), cvs.append(cv)
        state["cross_k"], state["cross_v"] = jnp.stack(cks), jnp.stack(cvs)
    step = jax.jit(lambda p, t, s: model.decode_step(p, t, s))
    outs = []
    for t in range(toks.shape[1]):
        lg, state = step(params, toks[:, t:t + 1], state)
        outs.append(lg[:, 0])
    logits_inc = jnp.stack(outs, axis=1)
    pa = np.asarray(logits_par, np.float32)
    pi = np.asarray(logits_inc, np.float32)
    rel = np.abs(pa - pi).max() / (np.abs(pa).max() + 1e-9)
    assert rel < 0.06, (arch, rel)


def test_sliding_window_cache_wraps():
    """Windowed decode >window steps: circular cache stays consistent."""
    cfg = configs.reduced("mixtral-8x22b")      # sliding_window=16
    model = Model(cfg)
    params, _ = model.init(jax.random.PRNGKey(3))
    B = 1
    state, _ = model.init_decode_state(B, 64)   # layout: windowed, size 16
    assert state["kv"]["k"].shape[2] == cfg.sliding_window
    step = jax.jit(lambda p, t, s: model.decode_step(p, t, s))
    tok = jnp.ones((B, 1), jnp.int32)
    for _ in range(24):                          # > window
        logits, state = step(params, tok, state)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    assert int(state["pos"]) == 24


# ---------------------------------------------------------------------------
# component: chunked flash attention vs naive oracle
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("causal,window,qc,kc", [
    (True, 0, 8, 8), (True, 0, 16, 4), (False, 0, 8, 16), (True, 12, 8, 8),
])
def test_chunked_attention_matches_naive(causal, window, qc, kc):
    rng = np.random.default_rng(0)
    B, T, H, K, D = 2, 32, 4, 2, 8
    q = jnp.asarray(rng.normal(size=(B, T, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, T, K, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, T, K, D)), jnp.float32)
    out = chunked_attention(q, k, v, causal=causal, window=window,
                            q_chunk=qc, k_chunk=kc)
    # naive
    G = H // K
    qr = q.reshape(B, T, K, G, D)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qr, k) / np.sqrt(D)
    qi = np.arange(T)[:, None]
    si = np.arange(T)[None, :]
    mask = np.ones((T, T), bool)
    if causal:
        mask &= si <= qi
    if window:
        mask &= si > qi - window
    s = jnp.where(jnp.asarray(mask)[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    ref = jnp.einsum("bkgqs,bskd->bqkgd", p, v).reshape(B, T, H, D)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# component: MoE dispatch correctness vs dense per-token computation
# ---------------------------------------------------------------------------
def test_moe_matches_dense_computation_when_capacity_ample():
    cfg = dataclasses.replace(configs.reduced("qwen3-moe-30b-a3b"),
                              capacity_factor=16.0)
    params, _ = init_moe(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(1)
    N, d = 24, cfg.d_model
    x = jnp.asarray(rng.normal(size=(N, d)), jnp.float32)
    y, aux = moe_ffn(params, x, cfg)

    # dense oracle: every token through its top-k experts, weighted
    logits = x @ params["w_router"]
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, cfg.num_experts_per_tok)
    top_p = top_p / top_p.sum(-1, keepdims=True)
    y_ref = np.zeros((N, d), np.float32)
    for i in range(N):
        for j in range(cfg.num_experts_per_tok):
            e = int(top_e[i, j])
            h = np.asarray(x[i] @ params["w_gate"][e])
            u = np.asarray(x[i] @ params["w_up"][e])
            act = h / (1 + np.exp(-h)) * u
            y_ref[i] += float(top_p[i, j]) * (act @ np.asarray(params["w_down"][e]))
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=2e-2, atol=2e-2)
    assert float(aux) > 0


def test_moe_capacity_drops_tokens():
    cfg = dataclasses.replace(configs.reduced("qwen3-moe-30b-a3b"),
                              capacity_factor=0.25)
    params, _ = init_moe(jax.random.PRNGKey(0), cfg)
    x = jnp.ones((64, cfg.d_model), jnp.float32)  # identical tokens: 1 expert hot
    y, _ = moe_ffn(params, x, cfg)
    # capacity caps the hot expert: later tokens must be dropped (zero output)
    norms = np.linalg.norm(np.asarray(y), axis=1)
    assert (norms < 1e-6).sum() > 0


def test_param_counts_match_analytic():
    """Analytic counting (roofline input) == actual initialized param count."""
    for arch in configs.ARCHS:
        cfg = configs.reduced(arch)
        model = Model(cfg)
        params, _ = model.init(jax.random.PRNGKey(0))
        actual = sum(x.size for x in jax.tree.leaves(params))
        analytic = count_params(cfg)
        assert abs(actual - analytic) / actual < 0.02, (arch, actual, analytic)
