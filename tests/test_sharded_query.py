"""Mesh-sharded corpus-query execution == single-device path, bitwise.

The acceptance invariant for the sharded serving path: with the corpus rows
split over a 2+ device ``data`` mesh axis (forced host devices via
``XLA_FLAGS=--xla_force_host_platform_device_count``), the per-shard
estimate launches + per-shard-top-k-and-merge ranking return results
bitwise identical to the single-device launch -- estimates, top-k scores
AND indices (tie order included), and end-to-end SearchResults.

Runs in a subprocess because the forced device count must be set before
jax initializes.
"""
import os
import subprocess
import sys
import textwrap

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    import sys; sys.path.insert(0, "src")
    import numpy as np, jax, jax.numpy as jnp
    from repro.data import DatasetSearchIndex, SketchCorpus
    from repro.data.synthetic import sparse_pair
    from repro.kernels import ops
    from repro.launch.mesh import make_corpus_mesh
    from repro.serve import SketchSearchService

    mesh = make_corpus_mesh()
    assert mesh.shape["data"] == 2, mesh

    rng = np.random.default_rng(3)

    # -- sharded_top_k == lax.top_k on tie-heavy scores (values AND indices)
    for n, k in ((11, 6), (8, 3), (5, 5)):
        score = jnp.asarray(
            rng.integers(-1, 3, size=(4, n)).astype(np.float32))
        v0, i0 = jax.lax.top_k(score, k)
        v1, i1 = ops.sharded_top_k(score, k, mesh=mesh, axis="data")
        assert np.array_equal(np.asarray(v0), np.asarray(v1)), (n, k)
        assert np.array_equal(np.asarray(i0), np.asarray(i1)), (n, k)

    # -- raw sharded wrapper with corpus rows NOT divisible by the axis:
    #    the wrapper's own inert-row padding path (sharded stores keep
    #    capacity divisible, so only raw buffers exercise it)
    fpb = jnp.asarray(rng.integers(0, 30, size=(1, 5, 64)).astype(np.int32))
    vb = jnp.asarray(rng.normal(size=(1, 5, 64)).astype(np.float32))
    nb = jnp.asarray(np.ones((1, 5), np.float32))
    fq2 = jnp.asarray(rng.integers(0, 30, size=(2, 64)).astype(np.int32))
    vq2 = jnp.asarray(rng.normal(size=(2, 64)).astype(np.float32))
    nq2 = jnp.ones((2,), jnp.float32)
    u = np.asarray(ops.icws_estimate_many_stacked(fq2, vq2, nq2,
                                                  fpb, vb, nb))
    s = np.asarray(ops.icws_estimate_many_sharded(
        fq2, vq2, nq2, fpb, vb, nb, mesh=mesh, axis="data"))
    assert np.array_equal(u, s)

    # -- SketchCorpus many-vs-many: sharded == unsharded, bitwise
    #    (5 tables: corpus rows NOT divisible by the 2-way axis)
    vecs = [sparse_pair(rng, n=400, nnz=80, overlap=0.3)[0] for _ in range(5)]
    queries = [sparse_pair(rng, n=400, nnz=80, overlap=0.3)[0]
               for _ in range(3)]
    plain = SketchCorpus(m=128, seed=2)
    shard = SketchCorpus(m=128, seed=2, mesh=mesh)
    for c in (plain, shard):
        c.add_batch(vecs)
    e0 = np.asarray(plain.estimate_vecs(queries))
    e1 = np.asarray(shard.estimate_vecs(queries))
    assert e0.shape == (3, 5)
    assert np.array_equal(e0, e1)
    # the sharded store's buffers are ALLOCATED across the mesh (corpus
    # memory spreads over devices; queries never redistribute the corpus)
    fpb, _, _, _ = shard._store.buffers()
    assert len(fpb.sharding.device_set) == 2, fpb.sharding
    assert shard._store.capacity % 2 == 0

    # -- end-to-end index: rankings and every statistic identical,
    #    sequential query and query_batch
    keys = np.arange(500)
    signal = rng.normal(size=500)
    tables = [("corr", keys, signal + 0.2 * rng.normal(size=500)),
              ("noise", keys, rng.normal(size=500)),
              ("disjoint", np.arange(9000, 9500), rng.normal(size=500)),
              ("half", np.arange(250, 750), rng.normal(size=500)),
              ("extra", keys, rng.normal(size=500))]
    qs = [(keys, signal),
          (np.arange(100, 600), rng.normal(size=500)),
          (np.arange(40), rng.normal(size=40))]

    def build(mesh=None):
        idx = DatasetSearchIndex(m=256, seed=1, mesh=mesh,
                                 keep_host_oracle=False)
        for nm, k, v in tables:
            idx.add_table(nm, k, v)
        return idx

    a, b = build(), build(mesh)
    assert a._corpus_axis is None and b._corpus_axis == "data"
    for k_, v_ in qs:
        ra = a.query(k_, v_, top_k=4, min_join=20)
        rb = b.query(k_, v_, top_k=4, min_join=20)
        assert ra == rb and ra, (ra, rb)       # dataclass ==: all stats
    assert (a.query_batch(qs, top_k=4, min_join=20)
            == b.query_batch(qs, top_k=4, min_join=20))

    # -- service front-end accepts the mesh and agrees with single-device
    svc = SketchSearchService(m=256, seed=1, keep_host_oracle=False,
                              mesh=mesh)
    for nm, k, v in tables:
        svc.ingest(nm, k, v)
    assert svc.search_batch(qs, top_k=4, min_join=20, micro_batch=2) == \\
        a.query_batch(qs, top_k=4, min_join=20)
    d = svc.describe()
    assert d["corpus_rows"] == 5.0 and d["corpus_capacity"] >= 5.0

    # -- every serving family: sharded == single-device, bitwise, and
    #    every sharded store buffer (fp/val/norm rows, dense tables, or
    #    sample key/value/tau rows) spreads over the mesh.  Iterates
    #    FAMILY_NAMES so a new family lands in this sweep automatically
    #    (the FC003 rule of repro.analysis checks exactly that).
    from repro.data.families import FAMILY_NAMES
    for fam in FAMILY_NAMES:
        def buildf(m=None):
            idx = DatasetSearchIndex(m=128, seed=1, mesh=m,
                                     keep_host_oracle=False, family=fam)
            for nm, k, v in tables:
                idx.add_table(nm, k, v)
            return idx
        fa, fb = buildf(), buildf(mesh)
        assert (fa.query_batch(qs, top_k=4, min_join=20)
                == fb.query_batch(qs, top_k=4, min_join=20)), fam
        for tb in fb.store.buffers():
            assert len(tb.sharding.device_set) == 2, (fam, tb.sharding)

    # -- packed stores (bit-packed wire layout, unpack-in-kernel): the
    #    sharded packed launch == single-device packed launch, bitwise,
    #    for every family, and the packed buffers spread over the mesh
    for fam in FAMILY_NAMES:
        def buildp(m=None):
            idx = DatasetSearchIndex(m=128, seed=1, mesh=m,
                                     keep_host_oracle=False, family=fam,
                                     packed=True)
            for nm, k, v in tables:
                idx.add_table(nm, k, v)
            return idx
        pa, pb = buildp(), buildp(mesh)
        assert (pa.query_batch(qs, top_k=4, min_join=20)
                == pb.query_batch(qs, top_k=4, min_join=20)), fam
        for tb in pb.store.buffers():
            assert len(tb.sharding.device_set) == 2, (fam, tb.sharding)
    print("SHARDED_OK")
""")


def test_sharded_query_bitwise_identical_to_single_device():
    out = subprocess.run(
        [sys.executable, "-c", _SCRIPT], capture_output=True, text=True,
        cwd=os.path.dirname(os.path.dirname(__file__)))
    assert "SHARDED_OK" in out.stdout, (out.stdout[-2000:], out.stderr[-4000:])
