"""Substrate tests: pipeline, checkpointing (incl. elastic), fault tolerance,
gradient compression, telemetry, dataset search."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import AsyncCheckpointer, all_steps, latest_step, restore, save
from repro.data import DatasetSearchIndex, TokenPipeline, sparse_pair
from repro.ft import (HeartbeatRegistry, PreemptionHandler, StragglerDetector,
                      elastic_plan, plan_recovery)
from repro.optim.compression import (CompressionConfig, compress,
                                     compression_ratio, decompress)


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------
def test_pipeline_deterministic_and_resumable():
    kw = dict(seed=3, global_batch=8, seq=16, vocab=100)
    p1 = TokenPipeline(**kw)
    b1 = [next(p1) for _ in range(4)]
    p1.close()
    # restart from step 2: identical stream from there
    p2 = TokenPipeline(**kw, start_step=2)
    b2 = [next(p2) for _ in range(2)]
    p2.close()
    assert np.array_equal(b1[2]["tokens"], b2[0]["tokens"])
    assert np.array_equal(b1[3]["labels"], b2[1]["labels"])


def test_pipeline_host_sharding_partitions_batch():
    kw = dict(seed=5, global_batch=8, seq=8, vocab=50, num_hosts=2)
    pa = TokenPipeline(**kw, host_id=0)
    pb = TokenPipeline(**kw, host_id=1)
    a, b = next(pa), next(pb)
    pa.close(), pb.close()
    assert a["tokens"].shape == (4, 8)
    assert not np.array_equal(a["tokens"], b["tokens"])


def test_pipeline_labels_are_shifted_tokens():
    p = TokenPipeline(seed=1, global_batch=2, seq=12, vocab=64)
    b = next(p)
    p.close()
    # labels[t] is the next token of the same stream
    from repro.data.synthetic import token_stream
    raw = token_stream(1, b["step"], 2, 12, 64)
    assert np.array_equal(b["tokens"], raw[:, :-1].astype(np.int32))
    assert np.array_equal(b["labels"], raw[:, 1:].astype(np.int32))


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------
def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"w": jax.random.normal(k, (4, 8)),
            "opt": {"mu": jnp.zeros((4, 8)), "step": jnp.int32(7)}}


def test_checkpoint_roundtrip(tmp_path):
    tree = _tree()
    save(tmp_path, 10, tree, extra={"data_step": 10})
    restored, extra = restore(tmp_path, 10, jax.tree.map(jnp.zeros_like, tree))
    assert extra["data_step"] == 10
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b))


def test_checkpoint_atomicity_ignores_partial(tmp_path):
    tree = _tree()
    save(tmp_path, 1, tree)
    # simulate a crash mid-write of step 2
    (tmp_path / "step_2.tmp").mkdir()
    (tmp_path / "step_2.tmp" / "garbage.npy").write_bytes(b"xx")
    assert latest_step(tmp_path) == 1


def test_checkpoint_gc_and_async(tmp_path):
    ck = AsyncCheckpointer(tmp_path, keep=2)
    tree = _tree()
    for s in (1, 2, 3, 4):
        ck.save(s, tree)
    ck.wait()
    assert all_steps(tmp_path) == [3, 4]


def test_checkpoint_shape_mismatch_raises(tmp_path):
    save(tmp_path, 1, {"w": jnp.zeros((2, 2))})
    with pytest.raises(ValueError):
        restore(tmp_path, 1, {"w": jnp.zeros((3, 3))})


def test_checkpoint_elastic_restore_across_mesh_sizes(tmp_path):
    """Save sharded on an 8-device mesh; restore onto a 4-device mesh."""
    script = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        import sys
        sys.path.insert(0, "src")
        from repro.checkpoint import save, restore
        from repro.compat import make_mesh

        mesh8 = make_mesh((8,), ("data",))
        x = jnp.arange(64, dtype=jnp.float32).reshape(8, 8)
        xs = jax.device_put(x, NamedSharding(mesh8, P("data", None)))
        save(r"{tmp_path}", 5, {{"x": xs}})

        # restore onto a DIFFERENT mesh (4 devices)
        devs = jax.devices()[:4]
        mesh4 = jax.sharding.Mesh(np.array(devs).reshape(4), ("data",))
        sh4 = NamedSharding(mesh4, P("data", None))
        restored, _ = restore(r"{tmp_path}", 5, {{"x": jnp.zeros((8, 8))}},
                              shardings={{"x": sh4}})
        assert np.array_equal(np.asarray(restored["x"]), np.asarray(x))
        assert len(restored["x"].sharding.device_set) == 4
        print("ELASTIC_OK")
    """)
    out = subprocess.run([sys.executable, "-c", script], capture_output=True,
                         text=True, cwd=os.path.dirname(os.path.dirname(__file__)))
    assert "ELASTIC_OK" in out.stdout, out.stderr[-2000:]


# ---------------------------------------------------------------------------
# fault tolerance
# ---------------------------------------------------------------------------
def test_heartbeats_flag_silent_hosts():
    hb = HeartbeatRegistry(num_hosts=4, timeout=10.0)
    for h in range(3):
        hb.post(h, step=5, now=100.0)
    assert hb.dead_hosts(now=105.0) == {3}
    assert hb.dead_hosts(now=120.0) == {0, 1, 2, 3}
    hb.post(3, step=5, now=121.0)
    assert 3 not in hb.dead_hosts(now=122.0)


def test_straggler_detection_needs_persistence():
    sd = StragglerDetector(num_hosts=4, k_mad=4.0, patience=2)
    for step in range(3):
        for h in range(4):
            sd.record(h, 1.0 + 0.01 * h)
        assert sd.stragglers() == set()
    # host 2 becomes 10x slower for 2 consecutive checks
    for _ in range(2):
        for h in range(4):
            sd.record(h, 10.0 if h == 2 else 1.0)
        s = sd.stragglers()
    assert s == {2}


def test_elastic_plan_and_recovery():
    data, model = elastic_plan(num_hosts=64, devices_per_host=4,
                               dead={1, 2}, model_parallel=16)
    assert model == 16 and data == (62 * 4) // 16
    hb = HeartbeatRegistry(num_hosts=4, timeout=10)
    sd = StragglerDetector(num_hosts=4)
    for h in range(4):
        hb.post(h, 0, now=0.0)
    act = plan_recovery(hb, sd, devices_per_host=4, model_parallel=4, now=5.0)
    assert act.kind == "none"
    for h in range(3):
        hb.post(h, 1, now=45.0)   # host 3 goes silent
    act = plan_recovery(hb, sd, devices_per_host=4, model_parallel=4, now=50.0)
    assert act.kind == "evict_and_rescale"
    assert act.dead_hosts == {3}
    assert act.new_mesh == (3, 4)


def test_preemption_handler_flag():
    ph = PreemptionHandler()
    assert not ph.should_save()
    ph.trigger_for_test()
    assert ph.should_save()


# ---------------------------------------------------------------------------
# gradient compression (CountSketch + error feedback)
# ---------------------------------------------------------------------------
def test_compression_unbiased_and_ratio():
    cfg = CompressionConfig(width=512, reps=5, seed=1)
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(size=4096), jnp.float32)
    tab = compress(g, cfg)
    dec = decompress(tab, 4096, cfg)
    err = np.linalg.norm(np.asarray(dec) - np.asarray(g)) / np.linalg.norm(np.asarray(g))
    assert err < 1.5  # heavy compression: noisy but bounded
    assert compression_ratio(4096, cfg) == pytest.approx(4096 / (512 * 5))


def test_compress_matches_countsketch_u32_oracle():
    """Compressed gradients share the u32 contract with served CountSketch
    corpora: compress() (both paths) equals the core.linear.CountSketchU32
    host oracle's table of the same dense vector, so a gradient table can
    be estimated against a CS corpus row directly."""
    from repro.core.linear import CountSketchU32
    rng = np.random.default_rng(7)
    g = rng.normal(size=600).astype(np.float32)
    oracle = CountSketchU32(width=64, seed=11).sketch_dense(
        g.astype(np.float64))
    for use_kernel in (False, True):
        cfg = CompressionConfig(width=64, reps=5, seed=11,
                                use_kernel=use_kernel)
        tab = np.asarray(compress(jnp.asarray(g), cfg), np.float64)
        np.testing.assert_allclose(tab, oracle.table, rtol=1e-5, atol=1e-5)
        # decode agrees between the two paths as well
        d0 = decompress(jnp.asarray(tab, jnp.float32), 600, cfg)
        d1 = decompress(jnp.asarray(tab, jnp.float32), 600,
                        CompressionConfig(width=64, reps=5, seed=11,
                                          use_kernel=not use_kernel))
        np.testing.assert_array_equal(np.asarray(d0), np.asarray(d1))


def test_error_feedback_converges_on_quadratic_sparse():
    """EF-compressed SGD reaches the optimum of a quadratic with a heavy-
    tailed sparse target (the regime sketch compression targets)."""
    from repro.optim.compression import compressed_update
    cfg = CompressionConfig(width=256, reps=5, seed=2)
    rng = np.random.default_rng(1)
    n = 4096
    t0 = np.zeros(n)
    nz = rng.choice(n, 128, replace=False)
    t0[nz] = rng.standard_t(2, size=128) * 3
    target = jnp.asarray(t0, jnp.float32)
    x = jnp.zeros(n)
    residual = jnp.zeros(n)
    for _ in range(120):
        delta, residual = compressed_update(x - target, residual, None, cfg,
                                            lr=0.3)
        x = x - delta
    final = float(jnp.linalg.norm(x - target) / jnp.linalg.norm(target))
    assert final < 1e-3, final


def test_error_feedback_converges_on_quadratic_dense():
    """Top-k fallback with exact values: even a dense Gaussian target (no
    heavy hitters -- the sketch's worst case) converges, just more slowly."""
    from repro.optim.compression import compressed_update
    cfg = CompressionConfig(width=256, reps=5, seed=3)
    rng = np.random.default_rng(2)
    n = 2048
    target = jnp.asarray(rng.normal(size=n), jnp.float32)
    x = jnp.zeros(n)
    residual = jnp.zeros(n)
    for _ in range(400):
        delta, residual = compressed_update(x - target, residual, None, cfg,
                                            lr=0.3)
        x = x - delta
    final = float(jnp.linalg.norm(x - target) / jnp.linalg.norm(target))
    assert final < 0.05, final


def test_naive_ef_with_estimated_values_documented_divergence():
    """Regression guard for the failure mode we fixed: subtracting noisy
    *estimated* values (instead of sketch-identified exact values) injects
    noise-floor energy and does NOT converge.  If this starts passing, the
    docstring rationale in compression.py is stale."""
    from repro.optim.compression import compress as C, ef_decode
    cfg = CompressionConfig(width=256, reps=5, seed=2)
    rng = np.random.default_rng(1)
    n = 4096
    t0 = np.zeros(n)
    t0[rng.choice(n, 128, replace=False)] = rng.standard_t(2, size=128) * 3
    target = jnp.asarray(t0, jnp.float32)
    x = jnp.zeros(n)
    residual = jnp.zeros(n)
    for _ in range(200):
        p = residual + 0.3 * (x - target)
        approx = ef_decode(C(p, cfg), n, cfg, norm_bound=jnp.linalg.norm(p))
        residual = p - approx
        x = x - approx
    final = float(jnp.linalg.norm(x - target) / jnp.linalg.norm(target))
    assert final > 0.05  # stalls or diverges; never reaches the optimum


def test_compressed_psum_in_shard_map():
    """Sketch-space pmean across 4 devices == mean gradient (approximately),
    and exact for the sketch tables (linearity)."""
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import sys; sys.path.insert(0, "src")
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.compat import make_mesh, shard_map
        from repro.optim.compression import CompressionConfig, compressed_update, compress

        cfg = CompressionConfig(width=256, reps=5, seed=3)
        mesh = make_mesh((4,), ("data",))
        rng = np.random.default_rng(0)
        # heavy-tailed shared signal + per-replica noise
        base = np.zeros(2048)
        base[rng.choice(2048, 64, replace=False)] = rng.standard_t(2, 64) * 5
        grads = jnp.asarray(base[None] + 0.05 * rng.normal(size=(4, 2048)),
                            jnp.float32)

        def worker(g, r):
            delta, new_r = compressed_update(g[0], r[0], "data", cfg, lr=1.0)
            return delta[None], new_r[None]

        f = shard_map(worker, mesh=mesh,
                      in_specs=(P("data", None), P("data", None)),
                      out_specs=(P("data", None), P("data", None)),
                      check=False)
        delta, res = f(grads, jnp.zeros_like(grads))
        delta = np.asarray(delta)
        # every replica got the SAME update
        assert np.allclose(delta[0], delta[1], atol=1e-5)
        true_mean = np.asarray(grads).mean(0)
        # extracted coordinates carry the exact mean values
        nzmask = delta[0] != 0
        assert nzmask.sum() > 32
        assert np.allclose(delta[0][nzmask], true_mean[nzmask], atol=1e-5)
        # linearity: psum of tables == table of summed gradients
        t_sum = sum(np.asarray(compress(grads[i], cfg)) for i in range(4))
        t_of_sum = np.asarray(compress(grads.sum(0), cfg))
        assert np.allclose(t_sum, t_of_sum, atol=1e-3)
        print("PSUM_OK")
    """)
    out = subprocess.run([sys.executable, "-c", script], capture_output=True,
                         text=True, cwd=os.path.dirname(os.path.dirname(__file__)))
    assert "PSUM_OK" in out.stdout, out.stderr[-2000:]


def test_gradient_telemetry_pairwise_similarity():
    """Sketch-estimated pairwise gradient cosines track the true cosines."""
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import sys; sys.path.insert(0, "src")
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.compat import make_mesh, shard_map
        from repro.train.telemetry import TelemetryConfig, gradient_agreement

        cfg = TelemetryConfig(m=512, seed=5)
        mesh = make_mesh((4,), ("data",))
        rng = np.random.default_rng(2)
        base = rng.normal(size=2048)
        grads = np.stack([base + 0.3 * rng.normal(size=2048) for _ in range(3)]
                         + [rng.normal(size=2048)])      # replica 3 diverges
        grads = jnp.asarray(grads, jnp.float32)

        def worker(g):
            return gradient_agreement(g[0], "data", cfg)[None]

        f = shard_map(worker, mesh=mesh, in_specs=(P("data", None),),
                      out_specs=P("data", None, None), check=False)
        sim = np.asarray(f(grads))[0]
        true = np.corrcoef(np.asarray(grads))
        # healthy replicas: high estimated cosine; diverged: low
        healthy = [sim[i, j] for i in range(3) for j in range(3) if i != j]
        bad = [sim[i, 3] for i in range(3)]
        assert min(healthy) > max(bad) + 0.2, (healthy, bad)
        print("TELEM_OK")
    """)
    out = subprocess.run([sys.executable, "-c", script], capture_output=True,
                         text=True, cwd=os.path.dirname(os.path.dirname(__file__)))
    assert "TELEM_OK" in out.stdout, out.stderr[-2000:]


# ---------------------------------------------------------------------------
# dataset search (the paper's Section 1.3 end to end)
# ---------------------------------------------------------------------------
def test_dataset_search_finds_correlated_joinable_table():
    rng = np.random.default_rng(7)
    idx = DatasetSearchIndex(m=512, seed=1)
    # query: dates 0..999, ridership values
    q_keys = np.arange(1000)
    signal = rng.normal(size=1000)
    q_vals = signal + 0.1 * rng.normal(size=1000)

    # corpus: correlated table (same keys), uncorrelated table (same keys),
    # disjoint-keys table
    idx.add_table("weather_correlated", q_keys, signal + 0.1 * rng.normal(size=1000))
    idx.add_table("noise_uncorrelated", q_keys, rng.normal(size=1000))
    idx.add_table("disjoint_keys", np.arange(5000, 6000), rng.normal(size=1000))

    res = idx.query(q_keys, q_vals, top_k=3, min_join=50)
    names = [r.name for r in res]
    assert "disjoint_keys" not in names          # join size ~0 filtered out
    assert names[0] == "weather_correlated"      # ranked by |corr|
    top = res[0]
    assert top.corr > 0.5
    assert abs(top.join_size - 1000) / 1000 < 0.35   # join size estimate


def test_dataset_search_join_stats_accuracy():
    rng = np.random.default_rng(8)
    idx = DatasetSearchIndex(m=1024, seed=2)
    keys_b = np.arange(500, 1500)
    vals_b = rng.uniform(1, 2, size=1000)
    idx.add_table("b", keys_b, vals_b)
    q_keys = np.arange(1000)       # overlap = keys 500..999 (500 keys)
    q_vals = rng.uniform(1, 2, size=1000)
    res = idx.query(q_keys, q_vals, min_join=10)[0]
    assert abs(res.join_size - 500) / 500 < 0.4
    true_sum = vals_b[:500].sum()  # sum of b's values over the join
    assert abs(res.sum_b - true_sum) / true_sum < 0.4


def test_dataset_search_storage_accounting():
    idx = DatasetSearchIndex(m=64, seed=0)
    idx.add_table("t", np.arange(10), np.ones(10))
    assert idx.storage_doubles() == 3 * (1.5 * 64 + 1)
