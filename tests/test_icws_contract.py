"""Host-vs-kernel ICWS contract: one RNG, interoperable fingerprints.

The host sketcher (:class:`repro.core.ICWS`) and the Pallas kernel
(:mod:`repro.kernels.icws_sketch`) must draw the same variates and emit the
same fingerprints, or mixed (host-sketched vs device-sketched) corpora
silently estimate zero.  These tests pin:

  * the numpy u32 RNG twins against the jnp originals, bit for bit;
  * host ``ICWS.sketch`` against the device kernel on the same vectors
    (fingerprints agree except where libm/XLA transcendentals differ in the
    last ulp AND that flips a floor/argmin -- bounded well under 1%);
  * the estimator on mixed host/device sketch pairs against the pure host
    estimate, within f32 tolerance.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ICWS, SparseVec
from repro.core import u32
from repro.core.icws import _stack
from repro.kernels import common as kcommon
from repro.kernels import ops


# ---------------------------------------------------------------------------
# numpy twins of the in-kernel u32 RNG: bit-exact
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("seed,stream", [(0, 1), (7, 5), (12345, 9),
                                         (2**31 - 1, 2)])
def test_u32_twins_bit_exact(seed, stream):
    rng = np.random.default_rng(seed + stream)
    keys = rng.integers(0, 2**32, size=257, dtype=np.uint64).astype(np.uint32)
    t = np.arange(64, dtype=np.int64)

    salt_np = u32.salt_for(seed, stream, t)
    salt_j = np.asarray(kcommon.salt_for(seed, stream, jnp.asarray(t)))
    assert np.array_equal(salt_np, salt_j.astype(np.uint32))

    h_np = u32.hash_u32(keys[None, :], salt_np[:, None])
    h_j = np.asarray(kcommon.hash_u32(jnp.asarray(keys)[None, :],
                                      jnp.asarray(salt_np)[:, None]))
    assert np.array_equal(h_np, h_j.astype(np.uint32))

    u_np = u32.uniform01(keys[None, :], salt_np[:, None])
    u_j = np.asarray(kcommon.uniform01(jnp.asarray(keys)[None, :],
                                       jnp.asarray(salt_np)[:, None]))
    assert np.array_equal(u_np, u_j)
    assert u_np.dtype == np.float32
    assert (u_np > 0).all() and (u_np < 1).all()

    m_np = u32.mix32(keys)
    m_j = np.asarray(kcommon.mix32(jnp.asarray(keys)))
    assert np.array_equal(m_np, m_j.astype(np.uint32))


# ---------------------------------------------------------------------------
# host sketch vs device kernel on identical vectors
# ---------------------------------------------------------------------------
def _host_and_device_sketch(rng, n, density, m, seed):
    x = rng.normal(size=n) * (rng.random(n) < density)
    if not x.any():
        x[0] = 1.0
    v = SparseVec.from_dense(x)
    host = ICWS(m=m, seed=seed).sketch(v)

    z32 = (v.values / v.norm()).astype(np.float32)
    w = jnp.asarray((z32 * z32)[None, :])
    keys = jnp.asarray(v.indices.astype(np.int32)[None, :])
    vals = jnp.asarray(z32[None, :])
    fp, val, _, _ = ops.icws_sketch(w, keys, vals, m=m, seed=seed)
    return v, host, (np.asarray(fp)[0], np.asarray(val)[0], v.norm())


@pytest.mark.parametrize("n,density,m,seed", [(64, 1.0, 128, 0),
                                              (300, 0.5, 256, 7),
                                              (1000, 0.2, 512, 3),
                                              (50, 0.9, 64, 11)])
def test_host_device_fingerprints_compatible(n, density, m, seed):
    rng = np.random.default_rng(n + m + seed)
    _, host, (fp_dev, val_dev, _) = _host_and_device_sketch(
        rng, n, density, m, seed)
    agree = np.mean(host.fingerprints == fp_dev)
    assert agree > 0.99, f"fingerprint agreement {agree:.4f}"
    # values at agreeing samples match to f32 rounding
    same = host.fingerprints == fp_dev
    np.testing.assert_allclose(host.values[same], val_dev[same],
                               rtol=1e-5, atol=1e-6)
    assert host.fingerprints.dtype == np.int32
    assert (host.fingerprints >= -1).all()          # 31-bit fp or empty


class ICWSSketchLike:
    """Adapter: raw device arrays quacking like an ICWSSketch for stacking."""

    def __init__(self, fp, val, norm):
        self.fingerprints = np.asarray(fp)
        self.values = np.asarray(val, np.float64)
        self.norm = float(norm)


@pytest.mark.parametrize("seed", [0, 5])
def test_mixed_host_device_estimate_matches_host(seed):
    """icws_estimate on (host-sketched A, device-sketched B) pairs must agree
    with the all-host estimator: one sketch per path, same contract."""
    rng = np.random.default_rng(40 + seed)
    n, m = 400, 1024
    pairs = []
    for _ in range(3):
        _, host_a, _ = _host_and_device_sketch(rng, n, 0.5, m, seed)
        _, host_b, dev_b = _host_and_device_sketch(rng, n, 0.5, m, seed)
        pairs.append((host_a, dev_b, host_b))

    icws = ICWS(m=m, seed=seed)
    A = _stack([p[0] for p in pairs])
    B_host = _stack([p[2] for p in pairs])
    host_host = icws.estimate_batch(A, B_host)

    # mixed: host-sketched A vs device-sketched B via the host estimator
    B_dev = _stack([ICWSSketchLike(*p[1]) for p in pairs])
    mixed = icws.estimate_batch(A, B_dev)
    scale = np.maximum(np.abs(host_host), 1.0)
    np.testing.assert_allclose(mixed / scale, host_host / scale, atol=0.05)

    # and via the device estimator kernel on the same mixed arrays
    dev = np.asarray(ops.icws_estimate(
        jnp.asarray(A.fingerprints, jnp.int32),
        jnp.asarray(A.values, jnp.float32),
        jnp.asarray(A.norm, jnp.float32),
        jnp.asarray(B_dev.fingerprints, jnp.int32),
        jnp.asarray(B_dev.values, jnp.float32),
        jnp.asarray(B_dev.norm, jnp.float32)))
    np.testing.assert_allclose(dev / scale, mixed / scale, atol=1e-4)


def test_host_empty_sketch_matches_kernel_sentinels():
    icws = ICWS(m=32, seed=0)
    s = icws.sketch(SparseVec.from_dense(np.zeros(8)))
    assert (s.fingerprints == -1).all()
    assert s.fingerprints.dtype == np.int32
    assert (s.values == 0).all() and s.norm == 0.0
    fp, val, _, _ = ops.icws_sketch(jnp.zeros((1, 128)),
                                    jnp.zeros((1, 128), jnp.int32),
                                    jnp.zeros((1, 128)), m=32, seed=0)
    assert (np.asarray(fp)[0] == s.fingerprints).all()
