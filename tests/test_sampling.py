"""Sampling-sketch subsystem: TS/PS oracles, key-match kernel, serving.

Covers the ISSUE-5 acceptance properties head-on: (a) device kernel vs jnp
ref vs host-oracle parity (fixed-shape smokes fast, hypothesis sweeps
``slow``); (b) unbiasedness of the inverse-inclusion-probability estimator
over seeds on sparse vectors; (c) the fixed-slot layout contract of
``pad_sample_batch`` (pad sentinels, tau semantics, truncation fallback);
(d) family plumbing particulars not already covered by the FAMILY_NAMES-
parameterized suites in ``test_families.py`` (which give ts/ps the
inert-spare-row bitwise test at several fill fractions and the batched ==
sequential service identity for free) and ``test_sharded_query.py``
(sharded == single-device rankings).
"""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import SparseVec, inner_fast
from repro.core.sampling import (PrioritySamplingU32, SampleSketch,
                                 ThresholdSamplingU32, priority_sample,
                                 sample_probs, threshold_sample, ts_target)
from repro.data import make_family, pad_sample_batch, wmh_storage
from repro.data.synthetic import sparse_pair
from repro.kernels import ref
from repro.kernels.sample_estimate import (SAMPLE_CORPUS_PAD_KEY,
                                           SAMPLE_QUERY_PAD_KEY,
                                           sample_estimate_fields_pallas,
                                           sample_inclusion_probs)


def _random_sample_rows(rng, F, B, m, key_pool: int, pad_key: int):
    """Synthetic padded sample rows: random live prefixes of keys drawn
    from a small pool (so cross-row matches actually happen), random
    values, random positive taus."""
    keys = np.full((F, B, m), pad_key, np.int32)
    vals = np.zeros((F, B, m), np.float32)
    taus = np.zeros((F, B), np.float32)
    for f in range(F):
        for b in range(B):
            live = int(rng.integers(0, min(m, key_pool) + 1))
            k = rng.choice(key_pool, size=live, replace=False)
            keys[f, b, :live] = np.sort(k)
            vals[f, b, :live] = rng.normal(size=live)
            taus[f, b] = rng.uniform(0.1, 5.0) if live else 0.0
    return jnp.asarray(keys), jnp.asarray(vals), jnp.asarray(taus)


# ---------------------------------------------------------------------------
# (a) kernel vs jnp ref vs host oracle
# ---------------------------------------------------------------------------
def test_sample_kernel_matches_ref_smoke():
    rng = np.random.default_rng(0)
    kq, vq, tq = _random_sample_rows(rng, 3, 5, 90, 64, SAMPLE_QUERY_PAD_KEY)
    kc, vc, tc = _random_sample_rows(rng, 3, 9, 90, 64, SAMPLE_CORPUS_PAD_KEY)
    aq, ac = sample_inclusion_probs(vq, tq), sample_inclusion_probs(vc, tc)
    qmap, cmap = (0, 1, 0, 2, 0, 1), (0, 0, 1, 0, 2, 1)
    ek = sample_estimate_fields_pallas(kq, vq, aq, kc, vc, ac,
                                       qmap=qmap, cmap=cmap, interpret=True)
    er = np.asarray(ref.sample_estimate_fields_ref(kq, vq, aq, kc, vc, ac,
                                                   qmap=qmap, cmap=cmap))
    assert ek.shape == (6, 5, 9)
    scale = max(1.0, float(np.max(np.abs(er))))
    np.testing.assert_allclose(np.asarray(ek), er, rtol=1e-4,
                               atol=1e-4 * scale)


@pytest.mark.slow
@settings(max_examples=8, deadline=None)
@given(data=st.data())
def test_sample_kernel_matches_ref(data):
    seed = data.draw(st.integers(0, 2 ** 31 - 1))
    rng = np.random.default_rng(seed)
    F = data.draw(st.integers(1, 3))
    C = data.draw(st.integers(1, 3))
    G = data.draw(st.integers(1, 7))
    qmap = tuple(data.draw(st.integers(0, F - 1)) for _ in range(G))
    cmap = tuple(data.draw(st.integers(0, C - 1)) for _ in range(G))
    Q, P = data.draw(st.integers(1, 10)), data.draw(st.integers(1, 14))
    m = data.draw(st.integers(1, 150))
    pool = data.draw(st.integers(max(1, m), 4 * m))
    kq, vq, tq = _random_sample_rows(rng, F, Q, m, pool,
                                     SAMPLE_QUERY_PAD_KEY)
    kc, vc, tc = _random_sample_rows(rng, C, P, m, pool,
                                     SAMPLE_CORPUS_PAD_KEY)
    aq, ac = sample_inclusion_probs(vq, tq), sample_inclusion_probs(vc, tc)
    ek = sample_estimate_fields_pallas(kq, vq, aq, kc, vc, ac,
                                       qmap=qmap, cmap=cmap, interpret=True)
    er = np.asarray(ref.sample_estimate_fields_ref(kq, vq, aq, kc, vc, ac,
                                                   qmap=qmap, cmap=cmap))
    assert ek.shape == (G, Q, P)
    scale = max(1.0, float(np.max(np.abs(er))))
    np.testing.assert_allclose(np.asarray(ek), er, rtol=1e-4,
                               atol=1e-4 * scale)


@pytest.mark.parametrize("name", ["ts", "ps"])
def test_sample_device_estimates_match_host_oracle(name):
    """Device key-match estimates over pad_sample_batch rows == core.sampling
    host-oracle estimates to 1e-5 relative, with sketches built by the same
    selection code but estimated independently (host f64 intersect1d vs
    device f32 Pallas contraction)."""
    fam = make_family(name, storage=wmh_storage(256), seed=9)
    oracle = fam.host_oracle()
    rng = np.random.default_rng(11)
    corpus = [sparse_pair(rng, n=2000, nnz=300, overlap=0.2)[0]
              for _ in range(7)]
    queries = [sparse_pair(rng, n=2000, nnz=300, overlap=0.2)[0]
               for _ in range(4)]
    dev = np.asarray(fam.estimate_fields(
        tuple(c[None] for c in fam.sketch_rows(queries)),
        tuple(c[None] for c in fam.sketch_rows(corpus)),
        qmap=(0,), cmap=(0,))[0], np.float64)               # [Q, P]
    host = np.array([[oracle.estimate(oracle.sketch(q), oracle.sketch(c))
                      for c in corpus] for q in queries])
    scale = float(np.max(np.abs(host)))
    assert scale > 0
    np.testing.assert_allclose(dev, host, rtol=1e-5, atol=1e-5 * scale)


# ---------------------------------------------------------------------------
# (b) unbiasedness of the inverse-probability estimator over seeds
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("cls", [ThresholdSamplingU32, PrioritySamplingU32])
def test_sample_estimator_unbiased_over_seeds(cls):
    """Mean estimate over independent hash seeds concentrates on the true
    inner product (within 4 standard errors) in a regime where sampling is
    real: nnz far above the slot count, so most inclusion probabilities
    are strictly below 1."""
    rng = np.random.default_rng(1)
    a, b = sparse_pair(rng, n=3000, nnz=500, overlap=0.3)
    true = inner_fast(a, b)
    ests = []
    for seed in range(400):
        o = cls(slots=64, seed=seed)
        sa, sb = o.sketch(a), o.sketch(b)
        assert sa.keys.size <= 64 and sb.keys.size <= 64
        ests.append(o.estimate(sa, sb))
    ests = np.array(ests)
    sem = ests.std(ddof=1) / np.sqrt(len(ests))
    assert abs(ests.mean() - true) < 4 * sem, (ests.mean(), true, sem)
    # the regime check: sampling actually happened (non-trivial taus)
    assert sa.tau > 0 and (sample_probs(sa.values, sa.tau, 64) < 1).any()


# ---------------------------------------------------------------------------
# (c) fixed-slot layout contract
# ---------------------------------------------------------------------------
def test_pad_sample_batch_layout():
    rng = np.random.default_rng(5)
    vecs = [sparse_pair(rng, n=1000, nnz=200, overlap=0.1)[0],
            SparseVec.from_pairs(np.arange(10), np.ones(10), 1000),
            SparseVec.from_pairs(np.zeros(0, np.int64), np.zeros(0), 1000)]
    slots = 48
    for method in ("ts", "ps"):
        keys, vals, taus = pad_sample_batch(vecs, slots=slots, method=method,
                                            seed=3)
        assert keys.shape == (3, slots) and vals.shape == (3, slots)
        assert keys.dtype == np.int32 and vals.dtype == np.float32
        assert taus.shape == (3,) and taus.dtype == np.float32
        for b in range(3):
            live = keys[b] != SAMPLE_QUERY_PAD_KEY
            n_live = int(live.sum())
            # live entries form an ascending-key prefix; pads carry value 0
            assert np.all(live[:n_live]) and not np.any(live[n_live:])
            assert np.all(np.diff(keys[b, :n_live]) > 0)
            assert np.all(keys[b, :n_live] >= 0)
            assert np.all(vals[b, n_live:] == 0.0)
        # the 10-nnz vector fits whole; the empty vector is all-pad
        assert (keys[1] != SAMPLE_QUERY_PAD_KEY).sum() == 10
        assert np.all(keys[2] == SAMPLE_QUERY_PAD_KEY) and taus[2] == 0.0
        # ps keeps the whole support => probability-1 sentinel tau
        if method == "ps":
            assert taus[1] == 0.0
    with pytest.raises(ValueError):
        pad_sample_batch(vecs, slots=slots, method="bogus")
    with pytest.raises(ValueError):
        pad_sample_batch(vecs, slots=slots, method="ps", target=10)


def test_threshold_overflow_truncates_to_slots():
    """With the target forced above the slot count, threshold sampling's
    overflow fallback must clamp the sample to the layout size (keeping
    the smallest h/p ranks)."""
    rng = np.random.default_rng(8)
    idx = rng.choice(100_000, size=200, replace=False)
    vals = rng.normal(size=200)
    k, v, tau = threshold_sample(idx, vals, slots=16, seed=0, target=200)
    assert k.size == 16
    assert tau == pytest.approx(float(np.sum(vals * vals)) * 16 / 200)
    # the default target leaves two-sigma slack below the slot count
    assert ts_target(256) == 256 - 32


def test_priority_sample_fixed_size_and_tau():
    rng = np.random.default_rng(9)
    idx = rng.choice(100_000, size=300, replace=False)
    vals = rng.normal(size=300) + 0.1
    k, v, tau = priority_sample(idx, vals, slots=32, seed=4)
    assert k.size == 32 and tau > 0
    # every kept coordinate's conditional inclusion probability is the
    # stored-layout reconstruction, and none exceeds 1
    p = sample_probs(v, tau, 32)
    assert np.all((p > 0) & (p <= 1))
    # whole support fits => everything kept with probability 1
    k2, v2, tau2 = priority_sample(idx[:20], vals[:20], slots=32, seed=4)
    assert k2.size == 20 and tau2 == 0.0
    assert np.all(sample_probs(v2, tau2, 32) == 1.0)


def test_sampling_coordination_and_key_folding():
    """Two sketches built independently agree on sampled keys (the
    coordinated hash) and raw indices fold into the 31-bit key domain --
    the same coordinate never lands under two different keys."""
    o = PrioritySamplingU32(slots=8, seed=5)
    idx = np.array([3, 1 << 40 | 3, 7, 11])   # 1<<40|3 folds onto key 3
    s = o.sketch(SparseVec.from_pairs(idx, np.ones(4), 1 << 50))
    assert s.keys.size == 3                   # folded duplicates aggregated
    assert set(s.keys.tolist()) == {3, 7, 11}
    assert float(s.values[s.keys == 3][0]) == 2.0
    # shared support sampled under the same seed matches key-for-key
    a = SparseVec.from_pairs(np.arange(50), np.ones(50), 1000)
    oa = ThresholdSamplingU32(slots=16, seed=6)
    sa, sb = oa.sketch(a), oa.sketch(a)
    np.testing.assert_array_equal(sa.keys, sb.keys)


def test_sample_sketch_storage_accounting():
    s = SampleSketch(keys=np.arange(3), values=np.ones(3), tau=1.0, slots=64)
    assert s.storage_doubles() == 65.0
    fam = make_family("ts", storage=100, seed=0)
    assert fam.slots == 99 and fam.storage_doubles_per_row() == 100.0
