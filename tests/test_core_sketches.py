"""Unit + property tests for the sketching core (the paper's Algorithms 1-5)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (ICWS, JL, KMV, MERSENNE_P, CountSketch, MinHash,
                        SparseVec, WeightedMinHash, fact1_bound, inner_fast,
                        progression_min, progression_min_bruteforce,
                        round_counts, round_unit, sketch_bruteforce,
                        stack_icws, stack_mh, stack_wmh, theorem2_bound)
from repro.core.hashing import AffineHashFamily, PairHashFamily


# ---------------------------------------------------------------------------
# hashing
# ---------------------------------------------------------------------------
def test_affine_hash_range_and_determinism():
    fam = AffineHashFamily.create(16, seed=3)
    x = np.arange(1000, dtype=np.int64)
    h = fam.hash_ints(x)
    assert h.shape == (16, 1000)
    assert h.min() >= 0 and h.max() < MERSENNE_P
    fam2 = AffineHashFamily.create(16, seed=3)
    assert np.array_equal(h, fam2.hash_ints(x))
    fam3 = AffineHashFamily.create(16, seed=4)
    assert not np.array_equal(h, fam3.hash_ints(x))


def test_pairhash_progression_structure():
    """h(i, j) must equal start(i) + j*b mod p — the structure progmin exploits."""
    fam = PairHashFamily.create(8, seed=11)
    i = 12345
    js = np.arange(50, dtype=np.int64)
    brute = fam.hash_pairs_bruteforce(i, js)
    starts = fam.block_starts(np.array([i]))[:, 0]
    expect = (starts[:, None] + fam.b[:, None] * js[None, :]) % MERSENNE_P
    assert np.array_equal(brute, expect)


def test_hash_uniformity_rough():
    fam = AffineHashFamily.create(4, seed=0)
    u = fam.hash_unit(np.arange(20000, dtype=np.int64))
    assert abs(u.mean() - 0.5) < 0.02
    assert abs(np.mean(u < 0.25) - 0.25) < 0.02


# ---------------------------------------------------------------------------
# progression_min: exactness (hypothesis property test)
# ---------------------------------------------------------------------------
@given(st.integers(min_value=2, max_value=2**31 - 1), st.data())
@settings(max_examples=300, deadline=None)
def test_progmin_matches_bruteforce(m, data):
    a = data.draw(st.integers(min_value=0, max_value=m - 1))
    b = data.draw(st.integers(min_value=0, max_value=m - 1))
    n = data.draw(st.integers(min_value=1, max_value=3000))
    fast = int(progression_min(a, b, m, n).ravel()[0])
    assert fast == progression_min_bruteforce(a, b, m, n)


def test_progmin_adversarial_small_moduli():
    """Exhaustive over small moduli — catches off-by-one in both branches."""
    for m in range(2, 18):
        for a in range(m):
            for b in range(m):
                for n in (1, 2, 3, m, 2 * m + 1):
                    fast = int(progression_min(a, b, m, n).ravel()[0])
                    assert fast == progression_min_bruteforce(a, b, m, n), (a, b, m, n)


def test_progmin_large_n():
    # n ~ L = 1e7 with p = 2^31-1: the production regime.
    p = int(MERSENNE_P)
    rng = np.random.default_rng(5)
    for _ in range(20):
        a, b = int(rng.integers(0, p)), int(rng.integers(0, p))
        n = int(rng.integers(10**6, 10**7))
        v = int(progression_min(a, b, p, n).ravel()[0])
        # With ~n samples of a ~uniform progression the min is ~p/n: sanity band.
        assert 0 <= v < p
        assert v <= 50 * (p // max(n, 1) + 1) or a == 0


# ---------------------------------------------------------------------------
# rounding (Algorithm 4)
# ---------------------------------------------------------------------------
@given(st.lists(st.floats(min_value=-100, max_value=100,
                          allow_nan=False, allow_infinity=False),
                min_size=1, max_size=60))
@settings(max_examples=200, deadline=None)
def test_round_counts_invariants(vals):
    v = np.array(vals)
    if np.linalg.norm(v) < 1e-9:
        return
    z = v / np.linalg.norm(v)
    L = 4096
    k = round_counts(z, L)
    assert k.sum() == L                      # exactly unit norm after rounding
    assert (k >= 0).all()
    zt = round_unit(z, L)
    assert np.allclose(np.sum(zt * zt), 1.0)  # unit vector out
    assert np.all(np.sign(zt[zt != 0]) == np.sign(z[zt != 0]))  # sign preserved
    # every squared entry an integer multiple of 1/L
    assert np.allclose(zt * zt * L, np.round(zt * zt * L), atol=1e-6)


def test_round_counts_only_argmax_rounds_up():
    z = np.array([0.9, 0.3, np.sqrt(1 - 0.81 - 0.09)])
    z = z / np.linalg.norm(z)
    L = 1000
    k = round_counts(z, L)
    down = np.floor(z * z * L).astype(np.int64)
    bumped = np.nonzero(k != down)[0]
    assert len(bumped) <= 1
    if len(bumped) == 1:
        assert bumped[0] == int(np.argmax(np.abs(z)))


# ---------------------------------------------------------------------------
# WMH: bit-exact equivalence with the extended-domain brute force
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("seed,L,n,density", [(0, 257, 40, 0.5), (1, 1000, 25, 0.3),
                                              (2, 64, 10, 1.0), (3, 4096, 60, 0.2)])
def test_wmh_fast_path_bit_exact(seed, L, n, density):
    rng = np.random.default_rng(seed)
    wmh = WeightedMinHash(m=24, seed=seed, L=L)
    a = rng.normal(size=n) * (rng.random(n) < density)
    if not a.any():
        a[0] = 1.0
    v = SparseVec.from_dense(a)
    fast, slow = wmh.sketch(v), sketch_bruteforce(wmh, v)
    assert np.array_equal(fast.hash_mins, slow.hash_mins)
    assert np.allclose(fast.values, slow.values)


def test_wmh_collision_rate_matches_weighted_jaccard():
    """Fact 5(1): collision prob == weighted Jaccard of rounded squared entries."""
    rng = np.random.default_rng(3)
    n = 100
    a = rng.normal(size=n) * (rng.random(n) < 0.5)
    b = rng.normal(size=n) * (rng.random(n) < 0.5)
    L = 10**6
    wmh = WeightedMinHash(m=4000, seed=9, L=L)
    sa = wmh.sketch(SparseVec.from_dense(a))
    sb = wmh.sketch(SparseVec.from_dense(b))
    rate = np.mean(sa.hash_mins == sb.hash_mins)
    za = round_unit(a / np.linalg.norm(a), L) ** 2
    zb = round_unit(b / np.linalg.norm(b), L) ** 2
    jbar = np.minimum(za, zb).sum() / np.maximum(za, zb).sum()
    assert abs(rate - jbar) < 4.0 / np.sqrt(4000) + 0.01


def test_wmh_union_estimator_accuracy():
    """Lemma 1 via Algorithm 5 line 2: M~ ~= sum max(a~^2, b~^2)."""
    rng = np.random.default_rng(4)
    n = 200
    a = rng.normal(size=n) * (rng.random(n) < 0.6)
    b = rng.normal(size=n) * (rng.random(n) < 0.6)
    L = 10**6
    m = 3000
    wmh = WeightedMinHash(m=m, seed=2, L=L)
    sa, sb = wmh.sketch(SparseVec.from_dense(a)), wmh.sketch(SparseVec.from_dense(b))
    hmin = np.minimum(sa.hash_mins, sb.hash_mins).astype(np.float64) / float(MERSENNE_P)
    m_tilde = (m / hmin.sum() - 1.0) / L
    za = round_unit(a / np.linalg.norm(a), L) ** 2
    zb = round_unit(b / np.linalg.norm(b), L) ** 2
    m_true = np.maximum(za, zb).sum()
    assert abs(m_tilde - m_true) / m_true < 0.15


def _sparse_pair(rng, n=1500, nnz=300, overlap=0.2, outliers=True):
    """The paper's synthetic protocol (Section 5.1), parameterized."""
    n_ov = int(overlap * nnz)
    idx = rng.choice(n, size=2 * nnz - n_ov, replace=False)
    ia = idx[:nnz]
    ib = np.concatenate([idx[:n_ov], idx[nnz:]])
    def vals(k):
        v = rng.uniform(-1, 1, size=k)
        if outliers:
            out = rng.random(k) < 0.1
            v[out] = rng.uniform(20, 30, size=out.sum())
        return v
    a, b = np.zeros(n), np.zeros(n)
    a[ia], b[ib] = vals(nnz), vals(len(ib))
    return SparseVec.from_dense(a), SparseVec.from_dense(b)


def test_wmh_beats_fact1_bound_statistically():
    """Theorem 2 in practice: WMH error well under eps*||a||*||b|| at low overlap."""
    rng = np.random.default_rng(11)
    m = 400
    wmh = WeightedMinHash(m=m, seed=5, L=10**7)
    errs, t2, f1 = [], [], []
    for _ in range(12):
        va, vb = _sparse_pair(rng, overlap=0.05)
        est = wmh.estimate(wmh.sketch(va), wmh.sketch(vb))
        errs.append(abs(est - inner_fast(va, vb)))
        t2.append(theorem2_bound(va, vb))
        f1.append(fact1_bound(va, vb))
    med = np.median(errs)
    # eps ~ 1/sqrt(m); allow generous constants, but the Fact-1 scale must be beaten.
    assert med < 3.0 / np.sqrt(m) * np.median(t2)
    assert med < 0.5 / np.sqrt(m) * np.median(f1)


def test_wmh_estimate_unbiased_statistically():
    rng = np.random.default_rng(21)
    va, vb = _sparse_pair(rng, n=400, nnz=80, overlap=0.3)
    true = inner_fast(va, vb)
    ests = []
    for seed in range(30):
        w = WeightedMinHash(m=128, seed=seed, L=10**6)
        ests.append(w.estimate(w.sketch(va), w.sketch(vb)))
    mean = np.mean(ests)
    spread = np.std(ests) / np.sqrt(len(ests))
    assert abs(mean - true) < 4 * spread + 0.05 * abs(true)


def test_wmh_identical_vectors():
    rng = np.random.default_rng(8)
    a = rng.normal(size=50)
    w = WeightedMinHash(m=512, seed=0, L=10**6)
    v = SparseVec.from_dense(a)
    s = w.sketch(v)
    est = w.estimate(s, s)
    true = float(np.dot(a, a))
    assert abs(est - true) / true < 0.2  # all m samples collide; only M~ noise


def test_wmh_zero_and_disjoint():
    w = WeightedMinHash(m=64, seed=0, L=1000)
    z = SparseVec.from_dense(np.zeros(10))
    a = SparseVec.from_dense(np.eye(10)[0])
    b = SparseVec.from_dense(np.eye(10)[5])
    assert w.estimate(w.sketch(z), w.sketch(a)) == 0.0
    assert abs(w.estimate(w.sketch(a), w.sketch(b))) < 1e-9  # no collisions


def test_wmh_batch_matches_single():
    rng = np.random.default_rng(13)
    w = WeightedMinHash(m=64, seed=1, L=10**5)
    pairs = [_sparse_pair(rng, n=300, nnz=60, overlap=0.4) for _ in range(5)]
    A = stack_wmh([w.sketch(a) for a, _ in pairs])
    B = stack_wmh([w.sketch(b) for _, b in pairs])
    batch = w.estimate_batch(A, B)
    single = [w.estimate(w.sketch(a), w.sketch(b)) for a, b in pairs]
    assert np.allclose(batch, single)


# ---------------------------------------------------------------------------
# MinHash (Algorithms 1-2)
# ---------------------------------------------------------------------------
def test_minhash_collision_rate_is_jaccard():
    rng = np.random.default_rng(2)
    n = 400
    a = (rng.random(n) < 0.5).astype(float)
    b = (rng.random(n) < 0.5).astype(float)
    mh = MinHash(m=4000, seed=1)
    sa, sb = mh.sketch(SparseVec.from_dense(a)), mh.sketch(SparseVec.from_dense(b))
    rate = np.mean(sa.hash_mins == sb.hash_mins)
    inter = np.sum((a > 0) & (b > 0))
    union = np.sum((a > 0) | (b > 0))
    assert abs(rate - inter / union) < 0.04


def test_minhash_binary_intersection_estimate():
    rng = np.random.default_rng(6)
    n = 2000
    a = (rng.random(n) < 0.3).astype(float)
    b = (rng.random(n) < 0.3).astype(float)
    mh = MinHash(m=2000, seed=3)
    est = mh.estimate(mh.sketch(SparseVec.from_dense(a)),
                      mh.sketch(SparseVec.from_dense(b)))
    true = float(np.sum(a * b))
    assert abs(est - true) / true < 0.25


# ---------------------------------------------------------------------------
# KMV
# ---------------------------------------------------------------------------
def test_kmv_inner_product():
    rng = np.random.default_rng(7)
    n = 3000
    a = (rng.random(n) < 0.3) * rng.uniform(-1, 1, n)
    b = (rng.random(n) < 0.3) * rng.uniform(-1, 1, n)
    kmv = KMV(k=600, seed=2)
    est = kmv.estimate(kmv.sketch(SparseVec.from_dense(a)),
                       kmv.sketch(SparseVec.from_dense(b)))
    true = float(np.sum(a * b))
    assert abs(est - true) < 0.3 * np.linalg.norm(a) * np.linalg.norm(b)


def test_kmv_small_support():
    kmv = KMV(k=64, seed=0)
    a = SparseVec.from_dense(np.array([1.0, 2.0, 0.0, 3.0]))
    est = kmv.estimate(kmv.sketch(a), kmv.sketch(a))
    # support smaller than k: sketch is the full vector, estimate near-exact
    assert abs(est - 14.0) / 14.0 < 0.35  # union estimator noise only


# ---------------------------------------------------------------------------
# JL and CountSketch (linear)
# ---------------------------------------------------------------------------
def test_jl_accuracy_and_linearity():
    rng = np.random.default_rng(9)
    a, b = rng.normal(size=500), rng.normal(size=500)
    jl = JL(m=2000, seed=4)
    sa, sb = jl.sketch_dense(a), jl.sketch_dense(b)
    est = jl.estimate(sa, sb)
    true = float(np.dot(a, b))
    assert abs(est - true) < 4.0 / np.sqrt(2000) * np.linalg.norm(a) * np.linalg.norm(b)
    # linearity: S(a+b) == S(a) + S(b)
    merged = jl.merge(sa, sb)
    direct = jl.sketch_dense(a + b)
    assert np.allclose(merged.proj, direct.proj, atol=1e-9)


def test_countsketch_accuracy_linearity_decode():
    rng = np.random.default_rng(10)
    a, b = rng.normal(size=500), rng.normal(size=500)
    cs = CountSketch(width=400, seed=5)
    sa, sb = cs.sketch_dense(a), cs.sketch_dense(b)
    est = cs.estimate(sa, sb)
    true = float(np.dot(a, b))
    assert abs(est - true) < 4.0 / np.sqrt(400) * np.linalg.norm(a) * np.linalg.norm(b)
    assert np.allclose(cs.merge(sa, sb).table, cs.sketch_dense(a + b).table, atol=1e-9)
    # decode: unbiased point query
    dec = cs.decode(sa, np.arange(500))
    assert np.mean((dec - a) ** 2) < np.mean(a ** 2)  # signal recovered


# ---------------------------------------------------------------------------
# ICWS (TPU-native WMH variant)
# ---------------------------------------------------------------------------
def test_icws_collision_rate_is_weighted_jaccard():
    rng = np.random.default_rng(12)
    n = 100
    a = rng.normal(size=n) * (rng.random(n) < 0.6)
    b = rng.normal(size=n) * (rng.random(n) < 0.6)
    icws = ICWS(m=4000, seed=3)
    sa = icws.sketch(SparseVec.from_dense(a))
    sb = icws.sketch(SparseVec.from_dense(b))
    rate = np.mean((sa.fingerprints == sb.fingerprints) & (sa.fingerprints >= 0))
    wa = (a / np.linalg.norm(a)) ** 2
    wb = (b / np.linalg.norm(b)) ** 2
    jbar = np.minimum(wa, wb).sum() / np.maximum(wa, wb).sum()
    assert abs(rate - jbar) < 4.0 / np.sqrt(4000) + 0.01


def test_icws_estimate_accuracy():
    rng = np.random.default_rng(14)
    errs, bounds = [], []
    icws = ICWS(m=400, seed=6)
    for _ in range(10):
        va, vb = _sparse_pair(rng, overlap=0.1)
        est = icws.estimate(icws.sketch(va), icws.sketch(vb))
        errs.append(abs(est - inner_fast(va, vb)))
        bounds.append(theorem2_bound(va, vb))
    assert np.median(errs) < 3.0 / np.sqrt(400) * np.median(bounds)


def test_icws_batch_matches_single():
    rng = np.random.default_rng(15)
    icws = ICWS(m=64, seed=1)
    pairs = [_sparse_pair(rng, n=300, nnz=60, overlap=0.4) for _ in range(4)]
    A = stack_icws([icws.sketch(a) for a, _ in pairs])
    B = stack_icws([icws.sketch(b) for _, b in pairs])
    assert np.allclose(icws.estimate_batch(A, B),
                       [icws.estimate(icws.sketch(a), icws.sketch(b)) for a, b in pairs])


# ---------------------------------------------------------------------------
# property: sketches are deterministic given (seed) and coordinate across vecs
# ---------------------------------------------------------------------------
@given(st.integers(min_value=0, max_value=2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_wmh_deterministic(seed):
    rng = np.random.default_rng(1)
    a = rng.normal(size=30)
    w1 = WeightedMinHash(m=16, seed=seed, L=1024)
    w2 = WeightedMinHash(m=16, seed=seed, L=1024)
    s1, s2 = w1.sketch_dense(a), w2.sketch_dense(a)
    assert np.array_equal(s1.hash_mins, s2.hash_mins)
    assert np.array_equal(s1.values, s2.values)


# ---------------------------------------------------------------------------
# union merge: the sharded-ingestion primitive
# ---------------------------------------------------------------------------
def test_minhash_union_merge_exact():
    """Sketching shards and merging == sketching the whole vector."""
    rng = np.random.default_rng(31)
    n = 1000
    full = rng.normal(size=n) * (rng.random(n) < 0.4)
    lo, hi = full.copy(), full.copy()
    lo[n // 2:] = 0.0
    hi[: n // 2] = 0.0
    mh = MinHash(m=128, seed=4)
    merged = mh.merge_union(mh.sketch_dense(lo), mh.sketch_dense(hi))
    direct = mh.sketch_dense(full)
    assert np.array_equal(merged.hash_mins, direct.hash_mins)
    assert np.array_equal(merged.values, direct.values)


def test_kmv_union_merge_exact():
    rng = np.random.default_rng(32)
    n = 1000
    full = rng.normal(size=n) * (rng.random(n) < 0.4)
    lo, hi = full.copy(), full.copy()
    lo[n // 2:] = 0.0
    hi[: n // 2] = 0.0
    kmv = KMV(k=64, seed=5)
    merged = kmv.merge_union(kmv.sketch_dense(lo), kmv.sketch_dense(hi))
    direct = kmv.sketch_dense(full)
    assert np.array_equal(merged.hashes, direct.hashes)
    assert np.array_equal(merged.values, direct.values)


@given(st.integers(min_value=2, max_value=6))
@settings(max_examples=10, deadline=None)
def test_minhash_union_merge_associative(parts):
    """Merging P shards in any order gives the direct sketch (fold-safe)."""
    rng = np.random.default_rng(33)
    n = 600
    full = rng.normal(size=n) * (rng.random(n) < 0.5)
    bounds = np.linspace(0, n, parts + 1).astype(int)
    mh = MinHash(m=64, seed=6)
    shards = []
    for i in range(parts):
        s = np.zeros(n)
        s[bounds[i]:bounds[i + 1]] = full[bounds[i]:bounds[i + 1]]
        if s.any():
            shards.append(mh.sketch_dense(s))
    acc = shards[0]
    for s in shards[1:]:
        acc = mh.merge_union(acc, s)
    direct = mh.sketch_dense(full)
    assert np.array_equal(acc.hash_mins, direct.hash_mins)
