"""Regression tests for the logical-axis rule table.

``DEFAULT_RULES`` is a dict literal; a duplicate key silently shadows the
earlier entry (this bit us: a second ``"capacity": None`` overrode the
documented ``("pod", "data")`` mapping).  Python can't see this at runtime,
so the uniqueness check parses the source.
"""
import ast
import inspect

import pytest

from repro.distributed import sharding
from repro.distributed.sharding import DEFAULT_RULES, spec_for


def _default_rules_literal_keys():
    """Keys of the DEFAULT_RULES dict literal, in source order, with repeats."""
    tree = ast.parse(inspect.getsource(sharding))
    for node in ast.walk(tree):
        if isinstance(node, ast.Assign):
            targets = [t.id for t in node.targets if isinstance(t, ast.Name)]
        elif isinstance(node, ast.AnnAssign) and isinstance(node.target, ast.Name):
            targets = [node.target.id]
        else:
            continue
        if "DEFAULT_RULES" in targets and isinstance(node.value, ast.Dict):
            return [k.value for k in node.value.keys
                    if isinstance(k, ast.Constant)]
    raise AssertionError("DEFAULT_RULES dict literal not found")


def test_default_rules_keys_are_unique():
    keys = _default_rules_literal_keys()
    dupes = {k for k in keys if keys.count(k) > 1}
    assert not dupes, f"duplicate DEFAULT_RULES keys shadow earlier entries: {dupes}"


def test_capacity_resolves_to_data_axes():
    assert DEFAULT_RULES["capacity"] == ("pod", "data")


def test_capacity_sharding_falls_back_when_tokens_take_data():
    """In MoE dispatch the tokens dim consumes the data axes first; the
    capacity dim must then replicate (axes already used), not error."""
    pytest.importorskip("jax")
    import numpy as np
    from jax.sharding import Mesh

    import jax
    devs = np.array(jax.devices()[:1]).reshape(1, 1)
    mesh = Mesh(devs, ("data", "model"))
    spec = spec_for((4, 8, 16, 32), ("tokens", "experts", "capacity", "embed"),
                    DEFAULT_RULES, mesh)
    # tokens got the data axis, capacity must not reuse it
    assert spec[0] == "data" and spec[2] is None
