"""Device-resident sketch corpus + one-vs-many estimation path.

Covers: the one-vs-many Pallas kernel vs its jnp oracle and vs the pairwise
kernel on a tiled query; SketchCorpus chunked append semantics; the device
corpus-query path against the host ICWS estimator on identical sketches
(1e-5 relative); the rewired DatasetSearchIndex (device vs host-oracle
agreement, duplicate-key ingestion); and the serving front-end.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ICWS, SparseVec
from repro.core.icws import StackedICWS
from repro.data import DatasetSearchIndex, SketchCorpus, sketch_batch
from repro.data.synthetic import sparse_pair
from repro.kernels import ops, ref
from repro.kernels.estimate import (estimate_one_vs_many_pallas,
                                    estimate_partials_pallas)
from repro.serve import SketchSearchService


# ---------------------------------------------------------------------------
# one-vs-many kernel: vs oracle, vs pairwise kernel on a tiled query
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("P,m", [(8, 128), (5, 100), (16, 512), (1, 64),
                                 (9, 130)])
def test_one_vs_many_kernel_matches_ref(P, m):
    rng = np.random.default_rng(P * 37 + m)
    fq = rng.integers(0, 50, size=(1, m)).astype(np.int32)
    fpc = rng.integers(0, 50, size=(P, m)).astype(np.int32)
    vq = rng.normal(size=(1, m)).astype(np.float32)
    vc = rng.normal(size=(P, m)).astype(np.float32)
    cnt_k, sw_k = estimate_one_vs_many_pallas(
        jnp.asarray(fq), jnp.asarray(vq), jnp.asarray(fpc), jnp.asarray(vc),
        interpret=True)
    cnt_r, sw_r = ref.estimate_one_vs_many_ref(
        jnp.asarray(fq), jnp.asarray(vq), jnp.asarray(fpc), jnp.asarray(vc))
    np.testing.assert_allclose(np.asarray(cnt_k), np.asarray(cnt_r))
    np.testing.assert_allclose(np.asarray(sw_k), np.asarray(sw_r), rtol=1e-4)


def test_one_vs_many_equals_pairwise_on_tiled_query():
    """Broadcasting the query in-kernel == materializing the [P, m] tile."""
    rng = np.random.default_rng(3)
    P, m = 12, 256
    fq = rng.integers(0, 30, size=(1, m)).astype(np.int32)
    vq = rng.normal(size=(1, m)).astype(np.float32)
    fpc = rng.integers(0, 30, size=(P, m)).astype(np.int32)
    vc = rng.normal(size=(P, m)).astype(np.float32)
    cnt_b, sw_b = estimate_one_vs_many_pallas(
        jnp.asarray(fq), jnp.asarray(vq), jnp.asarray(fpc), jnp.asarray(vc),
        interpret=True)
    tiled_f = jnp.asarray(np.repeat(fq, P, axis=0))
    tiled_v = jnp.asarray(np.repeat(vq, P, axis=0))
    cnt_p, sw_p = estimate_partials_pallas(tiled_f, tiled_v,
                                           jnp.asarray(fpc), jnp.asarray(vc),
                                           interpret=True)
    np.testing.assert_allclose(np.asarray(cnt_b), np.asarray(cnt_p))
    np.testing.assert_allclose(np.asarray(sw_b), np.asarray(sw_p), rtol=1e-5)


def test_one_vs_many_empty_query_guard():
    """An all-empty query sketch (fp == -1) collides with nothing."""
    P, m = 4, 128
    fq = jnp.full((1, m), -1, jnp.int32)
    vq = jnp.zeros((1, m))
    fpc = jnp.full((P, m), -1, jnp.int32)     # empty corpus rows too
    vc = jnp.zeros((P, m))
    cnt, sw = estimate_one_vs_many_pallas(fq, vq, fpc, vc, interpret=True)
    assert np.all(np.asarray(cnt) == 0.0)
    assert np.all(np.asarray(sw) == 0.0)


# ---------------------------------------------------------------------------
# SketchCorpus: chunked append, no restacking, device-vs-host estimates
# ---------------------------------------------------------------------------
def _lake_vecs(rng, count, n=600, nnz=150):
    vecs = []
    for _ in range(count):
        a, b = sparse_pair(rng, n=n, nnz=nnz, overlap=0.3)
        vecs.append(a)
    return vecs


def test_corpus_chunked_append_matches_one_shot():
    rng = np.random.default_rng(17)
    vecs = _lake_vecs(rng, 7)
    m = 128
    one = SketchCorpus(m=m, seed=5)
    one.add_batch(vecs)
    chunked = SketchCorpus(m=m, seed=5)
    chunked.add_batch(vecs[:3])
    chunked.add_batch(vecs[3:5])
    chunked.add_batch(vecs[5:])
    assert len(one) == len(chunked) == 7
    fp1, v1, n1, k1 = one.arrays()
    fp2, v2, n2, k2 = chunked.arrays()
    assert np.array_equal(np.asarray(fp1), np.asarray(fp2))
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2))
    np.testing.assert_allclose(np.asarray(n1), np.asarray(n2))
    assert np.array_equal(np.asarray(k1), np.asarray(k2))
    # appends land in the canonical store: rows already written are stable
    # across later appends (and capacity growth), no chunk re-consolidation
    assert chunked.capacity >= len(chunked)
    chunked.add_batch(vecs[:1])
    assert len(chunked) == 8
    fp3, _, _, _ = chunked.arrays()
    assert np.array_equal(np.asarray(fp3)[:7], np.asarray(fp2))


def test_corpus_device_query_matches_host_estimator_on_identical_sketches():
    """The acceptance bar: one-vs-many device estimates == host ICWS
    estimate_batch on the same sketch arrays, to 1e-5 relative."""
    rng = np.random.default_rng(23)
    vecs = _lake_vecs(rng, 9)
    q, _ = sparse_pair(rng, n=600, nnz=150, overlap=0.3)
    m = 256
    corpus = SketchCorpus(m=m, seed=2)
    corpus.add_batch(vecs)
    fq, vq, nq, _ = corpus.sketch_query(q)
    dev = np.asarray(corpus.estimate(fq, vq, nq[0]), np.float64)

    # identical sketches, host estimator (f64), query tiled host-side
    fpc, vc, nc = (np.asarray(a) for a in corpus.arrays()[:3])
    P = len(vecs)
    A = StackedICWS(fingerprints=np.repeat(np.asarray(fq), P, axis=0),
                    values=np.repeat(np.asarray(vq, np.float64), P, axis=0),
                    norm=np.full(P, float(nq[0]), np.float64))
    B = StackedICWS(fingerprints=fpc, values=vc.astype(np.float64),
                    norm=nc.astype(np.float64))
    host = ICWS(m=m, seed=2).estimate_batch(A, B)
    scale = np.maximum(np.abs(host), np.abs(dev))
    rel = np.abs(dev - host) / np.where(scale == 0, 1.0, scale)
    assert rel.max() < 1e-5, rel


def test_corpus_estimate_accuracy_end_to_end():
    """Device corpus query estimates true inner products (paper band)."""
    rng = np.random.default_rng(29)
    m = 2048
    pairs = [sparse_pair(rng, n=800, nnz=200, overlap=0.4) for _ in range(4)]
    corpus = SketchCorpus(m=m, seed=9)
    corpus.add_batch([b for _, b in pairs])
    from repro.core import inner_fast
    for qi, (a, _) in enumerate(pairs):
        est = np.asarray(corpus.estimate_vec(a))
        true = inner_fast(a, pairs[qi][1])
        bound = 4.0 / np.sqrt(m) * a.norm() * pairs[qi][1].norm()
        assert abs(est[qi] - true) < bound


def test_corpus_empty_raises():
    corpus = SketchCorpus(m=64)
    with pytest.raises(ValueError):
        corpus.arrays()


def test_corpus_add_sketches_validates_all_components():
    """Regression: a mismatched ``val`` (or ``norm``) must fail at ingest.

    Pre-fix, ``add_sketches`` checked only fp-vs-norm row counts and a
    wrong-sized ``val`` sailed in, deferring the failure to query time."""
    rng = np.random.default_rng(3)
    m = 64
    corpus = SketchCorpus(m=m)
    fp = rng.integers(0, 50, size=(4, m)).astype(np.int32)
    val = rng.normal(size=(4, m)).astype(np.float32)
    norm = np.ones(4, np.float32)
    key = rng.integers(0, 2 ** 31 - 1, size=(4, m)).astype(np.int32)
    with pytest.raises(ValueError):
        corpus.add_sketches(fp, val[:3], norm, key)     # short val
    with pytest.raises(ValueError):
        corpus.add_sketches(fp, val, norm[:3], key)     # short norm
    with pytest.raises(ValueError):
        corpus.add_sketches(fp, val, norm, key[:3])     # short argkeys
    assert len(corpus) == 0                             # nothing ingested
    corpus.add_sketches(fp, val, norm, key)             # matched: fine
    assert len(corpus) == 4


# ---------------------------------------------------------------------------
# DatasetSearchIndex: device path vs host oracle, duplicate keys
# ---------------------------------------------------------------------------
def test_dataset_search_device_vs_host_oracle():
    rng = np.random.default_rng(31)
    idx = DatasetSearchIndex(m=768, seed=4)
    keys = np.arange(800)
    signal = rng.normal(size=800)
    idx.add_table("corr", keys, signal + 0.2 * rng.normal(size=800))
    idx.add_table("noise", keys, rng.normal(size=800))
    idx.add_table("disjoint", np.arange(10_000, 10_800),
                  rng.normal(size=800))

    dev = idx.query(keys, signal, top_k=3, min_join=40)
    host = idx.query(keys, signal, top_k=3, min_join=40, backend="host")
    assert [r.name for r in dev] == [r.name for r in host]
    assert dev[0].name == "corr"
    for d, h in zip(dev, host):
        # two unbiased estimators of the same join size; both near truth
        assert abs(d.join_size - h.join_size) < 0.35 * 800
        assert d.corr == h.corr          # KMV refinement is shared


def test_dataset_search_duplicate_keys_regression():
    """Realistic lake table with repeated join keys must ingest and the
    join size must count joined row *pairs* (SQL semantics)."""
    rng = np.random.default_rng(37)
    n_orders = 1200
    customer = rng.integers(0, 200, size=n_orders)        # many repeats
    amount = rng.uniform(10, 500, size=n_orders)
    idx = DatasetSearchIndex(m=1024, seed=6)
    idx.add_table("orders", customer, amount)             # crashed before

    q_keys = np.arange(200)                               # customer dimension
    q_vals = rng.uniform(0, 1, size=200)
    res = idx.query(q_keys, q_vals, top_k=1, min_join=10)
    assert len(res) == 1
    # true join cardinality = number of order rows with customer in 0..199
    true_pairs = float(n_orders)
    assert abs(res[0].join_size - true_pairs) / true_pairs < 0.5
    # the indicator vector carries multiplicities
    ind, val, sq = idx.vectorize(customer, amount)
    assert ind.values.sum() == n_orders
    assert ind.nnz == len(np.unique(customer))
    # aggregated value vector sums duplicates
    first_key = int(ind.indices[0])
    assert np.isclose(val.values[0], amount[customer == first_key].sum())


def test_vectorize_aggregates_keys_colliding_mod_key_space():
    """Two distinct int64 keys that collide mod ``key_space`` must fold and
    aggregate identically in all three field vectors (pre-fix, the signed-
    value vector deduplicated *raw* keys, so colliding keys crashed/dropped
    in ``from_pairs`` while the indicator path aggregated them)."""
    ks = 97
    idx = DatasetSearchIndex(m=64, seed=0, key_space=ks)
    keys = np.array([1, 1 + ks, 5, 5 + 3 * ks, 96])
    vals = np.array([2.0, 3.0, 1.0, 4.0, -1.0])
    ind, val, sq = idx.vectorize(keys, vals)
    for v in (ind, val, sq):
        assert list(v.indices) == [1, 5, 96]          # folded + deduplicated
        assert np.all(v.indices < ks)                 # in the sketch domain
    assert ind.values[0] == 2.0                       # multiplicity of key 1
    assert val.values[0] == 5.0 and val.values[1] == 5.0
    assert sq.values[0] == 2.0 ** 2 + 3.0 ** 2
    # and a colliding table ingests + serves end to end on both paths
    idx.add_table("t", keys, vals)
    res = idx.query(keys, vals, top_k=1, min_join=1)
    host = idx.query(keys, vals, top_k=1, min_join=1, backend="host")
    assert res and host and res[0].name == host[0].name == "t"


def test_dataset_search_zero_values_survive_aggregation():
    keys = np.array([3, 3, 5])
    vals = np.array([1.0, -1.0, 0.0])     # duplicates cancel; explicit zero
    idx = DatasetSearchIndex(m=64, seed=0)
    ind, val, sq = idx.vectorize(keys, vals)
    assert set(ind.indices) == {3, 5}     # both keys represented
    assert set(val.indices) == {3, 5}     # cancellation nudged, not dropped


def test_sparsevec_sum_duplicates_option():
    v = SparseVec.from_pairs([4, 1, 4, 2], [1.0, 2.0, 3.0, 4.0], 10,
                             sum_duplicates=True)
    assert list(v.indices) == [1, 2, 4]
    assert list(v.values) == [2.0, 4.0, 4.0]
    with pytest.raises(ValueError):
        SparseVec.from_pairs([4, 1, 4], [1.0, 2.0, 3.0], 10)


# ---------------------------------------------------------------------------
# serving front-end
# ---------------------------------------------------------------------------
def test_sketch_search_service():
    rng = np.random.default_rng(41)
    svc = SketchSearchService(m=512, seed=3)
    keys = np.arange(500)
    signal = rng.normal(size=500)
    svc.ingest_many([
        ("a_corr", keys, signal + 0.1 * rng.normal(size=500)),
        ("b_noise", keys, rng.normal(size=500)),
    ])
    with pytest.raises(ValueError):
        svc.ingest("a_corr", keys, signal)
    res = svc.search(keys, signal, top_k=2, min_join=20)
    assert res and res[0].name == "a_corr"
    d = svc.describe()
    assert d["tables"] == 2.0 and d["queries_served"] == 1.0
    assert svc.stats.last_query_ms > 0
