"""Test-session wiring.

If the real ``hypothesis`` package is unavailable (air-gapped containers),
install the minimal fallback from :mod:`repro.testing.hypothesis_fallback`
into ``sys.modules`` before any test module imports it.  A normal dev setup
(``pip install -e .``) gets the real thing and this is a no-op.
"""
import importlib.util
import os
import sys

# allow running pytest without PYTHONPATH=src (e.g. bare `pytest`)
_SRC = os.path.join(os.path.dirname(os.path.dirname(__file__)), "src")
if _SRC not in sys.path and importlib.util.find_spec("repro") is None:
    sys.path.insert(0, _SRC)

if importlib.util.find_spec("hypothesis") is None:
    from repro.testing import hypothesis_fallback

    sys.modules["hypothesis"] = hypothesis_fallback
    sys.modules["hypothesis.strategies"] = hypothesis_fallback.strategies
