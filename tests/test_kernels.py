"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp oracle.

Sweeps shapes (aligned and ragged vs the block sizes) and dtypes, asserting
allclose against ref.py, plus statistical checks that the device ICWS path
obeys the weighted-Jaccard collision law end to end.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.countsketch import countsketch_pallas
from repro.kernels.estimate import estimate_partials_pallas
from repro.kernels.icws_sketch import icws_sketch_pallas


def _sparse_batch(rng, B, N, density=0.6, dtype=jnp.float32):
    """Padded sparse batch: (w, keys, vals) with zero-padding."""
    vals = rng.normal(size=(B, N)).astype(np.float32)
    mask = rng.random((B, N)) < density
    vals = vals * mask
    norms = np.linalg.norm(vals, axis=1, keepdims=True)
    norms[norms == 0] = 1.0
    valsn = vals / norms
    w = valsn ** 2
    keys = rng.integers(0, 2**31 - 1, size=(B, N)).astype(np.int32)
    return (jnp.asarray(w, dtype), jnp.asarray(keys),
            jnp.asarray(valsn, dtype))


# ---------------------------------------------------------------------------
# ICWS sketch kernel
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("B,N,m", [(1, 256, 128), (3, 300, 64), (2, 1000, 200),
                                   (4, 64, 128), (2, 513, 257)])
@pytest.mark.slow
def test_icws_kernel_matches_ref(B, N, m):
    rng = np.random.default_rng(B * 1000 + N + m)
    w, keys, vals = _sparse_batch(rng, B, N)
    fp_k, val_k, amin_k, key_k = icws_sketch_pallas(w, keys, vals, m=m, seed=7,
                                                    interpret=True)
    fp_r, val_r, amin_r, key_r = ref.icws_sketch_ref(w, keys, vals, m=m, seed=7)
    assert np.array_equal(np.asarray(fp_k), np.asarray(fp_r))
    assert np.array_equal(np.asarray(key_k), np.asarray(key_r))
    np.testing.assert_allclose(np.asarray(val_k), np.asarray(val_r), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(amin_k), np.asarray(amin_r), rtol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_icws_kernel_dtypes(dtype):
    rng = np.random.default_rng(0)
    w, keys, vals = _sparse_batch(rng, 2, 256, dtype=dtype)
    fp_k, val_k, _, _ = icws_sketch_pallas(w, keys, vals, m=64, seed=1,
                                           interpret=True)
    fp_r, val_r, _, _ = ref.icws_sketch_ref(w.astype(jnp.float32), keys,
                                            vals.astype(jnp.float32),
                                            m=64, seed=1)
    # bf16 inputs are upcast inside; fingerprints must agree except where the
    # bf16 rounding moved an argmin (rare) -- demand 95% agreement for bf16.
    agree = np.mean(np.asarray(fp_k) == np.asarray(fp_r))
    assert agree > (0.999 if dtype == jnp.float32 else 0.95)


def test_icws_kernel_empty_rows():
    w = jnp.zeros((2, 128))
    keys = jnp.zeros((2, 128), jnp.int32)
    vals = jnp.zeros((2, 128))
    fp, val, amin, key = icws_sketch_pallas(w, keys, vals, m=32, seed=0,
                                            interpret=True)
    assert np.all(np.asarray(fp) == -1)
    assert np.all(np.asarray(val) == 0.0)
    assert np.all(np.asarray(key) == 0)


@pytest.mark.slow
def test_icws_kernel_block_size_invariance():
    """Different tilings must give identical results (tie semantics included)."""
    rng = np.random.default_rng(42)
    w, keys, vals = _sparse_batch(rng, 2, 512)
    outs = []
    for bm, bn in [(64, 128), (128, 256), (128, 512)]:
        outs.append(icws_sketch_pallas(w, keys, vals, m=128, seed=3,
                                       bm=bm, bn=bn, interpret=True))
    for o in outs[1:]:
        assert np.array_equal(np.asarray(o[0]), np.asarray(outs[0][0]))
        assert np.array_equal(np.asarray(o[3]), np.asarray(outs[0][3]))
        np.testing.assert_allclose(np.asarray(o[1]), np.asarray(outs[0][1]),
                                   rtol=1e-6)


@pytest.mark.slow
def test_icws_device_collision_law():
    """End-to-end: device sketches obey the weighted-Jaccard collision law."""
    rng = np.random.default_rng(5)
    n = 256
    a = rng.normal(size=n) * (rng.random(n) < 0.5)
    b = rng.normal(size=n) * (rng.random(n) < 0.5)
    keys = np.arange(n, dtype=np.int32)

    def prep(x):
        nz = x != 0
        xn = x / np.linalg.norm(x)
        w = np.where(nz, xn ** 2, 0.0)
        return (jnp.asarray(w[None, :], jnp.float32), jnp.asarray(keys[None, :]),
                jnp.asarray(np.where(nz, xn, 0.0)[None, :], jnp.float32))

    m = 4096
    fpa, _, _, _ = icws_sketch_pallas(*prep(a), m=m, seed=11, interpret=True)
    fpb, _, _, _ = icws_sketch_pallas(*prep(b), m=m, seed=11, interpret=True)
    rate = np.mean(np.asarray(fpa) == np.asarray(fpb))
    wa = (a / np.linalg.norm(a)) ** 2
    wb = (b / np.linalg.norm(b)) ** 2
    jbar = np.minimum(wa, wb).sum() / np.maximum(wa, wb).sum()
    assert abs(rate - jbar) < 4.0 / np.sqrt(m) + 0.01


# ---------------------------------------------------------------------------
# CountSketch kernel
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("T,width,reps", [(1024, 128, 5), (1000, 100, 3),
                                          (4096, 512, 5), (64, 256, 2),
                                          (2048, 130, 5)])
def test_countsketch_kernel_matches_ref(T, width, reps):
    rng = np.random.default_rng(T + width)
    x = jnp.asarray(rng.normal(size=T), jnp.float32)
    tab_k = countsketch_pallas(x, width=width, reps=reps, seed=9, interpret=True)
    tab_r = ref.countsketch_ref(x, width=width, reps=reps, seed=9)
    np.testing.assert_allclose(np.asarray(tab_k), np.asarray(tab_r),
                               rtol=1e-5, atol=1e-5)


def test_countsketch_offset_consistency():
    """Sketching a long vector in two chunks with offsets == one shot."""
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=2048), jnp.float32)
    full = countsketch_pallas(x, width=256, seed=4, interpret=True)
    lo = countsketch_pallas(x[:1024], width=256, seed=4, offset=0, interpret=True)
    hi = countsketch_pallas(x[1024:], width=256, seed=4, offset=1024,
                            interpret=True)
    np.testing.assert_allclose(np.asarray(lo + hi), np.asarray(full),
                               rtol=1e-5, atol=1e-5)


def test_countsketch_linearity_and_decode():
    rng = np.random.default_rng(2)
    a = jnp.asarray(rng.normal(size=1024), jnp.float32)
    b = jnp.asarray(rng.normal(size=1024), jnp.float32)
    sa = countsketch_pallas(a, width=512, seed=3, interpret=True)
    sb = countsketch_pallas(b, width=512, seed=3, interpret=True)
    sab = countsketch_pallas(a + b, width=512, seed=3, interpret=True)
    np.testing.assert_allclose(np.asarray(sa + sb), np.asarray(sab),
                               rtol=1e-4, atol=1e-4)
    dec = ops.countsketch_decode(sa, jnp.arange(1024), seed=3)
    err = np.mean((np.asarray(dec) - np.asarray(a)) ** 2)
    assert err < np.mean(np.asarray(a) ** 2)


# ---------------------------------------------------------------------------
# Estimator kernel
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("P,m", [(8, 128), (5, 100), (16, 512), (1, 64),
                                 (9, 130)])
def test_estimate_kernel_matches_ref(P, m):
    rng = np.random.default_rng(P * 31 + m)
    fpa = rng.integers(0, 50, size=(P, m)).astype(np.int32)
    fpb = rng.integers(0, 50, size=(P, m)).astype(np.int32)
    va = rng.normal(size=(P, m)).astype(np.float32)
    vb = rng.normal(size=(P, m)).astype(np.float32)
    cnt_k, sw_k = estimate_partials_pallas(jnp.asarray(fpa), jnp.asarray(va),
                                           jnp.asarray(fpb), jnp.asarray(vb),
                                           interpret=True)
    cnt_r, sw_r = ref.estimate_partials_ref(jnp.asarray(fpa), jnp.asarray(va),
                                            jnp.asarray(fpb), jnp.asarray(vb))
    np.testing.assert_allclose(np.asarray(cnt_k), np.asarray(cnt_r))
    np.testing.assert_allclose(np.asarray(sw_k), np.asarray(sw_r), rtol=1e-4)


@pytest.mark.slow
def test_full_device_estimate_accuracy():
    """Device pipeline (sketch kernel + estimate kernel) estimates <a, b>."""
    rng = np.random.default_rng(8)
    n, m = 512, 2048
    a = rng.normal(size=n) * (rng.random(n) < 0.4)
    b = rng.normal(size=n) * (rng.random(n) < 0.4)
    keys = np.arange(n, dtype=np.int32)

    def prep(x):
        xn = x / np.linalg.norm(x)
        return (jnp.asarray(xn[None] ** 2, jnp.float32),
                jnp.asarray(keys[None]), jnp.asarray(xn[None], jnp.float32))

    fpa, va, _, _ = icws_sketch_pallas(*prep(a), m=m, seed=13, interpret=True)
    fpb, vb, _, _ = icws_sketch_pallas(*prep(b), m=m, seed=13, interpret=True)
    na = jnp.asarray([np.linalg.norm(a)], jnp.float32)
    nb = jnp.asarray([np.linalg.norm(b)], jnp.float32)
    est = float(ops.icws_estimate(fpa, va, na, fpb, vb, nb)[0])
    true = float(np.dot(a, b))
    bound = 4.0 / np.sqrt(m) * np.linalg.norm(a) * np.linalg.norm(b)
    assert abs(est - true) < bound
