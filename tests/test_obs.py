"""Observability layer: no-op guarantee, histogram algebra, exporters.

The two contracts that matter most, tested end to end through the real
serving stack: (1) with observability DISABLED (the default), every
instrumented path is a strict pass-through -- rankings are bitwise
identical with obs on and off, and the decorator adds only an enabled()
check; (2) with observability ENABLED, every launch/endpoint records into
the declared metric namespace and the trace ring, and batched==sequential
still holds through the instrumented launches.  Plus the unit algebra:
log-bucket layout, exact-window quantiles, bucketwise merge, registry
validation, quality EWMA, Chrome-trace schema, Prometheus text, snapshot
export, and the ``python -m repro.obs`` CLI.
"""
import json
import math

import numpy as np
import pytest

from repro import obs
from repro.obs import metrics as obs_metrics
from repro.obs.__main__ import main as obs_cli
from repro.obs.metrics import (N_FINITE, RECENT_WINDOW, Histogram,
                               bucket_bounds, bucket_index)
from repro.obs.quality import EWMA_ALPHA
from repro.serve import SketchSearchService


@pytest.fixture(autouse=True)
def _clean_obs_state():
    """Every test starts disabled with empty state and leaves it that way."""
    was = obs.enabled()
    obs.disable()
    obs.reset_all()
    yield
    if was:
        obs.enable()
    else:
        obs.disable()
    obs.reset_all()


# ---------------------------------------------------------------------------
# histogram bucket layout + quantiles + merge algebra
# ---------------------------------------------------------------------------

def test_bucket_index_layout():
    assert bucket_index(0.0) == 0
    assert bucket_index(-1.0) == 0
    assert bucket_index(1e-9) == 0                       # underflow
    assert bucket_index(1e9) == N_FINITE + 1             # overflow
    # monotone non-decreasing across 12 decades
    idxs = [bucket_index(10.0 ** e) for e in np.linspace(-8, 4, 200)]
    assert idxs == sorted(idxs)
    # every finite bucket's bounds actually contain values mapped to it
    for i in range(1, N_FINITE + 1):
        lo, hi = bucket_bounds(i)
        mid = math.sqrt(lo * hi)
        assert bucket_index(mid) == i, (i, lo, hi)


def test_histogram_exact_quantiles_within_window():
    h = Histogram("t")
    vals = [0.001 * (i + 1) for i in range(100)]         # fits the window
    for v in vals:
        h.record(v)
    assert h.count == 100 and len(h.recent) == 100
    assert h.quantile(0.5) == pytest.approx(vals[49])
    assert h.quantile(0.99) == pytest.approx(vals[98])
    assert h.min == pytest.approx(vals[0])
    assert h.max == pytest.approx(vals[-1])
    assert h.mean == pytest.approx(sum(vals) / 100)


def test_histogram_bucket_fallback_clamped():
    h = Histogram("t")
    for i in range(3 * RECENT_WINDOW):                   # overflow the window
        h.record(0.01 + 0.0001 * i)
    assert len(h.recent) < h.count
    q = h.quantile(0.5)
    assert h.min <= q <= h.max                           # clamped to extremes


def test_histogram_merge_algebra():
    a, b = Histogram("a"), Histogram("b")
    va = [0.001, 0.01, 0.1]
    vb = [0.002, 1.0, 10.0, 0.0005]
    for v in va:
        a.record(v)
    for v in vb:
        b.record(v)
    ref = Histogram("ref")
    for v in va + vb:
        ref.record(v)
    a.merge(b)
    assert a.count == ref.count == 7
    assert a.sum == pytest.approx(ref.sum)
    assert a.min == pytest.approx(ref.min)
    assert a.max == pytest.approx(ref.max)
    assert a.buckets == ref.buckets
    # union still fits the window => quantiles stay exact order statistics
    assert a.quantile(0.5) == pytest.approx(ref.quantile(0.5))
    d = a.as_dict()
    assert d["layout"] == obs_metrics.LAYOUT
    assert len(d["buckets"]) == N_FINITE + 2


def test_histogram_merge_rejects_layout_mismatch():
    a, b = Histogram("a"), Histogram("b")
    b.buckets = b.buckets[:-1]                           # foreign layout
    with pytest.raises(ValueError, match="layout"):
        a.merge(b)


# ---------------------------------------------------------------------------
# registry validation + family context
# ---------------------------------------------------------------------------

def test_registry_validates_name_kind_and_labels():
    with pytest.raises(KeyError, match="undeclared"):
        obs.counter("no.such_metric")
    with pytest.raises(TypeError, match="declared as"):
        obs.gauge("ops.launches_total", op="x", family="y")
    with pytest.raises(ValueError, match="requires labels"):
        obs.counter("ops.launches_total", op="x")
    c1 = obs.counter("ops.launches_total", op="x", family="y")
    c2 = obs.counter("ops.launches_total", family="y", op="x")
    assert c1 is c2                                      # one series per key
    c1.inc(3)
    assert c2.value == 3


def test_family_context_nesting():
    assert obs.current_family() == "-"
    with obs.family_context("icws"):
        assert obs.current_family() == "icws"
        with obs.family_context("ts"):
            assert obs.current_family() == "ts"
        assert obs.current_family() == "icws"
    assert obs.current_family() == "-"


# ---------------------------------------------------------------------------
# the no-op guarantee and the instrumented decorator
# ---------------------------------------------------------------------------

def test_disabled_paths_are_strict_noops():
    assert not obs.enabled()
    calls = []
    wrapped = obs.instrumented("icws_estimate")(lambda x: calls.append(x) or x)
    assert wrapped(7) == 7 and calls == [7]
    assert obs.record_sample("icws", 1.0, 2.0) is None
    s1 = obs.span("store.append", family="icws")
    s2 = obs.span("merge.merge_stores")
    assert s1 is s2                                      # shared null span
    with s1 as sp:
        sp.set("rows", 3)
    assert obs.events() == []
    assert obs.describe_metrics()["metrics"] == {}       # nothing registered


def test_instrumented_records_counts_latency_and_trace():
    obs.enable()
    wrapped = obs.instrumented("icws_estimate")(lambda: 42)
    with obs.family_context("ts"):
        assert wrapped() == 42                           # first call
        assert wrapped() == 42                           # steady state
    launches = obs.counter("ops.launches_total", op="icws_estimate",
                           family="ts")
    assert launches.value == 2
    first = obs.histogram("ops.first_call_seconds", op="icws_estimate")
    steady = obs.histogram("ops.launch_seconds", op="icws_estimate",
                           family="ts")
    assert first.count == 1 and steady.count == 1
    evts = [e for e in obs.events() if e["name"] == "ops.icws_estimate"]
    assert len(evts) == 2
    assert all(e["args"]["family"] == "ts" for e in evts)


def test_quality_ewma_arithmetic():
    obs.enable()
    # scale=1e6 => ppm == |est - ref|
    first = obs.record_sample("jl", 3.0, 1.0, scale=1e6)
    assert first == pytest.approx(2.0)
    second = obs.record_sample("jl", 6.0, 1.0, scale=1e6)
    assert second == pytest.approx(EWMA_ALPHA * 5.0 + (1 - EWMA_ALPHA) * 2.0)
    assert obs.rolling_ppm("jl") == pytest.approx(second)
    assert obs.rolling_ppm("cs") is None
    assert obs.counter("quality.samples_total", family="jl").value == 2
    assert obs.gauge("quality.ppm_error",
                     family="jl").value == pytest.approx(second)


# ---------------------------------------------------------------------------
# exporters: describe / prometheus / chrome trace / snapshot / CLI
# ---------------------------------------------------------------------------

def test_chrome_trace_schema_and_error_capture():
    obs.enable()
    with obs.span("store.append", family="icws", rows=4) as sp:
        sp.set("tenant", "a")
    with pytest.raises(RuntimeError):
        with obs.span("merge.merge_stores", family="ts"):
            raise RuntimeError("boom")
    trace = obs.chrome_trace()
    assert trace["displayTimeUnit"] == "ms"
    evts = trace["traceEvents"]
    assert [e["name"] for e in evts] == ["store.append", "merge.merge_stores"]
    for e in evts:
        assert e["ph"] == "X"
        assert isinstance(e["ts"], float) and isinstance(e["dur"], float)
        assert e["cat"] == e["name"].split(".")[0]
        json.dumps(e)                                    # fully serializable
    assert evts[0]["args"] == {"family": "icws", "rows": 4, "tenant": "a"}
    assert evts[1]["args"]["error"] == "RuntimeError"


def test_prometheus_text_format():
    obs.enable()
    obs.counter("serve.queries_total").inc(5)
    h = obs.histogram("serve.request_seconds", endpoint="search")
    h.record(0.01)
    h.record(0.02)
    text = obs.prometheus_text()
    assert "# HELP repro_serve_queries_total" in text
    assert "# TYPE repro_serve_queries_total counter" in text
    assert "repro_serve_queries_total 5" in text
    assert 'repro_serve_request_seconds_bucket{endpoint="search",le="+Inf"} 2' \
        in text
    assert 'repro_serve_request_seconds_count{endpoint="search"} 2' in text
    # cumulative bucket counts are non-decreasing
    cums = [int(line.rsplit(" ", 1)[1]) for line in text.splitlines()
            if line.startswith("repro_serve_request_seconds_bucket")]
    assert cums == sorted(cums) and cums[-1] == 2


def test_export_snapshot_and_cli(tmp_path, capsys):
    obs.enable()
    obs.counter("serve.queries_total").inc(2)
    obs.histogram("serve.request_seconds", endpoint="search").record(0.01)
    with obs.span("store.append", family="icws"):
        pass
    paths = obs.export_snapshot(str(tmp_path / "snap"))
    snap = json.loads(open(paths["metrics"]).read())
    assert snap["version"] == 1 and snap["enabled"] is True
    assert "serve.queries_total" in snap["metrics"]
    trace = json.loads(open(paths["chrome_trace"]).read())
    assert trace["traceEvents"][0]["name"] == "store.append"
    assert open(paths["jsonl"]).read().count("\n") == 1

    assert obs_cli(["show", paths["metrics"]]) == 0
    out = capsys.readouterr().out
    assert "serve.queries_total" in out and "p50=" in out

    obs.counter("serve.queries_total").inc(3)
    after = tmp_path / "after.json"
    obs.save_metrics(str(after))
    assert obs_cli(["diff", paths["metrics"], str(after)]) == 0
    out = capsys.readouterr().out
    assert "+3 (2 -> 5)" in out
    assert obs_cli(["diff", str(after), str(after)]) == 0
    assert "(no differences)" in capsys.readouterr().out


# ---------------------------------------------------------------------------
# end to end through the serving stack (jax; instrumented ops launches)
# ---------------------------------------------------------------------------

def _small_service():
    svc = SketchSearchService(m=32, seed=7, keep_host_oracle=False)
    rng = np.random.default_rng(17)
    keys = np.arange(60)
    sig = rng.normal(size=60)
    for t in range(6):
        svc.ingest(f"t{t}", keys, sig + (0.1 + 0.2 * t) * rng.normal(size=60))
    queries = [(keys, sig + 0.1 * rng.normal(size=60)) for _ in range(4)]
    return svc, queries


def test_rankings_bitwise_identical_obs_on_and_off():
    """The acceptance contract: enabling obs cannot change a single bit of
    what the instrumented launches compute."""
    svc_off, queries = _small_service()
    res_off = [svc_off.search(k, v, top_k=3, min_join=5) for k, v in queries]

    obs.enable()
    svc_on, queries_on = _small_service()
    res_on = [svc_on.search(k, v, top_k=3, min_join=5) for k, v in queries_on]
    assert res_on == res_off
    # and the telemetry actually recorded while producing identical bits
    snap = obs.describe_metrics()["metrics"]
    assert snap["ops.launches_total"]["series"]
    assert any(s["labels"]["endpoint"] == "search"
               for s in snap["serve.request_seconds"]["series"])
    assert obs.counter("serve.queries_total").value == len(queries)
    assert any(e["name"].startswith("ops.") for e in obs.events())


def test_batched_equals_sequential_with_obs_enabled():
    obs.enable()
    svc, queries = _small_service()
    seq = [svc.search(k, v, top_k=3, min_join=5) for k, v in queries]
    bat = svc.search_batch(queries, top_k=3, min_join=5, micro_batch=4)
    assert bat == seq
    assert obs.counter("serve.batch_queries_total").value == len(queries)


def test_describe_true_ints_and_latency_percentiles():
    svc, queries = _small_service()          # obs disabled: stats still work
    for k, v in queries:
        svc.search(k, v, top_k=3, min_join=5)
    d = svc.describe()
    for key in ("tables", "tenants", "corpus_rows", "queries_served"):
        assert isinstance(d[key], int), key
    assert d["tables"] == 6 and d["queries_served"] == 4
    for key in ("query_ms_p50", "query_ms_p95", "query_ms_p99"):
        assert isinstance(d[key], float) and d[key] > 0.0, key
    assert d["query_ms_p50"] <= d["query_ms_p99"]
    # private per-service stats: a second service starts from zero
    svc2, _ = _small_service()
    assert svc2.describe()["queries_served"] == 0
