"""Flash-attention Pallas kernel vs the XLA chunked-attention oracle."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention
from repro.models.attention import chunked_attention


def _qkv(rng, B, T, H, K, D, S=None, dtype=jnp.float32):
    S = S or T
    q = jnp.asarray(rng.normal(size=(B, T, H, D)), dtype)
    k = jnp.asarray(rng.normal(size=(B, S, K, D)), dtype)
    v = jnp.asarray(rng.normal(size=(B, S, K, D)), dtype)
    return q, k, v


@pytest.mark.parametrize("B,T,H,K,D,causal,window,qc,kc", [
    (2, 64, 4, 2, 16, True, 0, 16, 16),
    (1, 128, 4, 4, 32, True, 0, 64, 32),
    (2, 64, 4, 1, 16, False, 0, 32, 64),
    (1, 96, 6, 2, 16, True, 24, 32, 32),      # sliding window, ragged heads
    (1, 64, 2, 2, 64, True, 0, 64, 64),       # single chunk
])
@pytest.mark.slow
def test_flash_matches_chunked_reference(B, T, H, K, D, causal, window, qc, kc):
    rng = np.random.default_rng(B * 100 + T + H)
    q, k, v = _qkv(rng, B, T, H, K, D)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          interpret=True, qc=qc, kc=kc)
    ref = chunked_attention(q, k, v, causal=causal, window=window,
                            q_chunk=32, k_chunk=32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_dtypes(dtype):
    rng = np.random.default_rng(7)
    q, k, v = _qkv(rng, 1, 64, 4, 2, 32, dtype=dtype)
    out = flash_attention(q, k, v, causal=True, interpret=True, qc=32, kc=32)
    ref = chunked_attention(q.astype(jnp.float32), k.astype(jnp.float32),
                            v.astype(jnp.float32), causal=True,
                            q_chunk=32, k_chunk=32)
    tol = 5e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(ref),
                               rtol=tol, atol=tol)
    assert out.dtype == dtype


@pytest.mark.slow
def test_flash_block_size_invariance():
    rng = np.random.default_rng(9)
    q, k, v = _qkv(rng, 1, 128, 4, 2, 16)
    outs = [flash_attention(q, k, v, causal=True, interpret=True, qc=qc, kc=kc)
            for qc, kc in [(32, 32), (64, 16), (128, 64)]]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(o), np.asarray(outs[0]),
                                   rtol=1e-5, atol=1e-5)


def test_flash_hbm_traffic_model():
    """The kernel's HBM traffic is qkv+o only -- quantify the win over the
    XLA chain for EXPERIMENTS.md §Perf (structural, from tile counts)."""
    B, T, H, K, D = 1, 4096, 32, 4, 64
    qc = kc = 1024
    n_tiles = (T // qc) * (T // kc)
    # XLA chain (measured in HLO): ~6 materializations of each f32 score tile
    chain_bytes = n_tiles * qc * kc * 4 * 6 * (H)          # per batch, fwd
    flash_bytes = (T * H * D * 2) * 2 + (T * K * D * 2) * 2  # q+o, k+v bf16
    assert chain_bytes / flash_bytes > 20  # >20x structural reduction
