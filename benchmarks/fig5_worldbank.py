"""Paper Figure 5: winning tables on World-Bank-like column pairs.

The real study sketches 5000 random column pairs from 53 World Bank
datasets (storage 400) and buckets WMH-vs-baseline error differences by
overlap ratio and kurtosis.  Offline here, we generate heavy-tailed column
pairs with controlled overlap and outlier rate (repro.data.synthetic
.worldbank_like_pair) matching the published overlap distribution
(Table 7), and reproduce both winning tables:
    (a) WMH error - JL error   (blue = negative = WMH wins)
    (b) WMH error - MH error
Expected: WMH wins vs JL at low overlap; WMH wins vs MH everywhere, most at
high kurtosis; JL slightly wins at overlap > 0.75.
"""
from __future__ import annotations

import numpy as np

from repro.core import inner_fast, make
from repro.data.synthetic import kurtosis, worldbank_like_pair

from .common import emit, normalized_error

STORAGE = 400
OVERLAP_BUCKETS = (0.05, 0.1, 0.25, 0.5, 0.75, 1.0)
KURT_BUCKETS = (0.0, 10.0, 50.0)


def run(fast: bool = False):
    rng = np.random.default_rng(7)
    n_pairs = 30 if fast else 120
    methods = ("wmh", "jl", "mh")
    sketchers = {m: make(m, STORAGE, seed=3) for m in methods}

    rows = []
    for _ in range(n_pairs):
        ov = float(rng.choice([0.02, 0.05, 0.08, 0.15, 0.3, 0.6, 0.9]))
        out_rate = float(rng.choice([0.0, 0.02, 0.08]))
        va, vb = worldbank_like_pair(rng, overlap=ov, outlier_rate=out_rate)
        true = inner_fast(va, vb)
        kur = max(kurtosis(va), kurtosis(vb))
        errs = {}
        for m in methods:
            sk = sketchers[m]
            est = sk.estimate(sk.sketch(va), sk.sketch(vb))
            errs[m] = normalized_error(est, true, va.norm(), vb.norm())
        rows.append((ov, kur, errs))

    for baseline in ("jl", "mh"):
        for ov_max in OVERLAP_BUCKETS:
            for k_min in KURT_BUCKETS:
                sel = [e for (ov, kur, e) in rows if ov <= ov_max and kur >= k_min]
                if not sel:
                    continue
                delta = float(np.mean([e["wmh"] - e[baseline] for e in sel]))
                emit(f"fig5/wmh_minus_{baseline}/ov<{ov_max:g}/kurt>{k_min:g}",
                     0.0, f"delta={delta:+.4f} n={len(sel)} "
                          f"wmh_wins={delta < 0}")
    return rows
