"""Theory validation: Theorem 2 scaling laws, beyond the paper's figures.

  (a) error * sqrt(m) is ~flat in m   (the 1/sqrt(m) rate of Theorem 2);
  (b) WMH error / JL error tracks sqrt(gamma) as the overlap fraction gamma
      shrinks (the Section 1.2 sqrt(gamma) separation);
  (c) the ICWS variant matches paper-faithful WMH accuracy (same collision
      law) while removing the L discretization entirely.
"""
from __future__ import annotations

import numpy as np

from repro.core import inner_fast, make
from repro.data.synthetic import sparse_pair

from .common import emit, normalized_error


def run(fast: bool = False):
    rng = np.random.default_rng(17)
    trials = 3 if fast else 8

    # (a) 1/sqrt(m) rate
    rates = []
    for storage in (100, 200, 400, 800)[: 3 if fast else 4]:
        errs = []
        for t in range(trials):
            va, vb = sparse_pair(rng, overlap=0.05)
            sk = make("wmh", storage, seed=t)
            est = sk.estimate(sk.sketch(va), sk.sketch(vb))
            errs.append(normalized_error(est, inner_fast(va, vb),
                                         va.norm(), vb.norm()))
        m = sk.m
        rate = float(np.mean(errs)) * np.sqrt(m)
        rates.append(rate)
        emit(f"theory/rate/m{m}", 0.0, f"err*sqrt(m)={rate:.4f}")
    spread = max(rates) / max(min(rates), 1e-12)
    emit("theory/rate/flatness", 0.0,
         f"max_over_min={spread:.2f} (flat => ~1/sqrt(m) rate holds)")

    # (b) sqrt(gamma) separation vs linear sketching
    for gamma in (0.01, 0.04, 0.16, 0.64):
        w_err, j_err = [], []
        for t in range(trials):
            va, vb = sparse_pair(rng, overlap=gamma)
            for name, acc in (("wmh", w_err), ("jl", j_err)):
                sk = make(name, 400, seed=t)
                est = sk.estimate(sk.sketch(va), sk.sketch(vb))
                acc.append(normalized_error(est, inner_fast(va, vb),
                                            va.norm(), vb.norm()))
        ratio = float(np.mean(w_err)) / max(float(np.mean(j_err)), 1e-12)
        emit(f"theory/separation/gamma{gamma:g}", 0.0,
             f"wmh/jl={ratio:.3f} sqrt(gamma)={np.sqrt(gamma):.3f}")

    # (c) ICWS == WMH accuracy (collision-law equivalence), no L parameter
    w_errs, i_errs = [], []
    for t in range(trials * 2):
        va, vb = sparse_pair(rng, overlap=0.05)
        for name, acc in (("wmh", w_errs), ("icws", i_errs)):
            sk = make(name, 400, seed=100 + t)
            est = sk.estimate(sk.sketch(va), sk.sketch(vb))
            acc.append(normalized_error(est, inner_fast(va, vb),
                                        va.norm(), vb.norm()))
    emit("theory/icws_vs_wmh", 0.0,
         f"wmh={float(np.mean(w_errs)):.5f} icws={float(np.mean(i_errs)):.5f}")
