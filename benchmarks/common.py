"""Shared benchmark utilities: timing, CSV emission, method sweep."""
from __future__ import annotations

import time
from typing import Callable, Dict, Iterable, List

import numpy as np

from repro.core import (PAPER_METHODS, SparseVec, inner_fast, make,
                        stack_icws, stack_mh, stack_wmh)
from repro.obs.metrics import Histogram

RECORDS: List[Dict] = []


def emit(name: str, us_per_call: float, derived: str):
    RECORDS.append({"name": name, "value": float(us_per_call),
                    "derived": derived})
    print(f"{name},{us_per_call:.2f},{derived}", flush=True)


def timed(fn: Callable, *args, repeat: int = 1):
    t0 = time.perf_counter()
    out = None
    for _ in range(repeat):
        out = fn(*args)
    dt = (time.perf_counter() - t0) / repeat
    return out, dt * 1e6  # microseconds


def timed_median(fn: Callable, *args, repeat: int = 5):
    """(last result, latency Histogram) over ``repeat`` timed calls.

    The percentile-aware twin of :func:`timed` for the gated perf
    comparisons: container CPU contention makes single-shot and min-of-N
    wall clocks flaky, so gates compare ``hist.quantile(0.5)`` -- exact
    while ``repeat`` fits the histogram's raw-sample window (128).
    Seconds, not microseconds: callers scale for display.
    """
    h = Histogram("bench")
    out = None
    for _ in range(repeat):
        t0 = time.perf_counter()
        out = fn(*args)
        h.record(time.perf_counter() - t0)
    return out, h


def normalized_error(est: float, true: float, na: float, nb: float) -> float:
    """|est - true| / (||a|| ||b||): the paper's error metric (Section 5)."""
    return abs(est - true) / max(na * nb, 1e-12)


def method_errors(method: str, storage: float, pairs, seeds=range(10)) -> Dict:
    """Average normalized error over pairs x seeds for one method/storage.

    Sampling methods get a fresh seed per trial (the paper averages over 10
    independent trials); each pair is sketched and estimated.
    """
    errs = []
    sketch_us = []
    est_us = []
    for seed in seeds:
        sk = make(method, storage, seed=seed)
        for (va, vb) in pairs:
            (sa, dt1) = timed(sk.sketch, va)
            (sb, dt2) = timed(sk.sketch, vb)
            (est, dt3) = timed(sk.estimate, sa, sb)
            true = inner_fast(va, vb)
            errs.append(normalized_error(est, true, va.norm(), vb.norm()))
            sketch_us.extend([dt1, dt2])
            est_us.append(dt3)
    return {"err": float(np.mean(errs)),
            "err_std": float(np.std(errs)),
            "sketch_us": float(np.mean(sketch_us)),
            "est_us": float(np.mean(est_us))}
