"""Paper Figure 4: inner-product estimation error vs sketch storage on the
synthetic protocol (n=10000, nnz=2000, U(-1,1) values with 10% outliers in
U(20,30)), for overlap ratios {1%, 5%, 10%, 50%}.

Expected qualitative result (paper Section 5.1): WMH beats all baselines for
overlap <= 10%; at 50% linear sketching is comparable.  We also run the
beyond-paper ICWS variant.
"""
from __future__ import annotations

import numpy as np

from repro.core import PAPER_METHODS
from repro.data.synthetic import sparse_pair

from .common import emit, method_errors

OVERLAPS = (0.01, 0.05, 0.10, 0.50)
STORAGES = (100, 200, 400)
METHODS = PAPER_METHODS + ("icws",)
N_PAIRS = 4
N_SEEDS = 4


def run(fast: bool = False):
    rng = np.random.default_rng(42)
    n_pairs = 2 if fast else N_PAIRS
    seeds = range(2) if fast else range(N_SEEDS)
    storages = STORAGES[:2] if fast else STORAGES
    results = {}
    for ov in OVERLAPS:
        pairs = [sparse_pair(rng, overlap=ov) for _ in range(n_pairs)]
        for st in storages:
            for m in METHODS:
                r = method_errors(m, st, pairs, seeds=seeds)
                results[(ov, st, m)] = r["err"]
                emit(f"fig4/ov{ov:g}/s{st}/{m}", r["sketch_us"],
                     f"err={r['err']:.5f}")
    # paper claim: WMH <= linear baselines at low overlap (largest storage)
    st = storages[-1]
    for ov in (0.01, 0.05, 0.10):
        wmh, jl, cs = (results[(ov, st, k)] for k in ("wmh", "jl", "cs"))
        emit(f"fig4/claim/ov{ov:g}", 0.0,
             f"wmh={wmh:.5f} jl={jl:.5f} cs={cs:.5f} "
             f"wmh_beats_linear={wmh <= min(jl, cs) * 1.15}")
    return results
