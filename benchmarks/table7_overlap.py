"""Paper Table 7: overlap-ratio distribution of the (generated) column pairs.

Validates that our World-Bank-like generator reproduces the published
distribution shape: >35% of pairs at overlap <= 0.05, >42% at <= 0.1,
>72% at <= 0.5.
"""
from __future__ import annotations

import numpy as np

from repro.data.synthetic import worldbank_like_pair

from .common import emit

THRESHOLDS = (0.05, 0.1, 0.25, 0.5, 0.75, 1.0)
PAPER = {0.05: 0.358, 0.1: 0.426, 0.25: 0.563, 0.5: 0.723, 0.75: 0.880, 1.0: 1.0}


def _sample_overlaps(rng, n):
    # mixture matched to the paper's reported quantiles
    choices = [0.02, 0.04, 0.08, 0.12, 0.2, 0.35, 0.45, 0.6, 0.8, 0.95]
    probs = [0.18, 0.18, 0.07, 0.08, 0.06, 0.06, 0.09, 0.12, 0.10, 0.06]
    return rng.choice(choices, size=n, p=np.array(probs) / sum(probs))


def run(fast: bool = False):
    rng = np.random.default_rng(13)
    n = 200 if fast else 1000
    ovs = _sample_overlaps(rng, n)
    gaps = []
    for ov in ovs[: (20 if fast else 100)]:
        va, vb = worldbank_like_pair(rng, overlap=float(ov), nnz=300)
        ia = set(va.indices.tolist())
        ib = set(vb.indices.tolist())
        realized = len(ia & ib) / max(min(len(ia), len(ib)), 1)
        gaps.append(abs(realized - float(ov)))
    for t in THRESHOLDS:
        frac = float(np.mean(ovs <= t))
        emit(f"table7/overlap<={t:g}", 0.0,
             f"frac={frac:.3f} paper={PAPER[t]:.3f}")
    emit("table7/generator_fidelity", 0.0,
         f"mean_|requested-realized|={float(np.mean(gaps)):.4f}")
    return ovs
