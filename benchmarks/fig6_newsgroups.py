"""Paper Figure 6: TF-IDF document cosine-similarity estimation vs length.

The real study uses 700 docs from 20 Newsgroups (uni+bigram TF-IDF).
Offline proxy: Zipf-vocabulary TF-IDF corpus (repro.data.synthetic
.tfidf_corpus) over a 2^18 vocabulary; cosine == inner product of
unit-normalized vectors.  Expected: sampling sketches beat linear at this
storage; unweighted MH degrades on long documents while WMH stays accurate.
"""
from __future__ import annotations

import numpy as np

from repro.core import SparseVec, inner_fast, make
from repro.data.synthetic import tfidf_corpus

from .common import emit, normalized_error

STORAGE = 128
LEN_BUCKETS = ((0, 200), (200, 450), (450, 2200))  # unique-term counts


def run(fast: bool = False):
    rng = np.random.default_rng(11)
    docs = tfidf_corpus(rng, n_docs=30 if fast else 80)
    # normalize to unit norm => inner product == cosine
    docs = [SparseVec(indices=d.indices, values=d.values / d.norm(), n=d.n)
            for d in docs]
    lengths = [d.nnz for d in docs]
    methods = ("wmh", "mh", "jl", "cs", "kmv")
    sketchers = {m: make(m, STORAGE, seed=5) for m in methods}
    sketches = {m: [sketchers[m].sketch(d) for d in docs] for m in methods}

    n = len(docs)
    pair_idx = [(i, j) for i in range(n) for j in range(i + 1, n)]
    rng.shuffle(pair_idx)
    pair_idx = pair_idx[: (100 if fast else 500)]

    errs = {m: {b: [] for b in LEN_BUCKETS} for m in methods}
    for (i, j) in pair_idx:
        true = inner_fast(docs[i], docs[j])
        min_len = min(lengths[i], lengths[j])
        bucket = next(b for b in LEN_BUCKETS if b[0] <= min_len < b[1])
        for m in methods:
            est = sketchers[m].estimate(sketches[m][i], sketches[m][j])
            errs[m][bucket].append(abs(est - true))  # unit vectors: already normalized

    for b in LEN_BUCKETS:
        for m in methods:
            if errs[m][b]:
                emit(f"fig6/len{b[0]}-{b[1]}/{m}", 0.0,
                     f"cos_err={float(np.mean(errs[m][b])):.5f} n={len(errs[m][b])}")
    # paper claim: WMH stays accurate on long docs where MH degrades
    long_b = LEN_BUCKETS[-1]
    if errs["wmh"][long_b] and errs["mh"][long_b]:
        w = float(np.mean(errs["wmh"][long_b]))
        u = float(np.mean(errs["mh"][long_b]))
        emit("fig6/claim/long_docs", 0.0,
             f"wmh={w:.5f} mh={u:.5f} wmh_better={w <= u}")
    return errs
