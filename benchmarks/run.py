"""Benchmark driver: one module per paper table/figure + theory + perf.

Prints ``name,us_per_call,derived`` CSV rows (see benchmarks.common.emit).
``--fast`` shrinks trial counts for CI; the default sizes reproduce the
paper's qualitative results.  ``--json PATH`` additionally dumps every
emitted row as a JSON artifact (CI uploads ``BENCH_sketch.json`` from the
perf suite's smoke run).
"""
from __future__ import annotations

import argparse
import json
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset: fig4,fig5,fig6,table7,theory,perf")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write emitted rows as a JSON benchmark artifact")
    args = ap.parse_args()

    from . import (common, fig4_synthetic, fig5_worldbank, fig6_newsgroups,
                   perf_sketch, table7_overlap, theory_check)
    suites = {
        "fig4": fig4_synthetic.run,
        "fig5": fig5_worldbank.run,
        "fig6": fig6_newsgroups.run,
        "table7": table7_overlap.run,
        "theory": theory_check.run,
        "perf": perf_sketch.run,
    }
    only = ([s.strip() for s in args.only.split(",") if s.strip()]
            if args.only is not None else list(suites))
    # validate up front: a typo'd suite name must fail with a clear error
    # before any suite runs, not as a bare KeyError mid-run after the
    # header row is printed
    unknown = [s for s in only if s not in suites]
    if unknown:
        ap.error(f"unknown suite(s) for --only: {', '.join(unknown)} "
                 f"(choose from: {', '.join(suites)})")
    if not only:
        ap.error(f"--only selected no suites (choose from: {', '.join(suites)})")
    print("name,us_per_call,derived")
    t0 = time.time()
    durations = {}
    failures = {}
    for name in only:
        t = time.time()
        # A suite's gated assertion (accuracy/monotonicity checks) must not
        # abort the run before later suites execute and the JSON artifact is
        # written -- CI uploads the artifact from failed runs too.  Record
        # the failure, keep going, and propagate a nonzero exit at the end.
        try:
            suites[name](fast=args.fast)
        except AssertionError as e:
            failures[name] = f"{type(e).__name__}: {e}"
            print(f"# {name} FAILED: {failures[name]}", flush=True)
        durations[name] = time.time() - t
        print(f"# {name} done in {durations[name]:.1f}s", flush=True)
    print(f"# total {time.time()-t0:.1f}s")
    # with REPRO_OBS=1 the run doubles as a telemetry capture: export the
    # metrics snapshot + Chrome trace next to the JSON artifact (dir from
    # REPRO_OBS_DIR, default obs_snapshot/).  Written even when suites
    # failed -- the trace of a failed run is the one worth reading.
    from repro import obs
    if obs.enabled():
        paths = obs.export_snapshot()
        for kind, path in sorted(paths.items()):
            print(f"# obs {kind}: {path}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"fast": bool(args.fast), "suites": only,
                       "suite_seconds": durations, "failures": failures,
                       "rows": common.RECORDS}, f, indent=2)
        print(f"# wrote {len(common.RECORDS)} rows to {args.json}")
    if failures:
        print(f"# {len(failures)} suite(s) failed: "
              f"{', '.join(sorted(failures))}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
